"""End-to-end training driver (deliverable b): train a decoder LM for a few
hundred steps with the full substrate — fault-tolerant supervisor, atomic
checkpoints, stateless-indexable data pipeline, cosine schedule.

Presets:
    tiny  (~11M params)  — finishes a few hundred steps on this CPU container
    100m  (~124M params) — the deliverable scale; same code path, use on a
                           real machine (or be very patient on CPU)

    PYTHONPATH=src python examples/train_lm.py --preset tiny --steps 300
"""
import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.data import pipeline as data_lib
from repro.runtime.fault_tolerance import FaultToleranceConfig, Supervisor
from repro.train import loop as train_loop, optimizer as opt_lib

PRESETS = {
    # (layers, d_model, heads, kv, head_dim, d_ff, vocab, seq, batch)
    "tiny": (4, 256, 4, 2, 64, 1024, 4096, 128, 8),
    "100m": (12, 768, 12, 4, 64, 3072, 16384, 512, 16),
}


def make_cfg(preset: str):
    L, d, H, KV, hd, ff, V, seq, batch = PRESETS[preset]
    base = get_config("qwen2.5-3b")       # plain GQA decoder family
    cfg = dataclasses.replace(
        base, name=f"lm-{preset}", num_layers=L, d_model=d, num_heads=H,
        num_kv_heads=KV, head_dim=hd, d_ff=ff, vocab_size=V, qkv_bias=False,
        max_seq_len=seq)
    return cfg, seq, batch


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--preset", choices=list(PRESETS), default="tiny")
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--ckpt-dir", default="results/ckpt/train_lm")
    ap.add_argument("--log-every", type=int, default=20)
    args = ap.parse_args()

    cfg, seq, batch = make_cfg(args.preset)
    print(f"{cfg.name}: {cfg.param_count():,} params, "
          f"{batch}x{seq} tokens/step, {args.steps} steps")

    ocfg = opt_lib.OptimizerConfig(peak_lr=args.lr, warmup_steps=20,
                                   total_steps=args.steps)
    step_jit = jax.jit(train_loop.make_train_step(cfg, ocfg),
                       donate_argnums=(0, 1))
    dcfg = data_lib.DataConfig(seq_len=seq, global_batch=batch,
                               vocab_size=cfg.vocab_size)

    def data_fn(step):
        # narrow synthetic distribution => the LM can actually learn it
        b = data_lib.synth_batch(dataclasses.replace(dcfg, seed=step % 64),
                                 step=0)
        return {k: jnp.asarray(v) for k, v in b.items()}

    def step_fn(state, b):
        p, o = state
        p, o, m = step_jit(p, o, b)
        return (p, o), m

    def init_fn():
        return train_loop.init_train_state(jax.random.PRNGKey(0), cfg)

    sup = Supervisor(
        FaultToleranceConfig(checkpoint_dir=args.ckpt_dir,
                             checkpoint_every=100),
        step_fn, data_fn, init_fn)
    t0 = time.time()
    result = sup.run(args.steps)
    dt = time.time() - t0

    losses = [m["loss"] for m in result["metrics"]]
    for m in result["metrics"]:
        if m["step"] % args.log_every == 0 or m["step"] == args.steps - 1:
            print(f"step {m['step']:5d} loss={m['loss']:.4f} "
                  f"acc={m['accuracy']:.3f} lr={m['lr']:.2e}")
    first, last = np.mean(losses[:10]), np.mean(losses[-10:])
    toks = args.steps * batch * seq
    print(f"\nloss {first:.3f} -> {last:.3f} "
          f"({'LEARNING' if last < first * 0.95 else 'no descent!'}); "
          f"{toks / dt:.0f} tok/s on {jax.devices()[0].platform}")


if __name__ == "__main__":
    main()
