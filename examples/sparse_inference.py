"""Sparse processing demo (paper §IV): prune a model's MLP weights, encode
them block-CSC, and run the sparse Pallas kernel — zero blocks are skipped
entirely, the TPU-native analogue of the PE's cycle skipping.

    PYTHONPATH=src python examples/sparse_inference.py --sparsity 0.75
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.core import sparsity as sp
from repro.kernels import bcsc_matmul, ops, ref
from repro.models import transformer as tfm


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--sparsity", type=float, default=0.75)
    ap.add_argument("--block", type=int, default=16)
    args = ap.parse_args()

    cfg = get_config("qwen2.5-3b-reduced")
    params = tfm.init_params(jax.random.PRNGKey(0), cfg)

    # take one MLP up-projection and block-prune it (structured so the BCSC
    # skip translates to real MXU-tile savings)
    w = params["blocks"]["slot0"]["mlp"]["wg"][0]     # (d, ff)
    bk = bn = args.block
    w_pruned = sp.block_magnitude_prune(w, args.sparsity, bk, bn)
    m = sp.bcsc_encode(np.asarray(w_pruned), bk, bn)
    csc = sp.csc_encode((np.asarray(w_pruned) != 0).astype(np.int64))

    nb_total = (w.shape[0] // bk) * (w.shape[1] // bn)
    print(f"weight {w.shape}: {args.sparsity:.0%} block-pruned")
    print(f"  BCSC: {m.nnzb}/{nb_total} blocks kept "
          f"(skip ratio {1 - m.density:.0%})")
    quantized = (np.asarray(w_pruned) * 100).astype(np.int64)  # int8-ish view
    print(f"  scalar-CSC compression ratio: "
          f"{sp.csc_encode(quantized).compression_ratio():.2f}x")

    # run the sparse kernel vs the dense oracle
    x = jnp.asarray(np.random.default_rng(1).standard_normal(
        (32, w.shape[0])), jnp.float32)
    y_sparse = ops.bcsc_matmul(x, m)
    y_dense = ref.matmul_ref(x, w_pruned)
    err = float(jnp.max(jnp.abs(y_sparse - y_dense)))
    print(f"  sparse-kernel max|err| vs dense oracle: {err:.2e}")

    # grid-step accounting: the §IV claim — work scales with nnzb
    dense_steps = nb_total
    print(f"  kernel grid steps: {m.nnzb} sparse vs {dense_steps} dense "
          f"({dense_steps / max(m.nnzb, 1):.1f}x fewer)")

    # batch-1 decode shape: ops dispatches to the bcsc_gemv fast path
    # (fp32 VMEM scratch accumulator + fused activation epilogue, DESIGN.md §2)
    from repro.core import dataflow
    x1 = x[:1]
    assert dataflow.matmul_path(x1.shape[0]) == "gemv"
    y1 = ops.bcsc_gemv(x1, m, activation="silu")
    err1 = float(jnp.max(jnp.abs(y1 - jax.nn.silu(y_dense[:1]))))
    print(f"  batch-1 GEMV path (fused silu): max|err| {err1:.2e}; "
          f"{m.nnzb} grid steps vs {dense_steps} dense")


if __name__ == "__main__":
    main()
