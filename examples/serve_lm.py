"""Streaming LM serving through the `repro.serve.LLM` facade (ISSUE 5).

The canonical serving entry point: resolve a ServePlan ONCE from the model
config and the serving budget (`core.plan.plan_serve` — every dispatch
decision with its Eyexam-style bound rationale), hand it to `LLM`, and
stream. Requests arrive on a Poisson process, share a page pool provisioned
*below* the dense worst case, and stream tokens through per-request
callbacks as they are generated.

The facade serves behind the robustness guard (ISSUE 6) by default: every
request ends in a structured outcome (ok/shed/expired/preempted_out/failed)
delivered via ``on_outcome``, overload degrades along the plan's ladder
(int8 KV -> clamp -> shed) instead of raising, and ``--ttl`` attaches a
deadline in decode steps to every request.

With ``--replicas N`` the same facade serves through the multi-replica
control plane (ISSUE 7): a router places requests by prefix affinity and
measured queue depth across N scheduler replicas on one shared virtual
clock, heartbeats are audited every sync window, and ``--kill-replica-at
STEP`` chaos-kills replica 0 mid-run — stranded requests migrate by
recompute and every request still ends in exactly one outcome.

``--trace out.json`` writes the run's step-clock trace (ISSUE 8) as Chrome
``trace_event`` JSON — open it at https://ui.perfetto.dev (or
chrome://tracing): replicas render as processes, requests as threads, one
virtual decode step as 1 ms. The trace structure is deterministic (wall
time rides along as annotations), and the end-of-run drift report diffs
measured occupancy/length/route proxies against the plan's decisions.

``--mesh tp=2,ep=4`` serves mesh-sharded (ISSUE 10): attention KV heads
shard over ``tp`` per-device page pools and MoE experts over ``ep``, the
plan's explain() gains the mesh/NoC-mode decisions, and the report prints
per-device pool bytes and collective traffic. Token streams stay
bit-identical to the single-device run.

    PYTHONPATH=src python examples/serve_lm.py --requests 12 --rows 4
    PYTHONPATH=src python examples/serve_lm.py --mean-gap 1 --ttl 40
    PYTHONPATH=src python examples/serve_lm.py --replicas 3 \\
        --kill-replica-at 8
    PYTHONPATH=src python examples/serve_lm.py --trace trace.json
    PYTHONPATH=src python examples/serve_lm.py --mesh tp=2
    PYTHONPATH=src python examples/serve_lm.py --arch mixtral-8x7b \\
        --mesh tp=2,ep=4
"""
import argparse
import json
import time

import jax
import numpy as np

from repro.configs import get_config
from repro.core import dataflow, plan as plan_lib
from repro.models import transformer as tfm
from repro.serve import LLM
from repro.serve.scheduler import StreamRequest


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma2-2b")
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--rows", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=24)
    ap.add_argument("--cache-len", type=int, default=96)
    ap.add_argument("--page-size", type=int, default=16)
    ap.add_argument("--mean-gap", type=float, default=4.0,
                    help="mean Poisson inter-arrival gap, in decode steps")
    ap.add_argument("--prefix-len", type=int, default=16,
                    help="shared system-prompt prefix length (0 disables); "
                         "CoW prefix sharing stores it once across requests")
    ap.add_argument("--kv-quant", choices=["fp", "int8"], default=None,
                    help="page payload format (default: plan rule)")
    ap.add_argument("--spec-k", type=int, default=None,
                    help="speculative draft depth per round (0 disables; "
                         "default: plan rule — on at batch 1 where the "
                         "weight stream dominates). Needs an all-global-"
                         "attention arch (e.g. --arch qwen2.5-3b) on fp "
                         "pages; greedy outputs stay bit-identical")
    ap.add_argument("--ttl", type=float, default=None,
                    help="per-request deadline in decode steps from arrival "
                         "(unfinished requests resolve `expired`)")
    ap.add_argument("--replicas", type=int, default=1,
                    help="scheduler replicas behind the router (>1 serves "
                         "through the multi-replica control plane)")
    ap.add_argument("--kill-replica-at", type=float, default=None,
                    help="chaos-kill replica 0 at this virtual step "
                         "(requires --replicas > 1); stranded requests "
                         "migrate by recompute")
    ap.add_argument("--mesh", default=None, metavar="tp=2,ep=4",
                    help="serve mesh-sharded (ISSUE 10): tp shards "
                         "attention KV heads over per-device page pools, "
                         "ep shards the MoE expert axis (needs an MoE arch "
                         "e.g. --arch mixtral-8x7b). Token streams stay "
                         "bit-identical to single-device")
    ap.add_argument("--trace", metavar="OUT.json", default=None,
                    help="write the step-clock trace as Chrome trace_event "
                         "JSON (load at https://ui.perfetto.dev)")
    args = ap.parse_args()
    if args.kill_replica_at is not None and args.replicas < 2:
        ap.error("--kill-replica-at needs --replicas > 1 (killing the "
                 "only replica just respawns it)")

    cfg = get_config(args.arch + "-reduced")
    params = tfm.init_params(jax.random.PRNGKey(0), cfg)

    # resolve every dispatch decision once: pool provisioned for ~half-slot
    # expected occupancy (paging + preemption make under-provisioning safe)
    plan = plan_lib.plan_serve(
        cfg,
        hbm_budget_bytes=args.rows * 2 ** 30,     # demo-scale budget
        expected_batch=args.rows,
        expected_len_dist={"mean": args.cache_len // 2,
                           "max": args.cache_len},
        page_size=args.page_size,
        num_pages=max(args.rows * dataflow.pages_for(
            args.cache_len, args.page_size) // 2, 1),
        kv_quant=args.kv_quant,
        spec_k=args.spec_k,
        mesh=args.mesh)
    print(plan.explain())
    print()

    llm = LLM(cfg, params, plan, eos_id=1,   # guard on by default
              replicas=args.replicas)

    def finished(req, outcome):
        if not outcome.ok:
            why = f" ({outcome.reason})" if outcome.reason else ""
            print(f"  req {req.rid} -> {outcome.status}{why}")

    rng = np.random.default_rng(0)
    arrivals = np.cumsum(rng.exponential(args.mean_gap, args.requests))
    first_tokens = {}

    def stream(req, tok):
        if req.rid not in first_tokens:
            first_tokens[req.rid] = tok
            print(f"  req {req.rid} (arrived t={req.arrival:.0f}, admitted "
                  f"t={req.admitted_at:.0f}) first token: {tok}")

    # shared system-prompt prefix: CoW sharing stores its pages once,
    # refcounted across every live request
    prefix = list(rng.integers(2, cfg.vocab_size, args.prefix_len))
    reqs = [StreamRequest(rid=i,
                          prompt=prefix + list(
                              rng.integers(2, cfg.vocab_size,
                                           rng.integers(4, 12))),
                          max_new=int(rng.integers(4, args.max_new + 1)),
                          arrival=float(arrivals[i]),
                          ttl=args.ttl,
                          on_token=stream)
            for i in range(args.requests)]

    chaos = None
    if args.kill_replica_at is not None:
        from repro.serve.chaos import ReplicaChaosConfig
        chaos = ReplicaChaosConfig(
            kill_at_step={0: args.kill_replica_at})

    t0 = time.time()
    done = llm.stream(reqs, on_outcome=finished, chaos=chaos)
    dt = time.time() - t0
    new_toks = sum(len(r.out) for r in done)
    st = llm.phase_stats
    fleet = st.get("fleet", st)   # multi-replica aggregates live in "fleet"
    lat = [r.finished_at - r.arrival for r in done]
    print(f"{len(done)} requests, {new_toks} tokens in {dt:.1f}s "
          f"({new_toks / dt:.1f} tok/s wall; "
          f"{new_toks / max(st['clock_steps'], 1):.2f} tok/step)")
    print(f"latency p50 {np.percentile(lat, 50):.0f} / "
          f"p99 {np.percentile(lat, 99):.0f} steps; "
          f"preemptions {fleet['preemptions']}")
    if args.replicas > 1:
        ro = st["router"]
        print(f"fleet: {st['replicas_spawned']} replicas spawned, "
              f"{st['replicas_final']} live at end; "
              f"failovers {st['failovers']}"
              + (f" {st['failover_reasons']}" if st["failovers"] else "")
              + f", {st['migrated_requests']} requests migrated")
        print(f"router: {ro['affinity_hits']}/{ro['placements']} "
              f"placements hit prefix affinity "
              f"({fleet['shared_tokens_admitted']} prompt tokens adopted "
              f"from shared pages)")
    if st.get("spec_rounds"):
        print(f"speculation: k={st['spec_k']}, {st['spec_rounds']} verify "
              f"rounds retired {st['spec_accepted_tokens']}/"
              f"{st['spec_drafted_tokens']} drafted tokens "
              f"({st['spec_accepted_tokens'] / st['spec_rounds']:.2f} "
              f"tokens/dispatch)")
    print(f"outcomes: " + ", ".join(
        f"{k} {v}" for k, v in st["outcomes"].items() if v))
    pg = st.get("pages_peak")
    if pg:
        print(f"pages at peak: {pg['pages_used']}/{pg['pages_total']} in "
              f"use ({pg['used_tokens']} tokens), "
              f"fragmentation {pg['fragmentation']:.2f}, "
              f"{pg['shared_pages']} shared "
              f"(saved {pg['pages_saved_sharing']} pages)")
        print(f"sharing: {st['shared_tokens_admitted']} prompt tokens "
              f"admitted from adopted pages, {st['cow_copies']} CoW copies, "
              f"peak concurrency {st['peak_live_rows']} rows")

    if plan.sharded:
        rep = llm.sharding_report()
        snap = llm.telemetry().metrics.snapshot()
        print(f"mesh: {llm.mesh.describe()}")
        if rep.get("kv_bytes_per_device"):
            print(f"  pool/device {rep['kv_bytes_per_device']:,} B "
                  f"(single-device {rep['kv_bytes_single_device']:,} B, "
                  f"1/{plan.tp} KV heads each), lockstep divergence "
                  f"{rep.get('lockstep_divergence', 0)}")
        print(f"  collectives: {snap.counters['collective_ops']:.0f} "
              f"all-gathers, "
              f"{snap.counters['collective_allgather_bytes']:,.0f} B "
              f"({snap.counters['collective_allgather_bytes'] / max(new_toks, 1):,.0f} B/token)")

    tel = llm.telemetry()
    if tel.last_drift is not None:
        d = tel.last_drift
        print(f"plan drift: {len(d.confirmed)} CONFIRMED / "
              f"{len(d.findings)} compared over {d.windows} windows"
              + (" — " + "; ".join(f"{f.decision}.{f.metric}"
                                   for f in d.confirmed)
                 if d.confirmed else ""))
    if args.trace:
        with open(args.trace, "w") as f:
            json.dump(tel.tracer.to_chrome_trace(), f)
        print(f"wrote {len(tel.tracer.events)} spans to {args.trace} "
              f"(open at https://ui.perfetto.dev)")


if __name__ == "__main__":
    main()
