"""Streaming serving on the paged continuous-batching scheduler (ISSUE 3).

Requests arrive on a Poisson process, stream tokens through per-request
callbacks as they are generated, and share a page pool provisioned *below*
the dense worst case — the block-table indirection is what turns short
requests' stranded HBM into extra batch rows.

    PYTHONPATH=src python examples/serve_lm.py --requests 12 --rows 4
"""
import argparse
import time

import jax
import numpy as np

from repro.configs import get_config
from repro.models import transformer as tfm
from repro.serve.scheduler import ContinuousBatchingScheduler, StreamRequest


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma2-2b")
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--rows", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=24)
    ap.add_argument("--cache-len", type=int, default=96)
    ap.add_argument("--page-size", type=int, default=16)
    ap.add_argument("--mean-gap", type=float, default=4.0,
                    help="mean Poisson inter-arrival gap, in decode steps")
    ap.add_argument("--prefix-len", type=int, default=16,
                    help="shared system-prompt prefix length (0 disables); "
                         "CoW prefix sharing stores it once across requests")
    ap.add_argument("--kv-quant", choices=["fp", "int8"], default=None,
                    help="page payload format (default: dataflow rule)")
    args = ap.parse_args()

    cfg = get_config(args.arch + "-reduced")
    params = tfm.init_params(jax.random.PRNGKey(0), cfg)

    # pool provisioned at half the dense (rows x cache_len) worst case —
    # paging + preemption make that safe
    from repro.core import dataflow
    num_pages = max(args.rows * dataflow.pages_for(
        args.cache_len, args.page_size) // 2, 1)
    sch = ContinuousBatchingScheduler(
        cfg, params, rows=args.rows, cache_len=args.cache_len,
        page_size=args.page_size, num_pages=num_pages, eos_id=1,
        kv_quant=args.kv_quant)
    print(f"attn path: {'paged' if sch.paged else 'contiguous'} "
          f"({num_pages} pages x {sch.page_size} tokens, kv {sch.kv_quant}, "
          f"prefix sharing {'on' if sch.share_prefix else 'off'} vs dense "
          f"{args.rows} x {args.cache_len})")

    rng = np.random.default_rng(0)
    arrivals = np.cumsum(rng.exponential(args.mean_gap, args.requests))
    first_tokens = {}

    def stream(req, tok):
        if req.rid not in first_tokens:
            first_tokens[req.rid] = tok
            print(f"  req {req.rid} (arrived t={req.arrival:.0f}, admitted "
                  f"t={req.admitted_at:.0f}) first token: {tok}")

    # shared system-prompt prefix: CoW sharing stores its pages once,
    # refcounted across every live request
    prefix = list(rng.integers(2, cfg.vocab_size, args.prefix_len))
    reqs = [StreamRequest(rid=i,
                          prompt=prefix + list(
                              rng.integers(2, cfg.vocab_size,
                                           rng.integers(4, 12))),
                          max_new=int(rng.integers(4, args.max_new + 1)),
                          arrival=float(arrivals[i]),
                          on_token=stream)
            for i in range(args.requests)]

    t0 = time.time()
    done = sch.run(reqs)
    dt = time.time() - t0
    new_toks = sum(len(r.out) for r in done)
    st = sch.phase_stats
    lat = [r.finished_at - r.arrival for r in done]
    print(f"{len(done)} requests, {new_toks} tokens in {dt:.1f}s "
          f"({new_toks / dt:.1f} tok/s wall; "
          f"{new_toks / max(st['clock_steps'], 1):.2f} tok/step)")
    print(f"latency p50 {np.percentile(lat, 50):.0f} / "
          f"p99 {np.percentile(lat, 99):.0f} steps; "
          f"preemptions {st['preemptions']}")
    pg = sch.phase_stats.get("pages_peak")
    if pg:
        print(f"pages at peak: {pg['pages_used']}/{pg['pages_total']} in "
              f"use ({pg['used_tokens']} tokens), "
              f"fragmentation {pg['fragmentation']:.2f}, "
              f"{pg['shared_pages']} shared "
              f"(saved {pg['pages_saved_sharing']} pages)")
        print(f"sharing: {st['shared_tokens_admitted']} prompt tokens "
              f"admitted from adopted pages, {st['cow_copies']} CoW copies, "
              f"peak concurrency {st['peak_live_rows']} rows")


if __name__ == "__main__":
    main()
