"""Batched serving with continuous slot refill (deliverable b, serving kind).

    PYTHONPATH=src python examples/serve_lm.py --requests 12 --slots 4
"""
import argparse
import time

import jax
import numpy as np

from repro.configs import get_config
from repro.models import transformer as tfm
from repro.serve import kvcache
from repro.serve.engine import DecodeEngine, Request


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma2-2b")
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=24)
    ap.add_argument("--cache-len", type=int, default=96)
    args = ap.parse_args()

    cfg = get_config(args.arch + "-reduced")
    params = tfm.init_params(jax.random.PRNGKey(0), cfg)

    # GLB-capacity analogue: how many slots fit the cache budget? (§II)
    rep = kvcache.report(cfg, batch=args.slots, cache_len=args.cache_len,
                         chips=1)
    print(f"cache: {rep['total_gb'] * 1e3:.2f} MB for {args.slots} slots "
          f"x {args.cache_len} ctx")

    rng = np.random.default_rng(0)
    reqs = [Request(rid=i,
                    prompt=list(rng.integers(2, cfg.vocab_size,
                                             rng.integers(4, 12))),
                    max_new=args.max_new)
            for i in range(args.requests)]

    eng = DecodeEngine(cfg, params, slots=args.slots,
                       cache_len=args.cache_len, eos_id=1)
    t0 = time.time()
    done = eng.run(reqs)
    dt = time.time() - t0
    new_toks = sum(len(r.out) for r in done)
    print(f"{len(done)} requests, {new_toks} new tokens in {dt:.1f}s "
          f"({new_toks / dt:.1f} tok/s, batch-of-{args.slots} continuous)")
    for r in done[:3]:
        print(f"  req {r.rid}: prompt[{len(r.prompt)}] -> {r.out[:10]}...")


if __name__ == "__main__":
    main()
