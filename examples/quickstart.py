"""Quickstart: the public API in ~60 lines.

    PYTHONPATH=src python examples/quickstart.py

Builds a reduced gemma2-family model, plans its sharding with the HM-mesh
planner (the paper's per-layer NoC configuration), trains a few steps, and
greedily decodes a few tokens.
"""
import jax
import jax.numpy as jnp

from repro.configs import SHAPES, get_config
from repro.core import planner
from repro.data import pipeline as data_lib
from repro.launch.cell import mesh_desc
from repro.launch.mesh import make_local_mesh
from repro.models import transformer as tfm
from repro.serve.engine import DecodeEngine, Request
from repro.train import loop as train_loop, optimizer as opt_lib


def main():
    # 1. pick an architecture (any of the 10 assigned ids; -reduced = CPU-size)
    cfg = get_config("gemma2-2b-reduced")
    print(f"arch={cfg.name}: {cfg.num_layers}L d={cfg.d_model} "
          f"params={cfg.param_count():,}")

    # 2. the planner decides the per-layer NoC/sharding modes (paper Fig. 9)
    mesh = make_local_mesh()
    plan = planner.plan_model(cfg, SHAPES["train_4k"], mesh_desc(mesh))
    print("planner:", plan.describe().splitlines()[0])

    # 3. train a few steps on synthetic data
    params, opt_state = train_loop.init_train_state(jax.random.PRNGKey(0), cfg)
    step = jax.jit(train_loop.make_train_step(
        cfg, opt_lib.OptimizerConfig(peak_lr=1e-3, warmup_steps=2,
                                     total_steps=20)))
    for i in range(8):
        batch = {k: jnp.asarray(v) for k, v in data_lib.batch_for_arch(
            cfg, seq_len=64, global_batch=4, step=i).items()}
        params, opt_state, metrics = step(params, opt_state, batch)
        if i % 2 == 0:
            print(f"step {i}: loss={float(metrics['loss']):.3f}")

    # 4. serve greedily from the trained weights (plan-driven dispatch)
    from repro.core import plan as plan_lib
    eng = DecodeEngine(cfg, params,
                       plan_lib.plan_for_engine(cfg, slots=2, cache_len=48),
                       eos_id=-1)
    done = eng.run([Request(0, [5, 6, 7], max_new=8)])
    print("decoded:", done[0].out)


if __name__ == "__main__":
    main()
