"""Sequence-sharded flash attention (§Perf A1): exact parity with the
unsharded path on a real multi-device mesh. Runs in a subprocess because the
host device count must be set before jax initializes."""
import os
import subprocess
import sys

import pytest

_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import PartitionSpec as P
from repro.models import flash
from repro.sharding.collectives import shard_map

try:
    from jax.sharding import AxisType
    _mesh_kw = {"axis_types": (AxisType.Auto,) * 2}
except ImportError:  # jax < 0.5 — Auto is the only mesh kind
    _mesh_kw = {}

mesh = jax.make_mesh((2, 4), ("data", "model"), **_mesh_kw)
B, KV, R, S, D = 2, 2, 2, 64, 16
rng = np.random.default_rng(0)
q = jnp.asarray(rng.standard_normal((B, KV, R, S, D)), jnp.float32)
k = jnp.asarray(rng.standard_normal((B, KV, S, D)), jnp.float32)
v = jnp.asarray(rng.standard_normal((B, KV, S, D)), jnp.float32)
w = jnp.asarray(rng.standard_normal((B, KV, R, S, D)), jnp.float32)

def seq_sharded(qf, kf, vf, mode, msize):
    S_loc = S // 4
    def body(q_loc, k_full, v_full):
        off = jax.lax.axis_index("model") * S_loc
        qpos = off + jnp.arange(S_loc, dtype=jnp.int32)
        return flash.flash_attention(q_loc, k_full, v_full, mode, msize,
                                     0.0, 16, 16, qpos=qpos)
    return shard_map(
        body, mesh=mesh,
        in_specs=(P("data", None, None, "model", None),
                  P("data", None, None, None), P("data", None, None, None)),
        out_specs=P("data", None, None, "model", None),
        check_vma=False)(qf, kf, vf)

for mode, msize in [("causal", S), ("window", 12), ("chunk", 16)]:
    ref = flash.flash_attention(q, k, v, mode, msize, 0.0, 16, 16)
    got = jax.jit(lambda a, b, c: seq_sharded(a, b, c, mode, msize))(q, k, v)
    assert float(jnp.max(jnp.abs(got.astype(jnp.float32) -
                                 ref.astype(jnp.float32)))) == 0.0, mode
    for arg in range(3):
        g1 = jax.grad(lambda *xs: jnp.sum(flash.flash_attention(
            *xs, mode, msize, 0.0, 16, 16).astype(jnp.float32) * w),
            argnums=arg)(q, k, v)
        g2 = jax.grad(lambda *xs: jnp.sum(jax.jit(
            lambda a, b, c: seq_sharded(a, b, c, mode, msize)
        )(*xs).astype(jnp.float32) * w), argnums=arg)(q, k, v)
        assert float(jnp.max(jnp.abs(g1 - g2))) == 0.0, (mode, arg)
print("SEQSHARD_OK")
"""


def test_seq_sharded_flash_parity_8dev():
    env = dict(os.environ, PYTHONPATH="src")
    out = subprocess.run([sys.executable, "-c", _SCRIPT], env=env,
                         capture_output=True, text=True, timeout=600,
                         cwd=os.path.dirname(os.path.dirname(
                             os.path.abspath(__file__))))
    assert "SEQSHARD_OK" in out.stdout, out.stdout + out.stderr
