"""Observability suite (ISSUE 8): deterministic step-clock tracing, the
frozen metrics registry, phase timers, the shared heartbeat schema, and
Eyexam-at-runtime plan-drift detection.

The load-bearing invariants:

* trace *structure* is a pure function of the seed — two same-seed runs
  (including chaos runs, single-scheduler and multi-replica) produce
  byte-identical Chrome traces once wall-clock annotations are stripped;
* the metric key set is frozen — adding or removing a key silently fails
  the pinned-key test, and writing an undeclared name raises;
* a seeded mispredicted-occupancy scenario yields a DriftReport that names
  the divergent plan Decision, and an accurate plan yields a clean report.
"""
import json

import jax
import pytest

from repro.configs import get_config
from repro.core import plan as plan_lib
from repro.models import transformer as tfm
from repro.runtime.fault_tolerance import FaultToleranceConfig, Supervisor
from repro.serve import LLM, telemetry
from repro.serve.chaos import ChaosConfig, ReplicaChaosConfig
from repro.serve.scheduler import ContinuousBatchingScheduler, StreamRequest


@pytest.fixture(scope="module")
def model():
    cfg = get_config("qwen2.5-3b-reduced")
    params = tfm.init_params(jax.random.PRNGKey(0), cfg)
    return cfg, params


def _reqs(n=6, max_new=6, tenants=2):
    return [StreamRequest(rid=i, prompt=[3 + i % 4, 5, 7], max_new=max_new,
                          arrival=float(i), tenant="t%d" % (i % tenants))
            for i in range(n)]


def _plan(cfg, mean=10, cache_len=64):
    # page_size=4 keeps expected occupancy below PAGED_OCCUPANCY_MAX so the
    # plan resolves the paged path (the drift comparisons' richest case)
    return plan_lib.plan_serve(
        cfg, hbm_budget_bytes=1 << 30, expected_batch=3,
        expected_len_dist={"mean": mean, "max": cache_len}, page_size=4,
        sync_every=4)


# ------------------------------------------------------------------- tracer
def test_tracer_records_events_and_spans():
    tr = telemetry.Tracer()
    tr.event("queued", 0.0, cat="request", rid=3, tenant="t0")
    tr.span("decode_chunk", 4.0, 8.0, cat="phase", slot=1, wall_s=0.01,
            rows=2)
    assert len(tr.events) == 2
    e0, e1 = tr.events
    assert e0.dur == 0.0 and e0.rid == 3 and e0.args == {"tenant": "t0"}
    assert e1.dur == 4.0 and e1.slot == 1 and e1.wall_s == 0.01
    tr.reset()
    assert tr.events == []


def test_tracer_disabled_is_noop():
    tr = telemetry.Tracer(enabled=False)
    tr.event("queued", 0.0)
    tr.span("x", 0.0, 4.0)
    assert tr.events == [] and tr.signature() == "[]"


def test_signature_strips_wall_time_only():
    a, b = telemetry.Tracer(), telemetry.Tracer()
    a.span("prefill", 0.0, 4.0, cat="phase", wall_s=0.123)
    b.span("prefill", 0.0, 4.0, cat="phase", wall_s=9.876)
    assert a.signature() == b.signature()
    b.span("extra", 4.0, 4.0)
    assert a.signature() != b.signature()


def test_chrome_trace_mapping_and_strip():
    tr = telemetry.Tracer()
    tr.span("decode_chunk", 4.0, 8.0, cat="phase", slot=0, wall_s=0.5)
    tr.event("outcome", 8.0, cat="request", slot=0, rid=2, status="ok")
    doc = tr.to_chrome_trace()
    assert doc["otherData"]["schema"] == telemetry.SCHEMA
    meta = [e for e in doc["traceEvents"] if e["ph"] == "M"]
    assert meta[0]["args"]["name"] == "replica 0"       # pid = slot + 1
    span = next(e for e in doc["traceEvents"] if e["ph"] == "X")
    assert span["ts"] == 4000.0 and span["dur"] == 4000.0   # 1 step = 1 ms
    assert span["args"]["wall_s"] == 0.5
    inst = next(e for e in doc["traceEvents"] if e["ph"] == "i")
    assert inst["tid"] == 3 and inst["s"] == "t"            # tid = rid + 1
    stripped = tr.to_chrome_trace(strip_wall=True)
    assert all("wall_s" not in e["args"]
               for e in stripped["traceEvents"] if e["ph"] == "X")


# -------------------------------------------------------------- phase timer
def test_phase_timer_accumulates_and_traces():
    st = {}
    tr = telemetry.Tracer()
    with telemetry.phase_timer(st, "prefill_s", tracer=tr, name="prefill",
                               start=8.0, slot=2) as ph:
        ph.note(prompts=3)
    with telemetry.phase_timer(st, "prefill_s"):
        pass
    assert st["prefill_s"] > 0.0
    assert len(tr.events) == 1
    e = tr.events[0]
    assert e.name == "prefill" and e.clock == 8.0 and e.slot == 2
    assert e.args == {"prompts": 3} and e.wall_s is not None


def test_phase_timer_ready_blocks_device_values():
    class FakeDeviceArray:
        def __init__(self):
            self.blocked = False

        def block_until_ready(self):
            self.blocked = True

    x = FakeDeviceArray()
    with telemetry.phase_timer(None, None) as ph:
        assert ph.ready(x) is x
    assert x.blocked


# ---------------------------------------------------------------- heartbeat
def test_heartbeat_record_schema_and_injection():
    rec = telemetry.heartbeat_record(7, wall_time=100.0, mono_s=42.0,
                                     restarts=2, extra_key="v")
    assert rec == {"schema": telemetry.HEARTBEAT_SCHEMA, "step": 7,
                   "wall_time": 100.0, "mono_s": 42.0, "restarts": 2,
                   "extra_key": "v"}
    # clocks default to real readings when not injected
    live = telemetry.heartbeat_record(0)
    assert live["wall_time"] > 0 and live["mono_s"] > 0


def test_supervisor_heartbeat_uses_shared_schema(tmp_path):
    hb = tmp_path / "hb.json"
    sup = Supervisor(
        FaultToleranceConfig(checkpoint_dir=str(tmp_path / "ckpt"),
                             checkpoint_every=100,
                             heartbeat_path=str(hb)),
        step_fn=lambda state, batch: (state + 1, {"loss": 0.0}),
        data_fn=lambda step: step,
        init_state_fn=lambda: 0)
    sup.wall_clock = lambda: 1234.5          # injectable — deterministic
    sup.mono_clock = lambda: 11.25
    sup.run(num_steps=3)
    rec = json.loads(hb.read_text())
    assert rec == {"schema": telemetry.HEARTBEAT_SCHEMA, "step": 2,
                   "wall_time": 1234.5, "mono_s": 11.25, "restarts": 0}


# ----------------------------------------------------------------- registry
def test_metric_key_set_is_frozen():
    """THE pinned key set: this test fails when a metric is added or removed
    without updating telemetry.*_KEYS (and DESIGN.md §15) deliberately."""
    m = telemetry.MetricsRegistry()
    snap = m.snapshot()
    assert snap.key_set() == telemetry.METRIC_KEYS
    assert len(telemetry.COUNTER_KEYS) == 31
    assert len(telemetry.GAUGE_KEYS) == 12
    assert len(telemetry.HISTOGRAM_KEYS) == 5
    # mesh-sharded serving (ISSUE 10): the shard/collective keys are part
    # of the frozen schema — an undeclared shard metric must fail loudly
    # (test_registry_rejects_undeclared_names), not silently appear
    assert "collective_ops" in telemetry.COUNTER_KEYS
    assert "collective_allgather_bytes" in telemetry.COUNTER_KEYS
    assert "shard_pages_used_max" in telemetry.GAUGE_KEYS
    assert "shard_pages_used_min" in telemetry.GAUGE_KEYS
    assert "shard_lockstep_divergence" in telemetry.GAUGE_KEYS
    assert "collective" in telemetry.CATEGORIES
    assert telemetry.TENANT_COUNTER_KEYS == ("ok_requests", "ok_tokens")
    assert telemetry.TENANT_HISTOGRAM_KEYS == ("admission_wait_steps",)


def test_registry_rejects_undeclared_names():
    m = telemetry.MetricsRegistry()
    with pytest.raises(KeyError, match="undeclared counter"):
        m.count("made_up_counter")
    with pytest.raises(KeyError, match="undeclared gauge"):
        m.gauge("made_up_gauge", 1.0)
    with pytest.raises(KeyError, match="undeclared histogram"):
        m.observe("made_up_hist", 1.0)
    with pytest.raises(KeyError, match="undeclared tenant counter"):
        m.tenant_count("t0", "made_up")
    with pytest.raises(KeyError, match="undeclared tenant histogram"):
        m.tenant_observe("t0", "made_up", 1.0)
    # shard metrics are declared-or-die like everything else (ISSUE 10)
    with pytest.raises(KeyError, match="undeclared counter"):
        m.count("collective_psum_bytes")
    with pytest.raises(KeyError, match="undeclared gauge"):
        m.gauge("shard_pages_used_mean", 1.0)


def test_registry_windows_and_snapshot():
    m = telemetry.MetricsRegistry()
    m.count("decode_chunks")
    m.count("tokens_emitted", 5)
    m.gauge("active_rows", 3)
    m.gauge("resident_tokens", 24)
    m.end_window(4.0, slot=0)
    m.observe("admission_wait_steps", 2.0)
    snap = m.snapshot()
    assert snap.counters["tokens_emitted"] == 5
    assert snap.gauges["clock"] == 4.0
    assert snap.histograms["admission_wait_steps"]["count"] == 1
    assert m.windows == [{"clock": 4.0, "slot": 0,
                          **{k: m.gauges[k] for k in telemetry.GAUGE_KEYS}}]
    assert json.dumps(snap.as_dict())        # JSON-serializable
    m.reset()
    assert m.windows == [] and m.snapshot().counters["tokens_emitted"] == 0


def test_tenant_summary_percentiles():
    m = telemetry.MetricsRegistry()
    for i in range(100):
        m.tenant_observe("t0", "admission_wait_steps", float(i + 1))
    m.tenant_count("t0", "ok_requests", 3)
    m.tenant_count("t0", "ok_tokens", 90)
    m.tenant_count("t1", "ok_requests")
    s = m.tenant_summary()
    assert sorted(s) == ["t0", "t1"]
    assert s["t0"]["admission_wait_p50_steps"] == 50.0     # nearest rank
    assert s["t0"]["admission_wait_p99_steps"] == 99.0
    assert s["t0"]["goodput_tokens"] == 90
    assert s["t1"]["ok_requests"] == 1
    assert s["t1"]["admission_wait_p50_steps"] == 0.0


# ---------------------------------------------------------- drift detection
def _fill_windows(m, plan, resident_per_row, active_rows, n=4):
    for i in range(n):
        m.gauge("active_rows", active_rows)
        m.gauge("resident_tokens", resident_per_row * active_rows)
        m.end_window(float((i + 1) * plan.sync_every))


def test_drift_clean_when_measurements_match_plan(model):
    cfg, _ = model
    plan = _plan(cfg, mean=10)
    attn = next(d for d in plan.decisions if d.name == "attention")
    expected = attn.numbers["expected_resident_tokens"]
    m = telemetry.MetricsRegistry()
    _fill_windows(m, plan, resident_per_row=expected, active_rows=plan.rows)
    for _ in range(4):
        m.observe("finished_len_tokens", 10.0)
    m.count("prefill_real_tokens", 64)
    m.count("prefill_padded_tokens", 80)     # pad ratio 1.25 < pow2 bound 2
    rep = telemetry.detect_drift(plan, m)
    assert rep.windows == 4 and len(rep.findings) >= 4
    assert rep.clean, rep.render()
    assert {f.decision for f in rep.findings} >= {
        "attention", "capacity", "kv_quant", "mlp", "prefill"}


def test_drift_confirms_mispredicted_occupancy(model):
    """The tentpole acceptance scenario: the plan provisioned for mean
    length 40 but requests finish at ~10 tokens — the report must name the
    attention (paging) decision as divergent."""
    cfg, _ = model
    plan = _plan(cfg, mean=40)
    assert plan.paged                        # drift's richest comparison set
    m = telemetry.MetricsRegistry()
    _fill_windows(m, plan, resident_per_row=12, active_rows=plan.rows)
    for _ in range(4):
        m.observe("finished_len_tokens", 10.0)
    rep = telemetry.detect_drift(plan, m)
    confirmed = {f"{f.decision}.{f.metric}" for f in rep.confirmed}
    assert "attention.resident_tokens_per_row" in confirmed, rep.render()
    assert "capacity.mean_finished_len" in confirmed
    f = rep.for_decision("attention")[0]
    assert f.confirmed and f.ratio < 1.0 / (1.0 + f.threshold)
    assert "CONFIRMED" in f.render()
    assert rep.summary()["confirmed"]


def test_drift_confirms_forced_requant_under_fp_plan(model):
    cfg, _ = model
    plan = _plan(cfg, mean=10)
    kv = next(d for d in plan.decisions if d.name == "kv_quant")
    assert kv.choice == "fp"                 # small pool resolves fp pages
    m = telemetry.MetricsRegistry()
    m.count("requant_events")                # measured forced degrade rung
    rep = telemetry.detect_drift(plan, m)
    assert any(f.decision == "kv_quant" and f.metric == "requant_events"
               and f.confirmed for f in rep.findings)


def test_explain_renders_drift_lines(model):
    cfg, _ = model
    plan = _plan(cfg, mean=40)
    m = telemetry.MetricsRegistry()
    _fill_windows(m, plan, resident_per_row=12, active_rows=plan.rows)
    rep = telemetry.detect_drift(plan, m)
    text = plan.explain(drift=rep)
    assert "drift: [CONFIRMED] attention.resident_tokens_per_row" in text \
        or "[CONFIRMED] attention.resident_tokens_per_row" in text
    assert "CONFIRMED" in text.rsplit("drift:", 1)[-1]
    assert "drift:" not in plan.explain()    # no report, no drift lines


# --------------------------------------------------- end-to-end determinism
def _run_llm(model, chaos=None, **llm_kw):
    cfg, params = model
    llm = LLM(cfg, params, _plan(cfg), eos_id=-1, **llm_kw)
    llm.stream(_reqs(), chaos=chaos)
    return llm


def test_scheduler_trace_deterministic_same_seed(model):
    sigs, traces = [], []
    for _ in range(2):
        llm = _run_llm(model, chaos=ChaosConfig(
            seed=7, ensure_fail_rate=0.3, step_fail_chunks=(1,),
            nan_rids={2: (1,)}))
        tr = llm.telemetry().tracer
        assert tr.events, "run recorded no spans"
        sigs.append(tr.signature())
        traces.append(json.dumps(tr.to_chrome_trace(strip_wall=True),
                                 sort_keys=True))
    assert sigs[0] == sigs[1]
    assert traces[0] == traces[1]            # byte-identical once stripped


@pytest.mark.chaos
def test_replica_chaos_trace_deterministic_same_seed(model):
    traces = []
    for _ in range(2):
        llm = _run_llm(model, replicas=3,
                       chaos=ReplicaChaosConfig(kill_at_step={1: 4.0}))
        tr = llm.telemetry().tracer
        cats = {e.cat for e in tr.events}
        assert {"request", "phase", "window"} <= cats
        traces.append(json.dumps(tr.to_chrome_trace(strip_wall=True),
                                 sort_keys=True))
    assert traces[0] == traces[1]


def test_scheduler_run_populates_metrics_and_drift(model):
    llm = _run_llm(model)
    tel = llm.telemetry()
    snap = tel.metrics.snapshot()
    assert snap.key_set() == telemetry.METRIC_KEYS
    assert snap.counters["requests_queued"] == 6
    assert snap.counters["requests_admitted"] == 6
    assert snap.counters["ok"] == 6
    assert snap.counters["tokens_emitted"] >= 6
    assert snap.counters["decode_chunks"] >= 1
    assert snap.histograms["admission_wait_steps"]["count"] == 6
    assert tel.metrics.windows, "no per-window gauge history"
    # per-tenant goodput/wait percentiles (requests alternate t0/t1)
    tenants = tel.metrics.tenant_summary()
    assert sorted(tenants) == ["t0", "t1"]
    assert all(t["goodput_tokens"] > 0 for t in tenants.values())
    # end-of-run drift report reached phase_stats and the bundle
    assert tel.last_drift is not None
    assert llm.phase_stats["drift"] == tel.last_drift.summary()


def test_engine_generate_records_phase_spans(model):
    cfg, params = model
    llm = LLM(cfg, params, _plan(cfg), eos_id=-1)
    llm.generate([([3, 5, 7], 4), ([4, 5], 4)])
    names = [e.name for e in llm.telemetry().tracer.events]
    assert "prefill" in names and "decode_chunk" in names
    st = llm.phase_stats
    assert st["prefill_s"] > 0 and st["decode_s"] > 0


def test_trace_false_keeps_metrics_drops_spans(model):
    llm = _run_llm(model, trace=False)
    tel = llm.telemetry()
    assert tel.tracer.events == []
    assert tel.metrics.snapshot().counters["ok"] == 6


def test_shared_telemetry_bundle_resets_per_call(model):
    cfg, params = model
    tel = telemetry.Telemetry()
    llm = LLM(cfg, params, _plan(cfg), eos_id=-1, trace=tel)
    llm.stream(_reqs(n=3))
    first = tel.tracer.signature()
    llm.stream(_reqs(n=3))
    assert tel.tracer.signature() == first   # reset, not appended


def test_scheduler_shared_bundle_not_reset_by_scheduler(model):
    """A scheduler handed a shared bundle (replica mode) must not clear the
    fleet's events at its own run start — only an owned bundle resets."""
    cfg, params = model
    tel = telemetry.Telemetry()
    tel.tracer.event("dispatch", 0.0, cat="window")
    sched = ContinuousBatchingScheduler(
        cfg, params, _plan(cfg), eos_id=-1, telemetry=tel, slot=0)
    sched.run(_reqs(n=2))
    assert tel.tracer.events[0].name == "dispatch"
    assert "drift" not in sched.phase_stats  # fleet computes drift once
