"""Paged KV decode attention (ISSUE 3): the Pallas block-table kernel vs the
gather-then-softmax oracle and the contiguous-ring reference, across ragged
lengths, page boundaries, GQA/softcap, and multi-codebook configs."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from hypothesis_compat import given, settings, st

from repro.configs import get_config
from repro.core import dataflow
from repro.kernels import ops, ref
from repro.kernels.paged_attention import work_steps
from repro.models import decoding, layers, transformer as tfm
from repro.serve.paging import PageAllocator


def _paged_case(lengths, page_size, KV=2, R=2, D=16, seed=0, dtype=jnp.float32):
    """Random pools + a permuted block table covering ``lengths``."""
    rng = np.random.default_rng(seed)
    B = len(lengths)
    MP = max(dataflow.pages_for(n, page_size) for n in lengths)
    P = sum(dataflow.pages_for(n, page_size) for n in lengths) + 1
    q = jnp.asarray(rng.standard_normal((B, KV, R, D)), dtype)
    kp = jnp.asarray(rng.standard_normal((P, page_size, KV, D)), dtype)
    vp = jnp.asarray(rng.standard_normal((P, page_size, KV, D)), dtype)
    bt = np.full((B, MP), -1, np.int32)
    perm = rng.permutation(P)        # physical pages deliberately non-contiguous
    i = 0
    for b, n in enumerate(lengths):
        for j in range(dataflow.pages_for(n, page_size)):
            bt[b, j] = perm[i]
            i += 1
    return q, kp, vp, jnp.asarray(bt), jnp.asarray(np.asarray(lengths, np.int32))


# ------------------------------------------------------------------- kernel
@pytest.mark.parametrize("page_size", [4, 8])
@pytest.mark.parametrize("softcap", [0.0, 30.0])
def test_paged_kernel_matches_oracle_ragged(page_size, softcap):
    """Ragged lengths hitting len % ps in {0, 1, ps-1} plus mid-page."""
    lengths = [page_size, page_size + 1, 3 * page_size - 1, 2 * page_size + 2]
    q, kp, vp, bt, lens = _paged_case(lengths, page_size)
    B, KV, R, D = q.shape
    out = ops.paged_attention(q.reshape(B, 1, KV * R, D), kp, vp, bt, lens,
                              softcap=softcap)
    expect = ref.paged_attention_ref(q, kp, vp, bt, lens, softcap=softcap
                                     ).reshape(B, 1, KV * R, D)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect),
                               rtol=1e-5, atol=1e-5)


def test_paged_kernel_single_page_and_full_table():
    """Boundary grids: one page total, and every table entry allocated."""
    for lengths in ([3], [8, 8]):
        q, kp, vp, bt, lens = _paged_case(lengths, 8, seed=3)
        B, KV, R, D = q.shape
        out = ops.paged_attention(q.reshape(B, 1, KV * R, D), kp, vp, bt, lens)
        expect = ref.paged_attention_ref(q, kp, vp, bt, lens
                                         ).reshape(B, 1, KV * R, D)
        np.testing.assert_allclose(np.asarray(out), np.asarray(expect),
                                   rtol=1e-5, atol=1e-5)


def test_paged_kernel_matches_contiguous_decode_attention():
    """Paged read == layers.decode_attention over the same (scattered) KV."""
    cfg = get_config("qwen2.5-3b-reduced")
    rng = np.random.default_rng(1)
    B, KV, H, D = 3, cfg.num_kv_heads, cfg.num_heads, cfg.head_dim
    cache_len, ps = 32, 8
    MP = cache_len // ps
    lengths = np.asarray([4, 8, 19], np.int32)
    q = jnp.asarray(rng.standard_normal((B, 1, H, D)), jnp.float32)
    k_rows = jnp.asarray(rng.standard_normal((B, cache_len, KV, D)), jnp.float32)
    v_rows = jnp.asarray(rng.standard_normal((B, cache_len, KV, D)), jnp.float32)
    mask = jnp.arange(cache_len)[None, :] < jnp.asarray(lengths)[:, None]
    ctx_ref = layers.decode_attention(q, k_rows, v_rows, mask, cfg)

    bt = np.full((B, MP), -1, np.int32)
    nxt = 0
    for b, n in enumerate(lengths):
        for j in range(dataflow.pages_for(int(n), ps)):
            bt[b, j] = nxt
            nxt += 1
    pool = jnp.zeros((nxt + 1, ps, KV, D), jnp.float32)
    pk = decoding.scatter_rows_to_pages(pool, k_rows, jnp.asarray(bt),
                                        jnp.asarray(lengths))
    pv = decoding.scatter_rows_to_pages(pool, v_rows, jnp.asarray(bt),
                                        jnp.asarray(lengths))
    ctx_pg = ops.paged_attention(q, pk, pv, jnp.asarray(bt),
                                 jnp.asarray(lengths))
    # decode_attention rounds its fp32 context to the compute dtype (bf16)
    # on return; the kernel output must round to the identical values
    np.testing.assert_array_equal(
        np.asarray(ctx_pg.astype(ctx_ref.dtype), np.float32),
        np.asarray(ctx_ref, np.float32))


def test_paged_kernel_work_steps_proxy():
    """The skip bound: real work on exactly ceil(len/ps) grid steps per row."""
    ps = 8
    lengths = [1, 8, 9, 24]
    assert work_steps(lengths, ps) == 1 + 1 + 2 + 3
    assert work_steps(lengths, ps) == sum(
        dataflow.pages_for(n, ps) for n in lengths)
    # and strictly below the padded grid when rows are ragged
    MP = max(dataflow.pages_for(n, ps) for n in lengths)
    assert work_steps(lengths, ps) < len(lengths) * MP


@settings(max_examples=20, deadline=None)
@given(st.lists(st.integers(min_value=1, max_value=40), min_size=1,
                max_size=4),
       st.sampled_from([4, 8]))
def test_paged_kernel_property_ragged(lengths, page_size):
    """Property: kernel == oracle for arbitrary ragged lengths/page sizes."""
    q, kp, vp, bt, lens = _paged_case(lengths, page_size, seed=7)
    B, KV, R, D = q.shape
    out = ops.paged_attention(q.reshape(B, 1, KV * R, D), kp, vp, bt, lens)
    expect = ref.paged_attention_ref(q, kp, vp, bt, lens
                                     ).reshape(B, 1, KV * R, D)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect),
                               rtol=1e-5, atol=1e-5)


# ------------------------------------------------------ model-level routing
def _paged_cache_from_prefill(cfg, row_cache, bt, lengths, rows, cache_len,
                              num_pages, page_size):
    """Scatter a prefill(-batched) row cache into a fresh paged cache."""
    pc = decoding.init_paged_cache(cfg, rows, cache_len, num_pages, page_size)

    def merge(c_entry, row_entry, stacked):
        if decoding.is_paged_entry(c_entry):
            def scat(pool, rows_kv):
                return decoding.scatter_rows_to_pages(pool, rows_kv, bt,
                                                      lengths)
            f = jax.vmap(scat) if stacked else scat
            return {"pk": f(c_entry["pk"], row_entry["k"]),
                    "pv": f(c_entry["pv"], row_entry["v"])}
        return row_entry

    out = {}
    for part in ("blocks", "rem"):
        if part in pc:
            out[part] = {k: merge(pc[part][k], row_cache[part][k],
                                  stacked=(part == "blocks"))
                         for k in pc[part]}
    return out


@pytest.mark.parametrize("arch", ["qwen2.5-3b-reduced", "gemma2-2b-reduced"])
def test_serve_step_paged_matches_contiguous(arch):
    """serve_step through the paged route is bit-identical to the contiguous
    route (global layers paged; gemma2's local layers stay ring either way)."""
    cfg = get_config(arch)
    params = tfm.init_params(jax.random.PRNGKey(0), cfg)
    rows, cache_len, ps = 2, 32, 8
    MP = cache_len // ps
    prompts = [[5, 6, 7], [9, 8, 7, 6, 5, 4]]
    S = max(len(p) for p in prompts)
    toks = np.zeros((rows, S), np.int32)
    for i, p in enumerate(prompts):
        toks[i, :len(p)] = p
    lengths = jnp.asarray([len(p) for p in prompts], jnp.int32)
    lb, cb = decoding.prefill_batched(params, jnp.asarray(toks), lengths,
                                      cfg, cache_len)

    pager = PageAllocator(rows * MP, ps)
    for i, p in enumerate(prompts):
        assert pager.ensure(i, len(p) + 2)
    bt = jnp.asarray(pager.block_table_rows([0, 1], MP))
    paged = _paged_cache_from_prefill(cfg, cb, bt, lengths, rows, cache_len,
                                      rows * MP, ps)
    nxt = jnp.argmax(lb[:, -1], -1)[:, None]
    pos = lengths
    l_ref, c_ref = decoding.serve_step(params, cb, nxt, pos, cfg)
    l_pg, c_pg = decoding.serve_step(params, paged, nxt, pos, cfg,
                                     block_table=bt)
    np.testing.assert_array_equal(np.asarray(l_ref), np.asarray(l_pg))
    # second step exercises the decode-time page write
    nxt2 = jnp.argmax(l_ref[:, -1], -1)[:, None]
    l_ref2, _ = decoding.serve_step(params, c_ref, nxt2, pos + 1, cfg)
    l_pg2, _ = decoding.serve_step(params, c_pg, nxt2, pos + 1, cfg,
                                   block_table=bt)
    np.testing.assert_array_equal(np.asarray(l_ref2), np.asarray(l_pg2))


def test_serve_step_paged_multi_codebook():
    """Multi-codebook (4-d logits) route: musicgen-style K=4 codebooks
    through the paged cache match the contiguous path."""
    cfg = dataclasses.replace(get_config("musicgen-large-reduced"),
                              cross_attn_cond=0)
    params = tfm.init_params(jax.random.PRNGKey(0), cfg)
    rows, cache_len, ps, S = 2, 16, 4, 5
    MP = cache_len // ps
    K = cfg.num_codebooks
    assert K > 1
    rng = np.random.default_rng(2)
    toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (rows, K, S)), jnp.int32)
    logits, cb = decoding.prefill(params, toks, cfg, cache_len)
    assert logits.ndim == 4                       # (B, 1, K, V) — 4-d logits

    lengths = jnp.full((rows,), S, jnp.int32)
    pager = PageAllocator(rows * MP, ps)
    for i in range(rows):
        assert pager.ensure(i, S + 2)
    bt = jnp.asarray(pager.block_table_rows(list(range(rows)), MP))
    paged = _paged_cache_from_prefill(cfg, cb, bt, lengths, rows, cache_len,
                                      rows * MP, ps)
    nxt = jnp.argmax(logits[:, -1], -1)[..., None]      # (B, K, 1)
    pos = lengths
    l_ref, _ = decoding.serve_step(params, cb, nxt, pos, cfg)
    l_pg, _ = decoding.serve_step(params, paged, nxt, pos, cfg,
                                  block_table=bt)
    assert l_ref.shape[-2] == K
    np.testing.assert_array_equal(np.asarray(l_ref), np.asarray(l_pg))


def test_unallocated_table_entries_drop_writes():
    """Writes past a row's block table are dropped, not wrapped: a pos whose
    page is -1 must leave the pool untouched."""
    cfg = get_config("qwen2.5-3b-reduced")
    KV, D = cfg.num_kv_heads, cfg.head_dim
    pool = jnp.zeros((4, 4, KV, D), jnp.float32)
    rows_kv = jnp.ones((1, 8, KV, D), jnp.float32)
    bt = jnp.asarray([[2, -1]], jnp.int32)
    out = decoding.scatter_rows_to_pages(pool, rows_kv, bt,
                                         jnp.asarray([8], jnp.int32))
    # first page (physical 2) written, second page's 4 tokens dropped
    assert float(jnp.sum(out)) == 4 * KV * D
    assert float(jnp.sum(out[2])) == 4 * KV * D


# ------------------------------------------------------------ dispatch rule
def test_attn_path_occupancy_rule():
    ps = dataflow.PAGE_SIZE
    # short caches never page; low occupancy pages; near-full stays dense
    assert dataflow.attn_path(ps, ps // 2) == "contiguous"
    assert dataflow.attn_path(16 * ps, 4 * ps) == "paged"
    assert dataflow.attn_path(16 * ps, 15 * ps + 1) == "contiguous"
    # the boundary follows PAGED_OCCUPANCY_MAX on page-rounded occupancy
    cache = 16 * ps
    lim = int(dataflow.PAGED_OCCUPANCY_MAX * 16)
    assert dataflow.attn_path(cache, lim * ps) == "paged"
    assert dataflow.attn_path(cache, lim * ps + 1) == "contiguous"


def test_paged_vs_dense_token_accounting():
    lens = [10, 100, 64]
    ps = 64
    assert dataflow.paged_kv_tokens(lens, ps) == 64 + 128 + 64
    assert dataflow.dense_kv_tokens(3, 512) == 1536
    assert dataflow.paged_kv_tokens(lens, ps) < dataflow.dense_kv_tokens(3, 512)
