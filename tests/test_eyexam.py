"""Eyexam (paper Appendix A): step-wise bound tightening + HLO cost parser."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis_compat import given, settings, st  # optional dep, see tests/hypothesis_compat.py

from repro.core import eyexam, hloparse, reuse


# ------------------------------------------------------------- seven steps
def _acc(n_pes=256, noc="hmnoc"):
    side = int(np.sqrt(n_pes))
    return eyexam.AcceleratorModel(n_pes=n_pes, array_h=side, array_w=side,
                                   noc=noc)


@settings(max_examples=30, deadline=None)
@given(st.integers(1, 512), st.integers(1, 512), st.integers(1, 64))
def test_bounds_monotonically_tighten(n, c, m):
    """Each Eyexam step may only LOWER the bound (paper Table VIII)."""
    shape = reuse.gemm("g", n, c, m)
    steps = eyexam.seven_steps(shape, _acc())
    bounds = [s["bound"] for s in steps]
    assert all(b2 <= b1 + 1e-9 for b1, b2 in zip(bounds, bounds[1:]))
    assert bounds[0] == shape.macs


def test_hmnoc_scales_v1_saturates():
    """Fig. 14: broadcast NoC saturates with scale, HM-NoC keeps scaling."""
    dw = reuse.conv("dw", n=1, c=1, m=1, h=56, w=56, r=3, s=3, groups=64)
    perf_v1 = [eyexam.seven_steps(dw, _acc(n, "broadcast"))[-1]["bound"]
               for n in (256, 1024, 16384)]
    perf_v2 = [eyexam.seven_steps(dw, _acc(n, "hmnoc"))[-1]["bound"]
               for n in (256, 1024, 16384)]
    assert perf_v1[2] <= perf_v1[0] * 1.5          # v1 saturated
    assert perf_v2[2] > perf_v2[0] * 2.0           # v2 keeps scaling


def test_network_performance_aggregates():
    layers = [reuse.gemm(f"l{i}", 4096, 512, 512) for i in range(4)]
    mac_rate = eyexam.network_performance(layers, _acc())
    assert 0 < mac_rate <= 256


# ----------------------------------------------------------------- roofline
def test_roofline_terms_and_bound():
    r = eyexam.Roofline(flops=197e12, hbm_bytes=819e9, coll_bytes=0.0,
                        per_op_coll={}, chips=1)
    assert np.isclose(r.t_compute, 1.0)
    assert np.isclose(r.t_memory, 1.0)
    assert r.t_collective == 0.0
    r2 = eyexam.Roofline(flops=1e12, hbm_bytes=819e9 * 10, coll_bytes=1,
                         per_op_coll={}, chips=1)
    assert r2.bound == "memory"
    assert 0 < r2.fraction_of_roofline(1e12) <= 1.0


# --------------------------------------------------------------- HLO parser
def test_hloparse_counts_loop_iterations():
    """The reason this parser exists: cost_analysis counts scan bodies once."""
    def f(w, x):
        def body(c, wi):
            return jnp.tanh(c @ wi), ()
        c, _ = jax.lax.scan(body, x, w)
        return c

    w = jax.ShapeDtypeStruct((5, 64, 64), jnp.float32)
    x = jax.ShapeDtypeStruct((32, 64), jnp.float32)
    compiled = jax.jit(f).lower(w, x).compile()
    cost = hloparse.analyze(compiled.as_text())
    expect = 5 * 2 * 32 * 64 * 64          # 5 iterations x one (32,64)@(64,64)
    assert cost.flops == expect
    ca = compiled.cost_analysis()
    if isinstance(ca, list):               # jax < 0.5 returns [dict]
        ca = ca[0] if ca else {}
    assert ca.get("flops", 0) < expect     # the builtin undercounts


def test_hloparse_plain_matmul():
    compiled = jax.jit(lambda a, b: a @ b).lower(
        jax.ShapeDtypeStruct((128, 256), jnp.float32),
        jax.ShapeDtypeStruct((256, 64), jnp.float32)).compile()
    cost = hloparse.analyze(compiled.as_text())
    assert cost.flops == 2 * 128 * 256 * 64
    assert cost.hbm_bytes > 0


def test_hloparse_shape_bytes():
    assert hloparse._shape_bytes("f32[4,8]{1,0}") == 128
    assert hloparse._shape_bytes("bf16[10]") == 20
    assert hloparse._shape_bytes("(f32[2,2], s32[4])") == 32
    assert hloparse._shape_bytes("pred[]") == 1


def test_hloparse_nested_scan_multiplies():
    def f(x):
        def outer(c, _):
            def inner(c2, _):
                return jnp.tanh(c2 @ c2), ()
            c2, _ = jax.lax.scan(inner, c, None, length=3)
            return c2, ()
        c, _ = jax.lax.scan(outer, x, None, length=4)
        return c

    x = jax.ShapeDtypeStruct((16, 16), jnp.float32)
    compiled = jax.jit(f).lower(x).compile()
    cost = hloparse.analyze(compiled.as_text())
    assert cost.flops == 4 * 3 * 2 * 16 * 16 * 16


def test_hloparse_inplace_dus_fusion_counts_slice():
    """A scan that appends one token to a big cache buffer must be charged
    O(slice) bytes per step, not O(buffer) (the decode KV-append pattern)."""
    def f(cache, xs):
        def body(c, x):
            c = jax.lax.dynamic_update_slice_in_dim(c, x[None], 3, axis=0)
            return c, ()
        c, _ = jax.lax.scan(body, cache, xs)
        return c

    cache = jax.ShapeDtypeStruct((4096, 256), jnp.float32)
    xs = jax.ShapeDtypeStruct((8, 256), jnp.float32)
    cost = hloparse.analyze(jax.jit(f).lower(cache, xs).compile().as_text())
    buf = 4096 * 256 * 4
    # allowed: ONE loop-entry copy of the buffer (write+read = 2 passes) +
    # slice-granular updates. Disallowed: per-iteration full-buffer charges
    # (8 iterations x 2 ops x buffer ≈ 16 passes — the pre-fix behaviour).
    assert cost.hbm_bytes < 3 * buf, cost.hbm_bytes
