"""Fused BCSC MLP megakernel (ISSUE 2): oracle equivalence across sparsities
and decode shapes, ragged per-layer nnzb, activation fusion, the scratch-only
hidden-activation contract, the mlp_path dispatch rule, the ragged packing
stats, and the wall-clock-free fused-vs-two-call perf guards."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core import dataflow
from repro.core.sparsity import block_magnitude_prune
from repro.kernels import bcsc_mlp as bmlp
from repro.kernels import ops
from repro.models import layers
from repro.serve import sparse as sps


def _mats(d, ff, sparsity, seed=0, gated=True):
    rng = np.random.default_rng(seed)

    def prune(shape):
        w = jnp.asarray(rng.standard_normal(shape), jnp.float32)
        if sparsity > 0:
            w = block_magnitude_prune(w, sparsity, 16, 16)
        return np.asarray(w)

    wg, wd = prune((d, ff)), prune((ff, d))
    wu = prune((d, ff)) if gated else None
    return wg, wu, wd


def _ref(x, wg, wu, wd, act):
    actf = jax.nn.silu if act == "silu" else \
        (lambda t: jax.nn.gelu(t, approximate=True))
    h = actf(x @ wg)
    if wu is not None:
        h = h * (x @ wu)
    return h @ wd


# ------------------------------------------------------------ oracle sweeps
@pytest.mark.parametrize("M", [1, 4, 8])
@pytest.mark.parametrize("sparsity", [0.5, 0.7, 0.9])
def test_fused_mlp_matches_oracle(M, sparsity):
    wg, wu, wd = _mats(64, 128, sparsity)
    pg, pu, pd = (sps.pack_weight(w, 16, 16) for w in (wg, wu, wd))
    x = jnp.asarray(np.random.default_rng(1).standard_normal((M, 64)),
                    jnp.float32)
    out = ops.bcsc_mlp_packed(x, pg, pu, pd, d_ff=128, n_out=64,
                              activation="silu")
    np.testing.assert_allclose(np.asarray(out),
                               np.asarray(_ref(x, wg, wu, wd, "silu")),
                               rtol=5e-4, atol=5e-4)


@pytest.mark.parametrize("activation", ["silu", "gelu"])
def test_fused_mlp_ungated_and_activation_fusion(activation):
    wg, _, wd = _mats(64, 128, 0.7, seed=3, gated=False)
    pg, pd = sps.pack_weight(wg, 16, 16), sps.pack_weight(wd, 16, 16)
    x = jnp.asarray(np.random.default_rng(2).standard_normal((4, 64)),
                    jnp.float32)
    out = ops.bcsc_mlp_packed(x, pg, None, pd, d_ff=128, n_out=64,
                              activation=activation)
    np.testing.assert_allclose(np.asarray(out),
                               np.asarray(_ref(x, wg, None, wd, activation)),
                               rtol=5e-4, atol=5e-4)


def test_fused_mlp_gridded_variant_large_payload():
    """Payloads past UNROLL_CHUNKS_MAX chunks take the sequential-grid walk."""
    wg, wu, wd = _mats(128, 512, 0.5, seed=5)
    pg, pu, pd = (sps.pack_weight(w, 16, 16) for w in (wg, wu, wd))
    n_chunks = sum(p["blocks"].shape[0] // bmlp._pick_chunk(
        p["blocks"].shape[0]) for p in (pg, pu, pd))
    assert n_chunks > bmlp.UNROLL_CHUNKS_MAX     # really exercises the grid
    x = jnp.asarray(np.random.default_rng(6).standard_normal((8, 128)),
                    jnp.float32)
    out = ops.bcsc_mlp_packed(x, pg, pu, pd, d_ff=512, n_out=128,
                              activation="silu")
    np.testing.assert_allclose(np.asarray(out),
                               np.asarray(_ref(x, wg, wu, wd, "silu")),
                               rtol=5e-3, atol=5e-3)


# ------------------------------------------------------- ragged per-layer nnzb
def test_fused_mlp_ragged_counts_across_stacked_layers():
    """Two layers with very different densities share one padded stack; each
    layer's prefetched counts must select exactly its own real blocks."""
    dense_l = _mats(64, 128, 0.3, seed=7)       # dense-ish layer
    sparse_l = _mats(64, 128, 0.9, seed=8)      # very sparse layer
    packs = []
    for (wg, wu, wd) in (dense_l, sparse_l):
        packs.append(tuple(sps.pack_weight(w, 16, 16) for w in (wg, wu, wd)))
    # pad each projection to the stack-wide capacity (ragged nnzb kept)
    stacked = []
    for i in range(3):
        cap = max(p[i]["blocks"].shape[0] for p in packs)
        stacked.append([sps.pad_packed(p[i], cap) for p in packs])
    x = jnp.asarray(np.random.default_rng(9).standard_normal((1, 64)),
                    jnp.float32)
    for li, (wg, wu, wd) in enumerate((dense_l, sparse_l)):
        pg, pu, pd = (stacked[i][li] for i in range(3))
        assert int(pg["nnzb"]) < pg["blocks"].shape[0] or li == 0
        out = ops.bcsc_mlp_packed(x, pg, pu, pd, d_ff=128, n_out=64,
                                  activation="silu")
        np.testing.assert_allclose(np.asarray(out),
                                   np.asarray(_ref(x, wg, wu, wd, "silu")),
                                   rtol=5e-4, atol=5e-4)


def test_pad_packed_repeats_last_ids_and_keeps_nnzb():
    wg, _, _ = _mats(64, 128, 0.8, seed=11)
    p = sps.pack_weight(wg, 16, 16)
    real = int(p["nnzb"])
    padded = sps.pad_packed(p, p["blocks"].shape[0] + 16)
    assert int(padded["nnzb"]) == real
    rows, cols = np.asarray(padded["row_ids"]), np.asarray(padded["col_ids"])
    assert (rows[real:] == rows[real - 1]).all()
    assert (cols[real:] == cols[real - 1]).all()
    assert np.asarray(padded["blocks"])[real:].sum() == 0
    assert (np.diff(cols) >= 0).all()            # CSC order preserved


# ------------------------------------------------ scratch-only hidden contract
def test_fused_mlp_hidden_never_leaves_vmem():
    """The megakernel's only HBM output is the (M, n_out) result: no
    d_ff-sized buffer appears among pallas_call outputs, and the whole MLP is
    ONE pallas_call (vs three on the two-call path)."""
    cfg = get_config("qwen2.5-3b-reduced")
    wg, wu, wd = _mats(cfg.d_model, cfg.d_ff, 0.75, seed=13)
    mlp_params = {"wg": sps.pack_weight(wg, 16, 16),
                  "wu": sps.pack_weight(wu, 16, 16),
                  "wd": sps.pack_weight(wd, 16, 16)}
    x = jnp.ones((1, 1, cfg.d_model), jnp.bfloat16)
    jaxpr = jax.make_jaxpr(lambda p, xx: layers.mlp(p, xx, cfg))(mlp_params, x)

    def pallas_eqns(jpr):
        for e in jpr.eqns:
            if "pallas" in str(e.primitive):
                yield e
            for sub in jax.core.subjaxprs(e.params) \
                    if hasattr(jax.core, "subjaxprs") else []:
                yield from pallas_eqns(sub)

    calls = [e for e in jaxpr.jaxpr.eqns if "pallas" in str(e.primitive)]
    assert len(calls) == 1                       # megakernel: one fused call
    for v in calls[0].outvars:
        assert cfg.d_ff not in v.aval.shape      # hidden never aliased to HBM


# ------------------------------------------------------------- dispatch rule
def test_mlp_path_dispatch_rule():
    # decode shapes with modest hidden: fused (scratch fits)
    assert dataflow.mlp_path(1, 4096, 1024) == "fused"
    assert dataflow.mlp_path(8, 11008, 2048) == "fused"
    # huge M: bm grows until the hidden scratch cannot stay resident
    assert dataflow.mlp_path(512, 11008, 2048) == "two_call"
    # near-dense blocks: skipping cannot pay — stay dense
    assert dataflow.mlp_path(1, 4096, 1024, density=0.95) == "dense"
    assert dataflow.mlp_path(1, 4096, 1024,
                             density=dataflow.DENSE_BLOCK_DENSITY) == "dense"
    assert dataflow.mlp_path(1, 4096, 1024, density=0.5) == "fused"


def test_sparsify_leaves_near_dense_weights_unpacked():
    cfg = get_config("qwen2.5-3b-reduced")
    from repro.models import transformer as tfm
    params = tfm.init_params(jax.random.PRNGKey(0), cfg)
    packed, stats = sps.sparsify_mlp_params(params, cfg, sparsity=0.0)
    # unpruned weights are block-dense -> the dense arm of mlp_path
    assert stats["packed"] == 0
    assert set(stats["left_dense"]) == {"wg", "wu", "wd"}
    for slot in packed["blocks"]:
        mlp = packed["blocks"][slot]["mlp"]
        assert all(not ops.is_packed(mlp[k]) for k in ("wg", "wu", "wd"))


# ----------------------------------------------------- packing stats contract
def _pruned_packed_cfg(sparsity=0.75):
    cfg = get_config("qwen2.5-3b-reduced")
    from repro.models import transformer as tfm
    params = tfm.init_params(jax.random.PRNGKey(0), cfg)
    for slot in params["blocks"]:
        mlp = params["blocks"][slot].get("mlp")
        if mlp:
            for nm in list(mlp):
                w = mlp[nm]
                mlp[nm] = jnp.stack([
                    block_magnitude_prune(w[l], sparsity, 16, 16)
                    for l in range(w.shape[0])])
    packed, stats = sps.sparsify_mlp_params(params, cfg, sparsity=0.0)
    return cfg, params, packed, stats


def test_packing_efficiency_stats():
    cfg, _, packed, stats = _pruned_packed_cfg()
    assert stats["packed"] == 3
    assert 0 < stats["packing_efficiency"] <= 1
    for nm, w in stats["weights"].items():
        assert len(w["real"]) == cfg.num_layers
        assert all(r <= p for r, p in zip(w["real"], w["padded"]))
        assert w["packing_efficiency"] == pytest.approx(
            sum(w["real"]) / sum(w["padded"]))
    # pack-time prepared counts ride the params pytree, one (3,) per layer
    mlp0 = packed["blocks"]["slot0"]["mlp"]
    counts = np.asarray(mlp0["_bcsc_counts"])
    assert counts.shape[-1] == 3
    np.testing.assert_array_equal(counts[..., 0],
                                  np.asarray(mlp0["wg"]["nnzb"]))


# -------------------------------------------- wall-clock-free perf guards
def _load_bench():
    import importlib.util
    import os
    bench_path = os.path.join(os.path.dirname(__file__), os.pardir,
                              "benchmarks", "sparse_decode.py")
    spec = importlib.util.spec_from_file_location(
        "sparse_decode_bench", bench_path)
    bench = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(bench)
    return bench


def test_fused_proxies_beat_two_call_at_075():
    """Acceptance (ISSUE 2): fused grid steps <= two-call grid steps and the
    HBM-bytes-moved proxy strictly decreases, at 0.75 sparsity — enforceable
    in interpret mode on CPU (no wall clock)."""
    bench = _load_bench()
    _, _, _, stats = _pruned_packed_cfg(0.75)
    mp = bench.mlp_proxy(sparsity=0.75, stats=stats)
    assert mp["fused"]["grid_steps"] <= mp["two_call"]["grid_steps"]
    assert mp["fused"]["hbm_bytes"] < mp["two_call"]["hbm_bytes"]
    assert mp["fused"]["kernel_launches"] < mp["two_call"]["kernel_launches"]
    assert mp["fused"]["block_visits"] <= mp["two_call"]["block_visits"]
    assert mp["mixed_density"] is False       # bench config packs uniformly


def test_mlp_proxy_guards_mixed_density_archs():
    """ROADMAP latent bug (from PR 2): sparsify_mlp_params can route a
    weight dense in one layer group and packed in another, leaving
    stats["weights"][name] lists of UNEQUAL lengths. mlp_proxy must count
    only the projections packed in each layer instead of IndexError-ing."""
    bench = _load_bench()
    stats = {
        "block_density": 0.4, "packing_efficiency": 0.9,
        "weights": {
            "wg": {"real": [4, 4], "padded": [8, 8],
                   "packing_efficiency": 0.5, "dense_blocks": 16},
            "wu": {"real": [4, 4], "padded": [8, 8],
                   "packing_efficiency": 0.5, "dense_blocks": 16},
            # left dense in the second layer group: one entry only
            "wd": {"real": [4], "padded": [8],
                   "packing_efficiency": 0.5, "dense_blocks": 16},
        },
    }
    mp = bench.mlp_proxy(stats=stats)         # must not raise
    assert mp["mixed_density"] is True
    assert mp["two_call"]["grid_steps"] > 0
    assert mp["fused"]["block_visits"] <= mp["two_call"]["block_visits"]


def test_serve_equivalence_fused_vs_dense():
    """Full serve path: packed (fused megakernel) params produce the same
    logits as the dense pruned params — prefill and decode."""
    from repro.models import decoding
    cfg, pruned, packed, _ = _pruned_packed_cfg()
    toks = jnp.asarray([[5, 6, 7, 8]], jnp.int32)
    l_d, c_d = decoding.prefill(pruned, toks, cfg, 32)
    l_s, c_s = decoding.prefill(packed, toks, cfg, 32)
    np.testing.assert_allclose(np.asarray(l_d), np.asarray(l_s),
                               rtol=1e-2, atol=1e-2)
    nxt = jnp.argmax(l_d[:, -1], -1)[:, None]
    ld2, _ = decoding.serve_step(pruned, c_d, nxt, jnp.int32(4), cfg)
    ls2, _ = decoding.serve_step(packed, c_s, nxt, jnp.int32(4), cfg)
    np.testing.assert_allclose(np.asarray(ld2), np.asarray(ls2),
                               rtol=1e-2, atol=1e-2)
