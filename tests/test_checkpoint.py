"""Checkpointing: atomic save/restore, retention, elastic restore, and the
fault-tolerance supervisor (restart-on-failure, straggler detection)."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.manager import CheckpointManager
from repro.runtime.fault_tolerance import (FaultToleranceConfig,
                                           StragglerDetector, Supervisor)


def _state(x=1.0):
    return {"params": {"w": jnp.full((4, 4), x), "b": jnp.zeros((4,))},
            "step": jnp.int32(0)}


def test_save_restore_roundtrip(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    s = _state(3.5)
    mgr.save(7, s)
    restored, manifest = mgr.restore(jax.tree.map(
        lambda l: jax.ShapeDtypeStruct(l.shape, l.dtype), s))
    assert manifest["step"] == 7
    for a, b in zip(jax.tree.leaves(s), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_retention_keeps_newest(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    for step in (1, 2, 3, 4):
        mgr.save(step, _state(step))
    assert mgr.steps() == [3, 4]
    assert mgr.latest_step() == 4


def test_no_tmp_dirs_left_behind(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(1, _state())
    assert not [d for d in os.listdir(tmp_path) if d.endswith(".tmp")]


def test_restore_shape_mismatch_raises(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(1, _state())
    bad = {"params": {"w": jnp.zeros((5, 5)), "b": jnp.zeros((4,))},
           "step": jnp.int32(0)}
    with pytest.raises(AssertionError):
        mgr.restore(bad)


# ----------------------------------------------------------------- supervisor
def test_supervisor_restarts_after_injected_failure(tmp_path):
    fired = {"done": False}

    def step_fn(state, batch):
        return state + batch, {"loss": float(state)}

    def failure_injector(step, attempt):
        if step == 5 and not fired["done"]:
            fired["done"] = True
            raise RuntimeError("injected node failure")

    sup = Supervisor(
        FaultToleranceConfig(checkpoint_dir=str(tmp_path),
                             checkpoint_every=2, max_retries=2,
                             backoff_s=0.0),
        step_fn=step_fn,
        data_fn=lambda step: jnp.float32(1.0),
        init_state_fn=lambda: jnp.float32(0.0),
        failure_injector=failure_injector)
    result = sup.run(10)
    assert result["restarts"] == 1
    assert result["final_step"] == 9
    # the replayed run must produce the same final state as a clean one
    assert float(sup.ckpt.restore(jnp.float32(0))[0]) == 10.0


def test_supervisor_retry_budget_exhausts(tmp_path):
    def always_fail(state, batch):
        raise RuntimeError("dead node")

    sup = Supervisor(
        FaultToleranceConfig(checkpoint_dir=str(tmp_path), max_retries=2,
                             backoff_s=0.0),
        step_fn=always_fail, data_fn=lambda s: 0,
        init_state_fn=lambda: jnp.float32(0.0))
    with pytest.raises(RuntimeError, match="retry budget"):
        sup.run(3)


def test_straggler_detector_flags_slow_steps():
    det = StragglerDetector(factor=3.0, patience=2)
    for i in range(10):
        det.observe(i, 0.1)
    assert not det.observe(10, 0.15)
    assert det.observe(11, 1.0)           # 10x median
    assert det.observe(12, 1.0)
    assert det.persistent
    assert len(det.events) == 2


def test_straggler_detector_reset_forgets_everything():
    det = StragglerDetector(factor=3.0, patience=2)
    for i in range(10):
        det.observe(i, 0.1)
    det.observe(10, 1.0)
    det.observe(11, 1.0)
    assert det.persistent and det.times and det.events
    det.reset()
    assert det.strikes == 0 and not det.persistent
    assert det.times == [] and det.events == [] and det.last_step is None
    # post-reset: warms up from scratch (no flag until history rebuilds)
    assert not det.observe(0, 100.0)


def test_straggler_detector_tolerates_nonmonotonic_steps():
    """A replica restarts its local step counter after a failover/plan swap
    (serve/replica.py): a backwards step starts a fresh strike epoch, but
    keeps the timing history (durations stay comparable across restarts)."""
    det = StragglerDetector(factor=3.0, patience=3)
    for i in range(8):
        det.observe(i, 0.1)
    det.observe(8, 1.0)
    det.observe(9, 1.0)
    assert det.strikes == 2
    flagged = det.observe(0, 1.0)         # step clock restarted
    assert flagged                        # still slow vs retained history
    assert det.strikes == 1               # but stale strikes were cleared
    assert not det.persistent
    assert len(det.times) == 11           # history survived the restart
    # negative dt (clock skew) clamps instead of corrupting the median
    det.reset()
    for i in range(6):
        det.observe(i, -1.0)
    assert all(t == 0.0 for t in det.times)


def test_elastic_restore_with_shardings(tmp_path):
    """Restore device_puts against explicitly provided shardings (the
    re-shard-onto-new-mesh path)."""
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.launch.mesh import make_local_mesh
    mesh = make_local_mesh()
    mgr = CheckpointManager(str(tmp_path))
    s = _state(2.0)
    mgr.save(3, s)
    shardings = jax.tree.map(lambda _: NamedSharding(mesh, P()), s)
    restored, _ = mgr.restore(s, shardings=shardings)
    for a, b in zip(jax.tree.leaves(s), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
