"""HM-mesh planner: reuse model, per-layer mode selection (paper Fig. 9),
PartitionSpec synthesis, divisibility fall-backs."""
import numpy as np
import pytest
from hypothesis_compat import given, settings, st  # optional dep, see tests/hypothesis_compat.py
from jax.sharding import PartitionSpec as P

from repro.configs import SHAPES, get_config
from repro.core import hmmesh, planner, reuse
from repro.core.hmmesh import Mode


# ------------------------------------------------------------------ reuse law
def test_reuse_matches_paper_definitions():
    # conventional conv layer: lots of reuse everywhere
    c = reuse.conv("conv", n=4, c=64, m=128, h=16, w=16, r=3, s=3)
    r = reuse.reuse(c)
    assert r["weight"] > 100 and r["iact"] > 100 and r["psum"] > 100
    # depth-wise conv: G=C, M=C=1 per group — iact reuse collapses (Table I)
    dw = reuse.conv("dw", n=1, c=1, m=1, h=16, w=16, r=3, s=3, groups=64)
    assert reuse.reuse(dw)["iact"] < 10
    # FC at batch 1: weight reuse collapses to 1
    fc = reuse.gemm("fc", tokens=1, c_in=1024, m_out=1024)
    assert reuse.reuse(fc)["weight"] == 1.0


@settings(max_examples=30, deadline=None)
@given(st.integers(1, 4096), st.integers(1, 4096), st.integers(1, 4096))
def test_reuse_identity_total_macs(n, c, m):
    """MACs = reuse × count for every data type (conservation law)."""
    g = reuse.gemm("g", n, c, m)
    r = reuse.reuse(g)
    assert np.isclose(r["weight"] * g.weight_count, g.macs)
    assert np.isclose(r["iact"] * g.iact_count, g.macs)
    assert np.isclose(r["psum"] * g.psum_count, g.macs)


# --------------------------------------------------------------- mode table
MESH = planner.MeshDesc(pod=1, data=16, model=16)


def test_fig9_fc_batch1_weights_not_broadcast():
    """FC @ small batch: no weight reuse -> weights must NOT be broadcast
    (paper Fig. 9c picks unicast for weights)."""
    fc = reuse.gemm("fc", tokens=16, c_in=4096, m_out=4096)
    lp = planner.plan_layer(fc, MESH, training=False)
    assert lp.weight_mode != Mode.BROADCAST


def test_fig9_conv_like_training_avoids_weight_unicast_when_reuse_high():
    big = reuse.gemm("mlp", tokens=1 << 20, c_in=4096, m_out=16384)
    lp = planner.plan_layer(big, MESH, training=True)
    # huge token count: plenty of weight reuse; planner must exploit
    # parallelism rather than replicate compute
    assert lp.iact_mode in (Mode.INTERLEAVED_MC, Mode.UNICAST)


def test_plan_is_feasible_for_every_arch_cell():
    for arch in ("gemma2-2b", "mixtral-8x7b", "mamba2-130m"):
        cfg = get_config(arch)
        for shape in SHAPES.values():
            plan = planner.plan_model(cfg, shape, MESH)
            assert plan.layers, (arch, shape.name)
            assert plan.param_rule in ("fsdp_tp", "ep_fsdp", "tp_only",
                                       "fsdp_dp", "replicated")


def test_moe_plans_expert_parallel_when_divisible():
    cfg = get_config("llama4-maverick-400b-a17b")     # 128 experts % 16 == 0
    plan = planner.plan_model(cfg, SHAPES["train_4k"], MESH)
    assert plan.shard_experts
    cfg8 = get_config("mixtral-8x7b")                 # 8 experts % 16 != 0
    plan8 = planner.plan_model(cfg8, SHAPES["train_4k"], MESH)
    assert not plan8.shard_experts
    assert plan8.shard_ffn                            # falls back to TP


def test_gqa_kv_heads_fall_back_to_broadcast():
    cfg = get_config("gemma2-2b")                     # 8 heads, 4 kv < 16
    plan = planner.plan_model(cfg, SHAPES["train_4k"], MESH)
    assert not plan.shard_heads and not plan.shard_kv_heads
    cfg2 = get_config("qwen2.5-3b")                   # 16 heads % 16 == 0
    plan2 = planner.plan_model(cfg2, SHAPES["train_4k"], MESH)
    assert plan2.shard_heads


def test_pure_ssm_gets_unicast_act_mode():
    """mamba: no TP-able dims — the paper's Fig. 9b DW-CONV case."""
    cfg = get_config("mamba2-130m")
    plan = planner.plan_model(cfg, SHAPES["train_4k"], MESH)
    assert plan.act_axes == "all"
    assert plan.param_rule == "fsdp_dp"
    hybrid = planner.plan_model(get_config("recurrentgemma-2b"),
                                SHAPES["train_4k"], MESH)
    assert hybrid.act_axes == "dp"                    # has attention + MLP


# ----------------------------------------------------------- hmmesh -> specs
def test_mode_to_partition_spec():
    assert hmmesh.spec_for(Mode.BROADCAST, 2, 0, False) == P(None, None)
    assert hmmesh.spec_for(Mode.GROUPED_MC, 2, 1, False) == P(None, "model")
    assert hmmesh.spec_for(Mode.INTERLEAVED_MC, 3, 0, True) == \
        P(("pod", "data"), None, None)
    assert hmmesh.spec_for(Mode.UNICAST, 2, 0, True) == \
        P(("pod", "data", "model"), None)


@settings(max_examples=40, deadline=None)
@given(st.integers(1, 4096),
       st.sampled_from(list(Mode)),
       st.booleans())
def test_divisible_consistent_with_spec(dim, mode, multi_pod):
    mesh_shape = ({"pod": 2, "data": 16, "model": 16} if multi_pod
                  else {"data": 16, "model": 16})
    ok = hmmesh.divisible(dim, mode, mesh_shape, multi_pod)
    n = 1
    for a in hmmesh.mode_axes(mode, multi_pod):
        n *= mesh_shape[a]
    assert ok == (dim % n == 0 or n == 1)
