"""Per-kernel allclose vs the pure-jnp oracles (kernels/ref.py), swept over
shapes and dtypes. Kernels run interpret=True on CPU (same body as TPU)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.sparsity import bcsc_encode, block_magnitude_prune
from repro.kernels import ops, ref


# ------------------------------------------------------------------ rs_matmul
@pytest.mark.parametrize("M,K,N", [(8, 16, 8), (48, 100, 72), (129, 257, 65),
                                   (256, 128, 512), (1, 64, 1)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_rs_matmul_matches_oracle(M, K, N, dtype):
    rng = np.random.default_rng(42)
    x = jnp.asarray(rng.standard_normal((M, K)), dtype)
    w = jnp.asarray(rng.standard_normal((K, N)), dtype)
    out = ops.rs_matmul(x, w)
    expect = ref.matmul_ref(x, w)
    tol = 1e-5 if dtype == jnp.float32 else 5e-2
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect),
                               rtol=tol, atol=tol)


def test_rs_matmul_explicit_tiling():
    from repro.core.dataflow import MatmulTiling
    rng = np.random.default_rng(1)
    x = jnp.asarray(rng.standard_normal((64, 96)), jnp.float32)
    w = jnp.asarray(rng.standard_normal((96, 48)), jnp.float32)
    t = MatmulTiling(bm=16, bk=32, bn=16)
    out = ops.rs_matmul(x, w, tiling=t)
    # k-tiled accumulation reassociates the fp32 sum: allow 1e-4
    np.testing.assert_allclose(np.asarray(out),
                               np.asarray(ref.matmul_ref(x, w)),
                               rtol=1e-4, atol=1e-5)


# ----------------------------------------------------------------- bcsc_matmul
@pytest.mark.parametrize("K,N,bk,bn,sparsity", [
    (64, 96, 16, 16, 0.0), (64, 96, 16, 16, 0.5), (64, 96, 16, 16, 0.9),
    (128, 64, 32, 16, 0.75), (32, 32, 8, 8, 0.99),
])
def test_bcsc_matmul_matches_oracle(K, N, bk, bn, sparsity):
    rng = np.random.default_rng(7)
    w = rng.standard_normal((K, N)).astype(np.float32)
    if sparsity > 0:
        w = np.asarray(block_magnitude_prune(jnp.asarray(w), sparsity, bk, bn))
    m = bcsc_encode(w, bk, bn)
    x = jnp.asarray(rng.standard_normal((24, K)), jnp.float32)
    out = ops.bcsc_matmul(x, m)
    expect = ref.bcsc_matmul_ref(x, m)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect),
                               rtol=1e-5, atol=1e-5)


def test_bcsc_matmul_all_zero_matrix():
    m = bcsc_encode(np.zeros((32, 32), np.float32), 8, 8)
    x = jnp.ones((8, 32), jnp.float32)
    out = ops.bcsc_matmul(x, m)
    np.testing.assert_array_equal(np.asarray(out), 0.0)


def test_bcsc_skips_work_proportional_to_density():
    """The structural claim of §IV: grid steps scale with nnzb, not nbk·nbn."""
    rng = np.random.default_rng(3)
    w = rng.standard_normal((128, 128)).astype(np.float32)
    w_sparse = np.asarray(block_magnitude_prune(jnp.asarray(w), 0.9, 16, 16))
    m_dense = bcsc_encode(w, 16, 16)
    m_sparse = bcsc_encode(w_sparse, 16, 16)
    assert m_sparse.nnzb < m_dense.nnzb * 0.25
    assert m_sparse.density <= 0.15


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_bcsc_dtypes(dtype):
    rng = np.random.default_rng(9)
    w = rng.standard_normal((64, 64)).astype(np.float32)
    w = np.asarray(block_magnitude_prune(jnp.asarray(w), 0.6, 16, 16))
    m = bcsc_encode(w, 16, 16)
    x = jnp.asarray(rng.standard_normal((16, 64)), dtype)
    out = ops.bcsc_matmul(x, m)
    expect = ref.bcsc_matmul_ref(x, m)
    tol = 1e-5 if dtype == jnp.float32 else 5e-2
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect),
                               rtol=tol, atol=tol)


# ------------------------------------------------ sliding-window attention
@pytest.mark.parametrize("S,window,bq", [(40, 12, 8), (64, 16, 16),
                                         (33, 7, 8), (128, 128, 32)])
def test_swa_kernel_matches_oracle(S, window, bq):
    rng = np.random.default_rng(11)
    B, H, D, KV = 2, 4, 16, 2
    q = jnp.asarray(rng.standard_normal((B, S, H, D)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((B, S, KV, D)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((B, S, KV, D)), jnp.float32)
    out = ops.sliding_window_attention(q, k, v, window=window, bq=bq, bkv=bq)
    expect = ref.sliding_window_attention_ref(q, k, v, window)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect),
                               rtol=2e-4, atol=2e-4)


def test_swa_kernel_softcap():
    rng = np.random.default_rng(12)
    B, S, H, D, KV = 1, 32, 2, 8, 1
    q = jnp.asarray(rng.standard_normal((B, S, H, D)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((B, S, KV, D)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((B, S, KV, D)), jnp.float32)
    out = ops.sliding_window_attention(q, k, v, window=8, softcap=5.0,
                                       bq=8, bkv=8)
    expect = ref.sliding_window_attention_ref(q, k, v, 8, softcap=5.0)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect),
                               rtol=2e-4, atol=2e-4)


def test_flash_kernel_full_causal():
    rng = np.random.default_rng(13)
    B, S, H, D, KV = 2, 48, 4, 16, 4
    q = jnp.asarray(rng.standard_normal((B, S, H, D)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((B, S, KV, D)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((B, S, KV, D)), jnp.float32)
    out = ops.flash_attention(q, k, v, bq=16, bkv=16)
    expect = ref.flash_attention_ref(q, k, v)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect),
                               rtol=2e-4, atol=2e-4)
