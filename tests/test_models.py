"""Per-arch smoke (reduced configs): forward + one train step on CPU, output
shapes + finite values; decode-vs-prefill parity (the strongest correctness
test for the serving path)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_NAMES, get_config
from repro.data import pipeline as data_lib
from repro.models import decoding, transformer as tfm
from repro.train import loop as train_loop, optimizer as opt_lib

SEQ, BATCH = 64, 2


def _batch(cfg, seq=SEQ, batch=BATCH, seed=0):
    b = data_lib.batch_for_arch(cfg, seq, batch, step=0, seed=seed)
    return {k: jnp.asarray(v) for k, v in b.items()}


@pytest.mark.parametrize("arch", ARCH_NAMES)
def test_arch_smoke_forward_and_train_step(arch):
    cfg = get_config(arch + "-reduced")
    params = tfm.init_params(jax.random.PRNGKey(0), cfg)
    batch = _batch(cfg)

    x, aux = tfm.forward(params, batch["tokens"], cfg,
                         patch_embeds=batch.get("patch_embeds"),
                         cond=batch.get("cond"))
    S_total = SEQ if cfg.frontend != "vision" else SEQ
    assert x.shape == (BATCH, S_total, cfg.d_model)
    assert np.isfinite(np.asarray(x, np.float32)).all()

    step = train_loop.make_train_step(cfg, opt_lib.OptimizerConfig(
        warmup_steps=1, total_steps=10))
    opt_state = opt_lib.init_adamw(params)
    params2, opt_state2, metrics = jax.jit(step)(params, opt_state, batch)
    assert np.isfinite(float(metrics["loss"]))
    assert float(metrics["loss"]) > 0
    # params actually moved
    moved = any(
        not np.allclose(np.asarray(a), np.asarray(b))
        for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(params2)))
    assert moved


@pytest.mark.parametrize("arch", ["gemma2-2b", "qwen2.5-3b", "mamba2-130m",
                                  "recurrentgemma-2b", "mixtral-8x7b",
                                  "musicgen-large", "gemma3-12b"])
def test_decode_matches_forward(arch):
    """prefill(t<n) + serve_step == forward logits at the last position."""
    cfg = get_config(arch + "-reduced")
    params = tfm.init_params(jax.random.PRNGKey(1), cfg)
    batch = _batch(cfg, seq=24, batch=2, seed=3)
    toks = batch["tokens"]
    cond = batch.get("cond")

    # full forward logits at every position
    x, _ = tfm.forward(params, toks, cfg, cond=cond)
    full_logits = tfm.lm_logits(params, x, cfg)

    # prefill on all but last token, then decode the last one
    prompt = toks[..., :-1]
    last = toks[..., -1:]
    _, cache = decoding.prefill(params, prompt, cfg, cache_len=24, cond=cond)
    pos = jnp.int32(prompt.shape[-1])
    dec_logits, _ = decoding.serve_step(params, cache, last, pos, cfg,
                                        cond=cond)
    want = full_logits[:, -1:]
    # compare over the REAL vocab (padded tail is NEG_INF on both sides);
    # train path (chunked SSD / MoE sort-dispatch, bf16) and decode path
    # (fp32 recurrence / dense experts) legitimately differ in summation
    # order, so the contract is bounded deviation + argmax agreement.
    d = np.asarray(dec_logits, np.float32)[..., :cfg.vocab_size]
    w = np.asarray(want, np.float32)[..., :cfg.vocab_size]
    np.testing.assert_allclose(d, w, atol=1.0)
    assert np.mean(np.argmax(d, -1) == np.argmax(w, -1)) >= 0.75


def test_vision_arch_forward_includes_patches():
    cfg = get_config("internvl2-26b-reduced")
    params = tfm.init_params(jax.random.PRNGKey(0), cfg)
    batch = _batch(cfg)
    S_text = SEQ - cfg.num_patches
    assert batch["tokens"].shape == (BATCH, S_text)
    x, _ = tfm.forward(params, batch["tokens"], cfg,
                       patch_embeds=batch["patch_embeds"])
    assert x.shape == (BATCH, SEQ, cfg.d_model)


def test_long_context_decode_bounded_cache():
    """Ring-buffer caches: decode memory is O(window), not O(context)."""
    cfg = get_config("recurrentgemma-2b-reduced")
    cache = decoding.init_cache(cfg, batch=1, cache_len=1 << 16)
    leaves = jax.tree.leaves(cache)
    total = sum(l.size * l.dtype.itemsize for l in leaves)
    # local-attention windows (32) + rglru states only; far below 64k*d
    assert total < 4 * cfg.d_model * (1 << 16)


def test_loss_masks_padded_vocab():
    cfg = get_config("mamba2-130m-reduced")   # vocab 503 padded to 512
    params = tfm.init_params(jax.random.PRNGKey(0), cfg)
    batch = _batch(cfg)
    x, _ = tfm.forward(params, batch["tokens"], cfg)
    logits = tfm.lm_logits(params, x, cfg)
    pad = np.asarray(logits[..., cfg.vocab_size:], np.float32)
    assert (pad < -1e30).all()
