"""Sharding substrate: divisibility-guarded spec builders, autoshard param
rules, hierarchical collectives. All specs verified consistent with leaf
shapes (the invariant the 512-device dry-run depends on)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis_compat import given, settings, st  # optional dep, see tests/hypothesis_compat.py
from jax.sharding import PartitionSpec as P

from repro.configs import SHAPES, get_config
from repro.core import planner
from repro.models import decoding, transformer as tfm
from repro.sharding import autoshard, collectives, specs as sh

MESH_AXES = {"data": 16, "model": 16}
MESH_AXES_MP = {"pod": 2, "data": 16, "model": 16}


@settings(max_examples=50, deadline=None)
@given(st.integers(1, 8192),
       st.sampled_from([None, "model", ("data",), ("pod", "data"),
                        ("pod", "data", "model")]))
def test_maybe_only_returns_divisible(dim, axes):
    got = sh.maybe(axes, dim, MESH_AXES_MP)
    if got is not None:
        n = sh.axes_size(MESH_AXES_MP, got)
        assert dim % n == 0 and n > 1


def _check_spec_tree(abstract, spec_tree, mesh_axes):
    """Every spec entry must divide the corresponding dim."""
    flat_a = jax.tree.leaves(abstract)
    flat_s = jax.tree.leaves(spec_tree,
                             is_leaf=lambda x: isinstance(x, P))
    assert len(flat_a) == len(flat_s)
    for leaf, spec in zip(flat_a, flat_s):
        assert len(spec) <= len(leaf.shape), (spec, leaf.shape)
        for dim, entry in zip(leaf.shape, tuple(spec)):
            if entry is None:
                continue
            n = sh.axes_size(mesh_axes,
                             entry if isinstance(entry, tuple) else (entry,))
            assert dim % n == 0, (leaf.shape, spec)


@pytest.mark.parametrize("arch", ["gemma2-2b", "mixtral-8x7b", "mamba2-130m",
                                  "recurrentgemma-2b", "musicgen-large",
                                  "llama4-maverick-400b-a17b"])
@pytest.mark.parametrize("mesh_axes", [MESH_AXES, MESH_AXES_MP])
def test_param_specs_divide_real_arch_shapes(arch, mesh_axes):
    cfg = get_config(arch)
    md = planner.MeshDesc(pod=mesh_axes.get("pod", 1), data=16, model=16)
    plan = planner.plan_model(cfg, SHAPES["train_4k"], md)
    abstract = tfm.abstract_params(cfg)
    spec_tree = autoshard.param_specs(abstract, plan, mesh_axes)
    _check_spec_tree(abstract, spec_tree, mesh_axes)


@pytest.mark.parametrize("arch", ["gemma2-2b", "mamba2-130m", "gemma3-12b"])
def test_cache_specs_divide(arch):
    cfg = get_config(arch)
    md = planner.MeshDesc(pod=1, data=16, model=16)
    plan = planner.plan_model(cfg, SHAPES["decode_32k"], md)
    a_cache = decoding.abstract_cache(cfg, SHAPES["decode_32k"].global_batch,
                                      SHAPES["decode_32k"].seq_len)
    spec_tree = autoshard.cache_spec(a_cache, plan, MESH_AXES)
    _check_spec_tree(a_cache, spec_tree, MESH_AXES)


def test_long500k_batch1_cache_seq_sharded():
    """B=1 decode must spread the KV cache sequence, not replicate it."""
    cfg = get_config("gemma3-12b")
    md = planner.MeshDesc(pod=1, data=16, model=16)
    plan = planner.plan_model(cfg, SHAPES["long_500k"], md)
    a_cache = decoding.abstract_cache(cfg, 1, SHAPES["long_500k"].seq_len)
    spec_tree = autoshard.cache_spec(a_cache, plan, MESH_AXES)
    # find a global-attention KV leaf (cap == 524288) and check its seq spec
    found = []
    def visit(path, leaf, spec):
        if leaf.shape[-3:-2] and leaf.shape[-3] == SHAPES["long_500k"].seq_len:
            found.append(spec)
    flat_a = jax.tree_util.tree_flatten_with_path(a_cache)[0]
    flat_s = jax.tree.leaves(spec_tree, is_leaf=lambda x: isinstance(x, P))
    for (p, leaf), spec in zip(flat_a, flat_s):
        if len(leaf.shape) >= 3 and SHAPES["long_500k"].seq_len in leaf.shape:
            entries = tuple(spec)
            assert any(e is not None for e in entries), (leaf.shape, spec)
            found.append(spec)
    assert found


# ------------------------------------------------------------- collectives
def test_allreduce_stacked_single_device():
    from repro.launch.mesh import make_local_mesh
    mesh = make_local_mesh()
    x = jnp.arange(8.0)[None]          # (n_dp=1, 8)
    out = collectives.allreduce_stacked(mesh, x)
    np.testing.assert_allclose(np.asarray(out), np.asarray(x[0]))


def test_batch_spec_handles_indivisible_batch():
    cfg = get_config("gemma2-2b")
    md = planner.MeshDesc(pod=1, data=16, model=16)
    plan = planner.plan_model(cfg, SHAPES["long_500k"], md)
    abstract = {"tokens": jax.ShapeDtypeStruct((1, 7), jnp.int32)}
    spec = autoshard.batch_spec(abstract, plan, MESH_AXES)
    assert tuple(spec["tokens"]) == (None, None)       # B=1: replicate
