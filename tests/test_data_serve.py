"""Data pipeline determinism/host-sharding + serving engine behaviour."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis_compat import given, settings, st  # optional dep, see tests/hypothesis_compat.py

from repro.configs import get_config
from repro.data import pipeline as data_lib
from repro.models import decoding, transformer as tfm
from repro.serve.engine import DecodeEngine, Request
from repro.serve import kvcache


# ----------------------------------------------------------------- pipeline
def _dcfg(**kw):
    base = dict(seq_len=16, global_batch=8, vocab_size=100)
    base.update(kw)
    return data_lib.DataConfig(**base)


def test_batches_deterministic():
    cfg = _dcfg()
    a = data_lib.synth_batch(cfg, step=3)
    b = data_lib.synth_batch(cfg, step=3)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])


def test_batches_differ_across_steps_and_hosts():
    cfg = _dcfg(num_hosts=2)
    assert not np.array_equal(data_lib.synth_batch(cfg, 0, host=0)["tokens"],
                              data_lib.synth_batch(cfg, 0, host=1)["tokens"])
    assert not np.array_equal(data_lib.synth_batch(cfg, 0, host=0)["tokens"],
                              data_lib.synth_batch(cfg, 1, host=0)["tokens"])


def test_any_host_can_rebuild_any_shard():
    """The straggler re-dispatch property: shard is a pure fn of (step, host)."""
    cfg = _dcfg(num_hosts=4, host_id=2)
    mine = data_lib.synth_batch(cfg, step=9)
    rebuilt = data_lib.synth_batch(cfg, step=9, host=2)
    np.testing.assert_array_equal(mine["tokens"], rebuilt["tokens"])


def test_labels_shift_tokens():
    cfg = _dcfg()
    b = data_lib.synth_batch(cfg, 0)
    np.testing.assert_array_equal(b["labels"][:, :-1], b["tokens"][:, 1:])
    assert (b["labels"][:, -1] == -1).all()


def test_pipeline_prefetch_and_resume():
    cfg = _dcfg()
    p = data_lib.Pipeline(cfg, start_step=5)
    s, b = next(p)
    p.close()
    assert s == 5
    np.testing.assert_array_equal(b["tokens"],
                                  data_lib.synth_batch(cfg, 5)["tokens"])


@settings(max_examples=20, deadline=None)
@given(st.integers(0, 1000), st.integers(1, 8))
def test_tokens_in_vocab_range(step, hosts):
    cfg = _dcfg(num_hosts=hosts, global_batch=8 * hosts)
    b = data_lib.synth_batch(cfg, step, host=hosts - 1)
    assert (b["tokens"] >= 0).all() and (b["tokens"] < 100).all()


# ------------------------------------------------------------------- serving
def test_engine_serves_all_requests():
    cfg = get_config("qwen2.5-3b-reduced")
    params = tfm.init_params(jax.random.PRNGKey(0), cfg)
    eng = DecodeEngine(cfg, params, slots=2, cache_len=64, eos_id=-1)
    reqs = [Request(rid=i, prompt=[5, 6, 7, 8], max_new=6) for i in range(5)]
    done = eng.run(reqs)
    assert len(done) == 5
    assert all(len(r.out) == 6 for r in done)
    assert all(r.done for r in done)


def test_greedy_decode_deterministic():
    cfg = get_config("gemma2-2b-reduced")
    params = tfm.init_params(jax.random.PRNGKey(0), cfg)
    eng = DecodeEngine(cfg, params, slots=1, cache_len=32, eos_id=-1,
                       temperature=0.0)
    out1 = eng.run([Request(0, [3, 4, 5], 5)])[0].out
    out2 = eng.run([Request(1, [3, 4, 5], 5)])[0].out
    assert out1 == out2


def test_cache_report_capacity_math():
    cfg = get_config("gemma2-2b")
    rep = kvcache.report(cfg, batch=1, cache_len=8192, chips=256)
    assert rep["fits"]
    assert rep["max_slots_half_hbm"] >= 1
    assert kvcache.cache_bytes(cfg, 2, 4096) == 2 * kvcache.cache_bytes(
        cfg, 1, 4096)


def test_ring_cache_slot_positions():
    """Ring invariant: slot i holds the newest position ≡ i (mod m) ≤ pos."""
    from repro.models.decoding import _ring_positions
    pos = jnp.int32(10)
    m = 4
    got = np.asarray(_ring_positions(pos, m))
    assert got.tolist() == [8, 9, 10, 7]
    assert all(p % m == i for i, p in enumerate(got.tolist()))
    assert all(0 <= pos - p < m for p in got.tolist())
