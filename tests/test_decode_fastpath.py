"""Batch-1 sparse decode fast path (ISSUE 1): bcsc_gemv vs the oracle, fused
epilogues, the GEMV/GEMM dispatch rule, packed-MLP equivalence, and the
DecodeEngine's zero-per-token host-transfer contract."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core import dataflow
from repro.core.sparsity import bcsc_encode, block_magnitude_prune
from repro.kernels import ops, ref
from repro.kernels.epilogue import fused_epilogue
from repro.models import decoding, transformer as tfm
from repro.serve import kvcache, sparse as sps
from repro.serve.engine import DecodeEngine, Request, sample_greedy


def _sparse_bcsc(K, N, bk, bn, sparsity, seed=7):
    rng = np.random.default_rng(seed)
    w = rng.standard_normal((K, N)).astype(np.float32)
    if sparsity > 0:
        w = np.asarray(block_magnitude_prune(jnp.asarray(w), sparsity, bk, bn))
    return w, bcsc_encode(w, bk, bn)


# ------------------------------------------------------------------ bcsc_gemv
@pytest.mark.parametrize("M", [1, 4, 8])
@pytest.mark.parametrize("sparsity", [0.0, 0.5, 0.75, 0.9])
def test_bcsc_gemv_matches_oracle(M, sparsity):
    _, m = _sparse_bcsc(64, 96, 16, 16, sparsity)
    x = jnp.asarray(np.random.default_rng(1).standard_normal((M, 64)),
                    jnp.float32)
    assert dataflow.matmul_path(M) == "gemv"
    out = ops.bcsc_matmul(x, m)          # auto-dispatches to the GEMV kernel
    expect = ref.bcsc_matmul_ref(x, m)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_bcsc_gemv_dtypes(dtype):
    _, m = _sparse_bcsc(64, 64, 16, 16, 0.6)
    x = jnp.asarray(np.random.default_rng(3).standard_normal((4, 64)), dtype)
    out = ops.bcsc_gemv(x, m)
    expect = ref.bcsc_matmul_ref(x, m)
    tol = 1e-5 if dtype == jnp.float32 else 5e-2
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect),
                               rtol=tol, atol=tol)


def test_bcsc_gemv_rejects_wide_m():
    _, m = _sparse_bcsc(32, 32, 16, 16, 0.5)
    x = jnp.ones((16, 32), jnp.float32)
    with pytest.raises(AssertionError):
        ops.bcsc_gemv(x, m)


# ------------------------------------------------------------ fused epilogues
@pytest.mark.parametrize("activation", [None, "relu", "silu", "gelu"])
def test_gemv_epilogue_fusion(activation):
    _, m = _sparse_bcsc(64, 96, 16, 16, 0.7)
    rng = np.random.default_rng(11)
    x = jnp.asarray(rng.standard_normal((4, 64)), jnp.float32)
    bias = jnp.asarray(rng.standard_normal(96), jnp.float32)
    out = ops.bcsc_gemv(x, m, bias=bias, activation=activation)
    expect = fused_epilogue(ref.bcsc_matmul_ref(x, m), bias, activation)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("activation", [None, "relu", "silu", "gelu"])
def test_rs_matmul_epilogue_fusion(activation):
    rng = np.random.default_rng(12)
    x = jnp.asarray(rng.standard_normal((48, 100)), jnp.float32)
    w = jnp.asarray(rng.standard_normal((100, 72)), jnp.float32)
    bias = jnp.asarray(rng.standard_normal(72), jnp.float32)
    out = ops.rs_matmul(x, w, bias=bias, activation=activation)
    expect = fused_epilogue(ref.matmul_ref(x, w), bias, activation)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect),
                               rtol=1e-4, atol=1e-4)


def test_gemm_path_epilogue_postop():
    """M > GEMV_M_MAX takes the GEMM kernel; epilogue still applies."""
    _, m = _sparse_bcsc(64, 96, 16, 16, 0.5)
    rng = np.random.default_rng(13)
    x = jnp.asarray(rng.standard_normal((24, 64)), jnp.float32)
    bias = jnp.asarray(rng.standard_normal(96), jnp.float32)
    out = ops.bcsc_matmul(x, m, bias=bias, activation="silu")
    expect = fused_epilogue(ref.bcsc_matmul_ref(x, m), bias, "silu")
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect),
                               rtol=1e-5, atol=1e-5)


def test_epilogue_rejects_unknown_activation():
    with pytest.raises(ValueError):
        fused_epilogue(jnp.zeros((4, 4)), None, "tanh")


# ------------------------------------------------------------- dispatch rules
def test_dataflow_gemv_dispatch_rule():
    for M in (1, 4, 8):
        assert dataflow.matmul_path(M) == "gemv"
        assert dataflow.bcsc_tile_m(M) == dataflow.GEMV_BM
    for M in (9, 24, 100, 4096):
        assert dataflow.matmul_path(M) == "gemm"
    # the folded heuristic matches the old duplicated-clamp expression
    for M in (9, 17, 100, 511, 513, 10_000):
        old = min(min(512, max(8, 1 << (max(M, 1) - 1).bit_length())), 512)
        assert dataflow.bcsc_tile_m(M) == old


def test_gemv_grid_steps_beat_dense_at_70pct():
    """Acceptance: sparse decode beats dense rs_matmul at >=70% sparsity for
    batch 1 — grid-step count proxy for interpret mode."""
    K, N, bk, bn = 128, 256, 16, 16
    _, m = _sparse_bcsc(K, N, bk, bn, 0.7)
    blocks, _, _, _ = ops.prepare_bcsc(m)
    sparse_steps = blocks.shape[0]
    # normalize to identical (bk, bn) tiling for an apples-to-apples count
    dense_blocks = (K // bk) * (N // bn)
    assert sparse_steps < dense_blocks * 0.35
    assert sparse_steps < dense_blocks          # strict win at the same tiles


# ----------------------------------------------------- packed MLP equivalence
def _pruned_and_packed(cfg, sparsity=0.75):
    params = tfm.init_params(jax.random.PRNGKey(0), cfg)
    for slot in params["blocks"]:
        mlp = params["blocks"][slot].get("mlp")
        if mlp:
            for nm in list(mlp):
                w = mlp[nm]
                mlp[nm] = jnp.stack([
                    block_magnitude_prune(w[l], sparsity, 16, 16)
                    for l in range(w.shape[0])])
    packed, stats = sps.sparsify_mlp_params(params, cfg, sparsity=0.0)
    return params, packed, stats


def test_packed_mlp_serve_equivalence():
    cfg = get_config("qwen2.5-3b-reduced")
    pruned, packed, stats = _pruned_and_packed(cfg)
    assert stats["packed"] == 3                 # wg, wu, wd
    assert stats["block_density"] < 0.5
    toks = jnp.asarray([[5, 6, 7, 8]], jnp.int32)
    l_d, c_d = decoding.prefill(pruned, toks, cfg, 32)
    l_s, c_s = decoding.prefill(packed, toks, cfg, 32)
    np.testing.assert_allclose(np.asarray(l_d), np.asarray(l_s),
                               rtol=1e-2, atol=1e-2)
    nxt = jnp.argmax(l_d[:, -1], -1)[:, None]
    ld2, _ = decoding.serve_step(pruned, c_d, nxt, jnp.int32(4), cfg)
    ls2, _ = decoding.serve_step(packed, c_s, nxt, jnp.int32(4), cfg)
    np.testing.assert_allclose(np.asarray(ld2), np.asarray(ls2),
                               rtol=1e-2, atol=1e-2)


# ------------------------------------------------------- vector-pos decoding
def test_serve_step_vector_pos_matches_scalar():
    cfg = get_config("gemma2-2b-reduced")      # local+global pattern
    params = tfm.init_params(jax.random.PRNGKey(0), cfg)
    toks = jnp.asarray([[3, 4, 5], [3, 4, 5]], jnp.int32)
    logits, cache = decoding.prefill(params, toks, cfg, 32)
    nxt = jnp.argmax(logits[:, -1], -1)[:, None]
    l_scalar, c_scalar = decoding.serve_step(params, cache, nxt,
                                             jnp.int32(3), cfg)
    l_vec, c_vec = decoding.serve_step(params, cache, nxt,
                                       jnp.asarray([3, 3], jnp.int32), cfg)
    np.testing.assert_allclose(np.asarray(l_scalar), np.asarray(l_vec),
                               rtol=1e-5, atol=1e-5)
    for a, b in zip(jax.tree.leaves(c_scalar), jax.tree.leaves(c_vec)):
        np.testing.assert_allclose(np.asarray(a, dtype=np.float32),
                                   np.asarray(b, dtype=np.float32),
                                   rtol=1e-5, atol=1e-5)


# ------------------------------------------------------------- decode engine
def test_engine_matches_reference_greedy_loop():
    """The rewrite contract: identical tokens to the pre-refactor greedy loop
    (prefill + one serve_step per token, argmax sampling)."""
    cfg = get_config("qwen2.5-3b-reduced")
    params = tfm.init_params(jax.random.PRNGKey(0), cfg)
    prompt, max_new, cache_len = [5, 6, 7, 8], 6, 64

    toks = jnp.asarray([prompt], jnp.int32)
    logits, cache = decoding.prefill(params, toks, cfg, cache_len)
    pos, last, expect = jnp.int32(len(prompt)), logits[:, -1], []
    for _ in range(max_new):
        nxt = sample_greedy(last)
        expect.append(int(nxt[0]))
        logits, cache = decoding.serve_step(params, cache, nxt[:, None],
                                            pos, cfg)
        last, pos = logits[:, -1], pos + 1

    eng = DecodeEngine(cfg, params, slots=2, cache_len=cache_len, eos_id=-1,
                       sync_every=4)
    got = eng.run([Request(0, prompt, max_new)])[0].out
    assert got == expect


def test_engine_zero_per_token_host_transfers(monkeypatch):
    """Between refills the decode loop is device-resident: one device_get per
    sync_every-token chunk, never one per token."""
    cfg = get_config("qwen2.5-3b-reduced")
    params = tfm.init_params(jax.random.PRNGKey(0), cfg)
    sync_every, max_new, n_req = 4, 8, 3
    eng = DecodeEngine(cfg, params, slots=2, cache_len=64, eos_id=-1,
                       sync_every=sync_every)

    calls = {"n": 0}
    real_get = jax.device_get

    def counting_get(x):
        calls["n"] += 1
        return real_get(x)

    monkeypatch.setattr(jax, "device_get", counting_get)
    done = eng.run([Request(i, [5, 6, 7], max_new) for i in range(n_req)])
    total_tokens = sum(len(r.out) for r in done)
    assert total_tokens == n_req * max_new
    # 2 slots x 8 tokens in chunks of 4 -> 2 chunks per cohort, 2 cohorts = 4
    assert calls["n"] == eng.host_syncs
    assert calls["n"] <= -(-max_new // sync_every) * 2   # per-chunk, not per-token
    assert calls["n"] < total_tokens


def test_engine_eos_frees_slot_for_refill():
    """A slot hitting EOS is freed and refilled; every request completes."""
    cfg = get_config("qwen2.5-3b-reduced")
    params = tfm.init_params(jax.random.PRNGKey(0), cfg)
    eng = DecodeEngine(cfg, params, slots=1, cache_len=48, eos_id=-1,
                       sync_every=2)
    done = eng.run([Request(i, [2 + i, 3, 4], 3) for i in range(3)])
    assert len(done) == 3
    assert all(r.done and len(r.out) == 3 for r in done)


def test_engine_eos_terminates_early():
    cfg = get_config("qwen2.5-3b-reduced")
    params = tfm.init_params(jax.random.PRNGKey(0), cfg)
    # discover the greedy first token, then declare it the EOS id
    probe = DecodeEngine(cfg, params, slots=1, cache_len=48, eos_id=-1)
    first = probe.run([Request(0, [5, 6, 7], 1)])[0].out[0]
    eng = DecodeEngine(cfg, params, slots=1, cache_len=48, eos_id=first,
                       sync_every=4)
    done = eng.run([Request(0, [5, 6, 7], 8)])
    assert done[0].out == [first]            # EOS emitted, then slot freed


def test_sparse_params_engine_runs_gemv_decode():
    """End-to-end: BCSC-packed params serve through the engine and produce
    the same tokens as the dense pruned params."""
    cfg = get_config("qwen2.5-3b-reduced")
    pruned, packed, _ = _pruned_and_packed(cfg)
    reqs = lambda: [Request(0, [5, 6, 7, 8], 4), Request(1, [1, 2], 4)]
    dense_out = [r.out for r in DecodeEngine(
        cfg, pruned, slots=2, cache_len=48, eos_id=-1).run(reqs())]
    sparse_out = [r.out for r in DecodeEngine(
        cfg, packed, slots=2, cache_len=48, eos_id=-1).run(reqs())]
    assert dense_out == sparse_out


# ------------------------------------------------------- batched prefill (ISSUE 2)
def test_prefill_batched_matches_per_row_prefill():
    """Right-padded batched prefill == per-row batch-1 prefill, judged by the
    decode-visible contract: last-position logits AND the logits of a decode
    step taken from the resulting cache (this exercises the ragged ring
    gather, the pad-KV masking of global entries, and per-row positions)."""
    cfg = get_config("gemma2-2b-reduced")       # local+global -> ring caches
    params = tfm.init_params(jax.random.PRNGKey(0), cfg)
    prompts = [[5, 6, 7], [9, 8, 7, 6, 5, 4], [1, 2]]
    B = len(prompts)
    S = max(len(p) for p in prompts)
    toks = np.zeros((B, S), np.int32)
    for i, p in enumerate(prompts):
        toks[i, :len(p)] = p
    lengths = np.asarray([len(p) for p in prompts], np.int32)
    lb, cb = decoding.prefill_batched(params, jnp.asarray(toks),
                                      jnp.asarray(lengths), cfg, 32)
    nxt = jnp.argmax(lb[:, -1], -1)[:, None]
    lb2, _ = decoding.serve_step(params, cb, nxt,
                                 jnp.asarray(lengths, jnp.int32), cfg)
    for i, p in enumerate(prompts):
        l1, c1 = decoding.prefill(params, jnp.asarray([p], jnp.int32),
                                  cfg, 32)
        np.testing.assert_allclose(np.asarray(lb[i:i + 1]), np.asarray(l1),
                                   rtol=2e-2, atol=2e-2)
        l2, _ = decoding.serve_step(params, c1, nxt[i:i + 1],
                                    jnp.int32(len(p)), cfg)
        np.testing.assert_allclose(np.asarray(lb2[i:i + 1]), np.asarray(l2),
                                   rtol=2e-2, atol=2e-2)


@pytest.mark.parametrize("arch", ["qwen2.5-3b-reduced", "gemma2-2b-reduced"])
def test_engine_batched_prefill_matches_per_request(arch):
    """Tier-bucketed batched admission produces the same tokens as separate
    single-request engines, for mixed prompt lengths."""
    cfg = get_config(arch)
    params = tfm.init_params(jax.random.PRNGKey(0), cfg)
    prompts = [[5, 6, 7], [9, 8, 7, 6, 5, 4], [1, 2], [3, 3, 3, 3, 3]]
    ref = []
    for i, p in enumerate(prompts):
        eng = DecodeEngine(cfg, params, slots=1, cache_len=64, eos_id=-1,
                           sync_every=4)
        ref.append(eng.run([Request(i, p, 5)])[0].out)
    eng = DecodeEngine(cfg, params, slots=4, cache_len=64, eos_id=-1,
                       sync_every=4)
    done = eng.run([Request(i, p, 5) for i, p in enumerate(prompts)])
    got = [r.out for r in sorted(done, key=lambda r: r.rid)]
    assert got == ref
    st = eng.phase_stats
    # lengths 3,6,2,5 -> pow2 tiers {4, 8, 2} -> 3 batched prefills, not 4
    assert st["prefill_prompts"] == 4
    assert st["prefill_batches"] == 3
    assert st["prefill_real_tokens"] == 16
    assert st["prefill_padded_tokens"] == 2 + 4 + 8 * 2


def test_engine_tier_rule():
    cfg = get_config("qwen2.5-3b-reduced")
    params = tfm.init_params(jax.random.PRNGKey(0), cfg)
    eng = DecodeEngine(cfg, params, slots=1, cache_len=32, eos_id=-1)
    assert not eng._recurrent
    assert [eng._tier(n) for n in (1, 2, 3, 4, 5, 8, 9)] == \
        [1, 2, 4, 4, 8, 8, 16]
    rcfg = get_config("recurrentgemma-2b-reduced")
    rparams = tfm.init_params(jax.random.PRNGKey(0), rcfg)
    reng = DecodeEngine(rcfg, rparams, slots=1, cache_len=32, eos_id=-1)
    assert reng._recurrent            # pads would pollute rg-lru state:
    assert [reng._tier(n) for n in (3, 5, 7)] == [3, 5, 7]   # exact buckets


def test_engine_recurrent_arch_batched_admission():
    """Recurrent archs batch exact-length buckets (no padding) and still
    match per-request decoding."""
    cfg = get_config("recurrentgemma-2b-reduced")
    params = tfm.init_params(jax.random.PRNGKey(0), cfg)
    prompts = [[5, 6, 7], [8, 9, 10], [1, 2]]     # two share a length bucket
    ref = []
    for i, p in enumerate(prompts):
        eng = DecodeEngine(cfg, params, slots=1, cache_len=64, eos_id=-1,
                           sync_every=4)
        ref.append(eng.run([Request(i, p, 4)])[0].out)
    eng = DecodeEngine(cfg, params, slots=3, cache_len=64, eos_id=-1,
                       sync_every=4)
    done = eng.run([Request(i, p, 4) for i, p in enumerate(prompts)])
    assert [r.out for r in sorted(done, key=lambda r: r.rid)] == ref
    assert eng.phase_stats["prefill_batches"] == 2          # {len3 x2, len2}
    assert eng.phase_stats["prefill_padded_tokens"] == \
        eng.phase_stats["prefill_real_tokens"]              # exact tiers


# ------------------------------------------------------------- slot allocator
def test_slot_allocator_accounting():
    a = kvcache.SlotAllocator(2)
    assert a.available() == 2 and a.in_use == 0
    s0, s1 = a.alloc(), a.alloc()
    assert {s0, s1} == {0, 1} and a.available() == 0
    with pytest.raises(RuntimeError):
        a.alloc()
    a.free(s0)
    assert a.available() == 1 and a.live_slots() == [s1]
    with pytest.raises(ValueError):
        a.free(s0)
    assert a.alloc() == s0
