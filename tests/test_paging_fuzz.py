"""Randomized PageAllocator fuzz (ISSUE 6 satellite): seeded op sequences,
full invariant audit after every operation.

The auditor (serve.guard.audit_pool) asserts refcount-sum == block-table
references, refcount 0 ⟺ free, no duplicate pages within a table, lengths
covered, prefix-index residency — so "zero leaked pages" is checked after
every single mutation, not just at the end. Runs with or without hypothesis
(tests/hypothesis_compat.py); every sweep is seeded, so a failure names the
seed that reproduces it.
"""
import numpy as np
import pytest

from hypothesis_compat import HAS_HYPOTHESIS, fuzz_seeds, given, settings, st
from repro.serve.guard import PoolAuditError, assert_pool_clean, audit_pool
from repro.serve.paging import PageAllocator

NUM_PAGES = 24
PAGE_SIZE = 4


def _random_ops(pager, rng, steps=120, vocab=6, first_rid=0):
    """Drive one seeded op sequence; audit after EVERY mutation."""
    live = {}                     # rid -> prompt tokens (for registration)
    forks = {}                    # rid -> fork child rid (at most one each)
    next_rid = first_rid
    for _ in range(steps):
        op = rng.choice(["admit", "extend", "free", "cow", "grow_check",
                         "fork", "commit", "abort"])
        if op == "admit":
            rid = next_rid
            next_rid += 1
            plen = int(rng.integers(1, 4 * PAGE_SIZE))
            prompt = [int(t) for t in rng.integers(0, vocab, plen)]
            shared = pager.adopt_prefix(rid, prompt)
            assert shared <= plen
            if not pager.ensure(rid, plen):
                if pager.pages_of(rid):
                    pager.free(rid)          # roll back adoption, like the
                continue                     # scheduler's admission path
            pager.set_length(rid, plen)
            pager.register_prefix(rid, prompt)
            live[rid] = prompt
        elif op == "extend" and live:
            rid = int(rng.choice(list(live)))
            n = int(rng.integers(1, 2 * PAGE_SIZE))
            want = sum(1 for _ in live[rid]) + n
            # CoW before extending into shared pages, like the decode loop
            for logical in list(pager.shared_pages_in(
                    rid, len(live[rid]), want)):
                if pager.cow_page(rid, logical) is None:
                    break
            if pager.ensure(rid, want):
                pager.set_length(rid, want)
                live[rid] = live[rid] + [int(t) for t in
                                         rng.integers(0, vocab, n)]
        elif op == "free" and live:
            rid = int(rng.choice(list(live)))
            if rid in forks:                 # eviction aborts the branch
                pager.abort_fork(forks.pop(rid))
            pager.free(rid)
            del live[rid]
        elif op == "cow" and live:
            rid = int(rng.choice(list(live)))
            shared = pager.shared_pages_in(rid, 0, len(live[rid]))
            if shared:
                pager.cow_page(rid, shared[0])
        elif op == "grow_check":
            # audit-only step: exercised below via audit; keep op mix stable
            pass
        elif op == "fork" and live:
            # speculative branch: fork ids live at -2 - rid (the scheduler's
            # spelling — rids are >= 0 and -1 is its empty-row sentinel)
            cands = [r for r in live if r not in forks]
            if cands:
                rid = int(rng.choice(cands))
                child = -2 - rid
                got = pager.fork_chain(rid, child,
                                       cow_tail=bool(rng.integers(0, 2)))
                if got is None:              # pool pressure: nothing changed
                    assert not pager.pages_of(child)
                else:
                    forks[rid] = child
                    # draft appends land in the fork's tail headroom
                    want = len(live[rid]) + int(rng.integers(1, PAGE_SIZE))
                    if pager.ensure(child, want):
                        pager.set_length(child, want)
        elif op == "commit" and forks:
            rid = int(rng.choice(list(forks)))
            pager.commit_fork(rid, forks.pop(rid))
        elif op == "abort" and forks:
            rid = int(rng.choice(list(forks)))
            pager.abort_fork(forks.pop(rid))
        violations = audit_pool(pager)
        assert not violations, (violations, op)
    for rid in list(forks):
        pager.abort_fork(forks.pop(rid))
        assert not audit_pool(pager)
    for rid in list(live):
        pager.free(rid)
        assert not audit_pool(pager)
    assert_pool_clean(pager, drained=True)


@pytest.mark.parametrize("seed", fuzz_seeds(8))
def test_fuzz_alloc_free_adopt_cow(seed):
    _random_ops(PageAllocator(NUM_PAGES, PAGE_SIZE),
                np.random.default_rng(seed))


@pytest.mark.parametrize("seed", fuzz_seeds(3, base=1))
def test_fuzz_with_midrun_grow(seed):
    """grow() (the int8 degrade rung's pool expansion) preserves every
    invariant: old pages keep ids/contents, new ids join the free list."""
    rng = np.random.default_rng(seed)
    pager = PageAllocator(NUM_PAGES, PAGE_SIZE)
    live = []
    for rid in range(4):
        if pager.ensure(rid, int(rng.integers(1, 3 * PAGE_SIZE))):
            live.append(rid)
    assert not audit_pool(pager)
    added = pager.grow(NUM_PAGES * 2)
    assert added == NUM_PAGES
    assert pager.num_pages == NUM_PAGES * 2
    assert not audit_pool(pager)
    for rid in live:                 # drain the pre-grow residents so the
        pager.free(rid)              # sweep's drained audit sees one ledger
    _random_ops(pager, rng, steps=60, first_rid=100)


def test_refcount_sum_equals_held_pages():
    """Σ refcounts == Σ block-table lengths after every op in a sharing-heavy
    sequence (the exact 'zero leaked pages' ledger)."""
    pager = PageAllocator(NUM_PAGES, PAGE_SIZE)
    prompt = [1, 2, 3, 4, 5, 6, 7, 8]        # two full pages
    assert pager.adopt_prefix(0, prompt) == 0
    assert pager.ensure(0, len(prompt))
    pager.set_length(0, len(prompt))
    pager.register_prefix(0, prompt)
    for rid in (1, 2, 3):
        assert pager.adopt_prefix(rid, prompt) == len(prompt)
        pager.set_length(rid, len(prompt))
        snap = pager.snapshot()
        assert sum(snap["refs"]) == sum(len(t) for t in
                                        snap["tables"].values())
        assert not audit_pool(pager)
    for rid in (0, 1, 2, 3):
        pager.free(rid)
    assert_pool_clean(pager, drained=True)


def test_audit_catches_manufactured_corruption():
    """The auditor is only trustworthy if it actually fires: corrupt a pool
    in each invariant class and expect a named violation."""
    def fresh():
        p = PageAllocator(8, PAGE_SIZE)
        assert p.ensure(0, 2 * PAGE_SIZE)
        p.set_length(0, 2 * PAGE_SIZE)
        return p

    p = fresh()                               # leaked page: refcount drift
    p._refs[p._tables[0][0]] += 1
    assert any("refcount" in v for v in audit_pool(p))

    p = fresh()                               # double-free hazard
    p._free.append(p._tables[0][0])
    assert any("free list" in v or "double-free" in v for v in audit_pool(p))

    p = fresh()                               # duplicate page in one table
    dup = p._tables[0][0]
    p._tables[0][1] = dup
    assert any("twice" in v for v in audit_pool(p))

    p = fresh()                               # length not covered by pages
    p._lengths[0] = 10 * PAGE_SIZE
    assert any("not covered" in v for v in audit_pool(p))

    p = fresh()                               # dangling prefix index entry
    p._prefix_index[(-1, (9, 9, 9, 9))] = 7
    assert any("prefix" in v for v in audit_pool(p))

    p = fresh()                               # drained-only violations
    with pytest.raises(PoolAuditError, match="holds tables"):
        assert_pool_clean(p, drained=True)
    assert not audit_pool(p)                  # ...but clean when not drained


if HAS_HYPOTHESIS:
    @given(st.integers(min_value=0, max_value=2**31 - 1))
    @settings(max_examples=25, deadline=None)
    def test_fuzz_property(seed):
        _random_ops(PageAllocator(NUM_PAGES, PAGE_SIZE),
                    np.random.default_rng(seed), steps=60)
