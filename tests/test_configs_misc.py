"""Config sanity (analytic param counts vs known model sizes), RS tiling
properties, elastic restore."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis_compat import given, settings, st  # optional dep, see tests/hypothesis_compat.py

from repro.configs import ARCH_NAMES, SHAPES, get_config
from repro.core import dataflow

# Published total parameter counts (±tolerance: vocab padding, bias/norm
# accounting, tied embeddings differ slightly across reports).
KNOWN_PARAMS = {
    "gemma2-2b": (2.6e9, 0.3),
    "mistral-nemo-12b": (12.2e9, 0.15),
    "qwen2.5-3b": (3.1e9, 0.25),
    "gemma3-12b": (12.2e9, 0.25),
    "mamba2-130m": (0.13e9, 0.25),
    "recurrentgemma-2b": (2.7e9, 0.30),
    "internvl2-26b": (20e9, 0.35),    # backbone only (frontend is a stub)
    "musicgen-large": (3.3e9, 0.4),
    "mixtral-8x7b": (46.7e9, 0.15),
    "llama4-maverick-400b-a17b": (400e9, 0.25),
}


@pytest.mark.parametrize("arch", ARCH_NAMES)
def test_param_count_matches_published(arch):
    cfg = get_config(arch)
    got = cfg.param_count()
    want, tol = KNOWN_PARAMS[arch]
    assert abs(got - want) / want < tol, (arch, f"{got:.3e}", f"{want:.3e}")


def test_moe_active_params_far_below_total():
    cfg = get_config("llama4-maverick-400b-a17b")   # 128 experts, top-1
    total = cfg.param_count()
    active = cfg.param_count(active_only=True)
    assert active < total / 5


@pytest.mark.parametrize("arch", ARCH_NAMES)
def test_reduced_configs_validate(arch):
    cfg = get_config(arch + "-reduced")
    cfg.validate()
    assert cfg.param_count() < 5e6          # genuinely tiny


# --------------------------------------------------------------- RS tilings
@settings(max_examples=50, deadline=None)
@given(st.integers(1, 16384), st.integers(1, 16384), st.integers(1, 65536))
def test_rs_tiling_always_fits_vmem(M, K, N):
    t = dataflow.rs_matmul_tiling(M, K, N)
    assert t.fits()
    assert t.bm >= 1 and t.bk >= 1 and t.bn >= 1


def test_rs_tiling_mxu_aligned_for_big_matmuls():
    t = dataflow.rs_matmul_tiling(4096, 4096, 14336)
    assert t.bn % 128 == 0 and t.bk % 128 == 0
    assert t.bm % 8 == 0


# ------------------------------------------------------------ elastic restore
def test_elastic_restore_replans_for_new_mesh(tmp_path):
    from repro.checkpoint.manager import CheckpointManager
    from repro.launch.mesh import make_local_mesh
    from repro.runtime import elastic
    from repro.train import loop as train_loop

    cfg = get_config("qwen2.5-3b-reduced")
    params, opt = train_loop.init_train_state(jax.random.PRNGKey(0), cfg)
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(5, (params, opt))

    mesh = make_local_mesh()                 # the "new" (degraded) mesh
    abstract = train_loop.abstract_train_state(cfg)
    (p2, o2), manifest = elastic.restore_elastic(
        mgr, abstract, cfg, SHAPES["train_4k"], mesh)
    assert manifest["step"] == 5
    for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(p2)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
