"""Chaos + guard suite (ISSUE 6): every request terminal, zero leaked pages,
survivors bit-identical under injected faults, graceful degradation.

All model-driven tests run with ``audit_every_sync=True`` so the pool
invariant auditor runs after every sync window — a leak fails at the
boundary that caused it. Greedy decoding (temperature=0) + pre-dispatch
fault injection make every assertion bit-exact and seed-reproducible.
"""
import jax
import pytest

from repro.configs import get_config
from repro.core import plan as plan_lib
from repro.models import transformer as tfm
from repro.runtime.fault_tolerance import backoff_delay
from repro.serve import LLM
from repro.serve.chaos import ChaosConfig, FaultInjector, InjectedFault
from repro.serve.guard import GuardConfig, RequestOutcome
from repro.serve.scheduler import ContinuousBatchingScheduler, StreamRequest

pytestmark = pytest.mark.chaos

AUDIT = dict(audit_every_sync=True)


@pytest.fixture(scope="module")
def model():
    cfg = get_config("qwen2.5-3b-reduced")
    params = tfm.init_params(jax.random.PRNGKey(0), cfg)
    return cfg, params


def _plan(cfg, rows=3, cache_len=64, page_size=4, num_pages=24):
    return plan_lib.plan_for_scheduler(cfg, rows=rows, cache_len=cache_len,
                                       page_size=page_size,
                                       num_pages=num_pages)


def _reqs(n=4, max_new=8, arrival=0.0, **kw):
    return [StreamRequest(rid=i, prompt=[3 + i, 5, 7], max_new=max_new,
                          arrival=arrival, **kw) for i in range(n)]


def _llm(cfg, params, plan, **guard_kw):
    guard_kw = {**AUDIT, **guard_kw}
    return LLM(cfg, params, plan, eos_id=-1, guard=GuardConfig(**guard_kw))


# ----------------------------------------------------------- pure-unit layer
def test_outcome_status_validated():
    with pytest.raises(AssertionError):
        RequestOutcome("vanished")
    assert RequestOutcome("ok").ok and not RequestOutcome("shed").ok


def test_backoff_delay_schedule():
    assert [backoff_delay(a, 0.5) for a in (1, 2, 3)] == [0.5, 1.0, 2.0]
    assert backoff_delay(0, 0.5) == 0.5          # clamped, never negative


def test_injector_is_deterministic_and_bounded():
    cfg = ChaosConfig(seed=3, ensure_fail_rate=0.5, ensure_fail_max=5)
    runs = []
    for _ in range(2):
        inj = FaultInjector(cfg)
        runs.append([inj.ensure_fails(0, 4) for _ in range(64)])
    assert runs[0] == runs[1]                    # same seed, same schedule
    assert sum(runs[0]) == 5                     # capped: runs terminate

    inj = FaultInjector(ChaosConfig(step_fail_chunks=(1,),
                                    step_fail_attempts=2))
    inj.check_step(0)                            # not listed: passes
    for _ in range(2):
        with pytest.raises(InjectedFault):
            inj.check_step(1)
    inj.check_step(1)                            # budget spent: passes
    assert inj.injected["step"] == 2

    inj = FaultInjector(ChaosConfig(nan_rids={2: (7,)}))
    assert inj.nan_rids_for(2) == (7,)
    assert inj.nan_rids_for(2) == ()             # fires at most once


# ------------------------------------------------------- facade validation
def test_facade_rejects_empty_batch(model):
    cfg, params = model
    with pytest.raises(ValueError, match="empty request list"):
        _llm(cfg, params, _plan(cfg)).stream([])


def test_facade_rejects_empty_prompt(model):
    cfg, params = model
    with pytest.raises(ValueError, match="empty prompt"):
        _llm(cfg, params, _plan(cfg)).stream([([], 4)])


def test_facade_names_cache_len_limit(model):
    cfg, params = model
    llm = _llm(cfg, params, _plan(cfg, cache_len=32))
    with pytest.raises(ValueError, match=r"cache_len \(32\)"):
        llm.stream([(list(range(1, 30)), 8)])
    with pytest.raises(ValueError, match=r"cache_len \(32\)"):
        llm.generate([(list(range(1, 30)), 8)])


# ------------------------------------------------------------ guarded loop
def test_clean_run_all_ok_with_outcome_stats(model):
    cfg, params = model
    llm = _llm(cfg, params, _plan(cfg))
    seen = []
    done = llm.stream(_reqs(), on_outcome=lambda r, o: seen.append((r.rid,
                                                                    o.status)))
    assert all(r.outcome is not None and r.outcome.ok for r in done)
    assert all(len(r.out) == 8 for r in done)
    assert sorted(seen) == [(i, "ok") for i in range(4)]
    assert llm.phase_stats["outcomes"] == {
        "ok": 4, "shed": 0, "expired": 0, "preempted_out": 0, "failed": 0}


def test_deadline_expires_waiting_and_active(model):
    cfg, params = model
    llm = _llm(cfg, params, _plan(cfg))       # rows=3: rid 3 must wait
    reqs = _reqs(n=4, max_new=16)
    reqs[3].ttl = 4.0                         # waits behind 3 busy rows
    reqs[1].ttl = 4.0                         # admitted, dies mid-generation
    done = {r.rid: r for r in llm.stream(reqs)}
    assert done[0].outcome.ok and len(done[0].out) == 16
    assert done[1].outcome.status == "expired"
    assert 0 < len(done[1].out) < 16          # partial output kept
    assert "mid-generation" in done[1].outcome.reason
    assert done[3].outcome.status == "expired"
    assert done[3].out == [] and "before admission" in done[3].outcome.reason


def test_preempted_out_bounds_starvation(model):
    """Satellite (b): a request preempted past retry_budget resolves as
    ``preempted_out`` instead of recompute-thrashing, and the whole run —
    including re-admission order — is deterministic."""
    cfg, params = model
    plan = _plan(cfg, rows=3, cache_len=64, page_size=4, num_pages=6)
    outs = []
    for _ in range(2):
        llm = _llm(cfg, params, plan, retry_budget=0,
                   degrade_rungs=("shed",), shed_pressure=2.0)
        done = llm.stream(_reqs(n=4, max_new=12))
        assert llm.phase_stats["preemptions"] > 0
        statuses = {r.rid: r.outcome.status for r in done}
        assert "preempted_out" in statuses.values()
        for r in done:
            if r.outcome.status == "preempted_out":
                assert "retry budget" in r.outcome.reason
        outs.append([(r.rid, r.outcome.status, list(r.out)) for r in done])
    assert outs[0] == outs[1]                 # deterministic re-admission


def test_generous_budget_still_completes(model):
    """Same overloaded pool, default budget: everyone eventually finishes
    (the legacy recompute path, now with outcomes attached)."""
    cfg, params = model
    plan = _plan(cfg, rows=3, cache_len=64, page_size=4, num_pages=6)
    llm = _llm(cfg, params, plan, degrade_rungs=("shed",), shed_pressure=2.0)
    done = llm.stream(_reqs(n=4, max_new=12))
    assert all(r.outcome.ok and len(r.out) == 12 for r in done)


def test_shed_at_arrival_under_pressure(model):
    cfg, params = model
    llm = _llm(cfg, params, _plan(cfg), degrade_rungs=("shed",),
               shed_pressure=0.01)
    reqs = _reqs(n=3, max_new=16)             # fill the pool at t=0
    late = StreamRequest(rid=9, prompt=[2, 3], max_new=4, arrival=8.0)
    done = {r.rid: r for r in llm.stream(reqs + [late])}
    assert done[9].outcome.status == "shed"
    assert "pool pressure" in done[9].outcome.reason
    assert all(done[i].outcome.ok for i in range(3))
    assert llm.phase_stats["outcomes"]["shed"] == 1


def test_clamp_rung_degrades_budget(model):
    cfg, params = model
    llm = _llm(cfg, params, _plan(cfg), degrade_rungs=("clamp_max_new",),
               clamp_pressure=0.01, clamp_max_new=2)
    reqs = _reqs(n=3, max_new=16)
    late = StreamRequest(rid=9, prompt=[2, 3], max_new=16, arrival=8.0)
    done = {r.rid: r for r in llm.stream(reqs + [late])}
    assert done[9].outcome.ok
    assert len(done[9].out) == 2              # clamped, not shed
    assert done[9].outcome.degraded == ("clamp_max_new",)
    assert llm.phase_stats["clamped_admissions"] == 1


def test_int8_rung_migrates_pool_and_finishes_everyone(model):
    cfg, params = model
    plan = _plan(cfg, rows=4, cache_len=64, page_size=4, num_pages=16)
    assert "int8_kv" in plan.degrade and plan.num_pages_int8 > plan.num_pages
    llm = _llm(cfg, params, plan, int8_pressure=0.3)
    done = llm.stream([StreamRequest(rid=i, prompt=[3 + i, 5, 7, 11],
                                     max_new=16, arrival=float(i))
                       for i in range(6)])
    st = llm.phase_stats
    assert st["kv_quant"] == "int8" and "degraded_to_int8_at" in st
    assert all(r.outcome.ok and len(r.out) == 16 for r in done)


# ------------------------------------------------------------ chaos harness
def test_chaos_survivors_bit_identical(model):
    """The headline chaos invariant: under injected ensure failures, a
    transient step fault and a NaN poisoning, every request is terminal, the
    pool audits clean after every sync window, and every surviving request's
    tokens are bit-identical to the fault-free run."""
    cfg, params = model
    plan = _plan(cfg)
    llm = _llm(cfg, params, plan, degrade_rungs=("shed",))
    clean = {r.rid: list(r.out) for r in llm.stream(_reqs())}
    done = llm.stream(_reqs(), chaos=ChaosConfig(
        seed=7, ensure_fail_rate=0.3, ensure_fail_max=4,
        step_fail_chunks=(0,), step_fail_attempts=2, nan_rids={0: (2,)}))
    st = llm.phase_stats
    assert st["chaos_injected"]["ensure"] >= 1
    assert st["chaos_injected"]["step"] == 2
    assert st["chaos_injected"]["nan"] == 1
    assert all(r.outcome is not None for r in done)      # all terminal
    by_rid = {r.rid: r for r in done}
    assert by_rid[2].outcome.status == "failed"
    assert "non-finite" in by_rid[2].outcome.reason
    for r in done:
        if r.outcome.ok:
            assert list(r.out) == clean[r.rid]           # bit-identical


def test_chaos_transient_step_fault_retries(model):
    cfg, params = model
    llm = _llm(cfg, params, _plan(cfg), max_step_retries=3)
    done = llm.stream(_reqs(), chaos=ChaosConfig(
        step_fail_chunks=(0,), step_fail_attempts=2))
    assert llm.phase_stats["step_retries"] == 2
    assert all(r.outcome.ok and len(r.out) == 8 for r in done)


def test_chaos_permanent_step_fault_fails_everything(model):
    cfg, params = model
    llm = _llm(cfg, params, _plan(cfg), max_step_retries=1)
    done = llm.stream(_reqs(), chaos=ChaosConfig(
        step_fail_chunks=(0,), step_fail_attempts=99))
    assert all(r.outcome.status == "failed" for r in done)
    assert all("retries spent" in r.outcome.reason for r in done)
    # drained-pool audit ran inside the scheduler: no leak despite the abort


def test_chaos_ensure_starvation_terminates(model):
    """Heavy spurious allocation failures may stall admission but must never
    hang the loop or leak pages — the capped injector plus the clock advance
    on empty boundaries guarantee forward progress."""
    cfg, params = model
    llm = _llm(cfg, params, _plan(cfg), degrade_rungs=("shed",))
    done = llm.stream(_reqs(), chaos=ChaosConfig(
        seed=11, ensure_fail_rate=0.9, ensure_fail_max=16))
    assert all(r.outcome is not None for r in done)
    assert all(r.outcome.ok for r in done)     # transient: all finish


def test_guard_off_preserves_legacy_behavior(model):
    """guard=False is the pre-ISSUE-6 scheduler: no ladder, no deadline
    machinery, infeasible requests still raise (caller bug, both modes) —
    and the tokens match the guarded run exactly (the guard is pure policy,
    it never touches the numerics)."""
    cfg, params = model
    plan = _plan(cfg)
    guarded = {r.rid: list(r.out)
               for r in _llm(cfg, params, plan).stream(_reqs())}
    llm = LLM(cfg, params, plan, eos_id=-1, guard=False)
    done = llm.stream(_reqs())
    assert not llm.phase_stats["guard_enabled"]
    assert "outcomes" not in llm.phase_stats
    assert {r.rid: list(r.out) for r in done} == guarded
    tiny = LLM(cfg, params,
               _plan(cfg, rows=1, cache_len=64, page_size=4, num_pages=4),
               eos_id=-1, guard=False)
    with pytest.raises(ValueError, match="can never run"):
        tiny.stream([([1, 2, 3], 14)])
