"""Continuous-batching scheduler + paging subsystem (ISSUE 3): PageAllocator
accounting, SlotAllocator batch ops, the streaming scheduler vs the
DecodeEngine reference, arrivals, EOS page return, and recompute preemption."""
import jax
import pytest

from repro.configs import get_config
from repro.core import dataflow
from repro.models import transformer as tfm
from repro.serve import kvcache
from repro.serve.engine import DecodeEngine, Request
from repro.serve.paging import PageAllocator
from repro.serve.scheduler import ContinuousBatchingScheduler, StreamRequest


# ------------------------------------------------------------ page allocator
def test_page_allocator_alloc_free_accounting():
    a = PageAllocator(4, page_size=8)
    assert a.available() == 4 and a.in_use == 0
    assert a.ensure(0, 9)                 # 2 pages
    assert a.pages_of(0) == 2 and a.available() == 2
    assert a.ensure(0, 10)                # still 2 pages — no growth
    assert a.pages_of(0) == 2
    assert a.ensure(1, 8)                 # 1 page
    assert a.table(0) == [0, 1] and a.table(1) == [2]
    assert a.free(0) == 2
    assert a.available() == 3
    with pytest.raises(ValueError):
        a.free(0)                         # double free


def test_page_allocator_exhaustion_is_all_or_nothing():
    a = PageAllocator(3, page_size=4)
    assert a.ensure(0, 8)                 # 2 pages
    assert not a.ensure(1, 12)            # needs 3, only 1 free — no change
    assert a.available() == 1 and a.pages_of(1) == 0
    assert 1 not in a.live_requests()
    assert a.ensure(1, 4)                 # 1 page fits
    assert not a.ensure(1, 8)             # growth refused, table unchanged
    assert a.pages_of(1) == 1


def test_page_allocator_pop_order_deterministic():
    a = PageAllocator(4, page_size=4)
    a.ensure(0, 4)
    a.ensure(1, 8)
    assert a.table(0) == [0] and a.table(1) == [1, 2]
    a.free(0)
    a.free(1)
    a.ensure(2, 12)                       # freed pages come back lowest-first
    assert a.table(2) == [0, 1, 2]


def test_page_allocator_stats_fragmentation():
    a = PageAllocator(8, page_size=8)
    a.ensure(0, 9)                        # 2 pages for 9 tokens
    a.set_length(0, 9)
    s = a.stats()
    assert s["pages_used"] == 2 and s["pages_free"] == 6
    assert s["used_tokens"] == 9
    assert s["fragmentation"] == pytest.approx(1 - 9 / 16)
    a.free(0)
    assert a.stats()["fragmentation"] == 0.0


def test_block_table_rows_device_view():
    a = PageAllocator(6, page_size=4)
    a.ensure(7, 10)                       # 3 pages
    bt = a.block_table_rows([7, -1], max_pages=4)
    assert bt.shape == (2, 4)
    assert bt[0].tolist() == [0, 1, 2, -1]
    assert bt[1].tolist() == [-1, -1, -1, -1]


# ------------------------------------------------------------ slot allocator
def test_slot_allocator_alloc_many_exhaustion_and_order():
    a = kvcache.SlotAllocator(4)
    got = a.alloc_many(3)
    assert got == [0, 1, 2]               # pop-order determinism
    with pytest.raises(RuntimeError):
        a.alloc_many(2)                   # only 1 free — all-or-nothing
    assert a.available() == 1             # nothing was partially taken
    a.free_many([1, 2])
    assert a.available() == 3
    with pytest.raises(ValueError):
        a.free_many([1])                  # double free via the batch API
    assert a.alloc_many(0) == []


# --------------------------------------------------------- kvcache satellites
def test_max_slots_zero_when_one_slot_oversized():
    cfg = get_config("gemma2-2b")
    # astronomically long context: one slot alone exceeds half-HBM
    assert kvcache.max_slots(cfg, cache_len=1 << 28, chips=1) == 0
    assert kvcache.max_slots(cfg, cache_len=8192, chips=256) >= 1


def test_engine_raises_on_zero_slots():
    cfg = get_config("qwen2.5-3b-reduced")
    params = tfm.init_params(jax.random.PRNGKey(0), cfg)
    with pytest.raises(ValueError, match="slots must be >= 1"):
        DecodeEngine(cfg, params, slots=0, cache_len=32)
    with pytest.raises(ValueError, match="rows must be >= 1"):
        ContinuousBatchingScheduler(cfg, params, rows=0, cache_len=32)


def test_report_includes_paged_occupancy():
    cfg = get_config("gemma2-2b")
    pager = PageAllocator(16, page_size=64)
    pager.ensure(0, 100)
    pager.set_length(0, 100)
    rep = kvcache.report(cfg, batch=4, cache_len=8192, chips=256, pager=pager)
    assert rep["paged"]["pages_total"] == 16
    assert rep["paged"]["pages_used"] == 2
    assert 0.0 < rep["paged"]["fragmentation"] < 1.0
    assert "paged" not in kvcache.report(cfg, 4, 8192, 256)


# ----------------------------------------------------------------- scheduler
PROMPTS = [[5, 6, 7], [9, 8, 7, 6, 5, 4], [1, 2], [3, 3, 3, 3, 3]]


def _engine_reference(cfg, params, prompts, max_new, cache_len=64):
    eng = DecodeEngine(cfg, params, slots=1, cache_len=cache_len, eos_id=-1,
                       sync_every=4)
    return [eng.run([Request(99, p, max_new)])[0].out for p in prompts]


@pytest.mark.parametrize("attn_path", ["paged", "contiguous"])
def test_scheduler_matches_engine_tokens(attn_path):
    """Both dispatch arms produce the engine's exact greedy tokens."""
    cfg = get_config("qwen2.5-3b-reduced")
    params = tfm.init_params(jax.random.PRNGKey(0), cfg)
    ref = _engine_reference(cfg, params, PROMPTS, 5)
    sch = ContinuousBatchingScheduler(cfg, params, rows=2, cache_len=64,
                                      page_size=8, eos_id=-1, sync_every=4,
                                      attn_path=attn_path)
    assert sch.paged == (attn_path == "paged")
    done = sch.run([StreamRequest(i, p, 5) for i, p in enumerate(PROMPTS)])
    got = [r.out for r in sorted(done, key=lambda r: r.rid)]
    assert got == ref
    assert sch.phase_stats["attn_path"] == attn_path


def test_scheduler_recurrent_arch_contiguous_fallback():
    """Archs without global attention dispatch contiguous automatically."""
    cfg = get_config("recurrentgemma-2b-reduced")
    params = tfm.init_params(jax.random.PRNGKey(0), cfg)
    sch = ContinuousBatchingScheduler(cfg, params, rows=2, cache_len=64,
                                      eos_id=-1, sync_every=4)
    assert not sch.paged
    ref = _engine_reference(cfg, params, PROMPTS[:3], 4)
    done = sch.run([StreamRequest(i, p, 4) for i, p in enumerate(PROMPTS[:3])])
    assert [r.out for r in sorted(done, key=lambda r: r.rid)] == ref


def test_scheduler_streaming_callbacks_in_order():
    cfg = get_config("qwen2.5-3b-reduced")
    params = tfm.init_params(jax.random.PRNGKey(0), cfg)
    sch = ContinuousBatchingScheduler(cfg, params, rows=2, cache_len=64,
                                      page_size=8, eos_id=-1, sync_every=4)
    seen = {}
    reqs = [StreamRequest(i, p, 5,
                          on_token=lambda r, t: seen.setdefault(r.rid, []
                                                                ).append(t))
            for i, p in enumerate(PROMPTS)]
    done = sch.run(reqs)
    for r in done:
        assert seen[r.rid] == r.out       # streamed == accumulated, in order


def test_scheduler_arrival_gating_and_latency_stamps():
    cfg = get_config("qwen2.5-3b-reduced")
    params = tfm.init_params(jax.random.PRNGKey(0), cfg)
    sch = ContinuousBatchingScheduler(cfg, params, rows=2, cache_len=64,
                                      page_size=8, eos_id=-1, sync_every=4)
    reqs = [StreamRequest(0, [5, 6, 7], 4, arrival=0.0),
            StreamRequest(1, [1, 2], 4, arrival=10.0)]
    done = sch.run(reqs)
    by_rid = {r.rid: r for r in done}
    assert by_rid[0].admitted_at == 0.0
    assert by_rid[1].admitted_at >= 10.0          # never admitted early
    for r in done:
        assert r.first_token_at > r.admitted_at - 1e-9
        assert r.finished_at >= r.first_token_at
        assert r.finished_wall_s > 0


def test_scheduler_idle_jump_to_next_arrival():
    """With nothing active, the virtual clock jumps to the next arrival
    instead of spinning empty chunks."""
    cfg = get_config("qwen2.5-3b-reduced")
    params = tfm.init_params(jax.random.PRNGKey(0), cfg)
    sch = ContinuousBatchingScheduler(cfg, params, rows=2, cache_len=64,
                                      page_size=8, eos_id=-1, sync_every=4)
    done = sch.run([StreamRequest(0, [5, 6], 4, arrival=100.0)])
    assert done[0].admitted_at == 100.0
    assert sch.phase_stats["idle_steps"] == 100.0
    assert sch.phase_stats["decode_chunks"] == 1


def test_scheduler_eos_returns_pages():
    """Pages go back to the pool when a request finishes by EOS."""
    cfg = get_config("qwen2.5-3b-reduced")
    params = tfm.init_params(jax.random.PRNGKey(0), cfg)
    probe = ContinuousBatchingScheduler(cfg, params, rows=1, cache_len=48,
                                        page_size=8, eos_id=-1, sync_every=2)
    first = probe.run([StreamRequest(0, [5, 6, 7], 1)])[0].out[0]
    sch = ContinuousBatchingScheduler(cfg, params, rows=1, cache_len=48,
                                      page_size=8, eos_id=first, sync_every=4)
    done = sch.run([StreamRequest(0, [5, 6, 7], 8),
                    StreamRequest(1, [5, 6, 7], 8)])
    assert all(r.out == [first] for r in done)    # EOS cut both short
    st = sch.phase_stats["pages"]
    assert st["pages_free"] == st["pages_total"]  # everything returned
    peak = sch.phase_stats["pages_peak"]
    assert peak["pages_used"] > 0                 # mid-run occupancy recorded
    assert peak["used_tokens"] > 0


def test_scheduler_preemption_recompute_exact():
    """Under page pressure the latest-admitted request is preempted and
    recomputed — final tokens still match the unpressured reference."""
    cfg = get_config("qwen2.5-3b-reduced")
    params = tfm.init_params(jax.random.PRNGKey(0), cfg)
    ref = _engine_reference(cfg, params, PROMPTS, 12)
    sch = ContinuousBatchingScheduler(cfg, params, rows=3, cache_len=64,
                                      page_size=4, num_pages=6, eos_id=-1,
                                      sync_every=4)
    done = sch.run([StreamRequest(i, p, 12) for i, p in enumerate(PROMPTS)])
    got = [r.out for r in sorted(done, key=lambda r: r.rid)]
    assert got == ref
    assert sch.phase_stats["preemptions"] > 0
    assert max(r.preemptions for r in done) > 0
    st = sch.phase_stats["pages"]
    assert st["pages_free"] == st["pages_total"]


def test_scheduler_rejects_impossible_requests():
    cfg = get_config("qwen2.5-3b-reduced")
    params = tfm.init_params(jax.random.PRNGKey(0), cfg)
    sch = ContinuousBatchingScheduler(cfg, params, rows=1, cache_len=32,
                                      page_size=8, eos_id=-1)
    with pytest.raises(ValueError, match="exceeds cache_len"):
        sch.run([StreamRequest(0, [1] * 30, 8)])
    with pytest.raises(ValueError, match="rids must be unique"):
        sch.run([StreamRequest(0, [1, 2], 2), StreamRequest(0, [3, 4], 2)])
    tiny = ContinuousBatchingScheduler(cfg, params, rows=1, cache_len=32,
                                       page_size=8, num_pages=2, eos_id=-1)
    with pytest.raises(ValueError, match="can never run"):
        tiny.run([StreamRequest(0, [1] * 20, 8)])


def test_tier_clamped_to_cache_len():
    """A prompt whose pow2 tier exceeds cache_len must still prefill: the
    tier clamps (right-padding stays exact at any tier >= plen)."""
    from repro.serve.engine import length_tier
    assert length_tier(17, False, 24) == 24
    assert length_tier(17, False) == 32           # unclamped helper
    cfg = get_config("qwen2.5-3b-reduced")
    params = tfm.init_params(jax.random.PRNGKey(0), cfg)
    prompt = [3] * 17                             # pow2 tier 32 > cache_len 24
    eng = DecodeEngine(cfg, params, slots=1, cache_len=24, eos_id=-1,
                       sync_every=2)
    ref = eng.run([Request(0, prompt, 4)])[0].out
    assert len(ref) == 4
    sch = ContinuousBatchingScheduler(cfg, params, rows=1, cache_len=24,
                                      page_size=8, eos_id=-1, sync_every=2)
    done = sch.run([StreamRequest(0, prompt, 4)])
    assert done[0].out == ref


def test_scheduler_validates_feasibility_up_front():
    """A late-arriving infeasible request fails at run() entry, before any
    device work — finished requests' results are never lost mid-run."""
    cfg = get_config("qwen2.5-3b-reduced")
    params = tfm.init_params(jax.random.PRNGKey(0), cfg)
    sch = ContinuousBatchingScheduler(cfg, params, rows=1, cache_len=32,
                                      page_size=8, eos_id=-1)
    ok = StreamRequest(0, [5, 6], 3, arrival=0.0)
    bad = StreamRequest(1, [1] * 30, 8, arrival=500.0)
    with pytest.raises(ValueError, match="exceeds cache_len"):
        sch.run([ok, bad])
    assert ok.out == []                       # raised before any decoding


def test_scheduler_paged_pool_smaller_than_dense():
    """The configuration the subsystem exists for: a page pool provisioned
    below rows × cache_len still serves everything correctly."""
    cfg = get_config("qwen2.5-3b-reduced")
    params = tfm.init_params(jax.random.PRNGKey(0), cfg)
    rows, cache_len, ps = 4, 64, 8
    dense_pages = rows * (cache_len // ps)
    sch = ContinuousBatchingScheduler(cfg, params, rows=rows,
                                      cache_len=cache_len, page_size=ps,
                                      num_pages=dense_pages // 2, eos_id=-1,
                                      sync_every=4)
    ref = _engine_reference(cfg, params, PROMPTS, 6)
    done = sch.run([StreamRequest(i, p, 6) for i, p in enumerate(PROMPTS)])
    assert [r.out for r in sorted(done, key=lambda r: r.rid)] == ref
    assert dataflow.paged_kv_tokens(
        [len(p) + 6 for p in PROMPTS], ps) < dataflow.dense_kv_tokens(
        rows, cache_len)
