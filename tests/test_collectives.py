"""Direct unit tests for the sharding primitives (ISSUE 10 satellite):
``sharding.specs`` spec construction and axis-size edge cases,
``sharding.collectives`` on the degenerate 1-device mesh (every collective
must be a no-op/identity) and — in a subprocess with a forced 8-device host
platform — against the flat jax.lax references, plus the exact-concat
shard helpers in ``sharding.tensor_parallel``."""
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.sharding import collectives, specs as sh
from repro.sharding import tensor_parallel as tpar


def _mesh1(*axis_names):
    """A mesh of the single host device with 1-sized named axes."""
    devs = np.array(jax.devices()[:1]).reshape((1,) * len(axis_names))
    return Mesh(devs, axis_names)


# ------------------------------------------------------------------- specs
def test_mesh_axis_sizes_and_dp_axes():
    mesh = _mesh1("pod", "data", "model")
    assert sh.mesh_axis_sizes(mesh) == {"pod": 1, "data": 1, "model": 1}
    assert sh.dp_axes({"pod": 2, "data": 4, "model": 2}) == ("pod", "data")
    assert sh.dp_axes({"data": 4, "model": 2}) == ("data",)
    assert sh.dp_axes({"model": 2}) == ()


def test_axes_size_forms():
    ax = {"pod": 2, "data": 4, "model": 8}
    assert sh.axes_size(ax, None) == 1
    assert sh.axes_size(ax, "model") == 8
    assert sh.axes_size(ax, ("pod", "data")) == 8
    assert sh.axes_size(ax, ()) == 1


def test_maybe_divisibility_fallback():
    """``maybe`` is the fall-back-to-BROADCAST rule: a dimension that does
    not divide over the axis group must shard on None (replicate)."""
    ax = {"data": 4, "model": 8}
    assert sh.maybe("model", 64, ax) == "model"
    assert sh.maybe("model", 4, ax) is None          # 4 % 8 != 0
    assert sh.maybe(None, 64, ax) is None
    assert sh.maybe("model", 0, ax) == "model"       # 0 divides anything
    # single-element sequences collapse to the bare axis name
    assert sh.maybe(["model"], 64, ax) == "model"
    assert sh.maybe(("data", "model"), 64, ax) == ("data", "model")
    assert sh.maybe(("data", "model"), 8, ax) is None  # 8 % 32 != 0
    # a 1-sized axis group never shards
    assert sh.maybe("model", 64, {"model": 1}) is None


def test_named_and_tree_named_build_shardings():
    mesh = _mesh1("data")
    ns = sh.named(mesh, P("data"))
    assert isinstance(ns, NamedSharding)
    assert ns.spec == P("data")
    tree = {"a": P(), "b": {"c": P("data")}}
    out = sh.tree_named(mesh, tree)
    assert out["a"].spec == P() and out["b"]["c"].spec == P("data")


# --------------------------------------- degenerate 1-device mesh: no-ops
def test_allreduce_stacked_one_device_is_identity_sum():
    mesh = _mesh1("data")
    x = jnp.arange(12, dtype=jnp.float32).reshape(1, 3, 4)
    out = collectives.allreduce_stacked(mesh, x)
    assert out.shape == (3, 4)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(x[0]))


def test_hierarchical_psum_one_device_identity():
    mesh = _mesh1("pod", "data")
    x = jnp.arange(10, dtype=jnp.float32).reshape(2, 5)
    out = collectives.shard_map(
        lambda v: collectives.hierarchical_psum(v, "pod", "data"),
        mesh=mesh, in_specs=P(), out_specs=P())(x)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(x))


def test_ring_allgather_one_device_identity():
    mesh = _mesh1("model")
    x = jnp.arange(6, dtype=jnp.float32).reshape(2, 3)
    out = collectives.shard_map(
        lambda v: collectives.ring_allgather(v, "model"),
        mesh=mesh, in_specs=P(), out_specs=P("model"))(x)
    assert out.shape == (1, 2, 3)     # new leading gather dim, 1 source
    np.testing.assert_array_equal(np.asarray(out[0]), np.asarray(x))


# --------------------------------------------- tensor_parallel: exact math
def test_shard_slice_partitions_exactly():
    x = jnp.arange(24).reshape(2, 12)
    parts = [tpar.shard_slice(x, 1, s, 4) for s in range(4)]
    assert all(p.shape == (2, 3) for p in parts)
    np.testing.assert_array_equal(
        np.asarray(jnp.concatenate(parts, axis=1)), np.asarray(x))
    with pytest.raises(AssertionError):
        tpar.shard_slice(x, 1, 0, 5)            # 12 % 5 != 0


def test_all_gather_single_part_no_op():
    x = jnp.ones((2, 3))
    assert tpar.all_gather([x], axis=0) is x    # identity, no concat/copy
    out = tpar.all_gather([x, 2 * x], axis=0)
    assert out.shape == (4, 3)


def test_sharded_expert_mlp_bit_identical():
    rng = np.random.default_rng(3)
    E, d, f = 8, 16, 32
    x = jnp.asarray(rng.standard_normal((2, 1, d)), jnp.float32)
    wg = jnp.asarray(rng.standard_normal((E, d, f)), jnp.float32)
    wu = jnp.asarray(rng.standard_normal((E, d, f)), jnp.float32)
    wd = jnp.asarray(rng.standard_normal((E, f, d)), jnp.float32)
    act = jax.nn.silu
    g = jnp.einsum("bsd,edf->ebsf", x, wg,
                   preferred_element_type=jnp.float32)
    u = jnp.einsum("bsd,edf->ebsf", x, wu,
                   preferred_element_type=jnp.float32)
    full = jnp.einsum("ebsf,efd->ebsd", act(g) * u, wd,
                      preferred_element_type=jnp.float32)
    for ep in (1, 2, 4, 8):
        shard = tpar.sharded_expert_mlp(
            x, wg, wu, wd, act=act, cast=lambda t: t, ep=ep,
            accum_dtype=jnp.float32, compute_dtype=jnp.float32)
        assert jnp.array_equal(full, shard), f"ep={ep} diverged"


def test_sharded_decode_attention_bit_identical():
    from repro.configs import get_config
    from repro.models import layers
    cfg = get_config("gemma2-2b-reduced")
    B, T = 2, 16
    KV, D, H = cfg.num_kv_heads, cfg.head_dim, cfg.num_heads
    rng = np.random.default_rng(5)
    q = jnp.asarray(rng.standard_normal((B, 1, H, D)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((B, T, KV, D)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((B, T, KV, D)), jnp.float32)
    mask = jnp.arange(T)[None, :] < jnp.asarray([[9], [13]])
    full = layers.decode_attention(q, k, v, mask, cfg)
    for tp in (1, KV):
        shard = tpar.sharded_decode_attention(q, k, v, mask, cfg, tp)
        assert jnp.array_equal(full, shard), f"tp={tp} diverged"


# ---------------------------------------- multi-device (subprocess, mesh8)
_MULTI = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import PartitionSpec as P
from repro.sharding import collectives

try:
    from jax.sharding import AxisType
    kw = {"axis_types": (AxisType.Auto,) * 2}
except ImportError:
    kw = {}
mesh = jax.make_mesh((2, 4), ("pod", "data"), **kw)
rng = np.random.default_rng(0)
x = jnp.asarray(rng.standard_normal((8, 3, 5)), jnp.float32)

# hierarchical RS->AR->AG == flat psum over both axes
hier = collectives.shard_map(
    lambda v: collectives.hierarchical_psum(v[0], "pod", "data"),
    mesh=mesh, in_specs=P(("pod", "data")), out_specs=P())(x)
flat = collectives.shard_map(
    lambda v: jax.lax.psum(v[0], ("pod", "data")),
    mesh=mesh, in_specs=P(("pod", "data")), out_specs=P())(x)
np.testing.assert_allclose(np.asarray(hier), np.asarray(flat),
                           rtol=1e-6, atol=1e-6)

# allreduce_stacked == plain sum over the stacked dim
out = collectives.allreduce_stacked(mesh, x)
np.testing.assert_allclose(np.asarray(out), np.asarray(x.sum(0)),
                           rtol=1e-6, atol=1e-6)

# ring all-gather == lax.all_gather (source-index order)
mesh_m = jax.make_mesh((8,), ("model",), **({"axis_types": kw.get(
    "axis_types", ())[:1]} if kw else {}))
y = jnp.asarray(rng.standard_normal((16, 4)), jnp.float32)
ring = collectives.shard_map(
    lambda v: collectives.ring_allgather(v, "model"),
    mesh=mesh_m, in_specs=P("model"), out_specs=P("model"))(y)
ref = collectives.shard_map(
    lambda v: jax.lax.all_gather(v, "model"),
    mesh=mesh_m, in_specs=P("model"), out_specs=P("model"))(y)
np.testing.assert_array_equal(np.asarray(ring), np.asarray(ref))
print("MULTI_OK")
"""


def test_collectives_match_flat_references_on_8_devices():
    r = subprocess.run([sys.executable, "-c", _MULTI],
                       capture_output=True, text=True, timeout=600)
    assert r.returncode == 0, r.stderr
    assert "MULTI_OK" in r.stdout
