"""models.flash custom-VJP: forward + gradients vs direct softmax attention,
all three masking modes, GQA, softcap."""
import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import flash

B, S, H, D, KV = 2, 50, 4, 16, 2
R = H // KV


def _mk(seed=0, dtype=jnp.float32):
    rng = np.random.default_rng(seed)
    q = jnp.asarray(rng.standard_normal((B, KV, R, S, D)), dtype)
    k = jnp.asarray(rng.standard_normal((B, KV, S, D)), dtype)
    v = jnp.asarray(rng.standard_normal((B, KV, S, D)), dtype)
    return q, k, v


def _direct(q, k, v, mode, msize, softcap):
    s = jnp.einsum("bgrqd,bgkd->bgrqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) / math.sqrt(D)
    if softcap > 0:
        s = jnp.tanh(s / softcap) * softcap
    qp = jnp.arange(S)[:, None]
    kp = jnp.arange(S)[None, :]
    m = kp <= qp
    if mode == "window":
        m &= (qp - kp) < msize
    elif mode == "chunk":
        m &= (qp // msize) == (kp // msize)
    s = jnp.where(m[None, None, None], s, -2e38)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bgrqk,bgkd->bgrqd", p, v.astype(jnp.float32))


MODES = [("causal", S), ("window", 12), ("chunk", 16)]


@pytest.mark.parametrize("mode,msize", MODES)
@pytest.mark.parametrize("softcap", [0.0, 5.0])
def test_forward(mode, msize, softcap):
    q, k, v = _mk()
    out = flash.flash_attention(q, k, v, mode, msize, softcap, 16, 16)
    expect = _direct(q, k, v, mode, msize, softcap)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(expect), rtol=1e-2, atol=1e-2)


@pytest.mark.parametrize("mode,msize", MODES)
def test_gradients(mode, msize):
    q, k, v = _mk(3)
    w = jnp.asarray(np.random.default_rng(5).standard_normal(
        (B, KV, R, S, D)), jnp.float32)

    def loss_flash(q_, k_, v_):
        o = flash.flash_attention(q_, k_, v_, mode, msize, 0.0, 16, 16)
        return jnp.sum(o.astype(jnp.float32) * w)

    def loss_ref(q_, k_, v_):
        return jnp.sum(_direct(q_, k_, v_, mode, msize, 0.0) * w)

    g1 = jax.grad(loss_flash, argnums=(0, 1, 2))(q, k, v)
    g2 = jax.grad(loss_ref, argnums=(0, 1, 2))(q, k, v)
    for a, b in zip(g1, g2):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=2e-2, atol=2e-2)


def test_gradients_softcap():
    q, k, v = _mk(4)
    w = jnp.asarray(np.random.default_rng(8).standard_normal(
        (B, KV, R, S, D)), jnp.float32)

    def loss_flash(q_):
        o = flash.flash_attention(q_, k, v, "window", 8, 4.0, 16, 16)
        return jnp.sum(o.astype(jnp.float32) * w)

    def loss_ref(q_):
        return jnp.sum(_direct(q_, k, v, "window", 8, 4.0) * w)

    np.testing.assert_allclose(np.asarray(jax.grad(loss_flash)(q)),
                               np.asarray(jax.grad(loss_ref)(q)),
                               rtol=2e-2, atol=2e-2)


def test_block_size_invariance():
    """Result must not depend on block decomposition."""
    q, k, v = _mk(6)
    outs = [flash.flash_attention(q, k, v, "causal", S, 0.0, b, b)
            for b in (8, 16, 64)]
    for o in outs[1:]:
        np.testing.assert_allclose(np.asarray(outs[0], np.float32),
                                   np.asarray(o, np.float32),
                                   rtol=1e-2, atol=1e-2)


def test_numerical_stability_large_logits():
    q, k, v = _mk(7)
    out = flash.flash_attention(q * 100, k * 100, v, "causal", S, 0.0, 16, 16)
    assert np.isfinite(np.asarray(out, np.float32)).all()
