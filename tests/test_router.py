"""Router placement units (serve/router.py): prefix affinity, queue-depth
fallback, imbalance override, tenant-fair dispatch order. Pure host logic —
no model, no device work."""
import pytest

from repro.serve.router import Router, RouterConfig
from repro.serve.scheduler import StreamRequest


class FakeReplica:
    def __init__(self, slot, depth=0):
        self.slot = slot
        self.depth = depth

    def queue_depth(self):
        return self.depth


def _req(rid, prompt, arrival=0.0, tenant=None):
    return StreamRequest(rid=rid, prompt=list(prompt), max_new=4,
                         arrival=arrival, tenant=tenant)


# ---------------------------------------------------------------- affinity
def test_prefix_key_is_one_page_and_page_gated():
    r = Router(page_size=4)
    assert r.prefix_key([1, 2, 3]) is None          # shorter than a page
    assert r.prefix_key([1, 2, 3, 4]) == (1, 2, 3, 4)
    assert r.prefix_key([1, 2, 3, 4, 9, 9]) == (1, 2, 3, 4)
    assert Router(RouterConfig(affinity=False),
                  page_size=4).prefix_key([1, 2, 3, 4]) is None
    assert Router(page_size=0).prefix_key([1, 2, 3, 4]) is None


def test_same_prefix_routes_to_same_replica():
    r = Router(page_size=4)
    reps = [FakeReplica(0), FakeReplica(1), FakeReplica(2)]
    sys_prompt = [7, 7, 7, 7]
    first = r.place(_req(0, sys_prompt + [1]), reps)
    first.depth += 1
    for i in range(1, 5):
        rep = r.place(_req(i, sys_prompt + [i + 1]), reps)
        assert rep.slot == first.slot     # follows the claim despite depth
        rep.depth += 1
    assert r.stats["affinity_hits"] == 4


def test_affinity_yields_to_load_past_imbalance():
    r = Router(RouterConfig(max_depth_imbalance=2), page_size=4)
    reps = [FakeReplica(0, depth=0), FakeReplica(1, depth=0)]
    home = r.place(_req(0, [7, 7, 7, 7, 1]), reps)
    assert home.slot == 0                 # least depth, lowest slot
    reps[0].depth = 5                     # home now 5 deeper than replica 1
    moved = r.place(_req(1, [7, 7, 7, 7, 2]), reps)
    assert moved.slot == 1
    assert r.stats["affinity_overridden"] == 1
    # and the claim moved with it: next follower goes to the new home
    assert r.place(_req(2, [7, 7, 7, 7, 3]), reps).slot == 1


def test_no_key_falls_back_to_least_depth_lowest_slot():
    r = Router(page_size=4)
    reps = [FakeReplica(0, depth=3), FakeReplica(1, depth=1),
            FakeReplica(2, depth=1)]
    assert r.place(_req(0, [1, 2]), reps).slot == 1   # depth tie -> low slot


def test_forget_replica_drops_claims():
    r = Router(page_size=4)
    reps = [FakeReplica(0), FakeReplica(1, depth=9)]
    assert r.place(_req(0, [7, 7, 7, 7]), reps).slot == 0
    assert r.forget_replica(0) == 1
    # claim gone: placement re-judges by depth among survivors
    assert r.place(_req(1, [7, 7, 7, 7]), [reps[1]]).slot == 1


def test_place_requires_live_replicas():
    with pytest.raises(RuntimeError, match="no live replicas"):
        Router(page_size=4).place(_req(0, [1, 2, 3, 4]), [])


# ---------------------------------------------------------------- fairness
def test_fair_order_interleaves_tenants():
    burst = [_req(i, [1], arrival=0.0, tenant="a") for i in range(4)]
    single = [_req(10, [1], arrival=0.0, tenant="b")]
    order = Router.fair_order(burst + single)
    rids = [r.rid for r in order]
    # tenant b's lone request lands second, not behind the whole burst
    assert rids == [0, 10, 1, 2, 3]


def test_fair_order_stable_within_tenant_and_deterministic():
    reqs = [_req(2, [1], arrival=1.0, tenant="a"),
            _req(0, [1], arrival=0.0, tenant="a"),
            _req(5, [1], arrival=0.5, tenant="b"),
            _req(3, [1], arrival=2.0, tenant="b"),
            _req(9, [1], arrival=0.0)]            # None -> default tenant
    a = [r.rid for r in Router.fair_order(reqs)]
    b = [r.rid for r in Router.fair_order(list(reversed(reqs)))]
    assert a == b                                  # input-order independent
    pos = {rid: i for i, rid in enumerate(a)}
    assert pos[0] < pos[2] and pos[5] < pos[3]     # (arrival, rid) in tenant
