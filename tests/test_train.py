"""Training substrate: optimizer, microbatch equivalence, loss descent,
gradient compression."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.data import pipeline as data_lib
from repro.models import transformer as tfm
from repro.train import grad_compression, loop as train_loop, optimizer as opt_lib


def _setup(arch="qwen2.5-3b", seq=32, batch=4):
    cfg = get_config(arch + "-reduced")
    params = tfm.init_params(jax.random.PRNGKey(0), cfg)
    b = data_lib.batch_for_arch(cfg, seq, batch, step=0)
    return cfg, params, {k: jnp.asarray(v) for k, v in b.items()}


# ------------------------------------------------------------------ optimizer
def test_adamw_decreases_quadratic():
    cfg = opt_lib.OptimizerConfig(peak_lr=0.1, warmup_steps=0,
                                  total_steps=100, weight_decay=0.0)
    params = {"w": jnp.array([3.0, -2.0])}
    state = opt_lib.init_adamw(params)
    for _ in range(60):
        grads = {"w": 2 * params["w"]}
        params, state, _ = opt_lib.adamw_update(cfg, params, grads, state)
    assert float(jnp.sum(jnp.square(params["w"]))) < 0.5


def test_grad_clip_bounds_norm():
    g = {"a": jnp.full((10,), 100.0)}
    clipped, norm = opt_lib.clip_by_global_norm(g, 1.0)
    assert float(norm) > 100
    assert np.isclose(float(opt_lib.global_norm(clipped)), 1.0, rtol=1e-5)


def test_cosine_schedule_shape():
    cfg = opt_lib.OptimizerConfig(peak_lr=1e-3, warmup_steps=10,
                                  total_steps=100, min_lr_ratio=0.1)
    lrs = [float(opt_lib.cosine_schedule(cfg, jnp.int32(s)))
           for s in (0, 5, 10, 50, 100)]
    assert lrs[0] < lrs[1] < lrs[2]             # warmup ascends
    assert np.isclose(lrs[2], 1e-3)
    assert lrs[3] < lrs[2] and lrs[4] < lrs[3]  # cosine descends
    assert lrs[4] >= 1e-4 * 0.99                # floor at min_lr_ratio


# ------------------------------------------------------------- microbatching
def test_microbatch_equivalent_gradients():
    """mb=1 vs mb=2 must produce (nearly) identical updated params."""
    cfg, params, batch = _setup()
    ocfg = opt_lib.OptimizerConfig(warmup_steps=0, total_steps=10)
    s1 = train_loop.make_train_step(cfg, ocfg, microbatches=1)
    s2 = train_loop.make_train_step(cfg, ocfg, microbatches=2)
    opt = opt_lib.init_adamw(params)
    p1, _, m1 = jax.jit(s1)(params, opt, batch)
    opt = opt_lib.init_adamw(params)
    p2, _, m2 = jax.jit(s2)(params, opt, batch)
    # losses averaged identically
    np.testing.assert_allclose(float(m1["loss_total"]),
                               float(m2["loss_total"]), rtol=2e-2)
    for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p2)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=2e-2, atol=2e-3)


def test_loss_decreases_when_memorizing():
    """A few steps on ONE repeated batch must reduce the loss (end-to-end
    learning signal through flash attention, remat, chunked loss)."""
    cfg, params, batch = _setup("gemma2-2b", seq=32, batch=2)
    ocfg = opt_lib.OptimizerConfig(peak_lr=3e-3, warmup_steps=2,
                                   total_steps=40)
    step = jax.jit(train_loop.make_train_step(cfg, ocfg))
    opt = opt_lib.init_adamw(params)
    first = None
    for i in range(12):
        params, opt, metrics = step(params, opt, batch)
        if first is None:
            first = float(metrics["loss"])
    assert float(metrics["loss"]) < first * 0.9, \
        (first, float(metrics["loss"]))


def test_remat_policies_same_loss():
    cfg, params, batch = _setup()
    ocfg = opt_lib.OptimizerConfig(warmup_steps=0, total_steps=10)
    losses = []
    for policy in ("none", "full", "dots"):
        step = train_loop.make_train_step(cfg, ocfg, remat_policy=policy)
        opt = opt_lib.init_adamw(params)
        _, _, m = jax.jit(step)(params, opt, batch)
        losses.append(float(m["loss_total"]))
    np.testing.assert_allclose(losses[0], losses[1], rtol=1e-2)
    np.testing.assert_allclose(losses[0], losses[2], rtol=1e-2)


# -------------------------------------------------------- grad compression
def test_int8_error_feedback_tracks_true_sum():
    """Quantized grads + error feedback track the exact running sum."""
    rng = np.random.default_rng(0)
    g0 = jnp.asarray(rng.standard_normal((64, 64)), jnp.float32)
    ef = jnp.zeros_like(g0)
    total_true = np.zeros((64, 64), np.float32)
    total_comp = np.zeros((64, 64), np.float32)
    for i in range(20):
        gi = g0 * (1 + 0.1 * i)
        q, scale, ef = grad_compression.quantize(gi, ef)
        total_true += np.asarray(gi)
        total_comp += np.asarray(grad_compression.dequantize(q, scale))
    err = np.abs(total_true - total_comp).max() / np.abs(total_true).max()
    assert err < 0.05, err


def test_int8_quantize_payload_is_one_byte():
    g = jnp.asarray(np.random.default_rng(1).standard_normal((128, 128)),
                    jnp.float32)
    q, scale, ef = grad_compression.quantize(g, jnp.zeros_like(g))
    assert q.dtype == jnp.int8                 # 4x fewer collective bytes
    # error feedback bounded by one quantization step
    assert float(jnp.max(jnp.abs(ef))) <= float(scale) / 2 + 1e-6


def test_compressed_dp_step_single_device():
    """shard_map compressed-DP step runs and learns on a 1-device mesh."""
    from repro.launch.mesh import make_local_mesh
    mesh = make_local_mesh()
    cfg, params, batch = _setup(seq=16, batch=2)
    ocfg = opt_lib.OptimizerConfig(peak_lr=1e-3, warmup_steps=0,
                                   total_steps=10)
    loss_fn = train_loop.make_loss_fn(cfg, remat_policy="none")
    step = grad_compression.make_compressed_dp_train_step(mesh, loss_fn, ocfg)
    ef = grad_compression.init_error_feedback(mesh, params)
    opt = opt_lib.init_adamw(params)
    params2, opt2, ef2, metrics = jax.jit(step)(params, opt, batch, ef)
    assert np.isfinite(float(metrics["loss_total"]))
