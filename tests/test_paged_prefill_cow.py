"""Page-native KV end-to-end (ISSUE 4): paged prefill writes vs the PR 3
scatter path (bit-exact), copy-on-write prefix sharing (allocator refcounts,
prefix index, divergence at every page-boundary offset, preemption under
sharing), and page-granular int8 KV (kernel vs fp oracle, byte accounting)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core import dataflow
from repro.kernels import ops, ref
from repro.models import decoding, transformer as tfm
from repro.serve import kvcache
from repro.serve.paging import PageAllocator
from repro.serve.scheduler import ContinuousBatchingScheduler, StreamRequest


ARCH = "qwen2.5-3b-reduced"


@pytest.fixture(scope="module")
def cfg_params():
    cfg = get_config(ARCH)
    return cfg, tfm.init_params(jax.random.PRNGKey(0), cfg)


def _run(cfg, params, prompts, max_new=6, rows=2, cache_len=64, ps=8,
         **kw):
    sch = ContinuousBatchingScheduler(
        cfg, params, rows=rows, cache_len=cache_len, page_size=ps,
        eos_id=-1, sync_every=4, attn_path="paged", **kw)
    done = sch.run([StreamRequest(i, p, max_new)
                    for i, p in enumerate(prompts)])
    return [r.out for r in sorted(done, key=lambda r: r.rid)], sch


# ------------------------------------------------- paged prefill writes
def test_paged_prefill_bit_identical_to_scatter_path(cfg_params):
    """The page-native prefill output mode (PagedPrefill) produces pools
    bit-identical to the PR 3 path (dense prefill rows scattered into pages
    afterward) — and identical last logits."""
    cfg, params = cfg_params
    rows, cache_len, ps = 2, 32, 8
    MP = cache_len // ps
    prompts = [[5, 6, 7], [9, 8, 7, 6, 5, 4]]
    S = max(len(p) for p in prompts)
    toks = np.zeros((rows, S), np.int32)
    for i, p in enumerate(prompts):
        toks[i, :len(p)] = p
    lengths = jnp.asarray([len(p) for p in prompts], jnp.int32)
    pager = PageAllocator(rows * MP, ps)
    for i, p in enumerate(prompts):
        assert pager.ensure(i, len(p) + 2)
    bt = jnp.asarray(pager.block_table_rows([0, 1], MP))

    # PR 3 reference: dense row cache, then scatter into fresh pools
    lb_ref, cb = decoding.prefill_batched(params, jnp.asarray(toks), lengths,
                                          cfg, cache_len)
    ref_cache = decoding.init_paged_cache(cfg, rows, cache_len, rows * MP, ps)

    def scatter_part(part, stacked):
        out = {}
        for k, e in ref_cache[part].items():
            if decoding.is_paged_entry(e):
                f = (jax.vmap(lambda pool, rkv: decoding.scatter_rows_to_pages(
                    pool, rkv, bt, lengths)) if stacked else
                    lambda pool, rkv: decoding.scatter_rows_to_pages(
                        pool, rkv, bt, lengths))
                out[k] = {"pk": f(e["pk"], cb[part][k]["k"]),
                          "pv": f(e["pv"], cb[part][k]["v"])}
            else:
                out[k] = cb[part][k]
        return out

    expect = {p: scatter_part(p, p == "blocks") for p in ref_cache}

    # page-native path: prefill writes straight into the pools
    cache0 = decoding.init_paged_cache(cfg, rows, cache_len, rows * MP, ps)
    pp = decoding.PagedPrefill(cache=cache0, block_table_rows=bt,
                               slots=jnp.arange(rows, dtype=jnp.int32))
    lb_pg, got = decoding.prefill_batched(params, jnp.asarray(toks), lengths,
                                          cfg, cache_len, paged=pp)
    np.testing.assert_array_equal(np.asarray(lb_ref), np.asarray(lb_pg))
    for part in expect:
        for k, e in expect[part].items():
            if decoding.is_paged_entry(e):
                np.testing.assert_array_equal(np.asarray(e["pk"]),
                                              np.asarray(got[part][k]["pk"]))
                np.testing.assert_array_equal(np.asarray(e["pv"]),
                                              np.asarray(got[part][k]["pv"]))


def test_paged_prefill_write_start_skips_shared_prefix():
    """Tokens before write_start never land in pages (adopted pages are
    read-only); tokens at/after it are written normally."""
    pool = jnp.zeros((4, 4, 2, 8), jnp.float32)
    rows_kv = jnp.ones((1, 8, 2, 8), jnp.float32)
    bt = jnp.asarray([[0, 1]], jnp.int32)
    out = decoding.scatter_rows_to_pages(
        pool, rows_kv, bt, jnp.asarray([8], jnp.int32),
        start=jnp.asarray([4], jnp.int32))
    assert float(jnp.sum(out[0])) == 0.0          # shared page untouched
    assert float(jnp.sum(out[1])) == 4 * 2 * 8    # fresh page written


# ----------------------------------------------- allocator: refcounts/CoW
def test_allocator_adopt_register_refcounts():
    a = PageAllocator(8, page_size=4)
    prompt = list(range(10))                      # 2 full pages + 2 tokens
    assert a.ensure(0, 10)
    assert a.register_prefix(0, prompt) == 3      # 2 full + 1 partial key
    covered, pages = a.match_prefix(prompt)
    assert covered == 10 and pages == a.table(0)
    # full-page-only match for a diverging prompt
    covered, pages = a.match_prefix(prompt[:8] + [99, 98])
    assert covered == 8 and pages == a.table(0)[:2]
    assert a.adopt_prefix(1, prompt) == 10
    assert a.table(1) == a.table(0)
    assert all(a.refcount(p) == 2 for p in a.table(0))
    s = a.stats()
    assert s["shared_pages"] == 3
    assert s["pages_saved_sharing"] == 3
    assert s["refcount_histogram"] == {2: 3}
    # fragmentation stays a share in [0, 1] under sharing (logical capacity)
    a.set_length(0, 10)
    a.set_length(1, 10)
    s = a.stats()
    assert 0.0 <= s["fragmentation"] <= 1.0
    assert s["fragmentation"] == pytest.approx(1 - 20 / 24)


def test_allocator_shared_free_and_double_free_protection():
    a = PageAllocator(4, page_size=4)
    assert a.ensure(0, 8)
    a.register_prefix(0, list(range(8)))
    assert a.adopt_prefix(1, list(range(8))) == 8
    assert a.available() == 2                     # sharing allocated nothing
    assert a.free(0) == 0                         # still referenced by rid 1
    assert a.available() == 2
    with pytest.raises(ValueError):
        a.free(0)                                 # double free refused
    assert a.free(1) == 2                         # last ref returns pages
    assert a.available() == 4
    # index purged with the pages: nothing left to adopt
    assert a.adopt_prefix(2, list(range(8))) == 0


def test_allocator_cow_page_materializes_and_respects_pressure():
    a = PageAllocator(3, page_size=4)
    assert a.ensure(0, 8)
    a.register_prefix(0, list(range(8)))
    assert a.adopt_prefix(1, list(range(8))) == 8
    assert a.shared_pages_in(1, 4, 8) == [1]
    src, dst = a.cow_page(1, 1)
    assert src == a.table(0)[1] and dst not in a.table(0)
    assert a.refcount(src) == 1 and a.refcount(dst) == 1
    assert a.shared_pages_in(1, 4, 8) == []
    # second CoW attempt has no free page left -> None, nothing changed
    assert a.shared_pages_in(0, 0, 8) == [0] and a.shared_pages_in(
        1, 0, 4) == [0]
    before = a.table(1)
    assert a.cow_page(1, 0) is None
    assert a.table(1) == before


# --------------------------------------------- scheduler: CoW correctness
def _prefix(n, base=5):
    return [base + (i % 90) for i in range(n)]


def test_shared_prefix_outputs_bit_identical_and_pages_saved(cfg_params):
    """Acceptance: two requests sharing a k-page prefix consume k fewer
    pages than unshared admission, with identical decode outputs."""
    cfg, params = cfg_params
    prompts = [_prefix(16), _prefix(16)]          # k = 2 full shared pages
    outs, sch = _run(cfg, params, prompts)
    routs, ref_sch = _run(cfg, params, prompts, share_prefix=False)
    assert outs == routs
    assert sch.phase_stats["shared_tokens_admitted"] == 16
    k = dataflow.pages_for(16, 8)
    peak = sch.phase_stats["pages_peak"]["pages_used"]
    peak_ref = ref_sch.phase_stats["pages_peak"]["pages_used"]
    assert peak == peak_ref - k
    assert sch.phase_stats["pages_peak"]["pages_saved_sharing"] == k


@pytest.mark.parametrize("div", [7, 8, 9, 15, 16, 17])
def test_shared_prefix_divergence_at_every_page_offset(cfg_params, div):
    """Prompts diverging one-before / at / one-after each page boundary
    (page_size 8) decode identically to unshared admission."""
    cfg, params = cfg_params
    base = _prefix(20)
    p2 = base[:div] + [97 - (i % 7) for i in range(20 - div)]
    outs, sch = _run(cfg, params, [base, p2], max_new=5)
    routs, _ = _run(cfg, params, [base, p2], max_new=5, share_prefix=False)
    assert outs == routs
    shared = sch.phase_stats["shared_tokens_admitted"]
    assert shared == (div // 8) * 8               # full pages before the fork


def test_shared_whole_prompt_cow_on_first_append(cfg_params):
    """A whole-prompt adoption (partial tail page) must CoW before the first
    decode append — and still match the unshared run exactly."""
    cfg, params = cfg_params
    prompts = [_prefix(19), _prefix(19)]          # 2 full pages + 3-token tail
    outs, sch = _run(cfg, params, prompts)
    routs, _ = _run(cfg, params, prompts, share_prefix=False)
    assert outs == routs
    assert sch.phase_stats["shared_tokens_admitted"] == 19
    assert sch.phase_stats["cow_copies"] >= 1


def test_preemption_of_request_holding_shared_pages(cfg_params):
    """Recompute preemption composes with sharing: a tiny pool forces
    evictions while requests share prefix pages; final tokens still match
    the unpressured unshared reference."""
    cfg, params = cfg_params
    prompts = [_prefix(16), _prefix(16), _prefix(16) + [3, 3, 3]]
    routs, _ = _run(cfg, params, prompts, max_new=8, rows=3, cache_len=64,
                    ps=4, share_prefix=False)
    outs, sch = _run(cfg, params, prompts, max_new=8, rows=3, cache_len=64,
                     ps=4, num_pages=9)
    assert outs == routs
    assert sch.phase_stats["preemptions"] > 0
    st = sch.phase_stats["pages"]
    assert st["pages_free"] == st["pages_total"]  # everything returned
    assert st["shared_pages"] == 0                # no refs outlive the run


def test_streaming_and_arrival_sharing(cfg_params):
    """A later arrival adopts the prefix a live request registered earlier
    (cross-boundary sharing through the index)."""
    cfg, params = cfg_params
    sch = ContinuousBatchingScheduler(
        cfg, params, rows=2, cache_len=64, page_size=8, eos_id=-1,
        sync_every=4, attn_path="paged")
    reqs = [StreamRequest(0, _prefix(16), 10, arrival=0.0),
            StreamRequest(1, _prefix(16), 6, arrival=4.0)]
    done = sch.run(reqs)
    by_rid = {r.rid: r for r in done}
    assert by_rid[1].shared_tokens == 16
    ref = ContinuousBatchingScheduler(
        cfg, params, rows=2, cache_len=64, page_size=8, eos_id=-1,
        sync_every=4, attn_path="paged", share_prefix=False)
    dref = ref.run([StreamRequest(0, _prefix(16), 10, arrival=0.0),
                    StreamRequest(1, _prefix(16), 6, arrival=4.0)])
    assert {r.rid: r.out for r in done} == {r.rid: r.out for r in dref}


# ------------------------------------------------------- int8 KV pages
def _quant_case(lengths, ps, KV=2, R=2, D=16, seed=0):
    rng = np.random.default_rng(seed)
    B = len(lengths)
    MP = max(dataflow.pages_for(n, ps) for n in lengths)
    P = sum(dataflow.pages_for(n, ps) for n in lengths) + 1
    q = jnp.asarray(rng.standard_normal((B, KV, R, D)), jnp.float32)
    kp_f = jnp.asarray(rng.standard_normal((P, ps, KV, D)), jnp.float32)
    vp_f = jnp.asarray(rng.standard_normal((P, ps, KV, D)), jnp.float32)
    bt = np.full((B, MP), -1, np.int32)
    i = 0
    for b, n in enumerate(lengths):
        for j in range(dataflow.pages_for(n, ps)):
            bt[b, j] = i
            i += 1
    ks = jnp.max(jnp.abs(kp_f), axis=(1, 3))            # (P, KV) amax
    vs = jnp.max(jnp.abs(vp_f), axis=(1, 3))
    kq = decoding.quantize_to_i8(kp_f, ks[:, None, :, None])
    vq = decoding.quantize_to_i8(vp_f, vs[:, None, :, None])
    return (q, kq, vq, ks, vs, jnp.asarray(bt),
            jnp.asarray(np.asarray(lengths, np.int32)))


@pytest.mark.parametrize("softcap", [0.0, 30.0])
def test_int8_kernel_matches_quantized_oracle(softcap):
    """The kernel's in-loop per-page dequant is exact vs the gather-then-
    dequant oracle on the same int8 pools."""
    q, kq, vq, ks, vs, bt, lens = _quant_case([8, 9, 23], 8)
    B, KV, R, D = q.shape
    out = ops.paged_attention(q.reshape(B, 1, KV * R, D), kq, vq, bt, lens,
                              k_scale=ks, v_scale=vs, softcap=softcap)
    expect = ref.paged_attention_ref(q, kq, vq, bt, lens, softcap=softcap,
                                     k_scale=ks, v_scale=vs
                                     ).reshape(B, 1, KV * R, D)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect),
                               rtol=1e-5, atol=1e-5)


def test_int8_accuracy_vs_fp_oracle():
    """Acceptance: int8 pages stay close to the fp attention output — the
    accuracy-vs-fp oracle gate (amax-scaled 8-bit, ~1e-2 relative)."""
    rng = np.random.default_rng(3)
    lengths, ps = [8, 17], 8
    q, kq, vq, ks, vs, bt, lens = _quant_case(lengths, ps, seed=3)
    B, KV, R, D = q.shape
    # fp reference from the SAME underlying values (dequantized pools)
    kd = decoding.dequantize_i8(kq, ks[:, None, :, None])
    vd = decoding.dequantize_i8(vq, vs[:, None, :, None])
    fp = ref.paged_attention_ref(q, kd, vd, bt, lens)
    got = ops.paged_attention(q.reshape(B, 1, KV * R, D), kq, vq, bt, lens,
                              k_scale=ks, v_scale=vs
                              ).reshape(B, KV, R, D)
    np.testing.assert_allclose(np.asarray(got), np.asarray(fp),
                               rtol=1e-4, atol=1e-4)


def test_int8_scheduler_matches_fp_tokens(cfg_params):
    """End-to-end: the quantized page format produces the fp scheduler's
    greedy tokens at this scale (the accuracy oracle at token granularity),
    exercising quantized prefill scatter, requant append, and kernel dequant."""
    cfg, params = cfg_params
    prompts = [[5, 6, 7, 8, 9, 6, 5, 4], [9, 8, 7, 6, 5, 4]]
    fp_outs, fp_sch = _run(cfg, params, prompts, kv_quant="fp")
    i8_outs, i8_sch = _run(cfg, params, prompts, kv_quant="int8")
    assert i8_outs == fp_outs
    assert i8_sch.phase_stats["kv_quant"] == "int8"
    assert fp_sch.phase_stats["kv_quant"] == "fp"


def test_int8_sharing_composes(cfg_params):
    """CoW sharing over int8 pages (scales copied with the payload)."""
    cfg, params = cfg_params
    prompts = [_prefix(19), _prefix(19)]
    outs, sch = _run(cfg, params, prompts, kv_quant="int8")
    routs, _ = _run(cfg, params, prompts, kv_quant="int8",
                    share_prefix=False)
    assert outs == routs
    assert sch.phase_stats["shared_tokens_admitted"] == 19
    assert sch.phase_stats["cow_copies"] >= 1


def test_int8_byte_accounting(cfg_params):
    """int8 pools halve the KV payload; scale tables are accounted."""
    cfg, _ = cfg_params
    fp_b = kvcache.paged_cache_bytes(cfg, 4, 512, 32, 64, "fp")
    i8_b = kvcache.paged_cache_bytes(cfg, 4, 512, 32, 64, "int8")
    assert i8_b < fp_b
    # payload-only analytic model agrees with the eval_shape accounting
    n_glob = kvcache.num_global_layers(cfg)
    fp_pool = dataflow.paged_kv_bytes(32, 64, cfg.num_kv_heads, cfg.head_dim,
                                      n_glob, "fp")
    i8_pool = dataflow.paged_kv_bytes(32, 64, cfg.num_kv_heads, cfg.head_dim,
                                      n_glob, "int8")
    assert fp_b - i8_b == fp_pool - i8_pool
    assert kvcache.kv_page_bytes(cfg, 64, "int8") < kvcache.kv_page_bytes(
        cfg, 64, "fp")


def test_kv_quant_dispatch_rule():
    ps = dataflow.PAGE_SIZE
    assert dataflow.kv_quant_path(1, 16 * ps) == "fp"
    assert dataflow.kv_quant_path(dataflow.KV_QUANT_MIN_ROWS,
                                  16 * ps) == "int8"
    assert dataflow.kv_quant_path(128, ps) == "fp"    # too short to page
    assert dataflow.kv_dtype_bytes("int8") == 1
    assert dataflow.kv_dtype_bytes("fp") == 2


# --------------------------------------------------- report integration
def test_report_surfaces_sharing_and_quant(cfg_params):
    cfg, _ = cfg_params
    pager = PageAllocator(16, page_size=8)
    pager.ensure(0, 16)
    pager.register_prefix(0, list(range(16)))
    pager.adopt_prefix(1, list(range(16)))
    pager.set_length(0, 16)
    rep = kvcache.report(cfg, batch=4, cache_len=8192, chips=256,
                         pager=pager, kv_quant="int8")
    pg = rep["paged"]
    assert pg["shared_pages"] == 2
    assert pg["pages_saved_sharing"] == 2
    assert pg["kv_quant"] == "int8"
    assert pg["bytes_saved_sharing"] == 2 * kvcache.kv_page_bytes(
        cfg, 8, "int8")
    assert pg["refcount_histogram"] == {2: 2}
