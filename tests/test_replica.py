"""Multi-replica control plane chaos suite (ISSUE 7): deterministic
failover, exactly-once outcomes under replica loss, heartbeat stall
detection, pool-corruption quarantine, migration budgets, autoscaling and
feedback re-planning.

Greedy decoding (temperature=0, eos_id=-1) + the shared virtual clock make
every assertion bit-exact: a seeded kill at step k must leave surviving
requests' tokens identical to a fault-free run, and two same-seed chaos
runs must produce identical outcome sets.
"""
import jax
import pytest

from repro.configs import get_config
from repro.core import plan as plan_lib
from repro.models import transformer as tfm
from repro.serve import LLM
from repro.serve.chaos import ReplicaChaosConfig
from repro.serve.guard import GuardConfig
from repro.serve.replica import (AutoscaleConfig, ReplanConfig, ReplicaSet,
                                 SupervisorConfig)
from repro.serve.scheduler import StreamRequest

pytestmark = pytest.mark.chaos


@pytest.fixture(scope="module")
def model():
    cfg = get_config("qwen2.5-3b-reduced")
    params = tfm.init_params(jax.random.PRNGKey(0), cfg)
    return cfg, params


def _plan(cfg, rows=3, cache_len=64, page_size=4, num_pages=24):
    return plan_lib.plan_for_scheduler(cfg, rows=rows, cache_len=cache_len,
                                       page_size=page_size,
                                       num_pages=num_pages, sync_every=4)


def _reqs(n=8, max_new=6, spread=1.0, prefix=()):
    return [StreamRequest(rid=i, prompt=list(prefix) + [3 + i % 4, 5, 7],
                          max_new=max_new, arrival=float(i) * spread,
                          tenant="t%d" % (i % 2))
            for i in range(n)]


def _terminal_check(done, n):
    """Every submitted rid in exactly one terminal outcome, fleet-wide."""
    assert sorted(r.rid for r in done) == list(range(n))
    assert all(r.outcome is not None for r in done)


# ------------------------------------------------------------ determinism
def test_kill_survivors_bit_identical_and_exactly_once(model):
    cfg, params = model
    plan = _plan(cfg)
    base = LLM(cfg, params, plan, eos_id=-1, replicas=3).stream(_reqs())
    base_out = {r.rid: list(r.out) for r in base}
    assert all(r.outcome.status == "ok" for r in base)

    llm = LLM(cfg, params, plan, eos_id=-1, replicas=3)
    done = llm.stream(_reqs(), chaos=ReplicaChaosConfig(
        kill_at_step={0: 8.0}))
    _terminal_check(done, 8)
    st = llm.phase_stats
    assert st["failovers"] == 1
    # survivors (requests that never migrated) are bit-identical to the
    # fault-free run; migrated requests recompute to the same tokens under
    # greedy decode — token-stream continuity across the failover
    for r in done:
        if r.outcome.status == "ok":
            assert list(r.out) == base_out[r.rid], \
                f"rid {r.rid} diverged after failover"


def test_same_seed_chaos_runs_identical(model):
    cfg, params = model
    plan = _plan(cfg)
    runs = []
    for _ in range(2):
        llm = LLM(cfg, params, plan, eos_id=-1, replicas=3)
        done = llm.stream(_reqs(), chaos=ReplicaChaosConfig(
            kill_at_step={1: 4.0}))
        runs.append(sorted((r.rid, r.outcome.status, tuple(
            tuple(t) if isinstance(t, list) else t for t in r.out),
            r.replica, r.migrations) for r in done))
    assert runs[0] == runs[1]


def test_exactly_once_outcomes_under_kill_sweep(model):
    """Property sweep: kill-step x replica-count, every submitted rid ends
    in exactly one terminal RequestOutcome (the ReplicaSet itself raises on
    a double resolution, so completing the run IS the uniqueness proof)."""
    cfg, params = model
    plan = _plan(cfg)
    for n_rep, kill_step in [(2, 0.0), (2, 12.0), (3, 8.0)]:
        llm = LLM(cfg, params, plan, eos_id=-1, replicas=n_rep)
        done = llm.stream(_reqs(n=6), chaos=ReplicaChaosConfig(
            kill_at_step={0: kill_step}))
        _terminal_check(done, 6)
        assert llm.phase_stats["failovers"] == 1, (n_rep, kill_step)


# ------------------------------------------------------- detection paths
def test_permanent_stall_detected_by_heartbeat(model):
    cfg, params = model
    llm = LLM(cfg, params, _plan(cfg), eos_id=-1, replicas=2)
    done = llm.stream(_reqs(n=8, spread=6.0), chaos=ReplicaChaosConfig(
        stall_at_step={0: 12.0}))
    _terminal_check(done, 8)
    st = llm.phase_stats
    assert st["failovers"] == 1
    assert any(k.startswith("heartbeat stalled")
               for k in st["failover_reasons"])


def test_pool_corruption_quarantined_by_audit(model):
    cfg, params = model
    llm = LLM(cfg, params, _plan(cfg), eos_id=-1, replicas=2)
    done = llm.stream(_reqs(), chaos=ReplicaChaosConfig(
        corrupt_pool_at_step={1: 8.0}))
    _terminal_check(done, 8)
    st = llm.phase_stats
    assert st["failovers"] == 1
    assert any(k.startswith("pool audit failed")
               for k in st["failover_reasons"])


def test_migration_budget_exhaustion_resolves_failed(model):
    cfg, params = model
    rs = ReplicaSet(cfg, params, _plan(cfg), replicas=2, eos_id=-1,
                    migration_budget=0)
    done = rs.run(_reqs(n=6, max_new=8, spread=0.0),
                  chaos=ReplicaChaosConfig(kill_at_step={0: 4.0}))
    _terminal_check(done, 6)
    st = rs.phase_stats
    assert st["failed_migrations"] >= 1
    failed = [r for r in done if r.outcome.status == "failed"]
    assert failed and all("migration budget" in r.outcome.reason
                          for r in failed)
    # partial output survives on the failed requests (tokens kept)
    assert st["outcomes"]["failed"] == len(failed)
    assert st["outcomes"]["ok"] == 6 - len(failed)


def test_total_fleet_loss_respawns_and_finishes(model):
    cfg, params = model
    rs = ReplicaSet(cfg, params, _plan(cfg), replicas=2, eos_id=-1)
    done = rs.run(_reqs(n=4), chaos=ReplicaChaosConfig(
        kill_at_step={0: 4.0, 1: 4.0}))
    _terminal_check(done, 4)
    st = rs.phase_stats
    assert st["failovers"] == 2
    assert st["replicas_spawned"] == 3        # 2 initial + 1 replacement
    assert st["outcomes"]["ok"] == 4


# ------------------------------------------------- adaptation + affinity
def test_autoscale_up_and_down_with_hysteresis(model):
    cfg, params = model
    rs = ReplicaSet(cfg, params, _plan(cfg), replicas=1, eos_id=-1,
                    autoscale=AutoscaleConfig(
                        min_replicas=1, max_replicas=3, high_depth=2.0,
                        low_depth=0.5, patience_windows=2))
    # burst of 10 at t=0 overwhelms one replica's 3 rows, then drains
    done = rs.run(_reqs(n=10, max_new=8, spread=0.0))
    _terminal_check(done, 10)
    st = rs.phase_stats
    assert st["scale_ups"] >= 1
    assert st["scale_downs"] >= 1             # drained replicas retired
    assert st["replicas_final"] >= 1


def test_feedback_replan_shrinks_pool_at_drain(model):
    cfg, params = model
    # plan assumes mean occupancy cache_len/2 = 32; traffic actually
    # finishes at ~9 tokens -> drift >> threshold -> re-plan + hot-swap
    rs = ReplicaSet(cfg, params, _plan(cfg), replicas=1, eos_id=-1,
                    replan=ReplanConfig(min_samples=4, drift_threshold=0.3))
    base_pages = rs.plan.num_pages
    reqs = _reqs(n=10, max_new=6, spread=4.0)
    done = rs.run(reqs)
    _terminal_check(done, 10)
    st = rs.phase_stats
    assert st["replans"] >= 1
    assert rs.plan.num_pages < base_pages     # pool resized to measured mean
    assert rs.plan.cache_len == 64            # envelope pinned (feasibility)
    assert st["outcomes"]["ok"] == 10


def test_prefix_affinity_beats_round_robin_on_shared_traffic(model):
    """Two distinct system prompts with interleaved arrivals: affinity
    routing partitions each prompt group onto its home replica (maximal CoW
    page sharing), while depth-based placement interleaves the groups so
    co-resident requests hold mismatched prefixes and cannot share."""
    cfg, params = model
    plan = _plan(cfg)
    prefixes = [(11, 12, 13, 14, 11, 12, 13, 14),   # two full pages each
                (21, 22, 23, 24, 21, 22, 23, 24)]
    shared = {}
    from repro.serve.router import RouterConfig
    for affinity in (True, False):
        rs = ReplicaSet(cfg, params, plan, replicas=3, eos_id=-1,
                        router=RouterConfig(affinity=affinity))
        reqs = [StreamRequest(rid=i,
                              prompt=list(prefixes[i % 2]) + [3 + i % 4, 5, 7],
                              max_new=6, arrival=float(i),
                              tenant="t%d" % (i % 2))
                for i in range(12)]
        done = rs.run(reqs)
        _terminal_check(done, 12)
        shared[affinity] = \
            rs.phase_stats["fleet"]["shared_tokens_admitted"]
    assert shared[True] > shared[False]


# ------------------------------------------------------------- front door
def test_facade_replicas_validation_names_limit(model):
    cfg, params = model
    with pytest.raises(ValueError, match="replicas must be >= 1"):
        LLM(cfg, params, _plan(cfg), replicas=0)


def test_facade_constructor_callbacks_default_and_override(model):
    cfg, params = model
    plan = _plan(cfg)
    tokens, outcomes = [], []
    llm = LLM(cfg, params, plan, eos_id=-1,
              on_token=lambda r, t: tokens.append((r.rid, t)),
              on_outcome=lambda r, o: outcomes.append((r.rid, o.status)))
    done = llm.stream(_reqs(n=2, max_new=4))
    assert len(tokens) == 8 and len(outcomes) == 2
    assert all(s == "ok" for _, s in outcomes)
    # per-call override wins over the constructor default
    other = []
    llm.stream(_reqs(n=2, max_new=4),
               on_token=lambda r, t: other.append(t))
    assert len(other) == 8 and len(tokens) == 8
    assert all(r.outcome is not None for r in done)


def test_supervisor_detector_survives_plan_swap_step_restart(model):
    """The replan hot-swap restarts a replica's local step counter; the
    supervisor's per-slot StragglerDetector must absorb the non-monotonic
    step input (satellite: fault_tolerance.observe tolerance) without
    spurious failovers."""
    cfg, params = model
    rs = ReplicaSet(cfg, params, _plan(cfg), replicas=1, eos_id=-1,
                    supervisor=SupervisorConfig(heartbeat_patience=2),
                    replan=ReplanConfig(min_samples=4, drift_threshold=0.3))
    done = rs.run(_reqs(n=10, max_new=6, spread=4.0))
    _terminal_check(done, 10)
    st = rs.phase_stats
    assert st["replans"] >= 1
    assert st["failovers"] == 0               # swap never looked like death
