"""Optional-hypothesis shim (ISSUE 1 satellite).

``hypothesis`` is a dev-only dependency (requirements-dev.txt). When it is
missing, importing it at test-module top level used to abort *collection* of
the whole suite. This shim keeps every non-property test runnable: property
tests decorated with the stub ``given`` are individually skipped instead.

Usage in test modules:  ``from hypothesis_compat import given, settings, st``
"""
try:
    from hypothesis import given, settings, strategies as st  # noqa: F401
    HAS_HYPOTHESIS = True
except ModuleNotFoundError:
    import pytest

    HAS_HYPOTHESIS = False

    class _Strategy:
        """Accepts any strategies.* call and returns an inert placeholder."""

        def __getattr__(self, name):
            return lambda *a, **k: None

    st = _Strategy()

    def given(*_a, **_k):
        return lambda fn: pytest.mark.skip(
            reason="hypothesis not installed (see requirements-dev.txt)")(fn)

    def settings(*_a, **_k):
        return lambda fn: fn


def fuzz_seeds(n, base=0):
    """Deterministic seed list for randomized sweeps that must run with or
    without hypothesis (ISSUE 6: allocator fuzz) — a failing seed reproduces
    with plain pytest and no extra deps."""
    return [base + 7919 * i for i in range(n)]
