"""ServePlan (ISSUE 5): resolve-once dispatch vs the legacy per-call rules.

Covers the acceptance matrix — 3 seed configs × {dense, sparse params} ×
{fp, int8 KV} — asserting that plan-driven and legacy-kwarg engines choose
identical paths and produce bit-exact token outputs; plus plan.explain()
bound coverage (and agreement with mlp_bound_analysis), golden-plan
snapshot stability, to_json round-trip, the DeprecationWarning back-compat
contract, and the repro.serve.LLM facade.
"""
import json
import os
import warnings

import jax
import pytest

from repro.configs import get_config
from repro.core import dataflow
from repro.core import plan as plan_lib
from repro.models import transformer as tfm
from repro.serve import LLM, sparse as sps
from repro.serve.engine import DecodeEngine, Request, length_tier
from repro.serve.scheduler import ContinuousBatchingScheduler, StreamRequest

SEED_ARCHS = ("gemma2-2b-reduced", "mixtral-8x7b-reduced",
              "mamba2-130m-reduced")
GOLDEN = os.path.join(os.path.dirname(__file__), os.pardir, "scripts",
                      "golden_plans.json")

_PARAMS = {}


def _cfg_params(arch, packed: bool):
    """Init (and cache) params per arch; BCSC-pack the MLPs when asked."""
    key = (arch, packed)
    if key not in _PARAMS:
        cfg = get_config(arch)
        params = tfm.init_params(jax.random.PRNGKey(0), cfg)
        if packed:
            params, _ = sps.sparsify_mlp_params(params, cfg, sparsity=0.5)
        _PARAMS[key] = (cfg, params)
    return _PARAMS[key]


# --------------------------------------------- resolved thresholds == rules
@pytest.mark.parametrize("arch", SEED_ARCHS)
def test_plan_routes_match_dataflow_rules(arch):
    """The plan's resolved crossovers reproduce every core.dataflow rule at
    every M — the bit-exactness of plan-driven dispatch by construction."""
    cfg = get_config(arch)
    plan = plan_lib.plan_for_scheduler(cfg, rows=4, cache_len=64,
                                       page_size=8)
    d = cfg.d_model
    ff = cfg.dense_d_ff if (cfg.moe and cfg.dense_d_ff) else cfg.d_ff
    for M in (1, 2, 7, 8, 9, 16, 63, 64, 65, 128, 511, 512, 513, 4096):
        assert plan.matmul_route(M) == dataflow.matmul_path(M), M
        assert plan.bcsc_bm(M) == dataflow.bcsc_tile_m(M), M
        assert plan.mlp_route(M) == dataflow.mlp_path(
            M, ff, d, gated=cfg.mlp_gated), M
    for plen in (0, 1, 2, 3, 5, 8, 17, 33, 63, 64):
        assert plan.tier(plen) == length_tier(plen, plan.prefill_exact, 64), \
            plen


def test_active_plan_context_drives_route():
    """route_* helpers read the active plan inside the context and fall back
    to the dataflow rules outside it."""
    cfg = get_config("qwen2.5-3b-reduced")
    plan = plan_lib.plan_for_engine(cfg, slots=2, cache_len=32)
    assert plan_lib.active_plan() is None
    assert plan_lib.route_matmul(4) == dataflow.matmul_path(4)
    with plan_lib.activate(plan):
        assert plan_lib.active_plan() is plan
        assert plan_lib.route_matmul(4) == plan.matmul_route(4)
        assert plan_lib.tile_m(100) == plan.bcsc_bm(100)
    assert plan_lib.active_plan() is None


# --------------------------------------- the acceptance sweep (bit-exact)
@pytest.mark.parametrize("arch", SEED_ARCHS)
@pytest.mark.parametrize("packed", [False, True], ids=["dense", "sparse"])
@pytest.mark.parametrize("kv", ["fp", "int8"])
def test_plan_vs_legacy_dispatch_bitexact(arch, packed, kv):
    """3 seed configs × {dense, sparse} × {fp, int8 KV}: the legacy kwarg
    scheduler (auto-built shim plan) and the explicitly plan-driven one
    choose identical paths and emit bit-exact tokens."""
    cfg, params = _cfg_params(arch, packed)
    rows, cache_len, ps = 2, 32, 8
    kw = dict(rows=rows, cache_len=cache_len, page_size=ps, kv_quant=kv,
              sync_every=4)
    with pytest.warns(DeprecationWarning):
        legacy = ContinuousBatchingScheduler(cfg, params, eos_id=-1, **kw)
    plan = plan_lib.plan_for_scheduler(cfg, **kw)
    planned = ContinuousBatchingScheduler(cfg, params, plan, eos_id=-1)

    # identical path choices, decision for decision
    assert legacy.plan.attn_path == planned.plan.attn_path
    assert legacy.paged == planned.paged
    assert legacy.page_size == planned.page_size
    assert legacy.num_pages == planned.num_pages
    assert legacy.kv_quant == planned.kv_quant
    assert legacy.share_prefix == planned.share_prefix
    assert legacy.plan.as_dict() == planned.plan.as_dict()

    def reqs():
        return [StreamRequest(i, [5 + i, 6, 7], 3) for i in range(3)]

    out_legacy = [r.out for r in
                  sorted(legacy.run(reqs()), key=lambda r: r.rid)]
    out_plan = [r.out for r in
                sorted(planned.run(reqs()), key=lambda r: r.rid)]
    assert out_legacy == out_plan            # bit-exact token streams


def test_engine_legacy_kwargs_warn_and_match_plan_path():
    """Back-compat: DecodeEngine built from the old kwargs warns and decodes
    the exact same tokens as the plan-driven construction."""
    cfg, params = _cfg_params("gemma2-2b-reduced", False)
    with pytest.warns(DeprecationWarning):
        legacy = DecodeEngine(cfg, params, slots=2, cache_len=32, eos_id=-1,
                              sync_every=4)
    plan = plan_lib.plan_for_engine(cfg, slots=2, cache_len=32, sync_every=4)
    planned = DecodeEngine(cfg, params, plan, eos_id=-1)
    assert legacy.plan.as_dict() == planned.plan.as_dict()

    def reqs():
        return [Request(0, [5, 6, 7], 4), Request(1, [9, 8], 4)]

    out_legacy = [r.out for r in
                  sorted(legacy.run(reqs()), key=lambda r: r.rid)]
    out_plan = [r.out for r in
                sorted(planned.run(reqs()), key=lambda r: r.rid)]
    assert out_legacy == out_plan


def test_plan_plus_legacy_kwargs_rejected():
    """A plan and legacy geometry kwargs together would silently drop the
    kwargs — both engines refuse the mix (sync_every stays an override)."""
    cfg, params = _cfg_params("gemma2-2b-reduced", False)
    eplan = plan_lib.plan_for_engine(cfg, slots=1, cache_len=32)
    with pytest.raises(TypeError, match="not both"):
        DecodeEngine(cfg, params, eplan, slots=2, cache_len=32)
    splan = plan_lib.plan_for_scheduler(cfg, rows=1, cache_len=32)
    with pytest.raises(TypeError, match="not both"):
        ContinuousBatchingScheduler(cfg, params, splan, page_size=16)
    # sync_every alone composes with a plan
    eng = DecodeEngine(cfg, params, eplan, sync_every=2, eos_id=-1)
    assert eng.sync_every == 2


def test_pinned_override_rationale_is_truthful():
    """A caller-pinned decision that contradicts the rule is explained as a
    pin (with the rule's verdict), never with the rule's rationale."""
    cfg = get_config("gemma2-2b-reduced")
    # cache shorter than two pages: the occupancy rule says contiguous
    plan = plan_lib.plan_for_scheduler(cfg, rows=2, cache_len=24,
                                       page_size=16, attn_path="paged")
    att = next(d for d in plan.decisions if d.name == "attention")
    assert att.numbers["rule_choice"] == "contiguous"
    assert "pinned" in att.why and "contiguous" in att.why
    # int8 pinned below the cache-bound row count
    plan = plan_lib.plan_for_scheduler(cfg, rows=2, cache_len=64,
                                       page_size=8, kv_quant="int8")
    kv = next(d for d in plan.decisions if d.name == "kv_quant")
    assert kv.choice == "int8" and kv.numbers["rule_choice"] == "fp"
    assert "pinned" in kv.why


def test_plan_construction_emits_no_warning():
    """The deprecation fires only on the legacy kwarg spelling."""
    cfg, params = _cfg_params("gemma2-2b-reduced", False)
    plan = plan_lib.plan_for_engine(cfg, slots=1, cache_len=32)
    with warnings.catch_warnings():
        warnings.simplefilter("error", DeprecationWarning)
        DecodeEngine(cfg, params, plan, eos_id=-1)
        ContinuousBatchingScheduler(
            cfg, params,
            plan_lib.plan_for_scheduler(cfg, rows=1, cache_len=32),
            eos_id=-1)


# ------------------------------------------------------- explain() coverage
def test_explain_names_every_bound():
    """Every decision in the report names its bound (compute/HBM/occupancy)
    and every resolved plan carries the full decision set."""
    for arch in plan_lib.SNAPSHOT_CONFIGS:
        plan = plan_lib.snapshot_plan(arch)
        names = [d.name for d in plan.decisions]
        assert names == ["capacity", "matmul", "mlp", "attention",
                         "kv_quant", "spec", "degrade", "prefill"], names
        report = plan.explain()
        for d in plan.decisions:
            assert d.bound in plan_lib.BOUNDS
            assert f"[bound: {d.bound}]" in report
            assert d.name in report
        # the three-term coverage: each single-device bound kind appears at
        # least once (the fourth bound, collective, only exists on
        # mesh-sharded plans — tests/test_shard_serve.py covers it)
        for bound in ("compute", "HBM", "occupancy"):
            assert f"[bound: {bound}]" in report


def test_explain_mlp_entry_agrees_with_mlp_bound_analysis():
    """The MLP decision's roofline is the same numbers as
    benchmarks/sparse_decode.py::mlp_bound_analysis (which delegates to
    core.plan.mlp_roofline) — not a diverging copy."""
    sd = pytest.importorskip("benchmarks.sparse_decode")
    arch = "gemma2-2b"
    sp = plan_lib.SNAPSHOT_SPARSITY
    plan = plan_lib.snapshot_plan(arch)
    mlp = next(d for d in plan.decisions if d.name == "mlp")
    ref = sd.mlp_bound_analysis(arch=arch, sparsity=sp["sparsity"],
                                packing_efficiency=sp["packing_efficiency"])
    assert mlp.numbers["per_layer_time_s"] == ref["per_layer_time_s"]
    assert mlp.numbers["per_layer_bytes"] == ref["per_layer_bytes"]
    assert mlp.numbers["speedup"] == ref["speedup"]
    # and the rendered report shows the roofline times
    assert "per-layer roofline" in plan.explain()


# ----------------------------------------------------- snapshot + serialize
def test_golden_plan_snapshot_stable():
    """plan.to_json() of the canonical seed plans matches the checked-in
    golden file — the same gate perf_guard enforces in CI
    (plan-snapshot-stable). Regenerate scripts/golden_plans.json on
    deliberate dispatch changes."""
    golden = json.load(open(GOLDEN))
    # "__"-prefixed keys hold auxiliary snapshot families (e.g. __sharded__,
    # the mesh-sharded plans gated by sharded-plan-snapshot-stable)
    assert sorted(k for k in golden if not k.startswith("__")) \
        == sorted(plan_lib.SNAPSHOT_CONFIGS)
    for arch in plan_lib.SNAPSHOT_CONFIGS:
        got = json.loads(plan_lib.snapshot_plan(arch).to_json())
        assert got == golden[arch], f"plan drift for {arch}"


def test_to_json_round_trip_and_schema():
    plan = plan_lib.snapshot_plan("gemma2-2b")
    d = json.loads(plan.to_json())
    for key in ("rows", "cache_len", "gemv_m_max", "mlp_fused_m_max",
                "bcsc_chunk", "attn_path", "page_size", "num_pages",
                "kv_quant", "prefill_tiers", "decisions"):
        assert key in d, key
    assert d["bcsc_chunk"] == dataflow.BCSC_CHUNK
    assert d["page_size"] == dataflow.PAGE_SIZE
    assert all(dec["bound"] in plan_lib.BOUNDS for dec in d["decisions"])


def test_plan_serve_budget_clamps_rows():
    cfg = get_config("gemma2-2b")
    dist = {"mean": 512, "max": 1024}
    big = plan_lib.plan_serve(cfg, hbm_budget_bytes=1 << 40,
                              expected_batch=16, expected_len_dist=dist)
    assert big.rows == 16
    from repro.serve import kvcache
    slot = kvcache.cache_bytes(cfg, 1, 1024)
    clamped = plan_lib.plan_serve(cfg, hbm_budget_bytes=3 * slot,
                                  expected_batch=16, expected_len_dist=dist)
    assert clamped.rows == 3
    with pytest.raises(ValueError, match="cannot hold one"):
        plan_lib.plan_serve(cfg, hbm_budget_bytes=slot // 2,
                            expected_batch=1, expected_len_dist=dist)


# ----------------------------------------------------------------- facade
def test_llm_facade_generate_and_stream_share_one_plan():
    cfg, params = _cfg_params("gemma2-2b-reduced", False)
    plan = plan_lib.plan_for_scheduler(cfg, rows=2, cache_len=32,
                                       page_size=8, sync_every=4)
    llm = LLM(cfg, params, plan, eos_id=-1)

    done = llm.generate([([5, 6, 7], 3), ([9, 8], 3)])
    assert [r.rid for r in done] == [0, 1]
    assert all(len(r.out) == 3 for r in done)

    seen = []
    sdone = llm.stream([([5, 6, 7], 3), ([9, 8], 3)],
                       on_token=lambda r, t: seen.append((r.rid, t)))
    assert [r.rid for r in sdone] == [0, 1]
    assert all(len(r.out) == 3 for r in sdone)
    # streaming callbacks delivered every generated token, in order per rid
    for rid in (0, 1):
        assert [t for i, t in seen if i == rid] == sdone[rid].out
    # both entry points ran off the same resolved plan
    assert llm._engine.plan is plan and llm._scheduler.plan is plan
    # drain (dense slots) and continuous batching agree token-for-token here
    assert [r.out for r in done] == [r.out for r in sdone]


def test_llm_facade_explain_passthrough_and_default_plan():
    cfg, params = _cfg_params("gemma2-2b-reduced", False)
    llm = LLM(cfg, params, eos_id=-1)       # default plan resolution
    assert llm.plan.rows >= 1
    assert "[bound:" in llm.explain()


def test_cli_arch_name_resolution():
    assert plan_lib._resolve_arch_name("gemma2-2b") == "gemma2-2b"
    assert plan_lib._resolve_arch_name("gemma2_2b") == "gemma2-2b"
    assert plan_lib._resolve_arch_name("mixtral_8x7b") == "mixtral-8x7b"
    assert plan_lib._resolve_arch_name("qwen2_5_3b") == "qwen2.5-3b"
    assert plan_lib._resolve_arch_name("mamba2_130m-reduced") == \
        "mamba2-130m-reduced"
    with pytest.raises(KeyError):
        plan_lib._resolve_arch_name("nope")
