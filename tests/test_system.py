"""End-to-end system tests: supervised training run with checkpoint/restart,
then serving from the trained weights; dry-run cell construction."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import SHAPES, all_cells, cell_is_runnable, get_config
from repro.data import pipeline as data_lib
from repro.models import transformer as tfm
from repro.runtime.fault_tolerance import FaultToleranceConfig, Supervisor
from repro.serve.engine import DecodeEngine, Request
from repro.train import loop as train_loop, optimizer as opt_lib


def test_40_cells_accounted():
    cells = list(all_cells())
    assert len(cells) == 40
    skips = [c for c in cells if not c[2]]
    assert len(skips) == 4                       # pure full-attention @ 500k
    assert {c[0] for c in skips} == {"mistral-nemo-12b", "qwen2.5-3b",
                                     "internvl2-26b", "musicgen-large"}


def test_train_checkpoint_restart_serve(tmp_path):
    """The full lifecycle on one tiny model: train under the supervisor with
    an injected failure, restart from checkpoint, then serve greedily."""
    cfg = get_config("qwen2.5-3b-reduced")
    ocfg = opt_lib.OptimizerConfig(peak_lr=1e-3, warmup_steps=2,
                                   total_steps=10)
    step_jit = jax.jit(train_loop.make_train_step(cfg, ocfg))
    dcfg = data_lib.DataConfig(seq_len=32, global_batch=2,
                               vocab_size=cfg.vocab_size)

    def data_fn(step):
        return {k: jnp.asarray(v)
                for k, v in data_lib.synth_batch(dcfg, step).items()}

    def step_fn(state, batch):
        p, o = state
        p, o, m = step_jit(p, o, batch)
        return (p, o), m

    def init_fn():
        return train_loop.init_train_state(jax.random.PRNGKey(0), cfg)

    fired = {"done": False}

    def injector(step, attempt):
        if step == 4 and not fired["done"]:
            fired["done"] = True
            raise RuntimeError("injected")

    sup = Supervisor(FaultToleranceConfig(checkpoint_dir=str(tmp_path),
                                          checkpoint_every=3, backoff_s=0.0),
                     step_fn, data_fn, init_fn, failure_injector=injector)
    result = sup.run(8)
    assert result["restarts"] == 1
    assert result["final_step"] == 7
    losses = [m["loss"] for m in result["metrics"]]
    assert all(np.isfinite(l) for l in losses)

    # restore the final state and serve from it
    (params, _), _ = sup.ckpt.restore(init_fn())
    eng = DecodeEngine(cfg, params, slots=2, cache_len=48, eos_id=-1)
    done = eng.run([Request(0, [1, 2, 3], 4), Request(1, [4, 5], 4)])
    assert all(len(r.out) == 4 for r in done)


def test_cell_input_specs_every_kind():
    """input_specs covers every (arch-kind x shape-kind) stand-in shape."""
    from repro.launch.cell import input_specs
    cfg = get_config("gemma2-2b")
    spec = input_specs(cfg, SHAPES["train_4k"])
    assert spec["tokens"].shape == (256, 4096)
    assert spec["labels"].shape == (256, 4096)
    spec_d = input_specs(cfg, SHAPES["decode_32k"])
    assert spec_d["tokens"].shape == (128, 1)
    spec_m = input_specs(get_config("musicgen-large"), SHAPES["train_4k"])
    assert spec_m["tokens"].shape == (256, 4, 4096)
    assert spec_m["cond"].shape[0] == 256
    spec_v = input_specs(get_config("internvl2-26b"), SHAPES["prefill_32k"])
    assert spec_v["tokens"].shape[1] + spec_v["patch_embeds"].shape[1] == 32768


def test_elastic_reshard_roundtrip(tmp_path):
    """Save on one 'mesh', restore onto another (logical shapes preserved)."""
    from repro.checkpoint.manager import CheckpointManager
    cfg = get_config("gemma2-2b-reduced")
    params = tfm.init_params(jax.random.PRNGKey(0), cfg)
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(1, params)
    restored, _ = mgr.restore(jax.tree.map(
        lambda l: jax.ShapeDtypeStruct(l.shape, l.dtype), params))
    for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(restored)):
        assert a.shape == b.shape
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
