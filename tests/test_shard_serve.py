"""Mesh-sharded serving (ISSUE 10): mesh parsing + plan-time validation,
the plan's mesh/NoC-mode/pool Decisions, the ShardedPagePool lockstep
invariant, per-device pool byte accounting, acceptance-adaptive spec_k,
golden sharded-plan snapshots, and the tentpole acceptance — sharded
``LLM.stream()`` bit-identical to single-device per emitted token (tp=2
attention sharding and ep=4 expert sharding; re-asserted on a forced
8-device host mesh in a subprocess, the CI mesh8 configuration)."""
import json
import os
import subprocess
import sys

import jax
import pytest

from repro.configs import get_config
from repro.core import hmmesh, plan as plan_lib
from repro.serve import shard
from repro.serve.facade import LLM
from repro.serve.paging import PageAllocator

GOLDEN = os.path.join(os.path.dirname(__file__), os.pardir, "scripts",
                      "golden_plans.json")

PLAN_KW = dict(hbm_budget_bytes=1 << 30, expected_batch=3,
               expected_len_dist={"mean": 10, "max": 64}, page_size=4,
               sync_every=4)


def _params(cfg, seed=0):
    from repro.models import transformer as tfm
    return tfm.init_params(jax.random.PRNGKey(seed), cfg)


# ---------------------------------------------------------- mesh parsing
def test_parse_mesh_forms():
    assert plan_lib.parse_mesh(None) == (1, 1)
    assert plan_lib.parse_mesh("") == (1, 1)
    assert plan_lib.parse_mesh({}) == (1, 1)
    assert plan_lib.parse_mesh("tp=2,ep=4") == (2, 4)
    assert plan_lib.parse_mesh("ep=4,tp=2") == (2, 4)
    assert plan_lib.parse_mesh("tp=2") == (2, 1)
    assert plan_lib.parse_mesh({"ep": 4}) == (1, 4)
    assert plan_lib.parse_mesh((2, 4)) == (2, 4)
    with pytest.raises(ValueError, match="mesh"):
        plan_lib.parse_mesh("tp=2,dp=4")
    with pytest.raises(ValueError, match="mesh"):
        plan_lib.parse_mesh("tp2")
    with pytest.raises(ValueError, match=">= 1"):
        plan_lib.parse_mesh("tp=0")


def test_mesh_validation_raises_at_plan_time():
    cfg = get_config("gemma2-2b-reduced")       # 2 KV heads, no MoE
    with pytest.raises(ValueError, match="num_kv_heads"):
        plan_lib.plan_serve(cfg, mesh="tp=3", **PLAN_KW)
    with pytest.raises(ValueError, match="no\nexperts|no experts"):
        plan_lib.plan_serve(cfg, mesh="ep=2", **PLAN_KW)
    moe = get_config("mixtral-8x7b-reduced")    # 4 experts
    with pytest.raises(ValueError, match="num_experts"):
        plan_lib.plan_serve(moe, mesh="ep=3", **PLAN_KW)
    rec = get_config("mamba2-130m-reduced")     # recurrent: no head axis
    with pytest.raises(ValueError, match="recurrent"):
        plan_lib.plan_serve(rec, mesh="tp=2", **PLAN_KW)
    with pytest.raises(ValueError, match="drain engine"):
        plan_lib._resolve(
            cfg, cfg.name, 2, 64, mean_len=10, page_size=4, num_pages=None,
            attn_path="paged", share_prefix=None, kv_quant=None,
            sync_every=4, sparsity_stats=None, drain_only=True,
            mesh="tp=2")


# --------------------------------------------------- plan mesh decisions
def test_plan_explain_renders_mesh_and_noc_modes():
    cfg = get_config("mixtral-8x7b-reduced")
    plan = plan_lib.plan_serve(cfg, mesh="tp=2,ep=2", **PLAN_KW)
    assert (plan.tp, plan.ep) == (2, 2)
    assert plan.sharded and plan.mesh_devices == 4
    names = [d.name for d in plan.decisions]
    # the single-device decision list is a strict prefix: mesh-less plans
    # keep the pinned 8-name list (test_plan.py), sharded plans append
    assert names[:8] == ["capacity", "matmul", "mlp", "attention",
                        "kv_quant", "spec", "degrade", "prefill"]
    assert "mesh" in names and "noc_weights" in names
    assert "noc_kv" in names and "noc_acts" in names
    assert "noc_experts" in names           # ep>1 on an MoE arch
    rep = plan.explain()
    assert "mesh=tp2xep2" in rep
    assert "[bound: collective]" in rep     # the fourth roofline bound
    assert str(hmmesh.Mode.BROADCAST.value) in rep \
        or "BROADCAST" in rep               # weights stay replicated
    mesh_d = {d.name: d for d in plan.decisions}["mesh"]
    assert mesh_d.numbers["devices"] == 4
    assert mesh_d.numbers["allgather_bytes_per_token"] > 0


def test_unsharded_plan_has_no_mesh_decisions():
    cfg = get_config("gemma2-2b-reduced")
    plan = plan_lib.plan_serve(cfg, **PLAN_KW)
    assert not plan.sharded and plan.tp == plan.ep == 1
    assert [d.name for d in plan.decisions] == \
        ["capacity", "matmul", "mlp", "attention", "kv_quant", "spec",
         "degrade", "prefill"]
    assert "mesh" not in plan.explain()


def test_replan_never_re_meshes():
    cfg = get_config("gemma2-2b-reduced")
    base = plan_lib.plan_serve(cfg, mesh="tp=2", **PLAN_KW)
    swapped = plan_lib.replan_from_lengths(cfg, base, [20, 30, 40, 50] * 8)
    assert (swapped.tp, swapped.ep) == (base.tp, base.ep) == (2, 1)


# -------------------------------------------------------- partition specs
def test_partition_specs_subsume_launch_planner():
    from jax.sharding import PartitionSpec as P
    from repro.launch import cell
    cfg = get_config("mixtral-8x7b-reduced")
    plan = plan_lib.plan_serve(cfg, mesh="tp=2,ep=2", **PLAN_KW)
    specs = shard.partition_specs(plan)
    assert specs["weights"]["mode"] is hmmesh.Mode.BROADCAST
    assert specs["kv_pages"]["mode"] is hmmesh.Mode.GROUPED_MC
    assert specs["kv_pages"]["spec"] == P(None, None, "tp", None)
    assert specs["experts"]["mode"] is hmmesh.Mode.INTERLEAVED_MC
    assert specs["experts"]["spec"] == P("ep", None, None)
    # the launch path reads the same placement off the frozen plan
    assert cell.serve_partition_specs(plan) == specs


def test_serve_mesh_backing():
    mesh = shard.ServeMesh(tp=2, ep=4)
    assert mesh.devices == 8 and not mesh.trivial
    assert shard.ServeMesh().trivial
    if jax.device_count() < 8:
        assert not mesh.backed
        with pytest.raises(RuntimeError, match="device_count"):
            mesh.device_mesh()
        assert "logical" in mesh.describe()


# ------------------------------------------------------ sharded page pool
def test_sharded_pool_lockstep_and_divergence():
    pool = shard.ShardedPagePool(8, 4, shards=2)
    assert pool.num_pages == 8 and pool.page_size == 4
    assert pool.ensure(0, 10)               # lockstep mutation on all shards
    assert pool.pages_of(0) == 3
    assert all(s.pages_of(0) == 3 for s in pool.shards)
    pool.set_length(0, 10)
    assert pool.lockstep_divergence() == 0
    assert pool.stats()["shards"] == 2
    # out-of-band mutation of one shard IS divergence — the audit sees it
    pool.shards[1].ensure(99, 4)
    assert pool.lockstep_divergence() == 1
    # and the next lockstep call whose outcome differs across shards trips
    # the assertion: shard1 has one page fewer free, so a 5-page ensure
    # succeeds on shard0 but fails all-or-nothing on shard1
    with pytest.raises(AssertionError, match="lockstep"):
        pool.ensure(100, 20)


def test_sharded_pool_observe_publishes_shard_gauges():
    from repro.serve import telemetry
    pool = shard.ShardedPagePool(8, 4, shards=2)
    pool.ensure(0, 8)
    m = telemetry.MetricsRegistry()
    pool.observe(m)
    assert m.gauges["shard_pages_used_max"] == 2
    assert m.gauges["shard_pages_used_min"] == 2
    assert m.gauges["shard_lockstep_divergence"] == 0
    assert m.gauges["pages_used"] == 2      # canonical gauges still flow


def test_make_pool_dispatch():
    cfg = get_config("gemma2-2b-reduced")
    sharded = plan_lib.plan_serve(cfg, mesh="tp=2", **PLAN_KW)
    single = plan_lib.plan_serve(cfg, **PLAN_KW)
    assert isinstance(shard.make_pool(sharded), shard.ShardedPagePool)
    assert isinstance(shard.make_pool(single), PageAllocator)


def test_per_device_kv_bytes_exact_fraction():
    from repro.serve import kvcache
    cfg = get_config("gemma2-2b-reduced")
    plan = plan_lib.plan_serve(cfg, mesh="tp=2", **PLAN_KW)
    assert plan.paged
    total = kvcache.kv_page_bytes(cfg, plan.page_size, plan.kv_quant) \
        * plan.num_pages
    assert shard.per_device_kv_bytes(cfg, plan) * 2 == total  # exact 1/tp
    pool_d = {d.name: d for d in plan.decisions}["pool_shard"]
    assert pool_d.numbers["pool_bytes_per_device"] > 0


def test_chunk_collectives_counts():
    cfg = get_config("mixtral-8x7b-reduced")
    plan = plan_lib.plan_serve(cfg, mesh="tp=2,ep=2", **PLAN_KW)
    cc = shard.chunk_collectives(plan, steps=4, tokens=6)
    assert cc["collective_ops"] > 0
    assert cc["collective_allgather_bytes"] == 6 * {
        d.name: d for d in plan.decisions
    }["mesh"].numbers["allgather_bytes_per_token"]
    single = plan_lib.plan_serve(cfg, **PLAN_KW)
    assert shard.chunk_collectives(single, steps=4, tokens=6) == {}


# ------------------------------------------- acceptance-adaptive spec_k
SPEC_ARCH = "qwen2.5-3b-reduced"            # all-global: spec-eligible
SPEC_KW = dict(hbm_budget_bytes=1 << 30, expected_batch=2,
               expected_len_dist={"mean": 24, "max": 64}, page_size=8,
               attn_path="paged")


def test_replan_spec_k_steps_down_on_low_acceptance():
    cfg = get_config(SPEC_ARCH)
    base = plan_lib.plan_serve(cfg, **SPEC_KW, spec_k=4)
    assert base.spec_k == 4
    low = plan_lib.replan_spec_k(cfg, base, drafted_tokens=400,
                                 accepted_tokens=40)
    assert low.spec_k < base.spec_k         # drafts miss: k steps down
    d = {d.name: d for d in low.decisions}["spec"]
    assert "measured" in d.why
    assert d.numbers["alpha_measured"] < 0.5


def test_replan_spec_k_steps_up_and_guards():
    cfg = get_config(SPEC_ARCH)
    base = plan_lib.plan_serve(cfg, **SPEC_KW, spec_k=4)
    high = plan_lib.replan_spec_k(cfg, base, drafted_tokens=400,
                                  accepted_tokens=340)
    assert high.spec_k >= base.spec_k       # drafts hit: k grows (or holds)
    # too few samples: unchanged object, no decision churn
    assert plan_lib.replan_spec_k(cfg, base, drafted_tokens=10,
                                  accepted_tokens=2) is base
    # speculation off: nothing to adapt
    off = plan_lib.plan_serve(cfg, **SPEC_KW)
    if off.spec_k == 0:
        assert plan_lib.replan_spec_k(cfg, off, drafted_tokens=400,
                                      accepted_tokens=40) is off


# -------------------------------------------------- golden sharded plans
def test_golden_sharded_plan_snapshot_stable():
    """snapshot_sharded_plan for both ISSUE-10 configs × both mesh shapes
    matches scripts/golden_plans.json["__sharded__"] — the same gate
    perf_guard enforces in CI (sharded-plan-snapshot-stable)."""
    golden = json.load(open(GOLDEN))["__sharded__"]
    assert sorted(golden) == sorted(plan_lib.SHARDED_SNAPSHOT_CONFIGS)
    for arch in plan_lib.SHARDED_SNAPSHOT_CONFIGS:
        assert sorted(golden[arch]) \
            == sorted(plan_lib.SHARDED_SNAPSHOT_MESHES)
        for mesh in plan_lib.SHARDED_SNAPSHOT_MESHES:
            got = json.loads(
                plan_lib.snapshot_sharded_plan(arch, mesh).to_json())
            assert got == golden[arch][mesh], \
                f"sharded plan drift for {arch} @ {mesh}"


# --------------------------------------------- tentpole: bit-identity e2e
def _stream_outputs(cfg, params, plan, reqs, seed=42):
    llm = LLM(cfg, params, plan)
    done = llm.stream(reqs, rng=jax.random.PRNGKey(seed))
    return [r.out for r in done], llm


def test_stream_tp2_bit_identical_to_single_device():
    cfg = get_config("gemma2-2b-reduced")
    params = _params(cfg)
    reqs = [([5, 7, 11], 12), ([3, 2, 9, 4], 10)]
    p1 = plan_lib.plan_serve(cfg, **PLAN_KW)
    p2 = plan_lib.plan_serve(cfg, mesh="tp=2", **PLAN_KW)
    assert p1.paged and p2.paged
    o1, _ = _stream_outputs(cfg, params, p1, reqs)
    o2, llm2 = _stream_outputs(cfg, params, p2, reqs)
    assert o1 == o2                         # per-token bit-identity
    rep = llm2.sharding_report()
    assert rep["tp"] == 2 and rep["shards"] == 2
    assert rep["lockstep_divergence"] == 0
    assert rep["kv_bytes_per_device"] * 2 == rep["kv_bytes_single_device"]
    snap = llm2.telemetry().metrics.snapshot()
    assert snap.counters["collective_allgather_bytes"] > 0
    assert snap.gauges["shard_lockstep_divergence"] == 0
    cats = {e.cat for e in llm2.telemetry().tracer.events}
    assert "collective" in cats


def test_stream_ep4_bit_identical_to_single_device():
    cfg = get_config("mixtral-8x7b-reduced")
    params = _params(cfg, seed=1)
    reqs = [([5, 7, 11], 10), ([3, 2, 9, 4], 8)]
    p1 = plan_lib.plan_serve(cfg, **PLAN_KW)
    p2 = plan_lib.plan_serve(cfg, mesh="ep=4", **PLAN_KW)
    o1, _ = _stream_outputs(cfg, params, p1, reqs, seed=7)
    o2, llm2 = _stream_outputs(cfg, params, p2, reqs, seed=7)
    assert o1 == o2
    snap = llm2.telemetry().metrics.snapshot()
    assert snap.counters["collective_ops"] > 0      # expert gathers counted


# ------------------------------------- forced 8-device host mesh (mesh8)
_MESH8 = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax
from repro.configs import get_config
from repro.core import plan as plan_lib
from repro.models import transformer as tfm
from repro.serve import shard
from repro.serve.facade import LLM

assert jax.device_count() == 8
KW = dict(hbm_budget_bytes=1 << 30, expected_batch=3,
          expected_len_dist={"mean": 10, "max": 64}, page_size=4,
          sync_every=4)
for arch, mesh in (("gemma2-2b-reduced", "tp=2"),
                   ("mixtral-8x7b-reduced", "ep=4")):
    cfg = get_config(arch)
    params = tfm.init_params(jax.random.PRNGKey(0), cfg)
    reqs = [([5, 7, 11], 8), ([3, 2, 9, 4], 6)]
    o1 = [r.out for r in LLM(cfg, params, plan_lib.plan_serve(cfg, **KW))
          .stream(reqs, rng=jax.random.PRNGKey(3))]
    plan = plan_lib.plan_serve(cfg, mesh=mesh, **KW)
    sm = shard.ServeMesh.from_plan(plan)
    assert sm.backed, sm.describe()
    dm = sm.device_mesh()                   # places on real host devices
    assert dm.devices.size == sm.devices
    o2 = [r.out for r in LLM(cfg, params, plan)
          .stream(reqs, rng=jax.random.PRNGKey(3))]
    assert o1 == o2, (arch, mesh, o1, o2)
print("MESH8_OK")
"""


def test_sharded_stream_bit_identical_on_forced_8_device_mesh():
    """The acceptance assertion: on a forced 8-device host platform the
    mesh is backed, ServeMesh.device_mesh() places on real devices, and
    sharded stream() stays bit-identical to single-device."""
    r = subprocess.run([sys.executable, "-c", _MESH8],
                       capture_output=True, text=True, timeout=600)
    assert r.returncode == 0, r.stderr
    assert "MESH8_OK" in r.stdout
