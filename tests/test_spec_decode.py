"""Speculative decoding on CoW pages (ISSUE 9): the flattened k-position
verifier vs sequential ``serve_step`` (bit-exact, across page-boundary
offsets, GQA throughout, int8 fallback), the page-chain fork primitives
(fork/commit/abort refcount ceremony), the plan's ``spec`` roofline
Decision, and end-to-end scheduler equivalence — greedy token streams
bit-identical to the non-speculative path under staggered arrivals, EOS,
page-pressure preemption, and the recompute-resume fast path."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core import plan as plan_lib
from repro.models import decoding, transformer as tfm
from repro.serve.guard import assert_pool_clean, audit_pool
from repro.serve.paging import PageAllocator
from repro.serve.scheduler import ContinuousBatchingScheduler, StreamRequest

ARCH = "qwen2.5-3b-reduced"          # GQA: 4 query heads over 2 KV heads


@pytest.fixture(scope="module")
def cfg_params():
    cfg = get_config(ARCH)
    return cfg, tfm.init_params(jax.random.PRNGKey(0), cfg)


def _prefilled_row(cfg, params, prompt, cache_len=64, ps=8, kv_quant="fp"):
    """One paged row holding ``prompt``: (cache, block_table (1, MP))."""
    MP = cache_len // ps
    pager = PageAllocator(MP, ps)
    assert pager.ensure(0, cache_len)        # whole chain: headroom for k
    bt = jnp.asarray(pager.block_table_rows([0], MP))
    cache = decoding.init_paged_cache(cfg, 1, cache_len, MP, ps, kv_quant)
    pp = decoding.PagedPrefill(cache=cache, block_table_rows=bt,
                               slots=jnp.asarray([0]),
                               write_start=jnp.asarray([0]))
    S = 1 << (len(prompt) - 1).bit_length()
    toks = jnp.asarray([prompt + [0] * (S - len(prompt))], jnp.int32)
    logits, cache = decoding.prefill_batched(
        params, toks, jnp.asarray([len(prompt)]), cfg, cache_len, paged=pp)
    return cache, bt, logits[0, len(prompt) - 1]


# ---------------------------------------------- flattened verify vs serial
@pytest.mark.parametrize("plen", [3, 6, 8, 13])
def test_verify_matches_sequential_fp(cfg_params, plen):
    """verify_step's one-dispatch k-position logits equal k sequential
    serve_step calls bit-exactly (fp pools), with the candidate window
    landing inside a page, straddling a boundary, and starting page-aligned
    (ps=8: windows [3,7), [6,10), [8,12), [13,17))."""
    cfg, params = cfg_params
    k = 4
    rng = np.random.default_rng(plen)
    prompt = [int(t) for t in rng.integers(0, 500, plen)]
    cand = [int(t) for t in rng.integers(0, 500, k)]
    cache, bt, _ = _prefilled_row(cfg, params, prompt)

    seq = []
    c = cache
    for i, t in enumerate(cand):
        lg, c = decoding.serve_step(params, c, jnp.asarray([[t]], jnp.int32),
                                    jnp.asarray([plen + i], jnp.int32), cfg,
                                    block_table=bt)
        seq.append(np.asarray(lg[0, 0]))

    flat, _ = decoding.verify_step(params, cache,
                                   jnp.asarray([cand], jnp.int32),
                                   jnp.asarray([plen], jnp.int32), cfg,
                                   block_table=bt)
    for i in range(k):
        np.testing.assert_array_equal(np.asarray(flat[0, i]), seq[i])


def test_verify_dead_row_writes_drop(cfg_params):
    """A flattened batch may carry dead rows (all -1 block table, the
    scheduler's empty-slot sentinel): their appends must drop and never
    perturb a live row's pages — the regression behind the fork-id
    collision fix (fork children live at -2 - rid, never -1)."""
    cfg, params = cfg_params
    prompt, cand = [5, 6, 7], [11, 12, 13, 14]
    cache, bt, _ = _prefilled_row(cfg, params, prompt, cache_len=32)
    # rebuild as a 2-row pool: row 1 dead
    MP = 32 // 8
    pager = PageAllocator(2 * MP, 8)
    assert pager.ensure(0, 32)
    bt2 = jnp.asarray(pager.block_table_rows([0, -1], MP))
    cache2 = decoding.init_paged_cache(cfg, 2, 32, 2 * MP, 8)
    pp = decoding.PagedPrefill(cache=cache2, block_table_rows=bt2[:1],
                               slots=jnp.asarray([0]),
                               write_start=jnp.asarray([0]))
    lg, cache2 = decoding.prefill_batched(
        params, jnp.asarray([prompt + [0]], jnp.int32), jnp.asarray([3]),
        cfg, 32, paged=pp)

    ref, _ = decoding.verify_step(params, cache, jnp.asarray([cand]),
                                  jnp.asarray([3]), cfg, block_table=bt)
    got, _ = decoding.verify_step(params, cache2,
                                  jnp.asarray([cand, cand]),
                                  jnp.asarray([3, 0]), cfg, block_table=bt2)
    np.testing.assert_array_equal(np.asarray(ref[0]), np.asarray(got[0]))


def test_verify_int8_fallback_matches_sequential(cfg_params):
    """Quantized pools take the sequential k-loop fallback (per-page amax
    scales make append order observable): logits and pools must equal k
    explicit serve_step calls exactly."""
    cfg, params = cfg_params
    prompt, cand = [9, 8, 7, 6, 5], [3, 4, 5, 6]
    cache, bt, _ = _prefilled_row(cfg, params, prompt, kv_quant="int8")

    seq, c = [], cache
    for i, t in enumerate(cand):
        lg, c = decoding.serve_step(params, c, jnp.asarray([[t]], jnp.int32),
                                    jnp.asarray([5 + i], jnp.int32), cfg,
                                    block_table=bt)
        seq.append(np.asarray(lg[0, 0]))
    flat, cf = decoding.verify_step(params, cache, jnp.asarray([cand]),
                                    jnp.asarray([5], jnp.int32), cfg,
                                    block_table=bt)
    for i in range(4):
        np.testing.assert_array_equal(np.asarray(flat[0, i]), seq[i])
    for a, b in zip(jax.tree.leaves(c), jax.tree.leaves(cf)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_verify_rejects_non_global_configs():
    cfg = get_config("gemma2-2b-reduced")     # local+global interleave
    params = tfm.init_params(jax.random.PRNGKey(0), cfg)
    cache = decoding.init_paged_cache(cfg, 1, 32, 4, 8)
    with pytest.raises(AssertionError, match="all-global"):
        decoding.verify_step(params, cache, jnp.asarray([[1, 2]]),
                             jnp.asarray([0]), cfg,
                             block_table=jnp.zeros((1, 4), jnp.int32))


# ------------------------------------------------------- fork primitives
def test_fork_commit_abort_refcounts():
    """fork_chain is pure refcount ceremony (zero copies), commit adopts the
    child table and releases the pre-fork chain, abort is exactly one
    refcount drop — audit-clean at every step."""
    pager = PageAllocator(8, 4)
    assert pager.ensure(0, 10)                # 3 pages
    pager.set_length(0, 10)
    pages = list(pager.snapshot()["tables"][0])

    assert pager.fork_chain(0, -2) == ()      # no cow_tail requested
    assert all(pager.refcount(p) == 2 for p in pages)
    assert not audit_pool(pager)

    assert pager.abort_fork(-2) == 0          # shared pages survive
    assert all(pager.refcount(p) == 1 for p in pages)
    assert not audit_pool(pager)

    pager.fork_chain(0, -2)
    assert pager.ensure(-2, 14)               # branch grows a fresh tail page
    pager.set_length(-2, 14)
    assert pager.pages_of(-2) == 4
    pager.commit_fork(0, -2)                  # parent adopts the longer chain
    assert pager.pages_of(0) == 4
    assert pager.snapshot()["lengths"][0] == 14
    assert all(pager.refcount(p) == 1 for p in pages)
    assert not audit_pool(pager)

    pager.free(0)
    assert_pool_clean(pager, drained=True)


def test_fork_cow_tail_materializes_private_page():
    """cow_tail=True (sibling forks): a partial tail page gets a private
    copy so branch appends can't collide in the shared tail."""
    pager = PageAllocator(8, 4)
    assert pager.ensure(0, 6)                 # page 2 half full
    pager.set_length(0, 6)
    got = pager.fork_chain(0, -2, cow_tail=True)
    assert got and len(got) == 2              # (src, dst) device copy pair
    t0 = pager.snapshot()["tables"][0]
    t1 = pager.snapshot()["tables"][-2]
    assert t0[0] == t1[0] and t0[1] != t1[1]
    assert not audit_pool(pager)
    pager.abort_fork(-2)
    pager.free(0)
    assert_pool_clean(pager, drained=True)


def test_fork_chain_pressure_returns_none():
    pager = PageAllocator(2, 4)
    assert pager.ensure(0, 6)                 # both pages held, tail partial
    pager.set_length(0, 6)
    assert pager.fork_chain(0, -2, cow_tail=True) is None
    assert pager.pages_of(-2) == 0            # nothing changed
    assert not audit_pool(pager)


# ------------------------------------------------------ plan spec decision
def test_plan_spec_rule_batch1_enables():
    cfg = get_config("qwen2.5-3b")            # full-size: weight-stream bound
    p = plan_lib.plan_serve(cfg, hbm_budget_bytes=8 << 30, expected_batch=1,
                            expected_len_dist={"mean": 512, "max": 2048},
                            attn_path="paged")
    assert p.spec_k >= 2
    d = [d for d in p.decisions if d.name == "spec"][0]
    assert d.bound == "HBM"
    assert d.numbers["est_speedup"] >= plan_lib.SPEC_MIN_GAIN
    assert "weight" in d.why
    assert f"k={p.spec_k}" in p.explain()

    batched = plan_lib.plan_serve(cfg, hbm_budget_bytes=8 << 30,
                                  expected_batch=4,
                                  expected_len_dist={"mean": 512,
                                                     "max": 2048},
                                  attn_path="paged")
    assert batched.spec_k == 0                # rows amortize the weights


def test_plan_spec_pin_validation():
    cfg = get_config(ARCH)
    kw = dict(hbm_budget_bytes=1 << 30, expected_batch=2,
              expected_len_dist={"mean": 24, "max": 64}, page_size=8,
              attn_path="paged")
    assert plan_lib.plan_serve(cfg, **kw, spec_k=4).spec_k == 4
    with pytest.raises(ValueError, match="spec_k must be 0 or in"):
        plan_lib.plan_serve(cfg, **kw, spec_k=1)
    with pytest.raises(ValueError, match="all-global"):
        plan_lib.plan_serve(get_config("gemma2-2b-reduced"), **kw, spec_k=4)
    # legacy scheduler shim never speculates
    assert plan_lib.plan_for_scheduler(cfg, rows=2, cache_len=64,
                                       page_size=8).spec_k == 0


def test_replan_keeps_spec_pinned():
    """A feedback-driven hot-swap can never flip the spec dispatch."""
    cfg = get_config(ARCH)
    base = plan_lib.plan_serve(cfg, hbm_budget_bytes=1 << 30,
                               expected_batch=2,
                               expected_len_dist={"mean": 24, "max": 64},
                               page_size=8, attn_path="paged", spec_k=4)
    swapped = plan_lib.replan_from_lengths(cfg, base, [20, 30, 40])
    assert swapped.spec_k == base.spec_k == 4


# --------------------------------------------- end-to-end scheduler exact
def _mkplan(cfg, k, batch=2, **kw):
    return plan_lib.plan_serve(
        cfg, hbm_budget_bytes=1 << 30, expected_batch=batch,
        expected_len_dist={"mean": 24, "max": 64}, page_size=kw.pop("ps", 8),
        attn_path="paged", spec_k=k, **kw)


def _run_plan(cfg, params, plan, reqs, sync_every=4, eos_id=-1, seed=7):
    s = ContinuousBatchingScheduler(cfg, params, plan,
                                    sync_every=sync_every, eos_id=eos_id)
    done = s.run([StreamRequest(i, list(p), m, arrival=t)
                  for i, (p, m, t) in enumerate(reqs)],
                 rng=jax.random.PRNGKey(seed))
    return {r.rid: r.out for r in done}, s


@pytest.mark.parametrize("sync_every", [1, 4])
def test_spec_scheduler_bit_exact_staggered(cfg_params, sync_every):
    """Greedy token streams bit-identical to the non-speculative scheduler
    under staggered arrivals (dead rows in early chunks — the fork-id
    regression scenario) at chunk lengths 1 and 4."""
    cfg, params = cfg_params
    reqs = [([5, 6, 7], 9, 0.0), ([3, 4], 3, 2.0), ([9, 9, 9, 2], 7, 5.0)]
    base, _ = _run_plan(cfg, params, _mkplan(cfg, 0), reqs, sync_every)
    spec, s = _run_plan(cfg, params, _mkplan(cfg, 4), reqs, sync_every)
    assert base == spec
    st = s.phase_stats
    assert st["spec_rounds"] > 0
    assert 0 < st["spec_accepted_tokens"] <= st["spec_drafted_tokens"]
    assert st["pages"]["pages_free"] == st["pages"]["pages_total"]


def test_spec_scheduler_bit_exact_with_eos(cfg_params):
    """An EOS inside an accepted draft run must terminate the stream at the
    same token the sequential path does (trailing accepts are discarded)."""
    cfg, params = cfg_params
    reqs = [([5, 6, 7], 12, 0.0), ([3, 4], 12, 0.0)]
    # pick the baseline's own first output token as EOS: guaranteed to fire
    base0, _ = _run_plan(cfg, params, _mkplan(cfg, 0), reqs)
    eos = base0[0][1]
    base, _ = _run_plan(cfg, params, _mkplan(cfg, 0), reqs, eos_id=eos)
    spec, _ = _run_plan(cfg, params, _mkplan(cfg, 4), reqs, eos_id=eos)
    assert base == spec
    assert base[0][-1] == eos                 # EOS token itself is emitted


def test_spec_with_preemption_and_fast_resume(cfg_params):
    """Page pressure under speculation: preemption/recompute and the
    adopted-suffix resume fast path both preserve the exact streams."""
    cfg, params = cfg_params
    pre = [7, 3, 9, 4, 2, 8, 6, 1]            # shared prefix, 2 pages at ps=4
    reqs = [(pre + [11, 12], 24, 0.0), (pre + [13, 14], 10, 1.0),
            (pre + [15, 16], 10, 2.0)]
    ref, _ = _run_plan(cfg, params,
                       _mkplan(cfg, 0, batch=3, ps=4), reqs, sync_every=2)
    for k in (0, 2):
        plan = dataclasses.replace(_mkplan(cfg, k, batch=3, ps=4),
                                   num_pages=9)
        got, s = _run_plan(cfg, params, plan, reqs, sync_every=2)
        assert got == ref, f"spec_k={k} diverged under page pressure"
        assert s.phase_stats["preemptions"] > 0
    assert s.spec_on                          # the k=2 run really speculated
    assert s.phase_stats["spec_rounds"] > 0
    assert s.phase_stats["resume_fast_prompts"] > 0
    assert s.phase_stats["resume_fast_tokens"] > 0


def test_spec_randomized_equivalence(cfg_params):
    """Seeded sweep over request shapes, EOS ids, chunk lengths and draft
    depths: every speculative stream equals its sequential twin."""
    cfg, params = cfg_params
    rng = np.random.default_rng(0)
    for trial in range(3):
        n = int(rng.integers(2, 5))
        reqs = [([int(t) for t in rng.integers(0, 500, rng.integers(1, 9))],
                 int(rng.integers(1, 12)), float(rng.integers(0, 8)))
                for _ in range(n)]
        eos = int(rng.integers(-1, 600))
        T = int(rng.choice([1, 2, 4]))
        k = int(rng.choice([2, 3, 8]))
        base, _ = _run_plan(cfg, params, _mkplan(cfg, 0), reqs, T, eos,
                            seed=trial)
        spec, _ = _run_plan(cfg, params, _mkplan(cfg, k), reqs, T, eos,
                            seed=trial)
        assert base == spec, (trial, n, eos, T, k)


def test_spec_disabled_on_temperature(cfg_params):
    """Sampling (temperature > 0) gates speculation off at runtime: the
    draft/verify identity only holds for greedy argmax."""
    cfg, params = cfg_params
    plan = _mkplan(cfg, 4)
    s = ContinuousBatchingScheduler(cfg, params, plan, sync_every=4,
                                    eos_id=-1, temperature=0.8)
    assert not s.spec_on
    s2 = ContinuousBatchingScheduler(cfg, params, plan, sync_every=4,
                                     eos_id=-1)
    assert s2.spec_on
