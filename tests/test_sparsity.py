"""CSC / block-CSC formats (paper §IV, Fig. 16) + pruning. Includes the
paper's exact Fig. 16 example and hypothesis round-trip properties."""
import numpy as np
import pytest
from hypothesis_compat import given, settings, st  # optional dep, see tests/hypothesis_compat.py

from repro.core import sparsity


# ------------------------------------------------- the paper's Fig.16 example
def test_paper_fig16_example():
    """Weight matrix from Fig. 16 — address vector must match the paper."""
    # columns: [a,b | c,d,e | f | (empty) | g,h | i | j,k,l] per the figure
    mat = np.zeros((7, 8), dtype=np.int64)
    vals = dict(a=1, b=2, c=3, d=4, e=5, f=6, g=7, h=8, i=9, j=10, k=11, l=12)
    # col 0: a at row 1 (count 1), b at row 2 (count 0)
    mat[1, 0] = vals["a"]
    mat[2, 0] = vals["b"]
    # col 1: c (count 0) row 0, d (count 0) row 1, e (count 1) row 3
    mat[0, 1] = vals["c"]
    mat[1, 1] = vals["d"]
    mat[3, 1] = vals["e"]
    # col 2: f with 2 leading zeros -> row 2
    mat[2, 2] = vals["f"]
    # col 3: all zero
    # col 4: g with count 3 -> row 3
    mat[3, 4] = vals["g"]
    # col 5: h count 1 -> row 1, i count 1 -> row 3
    mat[1, 5] = vals["h"]
    mat[3, 5] = vals["i"]
    # col 6: j count 0 row 0, k count 0 row 1, l count 0 row 2
    mat[0, 6] = vals["j"]
    mat[1, 6] = vals["k"]
    mat[2, 6] = vals["l"]
    # col 7: all zero
    m = sparsity.csc_encode(mat)
    assert list(m.data) == [1, 2, 3, 4, 5, 6, 7, 8, 9, 10, 11, 12]
    assert list(m.count) == [1, 0, 0, 0, 1, 2, 3, 1, 1, 0, 0, 0]
    # paper: address = {0, 2, 5, 6, 6, 7, 9, 9(+3=12)}; repeated 6 marks the
    # empty column
    assert list(m.address) == [0, 2, 5, 6, 6, 7, 9, 12, 12]
    np.testing.assert_array_equal(sparsity.csc_decode(m), mat)


# --------------------------------------------------------- round-trip property
@settings(max_examples=40, deadline=None)
@given(st.integers(2, 24), st.integers(1, 16), st.floats(0.0, 1.0),
       st.integers(0, 2 ** 31 - 1))
def test_csc_roundtrip(rows, cols, zero_frac, seed):
    rng = np.random.default_rng(seed)
    mat = rng.integers(1, 127, (rows, cols)).astype(np.int64)
    mask = rng.random((rows, cols)) < zero_frac
    mat[mask] = 0
    m = sparsity.csc_encode(mat)
    np.testing.assert_array_equal(sparsity.csc_decode(m), mat)


def test_csc_count_overflow_long_runs():
    """Runs > 15 zeros must round-trip via explicit padding zeros (4b count)."""
    mat = np.zeros((40, 2), np.int64)
    mat[38, 0] = 5
    mat[0, 1] = 7
    mat[39, 1] = 9
    m = sparsity.csc_encode(mat, count_bits=4)
    assert (np.asarray(m.count) <= 15).all()
    np.testing.assert_array_equal(sparsity.csc_decode(m), mat)


@settings(max_examples=25, deadline=None)
@given(st.sampled_from([(16, 16, 4, 4), (32, 16, 8, 8), (64, 64, 16, 16)]),
       st.floats(0.0, 1.0), st.integers(0, 2 ** 31 - 1))
def test_bcsc_roundtrip(dims, zero_frac, seed):
    K, N, bk, bn = dims
    rng = np.random.default_rng(seed)
    mat = rng.standard_normal((K, N)).astype(np.float32)
    # zero whole blocks
    nb = (K // bk, N // bn)
    bmask = rng.random(nb) < zero_frac
    mask = np.kron(bmask, np.ones((bk, bn), bool))
    mat[mask] = 0
    m = sparsity.bcsc_encode(mat, bk, bn)
    np.testing.assert_array_equal(sparsity.bcsc_decode(m), mat)


def test_compression_ratio_increases_with_sparsity():
    rng = np.random.default_rng(0)
    ratios = []
    for sp in (0.0, 0.5, 0.9):
        mat = rng.integers(1, 127, (64, 64)).astype(np.int64)
        mask = rng.random((64, 64)) < sp
        mat[mask] = 0
        ratios.append(sparsity.csc_encode(mat).compression_ratio())
    assert ratios[0] < ratios[1] < ratios[2]
    assert ratios[0] < 1.0          # dense data: CSC must cost MORE than raw
    assert ratios[2] > 2.0          # 90% sparse: clear win (paper Table III)


def test_magnitude_prune_sparsity_level():
    import jax.numpy as jnp
    rng = np.random.default_rng(1)
    w = jnp.asarray(rng.standard_normal((64, 64)), jnp.float32)
    wp = sparsity.magnitude_prune(w, 0.75)
    frac = float((np.asarray(wp) == 0).mean())
    assert 0.70 <= frac <= 0.80
    # surviving entries unchanged
    keep = np.asarray(wp) != 0
    np.testing.assert_array_equal(np.asarray(wp)[keep], np.asarray(w)[keep])


def test_block_prune_produces_skippable_blocks():
    import jax.numpy as jnp
    rng = np.random.default_rng(2)
    w = jnp.asarray(rng.standard_normal((64, 64)), jnp.float32)
    wp = sparsity.block_magnitude_prune(w, 0.5, 16, 16)
    m = sparsity.bcsc_encode(np.asarray(wp), 16, 16)
    assert m.nnzb == 8            # exactly half of the 16 blocks survive
