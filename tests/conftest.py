"""Shared fixtures. NOTE: no XLA_FLAGS here — tests must see the real single
CPU device (only launch/dryrun.py forces 512 host devices)."""
import numpy as np
import pytest


@pytest.fixture
def rng():
    return np.random.default_rng(0)
