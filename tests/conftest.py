"""Shared fixtures. NOTE: no XLA_FLAGS here — tests must see the real single
CPU device (only launch/dryrun.py forces 512 host devices)."""
import numpy as np
import pytest


def pytest_configure(config):
    # no pytest.ini/pyproject in this repo, so markers register here
    config.addinivalue_line(
        "markers",
        "chaos: seeded fault-injection suite for the serving guard "
        "(run explicitly in CI via `-m chaos`; part of the default run too)")


@pytest.fixture
def rng():
    return np.random.default_rng(0)
