"""Deterministic synthetic token pipeline with per-host sharding + prefetch.

Design points for the 1000-node posture:
 * **Stateless indexing** — batch ``i`` is a pure function of (seed, i, host),
   so any host can (re)produce any shard: restarts and elastic re-sharding need
   no data-state checkpoint, and a straggler's shard can be re-dispatched to a
   healthy host (runtime.fault_tolerance consumes this property).
 * **Prefetch** — a background thread keeps ``prefetch`` batches ready.
"""
from __future__ import annotations

import dataclasses
import queue
import threading
from typing import Dict, Iterator, Optional

import jax
import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    seq_len: int
    global_batch: int
    vocab_size: int
    seed: int = 0
    num_hosts: int = 1
    host_id: int = 0
    num_codebooks: int = 1
    num_patches: int = 0
    d_model: int = 0
    cond_len: int = 0

    @property
    def host_batch(self) -> int:
        assert self.global_batch % self.num_hosts == 0
        return self.global_batch // self.num_hosts


def _rng_for(cfg: DataConfig, step: int, host: int) -> np.random.Generator:
    return np.random.default_rng(
        np.random.SeedSequence([cfg.seed, step, host]))


def synth_batch(cfg: DataConfig, step: int,
                host: Optional[int] = None) -> Dict[str, np.ndarray]:
    """The batch for (step, host) — pure function, any host can build any shard."""
    host = cfg.host_id if host is None else host
    rng = _rng_for(cfg, step, host)
    B, S = cfg.host_batch, cfg.seq_len
    S_text = S - cfg.num_patches
    if cfg.num_codebooks > 1:
        toks = rng.integers(0, cfg.vocab_size,
                            (B, cfg.num_codebooks, S_text), dtype=np.int32)
        labels = np.concatenate([toks[..., 1:],
                                 np.full((B, cfg.num_codebooks, 1), -1,
                                         np.int32)], axis=-1)
    else:
        toks = rng.integers(0, cfg.vocab_size, (B, S_text), dtype=np.int32)
        labels = np.concatenate(
            [toks[:, 1:], np.full((B, 1), -1, np.int32)], axis=-1)
    batch = {"tokens": toks, "labels": labels}
    if cfg.num_patches:
        batch["patch_embeds"] = rng.standard_normal(
            (B, cfg.num_patches, cfg.d_model)).astype(np.float32) * 0.02
    if cfg.cond_len:
        batch["cond"] = rng.standard_normal(
            (B, cfg.cond_len, cfg.d_model)).astype(np.float32) * 0.02
    return batch


class Pipeline:
    """Prefetching iterator over synth batches, resumable from any step."""

    def __init__(self, cfg: DataConfig, start_step: int = 0, prefetch: int = 2):
        self.cfg = cfg
        self.step = start_step
        self._q: "queue.Queue" = queue.Queue(maxsize=max(prefetch, 1))
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._worker, daemon=True)
        self._thread.start()

    def _worker(self):
        s = self.step
        while not self._stop.is_set():
            b = synth_batch(self.cfg, s)
            while not self._stop.is_set():
                try:
                    self._q.put((s, b), timeout=0.1)
                    break
                except queue.Full:
                    continue
            s += 1

    def __iter__(self) -> Iterator:
        return self

    def __next__(self):
        s, b = self._q.get()
        self.step = s + 1
        return s, b

    def close(self):
        self._stop.set()
        try:
            while True:
                self._q.get_nowait()
        except queue.Empty:
            pass
        self._thread.join(timeout=2)


def batch_for_arch(arch_cfg, seq_len: int, global_batch: int, step: int,
                   seed: int = 0) -> Dict[str, np.ndarray]:
    """Convenience: one host, shapes derived from an ArchConfig."""
    d = DataConfig(
        seq_len=seq_len, global_batch=global_batch,
        vocab_size=arch_cfg.vocab_size, seed=seed,
        num_codebooks=arch_cfg.num_codebooks,
        num_patches=arch_cfg.num_patches if arch_cfg.frontend == "vision" else 0,
        d_model=arch_cfg.d_model,
        cond_len=arch_cfg.cross_attn_cond)
    return synth_batch(d, step)
