from repro.train import grad_compression, loop, optimizer

__all__ = ["grad_compression", "loop", "optimizer"]
