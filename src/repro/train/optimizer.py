"""AdamW + schedules + clipping, from scratch (no optax dependency).

Optimizer state is a pytree congruent with params, so the FSDP PartitionSpecs
from autoshard apply leaf-for-leaf (ZeRO: moments live on the param shards).
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    step: jnp.ndarray          # () int32
    mu: dict                   # first moment, param-shaped
    nu: dict                   # second moment, param-shaped


@dataclasses.dataclass(frozen=True)
class OptimizerConfig:
    peak_lr: float = 3e-4
    min_lr_ratio: float = 0.1
    warmup_steps: int = 100
    total_steps: int = 10_000
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip_norm: float = 1.0


def cosine_schedule(cfg: OptimizerConfig, step):
    step = step.astype(jnp.float32)
    warm = cfg.peak_lr * step / jnp.maximum(cfg.warmup_steps, 1)
    prog = jnp.clip((step - cfg.warmup_steps) /
                    jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1), 0, 1)
    cos = cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * \
        0.5 * (1 + jnp.cos(jnp.pi * prog))
    return jnp.where(step < cfg.warmup_steps, warm, cfg.peak_lr * cos)


def global_norm(tree) -> jnp.ndarray:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32)))
              for x in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def clip_by_global_norm(grads, max_norm: float):
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale), grads), norm


def init_adamw(params) -> AdamWState:
    zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    return AdamWState(step=jnp.zeros((), jnp.int32), mu=zeros,
                      nu=jax.tree.map(jnp.copy, zeros))


def _decay_mask(path) -> bool:
    """Apply weight decay only to ≥2D matrices (skip norms/bias/scalars)."""
    return True


def adamw_update(cfg: OptimizerConfig, params, grads,
                 state: AdamWState) -> Tuple[dict, AdamWState, Dict]:
    grads, gnorm = clip_by_global_norm(grads, cfg.grad_clip_norm)
    step = state.step + 1
    lr = cosine_schedule(cfg, step)
    b1, b2 = cfg.b1, cfg.b2
    bc1 = 1 - b1 ** step.astype(jnp.float32)
    bc2 = 1 - b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32)
        m = b1 * m + (1 - b1) * g
        v = b2 * v + (1 - b2) * jnp.square(g)
        mh = m / bc1
        vh = v / bc2
        delta = mh / (jnp.sqrt(vh) + cfg.eps)
        if p.ndim >= 2:
            delta = delta + cfg.weight_decay * p.astype(jnp.float32)
        new_p = p.astype(jnp.float32) - lr * delta
        return new_p.astype(p.dtype), m, v

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_m = jax.tree.leaves(state.mu)
    flat_v = jax.tree.leaves(state.nu)
    new_p, new_m, new_v = [], [], []
    for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v):
        a, b, c = upd(p, g, m, v)
        new_p.append(a)
        new_m.append(b)
        new_v.append(c)
    params = jax.tree.unflatten(treedef, new_p)
    mu = jax.tree.unflatten(treedef, new_m)
    nu = jax.tree.unflatten(treedef, new_v)
    metrics = {"grad_norm": gnorm, "lr": lr}
    return params, AdamWState(step=step, mu=mu, nu=nu), metrics
