"""Training step + loop: microbatched gradient accumulation, remat policies,
donated buffers. The returned step is a pure function suitable for pjit with
the autoshard in/out shardings.

Compute/communication overlap: with ``microbatches > 1`` the gradient
accumulation scan lets XLA's latency-hiding scheduler overlap microbatch i's
FSDP all-gathers / grad reduce-scatters with microbatch i±1's compute —
the structural enabler for the paper's "hide NoC time under MAC time".
"""
from __future__ import annotations

import functools
from typing import Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import transformer as tfm
from repro.train import optimizer as opt_lib


def _split_microbatches(batch, k: int):
    def r(x):
        assert x.shape[0] % k == 0, (x.shape, k)
        return x.reshape((k, x.shape[0] // k) + x.shape[1:])
    return jax.tree.map(r, batch)


def make_loss_fn(cfg, remat_policy: str, hints=None):
    def loss_fn(params, batch):
        return tfm.loss_fn(params, batch, cfg, remat_policy=remat_policy,
                           hints=hints)
    return loss_fn


def make_train_step(cfg, opt_cfg: opt_lib.OptimizerConfig,
                    remat_policy: str = "dots",
                    microbatches: int = 1, hints=None) -> Callable:
    """Returns train_step(params, opt_state, batch) -> (params, opt_state,
    metrics)."""
    loss_fn = make_loss_fn(cfg, remat_policy, hints)
    vg = jax.value_and_grad(loss_fn, has_aux=True)

    def train_step(params, opt_state, batch):
        if microbatches == 1:
            (loss, metrics), grads = vg(params, batch)
        else:
            mbs = _split_microbatches(batch, microbatches)

            def mb_step(carry, mb):
                g_acc, l_acc = carry
                (loss, metrics), g = vg(params, mb)
                g_acc = jax.tree.map(
                    lambda a, b: a + b.astype(jnp.float32), g_acc, g)
                return (g_acc, l_acc + loss), metrics

            g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (grads, loss), metrics_seq = jax.lax.scan(
                mb_step, (g0, jnp.zeros(())), mbs)
            grads = jax.tree.map(lambda g: g / microbatches, grads)
            loss = loss / microbatches
            metrics = jax.tree.map(lambda m: jnp.mean(m), metrics_seq)
        params, opt_state, om = opt_lib.adamw_update(
            opt_cfg, params, grads, opt_state)
        metrics = {**metrics, **om, "loss_total": loss}
        return params, opt_state, metrics

    return train_step


def make_eval_step(cfg) -> Callable:
    loss_fn = make_loss_fn(cfg, remat_policy="none")

    def eval_step(params, batch):
        loss, metrics = loss_fn(params, batch)
        return {**metrics, "loss_total": loss}

    return eval_step


def init_train_state(rng, cfg) -> Tuple[dict, opt_lib.AdamWState]:
    params = tfm.init_params(rng, cfg)
    return params, opt_lib.init_adamw(params)


def abstract_train_state(cfg):
    return jax.eval_shape(lambda: init_train_state(jax.random.PRNGKey(0), cfg))
