"""Int8 error-feedback gradient compression for the DP all-reduce.

Each replica quantizes its local gradient to int8 (per-tensor scale), keeps the
quantization residual in an error-feedback buffer (added back next step — keeps
Adam convergent), all-gathers the int8 payloads over the dp axes, and
dequantizes + sums in fp32 locally.

Communication: (n−1)/n · 1 byte/elt vs 2·(n−1)/n · 4 bytes for a ring fp32
all-reduce → ~8× fewer collective bytes on the DP axes.

State layout: error-feedback buffers are *per-replica*, stored stacked on a
leading dp-sharded axis (n_dp, *param_shape) so they are representable as
global arrays. The whole step runs under shard_map with params replicated
(the planner's BROADCAST weight mode — pure DP; DESIGN.md §5).
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro.sharding.collectives import shard_map

from repro.train import optimizer as opt_lib


def quantize(g, ef):
    """g fp32 + error feedback -> (q int8, scale fp32 scalar, new_ef)."""
    gc = g.astype(jnp.float32) + ef
    scale = jnp.max(jnp.abs(gc)) / 127.0 + 1e-30
    q = jnp.clip(jnp.round(gc / scale), -127, 127).astype(jnp.int8)
    new_ef = gc - q.astype(jnp.float32) * scale
    return q, scale, new_ef


def dequantize(q, scale):
    return q.astype(jnp.float32) * scale


def _dp_size(mesh: Mesh) -> int:
    n = 1
    for a, s in zip(mesh.axis_names, mesh.devices.shape):
        if a in ("pod", "data"):
            n *= s
    return n


def init_error_feedback(mesh: Mesh, params):
    n = _dp_size(mesh)
    return jax.tree.map(
        lambda p: jnp.zeros((n,) + p.shape, jnp.float32), params)


def compressed_allreduce_leaf(g, ef, axis_names):
    """Inside shard_map. g: local grad; ef: local residual (same shape).
    Returns (summed grad fp32, new local residual)."""
    q, scale, new_ef = quantize(g, ef)
    flatq = q.reshape(-1)
    parts_q = flatq[None]                      # (1, numel)
    parts_s = scale[None]
    for ax in axis_names:
        parts_q = jax.lax.all_gather(parts_q, ax, axis=0, tiled=True)
        parts_s = jax.lax.all_gather(parts_s, ax, axis=0, tiled=True)
    total = jnp.einsum("nd,n->d", parts_q.astype(jnp.float32), parts_s)
    return total.reshape(g.shape), new_ef


def make_compressed_dp_train_step(mesh: Mesh, loss_fn, opt_cfg):
    """Pure-DP train step with int8-EF gradient all-reduce.

    loss_fn(params, batch) -> (scalar, metrics). Params/opt replicated; batch
    sharded over dp on dim0; ef stacked (n_dp, ...) sharded over dp.
    """
    dp = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    n_dp = _dp_size(mesh)

    def body(params, opt_state, batch, ef):
        (loss, metrics), grads = jax.value_and_grad(
            loss_fn, has_aux=True)(params, batch)
        flat_g, tdef = jax.tree.flatten(grads)
        flat_e = jax.tree.leaves(ef)
        new_g, new_e = [], []
        for g, e in zip(flat_g, flat_e):
            s, ne = compressed_allreduce_leaf(g, e[0], dp)
            new_g.append(s / n_dp)
            new_e.append(ne[None])
        grads = jax.tree.unflatten(tdef, new_g)
        ef = jax.tree.unflatten(tdef, new_e)
        loss = jax.lax.pmean(loss, dp)
        metrics = jax.tree.map(lambda m: jax.lax.pmean(m, dp), metrics)
        params, opt_state, om = opt_lib.adamw_update(
            opt_cfg, params, grads, opt_state)
        return params, opt_state, ef, {**metrics, **om, "loss_total": loss}

    def step(params, opt_state, batch, ef):
        # prefix specs: replicated params/opt/metrics, dp-sharded batch/ef
        f = shard_map(
            body, mesh=mesh,
            in_specs=(P(), P(), P(dp), P(dp)),
            out_specs=(P(), P(), P(dp), P()),
            check_vma=False)
        return f(params, opt_state, batch, ef)

    return step
