"""Named-axis helpers and divisibility-aware PartitionSpec builders."""
from __future__ import annotations

from typing import Dict, Optional, Sequence, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


def mesh_axis_sizes(mesh: Mesh) -> Dict[str, int]:
    return dict(zip(mesh.axis_names, mesh.devices.shape))


def dp_axes(mesh_axes: Dict[str, int]) -> Tuple[str, ...]:
    """Data-parallel axes: ('pod','data') on the multi-pod mesh, ('data',)."""
    return tuple(a for a in ("pod", "data") if a in mesh_axes)


def axes_size(mesh_axes: Dict[str, int], axes) -> int:
    if axes is None:
        return 1
    if isinstance(axes, str):
        return mesh_axes[axes]
    n = 1
    for a in axes:
        n *= mesh_axes[a]
    return n


def maybe(axes, dim_size: int, mesh_axes: Dict[str, int]):
    """Return ``axes`` if ``dim_size`` divides evenly over them, else None.

    This is the planner's fall-back-to-BROADCAST rule for diminished
    dimensions (e.g. GQA kv_heads < model axis — paper Table I)."""
    if axes is None:
        return None
    n = axes_size(mesh_axes, axes)
    if n <= 1 or dim_size % n != 0:
        return None
    if isinstance(axes, (list, tuple)) and len(axes) == 1:
        return axes[0]
    return tuple(axes) if isinstance(axes, (list, tuple)) else axes


def named(mesh: Mesh, spec: P) -> NamedSharding:
    return NamedSharding(mesh, spec)


def tree_named(mesh: Mesh, spec_tree):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), spec_tree,
                        is_leaf=lambda s: isinstance(s, P))
