"""Hierarchical collectives on the (pod, data, model) mesh.

The paper's two-level NoC (all-to-all inside a cluster, mesh between clusters)
motivates the classic hierarchical all-reduce: reduce-scatter inside the pod,
all-reduce the shards across pods, all-gather inside the pod. Inter-pod traffic
drops by the intra-pod fan-in — the HM-NoC scaling argument (§III-D).

Implemented with shard_map + jax.lax collectives; validated in tests against a
flat psum.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

try:  # location moved across jax versions
    from jax import shard_map as _shard_map
except Exception:  # pragma: no cover
    from jax.experimental.shard_map import shard_map as _shard_map  # type: ignore


def axis_size(axis_name: str) -> int:
    """Version-tolerant ``jax.lax.axis_size`` (absent before jax 0.6): the
    psum-of-one idiom is statically folded to the mesh axis size."""
    try:
        return jax.lax.axis_size(axis_name)
    except AttributeError:
        return jax.lax.psum(1, axis_name)


def shard_map(f, **kw):
    """Version-tolerant shard_map (check_vma/check_rep kwarg renamed)."""
    kw.pop("check_vma", None)
    kw.pop("check_rep", None)
    for flag in ({"check_vma": False}, {"check_rep": False}, {}):
        try:
            return _shard_map(f, **kw, **flag)
        except TypeError:
            continue
    return _shard_map(f, **kw)


def hierarchical_psum(x, pod_axis: str = "pod", inner_axis: str = "data"):
    """All-reduce over (pod × inner) as RS(inner) → AR(pod) → AG(inner).

    Equivalent to ``jax.lax.psum(x, (pod_axis, inner_axis))`` but inter-pod
    traffic carries only 1/inner of the payload. Call inside shard_map."""
    n_inner = axis_size(inner_axis)
    flat = x.reshape(-1)
    pad = (-flat.shape[0]) % n_inner
    if pad:
        flat = jnp.concatenate([flat, jnp.zeros((pad,), flat.dtype)])
    shard = jax.lax.psum_scatter(flat, inner_axis, scatter_dimension=0,
                                 tiled=True)
    shard = jax.lax.psum(shard, pod_axis)
    out = jax.lax.all_gather(shard, inner_axis, axis=0, tiled=True)
    if pad:
        out = out[:-pad]
    return out.reshape(x.shape)


def allreduce_stacked(mesh: Mesh, x):
    """Sum per-replica values stacked on dim 0 over the data-parallel axes.

    x: (n_dp, ...) sharded over ('pod','data'); returns the (replicated) sum.
    Uses the hierarchical schedule when a pod axis exists.
    """
    has_pod = "pod" in mesh.axis_names
    axes = ("pod", "data") if has_pod else ("data",)

    def body(xs):                     # xs: (1, ...) local slice
        v = xs[0]
        if has_pod:
            return hierarchical_psum(v, "pod", "data")
        return jax.lax.psum(v, "data")

    return shard_map(body, mesh=mesh, in_specs=P(axes), out_specs=P(),
                     check_vma=False)(x)


def ring_allgather(x, axis_name: str):
    """All-gather via (n-1) collective-permutes — an explicit ring schedule
    whose hops XLA can overlap with compute. Call inside shard_map; gathers
    along a new leading dim ordered by source index."""
    n = axis_size(axis_name)
    idx = jax.lax.axis_index(axis_name)
    perm = [(i, (i + 1) % n) for i in range(n)]
    chunks = [x]
    cur = x
    for _ in range(n - 1):
        cur = jax.lax.ppermute(cur, axis_name, perm)
        chunks.append(cur)
    stacked = jnp.stack(chunks)       # position j holds data from (idx - j) % n
    src = (idx - jnp.arange(n)) % n
    out = jnp.zeros_like(stacked)
    out = out.at[src].set(stacked)
    return out
