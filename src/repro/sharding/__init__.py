from repro.sharding import autoshard, collectives, specs

__all__ = ["autoshard", "collectives", "specs"]
