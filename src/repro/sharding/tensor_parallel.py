"""Device-side tensor-/expert-parallel decode math (ISSUE 10).

The sharded serving program is *shard-explicit single-jit*: one jit trace
contains an explicit loop over the mesh's model axis, and each iteration
computes exactly what one device computes from its local shard — the
paged-attention kernel reads only the local KV-head slice of the pool, the
MoE expert einsums read only the local expert slice. The collectives lower
to canonical-device-order concatenation, which is exact (no cross-device
float reduction ever happens), so sharded execution is bit-identical to
single-device **by construction**:

* per-KV-head locality (tp): every op in both attention paths treats the
  KV-head axis as a batch axis — q·k reduces over D per head, the online
  softmax (paged kernel) and the plain softmax (contiguous path) normalize
  per (kv_head, group) lane, and both lay q out as contiguous
  ``(KV, H/KV)`` groups — so computing heads in tp contiguous chunks and
  concatenating the contexts equals computing them at once; the full
  ``wo`` projection then runs on the gathered tensor unchanged.
* expert-as-batch (ep): the decode MoE einsums (``bsd,edf->ebsf`` and
  ``ebsf,efd->ebsd``) treat E as a pure batch axis, so per-shard expert
  slices concatenated along E equal the full einsum and the gate-weighted
  combine (``ebsd,bse->bsd``) runs on the gathered full-E tensor with
  exact 0.0 gates for unselected experts.

On a real mesh the loop body is what each device executes with the pool's
KV axis (and the experts' E axis) device-local — ``serve.shard`` supplies
the partition specs — and :func:`all_gather` is the wire collective.
tests/test_shard_serve.py asserts per-token bit-identity; the CI mesh8 job
re-runs the suite on a forced 8-device host platform.
"""
from __future__ import annotations

from typing import List, Optional

import jax
import jax.numpy as jnp


def shard_slice(x, axis: int, shard: int, n: int):
    """The local ``shard``-of-``n`` slice of ``x`` along ``axis`` (equal
    contiguous chunks; ``x.shape[axis]`` must divide by ``n``)."""
    size = x.shape[axis]
    assert size % n == 0, (size, n, axis)
    per = size // n
    return jax.lax.slice_in_dim(x, shard * per, (shard + 1) * per, axis=axis)


def all_gather(parts: List, axis: int):
    """The activation all-gather, lowered to canonical-device-order
    concatenation — exact, which is the whole bit-identity argument. On a
    backed mesh this is the one per-step wire collective (the plan's
    ``noc_acts`` decision prices it)."""
    return parts[0] if len(parts) == 1 else jnp.concatenate(parts, axis=axis)


def sharded_paged_attention(q, pk, pv, block_table, lengths, tp: int, *,
                            softcap: float = 0.0,
                            k_scale: Optional[jnp.ndarray] = None,
                            v_scale: Optional[jnp.ndarray] = None):
    """Paged decode attention over tp local KV shards, contexts gathered.

    q (B,1,H,D); pk/pv (P, page_size, KV, D); scales (P, KV). Each shard s
    runs the unmodified paged kernel on KV-head slice s and the matching
    contiguous q-head group — reading ONLY its local 1/tp of the pool —
    then head contexts are all-gathered for the full output projection.
    """
    from repro.kernels import ops as _ops   # deferred: keep import light

    if tp <= 1:
        kw = {} if k_scale is None else dict(k_scale=k_scale,
                                             v_scale=v_scale)
        return _ops.paged_attention(q, pk, pv, block_table, lengths,
                                    softcap=softcap, **kw)
    parts = []
    for s in range(tp):
        kw = {}
        if k_scale is not None:
            kw = dict(k_scale=shard_slice(k_scale, 1, s, tp),
                      v_scale=shard_slice(v_scale, 1, s, tp))
        parts.append(_ops.paged_attention(
            shard_slice(q, 2, s, tp),
            shard_slice(pk, 2, s, tp), shard_slice(pv, 2, s, tp),
            block_table, lengths, softcap=softcap, **kw))
    return all_gather(parts, axis=2)


def sharded_decode_attention(q, k_cache, v_cache, valid_mask, cfg, tp: int):
    """Contiguous-path decode attention (``layers.decode_attention``) over
    tp KV-head shards — the ring/local-window analogue of
    :func:`sharded_paged_attention`, so tp plans shard every attention
    kind, not just the paged pool."""
    from repro.models import layers

    if tp <= 1:
        return layers.decode_attention(q, k_cache, v_cache, valid_mask, cfg)
    parts = [layers.decode_attention(
        shard_slice(q, 2, s, tp),
        shard_slice(k_cache, 2, s, tp), shard_slice(v_cache, 2, s, tp),
        valid_mask, cfg) for s in range(tp)]
    return all_gather(parts, axis=2)


def sharded_expert_mlp(x, wg, wu, wd, *, act, cast, ep: int,
                       accum_dtype, compute_dtype):
    """The decode-time dense-all-experts MLP over ep expert shards.

    x (B,S,d); wg/wu (E,d,f); wd (E,f,d). Shard s computes the einsums for
    its contiguous E/ep expert slice only — the weights a real EP device
    holds — and the full-E activation is gathered along the (batch) expert
    axis for the caller's gate-weighted combine. Returns out (E,B,S,d).
    """
    E = wg.shape[0]
    assert E % ep == 0, (E, ep)
    chunks = []
    for s in range(ep):
        g = jnp.einsum("bsd,edf->ebsf", x, cast(shard_slice(wg, 0, s, ep)),
                       preferred_element_type=accum_dtype)
        u = jnp.einsum("bsd,edf->ebsf", x, cast(shard_slice(wu, 0, s, ep)),
                       preferred_element_type=accum_dtype)
        h = (act(g) * u).astype(compute_dtype)
        chunks.append(jnp.einsum("ebsf,efd->ebsd", h,
                                 cast(shard_slice(wd, 0, s, ep)),
                                 preferred_element_type=accum_dtype))
    return all_gather(chunks, axis=0)
