"""Apply a core.planner.ModelPlan to a whole model: PartitionSpecs for params,
optimizer state, batches and decode caches.

Rules (all divisibility-guarded — indivisible dims fall back to replication,
the planner's BROADCAST mode):

    param_rule 'fsdp_tp'  — TP over `model` (heads / d_ff / experts / vocab),
                            FSDP (ZeRO-3) over (`pod`,`data`) on the d_model dim
    param_rule 'ep_fsdp'  — same, but expert dim takes `model` (EP)
    param_rule 'tp_only'  — TP over `model`, replicated over data axes (decode)
    param_rule 'replicated' — pure DP
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

import jax
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.core.planner import ModelPlan
from repro.sharding.specs import axes_size, dp_axes, maybe


def _last_name(path) -> str:
    for e in reversed(path):
        if hasattr(e, "key"):
            return str(e.key)
    return ""


def _path_names(path):
    return [str(e.key) for e in path if hasattr(e, "key")]


class _Rules:
    def __init__(self, plan: ModelPlan, mesh_axes: Dict[str, int]):
        self.plan = plan
        self.ma = mesh_axes
        rule = plan.param_rule
        self.fsdp = (dp_axes(mesh_axes)
                     if rule in ("fsdp_tp", "ep_fsdp", "fsdp_dp") else None)
        self.tp = "model" if rule in ("fsdp_tp", "ep_fsdp", "tp_only") else None

    def f(self, dim: int):
        """FSDP axes if divisible."""
        return maybe(self.fsdp, dim, self.ma) if self.fsdp else None

    def t(self, dim: int):
        return maybe(self.tp, dim, self.ma) if self.tp else None


def param_spec(path, leaf, plan: ModelPlan, mesh_axes: Dict[str, int]) -> P:
    """PartitionSpec for one parameter leaf (shape includes any leading
    stacked-period dim, which is never sharded)."""
    r = _Rules(plan, mesh_axes)
    names = _path_names(path)
    name = names[-1] if names else ""
    shape = leaf.shape
    stacked = 1 if (names and names[0] == "blocks") else 0

    def pad(spec_entries):
        return P(*([None] * stacked + spec_entries))

    dims = shape[stacked:]
    nd = len(dims)

    if name == "embed":
        if nd == 3:  # (K, V, d)
            return pad([None, r.t(dims[1]) if plan.shard_vocab else None,
                        r.f(dims[2])])
        return pad([r.t(dims[0]) if plan.shard_vocab else None, r.f(dims[1])])
    if name == "lm_head":
        if nd == 3:  # (K, d, V)
            return pad([None, r.f(dims[1]),
                        r.t(dims[2]) if plan.shard_vocab else None])
        return pad([r.f(dims[0]), r.t(dims[1]) if plan.shard_vocab else None])

    if name in ("wq",):  # (d, H, D)
        return pad([r.f(dims[0]),
                    r.t(dims[1]) if plan.shard_heads else None, None])
    if name in ("wk", "wv"):  # (d, KV, D) — cross-attn uses H
        sh = plan.shard_kv_heads if "cross_attn" not in names else plan.shard_heads
        return pad([r.f(dims[0]), r.t(dims[1]) if sh else None, None])
    if name == "wo":  # (H, D, d)
        return pad([r.t(dims[0]) if plan.shard_heads else None, None,
                    r.f(dims[2])])
    if name in ("bq",):
        return pad([r.t(dims[0]) if plan.shard_heads else None, None])
    if name in ("bk", "bv"):
        return pad([r.t(dims[0]) if plan.shard_kv_heads else None, None])

    if name in ("wg", "wu", "w1"):
        if nd == 3:  # MoE experts (E, d, f)
            if plan.shard_experts:
                return pad([r.t(dims[0]), r.f(dims[1]), None])
            return pad([None, r.f(dims[1]),
                        r.t(dims[2]) if plan.shard_ffn else None])
        return pad([r.f(dims[0]), r.t(dims[1]) if plan.shard_ffn else None])
    if name in ("wd", "w2"):
        if nd == 3:  # (E, f, d)
            if plan.shard_experts:
                return pad([r.t(dims[0]), None, r.f(dims[2])])
            return pad([None, r.t(dims[1]) if plan.shard_ffn else None,
                        r.f(dims[2])])
        return pad([r.t(dims[0]) if plan.shard_ffn else None, r.f(dims[1])])
    if name == "router":  # (d, E)
        return pad([r.f(dims[0]), r.t(dims[1])])

    if name == "in_proj":  # ssm (d, e_all) — e_all rarely divisible; guard
        return pad([r.f(dims[0]), r.t(dims[1])])
    if name == "out_proj":  # (di|w, d)
        return pad([r.t(dims[0]), r.f(dims[1])])
    if name in ("in_x", "in_gate"):  # rglru (d, w)
        return pad([r.f(dims[0]), r.t(dims[1])])

    # conv weights, norms, gates, biases, scalars: replicate
    return pad([None] * nd)


def param_specs(abstract_params, plan: ModelPlan, mesh_axes: Dict[str, int]):
    return jax.tree_util.tree_map_with_path(
        lambda p, l: param_spec(p, l, plan, mesh_axes), abstract_params)


# ------------------------------------------------------------ activation hints
@dataclasses.dataclass(frozen=True)
class ShardingHints:
    """Activation sharding constraints — the planner's iact-NoC mode applied
    *inside* the program (paper: per-layer NoC reconfiguration).

    Without these, XLA's sharding propagation is free to re-shard activations
    onto the weight layout (batch-replicated, d_model-sharded), which inflates
    per-chip FLOPs by the dp factor and floods the ICI with resharding
    collective-permutes. The constraints pin activations to the planner's
    choice: INTERLEAVED_MC = batch over the dp axes.
    """
    mesh: Optional[object] = None  # jax.sharding.Mesh
    act: Optional[P] = None        # (B, S, d) hidden states
    logits: Optional[P] = None     # (B, C, V[,K]) loss-chunk logits
    model_size: int = 1            # size of the TP axis (for divisibility)
    tp: bool = True                # TP constraints enabled (param_rule != repl)

    def _named(self, spec: P):
        from jax.sharding import NamedSharding
        return NamedSharding(self.mesh, spec)

    def constrain_act(self, x):
        if self.act is not None and x.ndim >= len(self.act):
            return jax.lax.with_sharding_constraint(x, self._named(self.act))
        return x

    def constrain_tokens(self, x, tp_dim: Optional[int] = None,
                         tp_check: Optional[Tuple[int, ...]] = None,
                         batch_dim: int = 0, tp_candidates=None,
                         widen_batch: bool = False):
        """Pin an intra-block intermediate: batch over dp; optionally one dim
        over the model axis (the Megatron/TP pattern) when every size in
        ``tp_check`` divides the model axis. ``tp_candidates`` is a list of
        (dim, sizes) tried in order — first divisible wins (MoE: EP over the
        expert dim if it divides, else TP over d_ff).

        This is the per-tensor HM-NoC mode decision (paper Fig. 9) applied
        inside the layer — without it XLA propagation re-shards projection
        outputs onto indivisible feature dims (sliver collective-permutes).
        """
        if self.act is None:
            return x
        entries: list = [None] * x.ndim
        entries[batch_dim] = self.act[0]
        cands = tp_candidates if tp_candidates is not None else (
            [(tp_dim, tp_check if tp_check is not None
              else (x.shape[tp_dim],))] if tp_dim is not None else [])
        placed = False
        if self.tp and self.model_size > 1:
            for dim, sizes in cands:
                if all(s % self.model_size == 0 for s in sizes):
                    entries[dim % x.ndim] = "model"
                    placed = True
                    break
        if widen_batch and not placed and self.model_size > 1:
            # no TP dim divides: spread the batch over the model axis too (the
            # planner's unicast fall-back — paper Fig. 9b) when it divides
            b = self.act[0]
            if b is not None and "model" not in (
                    b if isinstance(b, tuple) else (b,)):
                axes = (b if isinstance(b, tuple) else (b,)) + ("model",)
                per = 1
                for a in axes:
                    per *= self.model_size if a == "model" else 1
                if x.shape[batch_dim] % (self._axes_size(axes)) == 0:
                    entries[batch_dim] = axes
        return jax.lax.with_sharding_constraint(x, self._named(P(*entries)))

    def _axes_size(self, axes) -> int:
        from repro.sharding.specs import mesh_axis_sizes
        ma = mesh_axis_sizes(self.mesh)
        n = 1
        for a in axes:
            n *= ma[a]
        return n

    def constrain_logits(self, x):
        if self.logits is None:
            return x
        spec = self.logits
        if x.ndim != len(spec):    # musicgen (B,C,K,V): insert codebook None
            entries = list(spec) + [None] * (x.ndim - len(spec))
            entries[-1], entries[len(spec) - 1] = entries[len(spec) - 1], None
            spec = P(*entries)
        return jax.lax.with_sharding_constraint(x, self._named(spec))


def act_batch_axes(plan: ModelPlan, mesh_axes: Dict[str, int],
                   batch_size: int):
    """Mesh axes for the token/batch dim, honoring the plan's iact mode with
    divisibility fall-backs: 'all' → dp+model → dp → None."""
    dp = dp_axes(mesh_axes)
    prefs = ([tuple(dp) + ("model",), dp] if plan.act_axes == "all"
             else [dp])
    for axes in prefs:
        got = maybe(axes, batch_size, mesh_axes)
        if got is not None:
            return got
    return None


def make_hints(plan: ModelPlan, mesh, batch_size: int) -> ShardingHints:
    from repro.sharding.specs import mesh_axis_sizes
    mesh_axes = mesh_axis_sizes(mesh)
    b_ax = act_batch_axes(plan, mesh_axes, batch_size)
    act = P(b_ax, None, None)
    v_ax = "model" if plan.shard_vocab else None
    logits = P(b_ax, None, v_ax)
    return ShardingHints(mesh=mesh, act=act, logits=logits,
                         model_size=mesh_axes.get("model", 1),
                         tp=plan.param_rule in ("fsdp_tp", "ep_fsdp",
                                                "tp_only"))


# ----------------------------------------------------------------- batch/cache
def batch_spec(abstract_batch, plan: ModelPlan, mesh_axes: Dict[str, int]):
    def spec(path, leaf):
        lead = act_batch_axes(plan, mesh_axes, leaf.shape[0])
        return P(*([lead] + [None] * (leaf.ndim - 1)))
    return jax.tree_util.tree_map_with_path(spec, abstract_batch)


def cache_spec(abstract_cache, plan: ModelPlan, mesh_axes: Dict[str, int]):
    """KV caches: batch per the plan's iact mode; heads over model if
    divisible, else the cache *sequence* dim over model (flash-decode style) —
    the planner's psum-NoC decision. Recurrent states: batch-sharded."""
    dp = dp_axes(mesh_axes)

    def spec(path, leaf):
        names = _path_names(path)
        name = names[-1] if names else ""
        stacked = 1 if (names and names[0] == "blocks") else 0
        dims = leaf.shape[stacked:]

        def pad(entries):
            return P(*([None] * stacked + entries))

        b_ax = act_batch_axes(plan, mesh_axes, dims[0])
        if name in ("k", "v"):          # (B, T, KV, D)
            if plan.shard_kv_heads:
                return pad([b_ax, None, maybe("model", dims[2], mesh_axes),
                            None])
            t_ax = maybe("model", dims[1], mesh_axes)
            if b_ax is None and t_ax is not None:
                # batch unshardable (long_500k B=1): spread seq over dp too
                t_all = maybe(tuple(dp) + ("model",), dims[1], mesh_axes)
                if t_all is not None:
                    t_ax = t_all
            return pad([b_ax, t_ax, None, None])
        # ssd state, conv windows, rglru h: batch-leading
        return pad([b_ax] + [None] * (len(dims) - 1))

    return jax.tree_util.tree_map_with_path(spec, abstract_cache)


def replicated_spec(tree):
    return jax.tree.map(lambda l: P(), tree)
