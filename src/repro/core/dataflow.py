"""Row-stationary tiling on the TPU memory hierarchy (paper §II ↔ DESIGN.md §2).

The paper keeps a (C0·M0 × S) weight matrix stationary in each PE's SPad and
streams iact windows past it. The TPU analogue: keep a (bk × bn) weight tile
stationary in VMEM, stream (bm × bk) activation tiles from HBM. This module
computes tile shapes that (a) fit the VMEM budget (the SPad-fit constraint of
Table III) and (b) align to MXU/VREG geometry (multiples of 8 sublanes × 128
lanes; matmul dims multiples of 128 where possible).
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

VMEM_BYTES = 16 * 1024 * 1024      # ~16 MiB usable per TensorCore (v5e class)
LANE = 128
SUBLANE = 8


def _round_down(x: int, m: int) -> int:
    return max((x // m) * m, m) if x >= m else x


@dataclasses.dataclass(frozen=True)
class MatmulTiling:
    bm: int      # activation rows per tile
    bk: int      # reduction tile
    bn: int      # output cols per tile (weight-stationary dim)
    dtype_bytes: int = 2

    @property
    def vmem_bytes(self) -> int:
        # x tile + w tile + fp32 accumulator tile (the psum-SPad analogue)
        return (self.bm * self.bk + self.bk * self.bn) * self.dtype_bytes + \
            self.bm * self.bn * 4

    def fits(self, budget: int = VMEM_BYTES) -> bool:
        # double-buffered streams (×2 on the streamed operands)
        return (2 * self.bm * self.bk * self.dtype_bytes +
                2 * self.bk * self.bn * self.dtype_bytes +
                self.bm * self.bn * 4) <= budget


def rs_matmul_tiling(M: int, K: int, N: int, dtype_bytes: int = 2,
                     budget: int = VMEM_BYTES) -> MatmulTiling:
    """Pick (bm, bk, bn) for an (M×K)·(K×N) matmul, weight-stationary.

    Strategy mirrors the RS dataflow: maximize the stationary weight tile
    (reuse ∝ bm per resident weight), then grow bm until the budget binds.
    """
    bn = _round_down(min(N, 512), LANE)
    bk = _round_down(min(K, 1024), LANE)
    bm = _round_down(min(M, 512), SUBLANE)
    t = MatmulTiling(bm, bk, bn, dtype_bytes)
    while not t.fits(budget) and t.bk > LANE:
        t = MatmulTiling(t.bm, t.bk // 2, t.bn, dtype_bytes)
    while not t.fits(budget) and t.bn > LANE:
        t = MatmulTiling(t.bm, t.bk, t.bn // 2, dtype_bytes)
    while not t.fits(budget) and t.bm > SUBLANE:
        t = MatmulTiling(t.bm // 2, t.bk, t.bn, dtype_bytes)
    assert t.fits(budget), (M, K, N, t)
    return t


# --------------------------------------------------------- decode (skinny-M)
# Batch-1 decode is the paper's headline regime (Table VI): M = batch·seq rows
# of activations against a large stationary weight. At M ≤ GEMV_M_MAX the MXU
# m-dimension is mostly padding and the win comes from skipping weight blocks,
# so ops.py routes these shapes to the bcsc_gemv kernel (one m-tile, fp32 VMEM
# scratch accumulator) instead of the revisit-accumulate GEMM kernel.
GEMV_M_MAX = 8          # decode-shaped row counts at/below this take the GEMV path
GEMV_BM = SUBLANE       # the single m-tile of the GEMV kernel (rows padded to 8)


def matmul_path(M: int) -> str:
    """Dispatch rule: 'gemv' for decode-shaped (skinny) M, else 'gemm'."""
    return "gemv" if M <= GEMV_M_MAX else "gemm"


def bcsc_tile_m(M: int) -> int:
    """m-tile for the BCSC kernels: next pow2 ≥ M, clamped to [SUBLANE, 512].

    The single source of truth for the bm heuristic (previously duplicated in
    ops.bcsc_matmul). GEMV shapes get exactly GEMV_BM; GEMM shapes grow with M
    so the per-block dot amortizes the index-vector walk.
    """
    if matmul_path(M) == "gemv":
        return GEMV_BM
    return min(512, max(SUBLANE, 1 << (max(M, 1) - 1).bit_length()))


# ------------------------------------------------------------- MLP dispatch
# The fused bcsc_mlp megakernel (kernels/bcsc_mlp.py) holds the whole
# (bm × d_ff) hidden activation in VMEM scratch — the SPad-residency condition
# of the paper's compressed-domain processing. The rule mirrors Table III:
# fuse when the scratch fits the budget, fall back to the two-call path when
# it does not, and skip packing entirely when the block density is so high
# that structural skipping cannot beat the dense MXU stream.
FUSED_MLP_VMEM_BUDGET = VMEM_BYTES // 2   # scratch share of VMEM (streams keep the rest)
DENSE_BLOCK_DENSITY = 0.85                # ≥ this, BCSC walk loses to dense stream
# Payload blocks streamed per megakernel grid step (one contiguous DMA, C
# unrolled MACs) — the SPad-line streaming analogue. Packs are padded to a
# multiple of this (serve.sparse) so every segment divides evenly.
BCSC_CHUNK = 8


def fused_mlp_scratch_bytes(bm: int, d_ff: int, n_out: int,
                            gated: bool = True) -> int:
    """fp32 VMEM scratch of the megakernel: hidden (×2 gated) + out accum."""
    n_hidden = 2 if gated else 1
    return 4 * bm * (n_hidden * d_ff + n_out)


def mlp_path(M: int, d_ff: int, n_out: int, *, gated: bool = True,
             density: float = None) -> str:
    """Dispatch rule for a BCSC-packed MLP: 'fused' | 'two_call' | 'dense'.

    'dense'   — block density too high for structural skipping to pay
                (pack-time callers leave the weight dense).
    'fused'   — the megakernel's hidden-activation scratch fits VMEM at the
                bm implied by M (always true for decode-shaped M).
    'two_call'— per-projection kernels with the hidden in HBM (large-M
                prefill/training shapes where the scratch would not fit).
    """
    if density is not None and density >= DENSE_BLOCK_DENSITY:
        return "dense"
    bm = bcsc_tile_m(M)
    if fused_mlp_scratch_bytes(bm, d_ff, n_out, gated) <= FUSED_MLP_VMEM_BUDGET:
        return "fused"
    return "two_call"


# ------------------------------------------------------ paged KV dispatch
# The KV cache is the activation-over-time analogue of the paper's weight
# streams, and the block table is its CSC address vector: a dense
# (rows × cache_len) slot provisions for the worst case (the v1 mistake the
# hierarchical mesh fixes), while fixed-size pages + per-request block tables
# allocate exactly ceil(len / page_size) pages as each sequence grows
# (serve/paging.py, kernels/paged_attention.py). The rule below mirrors
# mlp_path: dispatch 'paged' only when the indirection actually saves HBM at
# the expected occupancy; short contexts and near-full slots keep the
# contiguous ring/dense path (no block-table walk, no page-granularity waste).
PAGE_SIZE = 64                  # tokens per KV page (lane-friendly multiple)
PAGED_OCCUPANCY_MAX = 0.75      # above this mean occupancy dense wins (waste
                                # < page granularity; indirection pays nothing);
                                # exactly at the threshold still pages


def pages_for(length: int, page_size: int = PAGE_SIZE) -> int:
    """Pages a sequence of ``length`` tokens occupies: ceil(len / page_size)."""
    return -(-max(int(length), 0) // page_size)


def paged_kv_tokens(lengths, page_size: int = PAGE_SIZE) -> int:
    """Token-slots resident under paging: Σ ceil(len/ps)·ps over rows."""
    return sum(pages_for(n, page_size) * page_size for n in lengths)


def dense_kv_tokens(rows: int, cache_len: int) -> int:
    """Token-slots resident under the dense per-slot cache: rows · cache_len."""
    return rows * cache_len


def attn_path(cache_len: int, mean_len: float,
              page_size: int = PAGE_SIZE) -> str:
    """Dispatch rule for decode attention: 'paged' | 'contiguous'.

    'paged' when the expected resident tokens (mean length rounded up to page
    granularity) stay below PAGED_OCCUPANCY_MAX of the dense slot — the
    occupancy regime where block-table indirection converts stranded HBM into
    extra batch rows. 'contiguous' otherwise, and always for caches shorter
    than two pages (indirection overhead with nothing to reclaim).
    """
    if cache_len < 2 * page_size:
        return "contiguous"
    expected = pages_for(mean_len, page_size) * page_size
    if expected <= PAGED_OCCUPANCY_MAX * cache_len:
        return "paged"
    return "contiguous"


# ------------------------------------------------- page-granular KV quant
# decode_regimes (benchmarks/sparse_decode.py) measured the large-batch
# decode bound to be KV-cache streaming — the whole resident cache crosses
# HBM every step while weights amortize over the rows. int8 KV pages halve
# that stream (the paper's keep-it-compressed move applied to activations-
# over-time); at small batch the cache share is tiny and the dequant +
# per-page-scale bookkeeping buys nothing, so the rule mirrors mlp_path:
# quantize only in the regime the measurement says is cache-bound.
KV_QUANT_MIN_ROWS = 16          # >= this many decode rows, cache stream wins
KV_QUANT_DTYPES = ("fp", "int8")


def kv_quant_path(rows: int, cache_len: int,
                  page_size: int = PAGE_SIZE) -> str:
    """Dispatch rule for the paged KV store dtype: 'int8' | 'fp'.

    'int8' when the decode batch is wide enough that the KV stream dominates
    the step (KV_QUANT_MIN_ROWS, the decode_regimes finding) AND the cache is
    long enough to page at all (a sub-two-page cache never pages, so it never
    quantizes either — the scale tables would outweigh the payload win).
    """
    if cache_len < 2 * page_size:
        return "fp"
    return "int8" if rows >= KV_QUANT_MIN_ROWS else "fp"


def kv_dtype_bytes(kv_quant: str) -> int:
    """Payload bytes per KV element under a quant mode ('fp' = bf16)."""
    assert kv_quant in KV_QUANT_DTYPES, kv_quant
    return 1 if kv_quant == "int8" else 2


def paged_kv_bytes(n_pages: int, page_size: int, kv_heads: int,
                   head_dim: int, n_layers: int, kv_quant: str = "fp") -> int:
    """HBM bytes of an ``n_pages`` K+V pool across ``n_layers`` global
    layers, including the per-(page, kv-head) fp32 scale tables the int8
    format adds (they ride the block table: 2 scales × 4 B per page per
    kv-head per layer)."""
    payload = 2 * n_pages * page_size * kv_heads * head_dim \
        * kv_dtype_bytes(kv_quant) * n_layers
    scales = 2 * n_pages * kv_heads * 4 * n_layers if kv_quant == "int8" \
        else 0
    return payload + scales


def prefill_kv_transient_bytes(batch: int, seq: int, kv_heads: int,
                               head_dim: int, n_global_layers: int,
                               dtype_bytes: int = 2) -> int:
    """Largest global-attention K+V buffer a batched prefill materializes
    per layer-scan step, summed over global layers: (batch, seq, KV, D) × 2.

    With ``seq = cache_len`` this is the PR 3 scatter path's dense transient
    (every row padded to the worst case before the page scatter); with
    ``seq = tier`` it is the page-native path's only buffer — the projection
    output itself, which exists in either path. The difference is the
    allocation the paged prefill-write refactor deletes, and the byte gate
    scripts/perf_guard.py enforces.
    """
    return 2 * batch * seq * kv_heads * head_dim * dtype_bytes \
        * n_global_layers


def spad_fit_report(weight_count: int, sparsity: float,
                    tiling: MatmulTiling) -> dict:
    """Table-III analogue: do the (compressed) resident weights fit the budget?"""
    nominal = weight_count * tiling.dtype_bytes
    compressed = int(nominal * (1 - sparsity) * 1.5)  # 12b/8b CSC overhead ratio
    resident = tiling.bk * tiling.bn * tiling.dtype_bytes
    return {
        "nominal_bytes": nominal,
        "compressed_bytes": compressed,
        "resident_tile_bytes": resident,
        "fits_vmem": resident <= VMEM_BYTES,
    }
