"""ServePlan — every serving dispatch decision resolved ONCE (ISSUE 5).

Eyeriss v2's flexibility argument (paper §III) is that the *configuration* of
the array — NoC mode, dataflow, sparse vs dense path — is picked per layer
ahead of execution from the layer's shape and sparsity, with Eyexam (Appendix
A) as the analysis justifying each choice. The software analog had grown the
opposite way: four independent dispatch rules in ``core.dataflow``
(``matmul_path``, ``mlp_path``, ``attn_path``, ``kv_quant_path``) were
consulted ad hoc at call sites, and their inputs threaded through two
divergent serving front ends as overlapping constructor kwargs.

This module is the compile step. ``plan_serve(cfg, ...)`` resolves every
decision the serving system makes — matmul GEMV/GEMM route + tile sizes, MLP
fused/two_call + ``BCSC_CHUNK``, attention paged/contiguous + ``PAGE_SIZE``
+ page-pool size, KV quant mode, the prefill tier schedule, and slot/row
counts — into one frozen :class:`ServePlan`, each decision carrying its
Eyexam-style bound rationale (``plan.explain()`` renders the per-decision
roofline the way ``benchmarks/sparse_decode.py::mlp_bound_analysis`` does).

Execution then *reads* the plan instead of re-deriving the rules:

* engines (``serve.engine.DecodeEngine``, ``serve.scheduler.
  ContinuousBatchingScheduler``) take a ``plan`` instead of kwarg piles and
  activate it (:func:`activate`) around their jitted programs;
* ``models.layers.mlp`` and ``kernels.ops`` consult the active plan through
  :func:`route_mlp` / :func:`route_matmul` / :func:`tile_m`, falling back to
  the ``core.dataflow`` rules only when no plan is active (bare
  ``decoding.prefill``/``serve_step`` calls outside a serving engine).

The dispatch thresholds stored in the plan are resolved from the SAME
``core.dataflow`` rules, so plan-driven and legacy dispatch are bit-identical
by construction (asserted across the config matrix in tests/test_plan.py).
Legacy engine kwargs stay as thin shims (:func:`plan_for_engine`,
:func:`plan_for_scheduler`) that build a single-decision plan and emit a
``DeprecationWarning`` when reached implicitly.

CLI::

    PYTHONPATH=src python -m repro.core.plan --cfg gemma2_2b --hbm 2GiB
"""
from __future__ import annotations

import contextlib
import contextvars
import dataclasses
import json
from typing import Dict, Optional, Tuple

from repro.core import dataflow, eyexam, hmmesh

# Bounds a decision may cite — the three-term serving roofline (Eyexam's
# compute / memory split plus the occupancy axis paging trades on), plus
# the collective axis the mesh resolution stage (ISSUE 10) trades against
# HBM: bytes crossing the device mesh per emitted token.
BOUNDS = ("compute", "HBM", "occupancy", "collective")

# Analytic-model constants shared with benchmarks/sparse_decode.py (moved
# here so the plan's MLP rationale and mlp_bound_analysis are the same
# numbers by construction, not by copy).
BCSC_OVERHEAD = 1.02     # index-vector bytes per payload byte
KERNEL_LAUNCH_S = 2e-6   # per-kernel dispatch overhead (TPU-class estimate)

# Canonical snapshot inputs for the golden-plan drift gate
# (scripts/golden_plans.json; perf_guard check `plan-snapshot-stable`).
SNAPSHOT_CONFIGS = ("gemma2-2b", "mixtral-8x7b", "mamba2-130m")
SNAPSHOT_BUDGET_BYTES = 2 << 30          # 2 GiB
SNAPSHOT_BATCH = 8
SNAPSHOT_LEN_DIST = {"mean": 1024, "max": 2048}
SNAPSHOT_SPARSITY = {"sparsity": 0.75, "packing_efficiency": 0.93}

# Canonical sharded-snapshot inputs (ISSUE 10): the MoE seed configs at two
# mesh shapes each, recorded under the "__sharded__" key of
# scripts/golden_plans.json and gated by perf_guard
# `sharded-plan-snapshot-stable`.
SHARDED_SNAPSHOT_CONFIGS = ("mixtral-8x7b", "llama4-maverick-400b-a17b")
SHARDED_SNAPSHOT_MESHES = ("tp=2,ep=4", "tp=4,ep=2")


# ---------------------------------------------------------------- decisions
@dataclasses.dataclass(frozen=True)
class Decision:
    """One resolved dispatch decision with its Eyexam-style rationale.

    ``bound`` names the term of the serving roofline that justifies the
    choice (one of :data:`BOUNDS`); ``numbers`` carries the model inputs the
    rationale is computed from, so ``explain()`` can render the per-decision
    roofline and the snapshot gate can detect silent drift in the *reasons*,
    not just the choices.
    """
    name: str
    choice: str
    bound: str
    why: str
    numbers: Dict = dataclasses.field(default_factory=dict)

    def __post_init__(self):
        assert self.bound in BOUNDS, (self.name, self.bound)


# -------------------------------------------------------------- MLP roofline
def mlp_roofline(cfg, sparsity: float = 0.75,
                 packing_efficiency: float = 0.93, bm: int = 8) -> Dict:
    """Eyexam-style MLP bound model (paper Appendix A; DESIGN.md §9).

    The single source of the numbers behind the plan's MLP decision AND
    ``benchmarks/sparse_decode.py::mlp_bound_analysis`` (which delegates
    here): per decode step the MLP time is

        t = t_weight_stream + t_hidden_roundtrip + n_launch · t_launch

    Sparsity only shrinks the first term; the two-call path adds the second
    (the (bm × d_ff) hidden crosses HBM four times) and triples the third,
    while the fused megakernel removes both added terms — the bound returns
    to the weight stream, the only term sparsity can shrink.
    """
    d, ff = cfg.d_model, cfg.d_ff
    ups = 2 if cfg.mlp_gated else 1
    w_dense = (ups * d * ff + ff * d) * 2            # bf16
    w_real = w_dense * (1 - sparsity) * BCSC_OVERHEAD
    w_padded = w_real / max(packing_efficiency, 1e-6)
    hidden_rt = bm * ff * (ups * 4 + (2 * 4 if ups == 2 else 0) + 2 + 2)
    xio = bm * d * (2 + 4)

    def t(bytes_, launches):
        return bytes_ / eyexam.HBM_BW + launches * KERNEL_LAUNCH_S

    t_dense = t(w_dense + hidden_rt + xio, ups + 1)
    t_two = t(w_padded + hidden_rt + xio, ups + 1)
    t_fused = t(w_real + xio, 1)
    return {
        "sparsity": sparsity, "layers": cfg.num_layers,
        "per_layer_bytes": {
            "weights_dense": w_dense,
            "weights_sparse_real": w_real,
            "weights_sparse_padded": w_padded,
            "hidden_roundtrip": hidden_rt,
            "act_in_out": xio,
        },
        "per_layer_time_s": {
            "dense": t_dense,
            "two_call_sparse": t_two,
            "fused_sparse": t_fused,
        },
        "speedup": {
            "two_call_vs_dense": t_dense / t_two,
            "fused_vs_dense": t_dense / t_fused,
            "fused_vs_two_call": t_two / t_fused,
        },
        "bound": "weight-stream (the term sparsity shrinks) once the hidden "
                 "round-trip and extra launches are fused away",
        "kernel_launch_s": KERNEL_LAUNCH_S,
    }


def _fused_m_max(d_ff: int, n_out: int, gated: bool) -> Optional[int]:
    """Largest M routed 'fused' by ``dataflow.mlp_path`` — the crossover
    resolved once. ``bcsc_tile_m`` is monotone in M and clamps at 512, so
    scanning the pow-2 bm ladder is exact: returns None when even bm=512
    fits (fused at every M), 0 when even bm=8 does not (never fused)."""
    best = 0
    bm = dataflow.SUBLANE
    while bm <= 512:
        if dataflow.fused_mlp_scratch_bytes(bm, d_ff, n_out, gated) \
                <= dataflow.FUSED_MLP_VMEM_BUDGET:
            best = bm
        bm *= 2
    return None if best == 512 else best


# ------------------------------------------------------------------ ServePlan
@dataclasses.dataclass(frozen=True)
class ServePlan:
    """Every serving dispatch decision, resolved once and read per call.

    The threshold fields (``gemv_m_max``, ``mlp_fused_m_max`` …) are the
    ``core.dataflow`` rules evaluated ahead of time; the route queries
    (:meth:`matmul_route`, :meth:`mlp_route`, :meth:`tier`) are table
    lookups against them — bit-identical to the legacy per-call dispatch.
    """
    arch: str
    # capacity
    rows: int
    cache_len: int
    sync_every: int
    # matmul (GEMV/GEMM crossover + tile sizes)
    gemv_m_max: int
    gemv_bm: int
    # MLP (fused/two_call crossover + payload chunking)
    mlp_fused_m_max: Optional[int]       # None = fused at every M; 0 = never
    mlp_pack_dense_density: float        # >= this block density: don't pack
    bcsc_chunk: int
    # attention (paged/contiguous + page geometry + pool size)
    attn_path: str
    page_size: int
    max_pages: int
    num_pages: int
    share_prefix: bool
    # KV store dtype
    kv_quant: str
    # prefill admission schedule
    prefill_exact: bool                  # recurrent archs: exact-length tiers
    prefill_tiers: Tuple[int, ...]
    # overload degradation ladder (serve.guard walks it under measured pool
    # pressure): authorized rungs in escalation order, and the pool size the
    # int8 rung grows to (same HBM footprint, int8 payload)
    degrade: Tuple[str, ...] = ()
    num_pages_int8: int = 0
    # speculative decode (ISSUE 9): draft depth k per round (0 = disabled);
    # >0 only on all-global fp paged plans with one codebook, where the
    # flattened k-position verifier is bit-exact under greedy sampling
    spec_k: int = 0
    # mesh resolution (ISSUE 10): tensor-parallel degree (KV heads sliced
    # over tp, weights broadcast, head contexts all-gathered) and
    # expert-parallel degree (MoE expert axis sliced over ep). 1/1 = the
    # single-device plan; the sharded page pool holds num_pages pages per
    # device, each carrying only the local 1/tp KV-head slice.
    tp: int = 1
    ep: int = 1
    # rationale records (one per decision; not part of dispatch identity)
    decisions: Tuple[Decision, ...] = ()

    # ------------------------------------------------------- route queries
    def matmul_route(self, M: int) -> str:
        """'gemv' for decode-shaped (skinny) M, else 'gemm' — the resolved
        form of ``dataflow.matmul_path``."""
        return "gemv" if M <= self.gemv_m_max else "gemm"

    def bcsc_bm(self, M: int) -> int:
        """m-tile for the BCSC kernels at M rows (``dataflow.bcsc_tile_m``
        against the plan's resolved GEMV crossover)."""
        if self.matmul_route(M) == "gemv":
            return self.gemv_bm
        return min(512, max(dataflow.SUBLANE,
                            1 << (max(M, 1) - 1).bit_length()))

    def mlp_route(self, M: int) -> str:
        """'fused' | 'two_call' for a packed MLP at M rows — the resolved
        VMEM-scratch-fit crossover of ``dataflow.mlp_path``. (The 'dense'
        arm is a pack-time decision — ``serve.sparse`` judges it per weight
        against ``mlp_pack_dense_density`` — so it never reaches the
        per-call route.)"""
        if self.mlp_fused_m_max is None or M <= self.mlp_fused_m_max:
            return "fused"
        return "two_call"

    def tier(self, plen: int) -> int:
        """Prefill admission tier for a prompt of ``plen`` tokens — the
        resolved form of ``serve.engine.length_tier``."""
        if self.prefill_exact:
            return plen
        for t in self.prefill_tiers:
            if t >= plen:
                return t
        return self.cache_len

    @property
    def paged(self) -> bool:
        return self.attn_path == "paged"

    @property
    def sharded(self) -> bool:
        """True when the mesh resolution stage chose a non-trivial mesh."""
        return self.tp > 1 or self.ep > 1

    @property
    def mesh_devices(self) -> int:
        """Devices the resolved mesh spans (1 for single-device plans)."""
        return self.tp * self.ep

    # ------------------------------------------------------- serialization
    def as_dict(self) -> Dict:
        return dataclasses.asdict(self)

    def to_json(self, indent: int = 2) -> str:
        return json.dumps(self.as_dict(), indent=indent, sort_keys=True)

    # ------------------------------------------------------------- context
    def activate(self):
        """Context manager making this plan the active dispatch source for
        ``layers.mlp`` / ``kernels.ops`` tracing (see :func:`activate`)."""
        return activate(self)

    # -------------------------------------------------------------- report
    def explain(self, drift=None) -> str:
        """Render the per-decision rationale — the Eyexam-style report.

        Every decision names its bound (compute/HBM/occupancy) and prints
        the roofline numbers it was resolved from; the MLP entry carries the
        same per-layer time model as
        ``benchmarks/sparse_decode.py::mlp_bound_analysis``.

        Pass a ``serve.telemetry.DriftReport`` (Eyexam-at-runtime: measured
        proxies vs these decisions' numbers) as ``drift`` to append each
        decision's measured-vs-predicted verdicts — CONFIRMED lines mark the
        decisions whose runtime evidence diverged past the threshold.
        """
        mesh = f", mesh=tp{self.tp}xep{self.ep}" if self.sharded else ""
        lines = [
            f"ServePlan — {self.arch}  "
            f"(rows={self.rows}, cache_len={self.cache_len}, "
            f"sync_every={self.sync_every}{mesh})",
        ]
        for d in self.decisions:
            lines.append(f"  {d.name:<9s}: {d.choice:<28s} [bound: {d.bound}]")
            lines.append(f"      {d.why}")
            if drift is not None:
                for f in drift.for_decision(d.name):
                    lines.append(f"      drift: {f.render()}")
            if d.name == "mlp" and "per_layer_time_s" in d.numbers:
                t = d.numbers["per_layer_time_s"]
                s = d.numbers["speedup"]
                lines.append(
                    "      per-layer roofline: "
                    f"dense {t['dense']:.3e}s / "
                    f"two-call {t['two_call_sparse']:.3e}s / "
                    f"fused {t['fused_sparse']:.3e}s "
                    f"(fused x{s['fused_vs_dense']:.2f} vs dense, "
                    f"x{s['fused_vs_two_call']:.2f} vs two-call)")
            elif d.numbers:
                kv = ", ".join(
                    f"{k}={v:.4g}" if isinstance(v, float) else f"{k}={v}"
                    for k, v in d.numbers.items()
                    if isinstance(v, (int, float)))
                if kv:
                    lines.append(f"      {kv}")
        if drift is not None:
            lines.append(
                f"  drift: {len(drift.confirmed)} CONFIRMED / "
                f"{len(drift.findings)} compared over {drift.windows} "
                "measured window(s)")
        return "\n".join(lines)


# ----------------------------------------------------------- active context
_ACTIVE: contextvars.ContextVar = contextvars.ContextVar("serve_plan",
                                                         default=None)


def active_plan() -> Optional[ServePlan]:
    """The plan currently activated by a serving engine, or None."""
    return _ACTIVE.get()


@contextlib.contextmanager
def activate(plan: ServePlan):
    """Make ``plan`` the dispatch source for code traced inside the block."""
    token = _ACTIVE.set(plan)
    try:
        yield plan
    finally:
        _ACTIVE.reset(token)


def route_matmul(M: int) -> str:
    """Plan-first matmul dispatch: the active plan's resolved crossover, or
    the ``core.dataflow`` rule when no plan is active."""
    pl = active_plan()
    return pl.matmul_route(M) if pl is not None else dataflow.matmul_path(M)


def tile_m(M: int) -> int:
    pl = active_plan()
    return pl.bcsc_bm(M) if pl is not None else dataflow.bcsc_tile_m(M)


def gemv_bm() -> int:
    pl = active_plan()
    return pl.gemv_bm if pl is not None else dataflow.GEMV_BM


def route_mlp(M: int, d_ff: int, n_out: int, gated: bool = True) -> str:
    """Plan-first MLP dispatch ('fused' | 'two_call')."""
    pl = active_plan()
    if pl is not None:
        return pl.mlp_route(M)
    return dataflow.mlp_path(M, d_ff, n_out, gated=gated)


def bcsc_chunk() -> int:
    """Plan-first BCSC payload chunk stride (pack-time padding unit)."""
    pl = active_plan()
    return pl.bcsc_chunk if pl is not None else dataflow.BCSC_CHUNK


def page_size_default(cache_len: int) -> int:
    """Plan-first KV page size (``dataflow.PAGE_SIZE`` clamped to the
    cache) — the one place the constant becomes a runtime default."""
    pl = active_plan()
    if pl is not None:
        return pl.page_size
    return min(dataflow.PAGE_SIZE, cache_len)


# ------------------------------------------------------------------ resolve
def _normalize_len_dist(expected_len_dist) -> Tuple[float, int]:
    """(mean, max) from a {'mean','max'} dict or an iterable of lengths."""
    if isinstance(expected_len_dist, dict):
        mx = int(expected_len_dist["max"])
        mean = float(expected_len_dist.get("mean", mx / 2))
        return mean, mx
    lens = [int(x) for x in expected_len_dist]
    if not lens:
        raise ValueError("expected_len_dist must be non-empty")
    return sum(lens) / len(lens), max(lens)


def _pow2_tiers(cache_len: int) -> Tuple[int, ...]:
    """The admission tier ladder: powers of two clamped at cache_len —
    exactly the buckets ``length_tier`` produces, enumerated once."""
    tiers = []
    t = 1
    while t < cache_len:
        tiers.append(t)
        t <<= 1
    tiers.append(cache_len)
    return tuple(tiers)


# speculative decode roofline knobs (ISSUE 9): assumed per-candidate
# acceptance of the self-drafting bigram head, the draft depths considered,
# and the modeled gain below which speculation stays off
SPEC_ALPHA = 0.8
SPEC_K_CANDIDATES = (2, 3, 4, 6, 8)
SPEC_MIN_GAIN = 1.5


def parse_mesh(mesh) -> Tuple[int, int]:
    """Parse a mesh request into ``(tp, ep)``.

    Accepts ``None``/``""`` (no mesh → ``(1, 1)``), a ``(tp, ep)`` pair, a
    mapping ``{"tp": 2, "ep": 4}``, or the CLI string form ``"tp=2,ep=4"``
    (axes optional and order-free, so ``"ep=4"`` means ``tp=1, ep=4``).
    """
    if mesh is None or mesh == "" or mesh == {}:
        return 1, 1
    if isinstance(mesh, str):
        axes = {"tp": 1, "ep": 1}
        for part in mesh.split(","):
            name, sep, val = part.strip().partition("=")
            if name not in axes or not sep or not val.strip().isdigit():
                raise ValueError(
                    f"bad mesh spec {mesh!r}: expected 'tp=N,ep=M' "
                    f"(got segment {part.strip()!r})")
            axes[name.strip()] = int(val)
        tp, ep = axes["tp"], axes["ep"]
    elif isinstance(mesh, dict):
        unknown = sorted(set(mesh) - {"tp", "ep"})
        if unknown:
            raise ValueError(f"unknown mesh axes {unknown}; the serving "
                             "mesh has axes 'tp' and 'ep'")
        tp, ep = int(mesh.get("tp", 1)), int(mesh.get("ep", 1))
    else:
        tp, ep = (int(mesh[0]), int(mesh[1]))
    if tp < 1 or ep < 1:
        raise ValueError(f"mesh axes must be >= 1, got tp={tp} ep={ep}")
    return tp, ep


def _resolve(cfg, arch: str, rows: int, cache_len: int, *, mean_len: float,
             page_size: Optional[int], num_pages: Optional[int],
             attn_path: Optional[str], share_prefix: Optional[bool],
             kv_quant: Optional[str], sync_every: int,
             sparsity_stats: Optional[Dict], drain_only: bool,
             capacity_numbers: Optional[Dict] = None,
             spec_k: Optional[int] = None, mesh=None) -> ServePlan:
    """Shared decision resolution for plan_serve and the legacy shims.

    Every rule consulted here is the SAME ``core.dataflow`` rule the legacy
    per-call dispatch used, evaluated once — which is what makes the
    plan-vs-legacy sweep bit-exact.
    """
    from repro.models import transformer as tfm
    from repro.serve import kvcache

    kinds = {k for k, _ in tfm.slot_kinds(cfg)}
    recurrent = bool(kinds & {"ssm", "rglru"})
    has_global = "global" in kinds
    ps = page_size or min(dataflow.PAGE_SIZE, cache_len)
    max_pages = dataflow.pages_for(cache_len, ps)
    decisions = []

    # ---- capacity (HBM): rows × cache_len against the budget ----
    cap_n = dict(capacity_numbers or {})
    cap_n.setdefault("slot_bytes", kvcache.cache_bytes(cfg, 1, cache_len))
    decisions.append(Decision(
        "capacity", f"rows={rows} cache_len={cache_len}", "HBM",
        f"{rows} dense slot(s) of {cap_n['slot_bytes']} B each"
        + (f" fit the {cap_n['hbm_budget_bytes']} B budget"
           if "hbm_budget_bytes" in cap_n else " (caller-fixed geometry)"),
        cap_n))

    # ---- matmul route (compute): GEMV crossover at the decode width ----
    decode_route = dataflow.matmul_path(rows)
    decode_bm = dataflow.bcsc_tile_m(rows)
    decisions.append(Decision(
        "matmul", f"{decode_route} (bm={decode_bm}) at M={rows}", "compute",
        f"M={rows} {'<=' if decode_route == 'gemv' else '>'} "
        f"GEMV_M_MAX={dataflow.GEMV_M_MAX}: "
        + ("MXU m-rows would be padding — skip weight blocks via the "
           "scratch-accumulator GEMV kernel"
           if decode_route == "gemv" else
           "enough rows to amortize the index walk per resident block — "
           "revisit-accumulate GEMM"),
        {"gemv_m_max": dataflow.GEMV_M_MAX, "decode_bm": decode_bm}))

    # ---- MLP route (HBM): scratch-fit crossover + Eyexam roofline ----
    stats = sparsity_stats or {}
    d = cfg.d_model
    ff = cfg.dense_d_ff if (cfg.moe and cfg.dense_d_ff) else cfg.d_ff
    fused_max = _fused_m_max(ff, d, cfg.mlp_gated)
    mlp_route_decode = "fused" if (fused_max is None or rows <= fused_max) \
        else "two_call"
    mlp_n = mlp_roofline(cfg,
                         sparsity=float(stats.get("sparsity", 0.75)),
                         packing_efficiency=float(
                             stats.get("packing_efficiency", 0.93)),
                         bm=decode_bm)
    mlp_n["fused_m_max"] = fused_max
    mlp_n["scratch_bytes_at_decode_bm"] = dataflow.fused_mlp_scratch_bytes(
        decode_bm, ff, d, cfg.mlp_gated)
    mlp_n["scratch_budget_bytes"] = dataflow.FUSED_MLP_VMEM_BUDGET
    decisions.append(Decision(
        "mlp",
        f"{mlp_route_decode} (fused_m_max="
        f"{'inf' if fused_max is None else fused_max}, "
        f"chunk={dataflow.BCSC_CHUNK})", "HBM",
        "hidden activation stays in VMEM scratch while it fits "
        f"({mlp_n['scratch_bytes_at_decode_bm']} B <= "
        f"{mlp_n['scratch_budget_bytes']} B at bm={decode_bm}) — the "
        "two-call hidden round-trip and extra launches are the terms "
        "sparsity cannot shrink",
        mlp_n))

    # ---- attention (occupancy): paged vs contiguous + pool size ----
    rule_attn = dataflow.attn_path(cache_len, mean_len, ps) \
        if has_global else "contiguous"
    attn_pinned = attn_path is not None
    if attn_path is None:
        attn_path = rule_attn
    assert attn_path in ("paged", "contiguous"), attn_path
    paged = has_global and attn_path == "paged" and not drain_only
    attn_choice = "paged" if paged else "contiguous"
    rule_choice = "paged" if (has_global and rule_attn == "paged"
                              and not drain_only) else "contiguous"
    expected = dataflow.pages_for(mean_len, ps) * ps
    if paged:
        np_ = num_pages or rows * max_pages
    else:
        np_ = 0
    attn_n = {
        "page_size": ps, "max_pages_per_row": max_pages, "num_pages": np_,
        "expected_resident_tokens": expected, "cache_len": cache_len,
        "occupancy_threshold": dataflow.PAGED_OCCUPANCY_MAX,
        "tokens_resident_paged": rows * dataflow.pages_for(mean_len, ps) * ps,
        "tokens_resident_dense": dataflow.dense_kv_tokens(rows, cache_len),
    }
    attn_n["rule_choice"] = rule_choice
    if not has_global:
        why = ("no global-attention layers: ring/recurrent state is already "
               "bounded — indirection would reclaim nothing")
    elif drain_only:
        why = ("drain engine (DecodeEngine): dense per-slot cache by "
               "construction — paging applies to the streaming scheduler")
    elif attn_pinned and attn_choice != rule_choice:
        # a caller-pinned choice must not be explained with the rule's
        # (contradicting) rationale — record the pin and the rule's verdict
        why = (f"pinned '{attn_choice}' by caller — the occupancy rule "
               f"would pick '{rule_choice}' (expected resident {expected} "
               f"tokens vs {dataflow.PAGED_OCCUPANCY_MAX:.2f}·cache_len="
               f"{dataflow.PAGED_OCCUPANCY_MAX * cache_len:.0f})")
    elif paged:
        why = (f"expected resident {expected} tokens <= "
               f"{dataflow.PAGED_OCCUPANCY_MAX:.2f}·cache_len="
               f"{dataflow.PAGED_OCCUPANCY_MAX * cache_len:.0f}: block-table "
               "indirection converts stranded HBM into extra batch rows")
    else:
        why = ("occupancy too high (or cache shorter than two pages) for "
               "page indirection to reclaim anything — contiguous ring/dense "
               "slots")
    decisions.append(Decision("attention", attn_choice, "occupancy", why,
                              attn_n))

    if share_prefix is None:
        share_prefix = cfg.num_codebooks == 1
    share_prefix = bool(paged and share_prefix and cfg.num_codebooks == 1)

    # ---- KV quant (HBM): cache-stream share of the decode step ----
    rule_kv = dataflow.kv_quant_path(rows, cache_len, ps) if paged else "fp"
    kv_pinned = kv_quant is not None
    if kv_quant is None:
        kv_quant = rule_kv
    assert kv_quant in dataflow.KV_QUANT_DTYPES, kv_quant
    kv_quant = kv_quant if paged else "fp"
    w_bytes = cfg.param_count(active_only=True) * 2
    c_bytes = kvcache.cache_bytes(cfg, max(rows, 1), cache_len)
    cache_share = c_bytes / max(w_bytes + c_bytes, 1)
    kv_n = {
        "kv_quant_min_rows": dataflow.KV_QUANT_MIN_ROWS, "rows": rows,
        "weight_stream_bytes": w_bytes, "cache_stream_bytes": c_bytes,
        "cache_share": cache_share,
        "int8_step_speedup": (w_bytes + c_bytes) / (w_bytes + c_bytes / 2),
        "rule_choice": rule_kv,
    }
    if kv_pinned and kv_quant != rule_kv:
        kv_why = (f"pinned '{kv_quant}' by caller — the cache-bound rule "
                  f"would pick '{rule_kv}' (cache share {cache_share:.2f} "
                  f"at rows={rows} vs KV_QUANT_MIN_ROWS="
                  f"{dataflow.KV_QUANT_MIN_ROWS})")
    else:
        kv_why = (
            f"decode step streams the whole resident cache: cache share "
            f"{cache_share:.2f} of HBM bytes at rows={rows} "
            + (f">= KV_QUANT_MIN_ROWS={dataflow.KV_QUANT_MIN_ROWS} — int8 "
               "pages halve the dominant stream" if kv_quant == "int8" else
               "— below the cache-bound regime (or unpaged): per-page scale "
               "bookkeeping would outweigh the payload win"))
    decisions.append(Decision("kv_quant", kv_quant, "HBM", kv_why, kv_n))

    # ---- speculative decode (HBM): draft k, verify once per round ----
    # one flattened k-position verify streams the weights ONCE but the
    # resident cache k times; with geometric per-candidate acceptance alpha
    # a round retires E[n] = (1 - alpha^k)/(1 - alpha) tokens against
    # E[n] weight streams sequentially — speculation pays exactly when the
    # weight stream dominates the step (batch-1 decode), the Eyeriss v2
    # adapt-to-the-actual-work regime applied to autoregressive serving
    spec_pinned = spec_k is not None
    spec_eligible = (paged and kv_quant == "fp" and not recurrent
                     and kinds == {"global"} and cfg.num_codebooks == 1
                     and not drain_only)
    spec_cand = {}
    for kk in SPEC_K_CANDIDATES:
        exp_tokens = (1 - SPEC_ALPHA ** kk) / (1 - SPEC_ALPHA)
        spec_cand[kk] = exp_tokens * (w_bytes + c_bytes) \
            / (w_bytes + kk * c_bytes)
    rule_spec = max(spec_cand, key=spec_cand.get)
    rule_gain = spec_cand[rule_spec]
    rule_on = spec_eligible and rows == 1 and rule_gain >= SPEC_MIN_GAIN
    if spec_pinned:
        spec_choice = int(spec_k)
        if spec_choice and not (2 <= spec_choice <= max(SPEC_K_CANDIDATES)):
            raise ValueError(
                f"spec_k must be 0 or in [2, {max(SPEC_K_CANDIDATES)}], "
                f"got {spec_choice}")
        if spec_choice and not spec_eligible:
            raise ValueError(
                "spec_k > 0 requires an all-global-attention, single-"
                "codebook, fp paged plan — the flattened verifier is only "
                "bit-exact there (int8 appends requantize whole pages, so "
                "rejected drafts would poison committed scales)")
    else:
        spec_choice = rule_spec if rule_on else 0
    spec_n = {
        "alpha_assumed": SPEC_ALPHA, "rows": rows,
        "step_bytes_baseline": w_bytes + c_bytes,
        "verify_bytes_per_round": w_bytes + max(spec_choice, rule_spec)
        * c_bytes,
        "est_tokens_per_round": (1 - SPEC_ALPHA ** rule_spec)
        / (1 - SPEC_ALPHA),
        "est_speedup": rule_gain,
        "candidates": {str(kk): v for kk, v in spec_cand.items()},
        "rule_choice": f"k={rule_spec}" if rule_on else "off",
    }
    if spec_pinned and (spec_choice > 0) != rule_on:
        spec_why = (f"pinned {'k=%d' % spec_choice if spec_choice else 'off'}"
                    f" by caller — the batch-1 weight-stream rule would pick "
                    f"'{spec_n['rule_choice']}' (modeled "
                    f"{rule_gain:.2f}x at alpha={SPEC_ALPHA})")
    elif spec_choice:
        spec_why = (
            f"batch-1 decode is weight-stream bound (cache share "
            f"{cache_share:.2f}): one k={spec_choice} verify streams the "
            f"weights once for E[n]="
            f"{(1 - SPEC_ALPHA ** spec_choice) / (1 - SPEC_ALPHA):.2f} "
            f"retired tokens at alpha={SPEC_ALPHA} — modeled "
            f"{spec_cand.get(spec_choice, rule_gain):.2f}x over sequential "
            "greedy, bit-exact by accept-prefix construction")
    else:
        if not spec_eligible:
            spec_why = ("requires an all-global-attention, single-codebook, "
                        "fp paged plan (flattened verify appends are only "
                        "bit-exact there) — "
                        + ("drain engine" if drain_only else
                           f"this plan has kinds={sorted(kinds)}, "
                           f"kv_quant={kv_quant}, paged={paged}"))
        elif rows != 1:
            spec_why = (f"rows={rows}: batch rows already amortize the "
                        "weight stream, and the k x cache-stream verify "
                        "cost scales with occupancy — speculation is the "
                        "batch-1 lever")
        else:
            spec_why = (f"modeled gain {rule_gain:.2f}x < "
                        f"{SPEC_MIN_GAIN}x at alpha={SPEC_ALPHA}")
    decisions.append(Decision(
        "spec", f"k={spec_choice}" if spec_choice else "off", "HBM",
        spec_why, spec_n))

    # ---- degrade ladder (occupancy): authorized overload behavior ----
    # resolved here (not improvised under pressure) so the guard's ladder is
    # a plan decision with a roofline rationale like every other dispatch
    ladder = []
    np_int8 = 0
    deg_n: Dict = {"num_pages": np_}
    if paged:
        fp_page_b = kvcache.kv_page_bytes(cfg, ps, "fp")
        i8_page_b = kvcache.kv_page_bytes(cfg, ps, "int8")
        deg_n.update(fp_page_bytes=fp_page_b, int8_page_bytes=i8_page_b)
        if kv_quant == "fp":
            # pages the fp pool's HBM footprint holds in int8 layout, capped
            # at full provisioning (rows × max_pages — more is unreachable)
            np_int8 = min(int(np_ * fp_page_b // max(i8_page_b, 1)),
                          rows * max_pages)
            if np_int8 > np_:
                ladder.append("int8_kv")
        ladder += ["clamp_max_new", "shed"]
        deg_n["num_pages_int8"] = np_int8
    if not paged:
        deg_why = ("contiguous KV: no page pool to trade occupancy against "
                   "— arrivals queue on the slot allocator and only "
                   "deadlines bound their wait")
    else:
        steps = []
        if "int8_kv" in ladder:
            steps.append(
                f"requantize the pool to int8 pages at the same HBM "
                f"footprint ({np_} -> {np_int8} pages of {i8_page_b} B "
                f"vs {fp_page_b} B)")
        steps.append("clamp new admissions' max_new")
        steps.append("shed new arrivals off measured pool pressure")
        deg_why = ("occupancy, not compute, is what collapses under an "
                   "arrival spike: " + "; then ".join(steps)
                   + " — admitted work keeps finishing instead of the run "
                     "raising on pool exhaustion")
    decisions.append(Decision(
        "degrade", " -> ".join(ladder) if ladder else "none", "occupancy",
        deg_why, deg_n))

    # ---- prefill schedule (compute): pow2 tiers vs exact lengths ----
    tiers = () if recurrent else _pow2_tiers(cache_len)
    decisions.append(Decision(
        "prefill",
        "exact-length tiers" if recurrent else
        f"pow2 tiers ({len(tiers)} buckets <= {cache_len})", "compute",
        ("recurrent state (ssm/rglru): pad tokens would pollute the carried "
         "state, so admission buckets by exact length" if recurrent else
         "causality makes right-padding exact, so admission buckets to the "
         "next power of two — trace count stays logarithmic in prompt-"
         "length spread while batched prefill amortizes over the cohort"),
        {"n_tiers": len(tiers), "sync_every": sync_every}))

    # ---- mesh resolution (collective): the hierarchical-mesh stage ----
    # ISSUE 10: one frozen artifact owns the sharding choice the launch
    # path's planner/autoshard used to make separately. Decisions appear
    # only when a mesh is requested, so single-device plans (and their
    # golden snapshots) are untouched. The NoC vocabulary is
    # ``core.hmmesh.Mode``: per data type, pick the multicast pattern that
    # matches its reuse — exactly the paper's per-data-type NoC
    # reconfiguration, applied at cluster scale.
    tp, ep = parse_mesh(mesh)
    if tp > 1 or ep > 1:
        if drain_only:
            raise ValueError("mesh sharding serves through the streaming "
                             "scheduler — the drain engine is single-device")
        if recurrent and tp > 1:
            raise ValueError(
                f"tp={tp} shards attention KV heads; {arch} carries "
                "recurrent (ssm/rglru) state that has no head axis — "
                "serve it single-device or dp-replicated")
        if tp > 1 and cfg.num_kv_heads % tp != 0:
            raise ValueError(
                f"tp={tp} must divide num_kv_heads={cfg.num_kv_heads} — "
                "the paged-attention kernel reads whole local KV-head "
                "shards (hmmesh.divisible)")
        if ep > 1 and not getattr(cfg, "moe", False):
            raise ValueError(
                f"ep={ep} shards the MoE expert axis but {arch} has no "
                "experts — use tp (or dp replicas) instead")
        if ep > 1 and cfg.num_experts % ep != 0:
            raise ValueError(
                f"ep={ep} must divide num_experts={cfg.num_experts} — "
                "expert shards are contiguous slices of the expert axis")
        devices = tp * ep
        n_attn = sum(1 for i in range(cfg.num_layers)
                     if cfg.layer_kind(i) in ("global", "local", "chunked"))
        n_moe = sum(1 for i in range(cfg.num_layers) if cfg.is_moe_layer(i))
        # per-token collective traffic: each device produces a 1/tp slice
        # of every layer's head context and receives the other (tp-1)/tp
        ctx_bytes = cfg.num_heads * cfg.head_dim * 2
        ag_bytes_tok = int(n_attn * ctx_bytes * (tp - 1) / max(tp, 1))
        per_dev_hbm = w_bytes + c_bytes // max(tp, 1)
        decisions.append(Decision(
            "mesh", f"tp={tp} ep={ep} ({devices} devices)", "collective",
            f"{cfg.num_kv_heads} KV heads partition over tp={tp} "
            f"({cfg.num_kv_heads // tp} local heads/device)"
            + (f"; {cfg.num_experts} experts over ep={ep} "
               f"({cfg.num_experts // ep} local experts/device)"
               if ep > 1 else "")
            + f" — {ag_bytes_tok} collective B/token vs "
            f"{per_dev_hbm} HBM B/step per device: the all-gather is "
            "negligible next to the weight stream, so sharding converts "
            "mesh width into cache capacity at full occupancy",
            {"tp": tp, "ep": ep, "devices": devices,
             "allgather_bytes_per_token": ag_bytes_tok,
             "hbm_bytes_per_step_per_device": per_dev_hbm}))
        decisions.append(Decision(
            "noc_weights", hmmesh.Mode.BROADCAST.name, "HBM",
            f"dense weights replicate to all {devices} devices "
            f"({w_bytes} B each): decode is weight-stream bound, and a "
            f"sharded store would re-gather {(tp - 1) * w_bytes // max(tp, 1)}"
            " B per step onto the critical path — replication trades idle "
            "HBM capacity for zero collective bytes per step",
            {"mode": hmmesh.Mode.BROADCAST.value,
             "weight_bytes_per_device": w_bytes,
             "allgather_bytes_avoided_per_step":
                 (tp - 1) * w_bytes // max(tp, 1)}))
        decisions.append(Decision(
            "noc_kv", f"{hmmesh.Mode.GROUPED_MC.name} (local shards)",
            "HBM",
            f"KV pages shard by head over tp={tp}: every device streams "
            f"only its {c_bytes // max(tp, 1)} B local slice per step "
            f"(1/{tp} of {c_bytes} B) and the paged-attention kernel never "
            "reads a remote page — attention is per-KV-head local, so the "
            "cache stream divides with zero collective bytes",
            {"mode": hmmesh.Mode.GROUPED_MC.value, "tp": tp,
             "cache_stream_bytes_per_device": c_bytes // max(tp, 1),
             "cache_stream_bytes_single": c_bytes}))
        decisions.append(Decision(
            "noc_acts", "all-gather -> " + hmmesh.Mode.BROADCAST.name,
            "collective",
            f"head contexts are produced {hmmesh.Mode.UNICAST.name} (a "
            f"unique 1/{tp} slice per device) and all-gathered to full "
            f"width before the output projection: {ag_bytes_tok} B/token "
            f"received per device across {n_attn} attention layer(s) — "
            "the only per-step mesh traffic, and it is token-sized, not "
            "cache-sized",
            {"allgather_bytes_per_token": ag_bytes_tok,
             "attn_layers": n_attn, "ctx_bytes_per_layer": ctx_bytes}))
        if ep > 1:
            nmats = 3 if cfg.mlp_gated else 2
            e_bytes = cfg.num_experts * nmats * cfg.d_model * cfg.d_ff * 2 \
                * max(n_moe, 1)
            decisions.append(Decision(
                "noc_experts",
                f"{hmmesh.Mode.INTERLEAVED_MC.name} "
                f"({cfg.num_experts // ep}/{cfg.num_experts} per device)",
                "HBM",
                f"expert weights shard over ep={ep}: {e_bytes // ep} B "
                f"resident per device instead of {e_bytes} B — the expert "
                "axis is a batch axis in the decode einsums, so each shard "
                "computes its slice and the gate-weighted combine runs on "
                "the gathered full-E tensor (router stays replicated)",
                {"mode": hmmesh.Mode.INTERLEAVED_MC.value, "ep": ep,
                 "expert_bytes_per_device": e_bytes // ep,
                 "expert_bytes_total": e_bytes, "moe_layers": n_moe}))
        if paged:
            pool_b = kvcache.paged_cache_bytes(
                cfg, rows, cache_len, np_, ps, kv_quant)
            decisions.append(Decision(
                "pool_shard",
                f"{np_} pages x 1/{tp} heads per device", "occupancy",
                f"every device runs its own PageAllocator over {np_} pages "
                f"holding the local KV-head slice: {pool_b // max(tp, 1)} B "
                f"pool per device (1/{tp} of the {pool_b} B single-device "
                "pool), same block tables on every shard — the block table "
                "IS the distributed address space, so CoW sharing and the "
                "degrade ladder operate per device pool in lockstep",
                {"num_pages_per_device": np_,
                 "pool_bytes_per_device": pool_b // max(tp, 1),
                 "pool_bytes_single": pool_b, "tp": tp}))

    return ServePlan(
        arch=arch, rows=rows, cache_len=cache_len, sync_every=sync_every,
        gemv_m_max=dataflow.GEMV_M_MAX, gemv_bm=dataflow.GEMV_BM,
        mlp_fused_m_max=fused_max,
        mlp_pack_dense_density=dataflow.DENSE_BLOCK_DENSITY,
        bcsc_chunk=dataflow.BCSC_CHUNK,
        attn_path=attn_choice, page_size=ps, max_pages=max_pages,
        num_pages=np_, share_prefix=share_prefix, kv_quant=kv_quant,
        prefill_exact=recurrent, prefill_tiers=tiers,
        degrade=tuple(ladder), num_pages_int8=np_int8,
        spec_k=spec_choice, tp=tp, ep=ep, decisions=tuple(decisions))


def plan_serve(cfg, *, hbm_budget_bytes: int, expected_batch: int,
               expected_len_dist, sparsity_stats: Optional[Dict] = None,
               page_size: Optional[int] = None,
               num_pages: Optional[int] = None,
               attn_path: Optional[str] = None,
               share_prefix: Optional[bool] = None,
               kv_quant: Optional[str] = None,
               sync_every: int = 8, arch: Optional[str] = None,
               spec_k: Optional[int] = None, mesh=None) -> ServePlan:
    """Resolve a full ServePlan from (model cfg, serving budget).

    ``expected_len_dist`` is {'mean': …, 'max': …} (total tokens per request,
    prompt + generation) or an iterable of expected lengths; ``cache_len`` is
    its max and the expected occupancy its mean. ``expected_batch`` rows are
    provisioned, clamped to what ``hbm_budget_bytes`` can hold (at least one
    row must fit — mirroring the engines' refusal on a zero-slot budget).
    ``sparsity_stats`` ({'sparsity', 'packing_efficiency', 'block_density'},
    e.g. from ``serve.sparse.sparsify_mlp_params``) feeds the MLP roofline.
    The keyword overrides pin individual decisions (recorded as such); by
    default every decision comes from the ``core.dataflow`` rule it
    centralizes. ``mesh`` (``"tp=2,ep=4"``, a dict, or a ``(tp, ep)``
    pair) runs the mesh resolution stage: tensor-/expert-parallel degrees
    with one ``hmmesh.Mode`` Decision per data type and a per-device pool
    Decision (ISSUE 10).
    """
    from repro.serve import kvcache

    mean_len, cache_len = _normalize_len_dist(expected_len_dist)
    slot_bytes = kvcache.cache_bytes(cfg, 1, cache_len)
    fit_rows = int(hbm_budget_bytes // max(slot_bytes, 1))
    if fit_rows < 1:
        raise ValueError(
            f"hbm_budget_bytes={hbm_budget_bytes} cannot hold one "
            f"(1, {cache_len}) cache slot ({slot_bytes} B) — shrink the "
            "expected max length, shard over more chips, or raise the "
            "budget")
    rows = max(1, min(int(expected_batch), fit_rows))
    ps = page_size or min(dataflow.PAGE_SIZE, cache_len)
    if num_pages is None:
        # pool sized for the expected occupancy plus one growth page per
        # row, floored at one worst-case request and capped at full
        # provisioning — the occupancy regime paging exists for
        max_pages = dataflow.pages_for(cache_len, ps)
        want = rows * (dataflow.pages_for(mean_len, ps) + 1)
        num_pages = min(max(max_pages, want), rows * max_pages)
    return _resolve(
        cfg, arch or getattr(cfg, "name", type(cfg).__name__), rows,
        cache_len, mean_len=mean_len, page_size=ps, num_pages=num_pages,
        attn_path=attn_path, share_prefix=share_prefix, kv_quant=kv_quant,
        sync_every=sync_every, sparsity_stats=sparsity_stats,
        drain_only=False, spec_k=spec_k, mesh=mesh,
        capacity_numbers={
            "hbm_budget_bytes": int(hbm_budget_bytes),
            "expected_batch": int(expected_batch),
            "expected_mean_len": mean_len, "slot_bytes": slot_bytes,
            "rows_fitting_budget": fit_rows,
        })


def replan_from_lengths(cfg, base_plan: ServePlan, lengths,
                        *, arch: Optional[str] = None) -> ServePlan:
    """Feedback-driven re-plan (serve/replica.py): resolve a fresh ServePlan
    from *measured* finished-request total lengths (prompt + generated
    tokens), keeping the base plan's serving envelope — rows, cache_len,
    page geometry, kernel routes, sync cadence — pinned so a hot-swap at a
    drain boundary can never shrink feasibility (any request admissible
    under the base plan stays admissible) or flip a dispatch decision
    mid-deployment. Only the *pool size* re-resolves, from
    ``{'mean': measured mean, 'max': base.cache_len}`` — the occupancy knob
    the original ``expected_len_dist`` guess was standing in for.
    """
    from repro.serve import kvcache

    mean_len, _ = _normalize_len_dist(list(lengths))
    mean_len = min(mean_len, float(base_plan.cache_len))
    slot_bytes = kvcache.cache_bytes(cfg, 1, base_plan.cache_len)
    return plan_serve(
        cfg,
        hbm_budget_bytes=base_plan.rows * slot_bytes,
        expected_batch=base_plan.rows,
        expected_len_dist={"mean": mean_len, "max": base_plan.cache_len},
        page_size=base_plan.page_size or None,
        attn_path=base_plan.attn_path,
        share_prefix=base_plan.share_prefix,
        kv_quant=base_plan.kv_quant,
        sync_every=base_plan.sync_every,
        spec_k=base_plan.spec_k,    # pinned: a hot-swap never flips dispatch
        mesh={"tp": base_plan.tp, "ep": base_plan.ep}
        if base_plan.sharded else None,   # pinned: replicas never re-mesh
        arch=arch or base_plan.arch)


def _alpha_from_acceptance(rate: float, k: int) -> float:
    """Invert the geometric accept-prefix model for the per-candidate
    acceptance ``alpha``: the measured rate is emitted/drafted per round,
    ``E[n]/k = (1 - alpha^k) / ((1 - alpha) * k)``, strictly increasing in
    alpha on (0, 1) — bisection is exact enough for the k ladder."""
    k = max(int(k), 1)
    rate = min(max(float(rate), 0.0), 1.0)
    lo, hi = 0.0, 0.999
    for _ in range(60):
        mid = (lo + hi) / 2
        if (1 - mid ** k) / ((1 - mid) * k) < rate:
            lo = mid
        else:
            hi = mid
    return (lo + hi) / 2


def replan_spec_k(cfg, base_plan: ServePlan, *, drafted_tokens: int,
                  accepted_tokens: int, min_samples: int = 64) -> ServePlan:
    """Acceptance-adaptive speculative depth (ISSUE 10 satellite).

    Re-run the plan's own geometric-gain model with the *measured* draft
    acceptance (``spec_accepted_tokens / spec_drafted_tokens`` from
    telemetry) substituted for the assumed :data:`SPEC_ALPHA`, and pick the
    gain-maximizing k — stepping k down (or off, below
    :data:`SPEC_MIN_GAIN`) when the bigram draft hits less often than
    modeled. Everything else in the plan is pinned, so the swap is safe at
    any drain boundary; returns ``base_plan`` unchanged when speculation is
    off, the sample is too small, or the measured rate confirms the
    current k.
    """
    from repro.serve import kvcache

    if base_plan.spec_k < 2 or drafted_tokens < min_samples:
        return base_plan
    rate = accepted_tokens / max(drafted_tokens, 1)
    alpha = _alpha_from_acceptance(rate, base_plan.spec_k)
    w_bytes = cfg.param_count(active_only=True) * 2
    c_bytes = kvcache.cache_bytes(cfg, max(base_plan.rows, 1),
                                  base_plan.cache_len)
    cand = {}
    for kk in SPEC_K_CANDIDATES:
        exp_tokens = (1 - alpha ** kk) / max(1 - alpha, 1e-9)
        cand[kk] = exp_tokens * (w_bytes + c_bytes) \
            / (w_bytes + kk * c_bytes)
    best = max(cand, key=cand.get)
    new_k = best if cand[best] >= SPEC_MIN_GAIN else 0
    if new_k == base_plan.spec_k:
        return base_plan
    spec_n = {
        "alpha_assumed": SPEC_ALPHA, "alpha_measured": round(alpha, 4),
        "acceptance_rate_measured": round(rate, 4),
        "drafted_tokens": int(drafted_tokens),
        "accepted_tokens": int(accepted_tokens),
        "previous_k": base_plan.spec_k,
        "est_speedup": cand[best],
        "candidates": {str(kk): v for kk, v in cand.items()},
    }
    why = (f"measured acceptance {rate:.2f} over {drafted_tokens} drafted "
           f"tokens inverts to alpha={alpha:.2f} (planned {SPEC_ALPHA}): "
           + (f"the gain model now peaks at k={new_k} "
              f"({cand[best]:.2f}x)" if new_k else
              f"best modeled gain {cand[best]:.2f}x < {SPEC_MIN_GAIN}x — "
              "drafts miss too often to pay for the k-wide verify; "
              "speculation turns off")
           + f" — re-planned from k={base_plan.spec_k} at a drain boundary")
    decisions = tuple(
        d if d.name != "spec" else Decision(
            "spec", f"k={new_k}" if new_k else "off", "HBM", why, spec_n)
        for d in base_plan.decisions)
    return dataclasses.replace(base_plan, spec_k=new_k, decisions=decisions)


# ------------------------------------------------------------- legacy shims
def plan_for_engine(cfg, *, slots: int, cache_len: int,
                    sync_every: int = 8) -> ServePlan:
    """Single-decision plan for the drain engine's legacy kwargs
    (``DecodeEngine(cfg, params, slots=…, cache_len=…)``): dense per-slot
    cache, contiguous attention, every dispatch threshold resolved from the
    same ``core.dataflow`` rules the old per-call path consulted."""
    return _resolve(
        cfg, getattr(cfg, "name", type(cfg).__name__), slots, cache_len,
        mean_len=cache_len / 2, page_size=None, num_pages=None,
        attn_path=None, share_prefix=None, kv_quant=None,
        sync_every=sync_every, sparsity_stats=None, drain_only=True)


def plan_for_scheduler(cfg, *, rows: int, cache_len: int, page_size: int = 0,
                       num_pages: int = 0, attn_path: Optional[str] = None,
                       share_prefix: Optional[bool] = None,
                       kv_quant: Optional[str] = None,
                       sync_every: int = 8) -> ServePlan:
    """Single-decision plan from the streaming scheduler's legacy kwargs —
    exactly the resolution ``ContinuousBatchingScheduler.__init__`` used to
    perform inline (page_size default, occupancy rule at mean = cache_len/2,
    full pool provisioning, CoW and KV-quant rules)."""
    return _resolve(
        cfg, getattr(cfg, "name", type(cfg).__name__), rows, cache_len,
        mean_len=cache_len / 2, page_size=page_size or None,
        num_pages=num_pages or None, attn_path=attn_path,
        share_prefix=share_prefix, kv_quant=kv_quant,
        sync_every=sync_every, sparsity_stats=None, drain_only=False,
        spec_k=0)   # legacy shim: speculation is a plan_serve opt-in


# -------------------------------------------------------------- snapshotting
def snapshot_plan(arch: str) -> ServePlan:
    """The canonical resolved plan for a seed config — fixed budget/shape
    inputs so the serialized plan is deterministic. scripts/golden_plans.json
    records these; the perf-guard check ``plan-snapshot-stable`` (and
    tests/test_plan.py) gate drift."""
    from repro.configs import get_config
    cfg = get_config(arch)
    return plan_serve(cfg, hbm_budget_bytes=SNAPSHOT_BUDGET_BYTES,
                      expected_batch=SNAPSHOT_BATCH,
                      expected_len_dist=dict(SNAPSHOT_LEN_DIST),
                      sparsity_stats=dict(SNAPSHOT_SPARSITY), arch=arch)


def snapshot_sharded_plan(arch: str, mesh: str) -> ServePlan:
    """The canonical *sharded* plan for a seed config at one mesh shape —
    same fixed snapshot inputs as :func:`snapshot_plan` plus the mesh
    resolution stage. scripts/golden_plans.json records these under
    ``"__sharded__"`` as ``{arch: {mesh: plan}}``; perf_guard's
    ``sharded-plan-snapshot-stable`` gates drift. Environment-independent
    by construction: the mesh stage never reads ``jax.device_count()``
    (backing is a serve-time property, ``serve.shard.ServeMesh``)."""
    from repro.configs import get_config
    cfg = get_config(arch)
    return plan_serve(cfg, hbm_budget_bytes=SNAPSHOT_BUDGET_BYTES,
                      expected_batch=SNAPSHOT_BATCH,
                      expected_len_dist=dict(SNAPSHOT_LEN_DIST),
                      sparsity_stats=dict(SNAPSHOT_SPARSITY), arch=arch,
                      mesh=mesh)


# ----------------------------------------------------------------------- CLI
def _parse_bytes(s: str) -> int:
    s = s.strip()
    units = {"kib": 1 << 10, "mib": 1 << 20, "gib": 1 << 30,
             "kb": 10 ** 3, "mb": 10 ** 6, "gb": 10 ** 9, "b": 1}
    low = s.lower()
    for suffix, mult in units.items():
        if low.endswith(suffix):
            return int(float(low[: -len(suffix)]) * mult)
    return int(float(s))


def _resolve_arch_name(name: str) -> str:
    """Accept registry ids ('gemma2-2b'), module names ('gemma2_2b'), and
    either with a '-reduced' suffix."""
    from repro.configs import _ARCH_MODULES
    suffix = ""
    base = name
    if name.endswith("-reduced") or name.endswith("_reduced"):
        base, suffix = name[:-len("-reduced")], "-reduced"
    if base in _ARCH_MODULES:
        return base + suffix
    for reg, mod in _ARCH_MODULES.items():
        if base in (mod, reg.replace("-", "_")):
            return reg + suffix
    raise KeyError(f"unknown arch {name!r}; known: {list(_ARCH_MODULES)}")


def main(argv=None) -> int:
    import argparse
    from repro.configs import get_config

    ap = argparse.ArgumentParser(
        description="Resolve a ServePlan and print its Eyexam-style "
                    "per-decision rationale.")
    ap.add_argument("--cfg", required=True,
                    help="arch id (gemma2-2b) or module name (gemma2_2b)")
    ap.add_argument("--hbm", default="2GiB",
                    help="HBM budget (e.g. 2GiB, 512MiB, 16e9)")
    ap.add_argument("--batch", type=int, default=SNAPSHOT_BATCH,
                    help="expected decode batch width")
    ap.add_argument("--mean-len", type=int,
                    default=SNAPSHOT_LEN_DIST["mean"],
                    help="expected mean total tokens per request")
    ap.add_argument("--max-len", type=int, default=SNAPSHOT_LEN_DIST["max"],
                    help="max total tokens per request (the cache length)")
    ap.add_argument("--sparsity", type=float,
                    default=SNAPSHOT_SPARSITY["sparsity"])
    ap.add_argument("--mesh", default=None,
                    help="mesh shape, e.g. 'tp=2,ep=4' — adds the mesh "
                         "resolution stage (NoC mode per data type, "
                         "per-device pool)")
    ap.add_argument("--json", action="store_true",
                    help="print plan.to_json() instead of the report")
    args = ap.parse_args(argv)

    arch = _resolve_arch_name(args.cfg)
    plan = plan_serve(
        get_config(arch),
        hbm_budget_bytes=_parse_bytes(args.hbm),
        expected_batch=args.batch,
        expected_len_dist={"mean": args.mean_len, "max": args.max_len},
        sparsity_stats={"sparsity": args.sparsity,
                        "packing_efficiency":
                            SNAPSHOT_SPARSITY["packing_efficiency"]},
        arch=arch, mesh=args.mesh)
    print(plan.to_json() if args.json else plan.explain())
    return 0


if __name__ == "__main__":
    import sys
    sys.exit(main())
