"""HM-mesh planner: per-layer sharding-mode selection (the paper's per-layer
NoC reconfiguration, DESIGN.md §2).

For every layer GEMM the planner scores candidate (weight-mode, iact-mode)
pairs with an Eyexam-step-6 roofline estimate and picks the fastest feasible
one — reproducing the paper's behavior table (Fig. 9):

    CONV-like   (high reuse both)   → weights GROUPED_MC  / iacts INTERLEAVED_MC
    DW-CONV     (no iact reuse)     → weights BROADCAST   / iacts UNICAST
    FC @ B=1    (no weight reuse)   → weights UNICAST     / iacts BROADCAST
    MoE experts (G dimension)       → weights GROUPED_MC over experts (= EP)

The model-level entry point (`plan_model`) aggregates layer votes into a
ModelPlan: parameter-sharding rule, activation specs and cache specs that
`sharding.autoshard` applies to the pjit step.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, List, Optional, Tuple

from repro.core import eyexam
from repro.core.hmmesh import Mode
from repro.core.reuse import LayerShape, model_gemms, reuse

BYTES = 2            # bf16
TRAIN_BACKWARD = 3.0  # bwd ≈ 2× fwd FLOPs


@dataclasses.dataclass
class MeshDesc:
    pod: int
    data: int
    model: int

    @property
    def chips(self) -> int:
        return self.pod * self.data * self.model

    @property
    def dp(self) -> int:
        return self.pod * self.data

    def axes(self) -> Dict[str, int]:
        return {"pod": self.pod, "data": self.data, "model": self.model}


@dataclasses.dataclass
class LayerPlan:
    layer: str
    weight_mode: Mode
    iact_mode: Mode
    est_time: float
    terms: Dict[str, float]
    note: str = ""


# --------------------------------------------------------------- candidates
def _candidate_time(shape: LayerShape, wm: Mode, im: Mode, mesh: MeshDesc,
                    training: bool) -> Optional[Tuple[float, Dict[str, float]]]:
    """Roofline time for one (weight-mode, iact-mode) candidate, or None if
    infeasible (indivisible dims / incoherent pairing)."""
    N, C, M, G = shape.N, shape.C, shape.M, shape.G
    dp, mp = mesh.dp, mesh.model
    macs = shape.effective_macs
    flops = 2.0 * macs * (TRAIN_BACKWARD if training else 1.0)

    # tokens are interleaved (sharded) over the data axes whenever possible
    tok_shards = dp if (im in (Mode.INTERLEAVED_MC, Mode.UNICAST) and
                        N % dp == 0) else 1
    if im in (Mode.INTERLEAVED_MC, Mode.UNICAST) and N % dp:
        return None

    coll = 0.0
    if wm == Mode.BROADCAST:
        w_shards = 1
        if training:  # gradient all-reduce over dp (2(n-1)/n ≈ 2× bytes)
            coll += 2.0 * shape.weight_count * BYTES * (dp - 1) / max(dp, 1)
    elif wm == Mode.GROUPED_MC:
        # TP: weights sharded over model on G (if meaningful) else M
        if G > 1:
            if G % mp:
                return None
        elif M % mp:
            return None
        w_shards = mp
        if G > 1:  # EP: tokens all-to-all there and back
            coll += 2.0 * (N / max(tok_shards, 1)) * C * BYTES
        else:      # Megatron pair: all-reduce activations once per 2 GEMMs
            coll += (N / max(tok_shards, 1)) * M * BYTES / 2
        if training:
            coll += 2.0 * shape.weight_count * BYTES / mp * (dp - 1) / max(dp, 1)
    elif wm == Mode.UNICAST:
        # FSDP/ZeRO-3: weights sharded over every chip; all-gather per use
        w_shards = dp * mp
        gathers = 2 if training else 1  # fwd + bwd re-gather
        coll += gathers * shape.weight_count * BYTES * (1 - 1.0 / w_shards)
        if training:  # reduce-scatter grads
            coll += shape.weight_count * BYTES * (1 - 1.0 / w_shards)
    elif wm == Mode.INTERLEAVED_MC:
        # weights sharded over data axes only (ZeRO within pod rows)
        w_shards = dp
        gathers = 2 if training else 1
        coll += gathers * shape.weight_count * BYTES * (1 - 1.0 / dp)
        if training:
            coll += shape.weight_count * BYTES * (1 - 1.0 / dp)
    else:
        return None

    if im == Mode.BROADCAST and tok_shards > 1:
        return None

    chips = mesh.chips
    flops_per_chip = flops / chips
    # HBM traffic: weights (local shard) + iacts + psums, all per chip
    w_bytes = shape.weight_count * (1 - shape.sparsity_w) * BYTES / w_shards
    a_bytes = (shape.iact_count * (1 - shape.sparsity_a) +
               shape.psum_count) * BYTES / max(tok_shards, 1)
    # single-pass approximation: each operand crosses HBM once
    hbm = w_bytes + a_bytes

    t_c = flops_per_chip / eyexam.PEAK_FLOPS
    t_m = hbm / eyexam.HBM_BW
    t_n = (coll / chips) / eyexam.ICI_BW
    terms = {"compute": t_c, "memory": t_m, "collective": t_n}
    return max(t_c, t_m, t_n), terms


_W_MODES = (Mode.BROADCAST, Mode.GROUPED_MC, Mode.UNICAST, Mode.INTERLEAVED_MC)
_I_MODES = (Mode.BROADCAST, Mode.INTERLEAVED_MC)


def plan_layer(shape: LayerShape, mesh: MeshDesc, training: bool) -> LayerPlan:
    best = None
    for wm in _W_MODES:
        for im in _I_MODES:
            res = _candidate_time(shape, wm, im, mesh, training)
            if res is None:
                continue
            t, terms = res
            if best is None or t < best[0]:
                best = (t, terms, wm, im)
    assert best is not None, f"no feasible plan for {shape.name}"
    t, terms, wm, im = best
    r = reuse(shape)
    note = (f"reuse w={r['weight']:.0f} i={r['iact']:.0f} p={r['psum']:.0f}")
    return LayerPlan(shape.name, wm, im, t, terms, note)


# ------------------------------------------------------------- model planning
@dataclasses.dataclass
class ModelPlan:
    """Aggregated decision consumed by sharding.autoshard."""
    param_rule: str            # 'fsdp_tp' | 'tp_only' | 'ep_fsdp' | 'fsdp_dp' | 'replicated'
    shard_experts: bool        # EP over model axis
    shard_heads: bool          # attention heads over model axis
    shard_kv_heads: bool
    shard_ffn: bool            # d_ff over model axis
    shard_vocab: bool
    cache_seq_sharded: bool    # decode KV cache: shard seq over model
    layers: List[LayerPlan]
    mesh: MeshDesc
    # 'dp': tokens over the dp axes, TP over model (grouped-multicast).
    # 'all': tokens over EVERY axis, weights broadcast — the paper's DW-CONV
    # mode (Fig. 9b) for families with no TP-able dimension (pure SSM): the
    # model axis would otherwise idle, capping utilization at 1/model.
    act_axes: str = "dp"

    def describe(self) -> str:
        lines = [f"param_rule={self.param_rule} experts={self.shard_experts} "
                 f"heads={self.shard_heads} kv={self.shard_kv_heads} "
                 f"ffn={self.shard_ffn} vocab={self.shard_vocab} "
                 f"cache_seq={self.cache_seq_sharded}"]
        for lp in self.layers:
            lines.append(f"  {lp.layer:18s} W={lp.weight_mode.value:22s} "
                         f"A={lp.iact_mode.value:22s} t={lp.est_time:.2e} "
                         f"[{lp.note}]")
        return "\n".join(lines)


def plan_model(cfg, shape_cfg, mesh: MeshDesc) -> ModelPlan:
    """Plan a whole (arch × input-shape) cell."""
    training = shape_cfg.kind == "train"
    decode = shape_cfg.kind == "decode"
    tokens = shape_cfg.global_batch * (1 if decode else shape_cfg.seq_len)
    gemms = model_gemms(cfg, max(tokens, 1), decode=decode)
    layer_plans = [plan_layer(g, mesh, training) for g in gemms]

    votes = [lp.weight_mode for lp in layer_plans]
    n_unicast = sum(v in (Mode.UNICAST, Mode.INTERLEAVED_MC) for v in votes)

    mp = mesh.model
    shard_heads = cfg.num_heads > 0 and cfg.num_heads % mp == 0
    shard_kv = cfg.num_kv_heads > 0 and cfg.num_kv_heads % mp == 0
    shard_ffn = (cfg.d_ff or cfg.d_inner) % mp == 0 if (cfg.d_ff or cfg.ssm_state) else False
    shard_vocab = cfg.vocab_padded % mp == 0
    shard_experts = cfg.moe and cfg.num_experts % mp == 0

    if training:
        # params live FSDP over data(+pod), TP over model — grouped+interleaved
        rule = "ep_fsdp" if shard_experts else "fsdp_tp"
    elif decode:
        # low weight reuse → unicast-style: TP/EP shards, replicate over data
        rule = "ep_fsdp" if shard_experts else "tp_only"
    else:
        rule = "ep_fsdp" if shard_experts else "fsdp_tp"

    # Pure-SSM family: no attention heads, no MoE, no MLP — TP has nothing to
    # grip. Paper Fig. 9b (DW-CONV): broadcast weights, unicast iacts — tokens
    # over the WHOLE mesh, params FSDP over dp only.
    act_axes = "dp"
    if all(k == "ssm" for k in cfg.attn_pattern):
        act_axes = "all"
        rule = "fsdp_dp"
        shard_heads = shard_kv = shard_ffn = shard_experts = False
        shard_vocab = False

    cache_seq_sharded = decode and not shard_kv
    return ModelPlan(param_rule=rule, shard_experts=shard_experts,
                     shard_heads=shard_heads, shard_kv_heads=shard_kv,
                     shard_ffn=shard_ffn, shard_vocab=shard_vocab,
                     cache_seq_sharded=cache_seq_sharded,
                     layers=layer_plans, mesh=mesh, act_axes=act_axes)
