# The paper's primary contribution, in JAX:
#   hmmesh/planner — HM-NoC modes → per-layer sharding selection
#   reuse          — Table-I data-reuse analysis
#   eyexam         — 7-step bounds + 3-term TPU roofline
#   sparsity       — CSC / block-CSC formats + pruning
#   dataflow       — row-stationary VMEM tiling
from repro.core import dataflow, eyexam, hmmesh, planner, reuse, sparsity

__all__ = ["dataflow", "eyexam", "hmmesh", "planner", "reuse", "sparsity"]
