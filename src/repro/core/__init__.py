# The paper's primary contribution, in JAX:
#   hmmesh/planner — HM-NoC modes → per-layer sharding selection
#   reuse          — Table-I data-reuse analysis
#   eyexam         — 7-step bounds + 3-term TPU roofline
#   sparsity       — CSC / block-CSC formats + pruning
#   dataflow       — row-stationary VMEM tiling
#   plan           — ServePlan: every serving dispatch decision resolved once
from repro.core import dataflow, eyexam, hmmesh, planner, reuse, sparsity

__all__ = ["dataflow", "eyexam", "hmmesh", "plan", "planner", "reuse",
           "sparsity"]


def __getattr__(name):
    # `plan` loads lazily so `python -m repro.core.plan` (the ServePlan CLI)
    # does not import the module twice (runpy's sys.modules warning)
    if name == "plan":
        import importlib
        return importlib.import_module("repro.core.plan")
    raise AttributeError(name)
