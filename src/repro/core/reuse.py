"""Data-reuse analysis (paper §I-A, Table I, Fig. 2).

A DNN layer is described by the paper's dimensions
    G (groups) N (batch) M (out ch) C (in ch) H/W (ifmap) R/S (filter) E/F (ofmap)
and *data reuse* = MACs that touch the same value, per data type:

    weight reuse = N·E·F            (every output pixel in the batch)
    iact  reuse  = M·R·S / U²       (every out channel, every overlapping window)
    psum  reuse  = C·R·S            (accumulation depth)

Transformer matmuls are the degenerate case the paper warns about: R=S=E=F=1 —
reuse collapses onto N (weights), M (iacts) and C (psums) alone, which is
exactly why per-layer NoC/sharding flexibility matters.
"""
from __future__ import annotations

import dataclasses
from typing import Dict


@dataclasses.dataclass(frozen=True)
class LayerShape:
    """Paper Table-I dimensions. For GEMMs: N=tokens, C=in, M=out, rest 1."""
    name: str
    N: int = 1
    M: int = 1
    C: int = 1
    G: int = 1
    H: int = 1
    W: int = 1
    R: int = 1
    S: int = 1
    E: int = 1
    F: int = 1
    U: int = 1  # stride
    sparsity_w: float = 0.0   # fraction of zero weights
    sparsity_a: float = 0.0   # fraction of zero iacts

    @property
    def macs(self) -> int:
        return self.G * self.N * self.M * self.C * self.E * self.F * self.R * self.S

    @property
    def effective_macs(self) -> int:
        """MACs after zero-skipping both operands (paper §IV)."""
        return int(self.macs * (1 - self.sparsity_w) * (1 - self.sparsity_a))

    @property
    def weight_count(self) -> int:
        return self.G * self.M * self.C * self.R * self.S

    @property
    def iact_count(self) -> int:
        return self.G * self.N * self.C * self.H * self.W

    @property
    def psum_count(self) -> int:
        return self.G * self.N * self.M * self.E * self.F


def reuse(shape: LayerShape) -> Dict[str, float]:
    """MACs per value, for each of the paper's three data types."""
    return {
        "weight": shape.macs / max(shape.weight_count, 1),
        "iact": shape.macs / max(shape.iact_count, 1),
        "psum": shape.macs / max(shape.psum_count, 1),
    }


def gemm(name: str, tokens: int, c_in: int, m_out: int, groups: int = 1,
         sparsity_w: float = 0.0, sparsity_a: float = 0.0) -> LayerShape:
    """A transformer matmul as a LayerShape."""
    return LayerShape(name=name, N=tokens, C=c_in, M=m_out, G=groups,
                      sparsity_w=sparsity_w, sparsity_a=sparsity_a)


def conv(name: str, n: int, c: int, m: int, h: int, w: int, r: int, s: int,
         u: int = 1, groups: int = 1) -> LayerShape:
    e = (h - r) // u + 1
    f = (w - s) // u + 1
    return LayerShape(name=name, N=n, C=c, M=m, G=groups, H=h, W=w, R=r, S=s,
                      E=e, F=f, U=u)


# ----------------------------------------------------------- model → workload
def model_gemms(cfg, tokens: int, decode: bool = False):
    """Decompose an ArchConfig into its per-layer GEMM workloads (one pattern
    period + head/embed), for the planner. ``tokens`` = batch·seq per step."""
    out = []
    d, hd = cfg.d_model, cfg.head_dim
    H, KV = cfg.num_heads, cfg.num_kv_heads
    for j, kind in enumerate(cfg.attn_pattern):
        if kind in ("global", "local", "chunked"):
            out.append(gemm(f"l{j}.attn.q", tokens, d, H * hd))
            out.append(gemm(f"l{j}.attn.kv", tokens, d, 2 * KV * hd))
            out.append(gemm(f"l{j}.attn.o", tokens, H * hd, d))
            # score/context GEMMs: reduction over context length
            ctx = cfg.window_size if kind == "local" else (
                cfg.chunk_size if kind == "chunked" else tokens)
            out.append(gemm(f"l{j}.attn.qk", tokens, hd, min(ctx, tokens),
                            groups=H))
        elif kind == "ssm":
            di = cfg.d_inner
            out.append(gemm(f"l{j}.ssm.in", tokens, d,
                            2 * di + 2 * cfg.ssm_ngroups * cfg.ssm_state +
                            cfg.ssm_nheads))
            out.append(gemm(f"l{j}.ssm.out", tokens, di, d))
        elif kind == "rglru":
            w = cfg.lru_width
            out.append(gemm(f"l{j}.rglru.in", tokens, d, 2 * w))
            out.append(gemm(f"l{j}.rglru.out", tokens, w, d))
        if kind != "ssm":
            if cfg.is_moe_layer(j):
                # routed experts: the G dimension of Table I
                per_e = tokens * cfg.experts_per_token // cfg.num_experts
                out.append(gemm(f"l{j}.moe.up", max(per_e, 1), d, 2 * cfg.d_ff,
                                groups=cfg.num_experts))
                out.append(gemm(f"l{j}.moe.down", max(per_e, 1), cfg.d_ff, d,
                                groups=cfg.num_experts))
            else:
                ff = cfg.dense_d_ff or cfg.d_ff
                nup = 2 if cfg.mlp_gated else 1
                out.append(gemm(f"l{j}.mlp.up", tokens, d, nup * ff))
                out.append(gemm(f"l{j}.mlp.down", tokens, ff, d))
    out.append(gemm("lm_head", tokens, d, cfg.vocab_padded))
    return out
