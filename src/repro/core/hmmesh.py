"""HM-NoC modes mapped onto a (pod, data, model) TPU mesh (DESIGN.md §2).

The paper's four operating modes (Fig. 8) become tensor *layouts*:

    BROADCAST      — replicated on every chip (one copy multicast; max reuse)
    UNICAST        — fully sharded across all axes (unique data per chip; max bw)
    GROUPED_MC     — sharded over `model`, replicated over `data` (same data to a
                     group = a data-parallel replica row)
    INTERLEAVED_MC — sharded over `data`(+`pod`), replicated over `model`
                     (unique data interleaved across groups; e.g. ZeRO-3 shards)

Each *data type* (weights / iacts / psums) gets its own independently-chosen
mode, exactly as the paper runs three separate NoCs.
"""
from __future__ import annotations

import enum
from typing import Optional, Tuple

from jax.sharding import PartitionSpec as P


class Mode(enum.Enum):
    BROADCAST = "broadcast"
    UNICAST = "unicast"
    GROUPED_MC = "grouped_multicast"
    INTERLEAVED_MC = "interleaved_multicast"


# Mesh axis names (launch/mesh.py). `pod` is the inter-cluster mesh level.
POD_AXIS = "pod"
DATA_AXIS = "data"
MODEL_AXIS = "model"


def mode_axes(mode: Mode, multi_pod: bool) -> Tuple:
    """Which mesh axes a tensor dim is sharded over under each mode."""
    dp = (POD_AXIS, DATA_AXIS) if multi_pod else (DATA_AXIS,)
    if mode == Mode.BROADCAST:
        return ()
    if mode == Mode.UNICAST:
        return dp + (MODEL_AXIS,)
    if mode == Mode.GROUPED_MC:
        return (MODEL_AXIS,)
    if mode == Mode.INTERLEAVED_MC:
        return dp
    raise ValueError(mode)


def spec_for(mode: Mode, ndim: int, shard_dim: int, multi_pod: bool) -> P:
    """PartitionSpec placing the mode's axes on ``shard_dim`` of an ndim tensor."""
    axes = mode_axes(mode, multi_pod)
    entries: list = [None] * ndim
    if axes:
        entries[shard_dim] = axes if len(axes) > 1 else axes[0]
    return P(*entries)


def divisible(dim_size: int, mode: Mode, mesh_shape: dict, multi_pod: bool) -> bool:
    """Can ``dim_size`` be evenly sharded under ``mode`` on this mesh?"""
    n = 1
    for a in mode_axes(mode, multi_pod):
        n *= mesh_shape[a]
    return dim_size % n == 0 if n > 1 else True
