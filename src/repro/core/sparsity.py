"""Compressed-sparse-column formats + pruning (paper §IV, Fig. 16, Table III).

Two granularities:

* ``csc_encode/decode`` — the paper's exact scalar CSC: per column, 4-bit-style
  *count* (leading zeros since previous non-zero) + data vector, plus an
  *address* vector of per-column segment starts (repeated for empty columns).
  Used for format round-trip tests and compression-ratio studies.

* ``bcsc_encode`` — block-CSC, the TPU adaptation: the matrix is tiled into
  MXU-aligned (bk × bn) blocks; all-zero blocks are *skipped entirely* (the
  cycle-skipping analogue — DESIGN.md §2), non-zero blocks are stored dense.
  The Pallas kernel (kernels/bcsc_matmul.py) consumes this format via
  scalar-prefetched index vectors.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np


# ------------------------------------------------------------------ scalar CSC
@dataclasses.dataclass
class CSCMatrix:
    """Paper-exact CSC of a (rows × cols) matrix, column-major segments."""
    data: np.ndarray      # non-zero values
    count: np.ndarray     # leading zeros before each value (within its column)
    address: np.ndarray   # per-column start offsets, len cols+1
    shape: Tuple[int, int]
    count_bits: int = 4

    @property
    def nnz(self) -> int:
        return int(self.data.size)

    def storage_bits(self, data_bits: int = 8, addr_bits: int = 16) -> int:
        return (self.nnz * (data_bits + self.count_bits) +
                self.address.size * addr_bits)

    def compression_ratio(self, data_bits: int = 8) -> float:
        dense_bits = self.shape[0] * self.shape[1] * data_bits
        return dense_bits / max(self.storage_bits(data_bits), 1)


def csc_encode(mat: np.ndarray, count_bits: int = 4) -> CSCMatrix:
    """Encode column-by-column. Counts exceeding the bit budget are handled the
    way RLC does: an explicit zero entry is emitted (padding value 0)."""
    rows, cols = mat.shape
    max_count = (1 << count_bits) - 1
    data, count, address = [], [], [0]
    for c in range(cols):
        col = mat[:, c]
        run = 0
        for r in range(rows):
            v = col[r]
            if v == 0:
                run += 1
                if run > max_count:          # overflow → emit explicit zero
                    data.append(0)
                    count.append(max_count)
                    run = 0
            else:
                data.append(v)
                count.append(run)
                run = 0
        address.append(len(data))
    return CSCMatrix(np.asarray(data), np.asarray(count, np.int32),
                     np.asarray(address, np.int64), (rows, cols), count_bits)


def csc_decode(m: CSCMatrix) -> np.ndarray:
    rows, cols = m.shape
    out = np.zeros((rows, cols), dtype=np.asarray(m.data).dtype)
    for c in range(cols):
        r = 0
        for i in range(m.address[c], m.address[c + 1]):
            r += int(m.count[i])
            out[r, c] = m.data[i]
            r += 1
    return out


# ------------------------------------------------------------------- block CSC
@dataclasses.dataclass
class BCSCMatrix:
    """Block-CSC: (K×N) matrix tiled into (bk×bn) blocks, zero blocks skipped.

    blocks   (nnzb, bk, bn)  dense payload of non-zero blocks
    row_ids  (nnzb,)         block-row index of each payload block
    col_ptr  (nbn+1,)        block-column segment starts (CSC address vector)
    """
    blocks: jnp.ndarray
    row_ids: jnp.ndarray
    col_ptr: jnp.ndarray
    shape: Tuple[int, int]
    block: Tuple[int, int]

    @property
    def nnzb(self) -> int:
        return int(self.blocks.shape[0])

    @property
    def density(self) -> float:
        nb = (self.shape[0] // self.block[0]) * (self.shape[1] // self.block[1])
        return self.nnzb / max(nb, 1)


def bcsc_encode(mat, bk: int, bn: int) -> BCSCMatrix:
    """Host-side encode (compile-time, like the paper's known weight sparsity)."""
    m = np.asarray(mat)
    K, N = m.shape
    assert K % bk == 0 and N % bn == 0, (K, N, bk, bn)
    nbk, nbn = K // bk, N // bn
    tiles = m.reshape(nbk, bk, nbn, bn).transpose(2, 0, 1, 3)   # (nbn,nbk,bk,bn)
    nz = np.abs(tiles).sum(axis=(2, 3)) > 0                      # (nbn,nbk)
    blocks, row_ids, col_ptr = [], [], [0]
    for c in range(nbn):
        for r in range(nbk):
            if nz[c, r]:
                blocks.append(tiles[c, r])
                row_ids.append(r)
        col_ptr.append(len(blocks))
    if not blocks:  # degenerate all-zero matrix: keep one zero block
        blocks = [np.zeros((bk, bn), m.dtype)]
        row_ids = [0]
        col_ptr = [0] + [1] * nbn
    return BCSCMatrix(jnp.asarray(np.stack(blocks)),
                      jnp.asarray(np.asarray(row_ids, np.int32)),
                      jnp.asarray(np.asarray(col_ptr, np.int32)),
                      (K, N), (bk, bn))


def bcsc_decode(m: BCSCMatrix) -> np.ndarray:
    K, N = m.shape
    bk, bn = m.block
    out = np.zeros((K, N), dtype=np.asarray(m.blocks).dtype)
    col_ptr = np.asarray(m.col_ptr)
    row_ids = np.asarray(m.row_ids)
    blocks = np.asarray(m.blocks)
    for c in range(N // bn):
        for i in range(col_ptr[c], col_ptr[c + 1]):
            r = row_ids[i]
            out[r * bk:(r + 1) * bk, c * bn:(c + 1) * bn] = blocks[i]
    return out


# -------------------------------------------------------------------- pruning
def magnitude_prune(w, sparsity: float):
    """Zero the smallest |w| entries (paper refs [13]); returns pruned array."""
    flat = jnp.abs(w).ravel()
    k = int(flat.size * sparsity)
    if k == 0:
        return w
    thresh = jnp.sort(flat)[k - 1]
    return jnp.where(jnp.abs(w) > thresh, w, 0)


def block_magnitude_prune(w, sparsity: float, bk: int, bn: int):
    """Prune whole (bk×bn) blocks by L2 norm — structured so BCSC skipping
    translates to real MXU-tile savings (the TPU-native 'skip')."""
    K, N = w.shape
    assert K % bk == 0 and N % bn == 0
    tiles = w.reshape(K // bk, bk, N // bn, bn)
    norms = jnp.sqrt(jnp.sum(jnp.square(tiles.astype(jnp.float32)),
                             axis=(1, 3)))
    k = int(norms.size * sparsity)
    if k == 0:
        return w
    thresh = jnp.sort(norms.ravel())[k - 1]
    mask = (norms > thresh)[:, None, :, None]
    return (tiles * mask).reshape(K, N)


def prune_params(params, sparsity: float, min_size: int = 4096):
    """Magnitude-prune every ≥2D weight in a params pytree (sparse-model maker)."""
    def prune_leaf(path, x):
        name = path[-1].key if hasattr(path[-1], "key") else str(path[-1])
        if x.ndim >= 2 and x.size >= min_size and "norm" not in name.lower() \
                and name != "embed":
            return magnitude_prune(x, sparsity)
        return x
    return jax.tree_util.tree_map_with_path(prune_leaf, params)


def sparsity_stats(params) -> Dict[str, float]:
    total = nz = 0
    for x in jax.tree.leaves(params):
        total += x.size
        nz += int(jnp.count_nonzero(x))
    return {"total": float(total), "nonzero": float(nz),
            "sparsity": 1.0 - nz / max(total, 1)}
