"""Eyexam (paper Appendix A) — performance-bound analysis + TPU roofline.

Two halves:

1. ``seven_steps`` — the paper's step-by-step tightening of the performance
   bound (workload → dataflow → #PEs → array shape → storage → avg bandwidth),
   used by ``benchmarks/scaling.py`` to reproduce Fig. 14 and Fig. 27.

2. ``roofline_from_compiled`` — the three-term TPU roofline extracted from the
   multi-pod dry-run's compiled artifact:

       compute    = HLO_FLOPs  / (peak_FLOP/s per chip)
       memory     = HLO_bytes  / (HBM GB/s per chip)
       collective = Σ collective operand bytes / (ICI link GB/s per chip)

   ``cost_analysis`` supplies FLOPs/bytes; collective bytes are parsed from the
   post-SPMD HLO text (the compiled module is the per-chip program, so all
   three terms are already per-chip).
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional

# ----------------------------------------------------------- TPU v5e constants
PEAK_FLOPS = 197e12          # bf16 FLOP/s per chip
HBM_BW = 819e9               # bytes/s per chip
ICI_BW = 50e9                # bytes/s per link (spec: ~50 GB/s/link)
HBM_CAP = 16e9               # bytes per chip

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1,
    "f8e5m2": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "token": 0,
}

COLLECTIVE_OPS = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                  "collective-permute")

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_INSTR_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(\([^)]*\)|[a-z0-9]+\[[0-9,]*\][^ ]*)\s+"
    r"([\w\-]+)(\(.*)$")


def _shape_bytes(shape_str: str) -> int:
    """Total bytes of an HLO shape string (handles tuples)."""
    total = 0
    for dtype, dims in _SHAPE_RE.findall(shape_str):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                if d:
                    n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def collective_bytes(hlo_text: str) -> Dict[str, int]:
    """Sum operand bytes of every collective in (post-SPMD, per-chip) HLO text.

    Builds a name→shape symbol table line by line, then for each collective
    instruction sums the shapes of its operands.
    """
    shapes: Dict[str, str] = {}
    totals: Dict[str, int] = {op: 0 for op in COLLECTIVE_OPS}
    counts: Dict[str, int] = {op: 0 for op in COLLECTIVE_OPS}
    for line in hlo_text.splitlines():
        m = _INSTR_RE.match(line)
        if not m:
            continue
        name, shape_str, op, rest = m.groups()
        shapes[name] = shape_str
        op_base = op.rstrip("0123456789.")
        # strip -start/-done variants (async collectives)
        for c in COLLECTIVE_OPS:
            if op_base == c or op_base == c + "-start":
                # operand names: %foo.123 inside the parens
                operands = re.findall(r"%([\w.\-]+)", rest)
                b = 0
                for o in operands:
                    if o in shapes:
                        b += _shape_bytes(shapes[o])
                if b == 0:  # fall back to result shape
                    b = _shape_bytes(shape_str)
                totals[c] += b
                counts[c] += 1
                break
    totals["_counts"] = counts  # type: ignore
    return totals


@dataclasses.dataclass
class Roofline:
    flops: float
    hbm_bytes: float
    coll_bytes: float
    per_op_coll: Dict[str, int]
    chips: int

    @property
    def t_compute(self) -> float:
        return self.flops / PEAK_FLOPS

    @property
    def t_memory(self) -> float:
        return self.hbm_bytes / HBM_BW

    @property
    def t_collective(self) -> float:
        return self.coll_bytes / ICI_BW

    @property
    def bound(self) -> str:
        terms = {"compute": self.t_compute, "memory": self.t_memory,
                 "collective": self.t_collective}
        return max(terms, key=terms.get)  # type: ignore

    @property
    def t_total(self) -> float:
        """Optimistic fully-overlapped step time."""
        return max(self.t_compute, self.t_memory, self.t_collective)

    def fraction_of_roofline(self, useful_flops: float) -> float:
        """useful_flops (per chip) / peak over the bound-implied time."""
        if self.t_total <= 0:
            return 0.0
        return (useful_flops / self.t_total) / PEAK_FLOPS

    def summary(self) -> Dict[str, float]:
        return {
            "t_compute_s": self.t_compute,
            "t_memory_s": self.t_memory,
            "t_collective_s": self.t_collective,
            "bound": self.bound,
            "flops": self.flops,
            "hbm_bytes": self.hbm_bytes,
            "coll_bytes": self.coll_bytes,
        }


def roofline_from_compiled(compiled, chips: int,
                           hlo_text: Optional[str] = None) -> Roofline:
    """Three-term roofline from the compiled per-chip module.

    Uses core.hloparse (call-graph walk with while-loop trip-count
    multiplication) because ``cost_analysis()`` counts scan bodies once —
    see hloparse module docstring for the traffic model.
    """
    from repro.core import hloparse
    text = hlo_text if hlo_text is not None else compiled.as_text()
    cost = hloparse.analyze(text)
    return Roofline(flops=cost.flops, hbm_bytes=cost.hbm_bytes,
                    coll_bytes=cost.total_coll_bytes,
                    per_op_coll={**{k: int(v) for k, v in
                                    cost.coll_bytes.items()},
                                 "counts": {k: int(v) for k, v in
                                            cost.coll_counts.items()}},
                    chips=chips)


# =============================================================== seven steps
@dataclasses.dataclass
class AcceleratorModel:
    """Abstract accelerator for the analytical model (paper Fig. 23).

    noc: 'broadcast' (Eyeriss v1: one value/cycle/type from GLB regardless of
    scale) or 'hmnoc' (Eyeriss v2: one value/cycle/type *per cluster*).
    """
    n_pes: int
    array_h: int
    array_w: int
    noc: str = "hmnoc"
    cluster_size: int = 16        # PEs per cluster (v2: 4×4 in §III-D)
    macs_per_pe: int = 1
    spad_weights: int = 192       # max weights resident per PE (§IV)
    glb_bw_words: float = 1.0     # words/cycle/data-type from GLB source

    @property
    def n_clusters(self) -> int:
        return max(self.n_pes // self.cluster_size, 1)


def seven_steps(shape, acc: AcceleratorModel) -> List[Dict]:
    """Performance bound (MACs/cycle) after each Eyexam step for one layer.

    Row-stationary-flavored mapping: spatial dims are (C·R groups) × (M, E·F).
    Returns a list of dicts with the bound after steps 1..6.
    """
    steps = []
    macs = shape.macs
    # Step 1: layer size — all-parallel bound
    b1 = macs
    steps.append({"step": 1, "name": "layer shape", "bound": b1})
    # Step 2: dataflow (RS): parallelism across M·E·F·G·C·R (row-level)
    dataflow_par = shape.G * shape.M * shape.E * shape.F * shape.C * shape.R
    b2 = min(b1, dataflow_par)
    steps.append({"step": 2, "name": "dataflow", "bound": b2})
    # Step 3: finite PEs
    b3 = min(b2, acc.n_pes * acc.macs_per_pe)
    steps.append({"step": 3, "name": "#PEs", "bound": b3})
    # Step 4: physical array shape — fold (G·E·F) onto width, (M·C·R) onto height
    w_par = shape.G * shape.E * shape.F
    h_par = shape.M * shape.C * shape.R
    active_w = min(acc.array_w, w_par)
    active_h = min(acc.array_h, h_par)
    b4 = min(b3, active_w * active_h * acc.macs_per_pe)
    steps.append({"step": 4, "name": "array dims", "bound": b4,
                  "active_pes": active_w * active_h})
    # Step 5: storage — weights resident per PE cap (paper Table III)
    w_per_pe = shape.weight_count / max(active_w * active_h, 1)
    if w_per_pe > acc.spad_weights:
        b5 = b4  # needs temporal passes; bound unchanged, utilization later
    else:
        b5 = b4
    steps.append({"step": 5, "name": "storage", "bound": b5})
    # Step 6: average NoC bandwidth
    r = {"weight": macs / max(shape.weight_count, 1),
         "iact": macs / max(shape.iact_count, 1)}
    if acc.noc == "broadcast":
        src_bw = acc.glb_bw_words                   # does NOT scale (v1)
    else:
        src_bw = acc.glb_bw_words * acc.n_clusters  # scales with clusters (v2)
    # deliverable MACs/cycle limited by each data type: bw · reuse
    bw_bound = min(src_bw * r["weight"], src_bw * r["iact"])
    b6 = min(b5, bw_bound)
    steps.append({"step": 6, "name": "NoC bandwidth", "bound": b6})
    return steps


def layer_cycles(shape, acc: AcceleratorModel) -> float:
    """Cycles for one layer under the step-6 bound (the Fig. 14 model)."""
    bound = seven_steps(shape, acc)[-1]["bound"]
    return shape.macs / max(bound, 1e-9)


def network_performance(layers_: List, acc: AcceleratorModel) -> float:
    """End-to-end MACs/cycle over a whole network (batch already in shapes)."""
    total_macs = sum(s.macs for s in layers_)
    total_cycles = sum(layer_cycles(s, acc) for s in layers_)
    return total_macs / max(total_cycles, 1e-9)
