"""Structural cost parser for post-SPMD compiled HLO text.

Why this exists: ``compiled.cost_analysis()`` counts each while-loop *body
once*, but every ``lax.scan`` in the model (period stack, loss chunks, SSD
chunks, RG-LRU sequence scan, microbatch accumulation) is a while loop — so
its FLOP/byte numbers understate scanned work by the trip count. This parser
walks the computation call graph, multiplies every computation's cost by its
execution count (entry=1; while body/cond ×trip; fusion/call inherit caller),
and emits the three roofline inputs:

  * ``flops``      — dot/convolution FLOPs (elementwise excluded: MXU roofline)
  * ``hbm_bytes``  — fusion-boundary traffic model: operand+result bytes of
    materializing ops (dot/conv/reduce/fusion/copy/collective;
    dynamic-slice/DUS counted at slice granularity), parameters/constants/
    GTE/tuple/bitcast free. Elementwise inside fusions is VMEM-internal.
  * ``coll_bytes`` — per collective-op operand bytes (the ICI term).

Trip counts are read from each while condition's integer constant (scan
lowness: induction var starts at 0, compares LT bound).
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s2": 1, "s4": 1, "u2": 1, "u4": 1, "s8": 1, "u8": 1,
    "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3b11fnuz": 1, "s16": 2, "u16": 2,
    "bf16": 2, "f16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8,
    "f64": 8, "c64": 8, "c128": 16, "token": 0, "opaque": 0,
}

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_NAME_RE = re.compile(r"\s*%?([\w.\-]+)")
_OPCODE_RE = re.compile(r"^([a-z][\w\-]*)\(")
_CALLED_RE = re.compile(
    r"(?:calls|to_apply|body|condition|branch_computations)=\{?%?([\w.\-,% ]+)\}?")
_CONST_RE = re.compile(r"constant\((\d+)\)")
_TRIP_RE = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')


def _parse_shape(shape_str: str) -> List[Tuple[str, List[int]]]:
    out = []
    for dtype, dims in _SHAPE_RE.findall(shape_str):
        if dtype not in _DTYPE_BYTES:
            continue
        d = [int(x) for x in dims.split(",") if x] if dims else []
        out.append((dtype, d))
    return out


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for dtype, dims in _parse_shape(shape_str):
        n = 1
        for d in dims:
            n *= d
        total += n * _DTYPE_BYTES[dtype]
    return total


def _shape_dims(shape_str: str) -> List[int]:
    parsed = _parse_shape(shape_str)
    return parsed[0][1] if parsed else []


@dataclasses.dataclass
class Instr:
    name: str
    shape_str: str
    opcode: str
    rest: str                      # operand list + attributes (raw tail)

    def _operand_region(self) -> str:
        depth = 0
        end = len(self.rest)
        for i, ch in enumerate(self.rest):
            if ch == "(":
                depth += 1
            elif ch == ")":
                if depth == 0:
                    end = i
                    break
                depth -= 1
        return self.rest[:end]

    def operand_names(self) -> List[str]:
        return re.findall(r"%([\w.\-]+)", self._operand_region())

    def operand_shapes(self, sym: Dict[str, str]) -> List[str]:
        """Operand shapes resolved through the computation's symbol table."""
        return [sym[n] for n in self.operand_names() if n in sym]

    def attr_ints(self, attr: str) -> List[int]:
        m = re.search(attr + r"=\{([0-9,]*)\}", self.rest)
        if not m:
            return []
        return [int(x) for x in m.group(1).split(",") if x]

    def called(self) -> List[str]:
        out = []
        for m in _CALLED_RE.finditer(self.rest):
            for name in m.group(1).split(","):
                out.append(name.strip().lstrip("%"))
        return out


def _parse_instr(line: str) -> Optional[Instr]:
    """Parse one instruction line. Handles tuple result shapes containing
    ``/*index=N*/`` comments (which break any single-regex approach)."""
    ls = line.strip()
    if ls.startswith("ROOT "):
        ls = ls[5:]
    if not ls.startswith("%"):
        return None
    eq = ls.find(" = ")
    if eq < 0:
        return None
    name = ls[1:eq]
    rest = ls[eq + 3:]
    if rest.startswith("("):                      # tuple shape: balanced parens
        depth = 0
        end = 0
        for i, ch in enumerate(rest):
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    end = i
                    break
        shape, tail = rest[:end + 1], rest[end + 1:].lstrip()
    else:
        sp = rest.find(" ")
        if sp < 0:
            return None
        shape, tail = rest[:sp], rest[sp + 1:].lstrip()
    m = _OPCODE_RE.match(tail)
    if not m:
        return None
    opcode = m.group(1)
    return Instr(name, shape, opcode, tail[len(opcode) + 1:])


def _header_name(line: str) -> Optional[str]:
    """Computation header: ``[ENTRY] %name (params) -> retshape {``."""
    ls = line.strip()
    if not ls.endswith("{") or "->" not in ls or " = " in ls:
        return None
    if ls.startswith("ENTRY"):
        ls = ls[len("ENTRY"):]
    m = _NAME_RE.match(ls)
    return m.group(1) if m else None


def parse_computations(hlo_text: str) -> Tuple[Dict[str, List[Instr]],
                                               Optional[str]]:
    comps: Dict[str, List[Instr]] = {}
    current: Optional[str] = None
    entry: Optional[str] = None
    for line in hlo_text.splitlines():
        name = _header_name(line)
        if name is not None:
            current = name
            comps[current] = []
            if line.lstrip().startswith("ENTRY"):
                entry = name
            continue
        if line.strip() == "}":
            current = None
            continue
        if current is None:
            continue
        ins = _parse_instr(line)
        if ins is not None:
            comps[current].append(ins)
    return comps, entry


def _trip_count(cond: str, comps: Dict[str, List[Instr]],
                depth: int = 0) -> int:
    """Largest integer constant in the while condition (scan bound: induction
    var starts at 0, compares LT bound). Descends into fused comparisons."""
    best = 1
    if depth > 3:
        return best
    for ins in comps.get(cond, []):
        if ins.opcode == "constant" and ins.shape_str.split("[")[0] in (
                "s8", "s16", "s32", "s64", "u8", "u16", "u32", "u64"):
            m = re.match(r"(\d+)", ins.rest)
            if m:
                best = max(best, int(m.group(1)))
        for m in _CONST_RE.finditer(ins.rest):
            best = max(best, int(m.group(1)))
        for callee in ins.called():
            best = max(best, _trip_count(callee, comps, depth + 1))
    return best


def _dot_flops(ins: Instr, sym: Dict[str, str]) -> float:
    result = _shape_dims(ins.shape_str)
    n_out = 1
    for d in result:
        n_out *= d
    ops = ins.operand_shapes(sym)
    if not ops:
        return 0.0
    lhs = _shape_dims(ops[0])
    contract = ins.attr_ints("lhs_contracting_dims")
    k = 1
    for i in contract:
        if i < len(lhs):
            k *= lhs[i]
    return 2.0 * n_out * max(k, 1)


def _conv_flops(ins: Instr, sym: Dict[str, str]) -> float:
    result = _shape_dims(ins.shape_str)
    n_out = 1
    for d in result:
        n_out *= d
    ops = ins.operand_shapes(sym)
    if len(ops) < 2:
        return 0.0
    kernel = _shape_dims(ops[1])
    k = 1
    for d in kernel[:-1]:     # all dims but output-feature (layout-approximate)
        k *= d
    fg = re.search(r"feature_group_count=(\d+)", ins.rest)
    groups = int(fg.group(1)) if fg else 1
    return 2.0 * n_out * max(k // max(groups, 1), 1)


_FREE_OPS = {"parameter", "constant", "get-tuple-element", "tuple", "bitcast",
             "iota", "after-all", "partition-id", "replica-id", "reshape",
             "broadcast"}


def _fusion_bytes(ins: Instr, sym: Dict[str, str],
                  comps: Dict[str, List[Instr]]) -> int:
    """Boundary bytes of one fusion call.

    Special case: a fusion whose root is a dynamic-update-slice is an
    IN-PLACE update (KV-cache append, grad accumulation slot) — it touches
    O(update-slice) bytes, not the whole buffer. Counting operands+result
    would charge the full cache per decode step (measured: 84% of the decode
    memory term was this artifact).
    """
    for callee in ins.called():
        instrs = comps.get(callee, [])
        if not instrs:
            continue
        root = instrs[-1]
        if root.opcode == "dynamic-update-slice":
            csym = {i.name: i.shape_str for i in instrs}
            ops_ = root.operand_shapes(csym)
            upd = _shape_bytes(ops_[1]) if len(ops_) > 1 else 0
            # small side inputs (indices, scalars) are negligible
            return 2 * upd
    return _shape_bytes(ins.shape_str) + sum(
        _shape_bytes(s) for s in ins.operand_shapes(sym))

# Elementwise ops the CPU backend leaves at top level but a TPU compile would
# fuse into neighbours — their traffic is VMEM-internal on the target, so the
# HBM model treats them as free (documented in EXPERIMENTS.md methodology).
_ELEMENTWISE = {
    "add", "subtract", "multiply", "divide", "power", "maximum", "minimum",
    "and", "or", "xor", "not", "negate", "abs", "sign", "compare", "select",
    "convert", "exponential", "exponential-minus-one", "log", "log-plus-one",
    "tanh", "sqrt", "rsqrt", "cbrt", "sine", "cosine", "floor", "ceil",
    "round-nearest-afz", "round-nearest-even", "clamp", "is-finite",
    "shift-left", "shift-right-logical", "shift-right-arithmetic",
    "remainder", "atan2", "expm1", "logistic", "erf", "clz", "popcnt",
}


@dataclasses.dataclass
class HLOCost:
    flops: float = 0.0
    hbm_bytes: float = 0.0
    coll_bytes: Dict[str, float] = dataclasses.field(
        default_factory=lambda: {c: 0.0 for c in COLLECTIVES})
    coll_counts: Dict[str, float] = dataclasses.field(
        default_factory=lambda: {c: 0.0 for c in COLLECTIVES})
    trip_counts: Dict[str, int] = dataclasses.field(default_factory=dict)

    @property
    def total_coll_bytes(self) -> float:
        return sum(self.coll_bytes.values())


def analyze(hlo_text: str) -> HLOCost:
    comps, entry = parse_computations(hlo_text)
    if entry is None or entry not in comps:     # fall back: biggest computation
        entry = max(comps, key=lambda c: len(comps[c])) if comps else None
    cost = HLOCost()
    if entry is None:
        return cost
    _walk(entry, 1.0, comps, cost, flops_only=False, seen=set())
    return cost


def _base_op(opcode: str) -> str:
    op = opcode
    for c in COLLECTIVES:
        if op == c or op == c + "-start" or op == c + "-done":
            return c
    return op


def _walk(comp: str, count: float, comps: Dict[str, List[Instr]],
          cost: HLOCost, flops_only: bool, seen: set):
    """Accumulate costs of one computation × count.

    flops_only=True inside fusion bodies: their byte traffic is VMEM-internal
    (the fusion instruction at the caller already paid the boundary bytes),
    but dots fused into them still burn MXU flops.
    """
    instrs = comps.get(comp, [])
    sym = {ins.name: ins.shape_str for ins in instrs}
    for ins in instrs:
        op = ins.opcode
        base = _base_op(op)

        if op == "while":
            called = ins.called()
            m_body = re.search(r"body=%?([\w.\-]+)", ins.rest)
            m_cond = re.search(r"condition=%?([\w.\-]+)", ins.rest)
            body = m_body.group(1) if m_body else (called[0] if called else None)
            cond = m_cond.group(1) if m_cond else None
            m_trip = _TRIP_RE.search(ins.rest)       # XLA's own trip analysis
            if m_trip:
                trip = int(m_trip.group(1))
            else:
                trip = _trip_count(cond, comps) if cond else 1
            cost.trip_counts[body or "?"] = trip
            if body:
                _walk(body, count * trip, comps, cost, flops_only, seen)
            if cond:
                _walk(cond, count * trip, comps, cost, True, seen)
            continue

        if op in ("fusion",):
            for callee in ins.called():
                _walk(callee, count, comps, cost, True, seen)
            if not flops_only:
                b = _fusion_bytes(ins, sym, comps)
                cost.hbm_bytes += count * b
            continue

        if op in ("call", "conditional", "async-start"):
            for callee in ins.called():
                _walk(callee, count, comps, cost, flops_only, seen)
            continue

        if op == "dot":
            cost.flops += count * _dot_flops(ins, sym)
            if not flops_only:
                b = _shape_bytes(ins.shape_str) + sum(
                    _shape_bytes(s) for s in ins.operand_shapes(sym))
                cost.hbm_bytes += count * b
            continue

        if op == "convolution":
            cost.flops += count * _conv_flops(ins, sym)
            if not flops_only:
                b = _shape_bytes(ins.shape_str) + sum(
                    _shape_bytes(s) for s in ins.operand_shapes(sym))
                cost.hbm_bytes += count * b
            continue

        if base in COLLECTIVES:
            ob = sum(_shape_bytes(s) for s in ins.operand_shapes(sym))
            if ob == 0:
                ob = _shape_bytes(ins.shape_str)
            cost.coll_bytes[base] += count * ob
            cost.coll_counts[base] += count
            if not flops_only:
                cost.hbm_bytes += count * (ob + _shape_bytes(ins.shape_str))
            # reduction computations attached to all-reduce: negligible
            continue

        if flops_only or op in _FREE_OPS or op in _ELEMENTWISE:
            continue

        if op == "dynamic-slice":
            cost.hbm_bytes += count * 2 * _shape_bytes(ins.shape_str)
            continue
        if op in ("dynamic-update-slice",):
            ops_ = ins.operand_shapes(sym)
            upd = _shape_bytes(ops_[1]) if len(ops_) > 1 else \
                _shape_bytes(ins.shape_str)
            cost.hbm_bytes += count * 2 * upd
            continue
        if op == "copy":
            cost.hbm_bytes += count * 2 * _shape_bytes(ins.shape_str)
            continue
        # materializing ops: reduce/transpose/concat/gather/... and anything
        # unrecognized — count fusion-boundary operand+result bytes
        b = _shape_bytes(ins.shape_str) + sum(
            _shape_bytes(s) for s in ins.operand_shapes(sym))
        cost.hbm_bytes += count * b
