"""Atomic, mesh-agnostic checkpointing with elastic restore.

 * **Atomic**: state is written to ``step_XXXX.tmp/`` then os.rename'd —
   a crash mid-write can never corrupt the latest checkpoint.
 * **Mesh-agnostic**: leaves are stored by *logical* shape (npz per leaf,
   flattened path → file). Restore device_puts each leaf against whatever
   shardings the *current* mesh/plan dictates — a checkpoint written on
   2×16×16 restores onto 16×16 (or a degraded 2×15×16 replacement mesh)
   without conversion. This is the elastic-scaling path (runtime.elastic).
 * **Retention**: keep the newest ``keep`` checkpoints.
"""
from __future__ import annotations

import json
import os
import re
import shutil
import time
from typing import Any, Dict, List, Optional, Tuple

import jax
import numpy as np


_SEP = "__"


def _entry_name(e) -> str:
    """Path-entry name for DictKey/SequenceKey/GetAttrKey/FlattenedIndexKey."""
    for attr in ("key", "idx", "name"):
        if hasattr(e, attr):
            return str(getattr(e, attr))
    return str(e)


def _flatten(tree) -> Dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = _SEP.join(_entry_name(p) for p in path)
        flat[key] = np.asarray(leaf)
    return flat


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3):
        self.directory = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)

    # ------------------------------------------------------------------ save
    def save(self, step: int, state: Any, extra: Optional[Dict] = None) -> str:
        final = os.path.join(self.directory, f"step_{step:08d}")
        tmp = final + ".tmp"
        if os.path.exists(tmp):
            shutil.rmtree(tmp)
        os.makedirs(tmp)
        flat = _flatten(state)
        np.savez(os.path.join(tmp, "arrays.npz"), **flat)
        manifest = {
            "step": step,
            "time": time.time(),
            "keys": sorted(flat.keys()),
            "shapes": {k: list(v.shape) for k, v in flat.items()},
            "dtypes": {k: str(v.dtype) for k, v in flat.items()},
            "extra": extra or {},
        }
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
        f_dir = os.open(tmp, os.O_RDONLY)
        try:
            os.fsync(f_dir)
        finally:
            os.close(f_dir)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)
        self._gc()
        return final

    # ----------------------------------------------------------------- load
    def steps(self) -> List[int]:
        out = []
        for name in os.listdir(self.directory):
            m = re.fullmatch(r"step_(\d+)", name)
            if m:
                out.append(int(m.group(1)))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        s = self.steps()
        return s[-1] if s else None

    def restore(self, target: Any, step: Optional[int] = None,
                shardings=None) -> Tuple[Any, Dict]:
        """Restore into the structure of ``target`` (values ignored; may be
        ShapeDtypeStructs). ``shardings``: optional congruent pytree of
        NamedShardings for the *current* mesh (elastic re-shard)."""
        step = self.latest_step() if step is None else step
        assert step is not None, "no checkpoint found"
        path = os.path.join(self.directory, f"step_{step:08d}")
        with open(os.path.join(path, "manifest.json")) as f:
            manifest = json.load(f)
        arrays = np.load(os.path.join(path, "arrays.npz"))

        paths, treedef = jax.tree_util.tree_flatten_with_path(target)
        shard_leaves = (jax.tree.leaves(shardings)
                        if shardings is not None else [None] * len(paths))
        leaves = []
        for (p, leaf), shd in zip(paths, shard_leaves):
            key = _SEP.join(_entry_name(e) for e in p)
            arr = arrays[key]
            assert tuple(arr.shape) == tuple(leaf.shape), (key, arr.shape,
                                                           leaf.shape)
            if shd is not None:
                leaves.append(jax.device_put(arr, shd))
            else:
                leaves.append(jax.numpy.asarray(arr))
        return jax.tree.unflatten(treedef, leaves), manifest

    # ------------------------------------------------------------------- gc
    def _gc(self):
        steps = self.steps()
        for s in steps[:-self.keep]:
            shutil.rmtree(os.path.join(self.directory, f"step_{s:08d}"),
                          ignore_errors=True)
