"""Core neural-net layers (pure JAX, functional params-in/params-out style).

Everything computes in bf16 with fp32 accumulation (``preferred_element_type``),
normalizations and softmax in fp32 — the TPU analogue of the paper's
8b MAC / 20b psum precision pair (DESIGN.md §7).
"""
from __future__ import annotations

import contextvars
import math
from typing import Optional

import jax
import jax.numpy as jnp

COMPUTE_DTYPE = jnp.bfloat16
PARAM_DTYPE = jnp.float32
ACCUM_DTYPE = jnp.float32


def cast_compute(x):
    return x.astype(COMPUTE_DTYPE)


# ------------------------------------------------------- sharding-hints context
# Set around tracing by launch/cell.py (sharding.autoshard.ShardingHints).
# Layer internals pin their projection outputs to the planner's NoC mode via
# constrain_tokens; a None context (CPU smoke tests) is a no-op.
_HINTS: contextvars.ContextVar = contextvars.ContextVar("hints", default=None)


def set_hints(hints):
    return _HINTS.set(hints)


def reset_hints(token):
    _HINTS.reset(token)


def constrain(x, tp_dim: Optional[int] = None, tp_check=None,
              batch_dim: int = 0, tp_candidates=None,
              widen_batch: bool = False):
    h = _HINTS.get()
    if h is None:
        return x
    return h.constrain_tokens(x, tp_dim=tp_dim, tp_check=tp_check,
                              batch_dim=batch_dim,
                              tp_candidates=tp_candidates,
                              widen_batch=widen_batch)


# --------------------------------------------------------------------------- init
def dense_init(rng, shape, in_axis=0):
    fan_in = shape[in_axis] if isinstance(in_axis, int) else 1
    std = 1.0 / math.sqrt(max(fan_in, 1))
    return (jax.random.normal(rng, shape, dtype=jnp.float32) * std).astype(PARAM_DTYPE)


def embed_init(rng, shape):
    return (jax.random.normal(rng, shape, dtype=jnp.float32)).astype(PARAM_DTYPE)


# --------------------------------------------------------------------------- norm
def rms_norm(x, scale, eps: float):
    xf = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps)
    return (y * (1.0 + scale.astype(jnp.float32))).astype(x.dtype)


def head_rms_norm(x, scale, eps: float):
    """qk-norm: normalize over the head_dim axis of (..., D)."""
    xf = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps)
    return (y * (1.0 + scale.astype(jnp.float32))).astype(x.dtype)


# --------------------------------------------------------------------------- rope
def rope(x, positions, theta: float):
    """Rotary embedding. x: (..., S, H, D); positions: (..., S) int32."""
    d = x.shape[-1]
    half = d // 2
    freq = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    angles = positions[..., :, None].astype(jnp.float32) * freq  # (..., S, half)
    sin = jnp.sin(angles)[..., :, None, :]  # broadcast over heads
    cos = jnp.cos(angles)[..., :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def sinusoidal_pos(positions, d_model: int):
    """(..., S) int32 -> (..., S, d) sinusoidal table (musicgen)."""
    half = d_model // 2
    freq = jnp.exp(-math.log(10_000.0) * jnp.arange(half, dtype=jnp.float32) / half)
    ang = positions[..., None].astype(jnp.float32) * freq
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


# ---------------------------------------------------------------------- softcap
def softcap(logits, cap: float):
    if cap and cap > 0.0:
        logits = jnp.tanh(logits / cap) * cap
    return logits


# ------------------------------------------------------------------- attention
NEG_INF = -2.0e38


def _split_heads(x, n, d):
    return x.reshape(x.shape[:-1] + (n, d))


def attn_qkv(params, x, cfg):
    """Project to q,k,v. x: (B,S,d). Returns q (B,S,H,D), k/v (B,S,KV,D)."""
    q = jnp.einsum("bsd,dhk->bshk", x, cast_compute(params["wq"]),
                   preferred_element_type=ACCUM_DTYPE)
    k = jnp.einsum("bsd,dhk->bshk", x, cast_compute(params["wk"]),
                   preferred_element_type=ACCUM_DTYPE)
    v = jnp.einsum("bsd,dhk->bshk", x, cast_compute(params["wv"]),
                   preferred_element_type=ACCUM_DTYPE)
    if cfg.qkv_bias:
        q = q + params["bq"].astype(ACCUM_DTYPE)
        k = k + params["bk"].astype(ACCUM_DTYPE)
        v = v + params["bv"].astype(ACCUM_DTYPE)
    # TP over heads only when q AND kv head counts both divide the model axis
    # (keeps the GQA grouping consistent); else heads stay replicated — the
    # paper's diminished-M fragmentation (Table I). NOTE a widen-batch
    # fall-back (batch over the model axis for attention) was tried and
    # REFUTED: XLA lowers the layout change as all-gathers, costing ~10× the
    # replicated compute it saves (EXPERIMENTS.md §Perf, hypothesis log).
    tpc = (cfg.num_heads, cfg.num_kv_heads)
    q = constrain(q.astype(COMPUTE_DTYPE), tp_dim=2, tp_check=tpc)
    k = constrain(k.astype(COMPUTE_DTYPE), tp_dim=2, tp_check=tpc)
    v = constrain(v.astype(COMPUTE_DTYPE), tp_dim=2, tp_check=tpc)
    return q, k, v


def attn_out(params, ctx):
    """ctx: (B,S,H,D) -> (B,S,d). Row-parallel output in bf16 so the TP
    partial-sum all-reduce carries 2 bytes/elt (Megatron-style; MXU still
    accumulates fp32 internally) — §Perf iteration C2."""
    return jnp.einsum("bshk,hkd->bsd", ctx, cast_compute(params["wo"]),
                      preferred_element_type=COMPUTE_DTYPE)


def _gqa_scores(q, k, cap):
    """q (B,S,KV,R,D), k (B,T,KV,D) -> (B,KV,R,S,T) fp32 logits."""
    scale = 1.0 / math.sqrt(q.shape[-1])
    s = jnp.einsum("bsgrd,btgd->bgrst", q, k, preferred_element_type=jnp.float32)
    return softcap(s * scale, cap)


def _gqa_ctx(p, v):
    """p (B,KV,R,S,T) fp32, v (B,T,KV,D) -> (B,S,KV,R,D).

    p stays fp32: decode carries a single query row, so the PV product is
    tiny and fp32 probabilities keep this jnp fallback numerically aligned
    with the paged decode kernel's fp32 online-softmax accumulator
    (kernels/paged_attention.py) — the dispatch can switch paths per batch
    without shifting logits by a bf16 quantization step.
    """
    return jnp.einsum("bgrst,btgd->bsgrd", p, v.astype(jnp.float32),
                      preferred_element_type=jnp.float32)


def _flash_call(q, k, v, cfg, mode: str, msize: int):
    """Layout shim onto models.flash (custom-VJP, O(S) residuals).

    q (B,S,H,D); k,v (B,S,KV,D) -> (B,S,H,D). The (B,KV,R,S,D) internal layout
    keeps the GQA grouping explicit so TP-on-heads constraints survive.

    Sequence-sharded path (§Perf hillclimb, the paper's Eyexam-step-4 fix):
    when the head counts do NOT divide the model axis (gemma2 8H, qwen 2KV,
    mixtral 8KV ...), plain TP would leave the model axis idle and replicate
    attention compute ×model. Instead the q rows are sharded along S over the
    model axis under shard_map (K/V replicated — each chip attends its own
    query rows; flash rows are independent). dK/dV are psum'd by shard_map AD.
    """
    from repro.models import flash as flash_lib
    B, S, H, D = q.shape
    KV = k.shape[2]
    R = H // KV
    qf = q.reshape(B, S, KV, R, D).transpose(0, 2, 3, 1, 4)
    kf = k.transpose(0, 2, 1, 3)
    vf = v.transpose(0, 2, 1, 3)
    blk = 512
    while blk > S:
        blk //= 2
    blk = max(blk, 16)

    h = _HINTS.get()
    ms = h.model_size if h is not None else 1
    heads_tp = (H % ms == 0 and KV % ms == 0)
    use_seq = (h is not None and h.tp and ms > 1 and not heads_tp
               and S % ms == 0 and (S // ms) >= 128)
    if use_seq:
        from jax.sharding import PartitionSpec as P
        from repro.sharding.collectives import shard_map
        b_ax = h.act[0]
        S_loc = S // ms

        def body(q_loc, k_full, v_full):
            off = jax.lax.axis_index("model") * S_loc
            qpos = off + jnp.arange(S_loc, dtype=jnp.int32)
            return flash_lib.flash_attention(
                q_loc, k_full, v_full, mode, msize,
                cfg.attn_logit_softcap, min(blk, S_loc), blk, qpos=qpos)

        out = shard_map(
            body, mesh=h.mesh,
            in_specs=(P(b_ax, None, None, "model", None),
                      P(b_ax, None, None, None),
                      P(b_ax, None, None, None)),
            out_specs=P(b_ax, None, None, "model", None),
            check_vma=False)(qf, kf, vf)
    else:
        out = flash_lib.flash_attention(qf, kf, vf, mode, msize,
                                        cfg.attn_logit_softcap, blk, blk)
        out = constrain(out, tp_dim=1, tp_check=(KV, H))
    out = out.transpose(0, 3, 1, 2, 4).reshape(B, S, H, D)
    return out.astype(COMPUTE_DTYPE)


def full_causal_attention(q, k, v, cfg):
    """Full causal attention via blocked flash (no S×S materialization; FLOP
    upper bound 2× causal minimum — above-diagonal blocks are masked)."""
    return _flash_call(q, k, v, cfg, "causal", q.shape[1])


def local_attention(q, k, v, cfg):
    """Sliding-window causal attention, window w = cfg.window_size. Flash
    visits only the O(S·w) band."""
    w = cfg.window_size
    if q.shape[1] <= w:
        return full_causal_attention(q, k, v, cfg)
    return _flash_call(q, k, v, cfg, "window", w)


def chunked_attention(q, k, v, cfg):
    """llama4 iRoPE chunked attention: causal within fixed chunks."""
    c = cfg.chunk_size
    if q.shape[1] <= c:
        return full_causal_attention(q, k, v, cfg)
    return _flash_call(q, k, v, cfg, "chunk", c)


def decode_attention(q, k_cache, v_cache, valid_mask, cfg):
    """One-token attention against a cache.

    q (B,1,H,D); k_cache/v_cache (B,T,KV,D); valid_mask (B,T) bool.
    """
    B, _, H, D = q.shape
    KV = k_cache.shape[2]
    R = H // KV
    qr = q.reshape(B, 1, KV, R, D)
    s = _gqa_scores(qr, k_cache, cfg.attn_logit_softcap)  # (B,KV,R,1,T)
    s = jnp.where(valid_mask[:, None, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    ctx = _gqa_ctx(p, v_cache)  # (B,1,KV,R,D)
    return ctx.reshape(B, 1, KV * R, D).astype(COMPUTE_DTYPE)


def cross_attention(params, x, cond, cfg):
    """Cross-attention to a (stubbed) conditioning sequence. x (B,S,d), cond (B,T,d)."""
    H, D = cfg.num_heads, cfg.head_dim
    q = jnp.einsum("bsd,dhk->bshk", x, cast_compute(params["wq"]),
                   preferred_element_type=ACCUM_DTYPE).astype(COMPUTE_DTYPE)
    k = jnp.einsum("btd,dhk->bthk", cond, cast_compute(params["wk"]),
                   preferred_element_type=ACCUM_DTYPE).astype(COMPUTE_DTYPE)
    v = jnp.einsum("btd,dhk->bthk", cond, cast_compute(params["wv"]),
                   preferred_element_type=ACCUM_DTYPE).astype(COMPUTE_DTYPE)
    scale = 1.0 / math.sqrt(D)
    s = jnp.einsum("bshk,bthk->bhst", q, k,
                   preferred_element_type=jnp.float32) * scale
    p = jax.nn.softmax(s, axis=-1)
    ctx = jnp.einsum("bhst,bthk->bshk", p.astype(COMPUTE_DTYPE), v,
                     preferred_element_type=jnp.float32).astype(COMPUTE_DTYPE)
    return attn_out(params, ctx)


# ------------------------------------------------------------------------- MLP
def _packed_proj(x, packed, n_out: int, activation: Optional[str] = None):
    """(B,S,d) · BCSC-packed weight -> (B,S,n_out) via the sparse kernels.

    M = B·S rows: decode shapes (M ≤ dataflow.GEMV_M_MAX) hit the bcsc_gemv
    scratch-accumulator kernel with the activation fused into the epilogue;
    prefill/training shapes take the BCSC GEMM kernel. Zero weight blocks are
    skipped entirely — the serve-path realization of the paper's Sparse PE.
    """
    from repro.kernels import ops as _ops   # deferred: keep layer import light
    B, S, d = x.shape
    y = _ops.bcsc_apply_packed(x.reshape(B * S, d), packed, n_out=n_out,
                               activation=activation,
                               out_dtype=jnp.float32)
    return y.reshape(B, S, n_out)


def mlp(params, x, cfg, d_ff: Optional[int] = None):
    """GeGLU/SwiGLU MLP, Megatron-TP pattern: up-projections column-sharded
    over the model axis (grouped-multicast mode), down-projection row-sharded
    with a psum — the hidden h stays (batch, seq, d_ff/model) per chip.

    Any projection stored BCSC-packed (serve.sparse.sparsify_mlp_params)
    bypasses the einsum and runs the sparse kernel with the activation fused
    into its epilogue; dense weights keep the exact original path. When EVERY
    projection of the layer is packed and the dataflow rule allows it, the
    whole MLP collapses into the fused bcsc_mlp megakernel — one pallas_call,
    hidden activation in VMEM scratch, per-layer actual nnzb (never the
    padded stack count).

    Dispatch reads the active ServePlan (core.plan — the engines activate it
    around their jitted programs) and falls back to the core.dataflow rule
    when none is active; both resolve to the same crossover."""
    from repro.core import plan as _plan
    from repro.kernels.ops import is_packed
    act_name = "silu" if cfg.mlp_act == "silu" else "gelu"
    act = jax.nn.silu if cfg.mlp_act == "silu" else \
        (lambda t: jax.nn.gelu(t, approximate=True))
    ff = d_ff or (cfg.dense_d_ff if (cfg.moe and cfg.dense_d_ff) else cfg.d_ff)
    d = x.shape[-1]

    names = ("wg", "wu", "wd") if cfg.mlp_gated else ("w1", "w2")
    if all(is_packed(params[n]) for n in names):
        B, S, _ = x.shape
        if _plan.route_mlp(B * S, ff, d, gated=cfg.mlp_gated) == "fused":
            from repro.kernels import ops as _ops
            up2 = params["wu"] if cfg.mlp_gated else None
            y = _ops.bcsc_mlp_packed(
                x.reshape(B * S, d), params[names[0]], up2, params[names[-1]],
                d_ff=ff, n_out=d, activation=act_name,
                counts=params.get("_bcsc_counts"), out_dtype=jnp.float32)
            return constrain(y.reshape(B, S, d).astype(COMPUTE_DTYPE))

    if cfg.mlp_gated:
        wg, wu = params["wg"], params["wu"]
        g_act = _packed_proj(x, wg, ff, act_name) if is_packed(wg) else \
            act(jnp.einsum("bsd,df->bsf", x, cast_compute(wg),
                           preferred_element_type=ACCUM_DTYPE))
        u = _packed_proj(x, wu, ff) if is_packed(wu) else \
            jnp.einsum("bsd,df->bsf", x, cast_compute(wu),
                       preferred_element_type=ACCUM_DTYPE)
        h = constrain((g_act * u).astype(COMPUTE_DTYPE), tp_dim=2)
    else:
        w1 = params["w1"]
        h1 = _packed_proj(x, w1, ff, act_name) if is_packed(w1) else \
            act(jnp.einsum("bsd,df->bsf", x, cast_compute(w1),
                           preferred_element_type=ACCUM_DTYPE))
        h = constrain(h1.astype(COMPUTE_DTYPE), tp_dim=2)
    wd = params["wd"] if cfg.mlp_gated else params["w2"]
    # row-parallel down-proj in bf16: TP all-reduce payload halves (§Perf C2)
    if is_packed(wd):
        out = _packed_proj(h, wd, d).astype(COMPUTE_DTYPE)
    else:
        out = jnp.einsum("bsf,fd->bsd", h, cast_compute(wd),
                         preferred_element_type=COMPUTE_DTYPE)
    return constrain(out)


# ------------------------------------------------------------------ param init
def init_attn_params(rng, cfg, cross: bool = False):
    d, H, KV, D = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    ks = jax.random.split(rng, 8)
    p = {
        "wq": dense_init(ks[0], (d, H, D)),
        "wk": dense_init(ks[1], (d, KV, D)),
        "wv": dense_init(ks[2], (d, KV, D)),
        "wo": dense_init(ks[3], (H, D, d), in_axis=0),
    }
    if cross:
        p["wk"] = dense_init(ks[1], (d, H, D))
        p["wv"] = dense_init(ks[2], (d, H, D))
    if cfg.qkv_bias and not cross:
        p["bq"] = jnp.zeros((H, D), PARAM_DTYPE)
        p["bk"] = jnp.zeros((KV, D), PARAM_DTYPE)
        p["bv"] = jnp.zeros((KV, D), PARAM_DTYPE)
    if cfg.qk_norm and not cross:
        p["q_norm"] = jnp.zeros((D,), PARAM_DTYPE)
        p["k_norm"] = jnp.zeros((D,), PARAM_DTYPE)
    return p


def init_mlp_params(rng, cfg, d_ff: int):
    d = cfg.d_model
    ks = jax.random.split(rng, 3)
    if cfg.mlp_gated:
        return {"wg": dense_init(ks[0], (d, d_ff)),
                "wu": dense_init(ks[1], (d, d_ff)),
                "wd": dense_init(ks[2], (d_ff, d))}
    return {"w1": dense_init(ks[0], (d, d_ff)),
            "w2": dense_init(ks[1], (d_ff, d))}
