"""Generic decoder LM assembled from an ArchConfig.

Layers are applied through ``lax.scan`` over *pattern periods* (stacked params),
so HLO size — and thus AOT compile time for the 512-device dry-run — is O(one
period), not O(num_layers). Remainder layers (e.g. recurrentgemma's 26 = 8×3+2)
are applied unstacked after the scan.
"""
from __future__ import annotations

import math
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import layers, moe as moe_lib, rglru as rglru_lib, ssm as ssm_lib
from repro.models.layers import (ACCUM_DTYPE, COMPUTE_DTYPE, PARAM_DTYPE,
                                 cast_compute, rms_norm)

MOE_AUX_WEIGHT = 0.01
Z_LOSS_WEIGHT = 1e-4
LOSS_CHUNK = 512          # seq chunk for the vocab-sized logits (memory bound)


# --------------------------------------------------------------------- layout
def scan_period(cfg) -> int:
    p = cfg.pattern_period
    if cfg.moe:
        p = math.lcm(p, cfg.moe_every)
    return p


def num_scan_periods(cfg) -> int:
    return cfg.num_layers // scan_period(cfg)


def num_remainder(cfg) -> int:
    return cfg.num_layers % scan_period(cfg)


def slot_kinds(cfg):
    """Static (kind, is_moe) description for each slot in a scan period."""
    p = scan_period(cfg)
    return [(cfg.layer_kind(j), cfg.is_moe_layer(j)) for j in range(p)]


# ------------------------------------------------------------------ param init
def _init_block(rng, cfg, kind: str, is_moe: bool):
    d = cfg.d_model
    ks = jax.random.split(rng, 6)
    p: Dict = {"pre_norm": jnp.zeros((d,), PARAM_DTYPE),
               "pre_norm_mlp": jnp.zeros((d,), PARAM_DTYPE)}
    if cfg.use_post_norm:
        p["post_norm"] = jnp.zeros((d,), PARAM_DTYPE)
        p["post_norm_mlp"] = jnp.zeros((d,), PARAM_DTYPE)
    if kind in ("global", "local", "chunked"):
        p["attn"] = layers.init_attn_params(ks[0], cfg)
        if cfg.cross_attn_cond:
            p["cross_attn"] = layers.init_attn_params(ks[1], cfg, cross=True)
            p["pre_norm_cross"] = jnp.zeros((d,), PARAM_DTYPE)
    elif kind == "ssm":
        p["ssm"] = ssm_lib.init_ssm_params(ks[0], cfg)
    elif kind == "rglru":
        p["rglru"] = rglru_lib.init_rglru_params(ks[0], cfg)
    if kind != "ssm":
        if is_moe:
            p["moe"] = moe_lib.init_moe_params(ks[2], cfg)
        else:
            ff = cfg.dense_d_ff if (cfg.moe and cfg.dense_d_ff) else cfg.d_ff
            p["mlp"] = layers.init_mlp_params(ks[2], cfg, ff)
    return p


def init_params(rng, cfg):
    period = scan_period(cfg)
    nper = num_scan_periods(cfg)
    rem = num_remainder(cfg)
    kinds = slot_kinds(cfg)
    k_embed, k_head, k_blocks, k_rem = jax.random.split(rng, 4)

    Vp, d, K = cfg.vocab_padded, cfg.d_model, cfg.num_codebooks
    params: Dict = {
        "embed": layers.embed_init(k_embed, (K, Vp, d)) if K > 1
        else layers.embed_init(k_embed, (Vp, d)),
        "final_norm": jnp.zeros((d,), PARAM_DTYPE),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = (layers.dense_init(k_head, (K, d, Vp), in_axis=1)
                             if K > 1 else layers.dense_init(k_head, (d, Vp)))

    def init_period(rng_p):
        kk = jax.random.split(rng_p, period)
        return {f"slot{j}": _init_block(kk[j], cfg, *kinds[j])
                for j in range(period)}

    if nper:
        params["blocks"] = jax.vmap(init_period)(jax.random.split(k_blocks, nper))
    if rem:
        kk = jax.random.split(k_rem, rem)
        params["rem"] = {f"rem{j}": _init_block(kk[j], cfg, *kinds[j])
                         for j in range(rem)}
    return params


def abstract_params(cfg):
    """ShapeDtypeStruct pytree — no allocation (for the dry-run)."""
    return jax.eval_shape(lambda: init_params(jax.random.PRNGKey(0), cfg))


# ------------------------------------------------------------------- embedding
def embed_tokens(params, tokens, cfg):
    """tokens (B,S) or (B,K,S) -> (B,S,d)."""
    if cfg.num_codebooks > 1:
        # sum the K codebook embeddings (musicgen)
        x = jnp.zeros(tokens.shape[:1] + tokens.shape[2:] + (cfg.d_model,),
                      jnp.float32)
        for k in range(cfg.num_codebooks):
            x = x + params["embed"][k].astype(jnp.float32)[tokens[:, k]]
    else:
        x = params["embed"].astype(jnp.float32)[tokens]
    if cfg.embed_scale:
        x = x * math.sqrt(cfg.d_model)
    return x.astype(COMPUTE_DTYPE)


def lm_logits(params, x, cfg):
    """x (B,S,d) -> logits fp32 (B,S,Vp) or (B,S,K,Vp)."""
    if cfg.num_codebooks > 1:
        w = params["lm_head"]  # (K,d,Vp)
        logits = jnp.einsum("bsd,kdv->bskv", x, cast_compute(w),
                            preferred_element_type=jnp.float32)
    elif cfg.tie_embeddings:
        logits = jnp.einsum("bsd,vd->bsv", x, cast_compute(params["embed"]),
                            preferred_element_type=jnp.float32)
    else:
        logits = jnp.einsum("bsd,dv->bsv", x, cast_compute(params["lm_head"]),
                            preferred_element_type=jnp.float32)
    logits = layers.softcap(logits, cfg.final_logit_softcap)
    if cfg.vocab_padded != cfg.vocab_size:   # mask pad vocab
        pad_mask = jnp.arange(logits.shape[-1]) >= cfg.vocab_size
        logits = jnp.where(pad_mask, layers.NEG_INF, logits)
    return logits


# ------------------------------------------------------------------ block apply
def _rope_theta_for(cfg, kind: str) -> float:
    if kind == "local" and cfg.local_rope_theta > 0:
        return cfg.local_rope_theta
    return cfg.rope_theta


def _attn_train(p, x, cond, kind, positions, cfg):
    q, k, v = layers.attn_qkv(p, x, cfg)
    if cfg.qk_norm:
        q = layers.head_rms_norm(q, p["q_norm"], cfg.norm_eps)
        k = layers.head_rms_norm(k, p["k_norm"], cfg.norm_eps)
    if cfg.pos_embed == "rope":
        theta = _rope_theta_for(cfg, kind)
        q = layers.rope(q, positions, theta)
        k = layers.rope(k, positions, theta)
    if kind == "local":
        ctx = layers.local_attention(q, k, v, cfg)
    elif kind == "chunked":
        ctx = layers.chunked_attention(q, k, v, cfg)
    else:
        ctx = layers.full_causal_attention(q, k, v, cfg)
    return layers.attn_out(p, ctx)


def apply_block(p, x, cond, kind, is_moe, cfg, positions):
    """One decoder block (training / prefill form). x (B,S,d)."""
    aux = jnp.zeros((), jnp.float32)
    h = rms_norm(x, p["pre_norm"], cfg.norm_eps)
    if kind in ("global", "local", "chunked"):
        y = _attn_train(p["attn"], h, cond, kind, positions, cfg)
    elif kind == "ssm":
        y = ssm_lib.ssm_block(p["ssm"], h, cfg)
    elif kind == "rglru":
        y = rglru_lib.rglru_block(p["rglru"], h, cfg)
    if cfg.use_post_norm:
        y = rms_norm(y, p["post_norm"], cfg.norm_eps)
    x = x + y
    if cfg.cross_attn_cond and kind in ("global", "local", "chunked"):
        hc = rms_norm(x, p["pre_norm_cross"], cfg.norm_eps)
        x = x + layers.cross_attention(p["cross_attn"], hc, cond, cfg)
    if kind != "ssm":
        h = rms_norm(x, p["pre_norm_mlp"], cfg.norm_eps)
        if is_moe:
            y, aux = moe_lib.moe_layer(p["moe"], h, cfg)
        else:
            y = layers.mlp(p["mlp"], h, cfg)
        if cfg.use_post_norm:
            y = rms_norm(y, p["post_norm_mlp"], cfg.norm_eps)
        x = x + y
    return x, aux


# --------------------------------------------------------------------- forward
def forward(params, tokens, cfg, *, patch_embeds=None, cond=None,
            remat_policy: str = "none", hints=None):
    """Training/prefill forward. Returns final hidden states (B,S,d).

    ``hints`` (sharding.autoshard.ShardingHints) pins activations to the
    planner's iact-NoC mode inside the jitted program — without it XLA's
    propagation may re-shard activations onto the weight layout.
    """
    x = embed_tokens(params, tokens, cfg)
    if cfg.frontend == "vision" and patch_embeds is not None:
        x = jnp.concatenate([patch_embeds.astype(COMPUTE_DTYPE), x], axis=1)
    if hints is not None:
        x = hints.constrain_act(x)
    B, S = x.shape[:2]
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
    if cfg.pos_embed == "sinusoidal":
        x = x + layers.sinusoidal_pos(positions, cfg.d_model).astype(COMPUTE_DTYPE)

    kinds = slot_kinds(cfg)
    period = scan_period(cfg)

    def period_fn(x, period_params):
        aux = jnp.zeros((), jnp.float32)
        for j in range(period):
            x, a = apply_block(period_params[f"slot{j}"], x, cond,
                               *kinds[j], cfg, positions)
            if hints is not None:
                x = hints.constrain_act(x)
            aux = aux + a
        return x, aux

    if remat_policy == "full":
        period_fn = jax.checkpoint(period_fn)
    elif remat_policy == "dots":
        period_fn = jax.checkpoint(
            period_fn, policy=jax.checkpoint_policies.checkpoint_dots)
    elif remat_policy == "dots_no_batch":
        period_fn = jax.checkpoint(
            period_fn,
            policy=jax.checkpoint_policies.checkpoint_dots_with_no_batch_dims)

    aux_total = jnp.zeros((), jnp.float32)
    if "blocks" in params:
        x, auxs = jax.lax.scan(lambda c, pp: period_fn(c, pp),
                               x, params["blocks"])
        aux_total = aux_total + jnp.sum(auxs)
    if "rem" in params:
        for j in range(num_remainder(cfg)):
            x, a = apply_block(params["rem"][f"rem{j}"], x, cond,
                               *kinds[j], cfg, positions)
            aux_total = aux_total + a
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    return x, aux_total


# ------------------------------------------------------------------------ loss
def _xent_chunk(params, x_chunk, labels_chunk, cfg, hints=None):
    logits = lm_logits(params, x_chunk, cfg)         # fp32
    if hints is not None:
        logits = hints.constrain_logits(logits)
    lse = jax.scipy.special.logsumexp(logits, axis=-1)
    if cfg.num_codebooks > 1:                         # labels (B,K,C) -> (B,C,K)
        lbl = jnp.swapaxes(labels_chunk, 1, 2)
    else:
        lbl = labels_chunk
    valid = lbl >= 0
    lbl_safe = jnp.maximum(lbl, 0)
    picked = jnp.take_along_axis(logits, lbl_safe[..., None], axis=-1)[..., 0]
    nll = jnp.where(valid, lse - picked, 0.0)
    zloss = jnp.where(valid, jnp.square(lse), 0.0)
    return (jnp.sum(nll), jnp.sum(zloss), jnp.sum(valid),
            jnp.sum(jnp.where(valid, (jnp.argmax(logits, -1) == lbl), False)))


def loss_from_hidden(params, x, labels, cfg, hints=None):
    """Chunked softmax-xent over the (huge) vocab — never materializes the full
    (B,S,V) logits; scans LOSS_CHUNK positions at a time."""
    B, S = x.shape[:2]
    c = min(LOSS_CHUNK, S)
    while S % c:
        c //= 2
    n = S // c
    xr = jnp.moveaxis(x.reshape(B, n, c, -1), 1, 0)           # (n,B,c,d)
    if cfg.num_codebooks > 1:
        lr = jnp.moveaxis(labels.reshape(B, labels.shape[1], n, c), 2, 0)
    else:
        lr = jnp.moveaxis(labels.reshape(B, n, c), 1, 0)      # (n,B,c)

    # remat: the chunk's (B,c,V) logits would otherwise be SAVED per scan step
    # for the backward (GBs at 256k vocab) — recompute them instead
    xent = jax.checkpoint(
        lambda xc, lc: _xent_chunk(params, xc, lc, cfg, hints))

    def step(carry, inp):
        xc, lc = inp
        nll, zl, cnt, acc = xent(xc, lc)
        return (carry[0] + nll, carry[1] + zl, carry[2] + cnt,
                carry[3] + acc), None

    init = (jnp.zeros(()), jnp.zeros(()), jnp.zeros(()), jnp.zeros(()))
    (nll, zl, cnt, acc), _ = jax.lax.scan(step, init, (xr, lr))
    cnt = jnp.maximum(cnt, 1.0)
    return nll / cnt, zl / cnt, acc / cnt


def loss_fn(params, batch, cfg, *, remat_policy: str = "none", hints=None):
    """Full training loss. batch: tokens/labels (+patch_embeds/cond)."""
    x, aux = forward(params, batch["tokens"], cfg,
                     patch_embeds=batch.get("patch_embeds"),
                     cond=batch.get("cond"), remat_policy=remat_policy,
                     hints=hints)
    labels = batch["labels"]
    if cfg.frontend == "vision":                      # loss only on text tokens
        x = x[:, cfg.num_patches:]
    loss, zloss, acc = loss_from_hidden(params, x, labels, cfg, hints)
    total = loss + Z_LOSS_WEIGHT * zloss + MOE_AUX_WEIGHT * aux
    metrics = {"loss": loss, "z_loss": zloss, "moe_aux": aux, "accuracy": acc}
    return total, metrics
