from repro.models import decoding, frontend, layers, moe, rglru, ssm, transformer

__all__ = ["decoding", "frontend", "layers", "moe", "rglru", "ssm", "transformer"]
