"""Mamba-2 SSD (state-space duality) block — chunked matmul form + decode recurrence.

The chunked SSD algorithm (arXiv:2405.21060 §6) is already MXU-friendly: the
intra-chunk term is a masked (chunk×chunk) matmul and the inter-chunk term is a
short scan over chunk states — this is the TPU-native adaptation (DESIGN.md §2):
no per-element cycle skipping, all compute lands on the systolic array.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import layers
from repro.models.layers import (ACCUM_DTYPE, COMPUTE_DTYPE, PARAM_DTYPE,
                                 cast_compute, constrain, dense_init, rms_norm)


def _segsum(a):
    """a (..., T) -> (..., T, T): out[i,j] = sum_{k in (j, i]} a[k], -inf above diag."""
    cs = jnp.cumsum(a, axis=-1)
    seg = cs[..., :, None] - cs[..., None, :]
    T = a.shape[-1]
    mask = jnp.arange(T)[:, None] >= jnp.arange(T)[None, :]
    return jnp.where(mask, seg, -jnp.inf)


def _rep_groups(x, h):
    """(..., g, n) -> (..., h, n) by repeating each group h//g times."""
    g = x.shape[-2]
    return jnp.repeat(x, h // g, axis=-2)


def ssd_chunked(x, dt, A_log, B, C, chunk: int, init_state=None):
    """Chunked SSD scan.

    x  (b, l, h, p)   per-head inputs
    dt (b, l, h)      softplus-ed timestep
    A_log (h,)        A = -exp(A_log)
    B, C (b, l, g, n) input/output projections (g groups)
    Returns y (b, l, h, p), final_state (b, h, n, p).
    """
    b, l, h, p = x.shape
    g, n = B.shape[-2], B.shape[-1]
    c = min(chunk, l)
    while l % c:
        c //= 2
    nc = l // c
    A = -jnp.exp(A_log.astype(jnp.float32))                      # (h,)
    a = dt.astype(jnp.float32) * A                               # (b,l,h) log-decay
    xr = constrain(x.reshape(b, nc, c, h, p))
    dtr = dt.reshape(b, nc, c, h).astype(jnp.float32)
    ar = a.reshape(b, nc, c, h)
    Br = constrain(_rep_groups(B.reshape(b, nc, c, g, n), h))    # (b,nc,c,h,n)
    Cr = constrain(_rep_groups(C.reshape(b, nc, c, g, n), h))

    a_t = ar.transpose(0, 1, 3, 2)                               # (b,nc,h,c)
    a_cum = jnp.cumsum(a_t, axis=-1)                             # (b,nc,h,c)
    L = jnp.exp(_segsum(a_t))                                    # (b,nc,h,c,c)

    # ---- intra-chunk (block-diagonal) term
    # every (b,...)-leading intermediate is pinned batch-sharded: the scan-bwd
    # cotangents otherwise lose the batch sharding and replicate (DESIGN §5)
    CB = constrain(jnp.einsum("bzihn,bzjhn->bzhij", Cr, Br,
                              preferred_element_type=jnp.float32))  # (b,nc,h,c,c)
    M = CB * L * dtr.transpose(0, 1, 3, 2)[:, :, :, None, :]     # weight by dt_j
    Y_diag = constrain(jnp.einsum(
        "bzhij,bzjhp->bzihp", M.astype(COMPUTE_DTYPE),
        xr.astype(COMPUTE_DTYPE), preferred_element_type=jnp.float32))

    # ---- chunk states: S_z = sum_j exp(a_cum[z,-1] - a_cum[z,j]) dt_j B_j x_j^T
    decay = jnp.exp(a_cum[..., -1:] - a_cum)                     # (b,nc,h,c)
    w = (decay * dtr.transpose(0, 1, 3, 2)).transpose(0, 1, 3, 2)  # (b,nc,c,h)
    states = constrain(jnp.einsum(
        "bzch,bzchn,bzchp->bzhnp",
        w.astype(COMPUTE_DTYPE), Br.astype(COMPUTE_DTYPE),
        xr.astype(COMPUTE_DTYPE),
        preferred_element_type=jnp.float32))                     # (b,nc,h,n,p)

    # ---- inter-chunk recurrence over nc chunks
    a_tot = a_cum[..., -1]                                       # (b,nc,h)
    if init_state is None:
        init_state = jnp.zeros((b, h, n, p), jnp.float32)

    def chunk_step(s_prev, inp):
        st, at = inp                                             # (b,h,n,p), (b,h)
        s_new = constrain(s_prev * jnp.exp(at)[..., None, None] + st)
        return s_new, s_prev

    (final_state, prev_states) = jax.lax.scan(
        chunk_step, init_state,
        (states.transpose(1, 0, 2, 3, 4), a_tot.transpose(1, 0, 2)))
    prev_states = prev_states.transpose(1, 0, 2, 3, 4)           # (b,nc,h,n,p)

    # ---- contribution of the carried state: Y_off[i] = exp(a_cum[i]) C_i · S_prev
    Y_off = constrain(jnp.einsum(
        "bzchn,bzhnp,bzhc->bzchp",
        Cr.astype(COMPUTE_DTYPE),
        constrain(prev_states.astype(COMPUTE_DTYPE)),
        jnp.exp(a_cum).astype(COMPUTE_DTYPE),
        preferred_element_type=jnp.float32))
    y = (Y_diag + Y_off).reshape(b, l, h, p)
    return y.astype(COMPUTE_DTYPE), final_state


def ssd_decode_step(state, x, dt, A_log, B, C):
    """One-token recurrence. state (b,h,n,p); x (b,h,p); dt (b,h); B,C (b,g,n)."""
    h = x.shape[1]
    A = -jnp.exp(A_log.astype(jnp.float32))
    da = jnp.exp(dt.astype(jnp.float32) * A)                     # (b,h)
    Bh = _rep_groups(B, h).astype(jnp.float32)                   # (b,h,n)
    Ch = _rep_groups(C, h).astype(jnp.float32)
    upd = jnp.einsum("bh,bhn,bhp->bhnp", dt.astype(jnp.float32), Bh,
                     x.astype(jnp.float32))
    state = state * da[..., None, None] + upd
    y = jnp.einsum("bhn,bhnp->bhp", Ch, state)
    return y.astype(COMPUTE_DTYPE), state


# --------------------------------------------------------------------- block
def init_ssm_params(rng, cfg):
    d, di = cfg.d_model, cfg.d_inner
    g, n, hs = cfg.ssm_ngroups, cfg.ssm_state, cfg.ssm_nheads
    K = cfg.ssm_conv_kernel
    conv_ch = di + 2 * g * n
    ks = jax.random.split(rng, 6)
    return {
        "in_proj": dense_init(ks[0], (d, 2 * di + 2 * g * n + hs)),
        "conv_w": dense_init(ks[1], (K, conv_ch), in_axis=0),
        "conv_b": jnp.zeros((conv_ch,), PARAM_DTYPE),
        "A_log": jnp.zeros((hs,), PARAM_DTYPE),
        "D": jnp.ones((hs,), PARAM_DTYPE),
        "dt_bias": jnp.zeros((hs,), PARAM_DTYPE),
        "gate_norm": jnp.zeros((di,), PARAM_DTYPE),
        "out_proj": dense_init(ks[2], (di, d)),
    }


def _causal_conv1d(x, w, b):
    """Depthwise causal conv. x (b,l,ch), w (K,ch)."""
    K = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (K - 1, 0), (0, 0)))
    out = jnp.zeros_like(x, shape=x.shape).astype(jnp.float32)
    for k in range(K):  # K is tiny (4); unrolled taps beat conv lowering here
        out = out + xp[:, k:k + x.shape[1], :].astype(jnp.float32) * w[k].astype(jnp.float32)
    return (out + b.astype(jnp.float32)).astype(x.dtype)


def ssm_block(params, x, cfg, return_state: bool = False):
    """Full Mamba-2 mixer. x (b,l,d) -> (b,l,d) [, decode state]."""
    b, l, d = x.shape
    di, g, n, hs = cfg.d_inner, cfg.ssm_ngroups, cfg.ssm_state, cfg.ssm_nheads
    p = cfg.ssm_headdim
    K = cfg.ssm_conv_kernel
    zxbcdt = jnp.einsum("bld,de->ble", x, cast_compute(params["in_proj"]),
                        preferred_element_type=ACCUM_DTYPE).astype(COMPUTE_DTYPE)
    # the (2di+2gn+hs)-wide projection is rarely axis-divisible: keep it
    # batch-sharded so the splits below are communication-free
    zxbcdt = constrain(zxbcdt)
    z, xin, B, C, dt = jnp.split(
        zxbcdt, [di, 2 * di, 2 * di + g * n, 2 * di + 2 * g * n], axis=-1)
    conv_in = jnp.concatenate([xin, B, C], axis=-1)
    conv_out = jax.nn.silu(_causal_conv1d(conv_in, params["conv_w"],
                                          params["conv_b"]))
    conv_out = constrain(conv_out)
    xin, B, C = jnp.split(conv_out, [di, di + g * n], axis=-1)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + params["dt_bias"])
    xh = xin.reshape(b, l, hs, p)
    Bh = B.reshape(b, l, g, n)
    Ch = C.reshape(b, l, g, n)
    y, final_state = ssd_chunked(xh, dt, params["A_log"], Bh, Ch, cfg.ssm_chunk)
    y = y + params["D"].astype(jnp.float32)[None, None, :, None] * xh.astype(jnp.float32)
    y = constrain(y.reshape(b, l, di).astype(COMPUTE_DTYPE))
    y = rms_norm((y.astype(jnp.float32) * jax.nn.silu(z.astype(jnp.float32))
                  ).astype(COMPUTE_DTYPE), params["gate_norm"], cfg.norm_eps)
    out = jnp.einsum("ble,ed->bld", y, cast_compute(params["out_proj"]),
                     preferred_element_type=ACCUM_DTYPE).astype(COMPUTE_DTYPE)
    if not return_state:
        return out
    if l >= K - 1:
        conv_state = conv_in[:, l - (K - 1):]
    else:
        conv_state = jnp.pad(conv_in, ((0, 0), (K - 1 - l, 0), (0, 0)))
    return out, {"conv": conv_state, "ssd": final_state}


def ssm_block_decode(params, x, state, cfg):
    """One-token step. x (b,1,d); state dict with conv (b,K-1,ch), ssd (b,h,n,p)."""
    b = x.shape[0]
    di, g, n, hs = cfg.d_inner, cfg.ssm_ngroups, cfg.ssm_state, cfg.ssm_nheads
    p = cfg.ssm_headdim
    K = cfg.ssm_conv_kernel
    zxbcdt = jnp.einsum("bld,de->ble", x, cast_compute(params["in_proj"]),
                        preferred_element_type=ACCUM_DTYPE).astype(COMPUTE_DTYPE)
    z, xin, B, C, dt = jnp.split(
        zxbcdt, [di, 2 * di, 2 * di + g * n, 2 * di + 2 * g * n], axis=-1)
    conv_in = jnp.concatenate([xin, B, C], axis=-1)[:, 0]        # (b,ch)
    window = jnp.concatenate([state["conv"], conv_in[:, None, :]], axis=1)  # (b,K,ch)
    conv_out = jnp.einsum("bkc,kc->bc", window.astype(jnp.float32),
                          params["conv_w"].astype(jnp.float32))
    conv_out = jax.nn.silu(conv_out + params["conv_b"].astype(jnp.float32))
    conv_out = conv_out.astype(COMPUTE_DTYPE)
    xin, B, C = (conv_out[:, :di], conv_out[:, di:di + g * n],
                 conv_out[:, di + g * n:])
    dt = jax.nn.softplus(dt[:, 0].astype(jnp.float32) + params["dt_bias"])
    y, ssd_state = ssd_decode_step(state["ssd"], xin.reshape(b, hs, p), dt,
                                   params["A_log"], B.reshape(b, g, n),
                                   C.reshape(b, g, n))
    y = y + params["D"].astype(jnp.float32)[None, :, None] * \
        xin.reshape(b, hs, p).astype(jnp.float32)
    y = y.reshape(b, 1, di).astype(COMPUTE_DTYPE)
    y = rms_norm((y.astype(jnp.float32) * jax.nn.silu(z.astype(jnp.float32))
                  ).astype(COMPUTE_DTYPE), params["gate_norm"], cfg.norm_eps)
    out = jnp.einsum("ble,ed->bld", y, cast_compute(params["out_proj"]),
                     preferred_element_type=ACCUM_DTYPE).astype(COMPUTE_DTYPE)
    new_state = {"conv": window[:, 1:], "ssd": ssd_state}
    return out, new_state


def init_ssm_state(batch: int, cfg):
    conv_ch = cfg.d_inner + 2 * cfg.ssm_ngroups * cfg.ssm_state
    return {
        "conv": jnp.zeros((batch, cfg.ssm_conv_kernel - 1, conv_ch), COMPUTE_DTYPE),
        "ssd": jnp.zeros((batch, cfg.ssm_nheads, cfg.ssm_state, cfg.ssm_headdim),
                         jnp.float32),
    }
