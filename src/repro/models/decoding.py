"""KV/state caches + single-token decode and prefill paths.

Cache layout mirrors the block layout: entries for the scanned period-blocks are
stacked on a leading ``num_periods`` axis (so decode also scans), remainder
layers keep unstacked entries. Sliding-window and chunked layers use ring
buffers of size ``window``/``chunk`` — decode memory is bounded regardless of
context length (this is what makes ``long_500k`` runnable for those archs).

Ring invariant: slot ``i`` holds the token at the largest position ``p ≡ i
(mod m)`` with ``p ≤ pos``; validity masks are recomputed from ``pos`` each step.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import layers, moe as moe_lib, rglru as rglru_lib, ssm as ssm_lib
from repro.models import transformer as tfm
from repro.models.layers import COMPUTE_DTYPE, cast_compute, rms_norm


def _attn_cache_capacity(cfg, kind: str, cache_len: int) -> int:
    if kind == "local":
        return min(cfg.window_size, cache_len)
    if kind == "chunked":
        return min(cfg.chunk_size, cache_len)
    return cache_len


def _init_entry(cfg, kind: str, batch: int, cache_len: int):
    if kind in ("global", "local", "chunked"):
        cap = _attn_cache_capacity(cfg, kind, cache_len)
        shape = (batch, cap, cfg.num_kv_heads, cfg.head_dim)
        return {"k": jnp.zeros(shape, COMPUTE_DTYPE),
                "v": jnp.zeros(shape, COMPUTE_DTYPE)}
    if kind == "ssm":
        return ssm_lib.init_ssm_state(batch, cfg)
    if kind == "rglru":
        return rglru_lib.init_rglru_state(batch, cfg)
    raise ValueError(kind)


def init_cache(cfg, batch: int, cache_len: int):
    kinds = tfm.slot_kinds(cfg)
    period = tfm.scan_period(cfg)
    nper = tfm.num_scan_periods(cfg)
    rem = tfm.num_remainder(cfg)
    cache: Dict = {}
    if nper:
        def one_period():
            return {f"slot{j}": _init_entry(cfg, kinds[j][0], batch, cache_len)
                    for j in range(period)}
        cache["blocks"] = jax.tree.map(
            lambda x: jnp.broadcast_to(x, (nper,) + x.shape).copy(), one_period())
    if rem:
        cache["rem"] = {f"rem{j}": _init_entry(cfg, kinds[j][0], batch, cache_len)
                        for j in range(rem)}
    return cache


def abstract_cache(cfg, batch: int, cache_len: int):
    return jax.eval_shape(lambda: init_cache(cfg, batch, cache_len))


# ------------------------------------------------------------- paged cache
# Global-attention entries swap the dense (rows, cache_len, ...) slot for a
# shared pool of fixed-size pages, addressed through a per-row block table
# (the CSC address-vector analogue — serve/paging.py owns the host-side
# accounting, kernels/paged_attention.py the device-side read). Ring
# (local/chunked) and recurrent (ssm/rglru) entries keep their bounded
# per-row state: their memory never scales with context, so paging them
# would add indirection with nothing to reclaim.
#
# kv_quant='int8' stores page payloads as symmetric int8 with per-(page,
# kv-head) fp32 amax scales riding the block table ({pk,pv}_scale): the
# whole-page granularity keeps the dequant inside the kernel's page loop
# (one scale broadcast per DMA'd page) and the scale tables negligible
# next to the payload halving.
def _init_paged_entry(cfg, num_pages: int, page_size: int,
                      kv_quant: str = "fp"):
    from repro.core import dataflow as _df
    assert kv_quant in _df.KV_QUANT_DTYPES, kv_quant
    shape = (num_pages, page_size, cfg.num_kv_heads, cfg.head_dim)
    if kv_quant == "int8":
        sshape = (num_pages, cfg.num_kv_heads)
        return {"pk": jnp.zeros(shape, jnp.int8),
                "pv": jnp.zeros(shape, jnp.int8),
                "pk_scale": jnp.zeros(sshape, jnp.float32),
                "pv_scale": jnp.zeros(sshape, jnp.float32)}
    return {"pk": jnp.zeros(shape, COMPUTE_DTYPE),
            "pv": jnp.zeros(shape, COMPUTE_DTYPE)}


def is_paged_entry(entry) -> bool:
    return isinstance(entry, dict) and "pk" in entry


def is_quantized_entry(entry) -> bool:
    return isinstance(entry, dict) and "pk_scale" in entry


def init_paged_cache(cfg, rows: int, cache_len: int, num_pages: int,
                     page_size: Optional[int] = None,
                     kv_quant: Optional[str] = None):
    """Like init_cache, but 'global' entries become (num_pages, page_size,
    KV, D) pools; every other kind keeps its (rows, ...) per-row state.
    ``kv_quant='int8'`` stores pool payloads int8 with per-page scales.

    ``page_size``/``kv_quant`` default from the active ServePlan when a
    serving engine has one activated (core.plan — the single owner of the
    PAGE_SIZE/quant decisions), else from the core.dataflow constants."""
    from repro.core import plan as _plan
    if page_size is None:
        page_size = _plan.page_size_default(cache_len)
    if kv_quant is None:
        pl = _plan.active_plan()
        kv_quant = pl.kv_quant if pl is not None else "fp"
    kinds = tfm.slot_kinds(cfg)
    period = tfm.scan_period(cfg)
    nper = tfm.num_scan_periods(cfg)
    rem = tfm.num_remainder(cfg)

    def entry(kind):
        if kind == "global":
            return _init_paged_entry(cfg, num_pages, page_size, kv_quant)
        return _init_entry(cfg, kind, rows, cache_len)

    cache: Dict = {}
    if nper:
        one = {f"slot{j}": entry(kinds[j][0]) for j in range(period)}
        cache["blocks"] = jax.tree.map(
            lambda x: jnp.broadcast_to(x, (nper,) + x.shape).copy(), one)
    if rem:
        cache["rem"] = {f"rem{j}": entry(kinds[j][0]) for j in range(rem)}
    return cache


# ------------------------------------------------- page quantization helpers
def quantize_to_i8(x, scale):
    """Symmetric int8: q = round(x / scale * 127), scale an amax broadcastable
    to x. A zero scale (empty page / all-zero token) quantizes to zeros."""
    s = jnp.where(scale > 0, scale, 1.0)
    q = jnp.round(x.astype(jnp.float32) / s * 127.0)
    return jnp.clip(q, -127.0, 127.0).astype(jnp.int8)


def dequantize_i8(q, scale):
    return q.astype(jnp.float32) * (scale * (1.0 / 127.0))


def quantize_paged_entry(entry, num_pages: Optional[int] = None):
    """Requantize a resident fp page pool to the int8 layout in place — the
    device half of the guard's int8 degradation rung (serve/scheduler.py).

    Each page gets the same per-(page, kv-head) amax scale scheme the int8
    prefill/append paths use, so the paged-attention dequant and
    ``_append_token_i8``'s requant-on-loud-token logic work on the result
    unchanged. ``num_pages`` > the current pool grows the page axis with
    zero pages (zero scale == empty page by convention) — int8 pages cost
    half the HBM, so the same footprint holds ~2× the pages; existing
    physical page ids keep their contents and block tables stay valid.
    Handles both stacked ``(nper, P, ps, KV, D)`` and unstacked
    ``(P, ps, KV, D)`` pools (page axis -4 either way).
    """
    assert is_paged_entry(entry) and not is_quantized_entry(entry), entry

    def conv(pool):
        x = pool.astype(jnp.float32)
        scale = jnp.abs(x).max(axis=(-3, -1))          # (..., P, KV)
        q = quantize_to_i8(x, scale[..., None, :, None])
        if num_pages is not None and num_pages > pool.shape[-4]:
            pad = num_pages - pool.shape[-4]
            qw = [(0, 0)] * q.ndim
            qw[-4] = (0, pad)
            sw = [(0, 0)] * scale.ndim
            sw[-2] = (0, pad)
            q = jnp.pad(q, qw)
            scale = jnp.pad(scale, sw)
        return q, scale

    pk, ks = conv(entry["pk"])
    pv, vs = conv(entry["pv"])
    return {"pk": pk, "pv": pv, "pk_scale": ks, "pv_scale": vs}


def scatter_rows_to_pages(pool, rows_kv, block_table_rows, lengths,
                          start=None):
    """Write per-row contiguous KV (B,S,KV,D) into a page pool (P,ps,KV,D).

    Token t of row b lands at (block_table_rows[b, t // ps], t % ps) for
    start[b] <= t < lengths[b]; positions before ``start`` (pages adopted
    read-only from a shared prefix chain), pad positions, and unallocated
    (-1) table entries are routed out of range and dropped. This is the
    page-native prefill write (prefill_batched's paged mode scatters each
    layer's (B, tier) K/V straight into pages during the scan) and is
    symmetric with the paged kernel's read addressing.
    """
    P, ps = pool.shape[:2]
    B, S = rows_kv.shape[:2]
    s = jnp.arange(S, dtype=jnp.int32)
    page = jnp.take_along_axis(
        block_table_rows, jnp.broadcast_to(s // ps, (B, S)), axis=1)
    valid = (s[None, :] < lengths[:, None]) & (page >= 0)
    if start is not None:
        valid &= s[None, :] >= start[:, None]
    page = jnp.where(valid, page, P)                 # out of range -> dropped
    off = jnp.broadcast_to(s % ps, (B, S))
    return pool.at[page, off].set(rows_kv.astype(pool.dtype), mode="drop")


def quantize_rows_to_pages(pool, scales, rows_kv, block_table_rows, lengths,
                           start=None):
    """int8 variant of scatter_rows_to_pages: (pool, scales) -> updated.

    Per written (row, logical page, kv-head) the amax over the page's tokens
    becomes that physical page's scale (plain .set — prefill writes every
    page it touches from offset 0, pages are row-exclusive, and overwriting
    resets any stale scale a previous holder left). ``start`` must be
    page-aligned or equal to the row's length (the adoption contract:
    shared prefixes cover whole pages or the whole prompt).
    """
    P, ps, KV, D = pool.shape
    B, S = rows_kv.shape[:2]
    s = jnp.arange(S, dtype=jnp.int32)
    bt = block_table_rows
    page = jnp.take_along_axis(bt, jnp.broadcast_to(s // ps, (B, S)), axis=1)
    valid = (s[None, :] < lengths[:, None]) & (page >= 0)
    if start is not None:
        valid &= s[None, :] >= start[:, None]
    # per-(row, logical page, kv) amax over the tokens actually written
    nlp = -(-S // ps)
    a = jnp.abs(jnp.where(valid[..., None, None],
                          rows_kv.astype(jnp.float32), 0.0))
    a = jnp.pad(a, ((0, 0), (0, nlp * ps - S), (0, 0), (0, 0)))
    a = a.reshape(B, nlp, ps, KV, D).max(axis=(2, 4))        # (B, nlp, KV)
    wrote = jnp.pad(valid, ((0, 0), (0, nlp * ps - S))
                    ).reshape(B, nlp, ps).any(axis=2)        # (B, nlp)
    phys = jnp.where(wrote & (bt[:, :nlp] >= 0), bt[:, :nlp], P)
    new_scales = scales.at[phys.reshape(-1)].set(
        a.reshape(-1, KV), mode="drop")
    # quantize each token with its destination page's (fresh) scale
    tok_scale = jnp.take_along_axis(
        a, jnp.broadcast_to((s // ps)[None, :, None], (B, S, KV)), axis=1)
    q = quantize_to_i8(rows_kv, tok_scale[..., None])
    page = jnp.where(valid, page, P)
    off = jnp.broadcast_to(s % ps, (B, S))
    return pool.at[page, off].set(q, mode="drop"), new_scales


def paged_prefill_write(entry, k, v, block_table_rows, lengths, start=None):
    """Write a prefill layer's (B, S, KV, D) K/V straight into its page pool
    entry (fp or int8+scales), honoring the shared-prefix ``start`` mask."""
    if is_quantized_entry(entry):
        pk, ks = quantize_rows_to_pages(entry["pk"], entry["pk_scale"], k,
                                        block_table_rows, lengths, start)
        pv, vs = quantize_rows_to_pages(entry["pv"], entry["pv_scale"], v,
                                        block_table_rows, lengths, start)
        return {"pk": pk, "pv": pv, "pk_scale": ks, "pv_scale": vs}
    return {"pk": scatter_rows_to_pages(entry["pk"], k, block_table_rows,
                                        lengths, start),
            "pv": scatter_rows_to_pages(entry["pv"], v, block_table_rows,
                                        lengths, start)}


def _append_token_i8(pool, scales, tok, page, off):
    """Append one (B, KV, D) fp token per row into int8 pages at (page, off).

    Per-page amax scales must cover every token in the page, so a token
    louder than the page's current scale triggers an in-place **requant** of
    that page (q' = round(q · s_old/s_new) — bounded, monotone error; the
    common quiet-token case is an exact no-op since ratio == 1). A page's
    first token (off == 0) ignores whatever stale scale a previous holder
    left — pages come back from the pool content-dirty but are always
    re-scaled before anything in them is readable.
    """
    P, ps, KV, D = pool.shape
    B = tok.shape[0]
    valid = page >= 0
    pidx = jnp.clip(page, 0, P - 1)
    s_old = scales[pidx]                                       # (B, KV)
    s_old = jnp.where((off == 0)[:, None], 0.0, s_old)
    amax = jnp.abs(tok.astype(jnp.float32)).max(axis=-1)       # (B, KV)
    s_new = jnp.maximum(s_old, amax)
    ratio = jnp.where(s_new > 0, s_old / jnp.where(s_new > 0, s_new, 1.0),
                      1.0)                                     # <= 1
    pg = pool[pidx].astype(jnp.float32)                        # (B, ps, KV, D)
    pg = jnp.round(pg * ratio[:, None, :, None])
    q_tok = quantize_to_i8(tok, s_new[..., None]).astype(jnp.float32)
    sel = (jnp.arange(ps)[None, :] == off[:, None])[..., None, None]
    pg = jnp.where(sel, q_tok[:, None], pg)
    drop = jnp.where(valid, pidx, P)
    pool = pool.at[drop].set(
        jnp.clip(pg, -127.0, 127.0).astype(jnp.int8), mode="drop")
    scales = scales.at[drop].set(s_new, mode="drop")
    return pool, scales


def _paged_append(entry, k_tok, v_tok, block_table, posv):
    """Decode-time single-token append into a paged entry (fp or int8).

    The caller (scheduler CoW guard) guarantees the destination page is
    private (refcount 1) — shared pages are materialized before the chunk.
    """
    P, ps = entry["pk"].shape[:2]
    page = jnp.take_along_axis(block_table, (posv // ps)[:, None],
                               axis=1)[:, 0]
    off = posv % ps
    if is_quantized_entry(entry):
        pk, ks = _append_token_i8(entry["pk"], entry["pk_scale"], k_tok,
                                  page, off)
        pv, vs = _append_token_i8(entry["pv"], entry["pv_scale"], v_tok,
                                  page, off)
        return {"pk": pk, "pv": pv, "pk_scale": ks, "pv_scale": vs}
    dropped = jnp.where(page >= 0, page, P)        # unallocated -> dropped
    return {"pk": entry["pk"].at[dropped, off].set(
                k_tok.astype(entry["pk"].dtype), mode="drop"),
            "pv": entry["pv"].at[dropped, off].set(
                v_tok.astype(entry["pv"].dtype), mode="drop")}


# -------------------------------------------------------------- ring helpers
def _ring_positions(pos, m: int):
    """Absolute position held by each of the m ring slots at time ``pos``."""
    i = jnp.arange(m)
    return pos - jnp.mod(pos - i, m)


def _valid_mask(cfg, kind: str, cap: int, pos):
    """pos scalar or (B,) — per-slot positions for the device-resident decode
    loop (serve.engine). Returns (1, cap) or (B, cap)."""
    p = jnp.asarray(pos)[..., None]          # (1,) -> (cap,) or (B,1) -> (B,cap)
    i = jnp.arange(cap)
    if kind == "global":
        m = i <= p
    else:
        slot_pos = p - jnp.mod(p - i, cap)   # _ring_positions, broadcast form
        if kind == "local":
            m = slot_pos >= 0
        else:
            chunk_start = (p // cfg.chunk_size) * cfg.chunk_size
            m = slot_pos >= chunk_start
    return m if m.ndim == 2 else m[None, :]


# --------------------------------------------------------------- decode block
def _positions_2d(pos, B: int):
    """Scalar or (B,) pos -> (B,1) int32 position matrix."""
    pos = jnp.asarray(pos)
    if pos.ndim == 0:
        return jnp.broadcast_to(pos, (B, 1)).astype(jnp.int32)
    return pos[:, None].astype(jnp.int32)


def _attn_decode(p, x, kind, cache_entry, pos, cfg, block_table=None):
    """pos scalar (cohort decode) or (B,) (per-slot, the continuous-batching
    engine): each slot writes its own ring/cache position. A paged entry
    ({pk, pv} pool, is_paged_entry) takes the block-table path instead: the
    token is scattered into its page and attention reads the history through
    kernels.paged_attention (dispatch decided host-side by
    core.dataflow.attn_path — the serve scheduler's paged mode)."""
    B = x.shape[0]
    q, k, v = layers.attn_qkv(p, x, cfg)              # q (B,1,H,D), k/v (B,1,KV,D)
    if cfg.qk_norm:
        q = layers.head_rms_norm(q, p["q_norm"], cfg.norm_eps)
        k = layers.head_rms_norm(k, p["k_norm"], cfg.norm_eps)
    if cfg.pos_embed == "rope":
        theta = tfm._rope_theta_for(cfg, kind)
        positions = _positions_2d(pos, B)
        q = layers.rope(q, positions, theta)
        k = layers.rope(k, positions, theta)
    # mesh resolution (ISSUE 10): tp > 1 runs attention per local KV-head
    # shard (the shard-explicit single-jit program; bit-identical to tp=1
    # by per-head independence — see sharding.tensor_parallel)
    from repro.core import plan as _plan
    tp = getattr(_plan.active_plan(), "tp", 1) or 1
    if is_paged_entry(cache_entry):
        from repro.sharding import tensor_parallel as _tpar
        assert block_table is not None, "paged cache entry needs a block table"
        pos = jnp.asarray(pos)
        posv = jnp.broadcast_to(pos, (B,)).astype(jnp.int32)
        new_entry = _paged_append(cache_entry, k[:, 0], v[:, 0], block_table,
                                  posv)
        scales = {}
        if is_quantized_entry(new_entry):
            scales = dict(k_scale=new_entry["pk_scale"],
                          v_scale=new_entry["pv_scale"])
        ctx = _tpar.sharded_paged_attention(
            q, new_entry["pk"], new_entry["pv"], block_table, posv + 1,
            tp, softcap=cfg.attn_logit_softcap, **scales)
        return (layers.attn_out(p, ctx.astype(layers.COMPUTE_DTYPE)),
                new_entry)
    cap = cache_entry["k"].shape[1]
    pos = jnp.asarray(pos)
    if pos.ndim == 0:
        idx = pos % cap if kind != "global" else pos
        k_cache = jax.lax.dynamic_update_slice_in_dim(cache_entry["k"], k,
                                                      idx, axis=1)
        v_cache = jax.lax.dynamic_update_slice_in_dim(cache_entry["v"], v,
                                                      idx, axis=1)
    else:
        # per-slot write: one-hot select along the cap axis (vectorized form
        # of dynamic_update_slice; same clamp-at-cap semantics for 'global')
        idx = pos % cap if kind != "global" else jnp.minimum(pos, cap - 1)
        sel = (jnp.arange(cap)[None, :] == idx[:, None])[..., None, None]
        k_cache = jnp.where(sel, k, cache_entry["k"])
        v_cache = jnp.where(sel, v, cache_entry["v"])
    mask = _valid_mask(cfg, kind, cap, pos)
    if tp > 1:
        from repro.sharding import tensor_parallel as _tpar
        ctx = _tpar.sharded_decode_attention(
            q, k_cache, v_cache, jnp.broadcast_to(mask, (B, cap)), cfg, tp)
    else:
        ctx = layers.decode_attention(q, k_cache, v_cache,
                                      jnp.broadcast_to(mask, (B, cap)), cfg)
    return layers.attn_out(p, ctx), {"k": k_cache, "v": v_cache}


def apply_block_decode(p, x, cond, kind, is_moe, cfg, cache_entry, pos,
                       block_table=None):
    h = rms_norm(x, p["pre_norm"], cfg.norm_eps)
    if kind in ("global", "local", "chunked"):
        y, new_entry = _attn_decode(p["attn"], h, kind, cache_entry, pos, cfg,
                                    block_table)
    elif kind == "ssm":
        y, new_entry = ssm_lib.ssm_block_decode(p["ssm"], h, cache_entry, cfg)
    elif kind == "rglru":
        y, new_entry = rglru_lib.rglru_block_decode(p["rglru"], h, cache_entry, cfg)
    if cfg.use_post_norm:
        y = rms_norm(y, p["post_norm"], cfg.norm_eps)
    x = x + y
    if cfg.cross_attn_cond and kind in ("global", "local", "chunked"):
        hc = rms_norm(x, p["pre_norm_cross"], cfg.norm_eps)
        x = x + layers.cross_attention(p["cross_attn"], hc, cond, cfg)
    if kind != "ssm":
        h = rms_norm(x, p["pre_norm_mlp"], cfg.norm_eps)
        if is_moe:
            y, _ = moe_lib.moe_layer(p["moe"], h, cfg)
        else:
            y = layers.mlp(p["mlp"], h, cfg)
        if cfg.use_post_norm:
            y = rms_norm(y, p["post_norm_mlp"], cfg.norm_eps)
        x = x + y
    return x, new_entry


def serve_step(params, cache, tokens, pos, cfg, cond=None, hints=None,
               block_table=None):
    """One decode step. tokens (B,1) or (B,K,1); pos scalar int32 (shared
    across the batch) or (B,) int32 (per-slot positions — the continuous
    batching engine's device-resident loop). ``block_table`` (B, max_pages)
    int32 routes paged cache entries (init_paged_cache) through the paged
    attention kernel. Returns (logits fp32, new_cache)."""
    x = tfm.embed_tokens(params, tokens, cfg)
    if hints is not None:
        x = hints.constrain_act(x)
    B = x.shape[0]
    if cfg.pos_embed == "sinusoidal":
        positions = _positions_2d(pos, B)
        x = x + layers.sinusoidal_pos(positions, cfg.d_model).astype(COMPUTE_DTYPE)
    kinds = tfm.slot_kinds(cfg)
    period = tfm.scan_period(cfg)

    new_cache: Dict = {}
    if "blocks" in params:
        def body(x, inp):
            pp, pc = inp
            npc = {}
            for j in range(period):
                x, npc[f"slot{j}"] = apply_block_decode(
                    pp[f"slot{j}"], x, cond, *kinds[j], cfg,
                    pc[f"slot{j}"], pos, block_table)
                if hints is not None:
                    x = hints.constrain_act(x)
            return x, npc
        x, new_cache["blocks"] = jax.lax.scan(
            body, x, (params["blocks"], cache["blocks"]))
    if "rem" in params:
        new_cache["rem"] = {}
        for j in range(tfm.num_remainder(cfg)):
            x, new_cache["rem"][f"rem{j}"] = apply_block_decode(
                params["rem"][f"rem{j}"], x, cond, *kinds[j], cfg,
                cache["rem"][f"rem{j}"], pos, block_table)
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = tfm.lm_logits(params, x, cfg)
    return logits, new_cache


def verify_step(params, cache, tokens, pos, cfg, cond=None, hints=None,
                block_table=None):
    """Score k candidate tokens per row in ONE dispatch — the speculative-
    decoding verifier (ISSUE 9).

    tokens (B, k) int32: row b's candidate continuation; tokens[b, 0] lands
    at position ``pos[b]``. Returns (logits fp32 (B, k, ...vocab), new_cache)
    where logits[b, i] conditions on the row's history plus tokens[b, :i+1]
    — exactly what ``serve_step`` would return after consuming those i+1
    tokens sequentially, bit-identical for fp page pools (asserted in
    tests/test_spec_decode.py the way paged==contiguous was).

    Mechanism: the k positions flatten into the batch axis. Page pools are
    row-count-free (addressed purely through block tables), so replicating
    each row's block table k times gives k "virtual rows" sharing one page
    chain: every flattened row appends its token at its own (page, offset)
    — disjoint targets, one scatter — and reads with per-row length
    ``pos + i + 1``, which exposes exactly the appends of its own prefix
    (later candidates sit past the length bound and are masked). That makes
    the single dispatch causal over the candidate block with no transient
    (B, k, cache_len) attention mask and no second write pass.

    Only valid for configs whose every layer is global attention on a paged
    cache (the plan's ``spec`` gate): ring/recurrent entries carry per-row
    state that the flattening cannot replicate. Quantized (int8) pools take
    a sequential k-step fallback instead — per-page amax scales make the
    append order observable (a louder later token requants the whole page),
    so the flattened scatter would race whole-page rewrites; the fallback
    keeps pools and logits bit-identical to sequential ``serve_step`` calls
    at k× dispatch cost, which is why the plan speculates on fp pools only.
    """
    assert block_table is not None, "verify_step requires a paged cache"
    kinds = {kk for kk, _ in tfm.slot_kinds(cfg)}
    assert kinds == {"global"}, \
        f"verify_step needs an all-global-attention config, got {kinds}"
    B, k = tokens.shape
    posv = jnp.broadcast_to(jnp.asarray(pos), (B,)).astype(jnp.int32)

    quantized = any(is_quantized_entry(e)
                    for e in jax.tree.leaves(cache, is_leaf=is_paged_entry))
    if quantized:
        outs = []
        for i in range(k):
            lg, cache = serve_step(params, cache, tokens[:, i:i + 1],
                                   posv + i, cfg, cond=cond, hints=hints,
                                   block_table=block_table)
            outs.append(lg)
        return jnp.concatenate(outs, axis=1), cache

    posf = (posv[:, None]
            + jnp.arange(k, dtype=jnp.int32)[None, :]).reshape(-1)
    tokf = tokens.reshape(-1)[:, None]                       # (B*k, 1)
    btf = jnp.repeat(block_table, k, axis=0)                 # (B*k, MP)
    logits, new_cache = serve_step(params, cache, tokf, posf, cfg, cond=cond,
                                   hints=hints, block_table=btf)
    return logits.reshape((B, k) + logits.shape[2:]), new_cache


# -------------------------------------------------------------------- prefill
def _gather_ring(full, m: int):
    """full (B,S,...) -> ring (B,m,...) honoring the ring invariant at pos=S-1."""
    S = full.shape[1]
    i = jnp.arange(m)
    p = (S - 1) - jnp.mod((S - 1) - i, m)
    return jnp.take(full, jnp.clip(p, 0, S - 1), axis=1)


def _gather_ring_ragged(full, m: int, lengths):
    """Per-row ring gather: row b honors the ring invariant at pos=lengths[b]-1.

    The batched-prefill analogue of _gather_ring for right-padded batches:
    each row's ring slots are filled from its *own* last positions, so pad
    tokens past a row's length never enter the ring. Slots that would map to
    negative positions (prompt shorter than the ring) clip to 0 — their data
    is garbage-but-masked, exactly like _gather_ring's clip (the decode-side
    _valid_mask recomputes validity from pos).
    """
    S = full.shape[1]
    i = jnp.arange(m)
    last = (lengths - 1)[:, None]                    # (B,1)
    p = last - jnp.mod(last - i[None, :], m)         # (B,m)
    p = jnp.clip(p, 0, S - 1)
    idx = p.reshape(p.shape + (1,) * (full.ndim - 2))
    return jnp.take_along_axis(full, idx, axis=1)


@dataclasses.dataclass
class PagedPrefill:
    """Page-native prefill-write routing (the paged output mode of
    prefill_batched). When present, global-attention K/V is scattered
    straight into the page pools of ``cache`` through per-row block tables
    *during the layer scan* — the dense (B, cache_len, ...) slot-shaped
    transient of the scatter-after-prefill path never exists — and every
    per-row entry (ring, recurrent) is merged into its device row at
    ``slots``. The returned cache is the full-width cache, refill-complete.

    ``write_start`` (B,) masks writes before each row's shared-prefix
    boundary (copy-on-write prefix sharing: adopted pages are read-only and
    already hold identical content). None writes from token 0.
    """
    cache: Dict
    block_table_rows: "jnp.ndarray"      # (B, max_pages) physical page ids
    slots: "jnp.ndarray"                 # (B,) device rows being refilled
    write_start: Optional["jnp.ndarray"] = None


def _merge_rows(cache_entry, row_entry, slots):
    """Merge B-row prefill state into its full-width per-row cache entry."""
    return jax.tree.map(
        lambda c, s: c.at[slots].set(s.astype(c.dtype)),
        cache_entry, row_entry)


def _attn_prefill(p, x, kind, positions, cfg, cache_len: int, lengths=None,
                  cache_entry=None, paged: Optional[PagedPrefill] = None):
    q, k, v = layers.attn_qkv(p, x, cfg)
    if cfg.qk_norm:
        q = layers.head_rms_norm(q, p["q_norm"], cfg.norm_eps)
        k = layers.head_rms_norm(k, p["k_norm"], cfg.norm_eps)
    if cfg.pos_embed == "rope":
        theta = tfm._rope_theta_for(cfg, kind)
        q = layers.rope(q, positions, theta)
        k = layers.rope(k, positions, theta)
    if kind == "local":
        ctx = layers.local_attention(q, k, v, cfg)
    elif kind == "chunked":
        ctx = layers.chunked_attention(q, k, v, cfg)
    else:
        ctx = layers.full_causal_attention(q, k, v, cfg)
    cap = _attn_cache_capacity(cfg, kind, cache_len)
    S = k.shape[1]
    if paged is not None and is_paged_entry(cache_entry):
        # page-native write: (B, tier) K/V lands in pool pages as it is
        # produced — no (B, cache_len) padding, no post-prefill scatter
        entry = paged_prefill_write(cache_entry, k, v,
                                    paged.block_table_rows, lengths,
                                    paged.write_start)
    elif kind == "global":
        # pad rows of a right-padded batch leave pad-KV at positions >= that
        # row's length; decode's _valid_mask (i <= pos) never exposes them and
        # the serve loop overwrites them in order as pos advances.
        pad = cap - S
        entry = {"k": jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0))),
                 "v": jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))}
    elif lengths is None:
        entry = {"k": _gather_ring(k, cap), "v": _gather_ring(v, cap)}
    else:
        entry = {"k": _gather_ring_ragged(k, cap, lengths),
                 "v": _gather_ring_ragged(v, cap, lengths)}
    if paged is not None and not is_paged_entry(cache_entry):
        entry = _merge_rows(cache_entry, entry, paged.slots)
    return layers.attn_out(p, ctx), entry


def apply_block_prefill(p, x, cond, kind, is_moe, cfg, positions, cache_len,
                        lengths=None, cache_entry=None,
                        paged: Optional[PagedPrefill] = None):
    h = rms_norm(x, p["pre_norm"], cfg.norm_eps)
    if kind in ("global", "local", "chunked"):
        y, entry = _attn_prefill(p["attn"], h, kind, positions, cfg, cache_len,
                                 lengths, cache_entry, paged)
    elif kind == "ssm":
        y, entry = ssm_lib.ssm_block(p["ssm"], h, cfg, return_state=True)
        if paged is not None:
            entry = _merge_rows(cache_entry, entry, paged.slots)
    elif kind == "rglru":
        y, entry = rglru_lib.rglru_block(p["rglru"], h, cfg, return_state=True)
        if paged is not None:
            entry = _merge_rows(cache_entry, entry, paged.slots)
    if cfg.use_post_norm:
        y = rms_norm(y, p["post_norm"], cfg.norm_eps)
    x = x + y
    if cfg.cross_attn_cond and kind in ("global", "local", "chunked"):
        hc = rms_norm(x, p["pre_norm_cross"], cfg.norm_eps)
        x = x + layers.cross_attention(p["cross_attn"], hc, cond, cfg)
    if kind != "ssm":
        h = rms_norm(x, p["pre_norm_mlp"], cfg.norm_eps)
        if is_moe:
            y, _ = moe_lib.moe_layer(p["moe"], h, cfg)
        else:
            y = layers.mlp(p["mlp"], h, cfg)
        if cfg.use_post_norm:
            y = rms_norm(y, p["post_norm_mlp"], cfg.norm_eps)
        x = x + y
    return x, entry


def _prefill_impl(params, tokens, cfg, cache_len: int, lengths=None, *,
                  patch_embeds=None, cond=None, hints=None,
                  paged: Optional[PagedPrefill] = None):
    x = tfm.embed_tokens(params, tokens, cfg)
    if cfg.frontend == "vision" and patch_embeds is not None:
        x = jnp.concatenate([patch_embeds.astype(COMPUTE_DTYPE), x], axis=1)
    if hints is not None:
        x = hints.constrain_act(x)
    B, S = x.shape[:2]
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
    if cfg.pos_embed == "sinusoidal":
        x = x + layers.sinusoidal_pos(positions, cfg.d_model).astype(COMPUTE_DTYPE)
    kinds = tfm.slot_kinds(cfg)
    period = tfm.scan_period(cfg)

    cache: Dict = {}
    if "blocks" in params:
        if paged is not None:
            # paged output mode: scan over (params, cache) pairs so each
            # layer writes its K/V into the period's page pool (or merges
            # its per-row state at ``slots``) as the scan visits it
            def body(x, inp):
                pp, pc = inp
                entries = {}
                for j in range(period):
                    x, entries[f"slot{j}"] = apply_block_prefill(
                        pp[f"slot{j}"], x, cond, *kinds[j], cfg, positions,
                        cache_len, lengths, pc[f"slot{j}"], paged)
                    if hints is not None:
                        x = hints.constrain_act(x)
                return x, entries
            x, cache["blocks"] = jax.lax.scan(
                body, x, (params["blocks"], paged.cache["blocks"]))
        else:
            def body(x, pp):
                entries = {}
                for j in range(period):
                    x, entries[f"slot{j}"] = apply_block_prefill(
                        pp[f"slot{j}"], x, cond, *kinds[j], cfg, positions,
                        cache_len, lengths)
                    if hints is not None:
                        x = hints.constrain_act(x)
                return x, entries
            x, cache["blocks"] = jax.lax.scan(body, x, params["blocks"])
    if "rem" in params:
        cache["rem"] = {}
        for j in range(tfm.num_remainder(cfg)):
            x, cache["rem"][f"rem{j}"] = apply_block_prefill(
                params["rem"][f"rem{j}"], x, cond, *kinds[j], cfg, positions,
                cache_len, lengths,
                paged.cache["rem"][f"rem{j}"] if paged is not None else None,
                paged)
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    if lengths is None:
        x_last = x[:, -1:]
    else:
        # per-row last real position of the right-padded batch
        idx = (lengths - 1)[:, None, None]
        x_last = jnp.take_along_axis(x, jnp.broadcast_to(
            idx, (B, 1, x.shape[-1])), axis=1)
    logits = tfm.lm_logits(params, x_last, cfg)
    return logits, cache


def prefill(params, tokens, cfg, cache_len: int, *, patch_embeds=None,
            cond=None, hints=None):
    """Forward over the prompt, building the cache. Returns
    (last-position logits fp32, cache)."""
    return _prefill_impl(params, tokens, cfg, cache_len, None,
                         patch_embeds=patch_embeds, cond=cond, hints=hints)


def prefill_batched(params, tokens, lengths, cfg, cache_len: int, *,
                    cond=None, hints=None,
                    paged: Optional[PagedPrefill] = None):
    """Batched prefill over right-padded prompts of unequal length.

    tokens (B, S) right-padded to a common tier length S; lengths (B,) int32
    actual prompt lengths. Returns (per-row last-*real*-position logits
    (B,1,...), cache) where every cache entry honors each row's own length:
    ring entries gather per-row (``_gather_ring_ragged``), global entries
    rely on decode's pos-derived validity mask to hide pad positions.

    ``paged`` (PagedPrefill) switches on the page-native output mode: the
    returned cache is ``paged.cache`` with global K/V written straight into
    its page pools through per-row block tables during the layer scan and
    per-row entries merged at ``paged.slots`` — no (B, cache_len) dense
    transient, no post-prefill scatter, bit-identical pool contents to the
    scatter-after-prefill path (asserted in tests/test_paged_prefill_cow.py).

    Causality makes the padded forward exact for the real prefix of every
    attention row. NOT valid for recurrent kinds (ssm/rglru) when any
    length < S — pad tokens would pollute the carried state; callers
    (serve.engine) bucket those archs by exact length so lengths == S.
    Vision patch embeds are unsupported here: the per-row last-logits gather
    and ragged ring gather do not carry the ``num_patches`` offset of the
    concatenated sequence (no serving caller passes patches today).
    """
    assert cfg.frontend != "vision" or cfg.num_patches == 0, \
        "prefill_batched does not support vision patch offsets"
    return _prefill_impl(params, tokens, cfg, cache_len,
                         jnp.asarray(lengths, jnp.int32),
                         cond=cond, hints=hints, paged=paged)
