"""Stub modality frontends (per spec: ``input_specs()`` provides precomputed
frame/patch embeddings; the ViT / EnCodec encoders themselves are NOT built).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.layers import COMPUTE_DTYPE


def patch_embed_spec(batch: int, cfg):
    return jax.ShapeDtypeStruct((batch, cfg.num_patches, cfg.d_model),
                                COMPUTE_DTYPE)


def cond_embed_spec(batch: int, cfg):
    return jax.ShapeDtypeStruct((batch, cfg.cross_attn_cond, cfg.d_model),
                                COMPUTE_DTYPE)


def synth_patch_embeds(rng, batch: int, cfg):
    return jax.random.normal(rng, (batch, cfg.num_patches, cfg.d_model),
                             COMPUTE_DTYPE) * 0.02


def synth_cond_embeds(rng, batch: int, cfg):
    return jax.random.normal(rng, (batch, cfg.cross_attn_cond, cfg.d_model),
                             COMPUTE_DTYPE) * 0.02
