"""RG-LRU recurrent block (Griffin / RecurrentGemma).

Training uses ``jax.lax.associative_scan`` over the linear recurrence
h_t = a_t ⊙ h_{t-1} + b_t — log-depth, MXU/VPU-friendly, the TPU-native stand-in
for the ASIC's sequential PE recurrence.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.layers import (ACCUM_DTYPE, COMPUTE_DTYPE, PARAM_DTYPE,
                                 cast_compute, constrain, dense_init)

_C = 8.0                 # Griffin's fixed gate exponent scale
_NUM_BLOCKS = 8          # block-diagonal gate projections
_CONV_K = 4


def init_rglru_params(rng, cfg):
    d, w = cfg.d_model, cfg.lru_width
    nb = _NUM_BLOCKS
    bs = w // nb
    ks = jax.random.split(rng, 8)
    return {
        "in_x": dense_init(ks[0], (d, w)),        # recurrent branch input
        "in_gate": dense_init(ks[1], (d, w)),     # gelu gate branch
        "conv_w": dense_init(ks[2], (_CONV_K, w), in_axis=0),
        "conv_b": jnp.zeros((w,), PARAM_DTYPE),
        "w_input_gate": dense_init(ks[3], (nb, bs, bs), in_axis=1),
        "b_input_gate": jnp.zeros((nb, bs), PARAM_DTYPE),
        "w_rec_gate": dense_init(ks[4], (nb, bs, bs), in_axis=1),
        "b_rec_gate": jnp.zeros((nb, bs), PARAM_DTYPE),
        # Lambda init so a^c = sigmoid(L)^c lands in [0.9, 0.999]
        "Lambda": (jax.random.uniform(ks[5], (w,), jnp.float32,
                                      minval=2.2, maxval=6.9)).astype(PARAM_DTYPE),
        "out_proj": dense_init(ks[6], (w, d)),
    }


def _block_diag_proj(x, w, b):
    """x (..., nb*bs) @ block-diag w (nb, bs, bs) + b."""
    nb, bs, _ = w.shape
    xr = x.reshape(x.shape[:-1] + (nb, bs))
    y = jnp.einsum("...nb,nbc->...nc", xr.astype(jnp.float32),
                   w.astype(jnp.float32)) + b.astype(jnp.float32)
    return y.reshape(x.shape)


def _gates(params, x):
    """x (..., w) -> (log_a, gated_input) in fp32."""
    i_gate = jax.nn.sigmoid(_block_diag_proj(x, params["w_input_gate"],
                                             params["b_input_gate"]))
    r_gate = jax.nn.sigmoid(_block_diag_proj(x, params["w_rec_gate"],
                                             params["b_rec_gate"]))
    log_a = -_C * r_gate * jax.nn.softplus(params["Lambda"].astype(jnp.float32))
    a = jnp.exp(log_a)
    # sqrt(1 - a^2) normalizer keeps the recurrence norm-preserving
    norm = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12))
    bt = norm * i_gate * x.astype(jnp.float32)
    return a, bt


def _causal_conv1d(x, w, b):
    K = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (K - 1, 0), (0, 0)))
    out = jnp.zeros(x.shape, jnp.float32)
    for k in range(K):
        out = out + xp[:, k:k + x.shape[1], :].astype(jnp.float32) * \
            w[k].astype(jnp.float32)
    return (out + b.astype(jnp.float32)).astype(x.dtype)


def rglru_block(params, x, cfg, return_state: bool = False):
    """Full Griffin recurrent block. x (b,l,d) -> (b,l,d) [, decode state]."""
    gate = constrain(jax.nn.gelu(
        jnp.einsum("bld,dw->blw", x, cast_compute(params["in_gate"]),
                   preferred_element_type=ACCUM_DTYPE), approximate=True))
    xr = constrain(jnp.einsum("bld,dw->blw", x, cast_compute(params["in_x"]),
                              preferred_element_type=ACCUM_DTYPE
                              ).astype(COMPUTE_DTYPE))
    conv = _causal_conv1d(xr, params["conv_w"], params["conv_b"])
    a, bt = _gates(params, conv)                                 # fp32 (b,l,w)

    def combine(c1, c2):
        a1, b1 = c1
        a2, b2 = c2
        return a1 * a2, a2 * b1 + b2

    _, h = jax.lax.associative_scan(combine, (a, bt), axis=1)
    y = (h * gate.astype(jnp.float32)).astype(COMPUTE_DTYPE)
    out = jnp.einsum("blw,wd->bld", y, cast_compute(params["out_proj"]),
                     preferred_element_type=ACCUM_DTYPE).astype(COMPUTE_DTYPE)
    if not return_state:
        return out
    l = x.shape[1]
    K = _CONV_K
    if l >= K - 1:
        conv_state = xr[:, l - (K - 1):]
    else:
        conv_state = jnp.pad(xr, ((0, 0), (K - 1 - l, 0), (0, 0)))
    return out, {"conv": conv_state, "h": h[:, -1]}


def rglru_block_decode(params, x, state, cfg):
    """One-token step. state: {conv (b,K-1,w), h (b,w) fp32}."""
    gate = jax.nn.gelu(
        jnp.einsum("bld,dw->blw", x, cast_compute(params["in_gate"]),
                   preferred_element_type=ACCUM_DTYPE), approximate=True)
    xr = jnp.einsum("bld,dw->blw", x, cast_compute(params["in_x"]),
                    preferred_element_type=ACCUM_DTYPE).astype(COMPUTE_DTYPE)
    window = jnp.concatenate([state["conv"], xr], axis=1)         # (b,K,w)
    conv = jnp.einsum("bkw,kw->bw", window.astype(jnp.float32),
                      params["conv_w"].astype(jnp.float32)) + \
        params["conv_b"].astype(jnp.float32)
    a, bt = _gates(params, conv.astype(COMPUTE_DTYPE))            # (b,w)
    h = a * state["h"] + bt
    y = (h[:, None, :] * gate.astype(jnp.float32)).astype(COMPUTE_DTYPE)
    out = jnp.einsum("blw,wd->bld", y, cast_compute(params["out_proj"]),
                     preferred_element_type=ACCUM_DTYPE).astype(COMPUTE_DTYPE)
    return out, {"conv": window[:, 1:], "h": h}


def init_rglru_state(batch: int, cfg):
    return {
        "conv": jnp.zeros((batch, _CONV_K - 1, cfg.lru_width), COMPUTE_DTYPE),
        "h": jnp.zeros((batch, cfg.lru_width), jnp.float32),
    }
