"""Mixture-of-Experts layer with sort-based (gather/scatter) dispatch.

Top-k routing IS the paper's sparsity insight applied at layer granularity: each
token touches k/E of the weights — the diminished-reuse regime in which the
HM-planner picks the "unicast" (expert-parallel) mode (DESIGN.md §4).

Dispatch is sort-based and *per batch row* (vmapped) so each data shard sorts
locally — no global sort collectives. One-hot einsum dispatch (Mesh-TF style)
would add B·S·k·E·C·d FLOPs (8× the expert GEMMs at E=128); gather/scatter
dispatch moves bytes instead, which is what the roofline wants.
"""
from __future__ import annotations

import math
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.models.layers import (ACCUM_DTYPE, COMPUTE_DTYPE, PARAM_DTYPE,
                                 cast_compute, constrain, dense_init)


def init_moe_params(rng, cfg):
    d, f, E = cfg.d_model, cfg.d_ff, cfg.num_experts
    ks = jax.random.split(rng, 5)
    p = {
        "router": dense_init(ks[0], (d, E)),
        "wg": dense_init(ks[1], (E, d, f), in_axis=1),
        "wu": dense_init(ks[2], (E, d, f), in_axis=1),
        "wd": dense_init(ks[3], (E, f, d), in_axis=1),
    }
    if cfg.shared_expert:
        kk = jax.random.split(ks[4], 3)
        p["shared"] = {"wg": dense_init(kk[0], (d, f)),
                       "wu": dense_init(kk[1], (d, f)),
                       "wd": dense_init(kk[2], (f, d))}
    return p


def expert_capacity(tokens_per_row: int, cfg) -> int:
    c = math.ceil(tokens_per_row * cfg.experts_per_token *
                  cfg.capacity_factor / cfg.num_experts)
    return max(8, ((c + 7) // 8) * 8)  # pad to 8 for clean tiling


def _dispatch_row(x_row, eid_row, gate_row, E: int, C: int):
    """Single batch row. x_row (S,d); eid/gate (S,K). Returns
    expert_in (E,C,d), meta for combine."""
    S, K = eid_row.shape
    d = x_row.shape[-1]
    T = S * K
    flat_e = eid_row.reshape(T)
    flat_g = gate_row.reshape(T)
    tok_idx = jnp.repeat(jnp.arange(S), K)
    order = jnp.argsort(flat_e)                       # stable, groups experts
    sorted_e = flat_e[order]
    starts = jnp.searchsorted(sorted_e, jnp.arange(E))
    pos = jnp.arange(T) - starts[sorted_e]            # slot within expert
    keep = pos < C
    pos_c = jnp.clip(pos, 0, C - 1)
    xs = x_row[tok_idx[order]]                        # (T,d) gathered
    xs = jnp.where(keep[:, None], xs, 0)
    buf = jnp.zeros((E, C, d), x_row.dtype)
    buf = buf.at[sorted_e, pos_c].add(xs, mode="drop")
    meta = (order, sorted_e, pos_c, keep, tok_idx, flat_g)
    return buf, meta


def _combine_row(expert_out, meta, S: int):
    order, sorted_e, pos_c, keep, tok_idx, flat_g = meta
    vals = expert_out[sorted_e, pos_c]                # (T,d)
    g = flat_g[order]
    vals = jnp.where(keep[:, None], vals, 0) * g[:, None].astype(vals.dtype)
    out = jnp.zeros((S, expert_out.shape[-1]), expert_out.dtype)
    out = out.at[tok_idx[order]].add(vals, mode="drop")
    return out


def moe_layer_decode(params, x, cfg) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Decode-time MoE (S==1): compute all experts densely, combine by gates.

    At one token per row, sort-dispatch pads every expert to capacity — up to
    E·C/k wasted FLOPs. Dense-all-experts instead mirrors what an EP shard
    really does at decode: read the local expert weights once, apply to the few
    resident tokens; compute is trivial, HBM weight traffic dominates (and the
    roofline correctly shows the layer as memory-bound).
    """
    E, K = cfg.num_experts, cfg.experts_per_token
    logits = jnp.einsum("bsd,de->bse", x, cast_compute(params["router"]),
                        preferred_element_type=jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, eids = jax.lax.top_k(probs, K)
    gate_vals = gate_vals / jnp.maximum(
        jnp.sum(gate_vals, axis=-1, keepdims=True), 1e-9)
    gates_full = jnp.sum(
        jax.nn.one_hot(eids, E, dtype=jnp.float32) * gate_vals[..., None],
        axis=2)                                        # (B,S,E)
    act = jax.nn.silu if cfg.mlp_act == "silu" else \
        (lambda t: jax.nn.gelu(t, approximate=True))
    # mesh resolution (ISSUE 10): ep > 1 computes the expert einsums per
    # local E/ep slice — the weights a real EP device holds — and gathers
    # along the (batch) expert axis; the gate-weighted combine below runs
    # on the full-E tensor unchanged, so the result is bit-identical
    from repro.core import plan as _plan
    ep = getattr(_plan.active_plan(), "ep", 1) or 1
    if ep > 1 and E % ep == 0:
        from repro.sharding import tensor_parallel as _tpar
        out = _tpar.sharded_expert_mlp(
            x, params["wg"], params["wu"], params["wd"], act=act,
            cast=cast_compute, ep=ep, accum_dtype=ACCUM_DTYPE,
            compute_dtype=COMPUTE_DTYPE)
    else:
        g = jnp.einsum("bsd,edf->ebsf", x, cast_compute(params["wg"]),
                       preferred_element_type=ACCUM_DTYPE)
        u = jnp.einsum("bsd,edf->ebsf", x, cast_compute(params["wu"]),
                       preferred_element_type=ACCUM_DTYPE)
        h = (act(g) * u).astype(COMPUTE_DTYPE)
        out = jnp.einsum("ebsf,efd->ebsd", h, cast_compute(params["wd"]),
                         preferred_element_type=ACCUM_DTYPE)
    y = jnp.einsum("ebsd,bse->bsd", out,
                   gates_full).astype(COMPUTE_DTYPE)
    if cfg.shared_expert:
        sp = params["shared"]
        sg = jnp.einsum("bsd,df->bsf", x, cast_compute(sp["wg"]),
                        preferred_element_type=ACCUM_DTYPE)
        su = jnp.einsum("bsd,df->bsf", x, cast_compute(sp["wu"]),
                        preferred_element_type=ACCUM_DTYPE)
        sh = (act(sg) * su).astype(COMPUTE_DTYPE)
        y = y + jnp.einsum("bsf,fd->bsd", sh, cast_compute(sp["wd"]),
                           preferred_element_type=ACCUM_DTYPE).astype(COMPUTE_DTYPE)
    return y, jnp.zeros((), jnp.float32)


def moe_layer(params, x, cfg) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """x (B,S,d) -> (y (B,S,d), aux_loss scalar)."""
    B, S, d = x.shape
    if S <= 8:
        return moe_layer_decode(params, x, cfg)
    x = constrain(x)          # pin the dispatch input (scatter operands follow)
    E, K = cfg.num_experts, cfg.experts_per_token
    C = expert_capacity(S, cfg)
    logits = jnp.einsum("bsd,de->bse", x, cast_compute(params["router"]),
                        preferred_element_type=jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, eids = jax.lax.top_k(probs, K)          # (B,S,K)
    gate_vals = gate_vals / jnp.maximum(
        jnp.sum(gate_vals, axis=-1, keepdims=True), 1e-9)

    # ---- aux load-balance loss (Switch/Mixtral style)
    me = jnp.mean(probs, axis=(0, 1))                                # (E,)
    ce = jnp.mean((jax.nn.one_hot(eids, E).sum(axis=2) > 0), axis=(0, 1))
    aux = jnp.sum(me * ce) * E

    expert_in, meta = jax.vmap(
        lambda xr, er, gr: _dispatch_row(xr, er, gr, E, C),
        out_axes=(1, 0))(x, eids, gate_vals)
    # expert_in (E,B,C,d), expert-leading so the batched dot partitions/executes
    # cleanly (EP shards the leading axis; CPU DotThunk needs leading batch).
    # The E-dim constraint IS the MoE all-to-all: tokens leave the dp layout
    # and land expert-sharded (paper's interleaved-multicast, DESIGN.md §4).
    ecand = [(0, (E,))]                  # EP if E divides the model axis
    fcand = [(0, (E,)), (3, (cfg.d_ff,))]  # else TP over d_ff
    expert_in = constrain(expert_in, batch_dim=1, tp_candidates=ecand)
    act = jax.nn.silu if cfg.mlp_act == "silu" else \
        (lambda t: jax.nn.gelu(t, approximate=True))
    g = jnp.einsum("ebcd,edf->ebcf", expert_in, cast_compute(params["wg"]),
                   preferred_element_type=ACCUM_DTYPE)
    u = jnp.einsum("ebcd,edf->ebcf", expert_in, cast_compute(params["wu"]),
                   preferred_element_type=ACCUM_DTYPE)
    h = constrain((act(g) * u).astype(COMPUTE_DTYPE), batch_dim=1,
                  tp_candidates=fcand)
    # row-parallel expert down-proj in bf16 (TP all-reduce halves, §Perf C2)
    out = jnp.einsum("ebcf,efd->ebcd", h, cast_compute(params["wd"]),
                     preferred_element_type=COMPUTE_DTYPE)
    out = constrain(out, batch_dim=1, tp_candidates=ecand)
    y = jax.vmap(lambda eo, m: _combine_row(eo, m, S),
                 in_axes=(1, 0))(out, meta)
    y = constrain(y)

    if cfg.shared_expert:
        sp = params["shared"]
        sg = jnp.einsum("bsd,df->bsf", x, cast_compute(sp["wg"]),
                        preferred_element_type=ACCUM_DTYPE)
        su = jnp.einsum("bsd,df->bsf", x, cast_compute(sp["wu"]),
                        preferred_element_type=ACCUM_DTYPE)
        sh = (act(sg) * su).astype(COMPUTE_DTYPE)
        y = y + jnp.einsum("bsf,fd->bsd", sh, cast_compute(sp["wd"]),
                           preferred_element_type=ACCUM_DTYPE).astype(COMPUTE_DTYPE)
    return y, aux
