"""Blocked flash attention with a custom VJP — O(S) residuals.

Without this, differentiating the attention scan saves per-step probability
blocks (O(S²) per layer), which at train_4k/prefill_32k scale is tens of GB
per chip. The custom VJP saves only (out, lse) and recomputes probability
blocks in the backward pass — the textbook FlashAttention trade (≈30% more
attention FLOPs for O(S) memory).

Three masking modes share one implementation:
    causal  — full causal (all kv blocks visited, masked above the diagonal;
              compute upper bound 2× the causal minimum)
    window  — sliding window w: only the ≤(w+qb)/kb blocks in the band are
              visited (gemma2/3 local layers, mixtral SWA)
    chunk   — llama4 iRoPE chunked attention: causal within fixed chunks

Sequence sharding (the §Perf "diminished-heads" lever): ``qpos`` carries the
GLOBAL positions of the local q rows, so the q tensor can be sharded along S
(e.g. over the model axis under shard_map) while K/V stay replicated — each
chip computes full attention for its own query rows. Used by
layers._flash_call when the head count doesn't divide the TP axis.

GQA layout: q (B,KV,R,Sq,D); k,v (B,KV,Sk,D). Output bf16 (fp32 accumulation
inside — the psum-SPad precision pair). The portable-XLA twin of
kernels/local_attention.py (the Pallas TPU kernel); both are tested against
kernels/ref.py.
"""
from __future__ import annotations

import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp

NEG_INF = -2.0e38
_PAD_POS = -(2 ** 30)        # sentinel position for padded q rows


def _block_count(S: int, b: int) -> int:
    return (S + b - 1) // b


def _offsets(mode: str, msize: int, qb: int, kb: int, nk: int) -> int:
    """How many kv blocks each q block visits."""
    if mode == "causal":
        return nk
    if mode == "window":
        return min((msize - 1 + qb) // kb + 1, nk)
    if mode == "chunk":
        return min(msize // kb + (1 if msize % kb else 0) + 1, nk)
    raise ValueError(mode)


def _mask(mode: str, msize: int, Sk: int, qv, kpos):
    m = (kpos <= qv) & (kpos >= 0) & (kpos < Sk) & (qv >= 0)
    if mode == "window":
        m &= (qv - kpos) < msize
    elif mode == "chunk":
        m &= (qv // msize) == (kpos // msize)
    return m


def _kv_block_index(mode: str, i, r, qstart, qb: int, kb: int, nk: int):
    """Logical kv block for offset r of q block i (may be out of range —
    clamped for slicing, exact value used for masking positions)."""
    if mode == "causal":
        return r
    last = (qstart + qb - 1) // kb
    return last - r


def _fwd_impl(q, k, v, qpos, mode: str, msize: int, softcap: float,
              qb: int, kb: int):
    """Returns (out (B,KV,R,Sq,D) fp32, lse (B,KV,R,Sq) fp32)."""
    B, KV, R, Sq, D = q.shape
    Sk = k.shape[2]
    scale = 1.0 / math.sqrt(D)
    nq, nk = _block_count(Sq, qb), _block_count(Sk, kb)
    noff = _offsets(mode, msize, qb, kb, nk)

    qp = jnp.pad(q, ((0, 0),) * 3 + ((0, nq * qb - Sq), (0, 0)))
    kp = jnp.pad(k, ((0, 0),) * 2 + ((0, nk * kb - Sk), (0, 0)))
    vp = jnp.pad(v, ((0, 0),) * 2 + ((0, nk * kb - Sk), (0, 0)))
    posp = jnp.pad(qpos, (0, nq * qb - Sq), constant_values=_PAD_POS)

    def q_step(_, i):
        qi = jax.lax.dynamic_slice_in_dim(qp, i * qb, qb, axis=3)
        pos_i = jax.lax.dynamic_slice_in_dim(posp, i * qb, qb)
        qstart = pos_i[0]
        m0 = jnp.full((B, KV, R, qb), NEG_INF, jnp.float32)
        l0 = jnp.zeros((B, KV, R, qb), jnp.float32)
        a0 = jnp.zeros((B, KV, R, qb, D), jnp.float32)

        def kv_step(carry, r):
            m, l, acc = carry
            j_log = _kv_block_index(mode, i, r, qstart, qb, kb, nk)
            j = jnp.clip(j_log, 0, nk - 1)
            kj = jax.lax.dynamic_slice_in_dim(kp, j * kb, kb, axis=2)
            vj = jax.lax.dynamic_slice_in_dim(vp, j * kb, kb, axis=2)
            s = jnp.einsum("bgrqd,bgkd->bgrqk", qi.astype(jnp.float32),
                           kj.astype(jnp.float32)) * scale
            if softcap > 0.0:
                s = jnp.tanh(s / softcap) * softcap
            kpos = j_log * kb + jnp.arange(kb)
            msk = _mask(mode, msize, Sk, pos_i[:, None], kpos[None, :])
            s = jnp.where(msk[None, None, None], s, NEG_INF)
            m_new = jnp.maximum(m, jnp.max(s, axis=-1))
            p = jnp.where(s > NEG_INF / 2, jnp.exp(s - m_new[..., None]), 0.0)
            corr = jnp.exp(m - m_new)
            l_new = l * corr + jnp.sum(p, axis=-1)
            # S²-sized p feeds the MXU in bf16: halves the dominant HBM flow
            acc_new = acc * corr[..., None] + jnp.einsum(
                "bgrqk,bgkd->bgrqd", p.astype(jnp.bfloat16),
                vj.astype(jnp.bfloat16),
                preferred_element_type=jnp.float32)
            return (m_new, l_new, acc_new), None

        (m, l, acc), _ = jax.lax.scan(kv_step, (m0, l0, a0),
                                      jnp.arange(noff))
        l_safe = jnp.maximum(l, 1e-30)
        return None, (acc / l_safe[..., None], m + jnp.log(l_safe))

    _, (out_blocks, lse_blocks) = jax.lax.scan(q_step, None, jnp.arange(nq))
    out = jnp.moveaxis(out_blocks, 0, 3).reshape(B, KV, R, nq * qb, D)[
        :, :, :, :Sq]
    lse = jnp.moveaxis(lse_blocks, 0, 3).reshape(B, KV, R, nq * qb)[
        :, :, :, :Sq]
    return out, lse


def _bwd_impl(q, k, v, qpos, out, lse, do, mode: str, msize: int,
              softcap: float, qb: int, kb: int):
    B, KV, R, Sq, D = q.shape
    Sk = k.shape[2]
    scale = 1.0 / math.sqrt(D)
    nq, nk = _block_count(Sq, qb), _block_count(Sk, kb)
    noff = _offsets(mode, msize, qb, kb, nk)

    padq = ((0, 0),) * 3 + ((0, nq * qb - Sq), (0, 0))
    padk = ((0, 0),) * 2 + ((0, nk * kb - Sk), (0, 0))
    qp = jnp.pad(q, padq)
    op = jnp.pad(out, padq)
    dop = jnp.pad(do, padq).astype(jnp.float32)
    lsep = jnp.pad(lse, ((0, 0),) * 3 + ((0, nq * qb - Sq),))
    posp = jnp.pad(qpos, (0, nq * qb - Sq), constant_values=_PAD_POS)
    kp = jnp.pad(k, padk)
    vp = jnp.pad(v, padk)

    Drow = jnp.sum(dop * op.astype(jnp.float32), axis=-1)      # (B,KV,R,Sq)

    dk0 = jnp.zeros((B, KV, nk * kb, D), jnp.float32)
    dv0 = jnp.zeros_like(dk0)

    def q_step(carry, i):
        dk, dv = carry
        qi = jax.lax.dynamic_slice_in_dim(qp, i * qb, qb, axis=3)
        oi = jax.lax.dynamic_slice_in_dim(dop, i * qb, qb, axis=3)
        li = jax.lax.dynamic_slice_in_dim(lsep, i * qb, qb, axis=3)
        Di = jax.lax.dynamic_slice_in_dim(Drow, i * qb, qb, axis=3)
        pos_i = jax.lax.dynamic_slice_in_dim(posp, i * qb, qb)
        qstart = pos_i[0]

        def kv_step(inner, r):
            dqi, dk, dv = inner
            j_log = _kv_block_index(mode, i, r, qstart, qb, kb, nk)
            j = jnp.clip(j_log, 0, nk - 1)
            kj = jax.lax.dynamic_slice_in_dim(kp, j * kb, kb, axis=2)
            vj = jax.lax.dynamic_slice_in_dim(vp, j * kb, kb, axis=2)
            s_pre = jnp.einsum("bgrqd,bgkd->bgrqk", qi.astype(jnp.float32),
                               kj.astype(jnp.float32)) * scale
            if softcap > 0.0:
                t = jnp.tanh(s_pre / softcap)
                s = t * softcap
            else:
                s = s_pre
            kpos = j_log * kb + jnp.arange(kb)
            msk = _mask(mode, msize, Sk, pos_i[:, None], kpos[None, :])
            s = jnp.where(msk[None, None, None], s, NEG_INF)
            p = jnp.where(s > NEG_INF / 2, jnp.exp(s - li[..., None]), 0.0)
            dp = jnp.einsum("bgrqd,bgkd->bgrqk", oi, vj.astype(jnp.float32))
            ds = p * (dp - Di[..., None])
            if softcap > 0.0:
                ds = ds * (1.0 - jnp.square(t))
            ds = jnp.where(msk[None, None, None], ds, 0.0)
            ds16 = ds.astype(jnp.bfloat16)        # S²-sized: bf16 to the MXU
            p16 = p.astype(jnp.bfloat16)
            dqi = dqi + scale * jnp.einsum(
                "bgrqk,bgkd->bgrqd", ds16, kj.astype(jnp.bfloat16),
                preferred_element_type=jnp.float32)
            dk_blk = scale * jnp.einsum(
                "bgrqk,bgrqd->bgkd", ds16, qi.astype(jnp.bfloat16),
                preferred_element_type=jnp.float32)
            dv_blk = jnp.einsum("bgrqk,bgrqd->bgkd", p16,
                                oi.astype(jnp.bfloat16),
                                preferred_element_type=jnp.float32)
            dk_cur = jax.lax.dynamic_slice_in_dim(dk, j * kb, kb, axis=2)
            dv_cur = jax.lax.dynamic_slice_in_dim(dv, j * kb, kb, axis=2)
            dk = jax.lax.dynamic_update_slice_in_dim(dk, dk_cur + dk_blk,
                                                     j * kb, axis=2)
            dv = jax.lax.dynamic_update_slice_in_dim(dv, dv_cur + dv_blk,
                                                     j * kb, axis=2)
            return (dqi, dk, dv), None

        dq0 = jnp.zeros((B, KV, R, qb, D), jnp.float32)
        (dqi, dk, dv), _ = jax.lax.scan(kv_step, (dq0, dk, dv),
                                        jnp.arange(noff))
        return (dk, dv), dqi

    (dk, dv), dq_blocks = jax.lax.scan(q_step, (dk0, dv0), jnp.arange(nq))
    dq = jnp.moveaxis(dq_blocks, 0, 3).reshape(B, KV, R, nq * qb, D)[
        :, :, :, :Sq]
    return dq, dk[:, :, :Sk], dv[:, :, :Sk]


@functools.partial(jax.custom_vjp, nondiff_argnums=(4, 5, 6, 7, 8))
def _flash(q, k, v, qpos, mode: str, msize: int, softcap: float,
           qb: int, kb: int):
    out, _ = _fwd_impl(q, k, v, qpos, mode, msize, softcap, qb, kb)
    return out.astype(jnp.bfloat16)


def _fa_fwd(q, k, v, qpos, mode, msize, softcap, qb, kb):
    out, lse = _fwd_impl(q, k, v, qpos, mode, msize, softcap, qb, kb)
    out16 = out.astype(jnp.bfloat16)
    return out16, (q, k, v, qpos, out16, lse)


def _fa_bwd(mode, msize, softcap, qb, kb, res, dout):
    q, k, v, qpos, out, lse = res
    dq, dk, dv = _bwd_impl(q, k, v, qpos, out, lse, dout, mode, msize,
                           softcap, qb, kb)
    return dq.astype(q.dtype), dk.astype(k.dtype), dv.astype(v.dtype), None


_flash.defvjp(_fa_fwd, _fa_bwd)


def flash_attention(q, k, v, mode: str = "causal", msize: int = 0,
                    softcap: float = 0.0, qb: int = 512, kb: int = 512,
                    qpos: Optional[jnp.ndarray] = None):
    """q (B,KV,R,Sq,D); k,v (B,KV,Sk,D) -> out (B,KV,R,Sq,D) bf16.

    ``qpos`` (Sq,) int32: global positions of the q rows (sequence-sharded
    attention); defaults to arange(Sq) (q and k cover the same positions).
    """
    if qpos is None:
        qpos = jnp.arange(q.shape[3], dtype=jnp.int32)
    return _flash(q, k, v, qpos.astype(jnp.int32), mode, msize, softcap,
                  qb, kb)
