"""Block-CSC sparse matmul Pallas kernel — the Sparse PE (paper §IV) on TPU.

The paper's PE walks CSC-compressed weights (address/count/data vectors) and
*skips the cycles* of zero entries. A systolic MXU cannot skip per-scalar
cycles, so the TPU-native "skip" is structural (DESIGN.md §2): weights are
tiled into MXU-aligned (bk × bn) blocks, all-zero blocks are never fetched nor
multiplied.

Mechanism = the paper's address vector, verbatim: the grid has one step per
*non-zero* block (nnzb, not nbk·nbn); two scalar-prefetched vectors —
``row_ids`` (which K-block each payload block came from) and ``col_ids``
(which N-block it belongs to, the expanded CSC col_ptr) — drive the BlockSpec
index maps, exactly like the PE's addr SPad drives its weight SPad reads.
Runtime is proportional to nnzb: a 90%-block-sparse layer takes ~10% of the
dense grid steps. Weight sparsity is compile-time-known (paper Table III), so
the vectors are built on host at encode time.

Revisit contract: BCSC stores blocks column-major, so all payload blocks of one
output column are consecutive grid steps — output-tile revisits are contiguous
and the fp32 accumulate-in-place pattern is safe.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.sparsity import BCSCMatrix
from repro.kernels import epilogue as _epi
from repro.kernels.epilogue import fused_epilogue


def _bcsc_kernel(row_ids_ref, col_ids_ref, x_ref, blk_ref, o_ref):
    """Grid (m_tiles, nnzb). One step = one non-zero weight block."""
    j = pl.program_id(1)
    col = col_ids_ref[j]
    prev = col_ids_ref[jnp.maximum(j - 1, 0)]
    first = jnp.logical_or(j == 0, col != prev)   # new output column segment

    partial = jnp.dot(x_ref[...], blk_ref[0],
                      preferred_element_type=jnp.float32)

    @pl.when(first)
    def _init():
        o_ref[...] = partial

    @pl.when(jnp.logical_not(first))
    def _accum():
        o_ref[...] += partial


def expand_col_ptr(col_ptr: np.ndarray) -> np.ndarray:
    """CSC address vector -> per-block column ids (host-side, compile time)."""
    cp = np.asarray(col_ptr)
    return np.repeat(np.arange(cp.size - 1, dtype=np.int32), np.diff(cp))


def ensure_nonempty_cols(m: BCSCMatrix) -> BCSCMatrix:
    """Insert one explicit zero block into every empty block-column.

    Mirrors the paper's repeated-address convention for all-zero columns
    (Fig. 16): every output tile must be visited at least once so the kernel
    initializes it. Host-side; weight sparsity is static.
    """
    cp = np.asarray(m.col_ptr)
    counts = np.diff(cp)
    if (counts > 0).all():
        return m
    blocks = np.asarray(m.blocks)
    row_ids = np.asarray(m.row_ids)
    bk, bn = m.block
    new_blocks, new_rows, new_cp = [], [], [0]
    zero = np.zeros((bk, bn), blocks.dtype)
    for c in range(counts.size):
        lo, hi = cp[c], cp[c + 1]
        if hi > lo:
            new_blocks.append(blocks[lo:hi])
            new_rows.append(row_ids[lo:hi])
        else:
            new_blocks.append(zero[None])
            new_rows.append(np.zeros((1,), np.int32))
        new_cp.append(new_cp[-1] + max(hi - lo, 1))
    return BCSCMatrix(jnp.asarray(np.concatenate(new_blocks)),
                      jnp.asarray(np.concatenate(new_rows).astype(np.int32)),
                      jnp.asarray(np.asarray(new_cp, np.int32)),
                      m.shape, m.block)


def bcsc_matmul_raw(x, blocks, row_ids, col_ids, *, n_out: int, bm: int,
                    out_dtype=jnp.float32, interpret: bool = False):
    """x (M,K) · BCSC(K,N) -> (M,N).

    blocks (nnzb,bk,bn); row_ids/col_ids (nnzb,) int32 with col_ids
    non-decreasing and covering every block-column at least once
    (ensure_nonempty_cols). M % bm == 0; K % bk == 0; n_out % bn == 0.
    """
    M, K = x.shape
    nnzb, bk, bn = blocks.shape
    assert M % bm == 0 and K % bk == 0 and n_out % bn == 0, (M, K, n_out)
    nm = M // bm

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(nm, nnzb),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, rows, cols: (i, rows[j])),
            pl.BlockSpec((1, bk, bn), lambda i, j, rows, cols: (j, 0, 0)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, rows, cols: (i, cols[j])),
    )
    return pl.pallas_call(
        _bcsc_kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((M, n_out), out_dtype),
        compiler_params=_epi.CompilerParams(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(row_ids, col_ids, x, blocks)


# ------------------------------------------------------------ GEMV fast path
def _bcsc_gemv_kernel(row_ids_ref, col_ids_ref, x_ref, blk_ref, *rest,
                      nnzb: int, activation, has_bias: bool):
    """Grid (nnzb,): one step per non-zero block, single m-tile (M ≤ bm).

    Decode-shaped variant (DESIGN.md §2): instead of revisit-accumulating
    through ``o_ref`` the column partials build up in a fp32 VMEM scratch tile
    (the psum-SPad analogue), and the fused bias+activation epilogue fires on
    the last block of each output-column segment as the tile drains to HBM.
    """
    if has_bias:
        bias_ref, o_ref, acc_ref = rest
    else:
        o_ref, acc_ref = rest
        bias_ref = None
    j = pl.program_id(0)
    col = col_ids_ref[j]
    first = jnp.logical_or(j == 0, col != col_ids_ref[jnp.maximum(j - 1, 0)])
    last = jnp.logical_or(j == nnzb - 1,
                          col != col_ids_ref[jnp.minimum(j + 1, nnzb - 1)])

    @pl.when(first)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(x_ref[...], blk_ref[0],
                            preferred_element_type=jnp.float32)

    @pl.when(last)
    def _flush():
        b = bias_ref[0] if has_bias else None
        o_ref[...] = fused_epilogue(acc_ref[...], b,
                                    activation).astype(o_ref.dtype)


def bcsc_gemv_raw(x, blocks, row_ids, col_ids, *, n_out: int, bm: int,
                  bias=None, activation=None, out_dtype=jnp.float32,
                  interpret: bool = False):
    """Skinny x (M,K) · BCSC(K,N) -> (M,N), M ≤ bm (padded by ops.py).

    Same index-vector contract as bcsc_matmul_raw (col_ids non-decreasing,
    every block-column covered). bias, if given, is (1, n_out). Runtime is one
    grid step per non-zero block — the batch-1 regime where weight-block
    skipping is the whole win (paper Table VI).
    """
    M, K = x.shape
    nnzb, bk, bn = blocks.shape
    assert M == bm and K % bk == 0 and n_out % bn == 0, (M, K, n_out, bm)
    has_bias = bias is not None

    in_specs = [
        pl.BlockSpec((bm, bk), lambda j, rows, cols: (0, rows[j])),
        pl.BlockSpec((1, bk, bn), lambda j, rows, cols: (j, 0, 0)),
    ]
    args = [row_ids, col_ids, x, blocks]
    if has_bias:
        in_specs.append(
            pl.BlockSpec((1, bn), lambda j, rows, cols: (0, cols[j])))
        args.append(bias)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(nnzb,),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((bm, bn), lambda j, rows, cols: (0, cols[j])),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
    )
    return pl.pallas_call(
        functools.partial(_bcsc_gemv_kernel, nnzb=nnzb,
                          activation=activation, has_bias=has_bias),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((bm, n_out), out_dtype),
        compiler_params=_epi.CompilerParams(
            dimension_semantics=("arbitrary",)),
        interpret=interpret,
    )(*args)
