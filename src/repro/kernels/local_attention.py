"""Flash-style sliding-window causal attention Pallas kernel.

The compact-DNN hot-spot: gemma2/gemma3/mixtral run most layers with a bounded
attention window, so the kernel only visits the O(S·w) diagonal band instead
of O(S²). Online-softmax running (m, l, acc) state lives in VMEM scratch (the
psum-SPad analogue); K/V tiles stream HBM→VMEM along the band.

Grid: (B, H, nq, nk_per_q) where nk_per_q covers exactly the window band for
one query tile. The K/V index map computes the *logical* (possibly negative)
band block and clamps it into range; the kernel recomputes the unclamped
position to mask out-of-band/out-of-sequence keys, so clamp-duplicated tiles
contribute nothing. GQA is handled by mapping head h to KV head h // R in the
index maps — no K/V replication in HBM.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import epilogue as _epi

NEG_INF = -2.0e38


def _band_start(iq: int, bq: int, bk: int, nk_per_q: int):
    """Logical first k-block of the band for query tile iq (may be negative)."""
    last = (iq * bq + bq - 1) // bk
    return last - (nk_per_q - 1)


def _swa_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
                bq: int, bkv: int, nk_per_q: int, window: int, seq_len: int,
                softcap: float):
    iq = pl.program_id(2)
    ik = pl.program_id(3)

    @pl.when(ik == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0, 0].astype(jnp.float32)              # (bq, D)
    k = k_ref[0, 0].astype(jnp.float32)              # (bkv, D)
    v = v_ref[0, 0].astype(jnp.float32)

    s = jnp.dot(q, k.T, preferred_element_type=jnp.float32)
    s = s * (1.0 / math.sqrt(q.shape[-1]))
    if softcap > 0.0:
        s = jnp.tanh(s / softcap) * softcap

    # Positions from the *logical* (unclamped) block index: clamp-duplicated
    # tiles get fully-masked scores.
    kblk = _band_start(iq, bq, bkv, nk_per_q) + ik
    qpos = iq * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bkv), 0)
    kpos = kblk * bkv + jax.lax.broadcasted_iota(jnp.int32, (bq, bkv), 1)
    rel = qpos - kpos
    mask = (rel >= 0) & (rel < window) & (kpos >= 0) & (kpos < seq_len)
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
    p = jnp.where(s > NEG_INF / 2, jnp.exp(s - m_new[:, None]), 0.0)
    corr = jnp.exp(m_prev - m_new)
    l_ref[...] = l_ref[...] * corr + jnp.sum(p, axis=-1)
    acc_ref[...] = acc_ref[...] * corr[:, None] + jnp.dot(
        p, v, preferred_element_type=jnp.float32)
    m_ref[...] = m_new

    @pl.when(ik == nk_per_q - 1)
    def _done():
        o_ref[0, 0] = (acc_ref[...] /
                       jnp.maximum(l_ref[...], 1e-30)[:, None]
                       ).astype(o_ref.dtype)


def sliding_window_attention_raw(q, k, v, *, window: int, bq: int = 128,
                                 bkv: int = 128, softcap: float = 0.0,
                                 out_dtype=jnp.float32,
                                 interpret: bool = False):
    """q (B,H,S,D); k,v (B,KV,S,D), H % KV == 0, S % bq == S % bkv == 0.

    Returns (B,H,S,D). Pad/transpose handled by ops.sliding_window_attention.
    """
    B, H, S, D = q.shape
    KV = k.shape[1]
    R = H // KV
    assert S % bq == 0 and S % bkv == 0, (S, bq, bkv)
    nq = S // bq
    nk_per_q = (window - 1 + bq) // bkv + 1       # covers the band + diagonal

    def kv_index(b, h, iq, ik):
        blk = _band_start(iq, bq, bkv, nk_per_q) + ik
        return (b, h // R, jnp.clip(blk, 0, S // bkv - 1), 0)

    kernel = functools.partial(
        _swa_kernel, bq=bq, bkv=bkv, nk_per_q=nk_per_q, window=window,
        seq_len=S, softcap=softcap)

    return pl.pallas_call(
        kernel,
        grid=(B, H, nq, nk_per_q),
        in_specs=[
            pl.BlockSpec((1, 1, bq, D), lambda b, h, iq, ik: (b, h, iq, 0)),
            pl.BlockSpec((1, 1, bkv, D), kv_index),
            pl.BlockSpec((1, 1, bkv, D), kv_index),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, D), lambda b, h, iq, ik: (b, h, iq, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, S, D), out_dtype),
        scratch_shapes=[
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq, D), jnp.float32),
        ],
        compiler_params=_epi.CompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel",
                                 "arbitrary")),
        interpret=interpret,
    )(q, k, v)
