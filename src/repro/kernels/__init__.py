"""Pallas TPU kernels (validated interpret=True on CPU) + jnp oracles.

The paper's compute hot-spots: the Sparse PE (block-CSC matmul, §IV), the
row-stationary dataflow (dense matmul, §II), and the compact-DNN attention
band (sliding-window flash attention).
"""
from repro.kernels.ops import (bcsc_apply_packed, bcsc_gemv, bcsc_matmul,
                               bcsc_mlp_packed,
                               flash_attention, is_packed, paged_attention,
                               prepare_bcsc, rs_matmul,
                               sliding_window_attention)

__all__ = ["bcsc_apply_packed", "bcsc_gemv", "bcsc_matmul", "bcsc_mlp_packed",
           "flash_attention", "is_packed", "paged_attention", "prepare_bcsc",
           "rs_matmul", "sliding_window_attention"]
