"""Public jit'd wrappers around the Pallas kernels.

Each wrapper: picks tile shapes (core.dataflow — the SPad/VMEM-fit constraint),
pads inputs to tile multiples, dispatches the kernel, slices the result. On
this CPU container kernels run with interpret=True (the Python interpreter of
the kernel body); on TPU the same calls compile to Mosaic. ``INTERPRET`` is
resolved once from the backend so call sites never care.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import dataflow, plan as _plan
from repro.core.sparsity import BCSCMatrix
from repro.kernels import bcsc_matmul as _bcsc
from repro.kernels import bcsc_mlp as _bmlp
from repro.kernels import epilogue as _epi
from repro.kernels import local_attention as _swa
from repro.kernels import paged_attention as _paged
from repro.kernels import rs_matmul as _rs


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _pad_to(x, m: int, axis: int):
    pad = (-x.shape[axis]) % m
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


# ------------------------------------------------------------------ rs_matmul
def rs_matmul(x, w, *, bias=None, activation: Optional[str] = None,
              out_dtype=jnp.float32, tiling=None,
              interpret: Optional[bool] = None):
    """Dense (M,K)·(K,N) via the row-stationary kernel. Any M,K,N (padded).

    bias (N,) and ``activation`` fuse into the kernel's accumulator-flush
    epilogue (kernels/epilogue.py) — no second pass over the output.
    """
    interpret = (not _on_tpu()) if interpret is None else interpret
    M, K = x.shape
    _, N = w.shape
    t = tiling or dataflow.rs_matmul_tiling(M, K, N, x.dtype.itemsize)
    assert t.fits(), t                       # the Table-III SPad-fit gate
    xp = _pad_to(_pad_to(x, t.bm, 0), t.bk, 1)
    wp = _pad_to(_pad_to(w, t.bk, 0), t.bn, 1)
    bp = None if bias is None else _pad_to(bias.reshape(1, N), t.bn, 1)
    out = _rs.rs_matmul_raw(xp, wp, bm=t.bm, bk=t.bk, bn=t.bn, bias=bp,
                            activation=activation, out_dtype=out_dtype,
                            interpret=interpret)
    return out[:M, :N]


# ---------------------------------------------------------------- bcsc_matmul
def prepare_bcsc(m: BCSCMatrix):
    """Host-side (compile-time) index-vector prep: non-empty columns + col ids.

    Returns (blocks, row_ids, col_ids, n_out) ready for bcsc_matmul.
    """
    m = _bcsc.ensure_nonempty_cols(m)
    col_ids = _bcsc.expand_col_ptr(np.asarray(m.col_ptr))
    return (m.blocks, m.row_ids, jnp.asarray(col_ids), m.shape[1])


def _bcsc_apply(x, blocks, row_ids, col_ids, *, n_out: int, bm: int,
                bias, activation, out_dtype, interpret):
    """Shared GEMV/GEMM dispatch over prepared BCSC vectors.

    The route/tile come from the active ServePlan when a serving engine has
    one activated (core.plan.route_matmul/tile_m), else from the
    core.dataflow rule — the same resolved crossover either way."""
    M = x.shape[0]
    if bm <= 0:
        bm = _plan.tile_m(M)
    xp = _pad_to(x, bm, 0)
    bp = None if bias is None else _pad_to(bias.reshape(1, n_out),
                                           blocks.shape[2], 1)
    if _plan.route_matmul(M) == "gemv" and bm == _plan.gemv_bm():
        out = _bcsc.bcsc_gemv_raw(xp, blocks.astype(x.dtype), row_ids,
                                  col_ids, n_out=n_out, bm=bm, bias=bp,
                                  activation=activation, out_dtype=out_dtype,
                                  interpret=interpret)
        return out[:M]
    out = _bcsc.bcsc_matmul_raw(xp, blocks.astype(x.dtype), row_ids, col_ids,
                                n_out=n_out, bm=bm, out_dtype=jnp.float32,
                                interpret=interpret)
    if bias is not None or activation not in (None, "none"):
        # GEMM path keeps the revisit-accumulate kernel; epilogue applies as a
        # jnp post-op through the same shared definition (numerics identical).
        out = _epi.fused_epilogue(out, bp, activation)
    return out[:M].astype(out_dtype)


def bcsc_matmul(x, m: BCSCMatrix, *, bm: int = 0, bias=None,
                activation: Optional[str] = None, out_dtype=jnp.float32,
                interpret: Optional[bool] = None):
    """Sparse (M,K)·BCSC(K,N) -> (M,N); skips zero weight blocks entirely.

    Dispatches automatically on M (core.dataflow.matmul_path): decode-shaped
    M ≤ GEMV_M_MAX takes the scratch-accumulator GEMV kernel, larger M the
    revisit-accumulate GEMM kernel. Pass ``bm`` to force a GEMM tile.
    """
    interpret = (not _on_tpu()) if interpret is None else interpret
    blocks, row_ids, col_ids, n_out = prepare_bcsc(m)
    assert x.shape[1] == m.shape[0], (x.shape, m.shape)
    return _bcsc_apply(x, blocks, row_ids, col_ids, n_out=n_out, bm=bm,
                       bias=bias, activation=activation, out_dtype=out_dtype,
                       interpret=interpret)


def bcsc_gemv(x, m: BCSCMatrix, *, bias=None,
              activation: Optional[str] = None, out_dtype=jnp.float32,
              interpret: Optional[bool] = None):
    """Decode fast path: skinny (M≤8,K)·BCSC(K,N) -> (M,N) via the GEMV kernel."""
    M = x.shape[0]
    assert M <= dataflow.GEMV_M_MAX, \
        f"bcsc_gemv is the M<={dataflow.GEMV_M_MAX} decode path, got M={M}"
    return bcsc_matmul(x, m, bias=bias, activation=activation,
                       out_dtype=out_dtype, interpret=interpret)


def is_packed(w) -> bool:
    """True if a params leaf-group is a BCSC-packed weight dict — the
    {blocks, row_ids, col_ids} contract consumed by bcsc_apply_packed
    (produced by serve.sparse.pack_weight)."""
    return isinstance(w, dict) and "blocks" in w and "col_ids" in w


def bcsc_apply_packed(x, packed, *, n_out: int, bias=None,
                      activation: Optional[str] = None,
                      out_dtype=jnp.float32,
                      interpret: Optional[bool] = None):
    """Jit-friendly entry: (M,K) · packed BCSC dict -> (M,N).

    ``packed`` is serve.sparse.pack_weight's dict of plain arrays
    {blocks (nnzb,bk,bn), row_ids (nnzb,), col_ids (nnzb,)} — traversable as a
    params pytree leaf group (stacks under lax.scan, no host-side prep at
    trace time). n_out must be static (callers derive it from the config).
    """
    interpret = (not _on_tpu()) if interpret is None else interpret
    return _bcsc_apply(x, packed["blocks"], packed["row_ids"],
                       packed["col_ids"], n_out=n_out, bm=0, bias=bias,
                       activation=activation, out_dtype=out_dtype,
                       interpret=interpret)


def packed_nnzb(packed) -> jnp.ndarray:
    """Actual (un-padded) block count of a packed weight, int32 scalar.

    Ragged-aware packs (serve.sparse ≥ PR 2) carry ``nnzb``; legacy packs
    fall back to the padded payload length (every block treated as real).
    """
    n = packed.get("nnzb")
    if n is None:
        return jnp.int32(packed["blocks"].shape[0])
    return n.astype(jnp.int32).reshape(())


def bcsc_mlp_packed(x, gate_packed, up_packed, down_packed, *, d_ff: int,
                    n_out: int, activation: Optional[str] = None,
                    counts=None, out_dtype=jnp.float32,
                    interpret: Optional[bool] = None):
    """Fused sparse MLP megakernel over packed BCSC dicts (one pallas_call).

    ``gate_packed``/``down_packed`` are serve.sparse packed dicts for the
    gate/up-projection and down-projection; ``up_packed`` is the second
    (linear) up-projection for gated MLPs, or None. The hidden activation
    stays in VMEM scratch; per-layer actual nnzb rides the prefetched
    ``counts`` vector so padded stack blocks are skipped (no DMA, no MACs).
    ``counts`` is the pack-time-prepared (3,) int32 [n_g, n_u, n_d]
    (serve.sparse stores it as ``_bcsc_counts``); assembled here when absent.
    Callers should gate on ``core.dataflow.mlp_path(...) == 'fused'``.
    """
    interpret = (not _on_tpu()) if interpret is None else interpret
    M = x.shape[0]
    bm = _plan.tile_m(M)
    xp = _pad_to(x, bm, 0)
    gated = up_packed is not None
    if counts is None:
        counts = jnp.stack([
            packed_nnzb(gate_packed),
            packed_nnzb(up_packed) if gated else jnp.int32(0),
            packed_nnzb(down_packed),
        ])
    kw = {}
    if gated:
        kw = dict(u_blocks=up_packed["blocks"].astype(x.dtype),
                  u_rows=up_packed["row_ids"], u_cols=up_packed["col_ids"])
    out = _bmlp.bcsc_mlp_raw(
        xp, gate_packed["blocks"].astype(x.dtype), gate_packed["row_ids"],
        gate_packed["col_ids"], down_packed["blocks"].astype(x.dtype),
        down_packed["row_ids"], down_packed["col_ids"], counts,
        d_ff=d_ff, n_out=n_out, bm=bm, activation=activation,
        out_dtype=out_dtype, interpret=interpret, **kw)
    return out[:M]


# ------------------------------------------------------- paged attention
def paged_attention(q, k_pool, v_pool, block_table, lengths, *,
                    k_scale=None, v_scale=None, softcap: float = 0.0,
                    interpret: Optional[bool] = None):
    """Decode attention against a paged KV pool through a block table.

    q (B,1,H,D) — the decode-step query layout of layers.decode_attention;
    k_pool/v_pool (P, page_size, KV, D); block_table (B, max_pages) int32
    (-1 = unallocated); lengths (B,) int32 valid tokens per row. Returns
    (B,1,H,D) fp32. Dispatch between this and the contiguous-ring path is
    core.dataflow.attn_path's call (occupancy rule).

    int8 pools (core.dataflow.kv_quant_path) pass their per-(page, kv-head)
    amax scales as ``k_scale``/``v_scale`` (P, KV) fp32; the kernel
    dequantizes each page inside its online-softmax loop.
    """
    interpret = (not _on_tpu()) if interpret is None else interpret
    B, _, H, D = q.shape
    KV = k_pool.shape[2]
    R = H // KV
    out = _paged.paged_attention_raw(
        q.reshape(B, KV, R, D), k_pool, v_pool, block_table, lengths,
        k_scale=k_scale, v_scale=v_scale, softcap=softcap,
        interpret=interpret)
    return out.reshape(B, 1, H, D)


# -------------------------------------------------- sliding-window attention
def sliding_window_attention(q, k, v, *, window: int, softcap: float = 0.0,
                             bq: int = 128, bkv: int = 128,
                             interpret: Optional[bool] = None):
    """q (B,S,H,D); k,v (B,S,KV,D) -> (B,S,H,D) fp32. Any S (padded)."""
    interpret = (not _on_tpu()) if interpret is None else interpret
    B, S, H, D = q.shape
    bq = min(bq, max(8, S))
    bkv = min(bkv, max(8, S))
    qt = _pad_to(jnp.moveaxis(q, 2, 1), bq, 2)       # (B,H,Sp,D)
    kt = _pad_to(jnp.moveaxis(k, 2, 1), bkv, 2)      # (B,KV,Sp,D)
    vt = _pad_to(jnp.moveaxis(v, 2, 1), bkv, 2)
    Sp = max(qt.shape[2], kt.shape[2])
    qt = _pad_to(qt, Sp, 2)
    kt = _pad_to(kt, Sp, 2)
    vt = _pad_to(vt, Sp, 2)
    out = _swa.sliding_window_attention_raw(
        qt, kt, vt, window=window, bq=bq, bkv=bkv, softcap=softcap,
        interpret=interpret)
    return jnp.moveaxis(out[:, :, :S], 1, 2)         # (B,S,H,D)


def flash_attention(q, k, v, *, softcap: float = 0.0, bq: int = 128,
                    bkv: int = 128, interpret: Optional[bool] = None):
    """Full causal attention = sliding window with window = S."""
    return sliding_window_attention(q, k, v, window=q.shape[1],
                                    softcap=softcap, bq=bq, bkv=bkv,
                                    interpret=interpret)
