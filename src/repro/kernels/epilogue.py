"""Fused bias+activation epilogue shared by the matmul kernels (DESIGN.md §3).

The paper's PE applies ReLU while psums drain from the SPad — the epilogue
rides the accumulator flush instead of costing a second pass over the output.
The TPU analogue: apply bias+activation to the fp32 VMEM accumulator tile in
the same grid step that writes ``o_ref``, so the activation never round-trips
through HBM. ``rs_matmul`` (dense GEMM), ``bcsc_gemv`` (sparse decode) and the
jnp fallback for the BCSC GEMM path all share this one definition, which keeps
the fused and unfused paths numerically aligned for the oracle tests.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental.pallas import tpu as pltpu

ACTIVATIONS = (None, "none", "relu", "silu", "gelu")

# jax renamed TPUCompilerParams -> CompilerParams across 0.4.x/0.5.x; resolve
# once here so every kernel module stays version-agnostic.
CompilerParams = getattr(pltpu, "CompilerParams",
                         getattr(pltpu, "TPUCompilerParams", None))


def fused_epilogue(acc, bias=None, activation: Optional[str] = None):
    """acc: fp32 accumulator tile. bias: broadcastable to acc or None.

    Runs entirely in fp32 (the psum precision, DESIGN.md §7); callers cast to
    the output dtype afterwards.
    """
    acc = acc.astype(jnp.float32)
    if bias is not None:
        acc = acc + bias.astype(jnp.float32)
    if activation in (None, "none"):
        return acc
    if activation == "relu":
        return jnp.maximum(acc, 0.0)
    if activation == "silu":
        return jax.nn.silu(acc)
    if activation == "gelu":
        return jax.nn.gelu(acc, approximate=True)
    raise ValueError(f"unknown activation {activation!r}; one of {ACTIVATIONS}")
