"""Row-stationary dense matmul Pallas kernel (paper §II RS dataflow → TPU).

Hardware adaptation (DESIGN.md §2): the paper's PE keeps a small weight matrix
stationary in its SPad and streams iact windows past it, accumulating into a
psum SPad. On TPU the MXU has no per-scalar SPad; the stationarity that matters
is the *psum tile* — we hold a (bm × bn) fp32 accumulator in VMEM (the psum-SPad
analogue) across the whole K reduction while (bm × bk) activation tiles and
(bk × bn) weight tiles stream HBM→VMEM. Tile shapes come from
core.dataflow.rs_matmul_tiling, which enforces the VMEM-fit constraint
(the paper's Table-III SPad-fit check) and MXU alignment (multiples of 128).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import epilogue as _epi
from repro.kernels.epilogue import fused_epilogue


def _rs_matmul_kernel(x_ref, w_ref, *rest, nk: int, activation, has_bias: bool):
    """Grid (m, n, k), k innermost: accumulate into the stationary psum tile.

    The fused bias+activation epilogue (kernels/epilogue.py) runs as the psum
    tile drains at k == nk-1 — shared with the bcsc_gemv decode kernel.
    """
    if has_bias:
        bias_ref, o_ref, acc_ref = rest
    else:
        o_ref, acc_ref = rest
        bias_ref = None
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(x_ref[...], w_ref[...],
                            preferred_element_type=jnp.float32)

    @pl.when(k == nk - 1)
    def _done():
        b = bias_ref[0] if has_bias else None
        o_ref[...] = fused_epilogue(acc_ref[...], b,
                                    activation).astype(o_ref.dtype)


def rs_matmul_raw(x, w, *, bm: int, bk: int, bn: int, bias=None,
                  activation=None, out_dtype=jnp.float32,
                  interpret: bool = False):
    """(M,K)·(K,N) -> (M,N). M % bm == K % bk == N % bn == 0 (pad in ops.py).

    bias, if given, is (1, N) and is added — with ``activation`` applied —
    inside the kernel's final k-step (no second pass over the output).
    """
    M, K = x.shape
    K2, N = w.shape
    assert K == K2, (x.shape, w.shape)
    assert M % bm == 0 and K % bk == 0 and N % bn == 0, (M, K, N, bm, bk, bn)
    nm, nn, nk = M // bm, N // bn, K // bk
    has_bias = bias is not None

    in_specs = [
        pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),
        pl.BlockSpec((bk, bn), lambda i, j, k: (k, j)),
    ]
    args = [x, w]
    if has_bias:
        in_specs.append(pl.BlockSpec((1, bn), lambda i, j, k: (0, j)))
        args.append(bias)

    return pl.pallas_call(
        functools.partial(_rs_matmul_kernel, nk=nk, activation=activation,
                          has_bias=has_bias),
        grid=(nm, nn, nk),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((M, N), out_dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        compiler_params=_epi.CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(*args)
