"""Pure-jnp oracles for every Pallas kernel (the correctness contracts).

Each function is the semantic specification its kernel is tested against
(tests/test_kernels.py sweeps shapes/dtypes and asserts allclose).
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.sparsity import BCSCMatrix, bcsc_decode

NEG_INF = -2.0e38


def matmul_ref(x, w):
    """(M,K)·(K,N) with fp32 accumulation, fp32 result."""
    return jnp.dot(x, w, preferred_element_type=jnp.float32)


def bcsc_matmul_ref(x, m: BCSCMatrix):
    """Dense-decode oracle for the block-CSC sparse matmul."""
    w = jnp.asarray(bcsc_decode(m))
    return jnp.dot(x, w.astype(x.dtype), preferred_element_type=jnp.float32)


def sliding_window_attention_ref(q, k, v, window: int, softcap: float = 0.0):
    """Exact sliding-window causal GQA attention.

    q (B,S,H,D); k,v (B,S,KV,D) with H a multiple of KV. A query at position p
    attends to keys at positions t with  0 <= p - t < window  (matches
    models.layers.local_attention's band). Returns (B,S,H,D) fp32.
    """
    B, S, H, D = q.shape
    KV = k.shape[2]
    R = H // KV
    qf = q.astype(jnp.float32).reshape(B, S, KV, R, D)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    s = jnp.einsum("bsgrd,btgd->bgrst", qf, kf) / math.sqrt(D)
    if softcap and softcap > 0.0:
        s = jnp.tanh(s / softcap) * softcap
    rel = jnp.arange(S)[:, None] - jnp.arange(S)[None, :]
    mask = (rel >= 0) & (rel < window)
    s = jnp.where(mask[None, None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    ctx = jnp.einsum("bgrst,btgd->bsgrd", p, vf)
    return ctx.reshape(B, S, H, D)


def flash_attention_ref(q, k, v, softcap: float = 0.0, causal: bool = True):
    """Exact full (causal) GQA attention — oracle for window >= S."""
    S = q.shape[1]
    window = S if causal else 2 * S
    return sliding_window_attention_ref(q, k, v, window, softcap)


def paged_attention_ref(q, k_pool, v_pool, block_table, lengths,
                        softcap: float = 0.0, k_scale=None, v_scale=None):
    """Gather-then-softmax oracle for the paged decode-attention kernel.

    q (B,KV,R,D); k_pool/v_pool (P,ps,KV,D); block_table (B,MP) int32;
    lengths (B,). Gathers each row's pages into a dense (MP·ps) history and
    runs one exact masked softmax — the semantics paged_attention_raw must
    reproduce through block-table indirection and online-softmax merging.
    ``k_scale``/``v_scale`` (P, KV) fp32 dequantize int8 pools per page
    (symmetric amax format, value = q · scale / 127).
    """
    B, KV, R, D = q.shape
    P, ps = k_pool.shape[:2]
    MP = block_table.shape[1]
    bt = jnp.clip(block_table, 0, P - 1)
    kd = k_pool[bt].reshape(B, MP * ps, KV, D).astype(jnp.float32)
    vd = v_pool[bt].reshape(B, MP * ps, KV, D).astype(jnp.float32)
    if k_scale is not None:
        ksd = jnp.repeat(k_scale[bt], ps, axis=1) * (1.0 / 127.0)
        vsd = jnp.repeat(v_scale[bt], ps, axis=1) * (1.0 / 127.0)
        kd = kd * ksd[..., None]
        vd = vd * vsd[..., None]
    s = jnp.einsum("bgrd,btgd->bgrt", q.astype(jnp.float32), kd) / math.sqrt(D)
    if softcap and softcap > 0.0:
        s = jnp.tanh(s / softcap) * softcap
    valid = jnp.arange(MP * ps)[None, :] < lengths[:, None]
    s = jnp.where(valid[:, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bgrt,btgd->bgrd", p, vd)
