"""Paged decode-attention Pallas kernel — K/V read through a block table.

The paged KV cache (serve/paging.py) is the paper's compressed-domain idea
applied to activations-over-time: instead of a dense ``(rows, cache_len, ...)``
slot sized for the worst case, each sequence owns ``ceil(len / page_size)``
fixed-size pages, and a per-row **block table** maps logical page j to a
physical page id — the CSC address-vector indirection of §IV, with pages in
the role of non-zero blocks. This kernel is the decode-attention consumer of
that layout: one query token per row attends to its whole history without the
history ever being gathered into a contiguous buffer.

Structure (same scalar-prefetch trick as the nnzb walk in bcsc_mlp.py):

* grid ``(B, max_pages)`` — rows parallel, pages sequential per row;
* the flattened block table and per-row lengths ride the scalar prefetch, so
  the K/V index maps pick the *physical* page ``bt[b, j]`` for logical page j
  (clamped into range — unallocated entries are skipped, no new DMA);
* online-softmax running ``(m, l, acc)`` state lives in fp32 VMEM scratch
  (the psum-SPad analogue, identical to local_attention.py) and merges page
  partials in any physical order;
* pages past a row's occupancy ``ceil(len/ps)`` are skipped with ``pl.when``
  — per row the kernel does real work on exactly ``pages_for(len)`` grid
  steps, the proxy scripts/perf_guard.py gates.

GQA is native: q carries (KV, R, D) per row, K/V pages carry (ps, KV, D);
scores reduce per kv-head. ``core.dataflow.attn_path`` decides when decode
dispatches here vs. the contiguous-ring path (models/decoding._attn_decode).
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core import dataflow
from repro.kernels import epilogue as _epi

NEG_INF = -2.0e38


def row_work_steps(length, page_size: int):
    """The kernel's skip bound for one row: pages with real work (DMA+MACs).

    This is the SAME expression the kernel body evaluates for its
    ``pl.when(j < n_pages)`` guard (int or traced scalar) — the single
    source of truth, so a kernel-side change to the skip logic moves the
    cost proxy with it.
    """
    return (length + page_size - 1) // page_size


def work_steps(lengths, page_size: int) -> int:
    """Grid steps doing real work over a batch: Σ row_work_steps over rows.

    The wall-clock-free cost proxy benchmarks/sparse_decode.py records and
    scripts/perf_guard.py gates against the *independently* computed
    ``dataflow.pages_for`` bound (work ≤ ceil(len/ps) per row) and the
    padded (rows × max_pages) grid (strictly fewer steps on ragged rows).
    """
    return sum(int(row_work_steps(int(n), page_size)) for n in lengths)


def _paged_kernel(bt_ref, len_ref, q_ref, k_ref, v_ref, *rest,
                  page_size: int, max_pages: int, softcap: float,
                  quantized: bool):
    if quantized:
        ks_ref, vs_ref, o_ref, m_ref, l_ref, acc_ref = rest
    else:
        o_ref, m_ref, l_ref, acc_ref = rest
    b = pl.program_id(0)
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    length = len_ref[b]
    n_pages = row_work_steps(length, page_size)

    @pl.when(j < n_pages)
    def _page():
        q = q_ref[0].astype(jnp.float32)                 # (KV, R, D)
        k = k_ref[0].astype(jnp.float32)                 # (ps, KV, D)
        v = v_ref[0].astype(jnp.float32)
        if quantized:
            # per-page dequant inside the online-softmax loop: the page's
            # (KV,) amax scales ride the same block-table index map as the
            # payload, so int8 pages never round-trip through a dense fp
            # buffer — the compressed-domain contract of the BCSC kernels
            # applied to KV-over-time
            k = k * (ks_ref[0] * (1.0 / 127.0))[None, :, None]
            v = v * (vs_ref[0] * (1.0 / 127.0))[None, :, None]
        s = jnp.einsum("grd,tgd->grt", q, k,
                       preferred_element_type=jnp.float32)
        s = s * (1.0 / math.sqrt(q.shape[-1]))
        if softcap > 0.0:
            s = jnp.tanh(s / softcap) * softcap
        # logical token positions of this page; the tail page masks past len
        tpos = j * page_size + jax.lax.broadcasted_iota(
            jnp.int32, (1, 1, page_size), 2)
        s = jnp.where(tpos < length, s, NEG_INF)

        m_prev = m_ref[...]                              # (KV, R)
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
        p = jnp.where(s > NEG_INF / 2, jnp.exp(s - m_new[..., None]), 0.0)
        corr = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * corr + jnp.sum(p, axis=-1)
        acc_ref[...] = acc_ref[...] * corr[..., None] + jnp.einsum(
            "grt,tgd->grd", p, v, preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when(j == max_pages - 1)
    def _done():
        o_ref[0] = (acc_ref[...] /
                    jnp.maximum(l_ref[...], 1e-30)[..., None]
                    ).astype(o_ref.dtype)


def paged_attention_raw(q, k_pool, v_pool, block_table, lengths, *,
                        k_scale=None, v_scale=None, softcap: float = 0.0,
                        out_dtype=jnp.float32, interpret: bool = False):
    """q (B,KV,R,D); k_pool/v_pool (P,ps,KV,D); block_table (B,MP) int32
    (physical page id, or -1 for unallocated); lengths (B,) int32 ≥ 1.

    Returns (B,KV,R,D) ``out_dtype``. Tokens of row b live at pool position
    (block_table[b, t // ps], t % ps) for t < lengths[b]; the kernel never
    reads past a row's occupancy, so unallocated table entries only need to
    be out of the ``pages_for(length)`` prefix.

    ``k_scale``/``v_scale`` (P, KV) fp32 switch on the int8 page format:
    pools hold symmetric int8 payloads and each page is dequantized by its
    own per-kv-head amax scale inside the page loop (scales are fetched
    through the same block-table index map as the payload).
    """
    B, KV, R, D = q.shape
    P, ps, KVp, Dp = k_pool.shape
    MP = block_table.shape[1]
    assert (KV, D) == (KVp, Dp), (q.shape, k_pool.shape)
    assert block_table.shape == (B, MP) and lengths.shape == (B,)
    quantized = k_scale is not None
    assert quantized == (v_scale is not None), "need both or neither scale"
    if quantized:
        assert k_scale.shape == (P, KV) and v_scale.shape == (P, KV), \
            (k_scale.shape, v_scale.shape, (P, KV))

    def kv_map(b, j, bt, lens):
        # physical page through the prefetched block table; clamp keeps the
        # DMA in range on skipped (unallocated / past-occupancy) steps
        return (jnp.clip(bt[b * MP + j], 0, P - 1), 0, 0, 0)

    def scale_map(b, j, bt, lens):
        return (jnp.clip(bt[b * MP + j], 0, P - 1), 0)

    kernel = functools.partial(_paged_kernel, page_size=ps, max_pages=MP,
                               softcap=softcap, quantized=quantized)
    in_specs = [
        pl.BlockSpec((1, KV, R, D), lambda b, j, *s: (b, 0, 0, 0)),
        pl.BlockSpec((1, ps, KV, D), kv_map),
        pl.BlockSpec((1, ps, KV, D), kv_map),
    ]
    operands = [q, k_pool, v_pool]
    if quantized:
        in_specs += [pl.BlockSpec((1, KV), scale_map),
                     pl.BlockSpec((1, KV), scale_map)]
        operands += [k_scale, v_scale]
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(B, MP),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1, KV, R, D), lambda b, j, *s: (b, 0, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((KV, R), jnp.float32),
            pltpu.VMEM((KV, R), jnp.float32),
            pltpu.VMEM((KV, R, D), jnp.float32),
        ],
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, KV, R, D), out_dtype),
        compiler_params=_epi.CompilerParams(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(block_table.reshape(-1).astype(jnp.int32),
      lengths.astype(jnp.int32), *operands)
