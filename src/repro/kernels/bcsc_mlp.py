"""Fused BCSC MLP megakernel — the whole sparse MLP in one ``pallas_call``.

Why one kernel (paper §III/§IV, FlexNN 2403.09026, S2TA 2107.07983): compressed
-domain wins evaporate if the operator chain round-trips intermediates through
the memory hierarchy. The two-call path (PR 1) runs up-projection and
down-projection as separate GEMV kernels with the (bm × d_ff) hidden activation
materialized in HBM between them — at decode shapes that round-trip plus the
extra kernel dispatches cost more than the zero-block skipping saves
(DESIGN.md §9). This kernel is the hierarchical-mesh answer: the hidden
activation lives in a VMEM scratch accumulator (the PE-cluster SPad analogue)
from the first up-projection MAC to the last down-projection drain and is
never written to HBM.

Layout: one sequential grid walks the concatenated BCSC payloads of all
projections — ``[wg | (wu) | wd]`` — in **chunks of C contiguous payload
blocks** per grid step. A chunk is processed as three small batched
contractions instead of C scalar-indexed block ops:

  row1h (C, nK)   one-hot of the chunk's block-row ids   ⎫ the paper's addr-
  col1h (C, nF)   one-hot of the chunk's block-col ids   ⎭ vector decode
  xg    = row1h · x-blocks          gather the C activation slices
  part  = xg ⊗ payload              C block MACs as ONE batched matmul
  dst  += col1h · part              scatter-add into the hidden scratch

This keeps the MXU fed with one (C·bk × bn)-scale contraction per step (the
one-hot decode costs C·nK MACs ≪ the C·bk·bn block MACs) and — on the CPU
interpret backend — collapses ~4·C per-block XLA ops into ~7 per chunk, which
is what lets the fused path beat the dense einsum chain at decode shapes.

Ragged skip: segment capacities PG/PU/PD are static (the padded stack shape)
but *occupancy* is dynamic — the actual per-layer block counts arrive as a
scalar-prefetched ``counts`` vector, so under ``lax.scan`` over stacked layers
each layer executes only its own non-zero chunks. A chunk wholly past its
segment's count is skipped with ``pl.when`` and its block-stream index map
clamps to the last real chunk (no new DMA, no MACs); pad blocks *inside* a
partial chunk are masked out of ``row1h`` (and carry zero payload anyway —
serve.sparse.pad_packed), so the skip granularity is one chunk.

Phase walk (col-major BCSC ⇒ each up block finishes one bn-slice of hidden):

  j ∈ [0, NG)        h_g += scatter(x · wg-chunk)
  j ∈ [NG, NG+NU)    h_u += scatter(x · wu-chunk)              (gated only)
  j == NG+NU         h_g = act(h_g) [* h_u]           — fused activation/gate
  j ∈ [NG+NU, +ND)   o_acc += scatter(h_g · wd-chunk)
  j == last          o_ref = o_acc                     — single drain to HBM

The activation row x rides along fully VMEM-resident (decode-shaped bm × K is
KBs), so chunks with mixed block-rows need no per-block x DMA. Empty block-
columns need no explicit zero blocks here (scratch is zero-initialized), but
the packed format keeps ``ensure_nonempty_cols`` coverage so the same arrays
still feed the two-call kernels for shapes where the fused scratch would not
fit VMEM (core.dataflow.mlp_path decides).

TPU caveats (interpret=True on this container): the id vectors are read from
the scalar-prefetch (SMEM) refs with a dynamic slice — on real TPU they could
ride a VMEM stream blocked like the payload instead; and bn=16 sub-lane
one-hot scatters want lane-width alignment for peak Mosaic lowering. The
VMEM-fit gate in core.dataflow keeps the bm·d_ff scratch within budget.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core import dataflow
from repro.kernels import epilogue as _epi
from repro.kernels.epilogue import fused_epilogue


# Total chunk count at/below which the single-grid-step (fully unrolled)
# variant is used: the whole payload rides VMEM-resident and the phase walk
# compiles to one straight-line dependency chain (no sequential grid).
UNROLL_CHUNKS_MAX = 8


def _pick_chunk(P: int) -> int:
    """Largest supported chunk dividing the padded capacity P (static).

    Packs are padded to multiples of dataflow.BCSC_CHUNK (8); the stream
    chunk doubles that when it divides, trading skip granularity for fewer
    grid steps (one chunk = one DMA + one batched contraction).
    """
    for c in (2 * dataflow.BCSC_CHUNK, dataflow.BCSC_CHUNK):
        if P % c == 0:
            return c
    return 1


def _chunk_accum(rows_ref, cols_ref, blk_ref, src, dst_ref, base, count,
                 C: int, bk: int, bn: int, n_src: int, n_dst: int):
    """One chunk of C payload blocks: gather → batched MAC → scatter-add."""
    dst_ref[...] += _chunk_part(rows_ref, cols_ref, blk_ref[...], src, base,
                                count, C, bk, bn, n_src, n_dst)


def _chunk_part(rows_ref, cols_ref, blk, src, base, count,
                C: int, bk: int, bn: int, n_src: int, n_dst: int):
    """One chunk's contribution as a (bm, n_dst·bn) value (pure).

    ``blk`` is the chunk's (C, bk, bn) payload value; ids are read from the
    scalar-prefetch refs at ``base``. Pad blocks (≥ count) are masked out of
    the row one-hot, so their contribution is exactly zero.
    """
    rows = rows_ref[pl.ds(base, C)]
    cols = cols_ref[pl.ds(base, C)]
    valid = (base + jnp.arange(C, dtype=jnp.int32)) < count
    row1h = jnp.where(valid[:, None],
                      rows[:, None] == jnp.arange(n_src)[None, :],
                      False).astype(src.dtype)                    # (C, nK)
    bm = src.shape[0]
    xg = jnp.einsum("cs,msb->cmb", row1h,
                    src.reshape(bm, n_src, bk))                   # gather
    part = jnp.einsum("cmb,cbn->cmn", xg, blk.astype(src.dtype),
                      preferred_element_type=jnp.float32)         # C MACs
    col1h = (cols[:, None] == jnp.arange(n_dst)[None, :]).astype(jnp.float32)
    return jnp.einsum("cd,cmn->mdn", col1h, part,
                      preferred_element_type=jnp.float32
                      ).reshape(bm, n_dst * bn)                   # scatter


def _mlp_kernel_unrolled(counts_ref, g_rows_ref, g_cols_ref, u_rows_ref,
                         u_cols_ref, d_rows_ref, d_cols_ref, x_ref, g_blk_ref,
                         u_blk_ref, d_blk_ref, o_ref, *, NG: int, NU: int,
                         ND: int, CG: int, CU: int, CD: int, bk: int, bn: int,
                         d_ff: int, n_out: int, activation, gated: bool,
                         hidden_dtype):
    """Single-grid-step variant for decode-scale payloads (few chunks total).

    The whole phase walk is straight-line code — no sequential grid, no
    scratch refs, the hidden lives in registers/VREGs — so the interpret
    backend (and XLA generally) fuses it into one dependency chain instead of
    a while loop. Ragged skip degrades gracefully: pad blocks are masked out
    of the one-hots (zero contribution); at these payload sizes the stream
    waste is < one chunk per segment. Large payloads take _mlp_kernel, where
    whole chunks are skipped with no DMA at all.
    """
    x = x_ref[...]
    K = x.shape[1]
    n_g, n_u, n_d = counts_ref[0], counts_ref[1], counts_ref[2]

    def phase(rows_ref, cols_ref, blk_ref, src, count, N, C, n_src, n_dst):
        acc = jnp.zeros((src.shape[0], n_dst * bn), jnp.float32)
        for c in range(N):
            acc += _chunk_part(rows_ref, cols_ref,
                               blk_ref[pl.ds(c * C, C)], src, c * C, count,
                               C, bk, bn, n_src, n_dst)
        return acc

    h = phase(g_rows_ref, g_cols_ref, g_blk_ref, x, n_g, NG, CG,
              K // bk, d_ff // bn)
    h = fused_epilogue(h, None, activation)
    if gated:
        h = h * phase(u_rows_ref, u_cols_ref, u_blk_ref, x, n_u, NU, CU,
                      K // bk, d_ff // bn)
    h = h.astype(hidden_dtype).astype(jnp.float32)   # match two-call rounding
    out = phase(d_rows_ref, d_cols_ref, d_blk_ref, h, n_d, ND, CD,
                d_ff // bk, n_out // bn)
    o_ref[...] = out.astype(o_ref.dtype)


def _mlp_kernel(counts_ref, g_rows_ref, g_cols_ref, u_rows_ref, u_cols_ref,
                d_rows_ref, d_cols_ref, x_ref, g_blk_ref, u_blk_ref, d_blk_ref,
                o_ref, h_ref, u_hid_ref, o_acc_ref, *, NG: int, NU: int,
                ND: int, CG: int, CU: int, CD: int, bk: int, bn: int,
                d_ff: int, n_out: int, activation, gated: bool, hidden_dtype):
    """Grid (m_tiles, NG+NU+ND) chunk steps. ``u_*`` refs None when ungated."""
    j = pl.program_id(1)
    n_g = counts_ref[0]
    n_u = counts_ref[1]
    n_d = counts_ref[2]
    K = x_ref.shape[1]

    @pl.when(j == 0)
    def _init():
        h_ref[...] = jnp.zeros_like(h_ref)
        o_acc_ref[...] = jnp.zeros_like(o_acc_ref)
        if gated:
            u_hid_ref[...] = jnp.zeros_like(u_hid_ref)

    @pl.when(jnp.logical_and(j < NG, j * CG < n_g))
    def _up_gate():
        _chunk_accum(g_rows_ref, g_cols_ref, g_blk_ref, x_ref[...], h_ref,
                     jnp.minimum(j, NG - 1) * CG, n_g, CG, bk, bn,
                     K // bk, d_ff // bn)

    if gated:
        @pl.when(jnp.logical_and(jnp.logical_and(j >= NG, j < NG + NU),
                                 (j - NG) * CU < n_u))
        def _up_lin():
            _chunk_accum(u_rows_ref, u_cols_ref, u_blk_ref, x_ref[...],
                         u_hid_ref, jnp.clip(j - NG, 0, NU - 1) * CU, n_u,
                         CU, bk, bn, K // bk, d_ff // bn)

    @pl.when(j == NG + NU)
    def _activate():
        h = fused_epilogue(h_ref[...], None, activation)
        if gated:
            h = h * u_hid_ref[...]
        # round to the streaming compute dtype (bf16 in serving) so the fused
        # hidden matches the dense/two-call paths bit-for-bit at the rounding
        # step; scratch storage stays fp32 (the psum SPad precision)
        h_ref[...] = h.astype(hidden_dtype).astype(jnp.float32)

    @pl.when(jnp.logical_and(j >= NG + NU, (j - (NG + NU)) * CD < n_d))
    def _down():
        _chunk_accum(d_rows_ref, d_cols_ref, d_blk_ref, h_ref[...], o_acc_ref,
                     jnp.clip(j - (NG + NU), 0, ND - 1) * CD, n_d,
                     CD, bk, bn, d_ff // bk, n_out // bn)

    @pl.when(j == NG + NU + ND - 1)
    def _drain():
        o_ref[...] = o_acc_ref[...].astype(o_ref.dtype)


def bcsc_mlp_raw(x, g_blocks, g_rows, g_cols, d_blocks, d_rows, d_cols,
                 counts, *, u_blocks=None, u_rows=None, u_cols=None,
                 d_ff: int, n_out: int, bm: int, activation=None,
                 out_dtype=jnp.float32, interpret: bool = False):
    """Fused sparse MLP: ``act(x·Wg) [* (x·Wu)] · Wd`` in one kernel.

    x (M,K) with M % bm == 0; *_blocks (P?,bk,bn) BCSC payloads (padded
    capacity P?, actual occupancy ``counts`` = int32 (3,) [n_g, n_u, n_d]);
    *_rows/*_cols (P?,) int32 with pad entries repeating the last real entry
    (serve.sparse.pad_packed) so pad blocks are numeric no-ops and clamped
    index maps stay DMA-idempotent. d_ff % bn == 0 (hidden width),
    n_out % bn == 0. Returns (M, n_out).

    The hidden activation exists only as VMEM scratch — the out_shape is the
    (M, n_out) result alone, which tests assert (no HBM aliasing).
    """
    M, K = x.shape
    PG, bk, bn = g_blocks.shape
    PD = d_blocks.shape[0]
    gated = u_blocks is not None
    PU = u_blocks.shape[0] if gated else 0
    assert M % bm == 0 and K % bk == 0, (M, K, bm, bk)
    assert d_ff % bn == 0 and d_ff % bk == 0 and n_out % bn == 0, (
        d_ff, n_out, bk, bn)
    nm = M // bm
    CG, CU, CD = _pick_chunk(PG), _pick_chunk(max(PU, 1)), _pick_chunk(PD)
    NG, NU, ND = PG // CG, (PU // CU if gated else 0), PD // CD
    # decode-scale payloads (few chunks) take the straight-line single-step
    # variant: whole payloads VMEM-resident, no sequential grid
    unrolled = (NG + NU + ND) <= UNROLL_CHUNKS_MAX

    def _blk_map(offset, N, C, count_idx):
        """Chunk index map: clamp to the segment's last *real* chunk so steps
        past the occupancy re-point at resident data (no DMA)."""
        def index_map(i, j, cnt, *scalars):
            last = jnp.maximum((cnt[count_idx] - 1) // C, 0)
            return (jnp.clip(j - offset, 0, jnp.minimum(last, N - 1)), 0, 0)
        return index_map

    in_specs = [
        # activation row: fully VMEM-resident per m-tile (decode bm·K is KBs)
        pl.BlockSpec((bm, K), lambda i, *s: (i, 0)),
        pl.BlockSpec((PG, bk, bn) if unrolled else (CG, bk, bn),
                     (lambda i, *s: (0, 0, 0)) if unrolled
                     else _blk_map(0, NG, CG, 0)),
    ]
    args = [g_rows, g_cols]
    tensor_args = [x, g_blocks]
    if gated:
        in_specs.append(
            pl.BlockSpec((PU, bk, bn) if unrolled else (CU, bk, bn),
                         (lambda i, *s: (0, 0, 0)) if unrolled
                         else _blk_map(NG, NU, CU, 1)))
        args += [u_rows, u_cols]
        tensor_args.append(u_blocks)
    else:
        # dummy u operands keep the kernel arity static; pinned to block 0,
        # never read (scalar (1,) vectors, one zero payload block)
        in_specs.append(pl.BlockSpec((1, bk, bn), lambda i, *s: (0, 0, 0)))
        args += [jnp.zeros((1,), jnp.int32), jnp.zeros((1,), jnp.int32)]
        tensor_args.append(jnp.zeros((1, bk, bn), x.dtype))
    in_specs.append(
        pl.BlockSpec((PD, bk, bn) if unrolled else (CD, bk, bn),
                     (lambda i, *s: (0, 0, 0)) if unrolled
                     else _blk_map(NG + NU, ND, CD, 2)))
    args += [d_rows, d_cols]
    tensor_args.append(d_blocks)

    common = dict(NG=NG, NU=NU, ND=ND, CG=CG, CU=CU, CD=CD, bk=bk, bn=bn,
                  d_ff=d_ff, n_out=n_out, activation=activation, gated=gated,
                  hidden_dtype=x.dtype)
    if unrolled:
        grid = (nm,)
        semantics = ("parallel",)
        scratch = []
        kernel = functools.partial(_mlp_kernel_unrolled, **common)
    else:
        grid = (nm, NG + NU + ND)
        semantics = ("parallel", "arbitrary")
        scratch = [pltpu.VMEM((bm, d_ff), jnp.float32)]
        if gated:
            scratch.append(pltpu.VMEM((bm, d_ff), jnp.float32))
        scratch.append(pltpu.VMEM((bm, n_out), jnp.float32))
        if gated:
            kernel = functools.partial(_mlp_kernel, **common)
        else:
            def kernel(counts_ref, gr, gc, ur, uc, dr, dc, x_ref, g_blk,
                       u_blk, d_blk, o_ref, h_ref, o_acc_ref):
                return _mlp_kernel(counts_ref, gr, gc, ur, uc, dr, dc, x_ref,
                                   g_blk, u_blk, d_blk, o_ref, h_ref, None,
                                   o_acc_ref, **common)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=7,
        grid=grid,
        in_specs=in_specs,
        out_specs=pl.BlockSpec((bm, n_out), lambda i, *s: (i, 0)),
        scratch_shapes=scratch,
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((M, n_out), out_dtype),
        compiler_params=_epi.CompilerParams(
            dimension_semantics=semantics),
        interpret=interpret,
    )(counts, *args, *tensor_args)
