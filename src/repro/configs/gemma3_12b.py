"""gemma3-12b [dense] — 5:1 local:global attention, 128k, qk-norm, dual RoPE theta.

48L d_model=3840 16H (GQA kv=8, head_dim=256) d_ff=15360 vocab=262144
[hf:google/gemma-3-1b-pt family; unverified]
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="gemma3-12b",
    family="dense",
    num_layers=48,
    d_model=3840,
    num_heads=16,
    num_kv_heads=8,
    head_dim=256,
    d_ff=15360,
    vocab_size=262_144,
    attn_pattern=("local", "local", "local", "local", "local", "global"),
    window_size=1024,
    qk_norm=True,
    rope_theta=1_000_000.0,
    local_rope_theta=10_000.0,
    mlp_act="gelu",
    mlp_gated=True,
    tie_embeddings=True,
    embed_scale=True,
    use_post_norm=True,
    max_seq_len=131_072,
)
