"""Architecture configuration schema.

Every assigned architecture is described by an :class:`ArchConfig`. The config is
purely declarative — `repro.models.transformer` assembles the actual network from
it, and `repro.core.planner` reads the same fields to derive per-layer data-reuse
(the paper's Table I dimensions) and pick HM-mesh sharding modes.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Tuple

# Layer kinds usable in ``attn_pattern`` (the repeating period of block types).
LAYER_KINDS = ("global", "local", "chunked", "ssm", "rglru")


def pad_to_multiple(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    """Declarative model description (one per assigned architecture)."""

    name: str
    family: str                       # dense | ssm | hybrid | vlm | audio | moe
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    head_dim: int
    d_ff: int
    vocab_size: int

    # --- attention structure -------------------------------------------------
    attn_pattern: Tuple[str, ...] = ("global",)
    window_size: int = 0              # sliding-window size for "local" layers
    chunk_size: int = 0               # chunk width for "chunked" layers (llama4)
    attn_logit_softcap: float = 0.0   # gemma2-style tanh soft capping
    final_logit_softcap: float = 0.0
    qk_norm: bool = False
    qkv_bias: bool = False
    rope_theta: float = 10_000.0
    local_rope_theta: float = 0.0     # if >0, local layers use this theta (gemma3)
    pos_embed: str = "rope"           # rope | sinusoidal

    # --- MLP ------------------------------------------------------------------
    mlp_act: str = "silu"             # silu | gelu
    mlp_gated: bool = True            # GeGLU/SwiGLU (2 up mats) vs plain 2-layer

    # --- MoE -------------------------------------------------------------------
    moe: bool = False
    num_experts: int = 0
    experts_per_token: int = 0
    moe_every: int = 1                # MoE on layers where (idx % moe_every)==moe_every-1
    shared_expert: bool = False
    dense_d_ff: int = 0               # d_ff of the non-MoE layers when interleaved
    capacity_factor: float = 1.25

    # --- SSM (mamba2) ------------------------------------------------------------
    ssm_state: int = 0
    ssm_headdim: int = 64
    ssm_expand: int = 2
    ssm_conv_kernel: int = 4
    ssm_ngroups: int = 1
    ssm_chunk: int = 256

    # --- RG-LRU (recurrentgemma) ---------------------------------------------
    lru_width: int = 0

    # --- embeddings / head -----------------------------------------------------
    tie_embeddings: bool = True
    embed_scale: bool = False         # multiply embeddings by sqrt(d_model) (gemma)
    norm_eps: float = 1e-6
    use_post_norm: bool = False       # gemma2/3 sandwich norms

    # --- modality frontends (stubs per spec) ----------------------------------
    frontend: str = "none"            # none | vision | audio
    num_patches: int = 0              # vision tokens prepended to the sequence
    num_codebooks: int = 1            # musicgen EnCodec codebooks
    cross_attn_cond: int = 0          # length of stubbed conditioning sequence

    max_seq_len: int = 131_072

    # ---------------------------------------------------------------------------
    @property
    def vocab_padded(self) -> int:
        """Vocab padded so TP over a 16/32-way axis always divides (DESIGN §7)."""
        return pad_to_multiple(self.vocab_size, 256)

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_nheads(self) -> int:
        return self.d_inner // self.ssm_headdim if self.ssm_state else 0

    @property
    def pattern_period(self) -> int:
        return len(self.attn_pattern)

    @property
    def num_periods(self) -> int:
        return self.num_layers // self.pattern_period

    @property
    def remainder_layers(self) -> int:
        return self.num_layers % self.pattern_period

    def layer_kind(self, idx: int) -> str:
        return self.attn_pattern[idx % self.pattern_period]

    def is_moe_layer(self, idx: int) -> bool:
        return self.moe and (idx % self.moe_every == self.moe_every - 1)

    def validate(self) -> None:
        assert self.num_heads % max(self.num_kv_heads, 1) == 0, self.name
        for k in self.attn_pattern:
            assert k in LAYER_KINDS, (self.name, k)
        if "local" in self.attn_pattern:
            assert self.window_size > 0, self.name
        if "chunked" in self.attn_pattern:
            assert self.chunk_size > 0, self.name
        if "ssm" in self.attn_pattern:
            assert self.ssm_state > 0 and self.d_inner % self.ssm_headdim == 0
        if "rglru" in self.attn_pattern:
            assert self.lru_width > 0, self.name
        if self.moe:
            assert self.num_experts > 0 and self.experts_per_token > 0

    # --- parameter accounting (used for MODEL_FLOPS = 6·N·D) -------------------
    def param_count(self, active_only: bool = False) -> int:
        """Analytic parameter count (embedding included once if tied)."""
        d, hd = self.d_model, self.head_dim
        n_q, n_kv = self.num_heads, self.num_kv_heads
        total = 0
        # embeddings
        embed = self.vocab_padded * d * self.num_codebooks
        total += embed
        if not self.tie_embeddings:
            total += self.vocab_padded * d * self.num_codebooks
        for i in range(self.num_layers):
            kind = self.layer_kind(i)
            total += d  # pre-norm scale
            if self.use_post_norm:
                total += d
            if kind in ("global", "local", "chunked"):
                attn = d * n_q * hd + 2 * d * n_kv * hd + n_q * hd * d
                if self.qkv_bias:
                    attn += (n_q + 2 * n_kv) * hd
                total += attn
                if self.cross_attn_cond:
                    total += attn + d
            elif kind == "ssm":
                di, g, n, hs = self.d_inner, self.ssm_ngroups, self.ssm_state, self.ssm_nheads
                total += d * (2 * di + 2 * g * n + hs)      # in_proj
                total += (di + 2 * g * n) * self.ssm_conv_kernel  # conv1d
                total += hs * 3                                # A_log, D, dt_bias
                total += di * d                                # out_proj
            elif kind == "rglru":
                w = self.lru_width
                total += 2 * d * w + w * self.ssm_conv_kernel  # two branches + conv
                # RG-LRU input & recurrence gates: block-diagonal, ≈ 2·w·(w/8)
                total += 2 * w * max(w // 8, 1)
                total += w + w * d                             # Lambda + out_proj
            # MLP / MoE
            if kind in ("global", "local", "chunked", "rglru"):
                if self.is_moe_layer(i):
                    nmats = 3 if self.mlp_gated else 2
                    e_params = nmats * d * self.d_ff
                    if active_only:
                        total += self.experts_per_token * e_params
                    else:
                        total += self.num_experts * e_params
                    if self.shared_expert:
                        total += e_params
                    total += d * self.num_experts              # router
                else:
                    ff = self.dense_d_ff or self.d_ff
                    nmats = 3 if self.mlp_gated else 2
                    total += nmats * d * ff
            total += d  # mlp pre-norm
            if self.use_post_norm:
                total += d
        total += d  # final norm
        return total

    # --- reduced config for CPU smoke tests -----------------------------------
    def reduced(self) -> "ArchConfig":
        """Tiny same-family config: few layers (>= one full pattern period),
        narrow widths, tiny vocab — runs a real fwd/train step on CPU."""
        period = self.pattern_period
        n_layers = period * 2 + (1 if self.remainder_layers else 0)
        kv = min(self.num_kv_heads, 2)
        heads = max(kv * 2, 2)
        repl = {
            "name": self.name + "-reduced",
            "num_layers": n_layers,
            "d_model": 64,
            "num_heads": heads,
            "num_kv_heads": kv,
            "head_dim": 16,
            "d_ff": 128,
            "dense_d_ff": 128 if self.dense_d_ff else 0,
            "vocab_size": 503,          # deliberately not a multiple of 256
            "window_size": 32 if self.window_size else 0,
            "chunk_size": 32 if self.chunk_size else 0,
            "num_experts": min(self.num_experts, 4) if self.moe else 0,
            "experts_per_token": min(self.experts_per_token, 2) if self.moe else 0,
            "ssm_state": 16 if self.ssm_state else 0,
            "ssm_headdim": 16 if self.ssm_state else 64,
            "ssm_expand": 2,
            "ssm_chunk": 16,
            "lru_width": 64 if self.lru_width else 0,
            "num_patches": 8 if self.num_patches else 0,
            "cross_attn_cond": 8 if self.cross_attn_cond else 0,
            "max_seq_len": 512,
        }
        return dataclasses.replace(self, **repl)


def train_flops_per_token(cfg: ArchConfig) -> int:
    """MODEL_FLOPS/token = 6·N_active (dense fwd+bwd approximation)."""
    return 6 * cfg.param_count(active_only=True)
