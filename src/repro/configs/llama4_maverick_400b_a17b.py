"""llama4-maverick-400b-a17b [moe] — 128 experts top-1 + shared expert,
interleaved MoE (every 2nd layer), chunked attention (iRoPE: 3 chunked + 1 full).

48L d_model=5120 40H (GQA kv=8, head_dim=128) d_ff=8192(expert) vocab=202048
[hf:meta-llama/Llama-4-* family; unverified]

Parameter accounting: 24 MoE layers × (128 routed + 1 shared) experts of 3×5120×8192
≈ 390B routed + dense/attn ≈ 400B total, ~17B active (top-1 + shared) — matches the
assigned 400b-a17b.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="llama4-maverick-400b-a17b",
    family="moe",
    num_layers=48,
    d_model=5120,
    num_heads=40,
    num_kv_heads=8,
    head_dim=128,
    d_ff=8192,
    vocab_size=202_048,
    attn_pattern=("chunked", "chunked", "chunked", "global"),
    chunk_size=8192,
    rope_theta=500_000.0,
    mlp_act="silu",
    mlp_gated=True,
    moe=True,
    num_experts=128,
    experts_per_token=1,
    moe_every=2,
    shared_expert=True,
    dense_d_ff=16384,
    capacity_factor=1.25,
    tie_embeddings=False,
    max_seq_len=1_048_576,
)
