"""mamba2-130m [ssm] — attention-free, SSD (state-space duality).

24L d_model=768 d_ff=0 vocab=50280, ssm_state=128 [arXiv:2405.21060; unverified]
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="mamba2-130m",
    family="ssm",
    num_layers=24,
    d_model=768,
    num_heads=0,
    num_kv_heads=0,
    head_dim=0,
    d_ff=0,
    vocab_size=50_280,
    attn_pattern=("ssm",),
    ssm_state=128,
    ssm_headdim=64,
    ssm_expand=2,
    ssm_conv_kernel=4,
    ssm_ngroups=1,
    ssm_chunk=256,
    mlp_gated=False,
    tie_embeddings=True,
    norm_eps=1e-5,
    max_seq_len=1_048_576,
)
