"""recurrentgemma-2b [hybrid] — RG-LRU + local attention, 1:2 attn:recurrent.

26L d_model=2560 10H (MQA kv=1, head_dim=256) d_ff=7680 vocab=256000
[arXiv:2402.19427 (Griffin); hf]
Pattern period 3 (rglru, rglru, local); 26 = 8 periods + 2 remainder layers.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="recurrentgemma-2b",
    family="hybrid",
    num_layers=26,
    d_model=2560,
    num_heads=10,
    num_kv_heads=1,
    head_dim=256,
    d_ff=7680,
    vocab_size=256_000,
    attn_pattern=("rglru", "rglru", "local"),
    window_size=2048,
    lru_width=2560,
    rope_theta=10_000.0,
    mlp_act="gelu",
    mlp_gated=True,
    tie_embeddings=True,
    embed_scale=True,
    max_seq_len=1_048_576,
)
