"""Assigned input-shape sets (the 4 LM-transformer shapes; 40 cells total).

``decode_*`` / ``long_*`` lower ``serve_step`` (one new token against a KV cache of
``seq_len``); ``train_4k`` lowers ``train_step``; ``prefill_32k`` lowers
``prefill_step``. ``long_500k`` is only runnable for sub-quadratic archs
(see DESIGN.md §4 — long_500k applicability).
"""
from __future__ import annotations

import dataclasses
from typing import Dict


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    kind: str          # train | prefill | decode
    seq_len: int
    global_batch: int


SHAPES: Dict[str, ShapeConfig] = {
    "train_4k":    ShapeConfig("train_4k", "train", 4_096, 256),
    "prefill_32k": ShapeConfig("prefill_32k", "prefill", 32_768, 32),
    "decode_32k":  ShapeConfig("decode_32k", "decode", 32_768, 128),
    "long_500k":   ShapeConfig("long_500k", "decode", 524_288, 1),
}

# Archs whose attention is NOT sub-quadratic in decode state: long_500k skipped
# (pure full-attention; noted in DESIGN.md §4).
LONG_CONTEXT_SKIP = frozenset(
    {"mistral-nemo-12b", "qwen2.5-3b", "internvl2-26b", "musicgen-large"}
)


def cell_is_runnable(arch_name: str, shape_name: str) -> bool:
    if shape_name == "long_500k" and arch_name in LONG_CONTEXT_SKIP:
        return False
    return True


def reduced_shape(shape: ShapeConfig) -> ShapeConfig:
    """Shrunk shape for CPU smoke tests (same kind)."""
    return ShapeConfig(shape.name + "-reduced", shape.kind,
                       seq_len=64, global_batch=2)
