"""internvl2-26b [vlm] — InternViT frontend (STUB) + InternLM2-20B backbone.

48L d_model=6144 48H (GQA kv=8, head_dim=128) d_ff=16384 vocab=92553
[arXiv:2404.16821; hf]

Per spec the modality frontend is a stub: ``input_specs()`` provides 256
precomputed patch embeddings per sample, prepended to the text sequence.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="internvl2-26b",
    family="vlm",
    num_layers=48,
    d_model=6144,
    num_heads=48,
    num_kv_heads=8,
    head_dim=128,
    d_ff=16384,
    vocab_size=92_553,
    attn_pattern=("global",),
    rope_theta=1_000_000.0,
    mlp_act="silu",
    mlp_gated=True,
    tie_embeddings=False,
    frontend="vision",
    num_patches=256,
    max_seq_len=32_768,
)
