"""musicgen-large [audio] — decoder-only over EnCodec tokens (4 codebooks).

48L d_model=2048 32H (kv=32, head_dim=64) d_ff=8192 vocab=2048
[arXiv:2306.05284; hf]

The EnCodec frontend is a STUB per spec; tokens are (B, K=4, S) codebook ids with
a delay pattern applied upstream. Text conditioning is a stubbed sequence of 64
precomputed T5 embeddings consumed through cross-attention.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="musicgen-large",
    family="audio",
    num_layers=48,
    d_model=2048,
    num_heads=32,
    num_kv_heads=32,
    head_dim=64,
    d_ff=8192,
    vocab_size=2048,
    attn_pattern=("global",),
    pos_embed="sinusoidal",
    mlp_act="gelu",
    mlp_gated=False,
    tie_embeddings=False,
    frontend="audio",
    num_codebooks=4,
    cross_attn_cond=64,
    max_seq_len=8192,
)
