"""mistral-nemo-12b [dense] — full attention, 128k context.

40L d_model=5120 32H (GQA kv=8, head_dim=128) d_ff=14336 vocab=131072
[hf:mistralai/Mistral-Nemo-Base-2407; hf]
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="mistral-nemo-12b",
    family="dense",
    num_layers=40,
    d_model=5120,
    num_heads=32,
    num_kv_heads=8,
    head_dim=128,
    d_ff=14336,
    vocab_size=131_072,
    attn_pattern=("global",),
    rope_theta=1_000_000.0,
    mlp_act="silu",
    mlp_gated=True,
    tie_embeddings=False,
    max_seq_len=131_072,
)
