"""gemma2-2b [dense] — local+global alternating attention, logit softcaps.

26L d_model=2304 8H (GQA kv=4, head_dim=256) d_ff=9216 vocab=256000
[arXiv:2408.00118; hf]
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="gemma2-2b",
    family="dense",
    num_layers=26,
    d_model=2304,
    num_heads=8,
    num_kv_heads=4,
    head_dim=256,
    d_ff=9216,
    vocab_size=256_000,
    attn_pattern=("local", "global"),
    window_size=4096,
    attn_logit_softcap=50.0,
    final_logit_softcap=30.0,
    rope_theta=10_000.0,
    mlp_act="gelu",
    mlp_gated=True,
    tie_embeddings=True,
    embed_scale=True,
    use_post_norm=True,
    max_seq_len=8192,
)
