"""qwen2.5-3b [dense] — GQA with QKV bias.

36L d_model=2048 16H (GQA kv=2, head_dim=128) d_ff=11008 vocab=151936
[hf:Qwen/Qwen2.5-0.5B family; hf]
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="qwen2.5-3b",
    family="dense",
    num_layers=36,
    d_model=2048,
    num_heads=16,
    num_kv_heads=2,
    head_dim=128,
    d_ff=11008,
    vocab_size=151_936,
    attn_pattern=("global",),
    qkv_bias=True,
    rope_theta=1_000_000.0,
    mlp_act="silu",
    mlp_gated=True,
    tie_embeddings=True,
    max_seq_len=32_768,
)
