"""mixtral-8x7b [moe] — 8 experts top-2, sliding-window attention.

32L d_model=4096 32H (GQA kv=8, head_dim=128) d_ff=14336 vocab=32000
[arXiv:2401.04088; hf]
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="mixtral-8x7b",
    family="moe",
    num_layers=32,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    head_dim=128,
    d_ff=14336,
    vocab_size=32_000,
    attn_pattern=("local",),
    window_size=4096,
    rope_theta=1_000_000.0,
    mlp_act="silu",
    mlp_gated=True,
    moe=True,
    num_experts=8,
    experts_per_token=2,
    moe_every=1,
    tie_embeddings=False,
    max_seq_len=32_768,
)
