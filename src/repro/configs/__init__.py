"""Architecture config registry (``--arch <id>``)."""
from __future__ import annotations

import importlib
from typing import Dict, List

from repro.configs.base import ArchConfig, train_flops_per_token
from repro.configs.shapes import (SHAPES, ShapeConfig, LONG_CONTEXT_SKIP,
                                  cell_is_runnable, reduced_shape)

_ARCH_MODULES: Dict[str, str] = {
    "gemma2-2b": "gemma2_2b",
    "mistral-nemo-12b": "mistral_nemo_12b",
    "qwen2.5-3b": "qwen2_5_3b",
    "gemma3-12b": "gemma3_12b",
    "mamba2-130m": "mamba2_130m",
    "recurrentgemma-2b": "recurrentgemma_2b",
    "internvl2-26b": "internvl2_26b",
    "musicgen-large": "musicgen_large",
    "mixtral-8x7b": "mixtral_8x7b",
    "llama4-maverick-400b-a17b": "llama4_maverick_400b_a17b",
}

ARCH_NAMES: List[str] = list(_ARCH_MODULES)


def get_config(name: str) -> ArchConfig:
    if name.endswith("-reduced"):
        return get_config(name[: -len("-reduced")]).reduced()
    if name not in _ARCH_MODULES:
        raise KeyError(f"unknown arch {name!r}; known: {ARCH_NAMES}")
    mod = importlib.import_module(f"repro.configs.{_ARCH_MODULES[name]}")
    cfg: ArchConfig = mod.CONFIG
    cfg.validate()
    return cfg


def all_cells():
    """Yield every (arch, shape) cell incl. runnability flag — 40 total."""
    for a in ARCH_NAMES:
        for s in SHAPES.values():
            yield a, s, cell_is_runnable(a, s.name)


__all__ = [
    "ArchConfig", "ShapeConfig", "SHAPES", "ARCH_NAMES", "LONG_CONTEXT_SKIP",
    "get_config", "all_cells", "cell_is_runnable", "reduced_shape",
    "train_flops_per_token",
]
