from repro.runtime import elastic, fault_tolerance

__all__ = ["elastic", "fault_tolerance"]
