"""Fault tolerance: supervised train loop with checkpoint/restart, heartbeat
tracking, straggler detection/mitigation, and failure injection for tests.

At 1000+ nodes the assumptions are: (a) any step can fail (preemption, ICI
link flap, host OOM), (b) stragglers are common, (c) the job must make forward
progress without human action. The Supervisor provides:

 * periodic checkpoints + restore-on-restart (CheckpointManager);
 * a retry budget with exponential backoff — a failed step re-executes from
   the last checkpoint (the step function is pure, the data pipeline is
   stateless-indexable, so replay is exact);
 * straggler policy: step times exceeding ``straggler_factor × running
   median`` are logged and counted; persistent stragglers trigger the
   ``on_straggler`` callback (at scale: re-dispatch the shard / evict the
   host — here: pluggable, default logs);
 * heartbeat file (host liveness signal an external watchdog can consume).
"""
from __future__ import annotations

import dataclasses
import json
import os
import statistics
import time
from typing import Any, Callable, Dict, List, Optional

from repro.checkpoint.manager import CheckpointManager


def backoff_delay(attempt: int, base_s: float) -> float:
    """Exponential backoff schedule: ``base_s * 2**(attempt-1)`` seconds for
    attempt >= 1. The single retry-pacing rule shared by the train-loop
    Supervisor and the serving guard's transient-step retries
    (serve/scheduler.py) — the two retry loops cannot drift apart."""
    return base_s * (2 ** (max(attempt, 1) - 1))


@dataclasses.dataclass
class FaultToleranceConfig:
    checkpoint_dir: str
    checkpoint_every: int = 50
    keep: int = 3
    max_retries: int = 3
    backoff_s: float = 0.1
    straggler_factor: float = 3.0
    straggler_patience: int = 3
    heartbeat_path: Optional[str] = None


class StragglerDetector:
    """Median-based straggler detector, shared by the train-loop Supervisor
    and the serving ReplicaSupervisor (serve/replica.py).

    Serving reuse seam: a replica restarts its local step counter after a
    failover, so ``observe`` tolerates non-monotonic ``step`` input — a step
    that moves backwards starts a fresh epoch (strike state cleared, the
    timing history kept: step *durations* stay comparable across restarts,
    stale strikes do not). ``reset`` drops everything, for supervisors that
    recycle one detector across replica generations.
    """

    def __init__(self, factor: float, patience: int):
        self.factor = factor
        self.patience = patience
        self.times: List[float] = []
        self.strikes = 0
        self.events: List[Dict] = []
        self.last_step: Optional[int] = None

    def reset(self) -> None:
        """Forget all observations (history, strikes, events, epoch)."""
        self.times.clear()
        self.strikes = 0
        self.events.clear()
        self.last_step = None

    def observe(self, step: int, dt: float) -> bool:
        """Returns True if this step is flagged as a straggler."""
        if self.last_step is not None and step < self.last_step:
            # restarted step clock (e.g. replica failover): stale strikes
            # must not carry into the new epoch
            self.strikes = 0
        self.last_step = step
        dt = max(dt, 0.0)
        flagged = False
        if len(self.times) >= 5:
            med = statistics.median(self.times[-50:])
            if dt > self.factor * med:
                flagged = True
                self.strikes += 1
                self.events.append({"step": step, "dt": dt, "median": med})
            else:
                self.strikes = max(self.strikes - 1, 0)
        self.times.append(dt)
        return flagged

    @property
    def persistent(self) -> bool:
        return self.strikes >= self.patience


class Supervisor:
    """Drives (step_fn, data_fn) with checkpoint/restart + straggler policy.

    step_fn(state, batch) -> (state, metrics); must be pure (replayable).
    data_fn(step) -> batch; must be stateless-indexable (data.pipeline is).
    """

    def __init__(self, cfg: FaultToleranceConfig, step_fn: Callable,
                 data_fn: Callable, init_state_fn: Callable,
                 on_straggler: Optional[Callable] = None,
                 failure_injector: Optional[Callable] = None):
        self.cfg = cfg
        self.step_fn = step_fn
        self.data_fn = data_fn
        self.init_state_fn = init_state_fn
        self.on_straggler = on_straggler or (lambda det: None)
        self.failure_injector = failure_injector
        self.ckpt = CheckpointManager(cfg.checkpoint_dir, keep=cfg.keep)
        self.detector = StragglerDetector(cfg.straggler_factor,
                                          cfg.straggler_patience)
        self.restarts = 0
        # clock sources behind the heartbeat record — injectable so tests
        # (and the straggler suite) control both readings deterministically
        self.wall_clock: Callable[[], float] = time.time
        self.mono_clock: Callable[[], float] = time.monotonic

    # -------------------------------------------------------------- plumbing
    def _heartbeat(self, step: int):
        if self.cfg.heartbeat_path:
            # one schema shared with serving telemetry annotations
            # (serve.telemetry.HEARTBEAT_SCHEMA): monotonic step + wall time
            # + a jump-immune monotonic reading. Lazy import: serve pulls in
            # this module (scheduler -> backoff_delay), not vice versa.
            from repro.serve.telemetry import heartbeat_record
            with open(self.cfg.heartbeat_path, "w") as f:
                json.dump(heartbeat_record(
                    step, wall_time=self.wall_clock(),
                    mono_s=self.mono_clock(), restarts=self.restarts), f)

    def _restore_or_init(self):
        latest = self.ckpt.latest_step()
        if latest is None:
            return 0, self.init_state_fn()
        state = self.init_state_fn()
        restored, manifest = self.ckpt.restore(state, step=latest)
        return latest + 1, restored

    # ------------------------------------------------------------------ run
    def run(self, num_steps: int) -> Dict[str, Any]:
        start, state = self._restore_or_init()
        metrics_log: List[Dict] = []
        step = start
        while step < num_steps:
            batch = self.data_fn(step)
            attempt = 0
            while True:
                try:
                    if self.failure_injector is not None:
                        self.failure_injector(step, attempt)
                    t0 = time.monotonic()
                    state, metrics = self.step_fn(state, batch)
                    dt = time.monotonic() - t0
                    break
                except Exception as e:  # noqa: BLE001 — node failure surface
                    attempt += 1
                    self.restarts += 1
                    if attempt > self.cfg.max_retries:
                        raise RuntimeError(
                            f"step {step}: retry budget exhausted") from e
                    time.sleep(backoff_delay(attempt, self.cfg.backoff_s))
                    # restart from the last durable state
                    start2, state = self._restore_or_init()
                    step = start2
                    batch = self.data_fn(step)
            if self.detector.observe(step, dt):
                if self.detector.persistent:
                    self.on_straggler(self.detector)
            metrics_log.append({"step": step, **{k: float(v) for k, v in
                                                 metrics.items()}})
            if (step + 1) % self.cfg.checkpoint_every == 0 or \
                    step == num_steps - 1:
                self.ckpt.save(step, state)
            self._heartbeat(step)
            step += 1
        return {"metrics": metrics_log, "restarts": self.restarts,
                "straggler_events": self.detector.events,
                "final_step": step - 1}
