"""Elastic scaling: re-shard a checkpoint onto whatever mesh currently exists.

Checkpoints are stored by logical shape (checkpoint.manager), so scaling a job
from N to M pods — or degrading around a dead host — is: build the new mesh,
re-run the planner for the new MeshDesc, and restore with the new shardings.
No checkpoint conversion step.
"""
from __future__ import annotations

from typing import Any, Optional, Tuple

import jax
from jax.sharding import Mesh

from repro.checkpoint.manager import CheckpointManager
from repro.core.planner import MeshDesc, plan_model
from repro.sharding import autoshard, specs as sspec


def mesh_desc(mesh: Mesh) -> MeshDesc:
    sizes = sspec.mesh_axis_sizes(mesh)
    return MeshDesc(pod=sizes.get("pod", 1), data=sizes.get("data", 1),
                    model=sizes.get("model", 1))


def restore_elastic(ckpt: CheckpointManager, abstract_state, cfg, shape_cfg,
                    mesh: Mesh, step: Optional[int] = None) -> Tuple[Any, dict]:
    """Restore (params, opt_state) onto ``mesh``, re-planning shardings."""
    plan = plan_model(cfg, shape_cfg, mesh_desc(mesh))
    ma = sspec.mesh_axis_sizes(mesh)
    from jax.sharding import PartitionSpec as P

    params_abs, opt_abs = abstract_state
    p_specs = autoshard.param_specs(params_abs, plan, ma)
    p_sh = sspec.tree_named(mesh, p_specs)
    # optimizer moments share the param specs; step is replicated
    o_specs = type(opt_abs)(step=P(), mu=p_specs, nu=p_specs)
    o_sh = sspec.tree_named(mesh, o_specs)
    state, manifest = ckpt.restore((params_abs, opt_abs), step=step,
                                   shardings=(p_sh, o_sh))
    return state, manifest
