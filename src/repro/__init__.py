# Eyeriss v2 reproduction: adaptive-sharding JAX training/inference framework.
# The paper's primary contribution lives in repro.core (HM-mesh planner,
# Eyexam roofline, CSC/BCSC sparsity); substrates in sibling subpackages.
__version__ = "0.1.0"
