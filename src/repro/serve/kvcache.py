"""Cache sizing/accounting utilities (the GLB-capacity analogue, paper §II).

The cache pytree itself lives in models/decoding.py; this module answers the
capacity questions the planner and serving engine need: bytes per slot, whether
a (batch × context) fits HBM per chip under a given sharding, and the max slot
count for a budget.
"""
from __future__ import annotations

from typing import Dict

import jax

from repro.core import eyexam
from repro.models import decoding


def cache_bytes(cfg, batch: int, cache_len: int) -> int:
    tree = decoding.abstract_cache(cfg, batch, cache_len)
    return sum(l.size * l.dtype.itemsize for l in jax.tree.leaves(tree))


def paged_cache_bytes(cfg, rows: int, cache_len: int, num_pages: int,
                      page_size: int) -> int:
    """Bytes of the paged cache layout (global layers paged into a
    ``num_pages`` pool; ring/recurrent rows unchanged) — the HBM side of the
    dataflow.attn_path tradeoff the perf guard checks."""
    tree = jax.eval_shape(lambda: decoding.init_paged_cache(
        cfg, rows, cache_len, num_pages, page_size))
    return sum(l.size * l.dtype.itemsize for l in jax.tree.leaves(tree))


def cache_bytes_per_chip(cfg, batch: int, cache_len: int, chips: int,
                         sharded: bool = True) -> float:
    total = cache_bytes(cfg, batch, cache_len)
    return total / chips if sharded else float(total)


def max_slots(cfg, cache_len: int, chips: int,
              hbm_budget_fraction: float = 0.5) -> int:
    """Slots fitting the HBM budget. Returns 0 — not 1 — when even a single
    slot exceeds the budget: the old ``max(..., 1)`` floor masked a
    guaranteed OOM as a servable configuration (callers such as
    DecodeEngine now refuse loudly on 0)."""
    per_slot = cache_bytes(cfg, 1, cache_len) / chips
    budget = eyexam.HBM_CAP * hbm_budget_fraction
    return int(budget // max(per_slot, 1))


class SlotAllocator:
    """Alloc/free accounting for the engine's preallocated slot cache.

    The device cache is a fixed (slots, cache_len, ...) allocation (the GLB
    analogue: capacity is provisioned once, occupancy varies). The allocator
    tracks which batch rows are live so refills write into free rows only —
    the host-side half of the per-slot refill contract in serve.engine.
    """

    def __init__(self, slots: int):
        self.slots = slots
        self._free = list(range(slots - 1, -1, -1))   # pop() yields slot 0 first
        self._live = set()

    def available(self) -> int:
        return len(self._free)

    @property
    def in_use(self) -> int:
        return self.slots - len(self._free)

    def alloc(self) -> int:
        if not self._free:
            raise RuntimeError("no free slots")
        s = self._free.pop()
        self._live.add(s)
        return s

    def alloc_many(self, n: int):
        """Allocate n slots atomically (all-or-nothing): the scheduler admits
        a whole prefill tier at once and must not half-admit under pressure."""
        if n > len(self._free):
            raise RuntimeError(
                f"requested {n} slots, only {len(self._free)} free")
        return [self.alloc() for _ in range(n)]

    def free_many(self, slots) -> None:
        for s in slots:
            self.free(s)

    def free(self, slot: int) -> None:
        if slot not in self._live:
            raise ValueError(f"slot {slot} is not live")
        self._live.remove(slot)
        self._free.append(slot)

    def live_slots(self):
        return sorted(self._live)


def report(cfg, batch: int, cache_len: int, chips: int,
           pager=None) -> Dict[str, float]:
    """Capacity report; pass a serve.paging.PageAllocator as ``pager`` to
    include live paged-occupancy stats (pages total/free, fragmentation)
    alongside the dense-slot accounting it replaces."""
    total = cache_bytes(cfg, batch, cache_len)
    out = {
        "total_gb": total / 1e9,
        "per_chip_gb": total / chips / 1e9,
        "fits": total / chips < eyexam.HBM_CAP,
        "max_slots_half_hbm": max_slots(cfg, cache_len, chips),
    }
    if pager is not None:
        out["paged"] = pager.stats()
    return out
