"""Cache sizing/accounting utilities (the GLB-capacity analogue, paper §II).

The cache pytree itself lives in models/decoding.py; this module answers the
capacity questions the planner and serving engine need: bytes per slot, whether
a (batch × context) fits HBM per chip under a given sharding, and the max slot
count for a budget.
"""
from __future__ import annotations

from typing import Dict

import jax

from repro.core import eyexam
from repro.models import decoding


def cache_bytes(cfg, batch: int, cache_len: int) -> int:
    tree = decoding.abstract_cache(cfg, batch, cache_len)
    return sum(l.size * l.dtype.itemsize for l in jax.tree.leaves(tree))


def paged_cache_bytes(cfg, rows: int, cache_len: int, num_pages: int,
                      page_size: int, kv_quant: str = "fp") -> int:
    """Bytes of the paged cache layout (global layers paged into a
    ``num_pages`` pool; ring/recurrent rows unchanged) — the HBM side of the
    dataflow.attn_path tradeoff the perf guard checks. ``kv_quant='int8'``
    accounts the int8 payload + per-page scale tables."""
    tree = jax.eval_shape(lambda: decoding.init_paged_cache(
        cfg, rows, cache_len, num_pages, page_size, kv_quant))
    return sum(l.size * l.dtype.itemsize for l in jax.tree.leaves(tree))


def num_global_layers(cfg) -> int:
    """Global-attention layers — the ones the paged pool actually holds."""
    from repro.models import transformer as tfm
    kinds = tfm.slot_kinds(cfg)
    per_period = sum(1 for k, _ in kinds if k == "global")
    rem_global = sum(1 for k, _ in kinds[:tfm.num_remainder(cfg)]
                     if k == "global")
    return per_period * tfm.num_scan_periods(cfg) + rem_global


def kv_page_bytes(cfg, page_size: int, kv_quant: str = "fp") -> int:
    """HBM bytes one physical page costs across every global layer's K+V
    pool (plus its int8 scale entries) — the unit the sharing metrics are
    denominated in: each refcount above 1 is one page of this size NOT
    allocated."""
    from repro.core import dataflow
    return dataflow.paged_kv_bytes(1, page_size, cfg.num_kv_heads,
                                   cfg.head_dim, num_global_layers(cfg),
                                   kv_quant)


def cache_bytes_per_chip(cfg, batch: int, cache_len: int, chips: int,
                         sharded: bool = True) -> float:
    total = cache_bytes(cfg, batch, cache_len)
    return total / chips if sharded else float(total)


def max_slots(cfg, cache_len: int, chips: int,
              hbm_budget_fraction: float = 0.5) -> int:
    """Slots fitting the HBM budget. Returns 0 — not 1 — when even a single
    slot exceeds the budget: the old ``max(..., 1)`` floor masked a
    guaranteed OOM as a servable configuration (callers such as
    DecodeEngine now refuse loudly on 0)."""
    per_slot = cache_bytes(cfg, 1, cache_len) / chips
    budget = eyexam.HBM_CAP * hbm_budget_fraction
    return int(budget // max(per_slot, 1))


class SlotAllocator:
    """Alloc/free accounting for the engine's preallocated slot cache.

    The device cache is a fixed (slots, cache_len, ...) allocation (the GLB
    analogue: capacity is provisioned once, occupancy varies). The allocator
    tracks which batch rows are live so refills write into free rows only —
    the host-side half of the per-slot refill contract in serve.engine.
    """

    def __init__(self, slots: int):
        self.slots = slots
        self._free = list(range(slots - 1, -1, -1))   # pop() yields slot 0 first
        self._live = set()

    def available(self) -> int:
        return len(self._free)

    @property
    def in_use(self) -> int:
        return self.slots - len(self._free)

    def alloc(self) -> int:
        if not self._free:
            raise RuntimeError("no free slots")
        s = self._free.pop()
        self._live.add(s)
        return s

    def alloc_many(self, n: int):
        """Allocate n slots atomically (all-or-nothing): the scheduler admits
        a whole prefill tier at once and must not half-admit under pressure."""
        if n > len(self._free):
            raise RuntimeError(
                f"requested {n} slots, only {len(self._free)} free")
        return [self.alloc() for _ in range(n)]

    def free_many(self, slots) -> None:
        for s in slots:
            self.free(s)

    def free(self, slot: int) -> None:
        if slot not in self._live:
            raise ValueError(f"slot {slot} is not live")
        self._live.remove(slot)
        self._free.append(slot)

    def live_slots(self):
        return sorted(self._live)


def report(cfg, batch: int, cache_len: int, chips: int,
           pager=None, kv_quant: str = "fp") -> Dict[str, float]:
    """Capacity report; pass a serve.paging.PageAllocator as ``pager`` to
    include live paged-occupancy stats (pages total/free, fragmentation,
    prefix-sharing savings) alongside the dense-slot accounting it
    replaces. ``kv_quant`` denominates the byte-valued paged metrics in the
    pool's actual page format (int8 pages halve the payload)."""
    total = cache_bytes(cfg, batch, cache_len)
    out = {
        "total_gb": total / 1e9,
        "per_chip_gb": total / chips / 1e9,
        "fits": total / chips < eyexam.HBM_CAP,
        "max_slots_half_hbm": max_slots(cfg, cache_len, chips),
    }
    if pager is not None:
        st = pager.stats()
        page_b = kv_page_bytes(cfg, pager.page_size, kv_quant)
        st["kv_quant"] = kv_quant
        st["page_bytes"] = page_b
        # multicast saving in bytes: pages other requests reference instead
        # of allocating (Σ (refcount − 1) over shared pages)
        st["bytes_saved_sharing"] = st["pages_saved_sharing"] * page_b
        out["paged"] = st
    return out
