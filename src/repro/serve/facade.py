"""``repro.serve.LLM`` — the single serving front door (ISSUE 5).

The two serving engines grew divergent constructor kwarg piles
(``DecodeEngine(slots=…, cache_len=…)`` vs
``ContinuousBatchingScheduler(rows=…, page_size=…, num_pages=…,
attn_path=…, kv_quant=…)``). The facade replaces both entry points with one
object resolved around a :class:`repro.core.plan.ServePlan`:

    plan = core.plan.plan_serve(cfg, hbm_budget_bytes=…, expected_batch=…,
                                expected_len_dist={"mean": …, "max": …})
    llm = repro.serve.LLM(cfg, params, plan)
    done = llm.generate([(prompt, max_new), ...])          # drain semantics
    done = llm.stream(requests, on_token=callback)         # continuous batch

* :meth:`generate` drains a fixed request list to completion on the dense
  slot engine (``serve.engine.DecodeEngine``) — the batch-throughput path.
* :meth:`stream` serves arriving requests with continuous batching over the
  plan's paged (or contiguous) KV layout
  (``serve.scheduler.ContinuousBatchingScheduler``) and per-token callbacks
  — the latency/goodput path.

Both wrapped engines read every dispatch decision from the same plan, so
switching between the two entry points can never flip a kernel route
mid-deployment. ``plan=None`` resolves a conservative default plan (half
the per-chip HBM, modest batch) — explicit plans are the production path.
"""
from __future__ import annotations

from typing import Callable, Dict, List, Optional, Sequence, Union

from repro.core import eyexam, plan as plan_lib
from repro.serve import shard as shard_lib
from repro.serve.engine import DecodeEngine, Request
from repro.serve.guard import GuardConfig
from repro.serve.replica import ReplicaSet
from repro.serve.scheduler import ContinuousBatchingScheduler, StreamRequest
from repro.serve.telemetry import Telemetry

DEFAULT_LEN_DIST = {"mean": 256, "max": 512}
DEFAULT_BATCH = 8


RequestLike = Union[Request, StreamRequest, Dict, tuple]


class LLM:
    """One model + one resolved ServePlan, served two ways.

    ``eos_id``/``temperature`` are request-stream sampling semantics (not
    dispatch decisions), so they stay constructor kwargs; everything that
    picks a kernel path, a memory layout, or a capacity lives in ``plan``.
    Engines are built lazily and reused across calls (their jitted programs
    and donated cache buffers are warm after the first call).
    """

    def __init__(self, cfg, params, plan: Optional[plan_lib.ServePlan] = None,
                 *, eos_id: int = 1, temperature: float = 0.0,
                 guard: Union[GuardConfig, None, bool] = None,
                 replicas: int = 1,
                 on_token: Optional[Callable] = None,
                 on_outcome: Optional[Callable] = None,
                 trace: Union[bool, Telemetry] = True):
        if replicas < 1:
            raise ValueError(
                f"replicas must be >= 1, got {replicas}: serving always "
                "goes through at least one scheduler replica (replicas=1 "
                "is the single-scheduler fast path, replicas>=2 the "
                "fault-tolerant control plane)")
        if plan is None:
            plan = plan_lib.plan_serve(
                cfg,
                hbm_budget_bytes=int(eyexam.HBM_CAP // 2),
                expected_batch=DEFAULT_BATCH,
                expected_len_dist=dict(DEFAULT_LEN_DIST))
        self.cfg = cfg
        self.params = params
        self.plan = plan
        self.eos_id = eos_id
        self.temperature = temperature
        # robustness guard (ISSUE 6): on by default behind the facade — every
        # streamed request ends in a structured RequestOutcome, overload is
        # shed/degraded along the plan's ladder instead of raising. Pass
        # ``guard=False`` for the raw legacy engine behavior, or a tuned
        # GuardConfig for production deadlines/budgets.
        if guard is None:
            guard = GuardConfig()
        elif guard is False:
            guard = None
        self.guard: Optional[GuardConfig] = guard
        # multi-replica control plane (ISSUE 7): replicas >= 2 serves
        # stream() through a ReplicaSet — router placement, heartbeats,
        # deterministic failover — on the same plan and guard
        self.replicas = replicas
        # constructor-level streaming defaults: a deployment that always
        # wants the same callbacks sets them once here; per-call arguments
        # (and per-request callbacks) still override
        self.on_token = on_token
        self.on_outcome = on_outcome
        # observability (serve.telemetry, ISSUE 8): one Telemetry bundle
        # shared by whichever engine serves, reset at each call. trace=True
        # records spans on the virtual step clock (deterministic; wall time
        # as annotations); trace=False keeps the metrics registry but drops
        # span recording; passing a Telemetry shares an external bundle.
        if isinstance(trace, Telemetry):
            self._telemetry = trace
        else:
            self._telemetry = Telemetry(enabled=bool(trace))
        self._engine: Optional[DecodeEngine] = None
        self._scheduler: Optional[ContinuousBatchingScheduler] = None
        self._replicaset: Optional[ReplicaSet] = None
        self._last_run = None                # engine behind the last call

    # ------------------------------------------------------------- helpers
    def explain(self) -> str:
        """The plan's per-decision Eyexam rationale."""
        return self.plan.explain()

    @property
    def mesh(self) -> shard_lib.ServeMesh:
        """The plan's resolved serving mesh (ISSUE 10) — ``tp=1 ep=1`` for
        unsharded plans. Sharded plans serve through the same two entry
        points: the models read ``tp``/``ep`` off the active plan, so both
        ``generate`` and ``stream`` execute the shard-explicit program."""
        return shard_lib.ServeMesh.from_plan(self.plan)

    def sharding_report(self) -> Dict:
        """Mesh + per-device pool stats for the most recent call: resolved
        tp/ep, whether host devices back the mesh, single- vs per-device KV
        pool bytes, and (after a sharded paged ``stream``) live per-shard
        occupancy and the lockstep-divergence count."""
        pool = getattr(self._scheduler, "pager", None) \
            if self._scheduler is not None else None
        return shard_lib.sharding_stats(self.cfg, self.plan, pool=pool)

    def _normalize(self, requests: Sequence[RequestLike], cls,
                   on_token: Optional[Callable] = None) -> List:
        """Accept engine Request/StreamRequest objects, dicts, or
        (prompt, max_new) tuples; auto-assign rids by input position."""
        out = []
        for i, r in enumerate(requests):
            if isinstance(r, cls):
                pass
            elif isinstance(r, (Request, StreamRequest)):
                r = cls(rid=r.rid, prompt=list(r.prompt), max_new=r.max_new)
            elif isinstance(r, dict):
                r = cls(**{"rid": i, **r})
            else:
                prompt, max_new = r
                r = cls(rid=i, prompt=list(prompt), max_new=int(max_new))
            if cls is StreamRequest and on_token is not None \
                    and r.on_token is None:
                r.on_token = on_token
            out.append(r)
        if len({r.rid for r in out}) != len(out):
            raise ValueError("request rids must be unique")
        return out

    def _validate(self, requests: Sequence) -> None:
        """Caller-bug checks at the front door (ISSUE 6 satellite): empty
        batches and infeasible requests raise a clear ValueError naming the
        violated limit, before any engine is built or any work is traced —
        runtime faults, by contrast, become RequestOutcomes, never raises."""
        if not requests:
            raise ValueError(
                "empty request list — nothing to serve (did request "
                "construction upstream filter everything out?)")
        patches = self.cfg.num_patches if self.cfg.frontend == "vision" else 0
        cache_len = self.plan.cache_len
        for r in requests:
            if not r.prompt and not patches:
                raise ValueError(
                    f"request {r.rid}: empty prompt — decode needs at least "
                    "one conditioning token")
            plen = len(r.prompt) + patches
            if plen + max(r.max_new, 0) > cache_len:
                raise ValueError(
                    f"request {r.rid}: prompt ({plen} tokens"
                    f"{' incl. vision patches' if patches else ''}) + "
                    f"max_new ({r.max_new}) = {plen + max(r.max_new, 0)} "
                    f"exceeds the plan's cache_len ({cache_len}); shorten "
                    "the request or re-plan with a larger "
                    "expected_len_dist['max']")

    # ------------------------------------------------------------- serving
    def generate(self, requests: Sequence[RequestLike], rng=None
                 ) -> List[Request]:
        """Drain ``requests`` to completion (batch-throughput semantics).

        Wraps the dense-slot ``DecodeEngine``; returns the finished request
        objects in input order (``r.out`` holds the generated tokens).
        """
        if self._engine is None:
            self._engine = DecodeEngine(
                self.cfg, self.params, self.plan, eos_id=self.eos_id,
                temperature=self.temperature, telemetry=self._telemetry)
        self._last_run = self._engine
        reqs = self._normalize(requests, Request)
        self._validate(reqs)
        self._telemetry.reset()            # one trace per call
        done = self._engine.run(reqs, rng=rng)
        return sorted(done, key=lambda r: r.rid)

    def stream(self, requests: Sequence[RequestLike],
               on_token: Optional[Callable] = None, rng=None,
               on_outcome: Optional[Callable] = None, chaos=None
               ) -> List[StreamRequest]:
        """Serve ``requests`` with continuous batching + streaming.

        Wraps the paged ``ContinuousBatchingScheduler`` (requests may carry
        ``arrival`` stamps and per-request ``on_token`` callbacks; a
        call-level ``on_token(request, token)`` applies to any request
        without its own, falling back to the constructor-level default, as
        does ``on_outcome(request, outcome)``). With the default guard every
        returned request carries a terminal ``r.outcome``
        (ok/shed/expired/preempted_out/failed). With ``replicas >= 2`` the
        call serves through the multi-replica control plane
        (``serve.replica.ReplicaSet``): router placement, heartbeat
        supervision, deterministic failover. ``chaos`` takes a
        ``serve.chaos.ChaosConfig`` (or, multi-replica, a
        ``ReplicaChaosConfig``) for deterministic fault injection (tests/CI
        only). Returns finished requests in input order.
        """
        on_token = on_token if on_token is not None else self.on_token
        on_outcome = on_outcome if on_outcome is not None \
            else self.on_outcome
        reqs = self._normalize(requests, StreamRequest, on_token=on_token)
        self._validate(reqs)
        if on_outcome is not None:
            for r in reqs:
                if r.on_outcome is None:
                    r.on_outcome = on_outcome
        if self.replicas > 1:
            if self._replicaset is None:
                self._replicaset = ReplicaSet(
                    self.cfg, self.params, self.plan,
                    replicas=self.replicas, eos_id=self.eos_id,
                    temperature=self.temperature, guard=self.guard,
                    telemetry=self._telemetry)
            self._last_run = self._replicaset
            # ReplicaSet.run resets the shared bundle itself
            return self._replicaset.run(reqs, rng=rng, chaos=chaos)
        if self._scheduler is None:
            self._scheduler = ContinuousBatchingScheduler(
                self.cfg, self.params, self.plan, eos_id=self.eos_id,
                temperature=self.temperature, guard=self.guard,
                telemetry=self._telemetry)
        self._last_run = self._scheduler
        self._telemetry.reset()            # one trace per call
        done = self._scheduler.run(reqs, rng=rng, chaos=chaos)
        return sorted(done, key=lambda r: r.rid)

    # ------------------------------------------------------------- reports
    @property
    def phase_stats(self) -> Dict:
        """Phase stats of the most recently run entry point (prefill/decode
        split, paging/sharing counters)."""
        return self._last_run.phase_stats if self._last_run is not None \
            else {}

    def telemetry(self) -> Telemetry:
        """The Telemetry bundle of the most recent call: ``.tracer`` (spans
        on the virtual step clock; ``to_chrome_trace()`` for Perfetto),
        ``.metrics`` (frozen-key registry; ``snapshot()``), and
        ``.last_drift`` (Eyexam-at-runtime DriftReport vs the plan)."""
        return self._telemetry
