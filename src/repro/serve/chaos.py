"""Seeded chaos harness: deterministic fault injection for the serving loop.

The guard layer (serve/guard.py) promises that every request reaches a
terminal :class:`~repro.serve.guard.RequestOutcome` and that the page pool
never leaks — promises that are only worth anything if they hold under
faults. This module injects three fault classes the scheduler must absorb,
all driven by a fixed seed so a chaos run is exactly reproducible:

* **page-``ensure`` failures** — ``ensure_fails`` makes an allocation probe
  report pressure even when pages are free (rate-limited by
  ``ensure_fail_max`` so a run always terminates). The scheduler sees the
  same signal as genuine exhaustion: preempt, or stall the boundary.
* **transient step failures** — ``check_step`` raises
  :class:`InjectedFault` for the first ``step_fail_attempts`` attempts of
  each listed chunk, *before* the device call is issued (the decode state is
  donated to the jitted chunk, so a post-dispatch retry would replay against
  consumed buffers — pre-dispatch injection keeps retry trivially safe). The
  scheduler retries with the shared ``fault_tolerance.backoff_delay``
  schedule; exceeding ``max_step_retries`` resolves everything in flight as
  ``failed``.
* **NaN logits** — ``nan_rids_for`` names requests whose next-token logits
  are poisoned before a given chunk; the guard's NaN sweep must quarantine
  exactly those rows (outcome ``failed``) without touching survivors.

Faults are injected at the host/device boundary, never inside traced code,
so surviving requests' tokens stay bit-identical to a fault-free run — the
chaos suite (tests/test_serve_guard.py) asserts exactly that.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Tuple

import numpy as np


class InjectedFault(RuntimeError):
    """A deterministic injected fault — transient and safely retryable."""


@dataclasses.dataclass
class ChaosConfig:
    """One seeded fault schedule (pass to ``scheduler.run(..., chaos=)``).

    ``ensure_fail_rate`` is the per-probe probability of a spurious
    allocation failure, capped at ``ensure_fail_max`` total injections;
    ``step_fail_chunks`` lists decode-chunk indices whose first
    ``step_fail_attempts`` dispatch attempts raise; ``nan_rids`` maps a
    chunk index to the rids whose logits are poisoned before that chunk.
    """
    seed: int = 0
    ensure_fail_rate: float = 0.0
    ensure_fail_max: int = 64
    step_fail_chunks: Tuple[int, ...] = ()
    step_fail_attempts: int = 1
    nan_rids: Dict[int, Tuple[int, ...]] = dataclasses.field(
        default_factory=dict)


@dataclasses.dataclass
class ReplicaChaosConfig:
    """Replica-level fault schedule for the multi-replica control plane
    (serve/replica.ReplicaSet). Where :class:`ChaosConfig` injects faults a
    single scheduler must absorb, this schedules whole-replica failures the
    *supervisor* must absorb — the dominant failure mode at fleet scale:

    * ``kill_at_step``    — replica dies abruptly at the given virtual-clock
      step (its run generator is abandoned mid-flight: no finalization, page
      pool lost, in-flight requests stranded until failover re-routes them).
    * ``stall_at_step``   — replica hangs from that step on: it stops
      responding to boundary ticks but is never cleanly dead, so only the
      heartbeat audit (steps since last response, judged by the shared
      ``runtime.fault_tolerance.StragglerDetector``) can catch it.
    * ``corrupt_pool_at_step`` — the replica's PageAllocator metadata is
      corrupted at that step (a phantom refcount, exactly the drift
      ``guard.audit_pool`` exists to catch); the per-window pool audit must
      quarantine the replica before the corruption spreads.
    * ``request_chaos``   — optional per-replica :class:`ChaosConfig`
      threaded into that replica's scheduler run (both chaos layers
      compose).

    All maps are keyed by replica slot id; every schedule is deterministic
    on the shared virtual clock, so two same-seed runs fail identically.
    """
    kill_at_step: Dict[int, float] = dataclasses.field(default_factory=dict)
    stall_at_step: Dict[int, float] = dataclasses.field(default_factory=dict)
    corrupt_pool_at_step: Dict[int, float] = dataclasses.field(
        default_factory=dict)
    request_chaos: Dict[int, ChaosConfig] = dataclasses.field(
        default_factory=dict)


class FaultInjector:
    """Stateful executor of one :class:`ChaosConfig` (one run's faults).

    ``injected`` counts faults actually delivered per class — the chaos
    tests assert the schedule fired, not just that nothing crashed.
    """

    def __init__(self, cfg: ChaosConfig):
        self.cfg = cfg
        self._rng = np.random.default_rng(cfg.seed)
        self._step_attempts: Dict[int, int] = {}
        self._nan_pending = {k: tuple(v) for k, v in cfg.nan_rids.items()}
        self.injected = {"ensure": 0, "step": 0, "nan": 0}
        # observer hook (serve.telemetry): called as on_inject(kind, rid) at
        # every delivered injection — the schedule is seeded, so the
        # resulting trace events are as deterministic as the faults
        self.on_inject = None

    def _notify(self, kind: str, rid: int = -1) -> None:
        if self.on_inject is not None:
            self.on_inject(kind, rid)

    def ensure_fails(self, rid: int, n_tokens: int) -> bool:
        """Should this allocation probe spuriously report page pressure?"""
        if self.cfg.ensure_fail_rate <= 0.0 \
                or self.injected["ensure"] >= self.cfg.ensure_fail_max:
            return False
        if self._rng.random() < self.cfg.ensure_fail_rate:
            self.injected["ensure"] += 1
            self._notify("ensure", rid)
            return True
        return False

    def check_step(self, chunk_index: int) -> None:
        """Raise :class:`InjectedFault` while this chunk's failure budget
        lasts; silently pass once it is spent (the retry then succeeds)."""
        if chunk_index not in self.cfg.step_fail_chunks:
            return
        attempts = self._step_attempts.get(chunk_index, 0)
        if attempts >= self.cfg.step_fail_attempts:
            return
        self._step_attempts[chunk_index] = attempts + 1
        self.injected["step"] += 1
        self._notify("step")
        raise InjectedFault(
            f"injected step failure (chunk {chunk_index}, "
            f"attempt {attempts + 1})")

    def nan_rids_for(self, chunk_index: int) -> Tuple[int, ...]:
        """Rids whose pre-chunk logits should be poisoned with NaN.
        Fires at most once per chunk index: a boundary whose chunk is then
        skipped (all poisoned rows quarantined) must not re-poison."""
        rids = self._nan_pending.pop(chunk_index, ())
        if rids:
            self.injected["nan"] += len(rids)
            for rid in rids:
                self._notify("nan", rid)
        return rids
