"""Continuous-batching scheduler over a paged KV cache (streaming serving).

``DecodeEngine`` (serve/engine.py) provisions a dense ``(slots, cache_len)``
cache — the worst-case allocation Eyeriss v2's flexible hierarchy exists to
avoid — and drains a fixed request list with no notion of arrival time. This
scheduler replaces that model end to end:

* **Paged KV** — global-attention layers store KV in fixed-size pages
  addressed through per-request block tables (serve/paging.py ↔
  models.decoding.init_paged_cache ↔ kernels/paged_attention.py): pages are
  allocated on demand as sequences grow, returned the moment a request
  finishes, and under page pressure the latest-admitted request is
  **preempted** (pages freed, request requeued for recompute) so the oldest
  work always completes. ``core.dataflow.attn_path`` decides paged vs. the
  contiguous-ring fallback from the expected occupancy. Prefill is
  **page-native**: ``decoding.prefill_batched``'s paged output mode writes
  each layer's K/V straight into pool pages during the layer scan — no
  dense (B, cache_len) transient, no post-prefill scatter.
* **Copy-on-write prefix sharing** — admission walks the allocator's prefix
  index and points a request's leading block-table entries at pages already
  holding the same prompt prefix (refcount++, prefill skips those tokens'
  writes); fresh pages start at the first divergent token. Shared pages are
  read-only: before each decode chunk the scheduler materializes a private
  copy of any shared page the chunk will append to (``PageAllocator.cow_page``
  + a device-side page copy). ``core.dataflow.kv_quant_path`` additionally
  picks the page payload format — int8 with per-page scales at cache-bound
  batch widths, bf16 otherwise.
* **Continuous batching** — admission runs every ``sync_every`` decode steps:
  arrived requests are bucketed into length tiers and batch-prefilled into
  freed rows (``decoding.prefill_batched``, the engine's amortized-admission
  path), EOS rows are evicted and their pages returned at the same boundary.
* **Streaming** — each request may carry an ``on_token`` callback, invoked
  per generated token at every sync (per-chunk host transfer, never
  per-token — the device-residency contract is unchanged from the engine).
* **Arrival accounting** — requests carry an ``arrival`` stamp on a virtual
  clock that advances ``sync_every`` per decode chunk (deterministic,
  CI-stable; wall-clock is recorded alongside). Admission never runs ahead
  of arrival, and per-request admitted/first-token/finished stamps feed the
  goodput/latency numbers in benchmarks/sparse_decode.py --arrivals.
"""
from __future__ import annotations

import dataclasses
import time
import warnings
from typing import Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import dataflow, plan as plan_lib
from repro.models import decoding
from repro.serve import kvcache, paging
from repro.serve.engine import build_tier_batch, make_decode_step


@dataclasses.dataclass
class StreamRequest:
    """A request with arrival/latency accounting and optional streaming.

    ``arrival`` is in virtual decode steps (the scheduler's clock unit).
    ``on_token`` — if set — is called as ``on_token(request, token)`` for
    every generated token, in order, at each sync boundary. ``out`` always
    accumulates regardless. Latency stamps (``admitted_at``,
    ``first_token_at``, ``finished_at``) are on the same virtual clock;
    ``finished_wall_s`` is wall-clock seconds from run start.
    """
    rid: int
    prompt: List[int]
    max_new: int
    arrival: float = 0.0
    out: List = dataclasses.field(default_factory=list)
    done: bool = False
    on_token: Optional[Callable] = None
    admitted_at: Optional[float] = None
    first_token_at: Optional[float] = None
    finished_at: Optional[float] = None
    finished_wall_s: Optional[float] = None
    preemptions: int = 0
    shared_tokens: int = 0       # prompt tokens served from adopted pages
                                 # at the most recent admission (CoW sharing)


class ContinuousBatchingScheduler:
    """Streaming continuous-batching loop over paged (or contiguous) KV.

    Construction is plan-driven (ISSUE 5): pass a resolved
    ``core.plan.ServePlan`` (``plan_serve`` for budget-derived plans,
    ``plan_for_scheduler`` for explicit geometry) and every dispatch
    decision — rows, cache_len, page_size, pool size, paged vs contiguous,
    CoW sharing, KV quant, the prefill tier ladder — is read from it; the
    plan is activated around the jitted programs so ``layers.mlp`` and the
    kernels read the same resolved crossovers. The legacy kwarg pile
    (``rows=…, cache_len=…, page_size=…, num_pages=…, attn_path=…,
    kv_quant=…``) still works as a deprecated shim that builds the identical
    single-decision plan. Provisioning fewer pages than
    ``rows × ceil(cache_len/page_size)`` is the point of paging (short
    requests stop stranding worst-case HBM), with preemption as the safety
    valve; archs with no global-attention layers resolve to contiguous
    (ring/recurrent state is already bounded — nothing to page).
    """

    def __init__(self, cfg, params, plan: Optional[plan_lib.ServePlan] = None,
                 *, rows: Optional[int] = None,
                 cache_len: Optional[int] = None,
                 page_size: int = 0, num_pages: int = 0, eos_id: int = 1,
                 temperature: float = 0.0, sync_every: Optional[int] = None,
                 attn_path: Optional[str] = None,
                 share_prefix: Optional[bool] = None,
                 kv_quant: Optional[str] = None):
        legacy_kwargs = (rows is not None or cache_len is not None
                         or page_size or num_pages or attn_path is not None
                         or share_prefix is not None or kv_quant is not None)
        if plan is not None and legacy_kwargs:
            # a plan plus legacy dispatch kwargs would silently lose the
            # kwargs (the plan wins) — refuse instead of surprising the
            # caller mid-migration; sync_every alone stays an honored
            # per-engine override
            raise TypeError(
                "pass either plan= or the legacy rows=/cache_len=/"
                "page_size=/num_pages=/attn_path=/share_prefix=/kv_quant= "
                "kwargs, not both (the plan already fixes every decision)")
        if plan is None:
            # legacy kwarg pile: resolve it through the same shim the old
            # inline dispatch moved to (core.plan.plan_for_scheduler applies
            # the identical dataflow rules once) and deprecate the spelling
            if rows is None or cache_len is None:
                raise TypeError(
                    "ContinuousBatchingScheduler needs a ServePlan "
                    "(core.plan.plan_serve / plan_for_scheduler) or the "
                    "legacy rows=/cache_len= kwargs")
            warnings.warn(
                "constructing ContinuousBatchingScheduler from rows=/"
                "cache_len=/page_size=/... kwargs is deprecated — pass "
                "plan=core.plan.plan_for_scheduler(...) or serve through "
                "repro.serve.LLM",
                DeprecationWarning, stacklevel=2)
            if rows < 1:
                raise ValueError(
                    f"rows must be >= 1, got {rows}: a (1, {cache_len}) "
                    "cache row does not fit the HBM budget "
                    "(kvcache.max_slots == 0)")
            plan = plan_lib.plan_for_scheduler(
                cfg, rows=rows, cache_len=cache_len, page_size=page_size,
                num_pages=num_pages, attn_path=attn_path,
                share_prefix=share_prefix, kv_quant=kv_quant,
                sync_every=8 if sync_every is None else sync_every)
        if plan.rows < 1:
            raise ValueError(
                f"rows must be >= 1, got {plan.rows}: a "
                f"(1, {plan.cache_len}) cache row does not fit the HBM "
                "budget (kvcache.max_slots == 0)")
        self.cfg = cfg
        self.params = params
        self.plan = plan
        self.rows = plan.rows
        self.cache_len = plan.cache_len
        self.eos_id = eos_id
        self.temperature = temperature
        self.sync_every = max(1, plan.sync_every if sync_every is None
                              else sync_every)
        # every dispatch decision below reads the plan — the PAGE_SIZE /
        # occupancy / CoW / KV-quant rules were resolved exactly once
        self.page_size = plan.page_size
        self.paged = plan.paged
        self.max_pages = plan.max_pages
        if self.paged:
            self.num_pages = plan.num_pages
            self.pager = paging.PageAllocator(self.num_pages, self.page_size)
        else:
            self.num_pages = 0
            self.pager = None
        self.share_prefix = plan.share_prefix
        self.kv_quant = plan.kv_quant
        self.host_syncs = 0
        self.phase_stats: Dict = {}
        self._chunk = jax.jit(self._make_chunk_fn(), donate_argnums=(1,))
        self._refill = jax.jit(self._make_refill_fn(), donate_argnums=(1,))
        self._cow = jax.jit(self._make_cow_fn(), donate_argnums=(0,))

    # ------------------------------------------------------ device programs
    def _init_state(self):
        cfg = self.cfg
        if self.paged:
            cache = decoding.init_paged_cache(cfg, self.rows, self.cache_len,
                                              self.num_pages, self.page_size,
                                              self.kv_quant)
        else:
            cache = decoding.init_cache(cfg, self.rows, self.cache_len)
        vshape = (self.rows, cfg.num_codebooks, cfg.vocab_padded) \
            if cfg.num_codebooks > 1 else (self.rows, cfg.vocab_padded)
        last = jnp.zeros(vshape, jnp.float32)
        pos = jnp.zeros((self.rows,), jnp.int32)
        live = jnp.zeros((self.rows,), jnp.bool_)
        budget = jnp.zeros((self.rows,), jnp.int32)
        return (cache, last, pos, live, budget)

    def _make_refill_fn(self) -> Callable:
        """Batched prefill of one length tier into freed rows.

        Same contract as DecodeEngine's refill, except in paged mode the
        prefill itself is page-native (decoding.PagedPrefill): every
        global-attention layer's K/V is written into its block-table pages
        *during* the layer scan, per-row entries are merged at ``slots``
        inside the same program, and tokens before each row's shared-prefix
        boundary (``write_start``) are skipped — adopted pages stay
        read-only. The dense (B, cache_len) slot-shaped transient of the old
        scatter-after-prefill path never exists.
        """
        cfg, cache_len, paged = self.cfg, self.cache_len, self.paged

        def refill(params, state, toks, lengths, slots, max_new, block_table,
                   write_start):
            cache, last, pos, live, budget = state
            if paged:
                pp = decoding.PagedPrefill(
                    cache=cache, block_table_rows=block_table[slots],
                    slots=slots, write_start=write_start)
                logits, new_cache = decoding.prefill_batched(
                    params, toks, lengths, cfg, cache_len, paged=pp)
            else:
                logits, row_cache = decoding.prefill_batched(
                    params, toks, lengths, cfg, cache_len)
                new_cache = {}
                for part in ("blocks", "rem"):
                    if part in cache:
                        ax = (lambda c, s: c.at[:, slots].set(
                            s.astype(c.dtype))) if part == "blocks" else \
                            (lambda c, s: c.at[slots].set(s.astype(c.dtype)))
                        new_cache[part] = {
                            k: jax.tree.map(ax, cache[part][k],
                                            row_cache[part][k])
                            for k in cache[part]}
            last = last.at[slots].set(logits[:, -1].astype(last.dtype))
            pos = pos.at[slots].set(lengths)
            live = live.at[slots].set(True)
            budget = budget.at[slots].set(max_new)
            return (new_cache, last, pos, live, budget)

        return refill

    def _make_cow_fn(self) -> Callable:
        """Device-side page materialization for copy-on-write: content (and
        int8 scales) of physical pages ``src`` copied onto ``dst`` across
        every paged pool entry. Pairs are host-deduplicated; pad pairs
        repeat a real pair, so duplicate destinations carry identical
        values (order-independent scatter)."""
        def cow(state, src, dst):
            cache, last, pos, live, budget = state
            new_cache = {}
            for part in ("blocks", "rem"):
                if part not in cache:
                    continue
                stacked = part == "blocks"
                out = {}
                for name, e in cache[part].items():
                    if decoding.is_paged_entry(e):
                        if stacked:   # (nper, P, ...) — page axis 1
                            out[name] = {k: v.at[:, dst].set(v[:, src])
                                         for k, v in e.items()}
                        else:
                            out[name] = {k: v.at[dst].set(v[src])
                                         for k, v in e.items()}
                    else:
                        out[name] = e
                new_cache[part] = out
            return (new_cache, last, pos, live, budget)

        return cow

    def _make_chunk_fn(self) -> Callable:
        """sync_every fused decode steps — the engine's shared step
        (engine.make_decode_step), with serve_step routing paged entries
        through the block table."""
        T, paged = self.sync_every, self.paged
        step = make_decode_step(self.cfg, self.temperature, self.eos_id)

        def chunk(params, state, rng, block_table):
            bt = block_table if paged else None
            rngs = jax.random.split(rng, T)
            state, (toks, emits) = jax.lax.scan(
                lambda carry, rng_i: step(params, carry, rng_i,
                                          block_table=bt), state, rngs)
            return state, toks, emits

        return chunk

    # -------------------------------------------------------------- host loop
    def _plen(self, r: StreamRequest) -> int:
        """Effective prompt length at (re-)admission: original prompt plus
        any tokens generated before a preemption (recompute resume)."""
        return len(r.prompt) + len(r.out)

    def _resume_prompt(self, r: StreamRequest) -> List[int]:
        if not r.out:
            return list(r.prompt)
        if self.cfg.num_codebooks > 1:
            raise RuntimeError(
                "recompute preemption requires num_codebooks == 1")
        return list(r.prompt) + [int(t) for t in r.out]

    def _final_len(self, r: StreamRequest) -> int:
        """Upper bound on tokens this request ever holds (page cap)."""
        return len(r.prompt) + r.max_new

    def _block_table(self, row_rids: List[int]):
        return jnp.asarray(self.pager.block_table_rows(row_rids,
                                                       self.max_pages))

    def run(self, requests: List[StreamRequest], rng=None
            ) -> List[StreamRequest]:
        # the plan is the dispatch source for everything traced below
        with plan_lib.activate(self.plan):
            return self._run(requests, rng)

    def _run(self, requests: List[StreamRequest], rng=None
             ) -> List[StreamRequest]:
        rng = rng if rng is not None else jax.random.PRNGKey(0)
        rids = [r.rid for r in requests]
        if len(set(rids)) != len(rids):
            # block tables are keyed by rid — duplicates would silently share
            # pages and corrupt each other's KV history
            raise ValueError(f"request rids must be unique, got {rids}")
        # feasibility is arrival-independent (resume totals equal originals):
        # validate everything up front so a late infeasible request cannot
        # abort the run after other requests already finished
        for r in requests:
            total = len(r.prompt) + r.max_new
            if r.max_new > 0 and total > self.cache_len:
                raise ValueError(
                    f"request {r.rid}: prompt ({len(r.prompt)}) + max_new "
                    f"({r.max_new}) exceeds cache_len ({self.cache_len})")
            if self.paged and r.max_new > 0 and dataflow.pages_for(
                    total, self.page_size) > self.num_pages:
                raise ValueError(
                    f"request {r.rid} needs "
                    f"{dataflow.pages_for(total, self.page_size)} pages, "
                    f"pool has {self.num_pages}: it can never run")
        pending = sorted(requests, key=lambda r: (r.arrival, r.rid))
        waiting: List[StreamRequest] = []
        done: List[StreamRequest] = []
        if self.paged:
            # fresh pool per run (like the SlotAllocator below): an aborted
            # previous run must not leak its block tables into this one;
            # self.pager stays inspectable after the run (kvcache.report)
            self.pager = paging.PageAllocator(self.num_pages, self.page_size)
        for r in [r for r in pending if r.max_new <= 0]:
            pending.remove(r)
            r.done = True
            r.finished_at = r.arrival
            done.append(r)
        alloc = kvcache.SlotAllocator(self.rows)
        active: Dict[int, StreamRequest] = {}        # row -> request
        row_pos: Dict[int, int] = {}                 # row -> device pos mirror
        admit_order: List[int] = []                  # rows, oldest first
        row_rids = [-1] * self.rows
        state = self._init_state()
        K = self.cfg.num_codebooks
        T = self.sync_every
        clock = 0.0
        t0 = time.perf_counter()
        st = self.phase_stats = {
            "prefill_s": 0.0, "decode_s": 0.0, "prefill_batches": 0,
            "prefill_prompts": 0, "prefill_real_tokens": 0,
            "prefill_padded_tokens": 0, "decode_chunks": 0,
            "decode_steps": 0, "idle_steps": 0.0, "preemptions": 0,
            "attn_path": "paged" if self.paged else "contiguous",
            "kv_quant": self.kv_quant,
            "share_prefix": self.share_prefix,
            "shared_tokens_admitted": 0,   # prompt tokens served from
                                           # adopted (refcounted) pages
            "cow_copies": 0,               # shared pages materialized for
                                           # a decode append
            "peak_live_rows": 0,           # max concurrent admitted requests
        }

        preempted_rows: List[int] = []
        just_preempted: set = set()           # rids evicted this boundary
        peak_pages: Optional[Dict] = None     # busiest-boundary pool snapshot

        def clear_preempted_flags():
            """Drop the device live flags of rows preempted since the last
            clear: zombies would keep running full forward+sampling (and in
            paged mode DMA-ing clamped/freed pages) until the row is reused.
            Must run before any admission reuses a freed row AND before
            every decode chunk."""
            nonlocal state
            if not preempted_rows:
                return
            cache, last, pos, live, budget = state
            live = live.at[jnp.asarray(preempted_rows)].set(False)
            state = (cache, last, pos, live, budget)
            preempted_rows.clear()

        def preempt_latest() -> bool:
            """Free the latest-admitted row; requeue its request (recompute).
            Returns False when there is nothing to preempt."""
            if len(admit_order) <= 1:
                return False
            row = admit_order.pop()               # latest admitted
            r = active.pop(row)
            self._resume_prompt(r)                # raises early for K > 1
            self.pager.free(r.rid)
            alloc.free(row)
            row_rids[row] = -1
            row_pos.pop(row, None)
            r.preemptions += 1
            st["preemptions"] += 1
            preempted_rows.append(row)
            just_preempted.add(r.rid)
            waiting.insert(0, r)                  # keeps its queue priority
            return True

        while pending or waiting or active:
            # ---- arrivals (virtual clock; idle-jump when nothing to do) ----
            while pending and pending[0].arrival <= clock + 1e-9:
                waiting.append(pending.pop(0))
            if not active and not waiting:
                st["idle_steps"] += pending[0].arrival - clock
                clock = pending[0].arrival
                continue

            # ---- page headroom for the active rows' next chunk ------------
            # runs BEFORE admission: live rows reserve their chunk pages
            # first, so a new request is never admitted (and batch-prefilled)
            # only to be preempted at the same boundary — that would throw
            # the prefill away and thrash under sustained pressure
            if self.paged:
                for row in list(admit_order):         # oldest first
                    if row not in active:
                        continue
                    r = active[row]
                    need = min(row_pos[row] + T, self._final_len(r))
                    while row in active and not self.pager.ensure(r.rid,
                                                                  need):
                        if not preempt_latest():
                            raise RuntimeError(
                                "page pool exhausted with nothing left to "
                                "preempt — num_pages is too small")
                    if row in active:
                        self.pager.set_length(r.rid, row_pos[row])
            clear_preempted_flags()

            # ---- admission: arrived requests into freed rows --------------
            to_admit: List[StreamRequest] = []
            while waiting and len(to_admit) < alloc.available():
                r = waiting[0]
                if r.rid in just_preempted:
                    # evicted THIS boundary to relieve pressure — re-admitting
                    # into the pages it just freed would re-run its (growing)
                    # prefill only to preempt it again: wait one boundary.
                    # break, not skip: it keeps queue priority
                    break
                plen = self._plen(r)
                if self.paged:
                    # CoW prefix sharing: point leading table entries at
                    # resident pages already holding this prompt's prefix
                    # (refcount++); prefill will skip writes before the
                    # boundary. Roll the adoption back if the fresh-page
                    # remainder doesn't fit — all-or-nothing, like ensure.
                    r.shared_tokens = self.pager.adopt_prefix(
                        r.rid, self._resume_prompt(r)) \
                        if self.share_prefix else 0
                    if not self.pager.ensure(
                            r.rid, min(plen + T, self._final_len(r))):
                        if self.pager.pages_of(r.rid):
                            self.pager.free(r.rid)   # roll back adoption
                        r.shared_tokens = 0
                        break                  # page pressure: wait for frees
                    if self.share_prefix:
                        # publish this prompt's pages immediately — their
                        # content lands in this same boundary's refill, so a
                        # same-boundary arrival can already adopt the chain
                        self.pager.register_prefix(r.rid,
                                                   self._resume_prompt(r))
                waiting.pop(0)
                to_admit.append(r)
            just_preempted.clear()
            admits: List[Tuple[int, StreamRequest]] = list(
                zip(alloc.alloc_many(len(to_admit)), to_admit))
            for row, r in admits:
                admit_order.append(row)
                row_rids[row] = r.rid
                row_pos[row] = self._plen(r)
                if self.paged:
                    self.pager.set_length(r.rid, row_pos[row])
                    st["shared_tokens_admitted"] += r.shared_tokens
                if r.admitted_at is None:
                    r.admitted_at = clock
            if admits:
                buckets: Dict[int, List[Tuple[int, StreamRequest]]] = {}
                for row, r in admits:
                    buckets.setdefault(self.plan.tier(self._plen(r)),
                                       []).append((row, r))
                bt = self._block_table(row_rids) if self.paged else \
                    jnp.zeros((self.rows, 1), jnp.int32)
                tp0 = time.perf_counter()
                for tier, group in sorted(buckets.items()):
                    B = len(group)
                    toks, lengths, row_ids, budgets, starts = \
                        build_tier_batch(
                            group, tier, self._resume_prompt,
                            lambda r: r.max_new - len(r.out),
                            lambda r: r.shared_tokens)
                    for row, r in group:
                        active[row] = r
                    state = self._refill(self.params, state,
                                         jnp.asarray(toks),
                                         jnp.asarray(lengths),
                                         jnp.asarray(row_ids),
                                         jnp.asarray(budgets), bt,
                                         jnp.asarray(starts))
                    st["prefill_batches"] += 1
                    st["prefill_prompts"] += B
                    st["prefill_real_tokens"] += int(lengths.sum())
                    st["prefill_padded_tokens"] += B * tier
                jax.block_until_ready(state[1])
                st["prefill_s"] += time.perf_counter() - tp0

            if not active:
                continue
            st["peak_live_rows"] = max(st["peak_live_rows"], len(active))

            # ---- CoW guard: materialize shared pages this chunk appends to
            # (runs after admission so freshly adopted whole-prompt tails are
            # covered too; shared pages are read-only by contract)
            if self.paged and self.share_prefix:
                pairs: List[Tuple[int, int]] = []
                for row in list(admit_order):         # oldest first
                    if row not in active:
                        continue
                    r = active[row]
                    lo = row_pos[row]
                    hi = min(lo + T, self._final_len(r))
                    # re-probe after every mutation: a preemption can drop a
                    # refcount to 1 mid-loop (page no longer needs a copy)
                    while row in active:
                        shared = self.pager.shared_pages_in(r.rid, lo, hi)
                        if not shared:
                            break
                        pair = self.pager.cow_page(r.rid, shared[0])
                        if pair is None:              # no free page: pressure
                            if not preempt_latest():
                                raise RuntimeError(
                                    "page pool exhausted during CoW "
                                    "materialization with nothing left to "
                                    "preempt — num_pages is too small")
                            continue
                        pairs.append(pair)
                if pairs:
                    st["cow_copies"] += len(pairs)
                    # pad to a power of two (bounded retraces); pads repeat a
                    # real pair so duplicate dsts carry identical content
                    n = 1 << (len(pairs) - 1).bit_length()
                    pairs = pairs + [pairs[0]] * (n - len(pairs))
                    src = jnp.asarray([s for s, _ in pairs], jnp.int32)
                    dst = jnp.asarray([d for _, d in pairs], jnp.int32)
                    state = self._cow(state, src, dst)
            clear_preempted_flags()       # CoW-guard preemptions, pre-chunk

            if self.paged:
                # sample occupancy at the busiest point of the boundary —
                # the end-of-run snapshot is always fully drained
                s = self.pager.stats()
                if peak_pages is None or \
                        s["pages_used"] > peak_pages["pages_used"]:
                    peak_pages = s

            # ---------------------- device-resident decode chunk ----------
            td0 = time.perf_counter()
            rng, k = jax.random.split(rng)
            bt = self._block_table(row_rids) if self.paged else \
                jnp.zeros((self.rows, 1), jnp.int32)
            state, toks, emits = self._chunk(self.params, state, k, bt)
            toks_h, emits_h, live_h = jax.device_get((toks, emits, state[3]))
            self.host_syncs += 1
            st["decode_chunks"] += 1
            st["decode_steps"] += T
            st["decode_s"] += time.perf_counter() - td0
            clock += T
            for t in range(emits_h.shape[0]):
                for row, r in active.items():
                    if emits_h[t, row]:
                        tok = [int(v) for v in toks_h[t, row]] if K > 1 \
                            else int(toks_h[t, row])
                        r.out.append(tok)
                        if r.first_token_at is None:
                            r.first_token_at = clock - T + t + 1
                        if r.on_token is not None:
                            r.on_token(r, tok)
            freed_rows: List[int] = []
            for row in list(active):
                row_pos[row] += T
                if not live_h[row]:
                    r = active.pop(row)
                    r.done = True
                    r.finished_at = clock
                    r.finished_wall_s = time.perf_counter() - t0
                    done.append(r)
                    freed_rows.append(row)
                    admit_order.remove(row)
                    row_rids[row] = -1
                    row_pos.pop(row, None)
                    if self.paged:
                        self.pager.free(r.rid)   # pages return immediately
            alloc.free_many(freed_rows)
        st["total_wall_s"] = time.perf_counter() - t0
        st["clock_steps"] = clock
        if self.paged:
            st["pages"] = self.pager.stats()       # drained end state
            st["pages_peak"] = peak_pages          # busiest boundary
        return done
