"""Continuous-batching scheduler over a paged KV cache (streaming serving).

``DecodeEngine`` (serve/engine.py) provisions a dense ``(slots, cache_len)``
cache — the worst-case allocation Eyeriss v2's flexible hierarchy exists to
avoid — and drains a fixed request list with no notion of arrival time. This
scheduler replaces that model end to end:

* **Paged KV** — global-attention layers store KV in fixed-size pages
  addressed through per-request block tables (serve/paging.py ↔
  models.decoding.init_paged_cache ↔ kernels/paged_attention.py): pages are
  allocated on demand as sequences grow, returned the moment a request
  finishes, and under page pressure the latest-admitted request is
  **preempted** (pages freed, request requeued for recompute) so the oldest
  work always completes. ``core.dataflow.attn_path`` decides paged vs. the
  contiguous-ring fallback from the expected occupancy. Prefill is
  **page-native**: ``decoding.prefill_batched``'s paged output mode writes
  each layer's K/V straight into pool pages during the layer scan — no
  dense (B, cache_len) transient, no post-prefill scatter.
* **Copy-on-write prefix sharing** — admission walks the allocator's prefix
  index and points a request's leading block-table entries at pages already
  holding the same prompt prefix (refcount++, prefill skips those tokens'
  writes); fresh pages start at the first divergent token. Shared pages are
  read-only: before each decode chunk the scheduler materializes a private
  copy of any shared page the chunk will append to (``PageAllocator.cow_page``
  + a device-side page copy). ``core.dataflow.kv_quant_path`` additionally
  picks the page payload format — int8 with per-page scales at cache-bound
  batch widths, bf16 otherwise.
* **Continuous batching** — admission runs every ``sync_every`` decode steps:
  arrived requests are bucketed into length tiers and batch-prefilled into
  freed rows (``decoding.prefill_batched``, the engine's amortized-admission
  path), EOS rows are evicted and their pages returned at the same boundary.
* **Streaming** — each request may carry an ``on_token`` callback, invoked
  per generated token at every sync (per-chunk host transfer, never
  per-token — the device-residency contract is unchanged from the engine).
* **Arrival accounting** — requests carry an ``arrival`` stamp on a virtual
  clock that advances ``sync_every`` per decode chunk (deterministic,
  CI-stable; wall-clock is recorded alongside). Admission never runs ahead
  of arrival, and per-request admitted/first-token/finished stamps feed the
  goodput/latency numbers in benchmarks/sparse_decode.py --arrivals.
"""
from __future__ import annotations

import dataclasses
import time
import warnings
from typing import Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import dataflow, plan as plan_lib
from repro.models import decoding
from repro.runtime.fault_tolerance import backoff_delay
from repro.serve import chaos as chaos_mod, kvcache, paging
from repro.serve import shard as shard_mod
from repro.serve import guard as guard_mod
from repro.serve import telemetry as telemetry_mod
from repro.serve.engine import (build_tier_batch, make_decode_step,
                                make_spec_decode_step)


@dataclasses.dataclass
class StreamRequest:
    """A request with arrival/latency accounting and optional streaming.

    ``arrival`` is in virtual decode steps (the scheduler's clock unit).
    ``on_token`` — if set — is called as ``on_token(request, token)`` for
    every generated token, in order, at each sync boundary. ``out`` always
    accumulates regardless. Latency stamps (``admitted_at``,
    ``first_token_at``, ``finished_at``) are on the same virtual clock;
    ``finished_wall_s`` is wall-clock seconds from run start.
    """
    rid: int
    prompt: List[int]
    max_new: int
    arrival: float = 0.0
    out: List = dataclasses.field(default_factory=list)
    done: bool = False
    on_token: Optional[Callable] = None
    admitted_at: Optional[float] = None
    first_token_at: Optional[float] = None
    finished_at: Optional[float] = None
    finished_wall_s: Optional[float] = None
    preemptions: int = 0
    shared_tokens: int = 0       # prompt tokens served from adopted pages
                                 # at the most recent admission (CoW sharing)
    # --- robustness layer (serve.guard, ISSUE 6) ---
    ttl: Optional[float] = None  # deadline = arrival + ttl (virtual steps);
                                 # None falls back to guard.default_ttl_steps
    on_outcome: Optional[Callable] = None   # on_outcome(request, outcome)
    outcome: Optional[guard_mod.RequestOutcome] = None
    degraded: List[str] = dataclasses.field(default_factory=list)
    # --- multi-replica control plane (serve.router/replica, ISSUE 7) ---
    tenant: Optional[str] = None  # fair-admission key (None: default tenant)
    replica: Optional[int] = None           # replica that resolved it
    migrations: int = 0          # failovers survived (recompute re-routes)


class ContinuousBatchingScheduler:
    """Streaming continuous-batching loop over paged (or contiguous) KV.

    Construction is plan-driven (ISSUE 5): pass a resolved
    ``core.plan.ServePlan`` (``plan_serve`` for budget-derived plans,
    ``plan_for_scheduler`` for explicit geometry) and every dispatch
    decision — rows, cache_len, page_size, pool size, paged vs contiguous,
    CoW sharing, KV quant, the prefill tier ladder — is read from it; the
    plan is activated around the jitted programs so ``layers.mlp`` and the
    kernels read the same resolved crossovers. The legacy kwarg pile
    (``rows=…, cache_len=…, page_size=…, num_pages=…, attn_path=…,
    kv_quant=…``) still works as a deprecated shim that builds the identical
    single-decision plan. Provisioning fewer pages than
    ``rows × ceil(cache_len/page_size)`` is the point of paging (short
    requests stop stranding worst-case HBM), with preemption as the safety
    valve; archs with no global-attention layers resolve to contiguous
    (ring/recurrent state is already bounded — nothing to page).
    """

    def __init__(self, cfg, params, plan: Optional[plan_lib.ServePlan] = None,
                 *, rows: Optional[int] = None,
                 cache_len: Optional[int] = None,
                 page_size: int = 0, num_pages: int = 0, eos_id: int = 1,
                 temperature: float = 0.0, sync_every: Optional[int] = None,
                 attn_path: Optional[str] = None,
                 share_prefix: Optional[bool] = None,
                 kv_quant: Optional[str] = None,
                 guard: Optional[guard_mod.GuardConfig] = None,
                 telemetry: Optional[telemetry_mod.Telemetry] = None,
                 slot: int = -1):
        legacy_kwargs = (rows is not None or cache_len is not None
                         or page_size or num_pages or attn_path is not None
                         or share_prefix is not None or kv_quant is not None)
        if plan is not None and legacy_kwargs:
            # a plan plus legacy dispatch kwargs would silently lose the
            # kwargs (the plan wins) — refuse instead of surprising the
            # caller mid-migration; sync_every alone stays an honored
            # per-engine override
            raise TypeError(
                "pass either plan= or the legacy rows=/cache_len=/"
                "page_size=/num_pages=/attn_path=/share_prefix=/kv_quant= "
                "kwargs, not both (the plan already fixes every decision)")
        if plan is None:
            # legacy kwarg pile: resolve it through the same shim the old
            # inline dispatch moved to (core.plan.plan_for_scheduler applies
            # the identical dataflow rules once) and deprecate the spelling
            if rows is None or cache_len is None:
                raise TypeError(
                    "ContinuousBatchingScheduler needs a ServePlan "
                    "(core.plan.plan_serve / plan_for_scheduler) or the "
                    "legacy rows=/cache_len= kwargs")
            warnings.warn(
                "constructing ContinuousBatchingScheduler from rows=/"
                "cache_len=/page_size=/... kwargs is deprecated — pass "
                "plan=core.plan.plan_for_scheduler(...) or serve through "
                "repro.serve.LLM",
                DeprecationWarning, stacklevel=2)
            if rows < 1:
                raise ValueError(
                    f"rows must be >= 1, got {rows}: a (1, {cache_len}) "
                    "cache row does not fit the HBM budget "
                    "(kvcache.max_slots == 0)")
            plan = plan_lib.plan_for_scheduler(
                cfg, rows=rows, cache_len=cache_len, page_size=page_size,
                num_pages=num_pages, attn_path=attn_path,
                share_prefix=share_prefix, kv_quant=kv_quant,
                sync_every=8 if sync_every is None else sync_every)
        if plan.rows < 1:
            raise ValueError(
                f"rows must be >= 1, got {plan.rows}: a "
                f"(1, {plan.cache_len}) cache row does not fit the HBM "
                "budget (kvcache.max_slots == 0)")
        self.cfg = cfg
        self.params = params
        self.plan = plan
        self.rows = plan.rows
        self.cache_len = plan.cache_len
        self.eos_id = eos_id
        self.temperature = temperature
        self.sync_every = max(1, plan.sync_every if sync_every is None
                              else sync_every)
        # every dispatch decision below reads the plan — the PAGE_SIZE /
        # occupancy / CoW / KV-quant rules were resolved exactly once
        self.page_size = plan.page_size
        self.paged = plan.paged
        self.max_pages = plan.max_pages
        if self.paged:
            self.num_pages = plan.num_pages
            # mesh-sharded plans (ISSUE 10) get one allocator per tp device
            # in lockstep over the same distributed address space
            self.pager = shard_mod.make_pool(plan)
        else:
            self.num_pages = 0
            self.pager = None
        self.share_prefix = plan.share_prefix
        self.kv_quant = plan.kv_quant
        # speculative decode (ISSUE 9): the plan's roofline `spec` Decision
        # picks k (0 disables); the runtime additionally requires greedy
        # sampling and the fp paged pool the flattened k-position verifier
        # is bit-exact on. A mid-run int8 degrade rung turns it back off.
        self.spec_k = int(getattr(plan, "spec_k", 0))
        self.spec_on = (self.spec_k >= 2 and self.paged
                        and temperature <= 0 and cfg.num_codebooks == 1
                        and self.kv_quant == "fp")
        # recompute-resume fast path (ISSUE 9 satellite): a re-admitted
        # preempted request whose leading pages are still resident refills
        # only the non-adopted suffix through the flattened verifier —
        # same gates as speculation minus the plan's k choice
        self._fast_resume = (self.paged and self.share_prefix
                             and cfg.num_codebooks == 1
                             and self.kv_quant == "fp"
                             and {kk for kk, _ in decoding.tfm.slot_kinds(cfg)}
                             == {"global"})
        # robustness policy (serve.guard): guard=None preserves the legacy
        # raise-on-exhaustion semantics exactly; with a GuardConfig every
        # request resolves to a structured RequestOutcome and overload walks
        # the plan's degradation ladder instead of raising
        self.guard = guard
        if guard is not None and guard.degrade_rungs is not None:
            self._ladder = tuple(r for r in plan.degrade
                                 if r in guard.degrade_rungs)
        else:
            self._ladder = plan.degrade if guard is not None else ()
        # observability (serve.telemetry, ISSUE 8): events are keyed by
        # (virtual clock, replica slot, rid). A shared Telemetry comes from
        # the facade or the multi-replica control plane (which also owns its
        # reset); a self-owned bundle is reset at each run start.
        self.telemetry = telemetry if telemetry is not None \
            else telemetry_mod.Telemetry()
        self._own_telemetry = telemetry is None
        self.slot = slot
        self.host_syncs = 0
        self.phase_stats: Dict = {}
        self._live = None             # run-in-progress state (see _run_gen)
        self._chunk = jax.jit(self._make_chunk_fn(), donate_argnums=(1,))
        self._refill = jax.jit(self._make_refill_fn(), donate_argnums=(1,))
        self._cow = jax.jit(self._make_cow_fn(), donate_argnums=(0,))
        self._resume = jax.jit(self._make_resume_fn(), donate_argnums=(1,))

    def _chunk_span(self) -> int:
        """Worst-case tokens one decode chunk appends per row: T baseline
        steps, T rounds of k candidate writes under speculation (rejected
        candidates occupy page slots until the next round overwrites them,
        so headroom and the CoW window must cover them)."""
        return self.sync_every * (self.spec_k if self.spec_on else 1)

    # ------------------------------------------------------ device programs
    def _init_state(self):
        cfg = self.cfg
        if self.paged:
            cache = decoding.init_paged_cache(cfg, self.rows, self.cache_len,
                                              self.num_pages, self.page_size,
                                              self.kv_quant)
        else:
            cache = decoding.init_cache(cfg, self.rows, self.cache_len)
        vshape = (self.rows, cfg.num_codebooks, cfg.vocab_padded) \
            if cfg.num_codebooks > 1 else (self.rows, cfg.vocab_padded)
        last = jnp.zeros(vshape, jnp.float32)
        pos = jnp.zeros((self.rows,), jnp.int32)
        live = jnp.zeros((self.rows,), jnp.bool_)
        budget = jnp.zeros((self.rows,), jnp.int32)
        # per-row committed token stream by absolute position (-1 empty):
        # feeds the bigram self-draft (engine.ngram_successor); threaded
        # unchanged through the baseline step so both chunk flavors share
        # one state pytree
        hist = jnp.full((self.rows, self.cache_len), -1, jnp.int32)
        return (cache, last, pos, live, budget, hist)

    def _make_refill_fn(self) -> Callable:
        """Batched prefill of one length tier into freed rows.

        Same contract as DecodeEngine's refill, except in paged mode the
        prefill itself is page-native (decoding.PagedPrefill): every
        global-attention layer's K/V is written into its block-table pages
        *during* the layer scan, per-row entries are merged at ``slots``
        inside the same program, and tokens before each row's shared-prefix
        boundary (``write_start``) are skipped — adopted pages stay
        read-only. The dense (B, cache_len) slot-shaped transient of the old
        scatter-after-prefill path never exists.
        """
        cfg, cache_len, paged = self.cfg, self.cache_len, self.paged

        def refill(params, state, toks, lengths, slots, max_new, block_table,
                   write_start):
            cache, last, pos, live, budget, hist = state
            if paged:
                pp = decoding.PagedPrefill(
                    cache=cache, block_table_rows=block_table[slots],
                    slots=slots, write_start=write_start)
                logits, new_cache = decoding.prefill_batched(
                    params, toks, lengths, cfg, cache_len, paged=pp)
            else:
                logits, row_cache = decoding.prefill_batched(
                    params, toks, lengths, cfg, cache_len)
                new_cache = {}
                for part in ("blocks", "rem"):
                    if part in cache:
                        ax = (lambda c, s: c.at[:, slots].set(
                            s.astype(c.dtype))) if part == "blocks" else \
                            (lambda c, s: c.at[slots].set(s.astype(c.dtype)))
                        new_cache[part] = {
                            k: jax.tree.map(ax, cache[part][k],
                                            row_cache[part][k])
                            for k in cache[part]}
            last = last.at[slots].set(logits[:, -1].astype(last.dtype))
            pos = pos.at[slots].set(lengths)
            live = live.at[slots].set(True)
            budget = budget.at[slots].set(max_new)
            if cfg.num_codebooks == 1:
                # seed the self-draft history with the (resume-extended)
                # prompt; pad positions stay -1 (never matched)
                S = toks.shape[1]
                row_hist = jnp.where(
                    jnp.arange(S, dtype=jnp.int32)[None, :]
                    < lengths[:, None], toks.astype(jnp.int32), -1)
                hist = hist.at[slots].set(-1)
                hist = hist.at[slots, :S].set(row_hist)
            return (new_cache, last, pos, live, budget, hist)

        return refill

    def _make_cow_fn(self) -> Callable:
        """Device-side page materialization for copy-on-write: content (and
        int8 scales) of physical pages ``src`` copied onto ``dst`` across
        every paged pool entry. Pairs are host-deduplicated; pad pairs
        repeat a real pair, so duplicate destinations carry identical
        values (order-independent scatter)."""
        def cow(state, src, dst):
            cache, last, pos, live, budget, hist = state
            new_cache = {}
            for part in ("blocks", "rem"):
                if part not in cache:
                    continue
                stacked = part == "blocks"
                out = {}
                for name, e in cache[part].items():
                    if decoding.is_paged_entry(e):
                        if stacked:   # (nper, P, ...) — page axis 1
                            out[name] = {k: v.at[:, dst].set(v[:, src])
                                         for k, v in e.items()}
                        else:
                            out[name] = {k: v.at[dst].set(v[src])
                                         for k, v in e.items()}
                    else:
                        out[name] = e
                new_cache[part] = out
            return (new_cache, last, pos, live, budget, hist)

        return cow

    def _make_chunk_fn(self) -> Callable:
        """sync_every fused decode steps — the engine's shared step
        (engine.make_decode_step), with serve_step routing paged entries
        through the block table. Under speculation each scan step is one
        draft-k/verify-once round (engine.make_spec_decode_step), so the
        chunk's outputs widen to (T, B, k) and a chunk retires up to
        ``T * k`` tokens per row at the same T dispatches."""
        T, paged = self.sync_every, self.paged
        if self.spec_on:
            step = make_spec_decode_step(self.cfg, self.eos_id, self.spec_k)
        else:
            base = make_decode_step(self.cfg, self.temperature, self.eos_id)

            def step(params, carry, rng_i, block_table=None):
                # thread the spec history through untouched — one state
                # pytree for both chunk flavors (degrade rungs retrace the
                # same donated buffers)
                core, out = base(params, carry[:5], rng_i,
                                 block_table=block_table)
                return core + (carry[5],), out

        def chunk(params, state, rng, block_table):
            bt = block_table if paged else None
            rngs = jax.random.split(rng, T)
            state, (toks, emits) = jax.lax.scan(
                lambda carry, rng_i: step(params, carry, rng_i,
                                          block_table=bt), state, rngs)
            return state, toks, emits

        return chunk

    def _make_resume_fn(self) -> Callable:
        """Suffix-only refill for a recompute resume (ISSUE 9 satellite):
        the adopted prefix pages already hold K/V for tokens [0, start), so
        only the ``toks`` suffix flows through the flattened k-position
        verifier — one dispatch over len(suffix) flattened rows instead of
        a full-prompt prefill tier. ``toks`` (1, Lp) is the pow2-padded
        suffix, ``n_real`` its unpadded length; pad positions write beyond
        the committed length (overwritten by decode before any masked read)
        and their logits are never selected."""
        cfg = self.cfg

        def resume(params, state, toks, start, n_real, row, n_tok, max_new,
                   block_table, hist_row):
            cache, last, pos, live, budget, hist = state
            logits, cache = decoding.verify_step(
                params, cache, toks, start[None], cfg,
                block_table=block_table[row][None])
            last = last.at[row].set(logits[0, n_real - 1].astype(last.dtype))
            pos = pos.at[row].set(n_tok)
            live = live.at[row].set(True)
            budget = budget.at[row].set(max_new)
            hist = hist.at[row].set(hist_row)
            return (cache, last, pos, live, budget, hist)

        return resume

    # -------------------------------------------------------------- host loop
    def _plen(self, r: StreamRequest) -> int:
        """Effective prompt length at (re-)admission: original prompt plus
        any tokens generated before a preemption (recompute resume)."""
        return len(r.prompt) + len(r.out)

    def _resume_prompt(self, r: StreamRequest) -> List[int]:
        if not r.out:
            return list(r.prompt)
        if self.cfg.num_codebooks > 1:
            raise RuntimeError(
                "recompute preemption requires num_codebooks == 1")
        return list(r.prompt) + [int(t) for t in r.out]

    def _final_len(self, r: StreamRequest) -> int:
        """Upper bound on tokens this request ever holds (page cap)."""
        return len(r.prompt) + r.max_new

    def _block_table(self, row_rids: List[int]):
        return jnp.asarray(self.pager.block_table_rows(row_rids,
                                                       self.max_pages))

    def _degrade_to_int8(self, state, clock: float):
        """int8 rung of the degradation ladder: requantize the resident fp
        pool to int8 pages in place and GROW it to the plan's
        ``num_pages_int8`` (same HBM footprint, ~2× pages — pressure relief
        without evicting anyone). Page ids 0..old-1 keep their contents, so
        every block table survives verbatim; the jitted programs retrace on
        the new pytree structure automatically. Sticky for the scheduler's
        lifetime (there is no un-degrade rung — re-widening would need a
        lossy fp reconstruction for no occupancy win)."""
        new_pages = self.plan.num_pages_int8

        def migrate(cache):
            out_cache = {}
            for part in ("blocks", "rem"):
                if part not in cache:
                    continue
                out = {}
                for name, e in cache[part].items():
                    if decoding.is_paged_entry(e) \
                            and not decoding.is_quantized_entry(e):
                        out[name] = decoding.quantize_paged_entry(e,
                                                                  new_pages)
                    else:
                        out[name] = e
                out_cache[part] = out
            return out_cache

        cache, last, pos, live, budget, hist = state
        with warnings.catch_warnings():
            # fp buffers can't be reused for the int8 pool (dtype + shape
            # change) — the donation-unused warning is expected here, once
            warnings.simplefilter("ignore", UserWarning)
            cache = jax.jit(migrate, donate_argnums=(0,))(cache)
        self.pager.grow(new_pages)
        self.num_pages = new_pages
        self.kv_quant = "int8"
        if self.spec_on:
            # int8 appends rewrite whole pages (per-page scale requant), so
            # rejected-draft garbage would poison committed tokens' scales:
            # speculation and the suffix-resume verifier end at this rung
            self.spec_on = False
            self._chunk = jax.jit(self._make_chunk_fn(), donate_argnums=(1,))
        self._fast_resume = False
        self.phase_stats["kv_quant"] = "int8"
        self.phase_stats["degraded_to_int8_at"] = clock
        self.telemetry.metrics.count("requant_events")
        self.telemetry.tracer.event("degrade_rung", clock, cat="degrade",
                                    slot=self.slot, rung="int8_kv",
                                    pages=new_pages)
        return (cache, last, pos, live, budget, hist)

    def run(self, requests: List[StreamRequest], rng=None, chaos=None
            ) -> List[StreamRequest]:
        # the plan is the dispatch source for everything traced below; the
        # run is self-paced: every boundary ticks with no external clock and
        # the loop idle-jumps across arrival gaps
        gen = self._run_gen(requests, rng, chaos, external=False)
        with plan_lib.activate(self.plan):
            try:
                gen.send(None)                       # prime: setup + validate
                while True:
                    gen.send(("tick", None))
            except StopIteration as e:
                return e.value
            finally:
                self._live = None

    def start_gen(self, requests: List[StreamRequest], rng=None, chaos=None):
        """Prime a boundary-stepped run for an external driver (the
        multi-replica control plane, serve/replica.py).

        The returned generator yields a status dict before every sync-window
        boundary: ``{"clock", "drained", "active", "waiting", "pending",
        "done", "decode_chunks"}``. Send ``("tick", global_clock)`` to
        process ONE boundary with the scheduler's virtual clock synced to
        the shared ``global_clock`` (the scheduler never idle-jumps ahead of
        it, so N replicas driven with the same ticks stay in lockstep), or
        ``("stop", None)`` to finalize — ``StopIteration.value`` is the done
        list, exactly as :meth:`run` returns it. Caller-bug validation runs
        here, before the first yield. The driver must wrap every ``send`` in
        ``plan_lib.activate(self.plan)`` (dispatch identity) and may
        :meth:`inject` requests between boundaries (failover re-routes).
        Abandoning the generator (``close()``) models replica death: no
        finalization, no outcome delivery, live state left harvestable in
        ``self._live``.
        """
        gen = self._run_gen(requests, rng, chaos, external=True)
        with plan_lib.activate(self.plan):
            gen.send(None)
        return gen

    def inject(self, requests: List[StreamRequest]) -> None:
        """Add requests to a run in progress (multi-replica failover and
        router dispatch land here). Same caller-bug validation as run start;
        a request whose ``arrival`` is already in the past is admissible at
        the next boundary."""
        live = self._live
        if live is None:
            raise RuntimeError(
                "inject() requires a run in progress (start_gen)")
        for r in requests:
            if r.rid in live["rids"]:
                raise ValueError(
                    f"request rid {r.rid} already known to this run — rids "
                    "must be unique across the run, including re-routes")
            total = len(r.prompt) + r.max_new
            if r.max_new > 0 and total > self.cache_len:
                raise ValueError(
                    f"request {r.rid}: prompt ({len(r.prompt)}) + max_new "
                    f"({r.max_new}) exceeds cache_len ({self.cache_len})")
            if self.paged and r.max_new > 0 and dataflow.pages_for(
                    total, self.page_size) > self.num_pages:
                raise ValueError(
                    f"request {r.rid} needs "
                    f"{dataflow.pages_for(total, self.page_size)} pages, "
                    f"pool has {self.num_pages}: it can never run")
        for r in sorted(requests, key=lambda r: (r.arrival, r.rid)):
            live["rids"].add(r.rid)
            live["requests"].append(r)
            if r.max_new <= 0:
                r.done = True
                r.finished_at = r.arrival
                r.outcome = guard_mod.RequestOutcome(
                    "ok", "empty generation budget", at_step=r.arrival)
                if r.on_outcome is not None:
                    r.on_outcome(r, r.outcome)
                live["done"].append(r)
            else:
                live["pending"].append(r)
        live["pending"].sort(key=lambda r: (r.arrival, r.rid))

    def _run_gen(self, requests: List[StreamRequest], rng=None, chaos=None,
                 external: bool = False):
        rng = rng if rng is not None else jax.random.PRNGKey(0)
        g = self.guard
        inj = None
        if chaos is not None:
            inj = chaos if isinstance(chaos, chaos_mod.FaultInjector) \
                else chaos_mod.FaultInjector(chaos)
        self.last_injector = inj
        tel = self.telemetry
        if self._own_telemetry:
            tel.reset()
        tr, m = tel.tracer, tel.metrics
        slot = self.slot
        if inj is not None:
            # trace every delivered injection at the boundary it fired on
            # (the closure reads the loop's clock late-bound); the schedule
            # is seeded, so these events are same-seed deterministic too
            inj.on_inject = lambda kind, rid=-1: tr.event(
                "chaos_inject", clock, cat="chaos", slot=slot, rid=rid,
                kind=kind)
        rids = [r.rid for r in requests]
        if len(set(rids)) != len(rids):
            # block tables are keyed by rid — duplicates would silently share
            # pages and corrupt each other's KV history
            raise ValueError(f"request rids must be unique, got {rids}")
        # feasibility is arrival-independent (resume totals equal originals):
        # validate everything up front so a late infeasible request cannot
        # abort the run after other requests already finished — caller bugs
        # raise here, before any work; only runtime faults become outcomes
        for r in requests:
            total = len(r.prompt) + r.max_new
            if r.max_new > 0 and total > self.cache_len:
                raise ValueError(
                    f"request {r.rid}: prompt ({len(r.prompt)}) + max_new "
                    f"({r.max_new}) exceeds cache_len ({self.cache_len})")
            if self.paged and r.max_new > 0 and dataflow.pages_for(
                    total, self.page_size) > self.num_pages:
                raise ValueError(
                    f"request {r.rid} needs "
                    f"{dataflow.pages_for(total, self.page_size)} pages, "
                    f"pool has {self.num_pages}: it can never run")
        allreqs = list(requests)      # grows via inject() (failover re-routes)
        pending = sorted(requests, key=lambda r: (r.arrival, r.rid))
        waiting: List[StreamRequest] = []
        done: List[StreamRequest] = []
        if self.paged:
            # fresh pool per run (like the SlotAllocator below): an aborted
            # previous run must not leak its block tables into this one;
            # self.pager stays inspectable after the run (kvcache.report)
            self.pager = shard_mod.make_pool(self.plan)
        for r in [r for r in pending if r.max_new <= 0]:
            pending.remove(r)
            r.done = True
            r.finished_at = r.arrival
            r.outcome = guard_mod.RequestOutcome(
                "ok", "empty generation budget", at_step=r.arrival)
            if r.on_outcome is not None:
                r.on_outcome(r, r.outcome)
            done.append(r)
        alloc = kvcache.SlotAllocator(self.rows)
        active: Dict[int, StreamRequest] = {}        # row -> request
        # live run state, shared with inject() and harvestable by the
        # control plane after a replica death (the lists are the loop's own
        # objects, so external appends to pending are visible here)
        self._live = {"pending": pending, "waiting": waiting,
                      "active": active, "done": done, "requests": allreqs,
                      "rids": set(rids)}
        row_pos: Dict[int, int] = {}                 # row -> device pos mirror
        admit_order: List[int] = []                  # rows, oldest first
        row_rids = [-1] * self.rows
        state = self._init_state()
        K = self.cfg.num_codebooks
        T = self.sync_every
        clock = 0.0
        stall_streak = 0
        run_clock = telemetry_mod.RunClock()
        st = self.phase_stats = {
            "prefill_s": 0.0, "decode_s": 0.0, "prefill_batches": 0,
            "prefill_prompts": 0, "prefill_real_tokens": 0,
            "prefill_padded_tokens": 0, "decode_chunks": 0,
            "decode_steps": 0, "idle_steps": 0.0, "preemptions": 0,
            "attn_path": "paged" if self.paged else "contiguous",
            "kv_quant": self.kv_quant,
            "share_prefix": self.share_prefix,
            "shared_tokens_admitted": 0,   # prompt tokens served from
                                           # adopted (refcounted) pages
            "cow_copies": 0,               # shared pages materialized for
                                           # a decode append
            "peak_live_rows": 0,           # max concurrent admitted requests
            "guard_enabled": g is not None,
            "stalled_boundaries": 0,       # boundaries skipped: pool stalled
            "step_retries": 0,             # transient step faults retried
            "clamped_admissions": 0,       # max_new clamps (degrade rung 2)
            # speculative decode (ISSUE 9)
            "spec_k": self.spec_k if self.spec_on else 0,
            "spec_rounds": 0,              # draft/verify rounds dispatched
            "spec_drafted_tokens": 0,      # candidates scored by the verifier
            "spec_accepted_tokens": 0,     # candidates emitted (greedy-exact)
            "resume_fast_prompts": 0,      # suffix-only recompute resumes
            "resume_fast_tokens": 0,       # prompt tokens NOT re-prefilled
        }

        preempted_rows: List[int] = []
        just_preempted: set = set()           # rids evicted this boundary
        peak_pages: Optional[Dict] = None     # busiest-boundary pool snapshot

        def clear_preempted_flags():
            """Drop the device live flags of rows preempted since the last
            clear: zombies would keep running full forward+sampling (and in
            paged mode DMA-ing clamped/freed pages) until the row is reused.
            Must run before any admission reuses a freed row AND before
            every decode chunk."""
            nonlocal state
            if not preempted_rows:
                return
            cache, last, pos, live, budget, hist = state
            live = live.at[jnp.asarray(preempted_rows)].set(False)
            state = (cache, last, pos, live, budget, hist)
            preempted_rows.clear()

        def resolve(r: StreamRequest, status: str, reason: str = ""):
            """Terminal state: exactly one structured RequestOutcome per
            request, delivered via its on_outcome callback — never an
            exception escaping mid-batch."""
            r.done = True
            if r.finished_at is None:
                r.finished_at = clock
            r.finished_wall_s = run_clock.elapsed_s()
            r.outcome = guard_mod.RequestOutcome(
                status=status, reason=reason, at_step=clock,
                degraded=tuple(r.degraded))
            done.append(r)
            m.count(status)
            m.observe("e2e_latency_steps", r.finished_at - r.arrival)
            if r.first_token_at is not None:
                m.observe("ttft_steps", r.first_token_at - r.arrival)
            if status == "ok":
                # length/goodput hists cover completions only — shed/expired
                # partials would skew the capacity-drift comparison
                m.observe("finished_len_tokens", len(r.prompt) + len(r.out))
                m.observe("generated_tokens", len(r.out))
                m.tenant_count(r.tenant, "ok_requests")
                m.tenant_count(r.tenant, "ok_tokens", len(r.out))
            tr.event("outcome", r.finished_at, cat="request", slot=slot,
                     rid=r.rid, status=status)
            if r.on_outcome is not None:
                r.on_outcome(r, r.outcome)

        def deadline_of(r: StreamRequest) -> Optional[float]:
            ttl = r.ttl if r.ttl is not None else (
                g.default_ttl_steps if g is not None else None)
            return None if ttl is None else r.arrival + ttl

        def evict_active(row: int, status: str, reason: str):
            """Terminal eviction of a live row (expired/failed): pages and
            slot returned, device live flag scheduled for clearing, partial
            output kept on the resolved request."""
            r = active.pop(row)
            if self.paged:
                self.pager.free(r.rid)
            alloc.free(row)
            admit_order.remove(row)
            row_rids[row] = -1
            row_pos.pop(row, None)
            preempted_rows.append(row)
            resolve(r, status, reason)

        def ensure_pages(rid: int, n_tokens: int) -> bool:
            """pager.ensure behind the chaos harness: an injected failure is
            indistinguishable from genuine pressure (and allocates nothing),
            so the same preempt/stall machinery absorbs both."""
            if inj is not None and inj.ensure_fails(rid, n_tokens):
                return False
            return self.pager.ensure(rid, n_tokens)

        def preempt_latest() -> bool:
            """Free the latest-admitted row and requeue its request for
            recompute — unless its retry budget is spent, in which case it
            resolves as ``preempted_out`` (starvation bound: under sustained
            pressure the same victim would otherwise recompute-thrash
            forever). Returns False when there is nothing to preempt.
            Re-admission order is deterministic: ``waiting`` is kept sorted
            by (arrival, rid), never by insertion order under churn."""
            if len(admit_order) <= 1:
                return False
            row = admit_order.pop()               # latest admitted
            r = active.pop(row)
            self._resume_prompt(r)                # raises early for K > 1
            self.pager.free(r.rid)
            alloc.free(row)
            row_rids[row] = -1
            row_pos.pop(row, None)
            r.preemptions += 1
            st["preemptions"] += 1
            m.count("preemptions")
            tr.event("preempt", clock, cat="pool", slot=slot, rid=r.rid)
            preempted_rows.append(row)
            if g is not None and r.preemptions > g.retry_budget:
                resolve(r, "preempted_out",
                        f"preempted {r.preemptions} times — retry budget "
                        f"({g.retry_budget}) spent; {len(r.out)} generated "
                        "tokens kept")
                return True
            just_preempted.add(r.rid)
            waiting.append(r)
            waiting.sort(key=lambda w: (w.arrival, w.rid))
            return True

        def note_stall(why: str):
            """A boundary that could not reserve chunk headroom even after
            preempting everything preemptible: skip the chunk (appending
            without reserved pages would drop writes and corrupt reads) and
            advance the clock so arrivals/deadlines keep progressing. A
            streak longer than stall_budget fails the oldest resident
            request — the pool demonstrably cannot serve it."""
            nonlocal stall_streak
            st["stalled_boundaries"] += 1
            m.count("stalled_boundaries")
            tr.event("stall", clock, cat="pool", slot=slot, why=why)
            stall_streak += 1
            just_preempted.clear()
            if g is not None and stall_streak > g.stall_budget and \
                    admit_order:
                evict_active(admit_order[0], "failed",
                             f"{why}: {stall_streak} consecutive stalled "
                             f"boundaries (stall_budget {g.stall_budget})")
                stall_streak = 0

        while True:
            # ---- boundary gate: yield status, receive the next command ----
            # self-paced runs tick with no clock (internal idle-jumps);
            # externally driven runs receive the shared global clock and
            # never run ahead of it — N replicas ticked together stay in
            # deterministic lockstep on one virtual clock
            cmd, tick = yield {
                "clock": clock,
                "drained": not (pending or waiting or active),
                "active": len(active), "waiting": len(waiting),
                "pending": len(pending), "done": len(done),
                "decode_chunks": st["decode_chunks"]}
            if cmd == "stop":
                break
            if tick is not None and tick > clock:
                st["idle_steps"] += tick - clock
                clock = tick
            if not (pending or waiting or active):
                if not external:
                    break             # self-paced: nothing can arrive later
                continue              # lockstep: stay alive for inject()

            # ---- int8 degrade rung (boundary start, measured pressure) ----
            # requantizing relieves pressure BEFORE this boundary's arrivals
            # are judged for clamping/shedding, so rung 1 shadows rungs 2-3
            if "int8_kv" in self._ladder and self.paged \
                    and self.kv_quant == "fp" \
                    and self.plan.num_pages_int8 > self.num_pages:
                if self.pager.in_use / self.num_pages >= g.int8_pressure:
                    state = self._degrade_to_int8(state, clock)

            # ---- arrivals (virtual clock; idle-jump when nothing to do) ----
            while pending and pending[0].arrival <= clock + 1e-9:
                r = pending.pop(0)
                tr.event("queued", clock, cat="request", slot=slot,
                         rid=r.rid)
                m.count("requests_queued")
                if g is not None and self.paged and self._ladder:
                    # admission control at the front door: rungs 2-3 judge
                    # each arrival against measured pool pressure
                    pressure = self.pager.in_use / self.num_pages
                    if "shed" in self._ladder and pressure >= g.shed_pressure:
                        resolve(r, "shed",
                                f"pool pressure {pressure:.2f} >= shed "
                                f"threshold {g.shed_pressure:.2f} at arrival")
                        continue
                    if "clamp_max_new" in self._ladder \
                            and pressure >= g.clamp_pressure \
                            and r.max_new > g.clamp_max_new:
                        r.max_new = g.clamp_max_new
                        r.degraded.append("clamp_max_new")
                        st["clamped_admissions"] += 1
                        m.count("clamped_admissions")
                        tr.event("degrade_rung", clock, cat="degrade",
                                 slot=slot, rid=r.rid,
                                 rung="clamp_max_new")
                waiting.append(r)

            # ---- deadlines: expire whatever outlived arrival + ttl --------
            if g is not None:
                for r in list(waiting):
                    dl = deadline_of(r)
                    if dl is not None and clock + 1e-9 >= dl:
                        waiting.remove(r)
                        resolve(r, "expired",
                                f"deadline (arrival {r.arrival:g} + ttl "
                                f"{dl - r.arrival:g} steps) passed before "
                                "admission")
                for row, r in list(active.items()):
                    dl = deadline_of(r)
                    if dl is not None and clock + 1e-9 >= dl:
                        evict_active(row, "expired",
                                     f"deadline (arrival {r.arrival:g} + "
                                     f"ttl {dl - r.arrival:g} steps) passed "
                                     f"mid-generation; {len(r.out)} tokens "
                                     "kept")

            if not active and not waiting:
                if external:
                    continue      # lockstep: never idle-jump past the tick
                if not pending:
                    break
                st["idle_steps"] += pending[0].arrival - clock
                clock = pending[0].arrival
                continue

            # ---- page headroom for the active rows' next chunk ------------
            # runs BEFORE admission: live rows reserve their chunk pages
            # first, so a new request is never admitted (and batch-prefilled)
            # only to be preempted at the same boundary — that would throw
            # the prefill away and thrash under sustained pressure
            stalled = False
            span = self._chunk_span()     # T, or T*k under speculation
            if self.paged:
                for row in list(admit_order):         # oldest first
                    if row not in active:
                        continue
                    r = active[row]
                    need = min(row_pos[row] + span, self._final_len(r))
                    while row in active and not ensure_pages(r.rid, need):
                        if not preempt_latest():
                            if g is None:
                                raise RuntimeError(
                                    "page pool exhausted with nothing left "
                                    "to preempt — num_pages is too small")
                            stalled = True
                            break
                    if stalled:
                        break
                    if row in active:
                        self.pager.set_length(r.rid, row_pos[row])
            clear_preempted_flags()
            if stalled:
                note_stall("no page headroom for the next chunk")
                clock += T
                continue

            # ---- admission: arrived requests into freed rows --------------
            to_admit: List[StreamRequest] = []
            while waiting and len(to_admit) < alloc.available():
                r = waiting[0]
                if r.rid in just_preempted:
                    # evicted THIS boundary to relieve pressure — re-admitting
                    # into the pages it just freed would re-run its (growing)
                    # prefill only to preempt it again: wait one boundary.
                    # break, not skip: it keeps queue priority
                    break
                plen = self._plen(r)
                if self.paged:
                    # CoW prefix sharing: point leading table entries at
                    # resident pages already holding this prompt's prefix
                    # (refcount++); prefill will skip writes before the
                    # boundary. Roll the adoption back if the fresh-page
                    # remainder doesn't fit — all-or-nothing, like ensure.
                    r.shared_tokens = self.pager.adopt_prefix(
                        r.rid, self._resume_prompt(r)) \
                        if self.share_prefix else 0
                    if not ensure_pages(
                            r.rid, min(plen + span, self._final_len(r))):
                        if self.pager.pages_of(r.rid):
                            self.pager.free(r.rid)   # roll back adoption
                        r.shared_tokens = 0
                        break                  # page pressure: wait for frees
                    if self.share_prefix:
                        # publish this prompt's pages immediately — their
                        # content lands in this same boundary's refill, so a
                        # same-boundary arrival can already adopt the chain
                        self.pager.register_prefix(r.rid,
                                                   self._resume_prompt(r))
                waiting.pop(0)
                to_admit.append(r)
            just_preempted.clear()
            admits: List[Tuple[int, StreamRequest]] = list(
                zip(alloc.alloc_many(len(to_admit)), to_admit))
            for row, r in admits:
                admit_order.append(row)
                row_rids[row] = r.rid
                row_pos[row] = self._plen(r)
                if self.paged:
                    self.pager.set_length(r.rid, row_pos[row])
                    st["shared_tokens_admitted"] += r.shared_tokens
                if r.admitted_at is None:
                    r.admitted_at = clock
                    m.count("requests_admitted")
                    wait = clock - r.arrival
                    m.observe("admission_wait_steps", wait)
                    m.tenant_observe(r.tenant, "admission_wait_steps", wait)
                    tr.event("admitted", clock, cat="request", slot=slot,
                             rid=r.rid, shared_tokens=r.shared_tokens)
                if self.paged and r.shared_tokens:
                    m.count("shared_tokens_admitted", r.shared_tokens)
            if admits:
                # recompute-resume fast path (ISSUE 9 satellite): a preempted
                # request re-admitted while its leading pages are still
                # resident (adopt_prefix above re-pointed the table at them)
                # refills only the non-adopted suffix through the flattened
                # verifier — one dispatch over len(suffix) rows instead of a
                # full-prompt prefill tier. Partial coverage is page-aligned
                # by construction (a partial-tail index key matches only the
                # entire remainder), so the suffix starts on a fresh
                # (unshared) page and its writes need no CoW.
                fast: List[Tuple[int, StreamRequest]] = []
                if self._fast_resume:
                    fast = [(row, r) for row, r in admits
                            if r.out and 0 < r.shared_tokens < self._plen(r)
                            and r.shared_tokens % self.page_size == 0]
                    fast_rows = {row for row, _ in fast}
                    admits = [a for a in admits if a[0] not in fast_rows]
                buckets: Dict[int, List[Tuple[int, StreamRequest]]] = {}
                for row, r in admits:
                    buckets.setdefault(self.plan.tier(self._plen(r)),
                                       []).append((row, r))
                bt = self._block_table(row_rids) if self.paged else \
                    jnp.zeros((self.rows, 1), jnp.int32)
                with telemetry_mod.phase_timer(
                        st, "prefill_s", tracer=tr, name="prefill",
                        start=clock, slot=slot) as ph:
                    for row, r in fast:
                        active[row] = r
                        prompt = self._resume_prompt(r)
                        cov = r.shared_tokens
                        suffix = prompt[cov:]
                        Lp = 1 << (len(suffix) - 1).bit_length()
                        hrow = np.full((self.cache_len,), -1, np.int32)
                        hrow[:len(prompt)] = prompt
                        state = self._resume(
                            self.params, state,
                            jnp.asarray([suffix + [0] * (Lp - len(suffix))],
                                        jnp.int32),
                            jnp.asarray(cov, jnp.int32),
                            jnp.asarray(len(suffix), jnp.int32),
                            jnp.asarray(row, jnp.int32),
                            jnp.asarray(len(prompt), jnp.int32),
                            jnp.asarray(r.max_new - len(r.out), jnp.int32),
                            bt, jnp.asarray(hrow))
                        st["resume_fast_prompts"] += 1
                        st["resume_fast_tokens"] += cov
                        st["prefill_real_tokens"] += len(suffix)
                        tr.event("resume_fast", clock, cat="request",
                                 slot=slot, rid=r.rid, adopted=cov,
                                 suffix=len(suffix))
                    for tier, group in sorted(buckets.items()):
                        B = len(group)
                        toks, lengths, row_ids, budgets, starts = \
                            build_tier_batch(
                                group, tier, self._resume_prompt,
                                lambda r: r.max_new - len(r.out),
                                lambda r: r.shared_tokens)
                        for row, r in group:
                            active[row] = r
                        state = self._refill(self.params, state,
                                             jnp.asarray(toks),
                                             jnp.asarray(lengths),
                                             jnp.asarray(row_ids),
                                             jnp.asarray(budgets), bt,
                                             jnp.asarray(starts))
                        real = int(lengths.sum())
                        st["prefill_batches"] += 1
                        st["prefill_prompts"] += B
                        st["prefill_real_tokens"] += real
                        st["prefill_padded_tokens"] += B * tier
                        m.count("prefill_batches")
                        m.count("prefill_prompts", B)
                        m.count("prefill_real_tokens", real)
                        m.count("prefill_padded_tokens", B * tier)
                    ph.ready(state[1])
                    ph.note(prompts=len(admits) + len(fast),
                            tiers=len(buckets))

            if not active:
                if g is not None or inj is not None:
                    # nothing running and nothing admitted (transient chaos
                    # ensure-failures can starve admission): advance the
                    # clock so arrivals/deadlines keep progressing
                    st["idle_steps"] += T
                    clock += T
                continue
            st["peak_live_rows"] = max(st["peak_live_rows"], len(active))

            # ---- CoW guard: materialize shared pages this chunk appends to
            # (runs after admission so freshly adopted whole-prompt tails are
            # covered too; shared pages are read-only by contract)
            if self.paged and self.share_prefix:
                pairs: List[Tuple[int, int]] = []
                for row in list(admit_order):         # oldest first
                    if row not in active:
                        continue
                    r = active[row]
                    lo = row_pos[row]
                    hi = min(lo + span, self._final_len(r))
                    # re-probe after every mutation: a preemption can drop a
                    # refcount to 1 mid-loop (page no longer needs a copy)
                    while row in active:
                        shared = self.pager.shared_pages_in(r.rid, lo, hi)
                        if not shared:
                            break
                        pair = self.pager.cow_page(r.rid, shared[0])
                        if pair is None:              # no free page: pressure
                            if not preempt_latest():
                                if g is None:
                                    raise RuntimeError(
                                        "page pool exhausted during CoW "
                                        "materialization with nothing left "
                                        "to preempt — num_pages is too "
                                        "small")
                                stalled = True
                                break
                            continue
                        pairs.append(pair)
                    if stalled:
                        break
                if pairs:
                    # apply collected copies even on a stalled boundary: the
                    # allocator already repointed those tables, so the device
                    # content copy must land before anything reads the pages
                    st["cow_copies"] += len(pairs)
                    m.count("cow_copies", len(pairs))
                    tr.event("cow_copy", clock, cat="pool", slot=slot,
                             pages=len(pairs))
                    # pad to a power of two (bounded retraces); pads repeat a
                    # real pair so duplicate dsts carry identical content
                    n = 1 << (len(pairs) - 1).bit_length()
                    pairs = pairs + [pairs[0]] * (n - len(pairs))
                    src = jnp.asarray([s for s, _ in pairs], jnp.int32)
                    dst = jnp.asarray([d for _, d in pairs], jnp.int32)
                    state = self._cow(state, src, dst)
            clear_preempted_flags()       # CoW-guard preemptions, pre-chunk
            if stalled:
                note_stall("no free page for CoW materialization")
                clock += T
                continue

            if self.paged:
                # sample occupancy at the busiest point of the boundary —
                # the end-of-run snapshot is always fully drained
                s = self.pager.stats()
                if peak_pages is None or \
                        s["pages_used"] > peak_pages["pages_used"]:
                    peak_pages = s

            # ---- transient step faults (chaos): retry with backoff --------
            # injected BEFORE the device dispatch (the chunk's state arg is
            # donated — a post-dispatch replay would reuse consumed buffers)
            # and BEFORE the rng split, so retried boundaries consume no
            # randomness and survivors stay bit-identical to a clean run
            if inj is not None:
                attempt, aborted = 0, False
                while True:
                    try:
                        inj.check_step(st["decode_chunks"])
                        break
                    except chaos_mod.InjectedFault as e:
                        attempt += 1
                        st["step_retries"] += 1
                        m.count("step_retries")
                        limit = g.max_step_retries if g is not None else 3
                        if attempt > limit:
                            reason = (f"decode step failing persistently "
                                      f"({e}) — {limit} retries spent")
                            for row in list(active):
                                evict_active(row, "failed", reason)
                            for r in list(waiting) + list(pending):
                                resolve(r, "failed", reason)
                            waiting.clear()
                            pending.clear()
                            aborted = True
                            break
                        time.sleep(backoff_delay(
                            attempt, g.backoff_s if g is not None else 0.0))
                if aborted:
                    clear_preempted_flags()
                    continue

            # ---- NaN quarantine (pre-chunk): state[1] holds the logits
            # the previous chunk (or prefill) produced for each row — a
            # non-finite value there means this row's next sampled token
            # would be garbage. Sweep at the boundary, evict poisoned rows
            # BEFORE dispatching the chunk, so they emit nothing. (Chaos
            # poisons the same buffer, so injection and genuine NaNs take
            # the identical detection path. In-scan NaNs are caught one
            # boundary late — tokens of the chunk that produced them may
            # include garbage; the terminal outcome says so.)
            if inj is not None:
                prids = set(inj.nan_rids_for(st["decode_chunks"]))
                prows = [row for row, r in active.items() if r.rid in prids]
                if prows:
                    cache_c, last_c = state[0], state[1]
                    last_c = last_c.at[jnp.asarray(prows)].set(jnp.nan)
                    state = (cache_c, last_c) + state[2:]
            if g is not None and (g.nan_check or inj is not None):
                bad = jax.device_get(jnp.isnan(
                    state[1]).reshape(self.rows, -1).any(axis=1))
                for row in [int(i) for i in np.nonzero(bad)[0]
                            if int(i) in active]:
                    r = active[row]
                    evict_active(row, "failed",
                                 "non-finite logits at the sync boundary; "
                                 f"{len(r.out)} tokens kept")
                clear_preempted_flags()
                if not active:
                    st["idle_steps"] += T
                    clock += T
                    continue

            # ---------------------- device-resident decode chunk ----------
            # under speculation the chunk runs against CoW forks of each
            # row's page chain (refcount++, zero copies): draft writes land
            # in the fork's tail headroom, commit adopts the fork table
            # after the device round-trip, and any abort between simply
            # drops the refcounts — no rollback scatter (ISSUE 9)
            # fork child ids live at -2 - rid: real rids are >= 0 and -1 is
            # the empty-device-row sentinel in row_rids, so ~0 == -1 would
            # hand a dead row the fork's page table and let its flattened
            # verify writes clobber the parent's KV
            fork_rids: List[int] = []
            if self.spec_on:
                for row in list(admit_order):
                    if row in active:
                        rid = active[row].rid
                        self.pager.fork_chain(rid, -2 - rid)
                        fork_rids.append(rid)
            with telemetry_mod.phase_timer(
                    st, "decode_s", tracer=tr, name="decode_chunk",
                    start=clock, end=clock + T, slot=slot) as ph:
                rng, k = jax.random.split(rng)
                bt = self._block_table(row_rids) if self.paged else \
                    jnp.zeros((self.rows, 1), jnp.int32)
                state, toks, emits = self._chunk(self.params, state, k, bt)
                toks_h, emits_h, live_h = jax.device_get(
                    (toks, emits, state[3]))
                ph.note(rows=len(active))
            for rid in fork_rids:
                self.pager.commit_fork(rid, -2 - rid)
            self.host_syncs += 1
            st["decode_chunks"] += 1
            st["decode_steps"] += T
            m.count("decode_chunks")
            m.count("decode_steps", T)
            stall_streak = 0
            clock += T
            # window-end gauges, sampled while this chunk's rows are still
            # resident (pre-eviction) — the per-window occupancy record the
            # plan-drift detector measures against
            m.gauge("queue_pending", len(pending))
            m.gauge("queue_waiting", len(waiting))
            m.gauge("active_rows", len(active))
            if self.paged:
                self.pager.observe(m)
            m.end_window(clock, slot)
            emitted = 0
            spec = emits_h.ndim == 3          # (T, B, k) speculative chunk
            if spec:
                for t in range(emits_h.shape[0]):
                    for row, r in active.items():
                        for i in range(emits_h.shape[2]):
                            if emits_h[t, row, i]:
                                tok = int(toks_h[t, row, i])
                                r.out.append(tok)
                                emitted += 1
                                if r.first_token_at is None:
                                    r.first_token_at = clock - T + t + 1
                                if r.on_token is not None:
                                    r.on_token(r, tok)
                drafted = emits_h.shape[0] * emits_h.shape[2] * len(active)
                st["spec_rounds"] += emits_h.shape[0]
                st["spec_drafted_tokens"] += drafted
                st["spec_accepted_tokens"] += emitted
                m.count("spec_rounds", emits_h.shape[0])
                m.count("spec_drafted_tokens", drafted)
                m.count("spec_accepted_tokens", emitted)
                tr.event("spec_chunk", clock, cat="spec", slot=slot,
                         drafted=drafted, accepted=emitted)
            else:
                for t in range(emits_h.shape[0]):
                    for row, r in active.items():
                        if emits_h[t, row]:
                            tok = [int(v) for v in toks_h[t, row]] if K > 1 \
                                else int(toks_h[t, row])
                            r.out.append(tok)
                            emitted += 1
                            if r.first_token_at is None:
                                r.first_token_at = clock - T + t + 1
                            if r.on_token is not None:
                                r.on_token(r, tok)
            m.count("tokens_emitted", emitted)
            if getattr(self.plan, "sharded", False):
                # analytic collective traffic for this chunk (ISSUE 10):
                # counted under the frozen collective_* keys so drift
                # detection can compare measured all-gather bytes per token
                # against the mesh decision's model
                cc = shard_mod.chunk_collectives(self.plan, steps=T,
                                                 tokens=emitted)
                for key, val in cc.items():
                    m.count(key, val)
                if cc:
                    tr.event("collective_chunk", clock, cat="collective",
                             slot=slot, **cc)
            freed_rows: List[int] = []
            for row in list(active):
                # mirror the device pos: baseline rows advance one per scan
                # step; speculative rows advance by their accepted count
                row_pos[row] += int(emits_h[:, row, :].sum()) if spec else T
                if not live_h[row]:
                    r = active.pop(row)
                    freed_rows.append(row)
                    admit_order.remove(row)
                    row_rids[row] = -1
                    row_pos.pop(row, None)
                    if self.paged:
                        self.pager.free(r.rid)   # pages return immediately
                    resolve(r, "ok")
            alloc.free_many(freed_rows)

            if g is not None and g.audit_every_sync and self.paged:
                # debug/CI mode: the full pool invariant audit after every
                # sync window — leaks surface at the boundary that caused
                # them, not as an end-of-run mystery
                guard_mod.assert_pool_clean(self.pager, tracer=tr,
                                            clock=clock, slot=slot)
        st["total_wall_s"] = run_clock.elapsed_s()
        st["clock_steps"] = clock
        m.gauge("clock", clock)
        if g is not None:
            for r in allreqs:
                if r.outcome is None:       # unreachable by construction —
                    if not r.done:          # belt and braces for the promise
                        r.done = True       # that every request terminates
                        done.append(r)
                    r.outcome = guard_mod.RequestOutcome(
                        "failed", "run ended without a terminal state",
                        at_step=clock)
            st["outcomes"] = {k: 0 for k in guard_mod.OUTCOMES}
            for r in done:
                if r.outcome is not None:
                    st["outcomes"][r.outcome.status] += 1
        if inj is not None:
            st["chaos_injected"] = dict(inj.injected)
        if self.paged:
            st["pages"] = self.pager.stats()       # drained end state
            st["pages_peak"] = peak_pages          # busiest boundary
            if g is not None:
                # every request terminal implies a fully drained pool — the
                # leak audit is the cheap proof
                guard_mod.assert_pool_clean(self.pager, drained=True,
                                            tracer=tr, clock=clock,
                                            slot=slot)
        if self._own_telemetry or self.slot < 0:
            # Eyexam-at-runtime: diff measured occupancy/length/route
            # proxies against the plan's Decision.numbers. Fleet members
            # (slot >= 0 on a shared bundle) skip this — the ReplicaSet
            # computes drift once at finalize, over the shared registry.
            st["drift"] = tel.detect_drift(self.plan).summary()
        return done
