"""Paged KV accounting: fixed-size pages, per-request block tables,
copy-on-write prefix sharing.

The host-side half of the paged-cache contract (device side:
``models.decoding.init_paged_cache`` + ``kernels.paged_attention``). A
``PageAllocator`` owns a pool of ``num_pages`` fixed-size pages and, per
request, a **block table** — the ordered list of physical page ids holding
that request's KV history. This is the paper's CSC address vector applied to
activations-over-time: the dense ``(rows, cache_len)`` slot provisioned for
the worst case (the v1 mistake Eyeriss v2's flexible allocation fixes)
becomes exactly ``ceil(len / page_size)`` pages per live sequence, growing
on demand during decode and returned the moment the sequence finishes.

**Prefix sharing (multicast reuse).** Every page carries a refcount, and a
prefix index maps token prefixes to the physical page holding that slice of
history — the paged analogue of the paper's multicast of shared operands.
Admission walks the index (``adopt_prefix``): leading full pages whose
content matches an already-resident chain are adopted by reference
(refcount++, zero prefill writes), fresh pages are allocated only from the
first divergent token, and completed prompts register their pages for later
arrivals (``register_prefix``). Shared pages are **immutable**: the decode
write path must ask ``shared_pages_in`` before appending and materialize a
private copy (``cow_page`` — copy-on-write) for any page whose refcount
exceeds one. Pages return to the free pool only when their refcount reaches
zero, and index entries pointing at them are purged at that moment — the
refcount is the double-free guard.

Allocation is all-or-nothing (``ensure`` either covers the requested length
or changes nothing), so the scheduler can probe for page pressure and decide
preemption *before* touching device state. The allocator itself is
policy-free: it reports per-request page holdings (``pages_of``) and the
scheduler picks victims (serve/scheduler.py evicts the latest-admitted
request and requeues it for recompute).

Pop order is deterministic (lowest free page id first) so block tables — and
therefore device scatter/gather patterns — are reproducible run to run.
"""
from __future__ import annotations

from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core import dataflow


class PageAllocator:
    """Fixed-pool page allocator with per-request (rid-keyed) block tables,
    per-page refcounts, and a prefix-hash → page-chain index (CoW sharing)."""

    def __init__(self, num_pages: int, page_size: int = dataflow.PAGE_SIZE):
        assert num_pages >= 1 and page_size >= 1, (num_pages, page_size)
        self.num_pages = num_pages
        self.page_size = page_size
        self._free = list(range(num_pages - 1, -1, -1))  # pop() -> page 0 first
        self._tables: Dict[int, List[int]] = {}          # rid -> physical ids
        self._lengths: Dict[int, int] = {}               # rid -> token count
        self._refs = [0] * num_pages                     # per-page refcount
        # chained prefix index: (parent physical page, this page's token
        # slice) -> physical page. The parent id pins the whole preceding
        # prefix inductively (every page is indexed under exactly one chain
        # position), so lookup/registration stay exact AND O(len/page_size)
        # per prompt — no whole-prefix key copies. -1 is the root parent; a
        # partial-tail key carries the (< page_size) remainder slice.
        # Entries are purged when their page's refcount hits 0.
        self._prefix_index: Dict[Tuple[int, Tuple[int, ...]], int] = {}
        self._page_keys: Dict[int, List[Tuple]] = {}

    # ------------------------------------------------------------- queries
    def available(self) -> int:
        return len(self._free)

    @property
    def in_use(self) -> int:
        return self.num_pages - len(self._free)

    def pages_of(self, rid: int) -> int:
        return len(self._tables.get(rid, ()))

    def table(self, rid: int) -> List[int]:
        return list(self._tables[rid])

    def live_requests(self) -> List[int]:
        return sorted(self._tables)

    def pages_for(self, n_tokens: int) -> int:
        return dataflow.pages_for(n_tokens, self.page_size)

    def refcount(self, page: int) -> int:
        return self._refs[page]

    def snapshot(self) -> Dict:
        """Deep copy of the allocator's internal state for the pool invariant
        auditor (serve.guard.audit_pool) — queries only, never mutated."""
        return {
            "free": list(self._free),
            "refs": list(self._refs),
            "tables": {rid: list(t) for rid, t in self._tables.items()},
            "lengths": dict(self._lengths),
            "prefix_index": dict(self._prefix_index),
            "page_keys": {p: list(k) for p, k in self._page_keys.items()},
        }

    def fingerprint(self) -> Tuple:
        """A compact hashable digest of the allocation state — free-list
        head, per-page refcounts, and each live block table. Two allocators
        with equal fingerprints resolve every (rid, page index) to the same
        physical frame, which is exactly the lockstep invariant the
        mesh-sharded pool (serve.shard.ShardedPagePool) audits per window:
        comparing fingerprints is O(pages), comparing ``snapshot()`` dicts
        (which include the prefix index) is the deep/forensic variant."""
        return (tuple(self._free), tuple(self._refs),
                tuple(sorted((rid, tuple(t))
                             for rid, t in self._tables.items())),
                tuple(sorted(self._lengths.items())))

    # ----------------------------------------------------------- mutation
    def _pop_free(self) -> int:
        page = self._free.pop()
        assert self._refs[page] == 0, (page, self._refs[page])
        self._refs[page] = 1
        return page

    def _release(self, page: int) -> bool:
        """Drop one reference; return the page to the pool at refcount 0.
        Returns True when the page actually went back to the free list."""
        assert self._refs[page] >= 1, f"page {page} released at refcount 0"
        self._refs[page] -= 1
        if self._refs[page]:
            return False
        for key in self._page_keys.pop(page, ()):    # purge dangling prefixes
            if self._prefix_index.get(key) == page:
                del self._prefix_index[key]
        self._free.append(page)
        return True

    def grow(self, num_pages: int) -> int:
        """Append fresh free pages so the pool holds ``num_pages`` total —
        the allocator half of the int8 degradation rung (the device pool is
        requantized and padded along its page axis at the same moment, so
        existing physical ids 0..old-1 stay valid and every block table
        survives verbatim). Returns the number of pages added."""
        assert num_pages >= self.num_pages, (num_pages, self.num_pages)
        added = list(range(self.num_pages, num_pages))
        self._refs.extend([0] * len(added))
        self._free.extend(added)
        self._free.sort(reverse=True)         # keep lowest-first pop order
        self.num_pages = num_pages
        return len(added)

    def ensure(self, rid: int, n_tokens: int) -> bool:
        """Grow rid's block table to cover ``n_tokens``. All-or-nothing:
        returns False (and allocates nothing) under page pressure — the
        scheduler's preemption probe. Never shrinks. Capacity only: the
        *actual* token count (occupancy stats) is set_length's, so reserving
        headroom never inflates used_tokens."""
        table = self._tables.setdefault(rid, [])
        need = self.pages_for(n_tokens) - len(table)
        if need > len(self._free):
            if not table:
                del self._tables[rid]
            return False
        for _ in range(need):
            table.append(self._pop_free())
        return True

    def set_length(self, rid: int, n_tokens: int) -> None:
        """Record rid's actual token count (occupancy/fragmentation stats);
        pages must already cover it (``ensure`` first)."""
        assert self.pages_for(n_tokens) <= self.pages_of(rid), (
            rid, n_tokens, self.pages_of(rid))
        self._lengths[rid] = int(n_tokens)

    def free(self, rid: int) -> int:
        """Drop rid's reference on all of its pages. Shared pages survive
        with their other holders; pages reaching refcount 0 return to the
        pool (deterministic lowest-first pop order after churn). Returns the
        number of pages actually returned."""
        if rid not in self._tables:
            raise ValueError(f"request {rid} holds no pages")
        pages = self._tables.pop(rid)
        self._lengths.pop(rid, None)
        returned = sum(self._release(p) for p in pages)
        self._free.sort(reverse=True)
        return returned

    # ------------------------------------------------------ prefix sharing
    def match_prefix(self, tokens: Sequence[int]) -> Tuple[int, List[int]]:
        """Longest indexed chain covering ``tokens``: (n_covered, pages).

        Walks full-page keys in order; a chain hole (purged page) ends the
        match. When every full page matched AND the *whole* prompt is
        registered as a partial tail page, that page joins the chain too —
        the request then writes nothing during prefill and its first decode
        append copy-on-writes the shared tail.
        """
        ps = self.page_size
        toks = tuple(tokens)
        pages: List[int] = []
        covered, parent = 0, -1
        for j in range(1, len(toks) // ps + 1):
            page = self._prefix_index.get(
                (parent, toks[(j - 1) * ps:j * ps]))
            if page is None:
                break
            pages.append(page)
            covered = j * ps
            parent = page
        rem = len(toks) - covered
        if 0 < rem < ps and covered == (len(toks) // ps) * ps:
            page = self._prefix_index.get((parent, toks[covered:]))
            if page is not None:
                pages.append(page)
                covered = len(toks)
        return covered, pages

    def adopt_prefix(self, rid: int, tokens: Sequence[int]) -> int:
        """Point rid's leading block-table entries at the resident pages
        already holding ``tokens``' longest indexed prefix (refcount++ each).
        Must run at admission, before ``ensure`` (the table must be empty).
        Returns the number of prompt tokens covered — the prefill write
        path starts there. Roll back with ``free(rid)``.
        """
        assert not self._tables.get(rid), \
            f"adopt_prefix on a non-empty table for rid {rid}"
        covered, pages = self.match_prefix(tokens)
        if not pages:
            return 0
        for p in pages:
            self._refs[p] += 1
        self._tables[rid] = pages
        return covered

    def register_prefix(self, rid: int, tokens: Sequence[int]) -> int:
        """Index rid's prompt pages for later arrivals. Keys chain exact
        token slices through parent page ids (full pages, plus the whole
        remainder for a partial tail), so divergence at any offset simply
        stops matching — no hash collisions. First registration wins;
        re-registering an adopted chain is a no-op. Returns the number of
        new index entries."""
        ps = self.page_size
        toks = tuple(tokens)
        table = self._tables.get(rid, ())
        added, parent = 0, -1
        for j in range(1, len(toks) // ps + 1):
            added += self._index((parent, toks[(j - 1) * ps:j * ps]),
                                 table[j - 1])
            parent = table[j - 1]
        if len(toks) % ps and len(toks) // ps < len(table):
            added += self._index((parent, toks[(len(toks) // ps) * ps:]),
                                 table[len(toks) // ps])
        return added

    def _index(self, key: Tuple, page: int) -> int:
        if key in self._prefix_index:
            return 0
        self._prefix_index[key] = page
        self._page_keys.setdefault(page, []).append(key)
        return 1

    # -------------------------------------------------- generation forks
    def fork_chain(self, parent: int, child: int,
                   cow_tail: bool = False) -> Optional[Tuple[int, int]]:
        """Fork ``parent``'s page chain under ``child``: every physical page
        gains one reference and the child gets its own block-table copy — a
        speculative branch costs zero page copies. Appends into the branch
        then allocate fresh tail pages via ``ensure(child, ...)``, so the
        parent's committed history is immutable under the fork.

        ``cow_tail=True`` additionally materializes a private copy of the
        partial tail page (parent length not page-aligned), giving this
        writer its own append tail — the mode for *sibling* forks (beam /
        n-best) whose appends would otherwise collide in the shared tail. A
        single speculative fork per request skips it: its tail writes live
        beyond the parent's committed length, which length-masked reads
        never see, so abort needs no rollback scatter.

        Returns the (src, dst) physical pair to device-copy when a private
        tail was materialized, ``()`` when none was needed/requested, or
        ``None`` under page pressure (nothing changed — the same probe
        contract as ``ensure``/``cow_page``).
        """
        assert not self._tables.get(child), \
            f"fork_chain onto a non-empty table for rid {child}"
        table = self._tables[parent]
        n_tok = self._lengths.get(parent, 0)
        tail = n_tok % self.page_size
        if cow_tail and table and tail and not self._free:
            return None
        for p in table:
            self._refs[p] += 1
        self._tables[child] = list(table)
        if parent in self._lengths:
            self._lengths[child] = n_tok
        if cow_tail and table and tail:
            return self.cow_page(child, len(table) - 1)
        return ()

    def commit_fork(self, parent: int, child: int,
                    n_tokens: Optional[int] = None) -> int:
        """Accept a fork: ``parent`` adopts ``child``'s block table (shared
        prefix pages keep one reference through the child's copy — pure
        refcount bookkeeping, no page copies) and drops its own references
        on the pre-fork chain. ``n_tokens`` records the committed length.
        Returns the number of pages returned to the pool (pages the fork
        had CoW'd away from, now unreferenced)."""
        child_table = self._tables.pop(child)
        child_len = self._lengths.pop(child, None)
        old = self._tables[parent]
        self._tables[parent] = child_table
        returned = sum(self._release(p) for p in old)
        self._free.sort(reverse=True)
        if n_tokens is not None:
            self._lengths[parent] = int(n_tokens)
        elif child_len is not None:
            self._lengths[parent] = child_len
        return returned

    def abort_fork(self, child: int) -> int:
        """Reject a fork: drop one reference on every page the branch holds
        (fresh tail pages return to the pool, shared history survives with
        the parent). The parent's table/length were never touched — rollback
        is exactly this refcount drop. Returns pages returned."""
        return self.free(child)

    def shared_pages_in(self, rid: int, lo_token: int,
                        hi_token: int) -> List[int]:
        """Logical page indices of rid's table in [lo_token, hi_token) whose
        physical page is shared (refcount > 1) — the pages the decode write
        path must copy-on-write before appending."""
        table = self._tables.get(rid, ())
        lo = max(lo_token // self.page_size, 0)
        hi = min(self.pages_for(hi_token), len(table))
        return [j for j in range(lo, hi) if self._refs[table[j]] > 1]

    def cow_page(self, rid: int, logical: int) -> Optional[Tuple[int, int]]:
        """Materialize a private copy of rid's shared logical page: allocate
        a fresh page, repoint the table, drop one reference on the shared
        original. Returns (src_physical, dst_physical) for the device-side
        content copy, or None under page pressure (nothing changed — the
        scheduler's preemption probe, same contract as ``ensure``)."""
        table = self._tables[rid]
        src = table[logical]
        assert self._refs[src] > 1, \
            f"cow_page on unshared page {src} (rid {rid})"
        if not self._free:
            return None
        dst = self._pop_free()
        table[logical] = dst
        self._release(src)
        return src, dst

    # -------------------------------------------------------- device view
    def block_table_rows(self, rids: List[int], max_pages: int) -> np.ndarray:
        """(len(rids), max_pages) int32 physical-page table, -1 unallocated.

        Row order follows ``rids``; a rid without pages yields an all -1 row
        (a freed/never-admitted device row — every write drops, every read
        is skipped by the kernel's occupancy bound).
        """
        bt = np.full((len(rids), max_pages), -1, np.int32)
        for i, rid in enumerate(rids):
            pages = self._tables.get(rid, ())
            assert len(pages) <= max_pages, (rid, len(pages), max_pages)
            bt[i, :len(pages)] = pages
        return bt

    # -------------------------------------------------------------- stats
    def observe(self, metrics) -> None:
        """Publish the pool gauges into a telemetry MetricsRegistry (one
        call per sync window from the scheduler) — the per-window occupancy
        record plan-drift detection measures against."""
        used = self.in_use
        metrics.gauge("pages_used", used)
        metrics.gauge("pages_free", len(self._free))
        metrics.gauge("pool_pressure",
                      used / self.num_pages if self.num_pages else 0.0)
        metrics.gauge("shared_page_ratio",
                      sum(1 for r in self._refs if r > 1) / max(used, 1))
        metrics.gauge("resident_tokens", sum(self._lengths.values()))

    def stats(self) -> Dict[str, float]:
        used_pages = self.in_use
        used_tokens = sum(self._lengths.values())
        # fragmentation is denominated in LOGICAL page-slots (Σ block-table
        # lengths): shared pages store their tokens once physically but are
        # provisioned per holder, so the physical capacity can be smaller
        # than used_tokens under sharing — the logical view keeps the stat
        # the per-request tail-waste share in [0, 1] either way (identical
        # to the physical view when nothing is shared)
        logical_pages = sum(len(t) for t in self._tables.values())
        cap_tokens = logical_pages * self.page_size
        hist: Dict[int, int] = {}
        for r in self._refs:
            if r:
                hist[r] = hist.get(r, 0) + 1
        # multicast saving: each extra reference is one page NOT allocated
        # relative to unshared admission of the same requests
        pages_saved = sum((r - 1) for r in self._refs if r > 1)
        return {
            "page_size": self.page_size,
            "pages_total": self.num_pages,
            "pages_free": len(self._free),
            "pages_used": used_pages,
            "live_requests": len(self._tables),
            "used_tokens": used_tokens,
            # internal fragmentation: allocated-but-unoccupied share of the
            # live pages (tail-of-last-page waste); 0 when nothing is live
            "fragmentation": (1.0 - used_tokens / cap_tokens) if cap_tokens
            else 0.0,
            # ---- sharing metrics (ISSUE 4 satellite) ----
            "shared_pages": sum(1 for r in self._refs if r > 1),
            "pages_saved_sharing": pages_saved,
            "tokens_saved_sharing": pages_saved * self.page_size,
            "refcount_histogram": hist,
            "prefix_index_entries": len(self._prefix_index),
        }
