"""Paged KV accounting: fixed-size pages, per-request block tables.

The host-side half of the paged-cache contract (device side:
``models.decoding.init_paged_cache`` + ``kernels.paged_attention``). A
``PageAllocator`` owns a pool of ``num_pages`` fixed-size pages and, per
request, a **block table** — the ordered list of physical page ids holding
that request's KV history. This is the paper's CSC address vector applied to
activations-over-time: the dense ``(rows, cache_len)`` slot provisioned for
the worst case (the v1 mistake Eyeriss v2's flexible allocation fixes)
becomes exactly ``ceil(len / page_size)`` pages per live sequence, growing
on demand during decode and returned the moment the sequence finishes.

Allocation is all-or-nothing (``ensure`` either covers the requested length
or changes nothing), so the scheduler can probe for page pressure and decide
preemption *before* touching device state. The allocator itself is
policy-free: it reports per-request page holdings (``pages_of``) and the
scheduler picks victims (serve/scheduler.py evicts the latest-admitted
request and requeues it for recompute).

Pop order is deterministic (lowest free page id first) so block tables — and
therefore device scatter/gather patterns — are reproducible run to run.
"""
from __future__ import annotations

from typing import Dict, List

import numpy as np

from repro.core import dataflow


class PageAllocator:
    """Fixed-pool page allocator with per-request (rid-keyed) block tables."""

    def __init__(self, num_pages: int, page_size: int = dataflow.PAGE_SIZE):
        assert num_pages >= 1 and page_size >= 1, (num_pages, page_size)
        self.num_pages = num_pages
        self.page_size = page_size
        self._free = list(range(num_pages - 1, -1, -1))  # pop() -> page 0 first
        self._tables: Dict[int, List[int]] = {}          # rid -> physical ids
        self._lengths: Dict[int, int] = {}               # rid -> token count

    # ------------------------------------------------------------- queries
    def available(self) -> int:
        return len(self._free)

    @property
    def in_use(self) -> int:
        return self.num_pages - len(self._free)

    def pages_of(self, rid: int) -> int:
        return len(self._tables.get(rid, ()))

    def table(self, rid: int) -> List[int]:
        return list(self._tables[rid])

    def live_requests(self) -> List[int]:
        return sorted(self._tables)

    def pages_for(self, n_tokens: int) -> int:
        return dataflow.pages_for(n_tokens, self.page_size)

    # ----------------------------------------------------------- mutation
    def ensure(self, rid: int, n_tokens: int) -> bool:
        """Grow rid's block table to cover ``n_tokens``. All-or-nothing:
        returns False (and allocates nothing) under page pressure — the
        scheduler's preemption probe. Never shrinks. Capacity only: the
        *actual* token count (occupancy stats) is set_length's, so reserving
        headroom never inflates used_tokens."""
        table = self._tables.setdefault(rid, [])
        need = self.pages_for(n_tokens) - len(table)
        if need > len(self._free):
            if not table:
                del self._tables[rid]
            return False
        for _ in range(need):
            table.append(self._free.pop())
        return True

    def set_length(self, rid: int, n_tokens: int) -> None:
        """Record rid's actual token count (occupancy/fragmentation stats);
        pages must already cover it (``ensure`` first)."""
        assert self.pages_for(n_tokens) <= self.pages_of(rid), (
            rid, n_tokens, self.pages_of(rid))
        self._lengths[rid] = int(n_tokens)

    def free(self, rid: int) -> int:
        """Return all of rid's pages to the pool. Returns the page count."""
        if rid not in self._tables:
            raise ValueError(f"request {rid} holds no pages")
        pages = self._tables.pop(rid)
        self._lengths.pop(rid, None)
        # keep pop order deterministic after churn: lowest ids come back first
        self._free.extend(pages)
        self._free.sort(reverse=True)
        return len(pages)

    # -------------------------------------------------------- device view
    def block_table_rows(self, rids: List[int], max_pages: int) -> np.ndarray:
        """(len(rids), max_pages) int32 physical-page table, -1 unallocated.

        Row order follows ``rids``; a rid without pages yields an all -1 row
        (a freed/never-admitted device row — every write drops, every read
        is skipped by the kernel's occupancy bound).
        """
        bt = np.full((len(rids), max_pages), -1, np.int32)
        for i, rid in enumerate(rids):
            pages = self._tables.get(rid, ())
            assert len(pages) <= max_pages, (rid, len(pages), max_pages)
            bt[i, :len(pages)] = pages
        return bt

    # -------------------------------------------------------------- stats
    def stats(self) -> Dict[str, float]:
        used_pages = self.in_use
        used_tokens = sum(self._lengths.values())
        cap_tokens = used_pages * self.page_size
        return {
            "page_size": self.page_size,
            "pages_total": self.num_pages,
            "pages_free": len(self._free),
            "pages_used": used_pages,
            "live_requests": len(self._tables),
            "used_tokens": used_tokens,
            # internal fragmentation: allocated-but-unoccupied share of the
            # live pages (tail-of-last-page waste); 0 when nothing is live
            "fragmentation": (1.0 - used_tokens / cap_tokens) if cap_tokens
            else 0.0,
        }
