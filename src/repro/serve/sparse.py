"""BCSC-pack MLP weights so decode projections hit the sparse GEMV kernel.

The paper's batch-1 headline (Table VI: sparse MobileNet 12.6×) comes from
processing weights *in compressed form* — never expanding them — while the PE
array stays busy. The serve-path analogue (DESIGN.md §2–3): block-prune and
BCSC-encode each MLP projection **on host at load time**, store the prepared
index vectors as plain arrays inside the params pytree, and let
``models.layers.mlp`` route any packed weight through
``kernels.ops.bcsc_apply_packed`` (GEMV for decode-shaped M, GEMM otherwise).

Stacking constraint: the transformer scans over a stacked params pytree
(leading ``num_periods`` axis), so every layer's packed weight must have the
same nnzb. Layers with fewer non-zero blocks are padded with explicit zero
blocks appended to the last block-column — the same repeated-address
convention ensure_nonempty_cols uses (paper Fig. 16), so correctness is
unchanged and the pad cost is bounded by the densest layer of the stack.
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax.numpy as jnp
import numpy as np

from repro.core import sparsity as sp
from repro.kernels import ops as _ops

# MLP projection names eligible for packing (gated and plain variants).
MLP_WEIGHTS = ("wg", "wu", "wd", "w1", "w2")


# the packed-dict contract lives with its consumer (kernels.ops); re-exported
# here for serve-side callers
is_packed = _ops.is_packed


def pack_weight(w, bk: int, bn: int) -> Dict[str, jnp.ndarray]:
    """Host-side prune-free encode+prepare of one (K,N) weight.

    Returns {blocks (nnzb,bk,bn), row_ids (nnzb,), col_ids (nnzb,)} — the
    scalar-prefetch vectors fully expanded so nothing host-side remains at
    trace time (jit/scan-safe). N is NOT stored: it is re-derived from the
    config by the consumer (shapes must be static under jit).
    """
    blocks, row_ids, col_ids, _ = _ops.prepare_bcsc(
        sp.bcsc_encode(np.asarray(w), bk, bn))
    return {"blocks": jnp.asarray(blocks),
            "row_ids": jnp.asarray(row_ids),
            "col_ids": jnp.asarray(col_ids, dtype=jnp.int32)}


def pad_packed(packed: Dict[str, jnp.ndarray], nnzb: int) -> Dict[str, jnp.ndarray]:
    """Pad a packed weight to ``nnzb`` blocks with explicit zero blocks.

    Appended blocks carry the last column id (col_ids stays non-decreasing)
    and accumulate zeros — a no-op numerically, exactly like the repeated
    address entries of Fig. 16.
    """
    have = packed["blocks"].shape[0]
    if have == nnzb:
        return packed
    assert have < nnzb, (have, nnzb)
    pad = nnzb - have
    bk, bn = packed["blocks"].shape[1:]
    blocks = np.concatenate([np.asarray(packed["blocks"]),
                             np.zeros((pad, bk, bn),
                                      np.asarray(packed["blocks"]).dtype)])
    row_ids = np.concatenate([np.asarray(packed["row_ids"]),
                              np.zeros((pad,), np.int32)])
    last_col = np.asarray(packed["col_ids"])[-1]
    col_ids = np.concatenate([np.asarray(packed["col_ids"]),
                              np.full((pad,), last_col, np.int32)])
    return {"blocks": jnp.asarray(blocks), "row_ids": jnp.asarray(row_ids),
            "col_ids": jnp.asarray(col_ids)}


def _pack_stack(w_stack: np.ndarray, bk: int, bn: int) -> Dict[str, jnp.ndarray]:
    """(L,K,N) stacked weight -> packed dict with leading L axis (common nnzb)."""
    per_layer = [pack_weight(w_stack[l], bk, bn)
                 for l in range(w_stack.shape[0])]
    nnzb = max(p["blocks"].shape[0] for p in per_layer)
    per_layer = [pad_packed(p, nnzb) for p in per_layer]
    return {k: jnp.stack([p[k] for p in per_layer]) for k in per_layer[0]}


def _packable(w, bk: int, bn: int) -> bool:
    return (hasattr(w, "ndim") and w.ndim >= 2
            and w.shape[-2] % bk == 0 and w.shape[-1] % bn == 0)


def sparsify_mlp_params(params, cfg, sparsity: float = 0.0,
                        block: Tuple[int, int] = (16, 16)):
    """Block-prune (optional) + BCSC-pack every dense-MLP weight in ``params``.

    Returns (new_params, stats). sparsity == 0 packs without pruning (every
    block with a non-zero entry is kept) — used to check numerical equivalence
    against the dense path. Weights whose dims don't tile by ``block`` are
    left dense. MoE experts and attention projections are out of scope (the
    paper's Sparse-PE targets the big stationary weight streams).
    """
    bk, bn = block
    stats = {"packed": 0, "kept_blocks": 0, "total_blocks": 0}

    def pack_mat(w):
        wn = np.asarray(w, np.float32)
        if sparsity > 0:
            wn = np.asarray(sp.block_magnitude_prune(jnp.asarray(wn),
                                                     sparsity, bk, bn))
        return wn

    def convert_mlp(mlp: Dict, stacked: bool) -> Dict:
        out = dict(mlp)
        for name in MLP_WEIGHTS:
            w = mlp.get(name)
            if w is None or not _packable(w, bk, bn):
                continue
            if stacked:
                pruned = np.stack([pack_mat(np.asarray(w)[l])
                                   for l in range(w.shape[0])])
                out[name] = _pack_stack(pruned, bk, bn)
                nb = (w.shape[-2] // bk) * (w.shape[-1] // bn) * w.shape[0]
                kept = int(out[name]["blocks"].shape[0] *
                           out[name]["blocks"].shape[1])
            else:
                packed = pack_weight(pack_mat(w), bk, bn)
                out[name] = packed
                nb = (w.shape[-2] // bk) * (w.shape[-1] // bn)
                kept = int(packed["blocks"].shape[0])
            stats["packed"] += 1
            stats["kept_blocks"] += kept
            stats["total_blocks"] += nb
        return out

    def walk(tree, stacked: bool):
        if not isinstance(tree, dict):
            return tree
        out = {}
        for k, v in tree.items():
            if k == "mlp" and isinstance(v, dict):
                out[k] = convert_mlp(v, stacked)
            else:
                out[k] = walk(v, stacked)
        return out

    new_params = dict(params)
    if "blocks" in params:
        new_params["blocks"] = walk(params["blocks"], stacked=True)
    if "rem" in params:
        new_params["rem"] = walk(params["rem"], stacked=False)
    if stats["total_blocks"]:
        stats["block_density"] = stats["kept_blocks"] / stats["total_blocks"]
    return new_params, stats
