"""BCSC-pack MLP weights so decode projections hit the sparse GEMV kernel.

The paper's batch-1 headline (Table VI: sparse MobileNet 12.6×) comes from
processing weights *in compressed form* — never expanding them — while the PE
array stays busy. The serve-path analogue (DESIGN.md §2–3): block-prune and
BCSC-encode each MLP projection **on host at load time**, store the prepared
index vectors as plain arrays inside the params pytree, and let
``models.layers.mlp`` route any packed weight through
``kernels.ops.bcsc_apply_packed`` (GEMV for decode-shaped M, GEMM otherwise).

Stacking constraint: the transformer scans over a stacked params pytree
(leading ``num_periods`` axis), so every layer's packed *payload* must have
the same padded capacity. Layers with fewer non-zero blocks are padded with
explicit zero blocks whose index entries repeat the last real entry — the
paper's repeated-address convention (Fig. 16). The padding is now **ragged-
aware**: every pack carries its actual block count ``nnzb``, which the fused
megakernel (kernels/bcsc_mlp.py) scalar-prefetches to execute only the real
blocks of each layer. The two-call kernels still walk the padded capacity
(zero blocks are numeric no-ops there), which is exactly the waste the
``packing_efficiency`` stat quantifies and the fused path eliminates.

Storage dtype: blocks are stored in the serve compute dtype (bf16) at pack
time — the "keep it compressed *and* ready to stream" half of the paper's
§IV argument. The old path converted the full padded payload fp32→bf16 on
every decode step, a whole extra weight-stream pass per projection.
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax.numpy as jnp
import numpy as np

from repro.core import dataflow, sparsity as sp
from repro.kernels import ops as _ops
from repro.models.layers import COMPUTE_DTYPE

# MLP projection names eligible for packing (gated and plain variants).
MLP_WEIGHTS = ("wg", "wu", "wd", "w1", "w2")


# the packed-dict contract lives with its consumer (kernels.ops); re-exported
# here for serve-side callers
is_packed = _ops.is_packed


def packed_bytes(params) -> int:
    """Resident HBM bytes of every BCSC-packed weight in a params tree
    (payload blocks + row/col index vectors + nnzb scalars). The
    weight-stream half of the serving-memory report: decode_benchmark
    records it next to the cache-side numbers (kvcache.paged_cache_bytes).
    Returns 0 for an unpacked tree."""
    total = 0

    def walk(tree):
        nonlocal total
        if is_packed(tree):
            total += sum(v.size * v.dtype.itemsize for v in tree.values())
            return
        if isinstance(tree, dict):
            for v in tree.values():
                walk(v)

    walk(params)
    return total


def pack_weight(w, bk: int, bn: int,
                store_dtype=None) -> Dict[str, jnp.ndarray]:
    """Host-side prune-free encode+prepare of one (K,N) weight.

    Returns {blocks (nnzb,bk,bn), row_ids (nnzb,), col_ids (nnzb,),
    nnzb ()} — the scalar-prefetch vectors fully expanded so nothing
    host-side remains at trace time (jit/scan-safe). ``nnzb`` is the actual
    block count (ragged contract for the fused megakernel; padded stacks keep
    it per-layer). N is NOT stored: it is re-derived from the config by the
    consumer (shapes must be static under jit). ``store_dtype`` converts the
    payload once at pack time (serve uses bf16) instead of per decode step.
    """
    blocks, row_ids, col_ids, _ = _ops.prepare_bcsc(
        sp.bcsc_encode(np.asarray(w), bk, bn))
    blocks = jnp.asarray(blocks)
    if store_dtype is not None:
        blocks = blocks.astype(store_dtype)
    packed = {"blocks": blocks,
              "row_ids": jnp.asarray(row_ids),
              "col_ids": jnp.asarray(col_ids, dtype=jnp.int32),
              "nnzb": jnp.asarray(blocks.shape[0], jnp.int32)}
    # round the payload capacity up to the megakernel's chunked-DMA stride
    # (zero-payload pads; nnzb keeps the real count)
    return pad_packed(packed, _chunk_pad(blocks.shape[0]))


def _chunk_pad(n: int) -> int:
    # the chunk stride is a resolved ServePlan decision (core.plan owns the
    # BCSC_CHUNK constant's runtime use; dataflow fallback when packing
    # outside a plan — same value by construction)
    from repro.core import plan as _plan
    c = _plan.bcsc_chunk()
    return ((n + c - 1) // c) * c


def pad_packed(packed: Dict[str, jnp.ndarray], nnzb: int) -> Dict[str, jnp.ndarray]:
    """Pad a packed weight to ``nnzb`` payload blocks with explicit zeros.

    Appended index entries repeat the last real (row, col) pair — col_ids
    stays non-decreasing (Fig. 16's repeated-address convention) and a
    clamped index map re-fetches the already-resident block, so padded steps
    are DMA-idempotent. The zero payload accumulates nothing, so the two-call
    kernels (which walk the full padded capacity) stay numerically exact.
    ``nnzb`` keeps the *actual* count — the fused kernel's skip bound.
    """
    have = packed["blocks"].shape[0]
    if have == nnzb:
        return packed
    assert have < nnzb, (have, nnzb)
    pad = nnzb - have
    bk, bn = packed["blocks"].shape[1:]
    blocks = np.concatenate([np.asarray(packed["blocks"]),
                             np.zeros((pad, bk, bn),
                                      np.asarray(packed["blocks"]).dtype)])
    last_row = np.asarray(packed["row_ids"])[-1]
    row_ids = np.concatenate([np.asarray(packed["row_ids"]),
                              np.full((pad,), last_row, np.int32)])
    last_col = np.asarray(packed["col_ids"])[-1]
    col_ids = np.concatenate([np.asarray(packed["col_ids"]),
                              np.full((pad,), last_col, np.int32)])
    return {"blocks": jnp.asarray(blocks), "row_ids": jnp.asarray(row_ids),
            "col_ids": jnp.asarray(col_ids),
            "nnzb": packed.get("nnzb", jnp.asarray(have, jnp.int32))}


def _packable(w, bk: int, bn: int) -> bool:
    return (hasattr(w, "ndim") and w.ndim >= 2
            and w.shape[-2] % bk == 0 and w.shape[-1] % bn == 0)


def sparsify_mlp_params(params, cfg, sparsity: float = 0.0,
                        block: Tuple[int, int] = (16, 16),
                        store_dtype=COMPUTE_DTYPE):
    """Block-prune (optional) + BCSC-pack every dense-MLP weight in ``params``.

    Returns (new_params, stats). sparsity == 0 packs without pruning (every
    block with a non-zero entry is kept) — used to check numerical equivalence
    against the dense path. Weights whose dims don't tile by ``block`` are
    left dense, as are weights whose block density is too high for skipping
    to pay (core.dataflow.mlp_path's 'dense' arm, judged at the decode shape
    M=1 the packing targets). MoE experts and attention projections are out
    of scope (the paper's Sparse-PE targets the big stationary weight
    streams).

    ``stats`` reports, per packed weight, the real vs padded block counts of
    every layer and the resulting ``packing_efficiency`` (Σreal / Σpadded) —
    the fraction of two-call grid steps that do useful work. The fused
    megakernel executes only the real blocks, so 1 − efficiency is exactly
    the waste it removes.
    """
    bk, bn = block
    stats: Dict = {"packed": 0, "kept_blocks": 0, "total_blocks": 0,
                   "padded_blocks": 0, "left_dense": [], "weights": {}}

    def pack_mat(w):
        wn = np.asarray(w, np.float32)
        if sparsity > 0:
            wn = np.asarray(sp.block_magnitude_prune(jnp.asarray(wn),
                                                     sparsity, bk, bn))
        return wn

    def convert_mlp(mlp: Dict, stacked: bool) -> Dict:
        out = dict(mlp)
        for name in MLP_WEIGHTS:
            w = mlp.get(name)
            if w is None or not _packable(w, bk, bn):
                continue
            nb_layer = (w.shape[-2] // bk) * (w.shape[-1] // bn)
            if stacked:
                pruned = np.stack([pack_mat(np.asarray(w)[l])
                                   for l in range(w.shape[0])])
                per_layer = [pack_weight(pruned[l], bk, bn, store_dtype)
                             for l in range(pruned.shape[0])]
            else:
                per_layer = [pack_weight(pack_mat(w), bk, bn, store_dtype)]
            real = [int(p["nnzb"]) for p in per_layer]
            nb = nb_layer * len(per_layer)
            density = sum(real) / max(nb, 1)
            # ff/d_out for the dispatch rule: hidden width is whichever dim
            # the projection touches that isn't d_model — conservative M=1
            route = dataflow.mlp_path(1, w.shape[-1], w.shape[-2],
                                      gated=cfg.mlp_gated, density=density)
            if route == "dense":
                stats["left_dense"].append(name)
                continue
            padded = max(int(p["blocks"].shape[0]) for p in per_layer)
            if stacked:
                per_layer = [pad_packed(p, padded) for p in per_layer]
                out[name] = {k: jnp.stack([p[k] for p in per_layer])
                             for k in per_layer[0]}
            else:
                out[name] = per_layer[0]
            stats["packed"] += 1
            stats["kept_blocks"] += sum(real)
            stats["total_blocks"] += nb
            stats["padded_blocks"] += padded * len(per_layer)
            wstat = stats["weights"].setdefault(
                name, {"real": [], "padded": [], "dense_blocks": nb_layer})
            wstat["real"] += real
            wstat["padded"] += [padded] * len(per_layer)
        # pack-time prep of the megakernel's prefetched counts vector
        # ([n_gate, n_up, n_down] actual blocks; (L,3) for stacks) so the
        # serve path does zero per-call assembly
        order = ("wg", "wu", "wd") if "wg" in out else ("w1", "w2")
        if all(is_packed(out.get(n)) for n in order):
            cols = [out[order[0]]["nnzb"],
                    out[order[1]]["nnzb"] if len(order) == 3
                    else jnp.zeros_like(out[order[0]]["nnzb"]),
                    out[order[-1]]["nnzb"]]
            out["_bcsc_counts"] = jnp.stack(
                [c.astype(jnp.int32) for c in cols], axis=-1)
        return out

    def walk(tree, stacked: bool):
        if not isinstance(tree, dict):
            return tree
        out = {}
        for k, v in tree.items():
            if k == "mlp" and isinstance(v, dict):
                out[k] = convert_mlp(v, stacked)
            else:
                out[k] = walk(v, stacked)
        return out

    new_params = dict(params)
    if "blocks" in params:
        new_params["blocks"] = walk(params["blocks"], stacked=True)
    if "rem" in params:
        new_params["rem"] = walk(params["rem"], stacked=False)
    if stats["total_blocks"]:
        stats["block_density"] = stats["kept_blocks"] / stats["total_blocks"]
    for wstat in stats["weights"].values():
        wstat["packing_efficiency"] = (
            sum(wstat["real"]) / max(sum(wstat["padded"]), 1))
    if stats["padded_blocks"]:
        stats["packing_efficiency"] = (
            stats["kept_blocks"] / stats["padded_blocks"])
    return new_params, stats
