"""Serving robustness layer: outcomes, deadlines, overload control, audits.

Eyeriss v2's flexibility argument is about keeping utilization high when the
workload misbehaves; this module is the serving-side half of that claim
(ISSUE 6). Before it, a page-pool spike or a bad step surfaced as a raised
exception out of ``ContinuousBatchingScheduler`` — leaked pages, no terminal
status for in-flight streams. With a :class:`GuardConfig` attached, every
request submitted to the scheduler ends in exactly one structured
:class:`RequestOutcome`:

* ``ok``            — completed normally (EOS or budget).
* ``shed``          — refused at arrival: measured pool pressure above the
  shed threshold (admission control at the front door, never mid-flight).
* ``expired``       — its TTL/deadline passed before it finished (waiting
  requests expire un-admitted; active rows are evicted with partial output).
* ``preempted_out`` — preempted more than ``retry_budget`` times; resolving
  it beats recompute-thrashing it forever (starvation bound).
* ``failed``        — a non-transient fault: permanent step failure, NaN
  logits quarantined on its row, or a pool stall that outlived
  ``stall_budget`` boundaries.

Overload control walks the **degradation ladder** the plan authorizes
(``ServePlan.degrade``, resolved with an occupancy rationale): requantize the
page pool to int8 at the same HBM footprint (≈2× the pages), then clamp new
admissions' ``max_new``, then shed — degrade goodput gracefully instead of
raising on exhaustion.

:func:`audit_pool` is the pool invariant auditor (refcount/leak/block-table/
CoW-prefix consistency) the scheduler runs after every sync window in debug
mode (``audit_every_sync``) and the chaos suite runs in CI; it consumes
``PageAllocator.snapshot()`` and returns human-readable violations.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Tuple

OUTCOMES = ("ok", "shed", "expired", "preempted_out", "failed")


class PoolAuditError(RuntimeError):
    """A pool invariant was violated (leak, refcount drift, stale index)."""


@dataclasses.dataclass(frozen=True)
class RequestOutcome:
    """Terminal status of one request, delivered via ``on_outcome`` callbacks
    and the request's ``outcome`` field — never as an exception mid-batch.

    ``at_step`` is the scheduler's virtual clock when the request resolved;
    ``degraded`` lists the ladder rungs applied to this request (e.g.
    ``('clamp_max_new',)`` when its budget was clamped at admission).
    """
    status: str
    reason: str = ""
    at_step: float = 0.0
    degraded: Tuple[str, ...] = ()

    def __post_init__(self):
        assert self.status in OUTCOMES, self.status

    @property
    def ok(self) -> bool:
        return self.status == "ok"


@dataclasses.dataclass
class GuardConfig:
    """Robustness policy for the serving loop (scheduler ``guard=`` kwarg).

    Deadlines: ``default_ttl_steps`` (virtual decode steps from arrival)
    applies to requests without their own ``ttl``; ``None`` disables.
    ``retry_budget`` bounds recompute preemptions per request;
    ``stall_budget`` bounds consecutive boundaries the pool may stall with
    nothing left to preempt before the blocked (oldest) request fails.
    ``max_step_retries``/``backoff_s`` govern transient decode-step faults
    (exponential backoff via ``runtime.fault_tolerance.backoff_delay``).

    The pressure thresholds gate the degradation ladder against measured
    pool utilization (``PageAllocator.in_use / num_pages``); a rung only
    fires if the plan's ``degrade`` tuple authorizes it (further restricted
    by ``degrade_rungs`` when set). ``nan_check`` quarantines rows whose
    logits go non-finite; ``audit_every_sync`` runs the pool auditor after
    every sync window (debug/CI mode — raises :class:`PoolAuditError`).
    """
    default_ttl_steps: Optional[float] = None
    retry_budget: int = 8
    stall_budget: int = 8
    max_step_retries: int = 3
    backoff_s: float = 0.0
    int8_pressure: float = 0.85
    clamp_pressure: float = 0.92
    shed_pressure: float = 0.97
    clamp_max_new: int = 32
    degrade_rungs: Optional[Tuple[str, ...]] = None
    nan_check: bool = False
    audit_every_sync: bool = False


# ---------------------------------------------------------------- auditing
def audit_pool(pager, drained: bool = False, *, tracer=None,
               clock: float = 0.0, slot: int = -1) -> List[str]:
    """Check every PageAllocator invariant; return violations (empty = clean).

    Invariants audited:

    * free-list hygiene — no duplicates, ids in range, disjoint from every
      block table;
    * refcount exactness — each page's refcount equals the number of block-
      table entries referencing it (so Σ refcounts == Σ table lengths: no
      leaked and no double-held pages), and refcount 0 ⟺ on the free list;
    * block tables — no page appears twice within one table (CoW guarantees
      private append targets), recorded lengths are covered by pages;
    * prefix index — every indexed page is resident (refcount ≥ 1: purge-on-
      release worked) and the page→keys reverse map agrees with the index.

    With ``drained=True`` (end of run) additionally require the pool fully
    returned: no tables, every page free at refcount 0, empty index.

    A ``tracer`` (serve.telemetry.Tracer) records a ``pool_audit`` event
    ONLY when violations are found — clean audits leave no trace, so
    attaching a tracer never perturbs same-seed trace identity of a healthy
    run.
    """
    v: List[str] = []
    snap = pager.snapshot()
    num = pager.num_pages
    free, refs = snap["free"], snap["refs"]
    tables, lengths = snap["tables"], snap["lengths"]
    pidx, pkeys = snap["prefix_index"], snap["page_keys"]

    if len(set(free)) != len(free):
        v.append("free list contains duplicate page ids")
    for p in free:
        if not 0 <= p < num:
            v.append(f"free list id {p} out of range [0, {num})")
    held = [0] * num
    for rid, table in tables.items():
        seen = set()
        for p in table:
            if not 0 <= p < num:
                v.append(f"rid {rid}: table page {p} out of range")
                continue
            if p in seen:
                v.append(f"rid {rid}: page {p} appears twice in one "
                         "block table (CoW should have split it)")
            seen.add(p)
            held[p] += 1
    for p in range(num):
        if refs[p] != held[p]:
            v.append(f"page {p}: refcount {refs[p]} != {held[p]} block-table "
                     "references (leak or double-hold)")
    freeset = set(free)
    for p in range(num):
        if refs[p] == 0 and p not in freeset:
            v.append(f"page {p}: refcount 0 but not on the free list "
                     "(leaked page)")
        if refs[p] > 0 and p in freeset:
            v.append(f"page {p}: refcount {refs[p]} but on the free list "
                     "(double-free hazard)")
    for rid, n in lengths.items():
        if rid not in tables:
            v.append(f"rid {rid}: length recorded with no block table")
        elif pager.pages_for(n) > len(tables[rid]):
            v.append(f"rid {rid}: length {n} not covered by "
                     f"{len(tables[rid])} pages")
    for key, p in pidx.items():
        if not 0 <= p < num:
            v.append(f"prefix index entry {key!r} -> page {p} out of range")
        elif refs[p] == 0:
            v.append(f"prefix index entry -> page {p} with refcount 0 "
                     "(dangling: purge-on-release missed it)")
        elif key not in pkeys.get(p, ()):
            v.append(f"prefix key {key!r} missing from page {p}'s "
                     "reverse key list")
    for p, keys in pkeys.items():
        for key in keys:
            if pidx.get(key) != p:
                v.append(f"page {p}: stale reverse key {key!r} "
                         "(index maps it elsewhere)")
    if drained:
        if tables:
            v.append(f"drained pool still holds tables for rids "
                     f"{sorted(tables)}")
        if len(free) != num:
            v.append(f"drained pool has {len(free)}/{num} pages free")
        if any(refs):
            v.append("drained pool has nonzero refcounts: "
                     f"{[p for p in range(num) if refs[p]]}")
        if pidx:
            v.append(f"drained pool retains {len(pidx)} prefix index "
                     "entries")
    if v and tracer is not None:
        tracer.event("pool_audit", clock, cat="pool", slot=slot,
                     violations=len(v))
    return v


def assert_pool_clean(pager, drained: bool = False, *, tracer=None,
                      clock: float = 0.0, slot: int = -1) -> None:
    """Raise :class:`PoolAuditError` listing every violated invariant."""
    violations = audit_pool(pager, drained=drained, tracer=tracer,
                            clock=clock, slot=slot)
    if violations:
        raise PoolAuditError(
            f"pool audit failed ({len(violations)} violation(s)): "
            + "; ".join(violations))
