"""Mesh-sharded serving (ISSUE 10): the serve mesh + distributed page pool.

Eyeriss v2's hierarchical mesh reconfigures the NoC per data type to match
each data type's reuse; this module applies the same move at cluster
scale. The ``ServePlan``'s mesh resolution stage (``core.plan``) freezes
the parallelism — tp shards attention KV heads, ep shards the MoE expert
axis — and one ``hmmesh.Mode`` per data type:

=============  ====================  =======================================
data type      NoC mode              why
=============  ====================  =======================================
weights        BROADCAST             decode is weight-stream bound; a
                                     sharded store would re-gather onto the
                                     critical path every step
KV pages       GROUPED_MC (local)    attention is per-KV-head local: each
                                     device streams only its 1/tp slice,
                                     zero collective bytes
activations    UNICAST→all-gather    head contexts are produced as unique
                                     1/tp slices and gathered full-width —
                                     token-sized, the only per-step traffic
experts        INTERLEAVED_MC        the expert axis is a batch axis in the
                                     decode einsums; E/ep weights resident
                                     per device, combine on the gathered
                                     full-E tensor
=============  ====================  =======================================

This module owns the host side: :class:`ServeMesh` (the resolved mesh and
whether real devices back it), :class:`ShardedPagePool` (per-device
``PageAllocator``\\ s in lockstep over one distributed address space — the
block table), partition specs that subsume what ``launch/cell``'s planner
chose for the launch path, and the analytic collective accounting the
scheduler publishes under the ``collective`` trace category. The device
side — per-shard kernels and the exact concat collectives that make
sharded execution bit-identical to single-device — lives in
``sharding.tensor_parallel``. DESIGN.md §17 carries the full argument.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional

from repro.core import hmmesh
from repro.serve import paging


# -------------------------------------------------------------- serve mesh
@dataclasses.dataclass(frozen=True)
class ServeMesh:
    """The resolved serving mesh: ``tp`` × ``ep`` devices, logical by
    default. The sharded program is pure math (shard-explicit single-jit),
    so it runs — and is tested bit-identical — on any host; ``backed``
    reports whether enough real devices exist to place the shards
    (the CI mesh8 job forces 8 host devices to exercise that path)."""
    tp: int = 1
    ep: int = 1

    @classmethod
    def from_plan(cls, plan) -> "ServeMesh":
        return cls(tp=getattr(plan, "tp", 1) or 1,
                   ep=getattr(plan, "ep", 1) or 1)

    @property
    def devices(self) -> int:
        return self.tp * self.ep

    @property
    def trivial(self) -> bool:
        return self.devices == 1

    @property
    def backed(self) -> bool:
        import jax
        return jax.device_count() >= self.devices

    def device_mesh(self):
        """A ``jax.sharding.Mesh`` over axes ``("ep", "tp")`` on the first
        ``devices`` jax devices — only meaningful when :attr:`backed`."""
        import jax
        import numpy as np
        from jax.sharding import Mesh
        if not self.backed:
            raise RuntimeError(
                f"mesh tp={self.tp} ep={self.ep} needs {self.devices} "
                f"device(s), host has {jax.device_count()} — run under "
                "XLA_FLAGS=--xla_force_host_platform_device_count="
                f"{self.devices} (the CI mesh8 job) or serve logically")
        devs = np.array(jax.devices()[: self.devices]).reshape(
            self.ep, self.tp)
        return Mesh(devs, ("ep", "tp"))

    def describe(self) -> str:
        import jax
        backing = "backed" if self.backed else \
            f"logical ({jax.device_count()} host device(s))"
        return f"tp={self.tp} ep={self.ep} ({self.devices} devices, {backing})"


# --------------------------------------------------------- partition specs
def partition_specs(plan) -> Dict[str, Dict]:
    """Per-data-type placement, subsuming the ``launch/cell`` sharding
    planner into the frozen plan: the same ``hmmesh.Mode`` vocabulary
    ``core.planner``/``sharding.autoshard`` used for the launch path, now
    read off the ServePlan's mesh decisions. Each entry names the mode and
    the ``jax.sharding.PartitionSpec`` that realizes it on a
    :meth:`ServeMesh.device_mesh` (KV pools are (P, page_size, KV, D):
    head axis 2 shards over tp; expert weights are (E, d, f): expert axis
    0 shards over ep; everything else replicates)."""
    from jax.sharding import PartitionSpec as P
    tp = getattr(plan, "tp", 1) or 1
    ep = getattr(plan, "ep", 1) or 1
    return {
        "weights": {"mode": hmmesh.Mode.BROADCAST, "spec": P()},
        "kv_pages": {"mode": hmmesh.Mode.GROUPED_MC,
                     "spec": P(None, None, "tp" if tp > 1 else None, None)},
        "activations": {"mode": hmmesh.Mode.BROADCAST, "spec": P(),
                        "note": "produced UNICAST per shard, all-gathered"},
        "experts": {"mode": hmmesh.Mode.INTERLEAVED_MC,
                    "spec": P("ep" if ep > 1 else None, None, None)},
    }


# ------------------------------------------------------ sharded page pool
# PageAllocator methods that mutate allocator state: applied to every
# shard in lockstep, results asserted identical (the distributed half of
# the pool-invariant audit).
_MUTATING = ("grow", "ensure", "set_length", "free", "adopt_prefix",
             "register_prefix", "fork_chain", "commit_fork", "abort_fork",
             "cow_page")
# Read-only queries: any shard answers (metadata is replicated); shard 0
# is the canonical reader.
_READONLY = ("available", "pages_of", "table", "live_requests", "pages_for",
             "refcount", "snapshot", "fingerprint", "match_prefix",
             "shared_pages_in", "block_table_rows", "num_pages", "page_size",
             "in_use")


class ShardedPagePool:
    """``tp`` per-device :class:`~repro.serve.paging.PageAllocator`\\ s over
    ONE distributed address space.

    Page *frames* are device-local — frame ``p`` on device ``d`` stores the
    local 1/tp KV-head slice of logical page ``p`` — while the allocation
    metadata (free lists, refcounts, the chained prefix index, block
    tables) is replicated: every mutating call applies to all shards and
    must return the same result on each (asserted — lockstep is the
    invariant that makes one block-table row resolve to valid local frames
    on every device). CoW prefix sharing and the degradation ladder
    therefore run per device pool with zero cross-device coordination, and
    the scheduler uses this class exactly like a single ``PageAllocator``.
    """

    def __init__(self, num_pages: int, page_size: int, shards: int):
        assert shards >= 1, shards
        self.shards = tuple(paging.PageAllocator(num_pages, page_size)
                            for _ in range(shards))

    def __getattr__(self, name: str):
        if name.startswith("_") or name == "shards":
            raise AttributeError(name)
        if name in _MUTATING:
            def lockstep(*a, __name=name, **kw):
                results = [getattr(s, __name)(*a, **kw) for s in self.shards]
                first = results[0]
                assert all(r == first for r in results[1:]), (
                    f"sharded pool divergence in {__name}: {results} — "
                    "per-device allocators fell out of lockstep")
                return first
            return lockstep
        if name in _READONLY:
            return getattr(self.shards[0], name)
        raise AttributeError(name)

    # ----------------------------------------------------------- telemetry
    def lockstep_divergence(self) -> int:
        """Shards whose full snapshot differs from shard 0 (0 = healthy).
        Published as the ``shard_lockstep_divergence`` gauge and checked by
        the per-window pool audit."""
        fps = [s.fingerprint() for s in self.shards]
        return sum(1 for fp in fps[1:] if fp != fps[0])

    def observe(self, metrics) -> None:
        """Publish the canonical pool gauges plus the shard-tagged extras
        (max/min per-device occupancy and the lockstep divergence count)."""
        self.shards[0].observe(metrics)
        used = [s.in_use for s in self.shards]
        metrics.gauge("shard_pages_used_max", max(used))
        metrics.gauge("shard_pages_used_min", min(used))
        metrics.gauge("shard_lockstep_divergence",
                      self.lockstep_divergence())

    def stats(self) -> Dict[str, float]:
        st = self.shards[0].stats()
        st["shards"] = len(self.shards)
        st["lockstep_divergence"] = self.lockstep_divergence()
        return st


def make_pool(plan):
    """The plan's page pool: a :class:`ShardedPagePool` (one allocator per
    tp device) for sharded paged plans, else a plain PageAllocator."""
    tp = getattr(plan, "tp", 1) or 1
    if getattr(plan, "sharded", False) and plan.paged and tp > 1:
        return ShardedPagePool(plan.num_pages, plan.page_size, shards=tp)
    return paging.PageAllocator(plan.num_pages, plan.page_size)


# ------------------------------------------------- collective accounting
def chunk_collectives(plan, *, steps: int, tokens: int) -> Dict[str, int]:
    """Analytic collective traffic for one decode chunk, from the plan's
    mesh decisions: one head-context all-gather per attention layer per
    step (tp), one expert gather per MoE layer per step (ep). The
    scheduler counts these under the frozen ``collective_*`` metric keys
    and traces them in the ``collective`` category — the measurement half
    of drift detection for the mesh decision."""
    dec = {d.name: d for d in getattr(plan, "decisions", ())}
    mesh = dec.get("mesh")
    if mesh is None:
        return {}
    acts = dec.get("noc_acts")
    n_attn = int(acts.numbers.get("attn_layers", 0)) if acts else 0
    n_moe = int(dec["noc_experts"].numbers.get("moe_layers", 0)) \
        if "noc_experts" in dec else 0
    ops_per_step = (n_attn if plan.tp > 1 else 0) \
        + (n_moe if plan.ep > 1 else 0)
    per_tok = int(mesh.numbers.get("allgather_bytes_per_token", 0))
    return {"collective_ops": int(steps) * ops_per_step,
            "collective_allgather_bytes": per_tok * int(tokens)}


def per_device_kv_bytes(cfg, plan) -> int:
    """Bytes of the paged KV pool ONE tp device holds (its local 1/tp
    KV-head slice of every page frame). Both the fp payload and the int8
    per-(page, head) scales are linear in the head axis, and plan
    resolution enforced tp | num_kv_heads, so the division is exact — the
    ``sharded-pool-bytes-per-device`` perf gate checks measured bytes
    against this."""
    from repro.serve import kvcache
    if not plan.paged:
        return 0
    total = kvcache.kv_page_bytes(cfg, plan.page_size, plan.kv_quant) \
        * plan.num_pages
    return total // (plan.tp if plan.tp > 1 else 1)


def sharding_stats(cfg, plan, pool=None) -> Dict:
    """One report block for examples/bench: the resolved mesh, per-device
    pool bytes, and (when a pool is passed) live shard occupancy."""
    from repro.serve import kvcache
    mesh = ServeMesh.from_plan(plan)
    single = kvcache.kv_page_bytes(cfg, plan.page_size, plan.kv_quant) \
        * plan.num_pages if plan.paged else 0
    out = {"tp": mesh.tp, "ep": mesh.ep, "devices": mesh.devices,
           "backed": mesh.backed,
           "kv_bytes_single_device": single,
           "kv_bytes_per_device": per_device_kv_bytes(cfg, plan)}
    if isinstance(pool, ShardedPagePool):
        out["shards"] = len(pool.shards)
        out["shard_pages_used"] = [s.in_use for s in pool.shards]
        out["lockstep_divergence"] = pool.lockstep_divergence()
    return out
