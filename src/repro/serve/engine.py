"""Batched serving engine: prefill + decode steps, sampling, slot management.

``serve_step``/``prefill_step`` are the functions the dry-run lowers for the
``decode_*``/``prefill_*`` shapes. The ``DecodeEngine`` adds a host-side
continuous-batching loop (slot refill on EOS) used by examples/serve_lm.py.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import decoding, transformer as tfm


def make_serve_step(cfg) -> Callable:
    """(params, cache, tokens, pos[, cond]) -> (logits, new_cache)."""
    def serve_step(params, cache, tokens, pos, cond=None):
        return decoding.serve_step(params, cache, tokens, pos, cfg, cond=cond)
    return serve_step


def make_prefill_step(cfg, cache_len: int) -> Callable:
    def prefill_step(params, tokens, patch_embeds=None, cond=None):
        return decoding.prefill(params, tokens, cfg, cache_len,
                                patch_embeds=patch_embeds, cond=cond)
    return prefill_step


def sample_greedy(logits):
    return jnp.argmax(logits, axis=-1)


def sample_temperature(rng, logits, temperature: float = 1.0):
    if temperature <= 0:
        return sample_greedy(logits)
    return jax.random.categorical(rng, logits / temperature, axis=-1)


def make_generate_fn(cfg, num_steps: int, temperature: float = 0.0):
    """Fused prefill + N decode steps via lax.scan (one jit-able program)."""
    def generate(params, tokens, rng, patch_embeds=None, cond=None):
        B = tokens.shape[0]
        prompt_len = tokens.shape[-1] + (
            cfg.num_patches if cfg.frontend == "vision" else 0)
        cache_len = prompt_len + num_steps
        logits, cache = decoding.prefill(params, tokens, cfg, cache_len,
                                         patch_embeds=patch_embeds, cond=cond)

        def step(carry, rng_i):
            cache, last_logits, pos = carry
            nxt = sample_temperature(rng_i, last_logits[..., -1, :] if
                                     cfg.num_codebooks > 1 else
                                     last_logits[:, -1], temperature)
            if cfg.num_codebooks > 1:
                tok = nxt.reshape(B, cfg.num_codebooks, 1) if nxt.ndim > 1 \
                    else jnp.tile(nxt[:, None, None], (1, cfg.num_codebooks, 1))
            else:
                tok = nxt[:, None]
            logits, cache = decoding.serve_step(params, cache, tok, pos, cfg,
                                                cond=cond)
            return (cache, logits, pos + 1), nxt

        rngs = jax.random.split(rng, num_steps)
        (_, _, _), out_tokens = jax.lax.scan(
            step, (cache, logits, jnp.int32(prompt_len)), rngs)
        return jnp.moveaxis(out_tokens, 0, 1)  # (B, num_steps[, K])

    return generate


@dataclasses.dataclass
class Request:
    rid: int
    prompt: List[int]
    max_new: int
    out: List[int] = dataclasses.field(default_factory=list)
    done: bool = False


class DecodeEngine:
    """Host-side continuous batching over a fixed slot count.

    Slots hold independent sequences; finished slots are refilled from the
    queue between steps (cache entries are per-slot along batch dim, so refill
    is a host-side prefill of one slot batched into the running cache — here
    simplified to cohort refill, which is what fixed-shape TPU serving does).
    """

    def __init__(self, cfg, params, slots: int, cache_len: int,
                 eos_id: int = 1, temperature: float = 0.0):
        self.cfg = cfg
        self.params = params
        self.slots = slots
        self.cache_len = cache_len
        self.eos_id = eos_id
        self.temperature = temperature
        self._serve = jax.jit(make_serve_step(cfg))
        self._prefill = jax.jit(make_prefill_step(cfg, cache_len))

    def run(self, requests: List[Request], rng=None) -> List[Request]:
        rng = rng if rng is not None else jax.random.PRNGKey(0)
        queue = list(requests)
        done: List[Request] = []
        while queue:
            cohort = [queue.pop(0) for _ in range(min(self.slots, len(queue)))]
            plen = max(len(r.prompt) for r in cohort)
            toks = jnp.array([[0] * (plen - len(r.prompt)) + r.prompt
                              for r in cohort], jnp.int32)
            logits, cache = self._prefill(self.params, toks)
            pos = jnp.int32(plen)
            last = logits[:, -1]
            live = [True] * len(cohort)
            for step in range(max(r.max_new for r in cohort)):
                rng, k = jax.random.split(rng)
                nxt = sample_temperature(k, last, self.temperature)
                for i, r in enumerate(cohort):
                    if live[i] and len(r.out) < r.max_new:
                        t = int(nxt[i])
                        r.out.append(t)
                        if t == self.eos_id or len(r.out) >= r.max_new:
                            live[i] = False
                            r.done = True
                if not any(live):
                    break
                logits, cache = self._serve(self.params, cache,
                                            nxt[:, None], pos)
                last = logits[:, -1] if logits.ndim == 3 else logits[:, -1]
                pos = pos + 1
            for r in cohort:
                r.done = True
                done.append(r)
        return done
