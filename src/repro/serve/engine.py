"""Batched serving engine: prefill + decode steps, sampling, slot management.

``serve_step``/``prefill_step`` are the functions the dry-run lowers for the
``decode_*``/``prefill_*`` shapes. The ``DecodeEngine`` adds a continuous
batching loop (batched tier-bucketed refill on EOS, ISSUE 2) whose inner
decode loop is **device resident**: sampling, EOS detection and budget
accounting all run inside a ``lax.scan`` of ``sync_every`` fused steps, so
between refills there are zero per-token device→host transfers — the
utilization lever the Eyexam step model identifies for batch-1 decode (paper
Table VI; ISSUE 1). The decode state is donated to both jitted programs, so
the slot KV cache is updated in place rather than copied each chunk.
"""
from __future__ import annotations

import dataclasses
import warnings
from typing import Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import plan as plan_lib
from repro.models import decoding
from repro.serve import kvcache
from repro.serve import telemetry as telemetry_mod
from repro.serve.guard import RequestOutcome


def make_serve_step(cfg) -> Callable:
    """(params, cache, tokens, pos[, cond]) -> (logits, new_cache)."""
    def serve_step(params, cache, tokens, pos, cond=None):
        return decoding.serve_step(params, cache, tokens, pos, cfg, cond=cond)
    return serve_step


def make_prefill_step(cfg, cache_len: int) -> Callable:
    def prefill_step(params, tokens, patch_embeds=None, cond=None):
        return decoding.prefill(params, tokens, cfg, cache_len,
                                patch_embeds=patch_embeds, cond=cond)
    return prefill_step


def sample_greedy(logits):
    return jnp.argmax(logits, axis=-1)


def sample_temperature(rng, logits, temperature: float = 1.0):
    if temperature <= 0:
        return sample_greedy(logits)
    return jax.random.categorical(rng, logits / temperature, axis=-1)


def make_generate_fn(cfg, num_steps: int, temperature: float = 0.0):
    """Fused prefill + N decode steps via lax.scan (one jit-able program)."""
    def generate(params, tokens, rng, patch_embeds=None, cond=None):
        B = tokens.shape[0]
        prompt_len = tokens.shape[-1] + (
            cfg.num_patches if cfg.frontend == "vision" else 0)
        cache_len = prompt_len + num_steps
        logits, cache = decoding.prefill(params, tokens, cfg, cache_len,
                                         patch_embeds=patch_embeds, cond=cond)

        def step(carry, rng_i):
            cache, last_logits, pos = carry
            nxt = sample_temperature(rng_i, last_logits[..., -1, :] if
                                     cfg.num_codebooks > 1 else
                                     last_logits[:, -1], temperature)
            if cfg.num_codebooks > 1:
                tok = nxt.reshape(B, cfg.num_codebooks, 1) if nxt.ndim > 1 \
                    else jnp.tile(nxt[:, None, None], (1, cfg.num_codebooks, 1))
            else:
                tok = nxt[:, None]
            logits, cache = decoding.serve_step(params, cache, tok, pos, cfg,
                                                cond=cond)
            return (cache, logits, pos + 1), nxt

        rngs = jax.random.split(rng, num_steps)
        (_, _, _), out_tokens = jax.lax.scan(
            step, (cache, logits, jnp.int32(prompt_len)), rngs)
        return jnp.moveaxis(out_tokens, 0, 1)  # (B, num_steps[, K])

    return generate


@dataclasses.dataclass
class Request:
    rid: int
    prompt: List[int]
    max_new: int
    out: List[int] = dataclasses.field(default_factory=list)
    done: bool = False
    outcome: Optional[RequestOutcome] = None


def length_tier(plen: int, recurrent: bool, cache_len: int = 0) -> int:
    """Length bucket for batched prefill: next power of two (attention archs
    — causality makes right-padding exact); exact length for recurrent archs
    (pads would pollute ssm/rglru carried state). Clamped to ``cache_len``
    when given: the padded tier must fit the cache rows prefill builds
    (plen itself is validated ≤ cache_len by the callers, and right-padding
    stays exact at any tier ≥ plen). Shared by DecodeEngine and
    serve.scheduler."""
    if recurrent:
        return plen
    tier = 1 << max(plen - 1, 0).bit_length()
    return min(tier, cache_len) if cache_len else tier


def make_decode_step(cfg, temperature: float, eos_id: int) -> Callable:
    """One fused decode step: sample → EOS/budget masks → serve_step.

    The single source of the sampling/EOS/budget semantics, shared by
    DecodeEngine's chunk and the scheduler's paged chunk (which passes a
    ``block_table``) — the two loops cannot drift apart.
    """
    K = cfg.num_codebooks

    def step(params, carry, rng_i, block_table=None):
        cache, last, pos, live, budget = carry
        # ``last`` is (B,V) for LMs, (B,K,V) for multi-codebook (musicgen) —
        # sample_temperature reduces the trailing axis either way; the first
        # codebook carries EOS.
        nxt = sample_temperature(rng_i, last, temperature)
        head = nxt[:, 0] if K > 1 else nxt
        emit = live                          # emitted this step
        budget = budget - emit.astype(jnp.int32)
        live = live & (head != eos_id) & (budget > 0)
        tok = nxt[..., None]                 # (B,1) or (B,K,1)
        logits, cache = decoding.serve_step(params, cache, tok, pos, cfg,
                                            block_table=block_table)
        last = logits[:, -1]                 # (B,V) or (B,K,V)
        return (cache, last, pos + 1, live, budget), (nxt, emit)

    return step


def ngram_successor(hist, pos, tok):
    """Self-drafting bigram lookup (ISSUE 9): for each row, the token that
    followed the most recent earlier occurrence of ``tok`` in that row's
    history, falling back to ``tok`` itself (repeat) when it never occurred.

    ``hist`` (B, H) holds the row's token stream by absolute position
    (positions >= ``pos`` are garbage from rejected drafts — masked here);
    ``pos`` (B,) is the valid history length. Only the successor position
    ``j + 1 < pos`` may be read, so the draft is a pure function of the
    committed stream — acceptance rate is a quality knob, never a
    correctness one.
    """
    H = hist.shape[1]
    idx = jnp.arange(H, dtype=jnp.int32)
    match = (hist == tok[:, None]) & (idx[None, :] + 1 < pos[:, None])
    j = jnp.where(match, idx[None, :], -1).max(axis=1)        # most recent
    nxt = jnp.take_along_axis(hist, jnp.clip(j + 1, 0, H - 1)[:, None],
                              axis=1)[:, 0]
    return jnp.where(j >= 0, nxt, tok)


def make_spec_decode_step(cfg, eos_id: int, k: int) -> Callable:
    """One fused speculative round: draft k candidates → ONE k-position
    verify dispatch → accept the matched prefix (ISSUE 9). Greedy only —
    the scheduler gates speculation on temperature <= 0 (and the plan on
    fp paged pools), which is what makes the accepted stream bit-identical
    to ``make_decode_step``'s: candidate 0 IS the baseline's argmax over
    ``last``, and candidate i+1 is emitted only when it equals the
    verifier's argmax after candidates 0..i — every emitted token is
    exactly the token sequential greedy decode would have produced.

    Carry adds a ``hist`` (B, H) token-history buffer (absolute-position
    indexed, seeded from the prompt at refill) that feeds the bigram
    self-draft; rejected candidates past the accepted prefix leave garbage
    beyond ``pos``, which both the drafter and the paged attention reads
    mask by length — no rollback scatter, host-side fork refcounts
    (paging.fork_chain/commit_fork/abort_fork) are the only cleanup.

    Per round a row emits n ∈ [1, k] tokens (0 when dead): the accepted
    prefix clamped by EOS and remaining budget; ``pos`` advances by n and
    ``last`` becomes the verifier logits after the last emitted token —
    the all-accepted case hands next round its bonus argmax for free.
    Emits (toks (B, k), emit_mask (B, k)) per scan step.
    """
    K = cfg.num_codebooks
    assert K == 1, "speculative decode is single-codebook only"
    assert k >= 2, f"spec k must be >= 2, got {k}"

    def step(params, carry, rng_i, block_table=None):
        del rng_i                              # greedy: sampling is argmax
        cache, last, pos, live, budget, hist = carry
        B = last.shape[0]
        t0 = jnp.argmax(last, axis=-1).astype(jnp.int32)      # (B,)
        cands = [t0]
        for _ in range(k - 1):
            cands.append(ngram_successor(hist, pos, cands[-1]))
        v = jnp.stack(cands, axis=1)                          # (B, k)
        logits, cache = decoding.verify_step(params, cache, v, pos, cfg,
                                             block_table=block_table)
        g = jnp.argmax(logits, axis=-1).astype(jnp.int32)     # (B, k)
        # accept-prefix: candidate 0 is the true argmax by construction;
        # candidate i (i >= 1) survives iff it equals the verifier's argmax
        # after candidates 0..i-1 AND everything before it survived
        ok = jnp.concatenate(
            [jnp.ones((B, 1), bool), v[:, 1:] == g[:, :-1]], axis=1)
        acc = jnp.cumprod(ok.astype(jnp.int32), axis=1).astype(bool)
        # emission clamps, identical semantics to make_decode_step unrolled:
        # stop at the first emitted EOS, never exceed the remaining budget
        not_eos = v != eos_id
        no_prior_eos = jnp.concatenate(
            [jnp.ones((B, 1), bool),
             jnp.cumprod(not_eos[:, :-1].astype(jnp.int32),
                         axis=1).astype(bool)], axis=1)
        steps_i = jnp.arange(k, dtype=jnp.int32)[None, :]
        emit = live[:, None] & acc & no_prior_eos & (budget[:, None] > steps_i)
        n = emit.sum(axis=1).astype(jnp.int32)                # (B,)
        budget = budget - n
        hit_eos = jnp.any(emit & ~not_eos, axis=1)
        new_live = live & ~hit_eos & (budget > 0)
        # next round's sampling distribution: verifier logits after the last
        # emitted token (dead rows keep their last unchanged, n == 0 there)
        sel = jnp.clip(n - 1, 0, k - 1)
        picked = jnp.take_along_axis(logits, sel[:, None, None],
                                     axis=1)[:, 0]
        last = jnp.where(live[:, None], picked, last)
        # history append: write all k candidates at pos..pos+k-1 — the
        # rejected tail beyond pos+n is overwritten by the next round and
        # masked by ngram_successor/verify reads until then
        H = hist.shape[1]
        posk = pos[:, None] + steps_i
        posk = jnp.where((posk < H) & live[:, None], posk, H)
        hist = hist.at[jnp.arange(B)[:, None], posk].set(v, mode="drop")
        return (cache, last, pos + n, new_live, budget, hist), (v, emit)

    return step


def build_tier_batch(group, tier: int, prompt_of: Callable,
                     budget_of: Callable, start_of: Callable = None):
    """Host-side arrays for one admission tier: (toks, lengths, slots,
    budgets, starts). ``group`` is [(slot, request), ...];
    ``prompt_of``/``budget_of`` extract the (possibly resume-extended)
    prompt and remaining budget; ``start_of`` the first prompt token the
    prefill actually writes (> 0 when a shared-prefix chain already holds
    the leading pages — the scheduler's CoW admission; default 0, write
    everything). Shared by DecodeEngine.run and the scheduler's admission."""
    B = len(group)
    toks = np.zeros((B, tier), np.int32)
    lengths = np.empty((B,), np.int32)
    slot_ids = np.empty((B,), np.int32)
    budgets = np.empty((B,), np.int32)
    starts = np.zeros((B,), np.int32)
    for i, (slot, r) in enumerate(group):
        p = prompt_of(r)
        toks[i, :len(p)] = p
        lengths[i] = len(p)
        slot_ids[i] = slot
        budgets[i] = budget_of(r)
        if start_of is not None:
            starts[i] = start_of(r)
    return toks, lengths, slot_ids, budgets, starts


class DecodeEngine:
    """Continuous batching over a fixed slot count, device-resident decode.

    Slots hold independent sequences with **per-slot positions** (the
    vector-pos path of decoding.serve_step). Admission is **chunked batched
    prefill** (ISSUE 2): pending prompts are bucketed into padded length
    tiers (next power of two; exact lengths for recurrent archs, where pad
    tokens would pollute the carried state), each tier is prefilled as ONE
    batch through ``decoding.prefill_batched``, and the resulting cache rows
    are scattered into their slots — admission cost amortizes over the
    cohort the same way decode already does, instead of one batch-1 prefill
    per slot. Between refills the loop never leaves the device: ``sync_every``
    decode steps — on-device sampling, EOS live-mask and max_new budget
    tracking — run as one ``lax.scan`` (same structure as make_generate_fn),
    and the generated token block is fetched with a single ``jax.device_get``
    per chunk. ``host_syncs`` counts those fetches; there are zero per-token
    transfers. The decode-state argument of both jitted programs is donated,
    so the KV cache updates in place instead of being copied every chunk.

    ``phase_stats`` (reset per run) reports the prefill/decode wall-clock
    split, batch counts, and real-vs-padded prefill token counts — the
    admission-amortization evidence benchmarks/sparse_decode.py records.

    Construction is plan-driven (ISSUE 5): pass a ``core.plan.ServePlan``
    (``plan_for_engine`` for explicit slots/cache_len) and the engine reads
    slots, cache_len, sync cadence, and the prefill tier ladder from it,
    activating the plan around its jitted programs so the MLP/matmul kernel
    routes come from the same resolved crossovers. The legacy
    ``slots=…, cache_len=…`` kwargs remain as a deprecated shim building
    the identical single-decision plan.
    """

    def __init__(self, cfg, params, plan: Optional[plan_lib.ServePlan] = None,
                 *, slots: Optional[int] = None,
                 cache_len: Optional[int] = None,
                 eos_id: int = 1, temperature: float = 0.0,
                 sync_every: Optional[int] = None,
                 telemetry: Optional["telemetry_mod.Telemetry"] = None):
        if plan is not None and not (slots is None and cache_len is None):
            # a plan plus legacy geometry kwargs would silently lose the
            # kwargs (the plan wins) — refuse instead of surprising the
            # caller mid-migration; sync_every alone stays an honored
            # per-engine override
            raise TypeError(
                "pass either plan= or the legacy slots=/cache_len= kwargs, "
                "not both (the plan already fixes the geometry)")
        if plan is None:
            # legacy kwarg construction: build the single-decision plan the
            # old inline dispatch amounted to (same core.dataflow rules, so
            # behavior is bit-identical — tests/test_plan.py asserts it)
            if slots is None or cache_len is None:
                raise TypeError(
                    "DecodeEngine needs a ServePlan (core.plan.plan_serve / "
                    "plan_for_engine) or the legacy slots=/cache_len= kwargs")
            warnings.warn(
                "constructing DecodeEngine from slots=/cache_len= kwargs is "
                "deprecated — pass plan=core.plan.plan_for_engine(...) or "
                "serve through repro.serve.LLM",
                DeprecationWarning, stacklevel=2)
            if slots < 1:
                # kvcache.max_slots returns 0 when one slot alone exceeds
                # the HBM budget — refuse here instead of letting the
                # zero-row cache OOM or produce empty batches downstream
                raise ValueError(
                    f"slots must be >= 1, got {slots}: a (1, {cache_len}) "
                    "cache slot does not fit the HBM budget "
                    "(kvcache.max_slots == 0) — shrink cache_len, shard "
                    "over more chips, or raise the budget fraction")
            plan = plan_lib.plan_for_engine(
                cfg, slots=slots, cache_len=cache_len,
                sync_every=8 if sync_every is None else sync_every)
        if plan.rows < 1:
            raise ValueError(
                f"slots must be >= 1, got {plan.rows}: a "
                f"(1, {plan.cache_len}) cache slot does not fit the HBM "
                "budget (kvcache.max_slots == 0) — shrink cache_len, shard "
                "over more chips, or raise the budget fraction")
        self.cfg = cfg
        self.params = params
        self.plan = plan
        self.slots = plan.rows
        self.cache_len = plan.cache_len
        self.eos_id = eos_id
        self.temperature = temperature
        self.sync_every = max(1, plan.sync_every if sync_every is None
                              else sync_every)
        self.host_syncs = 0                  # device->host fetches (per chunk)
        # mirror of plan.prefill_exact (tests introspect it; tier dispatch
        # itself goes through plan.tier)
        self._recurrent = plan.prefill_exact
        self.phase_stats: Dict = {}
        # observability (serve.telemetry): the drain engine has no arrival
        # clock, so its spans sit on a synthetic one (decode_chunks * T)
        self.telemetry = telemetry if telemetry is not None \
            else telemetry_mod.Telemetry()
        self._own_telemetry = telemetry is None
        # the decode state (arg 1: cache + sampling state) is donated — the
        # cache buffer is updated in place step over step, never copied
        self._chunk = jax.jit(self._make_chunk_fn(), donate_argnums=(1,))
        self._refill = jax.jit(self._make_refill_fn(), donate_argnums=(1,))

    # ------------------------------------------------------ device programs
    def _make_refill_fn(self) -> Callable:
        """Batched prefill of one length tier, scattered into its slots.

        toks (B, tier) right-padded; lengths/slots/max_new (B,). One jit per
        (tier, B) shape pair — tiers are powers of two, so the trace count
        stays logarithmic in prompt-length spread.
        """
        cfg, cache_len = self.cfg, self.cache_len

        def refill(params, state, toks, lengths, slots, max_new):
            cache, last, pos, live, budget = state
            logits, row_cache = decoding.prefill_batched(
                params, toks, lengths, cfg, cache_len)
            plen = lengths + (cfg.num_patches
                              if cfg.frontend == "vision" else 0)
            new_cache = {}
            if "blocks" in cache:    # stacked entries: (nper, B, ...) — axis 1
                new_cache["blocks"] = jax.tree.map(
                    lambda c, s: c.at[:, slots].set(s.astype(c.dtype)),
                    cache["blocks"], row_cache["blocks"])
            if "rem" in cache:       # unstacked entries: (B, ...) — axis 0
                new_cache["rem"] = jax.tree.map(
                    lambda c, s: c.at[slots].set(s.astype(c.dtype)),
                    cache["rem"], row_cache["rem"])
            last = last.at[slots].set(logits[:, -1].astype(last.dtype))
            pos = pos.at[slots].set(plen)
            live = live.at[slots].set(True)
            budget = budget.at[slots].set(max_new)
            return (new_cache, last, pos, live, budget)

        return refill

    def _tier(self, plen: int) -> int:
        # the plan's resolved tier ladder (== length_tier by construction)
        return self.plan.tier(plen)

    def _make_chunk_fn(self) -> Callable:
        """sync_every fused decode steps: sample → track EOS/budget → step."""
        T = self.sync_every
        step = make_decode_step(self.cfg, self.temperature, self.eos_id)

        def chunk(params, state, rng):
            rngs = jax.random.split(rng, T)
            state, (toks, emits) = jax.lax.scan(
                lambda carry, rng_i: step(params, carry, rng_i), state, rngs)
            return state, toks, emits

        return chunk

    # -------------------------------------------------------------- host loop
    def _init_state(self):
        cfg = self.cfg
        cache = decoding.init_cache(cfg, self.slots, self.cache_len)
        vshape = (self.slots, cfg.num_codebooks, cfg.vocab_padded) \
            if cfg.num_codebooks > 1 else (self.slots, cfg.vocab_padded)
        last = jnp.zeros(vshape, jnp.float32)
        pos = jnp.zeros((self.slots,), jnp.int32)
        live = jnp.zeros((self.slots,), jnp.bool_)
        budget = jnp.zeros((self.slots,), jnp.int32)
        return (cache, last, pos, live, budget)

    def run(self, requests: List[Request], rng=None) -> List[Request]:
        # the plan is the dispatch source for everything traced below
        # (layers.mlp / kernels.ops read it instead of re-deriving rules)
        with plan_lib.activate(self.plan):
            return self._run(requests, rng)

    def _run(self, requests: List[Request], rng=None) -> List[Request]:
        rng = rng if rng is not None else jax.random.PRNGKey(0)
        queue = list(requests)
        done: List[Request] = []
        for r in [r for r in queue if r.max_new <= 0]:
            queue.remove(r)
            r.done = True
            r.outcome = RequestOutcome("ok", "empty generation budget")
            done.append(r)
        alloc = kvcache.SlotAllocator(self.slots)
        active: Dict[int, Request] = {}
        state = self._init_state()
        K = self.cfg.num_codebooks
        st = self.phase_stats = {
            "prefill_s": 0.0, "decode_s": 0.0, "prefill_batches": 0,
            "prefill_prompts": 0, "prefill_real_tokens": 0,
            "prefill_padded_tokens": 0, "decode_chunks": 0,
        }
        if self._own_telemetry:
            self.telemetry.reset()
        tr = self.telemetry.tracer
        T = self.sync_every

        while queue or active:
            # ---- admission: batched prefill, one call per length tier ----
            admits: List[Tuple[int, Request]] = []
            while queue and alloc.available():
                r = queue[0]
                plen = len(r.prompt) + (self.cfg.num_patches
                                        if self.cfg.frontend == "vision" else 0)
                if plen + r.max_new > self.cache_len:
                    # global-attention slots would silently wrap/clobber the
                    # last cache row past cache_len — refuse loudly instead
                    raise ValueError(
                        f"request {r.rid}: prompt ({plen}) + max_new "
                        f"({r.max_new}) exceeds cache_len ({self.cache_len})")
                queue.pop(0)
                admits.append((alloc.alloc(), r))
            if admits:
                buckets: Dict[int, List[Tuple[int, Request]]] = {}
                for slot, r in admits:
                    buckets.setdefault(self._tier(len(r.prompt)),
                                       []).append((slot, r))
                with telemetry_mod.phase_timer(
                        st, "prefill_s", tracer=tr, name="prefill",
                        start=st["decode_chunks"] * T) as ph:
                    for tier, group in sorted(buckets.items()):
                        B = len(group)
                        toks, lengths, slot_ids, max_news, _ = \
                            build_tier_batch(
                                group, tier, lambda r: r.prompt,
                                lambda r: r.max_new)
                        for slot, r in group:
                            active[slot] = r
                        state = self._refill(self.params, state,
                                             jnp.asarray(toks),
                                             jnp.asarray(lengths),
                                             jnp.asarray(slot_ids),
                                             jnp.asarray(max_news))
                        st["prefill_batches"] += 1
                        st["prefill_prompts"] += B
                        st["prefill_real_tokens"] += int(lengths.sum())
                        st["prefill_padded_tokens"] += B * tier
                    ph.ready(state[1])          # phase-accurate timing
                    ph.note(prompts=len(admits), tiers=len(buckets))

            # ---------------------- device-resident decode chunk ----------
            with telemetry_mod.phase_timer(
                    st, "decode_s", tracer=tr, name="decode_chunk",
                    start=st["decode_chunks"] * T,
                    end=(st["decode_chunks"] + 1) * T) as ph:
                rng, k = jax.random.split(rng)
                state, toks, emits = self._chunk(self.params, state, k)
                # the single device->host transfer for this chunk
                toks_h, emits_h, live_h = jax.device_get(
                    (toks, emits, state[3]))
                ph.note(rows=len(active))
            self.host_syncs += 1
            st["decode_chunks"] += 1
            for t in range(emits_h.shape[0]):
                for slot, r in active.items():
                    if emits_h[t, slot]:
                        r.out.append([int(v) for v in toks_h[t, slot]]
                                     if K > 1 else int(toks_h[t, slot]))
            for slot in list(active):
                if not live_h[slot]:
                    r = active.pop(slot)
                    r.done = True
                    r.outcome = RequestOutcome("ok")
                    done.append(r)
                    alloc.free(slot)
        return done
