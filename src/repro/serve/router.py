"""Request placement across replicas: prefix affinity, load, fairness.

The multi-replica control plane (serve/replica.py) holds N independent
``ContinuousBatchingScheduler`` instances, each with its own page pool and
copy-on-write prefix index. Placement therefore decides more than load
balance: a request landing on the replica that already holds its prompt
prefix adopts those pages (refcount++, prefill skips the shared tokens),
while the same request on any other replica re-prefills and re-stores the
identical KV. The router encodes that locality:

* **Prefix affinity** — requests are keyed by their first KV page worth of
  prompt tokens (the allocator's prefix index is page-granular, so anything
  shorter can never be adopted). The first request of a key claims a home
  replica; followers with the same key go home too — unless home's measured
  queue depth has fallen ``max_depth_imbalance`` behind the least-loaded
  replica, at which point load wins (affinity is a heuristic, starvation is
  not acceptable).
* **Queue depth** — the fallback (and tiebreak) is the replica with the
  fewest resident requests (pending + waiting + active, measured from the
  scheduler's live state, including placements made earlier in the same
  window), lowest slot id on ties so placement is deterministic.
* **Tenant fairness** — same-window arrivals are dispatched in per-tenant
  round-robin order (stable (arrival, rid) within a tenant): one tenant's
  burst cannot occupy every row ahead of another tenant's single request
  that arrived the same window.

The router is deliberately stateless about replica health: the supervisor
calls :meth:`forget_replica` on failover and the affinity map drops every
claim on the dead replica (its pool — and thus every adoptable page — is
gone, so affinity would route to a cold replacement for no sharing win).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

DEFAULT_TENANT = ""


@dataclasses.dataclass
class RouterConfig:
    """Placement policy knobs.

    ``affinity`` switches prefix-affinity routing (off: pure least-depth);
    ``max_depth_imbalance`` is how many requests deeper than the least
    loaded replica the affinity home may run before load balancing
    overrides the sharing win.
    """
    affinity: bool = True
    max_depth_imbalance: int = 4


class Router:
    """Places requests onto live replicas; owns the prefix→home map.

    ``page_size`` must match the replicas' plan (the affinity key is one KV
    page of prompt — the unit the CoW prefix index can actually share).
    """

    def __init__(self, cfg: Optional[RouterConfig] = None, *,
                 page_size: int = 0):
        self.cfg = cfg or RouterConfig()
        self.page_size = page_size
        self._home: Dict[Tuple[int, ...], int] = {}   # prefix key -> slot
        self.stats = {"placements": 0, "affinity_hits": 0,
                      "affinity_overridden": 0, "forgotten_keys": 0}

    # ----------------------------------------------------------- affinity
    def prefix_key(self, prompt: Sequence[int]) -> Optional[Tuple[int, ...]]:
        """First full KV page of the prompt, or None when the prompt is
        shorter than one page (nothing page-granular to share)."""
        if not self.cfg.affinity or self.page_size <= 0 \
                or len(prompt) < self.page_size:
            return None
        return tuple(int(t) for t in prompt[: self.page_size])

    def forget_replica(self, slot: int) -> int:
        """Drop every affinity claim on a dead replica (its pool is gone).
        Returns the number of keys released."""
        dead = [k for k, s in self._home.items() if s == slot]
        for k in dead:
            del self._home[k]
        self.stats["forgotten_keys"] += len(dead)
        return len(dead)

    # ---------------------------------------------------------- placement
    def place(self, request, replicas: List) -> object:
        """Pick the replica for ``request`` among live ``replicas`` (each
        exposing ``.slot`` and ``.queue_depth()``). Deterministic: depth
        ties break on slot id, and the affinity map mutates in placement
        order."""
        if not replicas:
            raise RuntimeError("router: no live replicas to place onto")
        by_slot = {rep.slot: rep for rep in replicas}
        depths = {rep.slot: rep.queue_depth() for rep in replicas}
        least = min(replicas, key=lambda rep: (depths[rep.slot], rep.slot))
        chosen = least
        key = self.prefix_key(request.prompt)
        if key is not None:
            home = self._home.get(key)
            if home is not None and home in by_slot:
                if depths[home] <= depths[least.slot] \
                        + self.cfg.max_depth_imbalance:
                    chosen = by_slot[home]
                    self.stats["affinity_hits"] += 1
                else:
                    self.stats["affinity_overridden"] += 1
            self._home[key] = chosen.slot
        self.stats["placements"] += 1
        return chosen

    # ----------------------------------------------------------- fairness
    @staticmethod
    def fair_order(requests: Sequence) -> List:
        """Per-tenant round-robin dispatch order for one admission window.

        Within a tenant, requests keep strict (arrival, rid) order; across
        tenants, one request per tenant is taken per round, tenants ordered
        by their earliest (arrival, rid) — deterministic, and a 50-request
        burst from tenant A interleaves 1:1 with tenant B's requests
        instead of monopolizing every free row first.
        """
        queues: Dict[str, List] = {}
        for r in sorted(requests, key=lambda r: (r.arrival, r.rid)):
            queues.setdefault(r.tenant or DEFAULT_TENANT, []).append(r)
        order = sorted(queues,
                       key=lambda t: (queues[t][0].arrival, queues[t][0].rid))
        out: List = []
        while any(queues.values()):
            for t in order:
                if queues[t]:
                    out.append(queues[t].pop(0))
        return out
