"""Eyexam at runtime: step-clock tracing, metrics registry, plan drift
(ISSUE 8).

``ServePlan.explain()`` is the *plan-time* Eyexam report: every dispatch
decision with the roofline numbers it was resolved from. This module is the
runtime half — it records what actually happened on the scheduler's virtual
step clock and diffs it against what the plan predicted. Three pieces, all
stdlib-only (zero third-party dependencies) and deterministic by
construction:

* :class:`Tracer` — typed spans/events keyed by ``(clock, replica_slot,
  rid)``. The *structure* of a trace (names, categories, virtual-clock
  stamps, args) is a pure function of the seed: wall-clock durations are
  attached as **annotations** (the ``wall_s`` field, stripped by
  :meth:`Tracer.signature` / ``to_chrome_trace(strip_wall=True)``), so two
  same-seed runs — including chaos runs — produce byte-identical traces
  once the annotations are dropped. ``to_chrome_trace()`` exports Chrome
  ``trace_event`` JSON (load it at https://ui.perfetto.dev): one virtual
  step renders as 1 ms, replicas as processes, requests as threads.
* :class:`MetricsRegistry` — counters/gauges/histograms over a **frozen,
  documented key set** (:data:`METRIC_KEYS`): registering an undeclared
  name raises, so a metric cannot be added or dropped silently. Gauges are
  snapshotted per sync window (``end_window``) — the per-window history is
  the measurement side of drift detection — and :meth:`MetricsRegistry.
  snapshot` renders everything into one frozen :class:`MetricsSnapshot`.
* :func:`detect_drift` — compares measured proxies (mean resident tokens
  per row, finished lengths, per-step HBM-byte estimate, tier-pad waste,
  the fused-vs-two-call route at the *measured* decode width, forced
  requants) against the active plan's ``Decision.numbers`` and emits a
  :class:`DriftReport` naming every decision whose measured bound diverged
  past the threshold. Surfaced via ``plan.explain(drift=report)``, the
  scheduler's end-of-run stats, and the ``plan-drift-clean`` perf_guard
  gate.

:func:`phase_timer` is the one wall-clock phase-timing pattern (the
``t0 = time.perf_counter() … st[key] += …`` blocks the engine and scheduler
used to hand-roll three times over), and :func:`heartbeat_record` is the
shared heartbeat schema (monotonic + wall time, injectable for tests) the
train-loop Supervisor writes.
"""
from __future__ import annotations

import contextlib
import dataclasses
import json
import math
import time
from typing import Any, Dict, List, Mapping, Optional, Tuple

SCHEMA = "repro.telemetry/v1"

# ---------------------------------------------------------------- tracing
# Span/event categories (the taxonomy DESIGN.md §15 documents):
#   request — queued / admitted / outcome instants, per-rid
#   phase   — prefill / decode_chunk spans (wall_s annotated)
#   pool    — preempt / cow_copy / stall / pool_audit
#   degrade — degrade_rung (int8_kv requant, clamp_max_new)
#   chaos   — step_retry and other injected-fault absorptions
#   window  — fleet window stages: dispatch / tick / failover / migrate /
#             scale_up / scale_down / replan
#   spec    — speculative decode rounds: spec_chunk (drafted/accepted per
#             dispatch window)
#   collective — mesh traffic (ISSUE 10): per-chunk all-gather accounting
#             on sharded plans (serve.shard.chunk_collectives)
CATEGORIES = ("request", "phase", "pool", "degrade", "chaos", "window",
              "event", "spec", "collective")


@dataclasses.dataclass(frozen=True)
class TraceEvent:
    """One trace record. ``dur == 0`` renders as an instant event.

    Every field except ``wall_s`` is deterministic given the seed;
    ``wall_s`` is the wall-clock annotation and is the ONLY field stripped
    for trace-identity comparisons.
    """
    name: str
    cat: str
    clock: float                 # virtual-step stamp (span start)
    dur: float = 0.0             # virtual-step duration (0: instant)
    slot: int = -1               # replica slot (-1: single scheduler/fleet)
    rid: int = -1                # request id (-1: not request-scoped)
    args: Mapping[str, Any] = dataclasses.field(default_factory=dict)
    wall_s: Optional[float] = None   # annotation — never part of identity

    def key(self) -> Tuple[float, int, int]:
        return (self.clock, self.slot, self.rid)


class Tracer:
    """Deterministic span/event recorder on the virtual step clock."""

    def __init__(self, enabled: bool = True):
        self.enabled = enabled
        self.events: List[TraceEvent] = []

    def reset(self) -> None:
        self.events.clear()

    def event(self, name: str, clock: float, *, cat: str = "event",
              slot: int = -1, rid: int = -1, wall_s: Optional[float] = None,
              **args) -> None:
        """Record an instant event at ``clock``."""
        self.span(name, clock, clock, cat=cat, slot=slot, rid=rid,
                  wall_s=wall_s, **args)

    def span(self, name: str, start: float, end: float, *, cat: str = "event",
             slot: int = -1, rid: int = -1, wall_s: Optional[float] = None,
             **args) -> None:
        """Record a complete span over ``[start, end]`` virtual steps."""
        if not self.enabled:
            return
        self.events.append(TraceEvent(
            name=name, cat=cat, clock=float(start),
            dur=float(end) - float(start), slot=int(slot), rid=int(rid),
            args=dict(args), wall_s=wall_s))

    # ------------------------------------------------------------- exports
    def signature(self) -> str:
        """Canonical JSON of the trace with wall-time annotations stripped
        — the bit-identity surface the determinism tests/gates compare."""
        return json.dumps(
            [{"name": e.name, "cat": e.cat, "clock": e.clock, "dur": e.dur,
              "slot": e.slot, "rid": e.rid, "args": e.args}
             for e in self.events],
            sort_keys=True, separators=(",", ":"))

    def to_chrome_trace(self, strip_wall: bool = False) -> Dict:
        """Chrome ``trace_event`` JSON (Perfetto-loadable).

        Mapping: 1 virtual step -> 1000 µs (1 ms), pid = replica slot + 1
        (pid 0 is the single scheduler / fleet control plane), tid = rid + 1
        (tid 0 is the window lane). Wall-clock annotations ride in
        ``args.wall_s`` unless ``strip_wall`` — with it stripped the JSON is
        byte-identical across same-seed runs.
        """
        evs: List[Dict] = []
        pids = {}
        for e in self.events:
            pid = e.slot + 1
            if pid not in pids:
                pids[pid] = ("scheduler" if pid == 0
                             else f"replica {e.slot}")
            tid = e.rid + 1
            args = dict(e.args)
            if e.wall_s is not None and not strip_wall:
                args["wall_s"] = e.wall_s
            rec = {"name": e.name, "cat": e.cat, "pid": pid, "tid": tid,
                   "ts": round(e.clock * 1000.0, 3), "args": args}
            if e.dur > 0:
                rec["ph"] = "X"
                rec["dur"] = round(e.dur * 1000.0, 3)
            else:
                rec["ph"] = "i"
                rec["s"] = "t"
            evs.append(rec)
        meta = [{"name": "process_name", "ph": "M", "pid": pid, "tid": 0,
                 "args": {"name": label}}
                for pid, label in sorted(pids.items())]
        return {"traceEvents": meta + evs, "displayTimeUnit": "ms",
                "otherData": {"schema": SCHEMA,
                              "clock": "virtual decode steps (1 step = 1ms)"}}


# ------------------------------------------------------------ phase timing
class PhaseHandle:
    """Yielded by :func:`phase_timer`. ``ready(x)`` registers a device value
    to block on before the clock stops (phase-accurate timing for async
    dispatch); ``note(**kw)`` attaches deterministic args to the span."""

    def __init__(self):
        self.elapsed_s = 0.0
        self._sync = None
        self._args: Dict[str, Any] = {}

    def ready(self, x):
        self._sync = x
        return x

    def note(self, **kw) -> None:
        self._args.update(kw)


@contextlib.contextmanager
def phase_timer(sink: Optional[Dict], key: Optional[str], *,
                tracer: Optional[Tracer] = None, name: Optional[str] = None,
                cat: str = "phase", start: float = 0.0,
                end: Optional[float] = None, slot: int = -1, rid: int = -1):
    """The one wall-clock phase-timing pattern (ISSUE 8 satellite).

    Replaces the hand-rolled ``t0 = perf_counter(); …; st[k] += …`` blocks:
    accumulates elapsed wall seconds into ``sink[key]`` (when given) and —
    when a tracer is attached — records a span named ``name or key`` over
    ``[start, end]`` virtual steps with the wall time as an annotation.
    Call ``handle.ready(device_value)`` inside the block to make the timer
    block on async device work before stopping the clock.
    """
    h = PhaseHandle()
    t0 = time.perf_counter()
    try:
        yield h
    finally:
        if h._sync is not None and hasattr(h._sync, "block_until_ready"):
            h._sync.block_until_ready()
        h.elapsed_s = time.perf_counter() - t0
        if sink is not None and key:
            sink[key] = sink.get(key, 0.0) + h.elapsed_s
        if tracer is not None:
            tracer.span(name or key or "phase", start,
                        start if end is None else end, cat=cat, slot=slot,
                        rid=rid, wall_s=h.elapsed_s, **h._args)


class RunClock:
    """Wall clock for a whole run (the third hand-rolled pattern): started
    at construction, read via :meth:`elapsed_s` for ``finished_wall_s`` /
    ``total_wall_s`` stamps — annotations, never part of trace identity."""

    def __init__(self):
        self.t0 = time.perf_counter()

    def elapsed_s(self) -> float:
        return time.perf_counter() - self.t0


# --------------------------------------------------------------- heartbeat
HEARTBEAT_SCHEMA = "repro.telemetry/heartbeat-v1"


def heartbeat_record(step: int, *, wall_time: Optional[float] = None,
                     mono_s: Optional[float] = None, restarts: int = 0,
                     **extra) -> Dict:
    """The one heartbeat schema (shared with trace annotations): a monotonic
    reading (``mono_s``, immune to wall-clock jumps) PLUS wall time, both
    injectable so tests control them. Extra keys ride along verbatim."""
    rec = {"schema": HEARTBEAT_SCHEMA, "step": int(step),
           "wall_time": time.time() if wall_time is None else float(wall_time),
           "mono_s": time.monotonic() if mono_s is None else float(mono_s),
           "restarts": int(restarts)}
    rec.update(extra)
    return rec


# ---------------------------------------------------------- metric registry
# THE frozen key set (ISSUE 8 satellite): adding or removing a metric is an
# API change — update these tuples AND the key-set test AND DESIGN.md §15
# together. MetricsRegistry raises KeyError on any undeclared name, so the
# set cannot drift silently.
COUNTER_KEYS: Tuple[str, ...] = (
    # request lifecycle
    "requests_queued", "requests_admitted", "tokens_emitted",
    # terminal outcomes (mirrors serve.guard.OUTCOMES)
    "ok", "shed", "expired", "preempted_out", "failed",
    # prefill / decode work
    "prefill_batches", "prefill_prompts", "prefill_real_tokens",
    "prefill_padded_tokens", "decode_chunks", "decode_steps",
    # pool / degradation / chaos events
    "preemptions", "cow_copies", "shared_tokens_admitted",
    "requant_events", "clamped_admissions", "stalled_boundaries",
    "step_retries",
    # fleet control plane
    "migrations", "failovers", "scale_ups", "scale_downs", "replans",
    # speculative decode (ISSUE 9): acceptance rate =
    # spec_accepted_tokens / spec_drafted_tokens
    "spec_rounds", "spec_drafted_tokens", "spec_accepted_tokens",
    # mesh collectives (ISSUE 10): analytic all-gather accounting on
    # sharded plans (serve.shard.chunk_collectives); zero on tp=ep=1
    "collective_ops", "collective_allgather_bytes",
)
GAUGE_KEYS: Tuple[str, ...] = (
    "clock", "queue_pending", "queue_waiting", "active_rows",
    "pool_pressure", "pages_used", "pages_free", "shared_page_ratio",
    "resident_tokens",
    # shard-tagged pool gauges (ISSUE 10): per-device occupancy spread and
    # the lockstep-divergence count of the sharded page pool
    "shard_pages_used_max", "shard_pages_used_min",
    "shard_lockstep_divergence",
)
HISTOGRAM_KEYS: Tuple[str, ...] = (
    "admission_wait_steps", "ttft_steps", "e2e_latency_steps",
    "finished_len_tokens", "generated_tokens",
)
# per-tenant sub-registry keys (satellite: p50/p99 admission wait + goodput)
TENANT_COUNTER_KEYS: Tuple[str, ...] = ("ok_requests", "ok_tokens")
TENANT_HISTOGRAM_KEYS: Tuple[str, ...] = ("admission_wait_steps",)

METRIC_KEYS = frozenset(COUNTER_KEYS) | frozenset(GAUGE_KEYS) \
    | frozenset(HISTOGRAM_KEYS)
assert len(METRIC_KEYS) == len(COUNTER_KEYS) + len(GAUGE_KEYS) \
    + len(HISTOGRAM_KEYS), "metric names must be unique across kinds"


def _percentile(sorted_vals: List[float], q: float) -> float:
    """Nearest-rank percentile over an already-sorted list."""
    if not sorted_vals:
        return 0.0
    rank = max(int(math.ceil(q / 100.0 * len(sorted_vals))), 1)
    return float(sorted_vals[min(rank, len(sorted_vals)) - 1])


def _hist_summary(values: List[float]) -> Dict[str, float]:
    if not values:
        return {"count": 0, "sum": 0.0, "min": 0.0, "max": 0.0,
                "mean": 0.0, "p50": 0.0, "p99": 0.0}
    sv = sorted(values)
    total = float(sum(sv))
    return {"count": len(sv), "sum": total, "min": float(sv[0]),
            "max": float(sv[-1]), "mean": total / len(sv),
            "p50": _percentile(sv, 50.0), "p99": _percentile(sv, 99.0)}


@dataclasses.dataclass(frozen=True)
class MetricsSnapshot:
    """One frozen view of the registry: full counter/gauge maps plus
    histogram and per-tenant summaries. ``key_set()`` must equal
    :data:`METRIC_KEYS` — the drift test pins it."""
    clock: float
    counters: Mapping[str, float]
    gauges: Mapping[str, float]
    histograms: Mapping[str, Mapping[str, float]]
    tenants: Mapping[str, Mapping[str, float]]

    def key_set(self) -> frozenset:
        return frozenset(self.counters) | frozenset(self.gauges) \
            | frozenset(self.histograms)

    def as_dict(self) -> Dict:
        return {"clock": self.clock, "counters": dict(self.counters),
                "gauges": dict(self.gauges),
                "histograms": {k: dict(v)
                               for k, v in self.histograms.items()},
                "tenants": {k: dict(v) for k, v in self.tenants.items()}}


class MetricsRegistry:
    """Counters/gauges/histograms over the frozen key set, snapshotted per
    sync window. ``windows`` holds one gauge snapshot per decode boundary
    (tagged with clock + replica slot) — the measured-occupancy history
    :func:`detect_drift` consumes."""

    def __init__(self):
        self.counters: Dict[str, float] = {}
        self.gauges: Dict[str, float] = {}
        self.hists: Dict[str, List[float]] = {}
        self.tenants: Dict[str, Dict] = {}
        self.windows: List[Dict] = []
        self.reset()

    def reset(self) -> None:
        self.counters = {k: 0 for k in COUNTER_KEYS}
        self.gauges = {k: 0.0 for k in GAUGE_KEYS}
        self.hists = {k: [] for k in HISTOGRAM_KEYS}
        self.tenants = {}
        self.windows = []

    # ------------------------------------------------------------- writers
    def count(self, key: str, n: float = 1) -> None:
        if key not in self.counters:
            raise KeyError(f"undeclared counter {key!r} — the metric key "
                           "set is frozen (telemetry.COUNTER_KEYS)")
        self.counters[key] += n

    def gauge(self, key: str, value: float) -> None:
        if key not in self.gauges:
            raise KeyError(f"undeclared gauge {key!r} — the metric key set "
                           "is frozen (telemetry.GAUGE_KEYS)")
        self.gauges[key] = float(value)

    def observe(self, key: str, value: float) -> None:
        if key not in self.hists:
            raise KeyError(f"undeclared histogram {key!r} — the metric key "
                           "set is frozen (telemetry.HISTOGRAM_KEYS)")
        self.hists[key].append(float(value))

    def _tenant(self, tenant: Optional[str]) -> Dict:
        t = tenant if tenant is not None else "default"
        if t not in self.tenants:
            self.tenants[t] = {
                **{k: 0 for k in TENANT_COUNTER_KEYS},
                **{k: [] for k in TENANT_HISTOGRAM_KEYS}}
        return self.tenants[t]

    def tenant_count(self, tenant: Optional[str], key: str,
                     n: float = 1) -> None:
        if key not in TENANT_COUNTER_KEYS:
            raise KeyError(f"undeclared tenant counter {key!r}")
        self._tenant(tenant)[key] += n

    def tenant_observe(self, tenant: Optional[str], key: str,
                       value: float) -> None:
        if key not in TENANT_HISTOGRAM_KEYS:
            raise KeyError(f"undeclared tenant histogram {key!r}")
        self._tenant(tenant)[key].append(float(value))

    def end_window(self, clock: float, slot: int = -1) -> None:
        """Close one sync window: archive the current gauges (the drift
        detector's per-window measurement record)."""
        self.gauges["clock"] = float(clock)
        self.windows.append({"clock": float(clock), "slot": int(slot),
                             **self.gauges})

    # ------------------------------------------------------------- readers
    def tenant_summary(self) -> Dict[str, Dict[str, float]]:
        """Per-tenant goodput + admission-wait percentiles, in steps —
        the measurement half of SLO-aware scheduling."""
        out: Dict[str, Dict[str, float]] = {}
        for t in sorted(self.tenants):
            rec = self.tenants[t]
            waits = _hist_summary(rec["admission_wait_steps"])
            out[t] = {"ok_requests": rec["ok_requests"],
                      "goodput_tokens": rec["ok_tokens"],
                      "admission_wait_p50_steps": waits["p50"],
                      "admission_wait_p99_steps": waits["p99"],
                      "admission_wait_mean_steps": waits["mean"]}
        return out

    def snapshot(self, clock: Optional[float] = None) -> MetricsSnapshot:
        return MetricsSnapshot(
            clock=float(self.gauges["clock"] if clock is None else clock),
            counters=dict(self.counters), gauges=dict(self.gauges),
            histograms={k: _hist_summary(v) for k, v in self.hists.items()},
            tenants=self.tenant_summary())


# ------------------------------------------------------------------- bundle
class Telemetry:
    """The bundle one serving entry point owns: a tracer + a metrics
    registry (+ the last drift report). Shared across a ReplicaSet's
    schedulers (each tags its slot); the facade resets it per call."""

    def __init__(self, enabled: bool = True):
        self.tracer = Tracer(enabled=enabled)
        self.metrics = MetricsRegistry()
        self.last_drift: Optional[DriftReport] = None

    def reset(self) -> None:
        self.tracer.reset()
        self.metrics.reset()
        self.last_drift = None

    def detect_drift(self, plan, threshold: float = 0.5) -> "DriftReport":
        self.last_drift = detect_drift(plan, self.metrics,
                                       threshold=threshold)
        return self.last_drift


# ------------------------------------------------------------ drift report
CONFIRMED = "CONFIRMED"
WITHIN = "within"


@dataclasses.dataclass(frozen=True)
class DriftFinding:
    """One measured-vs-predicted comparison against a plan Decision."""
    decision: str        # Decision.name ("attention", "capacity", ...)
    metric: str
    predicted: float
    measured: float
    ratio: float         # measured / predicted
    threshold: float     # relative divergence that flips the verdict
    verdict: str         # CONFIRMED | within
    why: str

    @property
    def confirmed(self) -> bool:
        return self.verdict == CONFIRMED

    def render(self) -> str:
        return (f"[{self.verdict}] {self.decision}.{self.metric}: "
                f"predicted {self.predicted:g}, measured {self.measured:g} "
                f"(x{self.ratio:.2f}, threshold +/-{self.threshold:.0%}) — "
                f"{self.why}")


@dataclasses.dataclass(frozen=True)
class DriftReport:
    """Per-run Eyexam-at-runtime verdict: every compared decision with its
    measured-vs-predicted numbers; ``confirmed`` names the divergent ones."""
    clock: float
    windows: int
    threshold: float
    findings: Tuple[DriftFinding, ...]

    @property
    def confirmed(self) -> Tuple[DriftFinding, ...]:
        return tuple(f for f in self.findings if f.confirmed)

    @property
    def clean(self) -> bool:
        return not self.confirmed

    def for_decision(self, name: str) -> Tuple[DriftFinding, ...]:
        return tuple(f for f in self.findings if f.decision == name)

    def summary(self) -> Dict:
        return {"windows": self.windows, "compared": len(self.findings),
                "confirmed": [f"{f.decision}.{f.metric}"
                              for f in self.confirmed]}

    def render(self) -> str:
        head = (f"DriftReport @ clock {self.clock:g} ({self.windows} "
                f"window(s), threshold {self.threshold:.0%}): "
                f"{len(self.confirmed)} CONFIRMED / "
                f"{len(self.findings)} compared")
        return "\n".join([head] + [f"  {f.render()}" for f in self.findings])


def _verdict(ratio: float, threshold: float) -> str:
    if ratio <= 0:
        return CONFIRMED
    lo, hi = 1.0 / (1.0 + threshold), 1.0 + threshold
    return WITHIN if lo <= ratio <= hi else CONFIRMED


def detect_drift(plan, metrics: MetricsRegistry,
                 threshold: float = 0.5) -> DriftReport:
    """Diff measured run proxies against ``plan.decisions[*].numbers``.

    Comparisons (each skipped when its prediction or measurement is absent):

    * ``attention.resident_tokens_per_row`` — page-rounded mean resident
      tokens per live row (window gauges) vs ``expected_resident_tokens``:
      the occupancy assumption behind paged-vs-contiguous.
    * ``capacity.mean_finished_len`` — mean finished total length vs
      ``expected_mean_len`` (plans resolved by ``plan_serve``).
    * ``kv_quant.hbm_step_bytes`` — estimated per-step HBM traffic (weight
      stream + cache stream scaled by measured occupancy) vs the decision's
      expected-occupancy estimate.
    * ``kv_quant.requant_events`` — any forced fp->int8 requant under a
      plan that resolved fp pages is measured proof the occupancy
      prediction was low (always CONFIRMED when it fires).
    * ``mlp.decode_m`` — the fused/two-call route at the measured mean
      decode width vs at the provisioned ``rows``: CONFIRMED when the
      measured width lands on the other side of the crossover.
    * ``prefill.pad_ratio`` — measured padded/real prefill tokens vs the
      tier ladder's worst-case bound (2.0 for pow2 tiers, 1.0 exact).
    * ``mesh.allgather_bytes_per_token`` — measured collective bytes per
      emitted token (``collective_allgather_bytes`` / ``tokens_emitted``)
      vs the mesh decision's per-token model (sharded plans only).
    """
    decisions = {d.name: d for d in getattr(plan, "decisions", ())}
    findings: List[DriftFinding] = []
    windows = [w for w in metrics.windows if w.get("active_rows", 0) > 0]
    c = metrics.counters
    clock = metrics.gauges.get("clock", 0.0)

    def add(decision, metric, predicted, measured, why,
            verdict=None) -> None:
        pred = float(predicted)
        meas = float(measured)
        ratio = meas / pred if pred else math.inf
        findings.append(DriftFinding(
            decision=decision, metric=metric, predicted=pred, measured=meas,
            ratio=ratio, threshold=threshold,
            verdict=verdict or _verdict(ratio, threshold), why=why))

    mean_resident_per_row = mean_resident_total = None
    if windows:
        mean_resident_per_row = sum(
            w["resident_tokens"] / max(w["active_rows"], 1)
            for w in windows) / len(windows)
        mean_resident_total = sum(
            w["resident_tokens"] for w in windows) / len(windows)

    # ---- attention: measured occupancy vs the paging assumption ----
    attn = decisions.get("attention")
    if attn is not None and mean_resident_per_row is not None \
            and "expected_resident_tokens" in attn.numbers \
            and getattr(plan, "paged", False):
        ps = max(int(getattr(plan, "page_size", 1)), 1)
        measured = math.ceil(mean_resident_per_row / ps) * ps
        add("attention", "resident_tokens_per_row",
            attn.numbers["expected_resident_tokens"], measured,
            "mean page-rounded resident tokens per live row across "
            f"{len(windows)} decode window(s) — the occupancy the "
            "paged-vs-contiguous rule was resolved from")

    # ---- capacity: finished lengths vs the expected mean ----
    cap = decisions.get("capacity")
    lens = metrics.hists.get("finished_len_tokens", [])
    if cap is not None and lens and "expected_mean_len" in cap.numbers:
        add("capacity", "mean_finished_len",
            cap.numbers["expected_mean_len"], sum(lens) / len(lens),
            f"mean finished prompt+output length over {len(lens)} "
            "request(s) vs the expected_len_dist mean the pool was "
            "provisioned for")

    # ---- kv_quant: per-step HBM traffic estimate at measured occupancy --
    kv = decisions.get("kv_quant")
    if kv is not None and mean_resident_total is not None \
            and "weight_stream_bytes" in kv.numbers \
            and "cache_stream_bytes" in kv.numbers:
        w_b = kv.numbers["weight_stream_bytes"]
        c_b = kv.numbers["cache_stream_bytes"]
        cap_tokens = max(plan.rows * plan.cache_len, 1)
        exp_tok = attn.numbers.get("expected_resident_tokens",
                                   plan.cache_len) if attn is not None \
            else plan.cache_len
        pred_frac = min(exp_tok * plan.rows / cap_tokens, 1.0)
        meas_frac = min(mean_resident_total / cap_tokens, 1.0)
        add("kv_quant", "hbm_step_bytes",
            w_b + c_b * pred_frac, w_b + c_b * meas_frac,
            "decode-step HBM estimate: weight stream + cache stream scaled "
            f"by occupancy (predicted {pred_frac:.2f}, measured "
            f"{meas_frac:.2f} of the full pool)")
    if kv is not None and c.get("requant_events", 0) > 0 \
            and getattr(plan, "kv_quant", None) == "fp" \
            or (kv is not None and kv.choice == "fp"
                and c.get("requant_events", 0) > 0):
        add("kv_quant", "requant_events", 0.0, c["requant_events"],
            "the plan resolved fp pages but measured pool pressure forced "
            "the int8 degrade rung — the occupancy prediction ran low",
            verdict=CONFIRMED)

    # ---- mlp: fused/two-call crossover at the measured decode width ----
    mlp = decisions.get("mlp")
    if mlp is not None and windows and hasattr(plan, "mlp_route"):
        mean_active = sum(w["active_rows"] for w in windows) / len(windows)
        m_meas = max(int(round(mean_active)), 1)
        route_plan = plan.mlp_route(plan.rows)
        route_meas = plan.mlp_route(m_meas)
        add("mlp", "decode_m", plan.rows, mean_active,
            f"mean live decode width; route at provisioned rows = "
            f"{route_plan}, at measured width = {route_meas}",
            verdict=CONFIRMED if route_meas != route_plan else WITHIN)

    # ---- prefill: tier-pad waste vs the ladder's worst case ----
    pre = decisions.get("prefill")
    if pre is not None and c.get("prefill_real_tokens", 0) > 0:
        bound = 1.0 if getattr(plan, "prefill_exact", False) else 2.0
        ratio = c["prefill_padded_tokens"] / c["prefill_real_tokens"]
        add("prefill", "pad_ratio", bound, ratio,
            "measured padded/real prefill tokens vs the tier ladder's "
            "worst-case pad bound",
            verdict=CONFIRMED if ratio > bound + 1e-9 else WITHIN)

    # ---- mesh: measured collective bytes/token vs the per-token model ---
    mesh = decisions.get("mesh")
    if mesh is not None and c.get("tokens_emitted", 0) > 0 \
            and mesh.numbers.get("allgather_bytes_per_token", 0) > 0:
        add("mesh", "allgather_bytes_per_token",
            mesh.numbers["allgather_bytes_per_token"],
            c.get("collective_allgather_bytes", 0)
            / max(c["tokens_emitted"], 1),
            "measured collective all-gather bytes per emitted token vs the "
            "mesh decision's model — divergence means the mesh moves more "
            "than token-sized traffic per step")

    return DriftReport(clock=float(clock), windows=len(windows),
                       threshold=threshold, findings=tuple(findings))
