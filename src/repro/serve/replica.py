"""Multi-replica serving control plane: lockstep replicas, heartbeats,
deterministic failover (ISSUE 7).

One ``ContinuousBatchingScheduler`` is a single failure domain: a host dies
and every in-flight request dies with it. This module wraps N schedulers —
each with its own page pool, jitted programs, and rng stream — behind the
same front door (``repro.serve.LLM(..., replicas=N)``) and makes the fleet
survive replica loss without giving up the repo's determinism contract:

* **One shared virtual clock.** Replicas are driven through the
  scheduler's boundary-stepped generator (``start_gen`` / ``("tick", G)``):
  every live replica processes the boundary at global clock G before anyone
  sees G+T, and a scheduler never idle-jumps ahead of the clock. Placement,
  failure detection, and failover all key off G — two same-seed runs
  produce identical outcome sets because nothing reads wall-clock.
* **Heartbeats + supervision.** A replica's heartbeat is *virtual steps
  since it last responded to a tick*, audited every sync window. The
  ``ReplicaSupervisor`` reuses the train-loop's
  ``runtime.fault_tolerance.StragglerDetector`` (median-based flagging,
  strike persistence) to catch creeping stalls, with a hard
  ``max_silent_windows`` ceiling behind it (a replica silent from its very
  first window never builds the healthy history the median needs), plus a
  ``guard.audit_pool`` sweep per window to quarantine allocator corruption
  before it spreads. Kills are visible immediately (the replica's state
  flips); stalls and corruption are *detected*, not observed.
* **Deterministic failover.** A failed replica's generator is abandoned
  exactly as a dead process would be (no finalization — ``gen.close()``),
  its unfinished requests harvested from the scheduler's live state and
  re-routed in (arrival, rid) order through the router. Active requests
  migrate by recompute through the existing preemption path (the resume
  prompt is ``prompt + out``, bit-exact under greedy decode); each request
  carries a migration budget and pays the shared ``backoff_delay`` schedule
  per migration, and a request whose budget is spent resolves ``failed`` —
  so every submitted rid ends in exactly one terminal
  :class:`~repro.serve.guard.RequestOutcome`, fleet-wide, under any chaos
  schedule.
* **Feedback re-planning.** Finished-request lengths feed
  ``core.plan.replan_from_lengths``; when the measured mean drifts past the
  plan's assumed occupancy, the replica hot-swaps the re-resolved plan at a
  drain boundary (never mid-flight — dispatch identity holds within a
  request's lifetime).
* **Autoscaling.** Measured queue depth per live replica against
  high/low watermarks with patience counters (hysteresis): bursts spawn
  replicas, sustained idleness retires drained ones — never a replica that
  still holds work.

Replica-level chaos (``serve.chaos.ReplicaChaosConfig``) schedules kills,
permanent stalls, and pool corruption on the same virtual clock, so the
chaos suite can assert the real promises: survivors bit-identical to a
fault-free run, exactly-once outcomes, goodput within a constant factor of
the no-failure run.
"""
from __future__ import annotations

import dataclasses
import statistics
from typing import Dict, List, Optional

import jax

from repro.core import plan as plan_lib
from repro.runtime.fault_tolerance import StragglerDetector, backoff_delay
from repro.serve import chaos as chaos_mod, guard as guard_mod
from repro.serve import telemetry as telemetry_mod
from repro.serve.router import Router, RouterConfig
from repro.serve.scheduler import ContinuousBatchingScheduler, StreamRequest

# replica lifecycle states
LIVE = "live"
DEAD = "dead"              # killed (chaos or supervisor on stall/corruption)
RETIRED = "retired"        # cleanly drained and stopped (scale-down)


@dataclasses.dataclass
class SupervisorConfig:
    """Failure-detection policy (all thresholds in sync windows).

    The straggler detector needs ~5 healthy observations before its median
    is meaningful; ``max_silent_windows`` is the unconditional ceiling that
    catches replicas stalled too early to have a history.
    """
    heartbeat_factor: float = 3.0     # StragglerDetector flag multiplier
    heartbeat_patience: int = 2       # consecutive flags -> stalled
    max_silent_windows: int = 8       # hard heartbeat ceiling
    audit_every_window: bool = True   # guard.audit_pool per replica/window


@dataclasses.dataclass
class AutoscaleConfig:
    """Queue-depth autoscaling with hysteresis (depths per live replica)."""
    min_replicas: int = 1
    max_replicas: int = 4
    high_depth: float = 6.0           # scale up above this
    low_depth: float = 1.0            # scale down below this
    patience_windows: int = 3         # watermark must hold this long


@dataclasses.dataclass
class ReplanConfig:
    """Feedback-driven re-planning policy.

    Re-plan fires when the measured mean finished length drifts more than
    ``drift_threshold`` (relative) from the plan's assumed occupancy, with
    at least ``min_samples`` finished requests behind the measurement; the
    swap itself only happens at a replica drain boundary.
    """
    min_samples: int = 8
    drift_threshold: float = 0.3


class Replica:
    """One scheduler + its boundary-stepped generator + liveness state.

    ``slot`` is the fleet-unique identity (never reused — chaos schedules,
    detectors, and router affinity key off it). ``generation`` counts plan
    hot-swaps; the local step counter restarts with each generation, which
    is exactly the non-monotonic input seam ``StragglerDetector.observe``
    tolerates.
    """

    def __init__(self, slot: int, cfg, params, plan, *, eos_id: int,
                 temperature: float, guard,
                 telemetry: Optional[telemetry_mod.Telemetry] = None):
        self.slot = slot
        self.cfg = cfg
        self.params = params
        self.eos_id = eos_id
        self.temperature = temperature
        self.guard = guard
        self.telemetry = telemetry   # fleet-shared registry (slot-tagged)
        self.state = LIVE
        self.failed_over = False     # failover executed (exactly once)
        self.fail_reason = ""
        self.generation = 0
        self.local_step = 0          # boundaries processed this generation
        self.last_response: float = 0.0   # clock of last answered tick
        self.last_status: Optional[Dict] = None
        self.done_accum: List[StreamRequest] = []   # prior generations
        self.stalled_by_chaos = False
        self._gen = None
        self.scheduler = ContinuousBatchingScheduler(
            cfg, params, plan, eos_id=eos_id, temperature=temperature,
            guard=guard, telemetry=telemetry, slot=slot)

    # ------------------------------------------------------------ lifecycle
    def start(self, rng, chaos=None, at_clock: float = 0.0,
              sync_every: int = 1) -> None:
        self._gen = self.scheduler.start_gen([], rng=rng, chaos=chaos)
        # a fresh replica counts as having just responded (spawn is a sync)
        self.last_response = at_clock - sync_every
        self.local_step = 0
        self.last_status = None

    def tick(self, clock: float) -> Optional[Dict]:
        """Process one boundary at the shared clock. Returns the status
        dict, or None if the replica's own pool audit raised (the replica
        is marked DEAD for the supervisor to fail over)."""
        try:
            with plan_lib.activate(self.scheduler.plan):
                self.last_status = self._gen.send(("tick", clock))
        except guard_mod.PoolAuditError as e:
            # the generator died raising — same surface as a crashed host
            self._gen = None
            self.state = DEAD
            self.last_status = None
            self.fail_reason = f"pool audit failed in-run: {e}"
            return None
        self.last_response = clock
        self.local_step += 1
        return self.last_status

    def stop(self) -> List[StreamRequest]:
        """Finalize cleanly (requires a drained scheduler when guarded)."""
        try:
            with plan_lib.activate(self.scheduler.plan):
                self._gen.send(("stop", None))
        except StopIteration as e:
            return e.value
        finally:
            self._gen = None
        raise RuntimeError("scheduler generator did not finalize on stop")

    def kill(self) -> None:
        """Abandon the run exactly as a dead process would: the generator
        unwinds without finalization, outcomes undelivered, live state left
        harvestable."""
        if self._gen is not None:
            self._gen.close()
            self._gen = None

    # ------------------------------------------------------------- queries
    def queue_depth(self) -> int:
        live = self.scheduler._live
        if live is None:
            return 0
        return len(live["pending"]) + len(live["waiting"]) \
            + len(live["active"])

    def heartbeat(self, clock: float) -> float:
        """Virtual steps since this replica last answered a tick."""
        return clock - self.last_response

    def harvest_unfinished(self) -> List[StreamRequest]:
        """Requests stranded by this replica's death (no terminal outcome),
        in (arrival, rid) order — the failover re-route order."""
        live = self.scheduler._live
        if live is None:
            return []
        stranded = list(live["pending"]) + list(live["waiting"]) \
            + list(live["active"].values())
        stranded = [r for r in stranded if r.outcome is None]
        return sorted(stranded, key=lambda r: (r.arrival, r.rid))

    def collect_done(self) -> List[StreamRequest]:
        """Every request this replica resolved, across plan generations."""
        out = list(self.done_accum)
        if self.scheduler._live is not None:
            out.extend(self.scheduler._live["done"])
        return out

    # ----------------------------------------------------------- plan swap
    def swap_plan(self, plan, rng, chaos=None, at_clock: float = 0.0) -> None:
        """Hot-swap a re-resolved plan at a drain boundary: finalize the
        drained run, rebuild the scheduler on the new plan, restart the
        generator. The local step counter restarts — downstream heartbeat
        observers must tolerate the non-monotonic step input."""
        self.done_accum.extend(self.stop())
        self.generation += 1
        self.scheduler = ContinuousBatchingScheduler(
            self.cfg, self.params, plan, eos_id=self.eos_id,
            temperature=self.temperature, guard=self.guard,
            telemetry=self.telemetry, slot=self.slot)
        self.start(rng, chaos=chaos, at_clock=at_clock,
                   sync_every=self.scheduler.sync_every)


class ReplicaSupervisor:
    """Per-window liveness audit over the fleet.

    Reuses the train loop's :class:`StragglerDetector` per slot: each
    window every live replica's heartbeat (in windows) is observed; a
    healthy replica contributes ~1.0, a stalling one a growing value that
    flags once past ``factor × median`` and persists past ``patience``.
    ``max_silent_windows`` backstops the cold-start case, and
    ``guard.audit_pool`` catches allocator corruption the same window it
    appears. Returns *reasons*, never mutates the fleet — failover policy
    belongs to the ReplicaSet.
    """

    def __init__(self, cfg: Optional[SupervisorConfig] = None):
        self.cfg = cfg or SupervisorConfig()
        self._detectors: Dict[int, StragglerDetector] = {}

    def detector(self, slot: int) -> StragglerDetector:
        if slot not in self._detectors:
            self._detectors[slot] = StragglerDetector(
                self.cfg.heartbeat_factor, self.cfg.heartbeat_patience)
        return self._detectors[slot]

    def audit(self, replicas: List[Replica], clock: float,
              sync_every: int) -> List[tuple]:
        """One supervision window: returns [(replica, reason), ...] for
        every replica that must be failed over, deterministic order."""
        failures = []
        for rep in replicas:
            if rep.state == DEAD:
                failures.append(
                    (rep, rep.fail_reason or "replica died (killed)"))
                continue
            hb_windows = rep.heartbeat(clock) / max(sync_every, 1)
            det = self.detector(rep.slot)
            det.observe(rep.local_step, hb_windows)
            if det.persistent:
                failures.append((rep, f"heartbeat stalled: silent for "
                                      f"{hb_windows:.0f} windows (straggler "
                                      f"strikes {det.strikes})"))
                continue
            if hb_windows > self.cfg.max_silent_windows:
                failures.append((rep, f"heartbeat stalled: silent for "
                                      f"{hb_windows:.0f} windows (hard "
                                      "ceiling "
                                      f"{self.cfg.max_silent_windows})"))
                continue
            if self.cfg.audit_every_window and rep.scheduler.paged \
                    and rep.scheduler.pager is not None:
                violations = guard_mod.audit_pool(rep.scheduler.pager)
                if violations:
                    failures.append(
                        (rep, f"pool audit failed ({len(violations)} "
                              f"violation(s)): {violations[0]}"))
        return failures


class ReplicaSet:
    """N lockstep scheduler replicas behind one run() call.

    The drive loop per window at global clock G, in fixed order (every
    stage deterministic on G and the seed):

    1. apply due replica chaos (kill / stall / pool corruption);
    2. supervise: heartbeat + pool audits -> failover (harvest stranded
       requests, re-route in (arrival, rid) order, budget-checked);
    3. autoscale on measured queue depth (hysteresis);
    4. re-plan check at drain boundaries (measured length feedback);
    5. dispatch due arrivals in per-tenant fair order through the router;
    6. tick every responsive replica with ("tick", G);
    7. harvest finished-length feedback;
    then G += sync_every until every submitted request holds a terminal
    outcome. Requests the fleet can no longer host (migration budget spent)
    resolve ``failed`` here — the exactly-once outcome promise is the
    ReplicaSet's, not any single scheduler's.
    """

    def __init__(self, cfg, params, plan=None, *, replicas: int = 2,
                 eos_id: int = 1, temperature: float = 0.0,
                 guard: Optional[guard_mod.GuardConfig] = None,
                 router: Optional[RouterConfig] = None,
                 supervisor: Optional[SupervisorConfig] = None,
                 autoscale: Optional[AutoscaleConfig] = None,
                 replan: Optional[ReplanConfig] = None,
                 migration_budget: int = 3,
                 migrate_backoff_steps: float = 0.0,
                 max_rounds: int = 10_000,
                 telemetry: Optional[telemetry_mod.Telemetry] = None):
        if replicas < 1:
            raise ValueError(
                f"replicas must be >= 1, got {replicas}: the control plane "
                "needs at least one scheduler replica to place requests on")
        if plan is None:
            plan = plan_lib.plan_serve(
                cfg, hbm_budget_bytes=1 << 30, expected_batch=4,
                expected_len_dist={"mean": 256, "max": 512})
        self.cfg = cfg
        self.params = params
        self.plan = plan                  # template for spawns (may re-plan)
        self.eos_id = eos_id
        self.temperature = temperature
        # the control plane's promises (exactly-once outcomes, failover)
        # are guard-layer promises — a guardless fleet would raise on the
        # first overload instead of degrading, so the guard is always on
        self.guard = guard or guard_mod.GuardConfig()
        self.sync_every = plan.sync_every
        self.router = Router(router, page_size=plan.page_size)
        self.supervisor = ReplicaSupervisor(supervisor)
        self.autoscale = autoscale
        self.replan = replan
        self.migration_budget = migration_budget
        self.migrate_backoff_steps = migrate_backoff_steps
        self.max_rounds = max_rounds
        self.n_replicas = replicas
        # one fleet-shared Telemetry: every replica's scheduler writes into
        # it tagged with its slot; the control plane adds window/failover
        # events on slot -1 and owns the per-run reset
        self.telemetry = telemetry if telemetry is not None \
            else telemetry_mod.Telemetry()
        self._all: List[Replica] = []     # every replica ever spawned
        self._next_slot = 0
        self.phase_stats: Dict = {}

    # ------------------------------------------------------------- helpers
    def _live(self) -> List[Replica]:
        return [rep for rep in self._all if rep.state == LIVE]

    def _rng_for(self, root, slot: int, generation: int):
        # fold slot and generation into the root key: per-replica streams
        # are independent of fleet membership, so a survivor's randomness
        # never depends on whether another replica died
        return jax.random.fold_in(jax.random.fold_in(root, slot), generation)

    def _spawn(self, root, chaos: chaos_mod.ReplicaChaosConfig,
               at_clock: float) -> Replica:
        slot = self._next_slot
        self._next_slot += 1
        rep = Replica(slot, self.cfg, self.params, self.plan,
                      eos_id=self.eos_id, temperature=self.temperature,
                      guard=self.guard, telemetry=self.telemetry)
        rep.start(self._rng_for(root, slot, 0),
                  chaos=chaos.request_chaos.get(slot),
                  at_clock=at_clock, sync_every=self.sync_every)
        self._all.append(rep)
        self._st["replicas_spawned"] += 1
        return rep

    def _resolve_failed(self, r: StreamRequest, clock: float,
                        reason: str) -> None:
        r.done = True
        r.finished_at = clock
        r.outcome = guard_mod.RequestOutcome(
            "failed", reason, at_step=clock, degraded=tuple(r.degraded))
        self.telemetry.metrics.count("failed")
        self.telemetry.tracer.event("outcome", clock, cat="request",
                                    rid=r.rid, status="failed")
        if r.on_outcome is not None:
            r.on_outcome(r, r.outcome)
        self._failed.append(r)

    def _failover(self, rep: Replica, reason: str, clock: float) -> None:
        """Deterministic failover: kill, forget affinity, re-route stranded
        requests in (arrival, rid) order with per-request budgets."""
        rep.kill()
        rep.state = DEAD
        rep.failed_over = True
        self.router.forget_replica(rep.slot)
        st = self._st
        st["failovers"] += 1
        st["failover_reasons"].setdefault(reason.split(":")[0], 0)
        st["failover_reasons"][reason.split(":")[0]] += 1
        tel = self.telemetry
        tel.metrics.count("failovers")
        tel.tracer.event("failover", clock, cat="window",
                         replica=rep.slot, reason=reason.split(":")[0])
        for r in rep.harvest_unfinished():
            tel.metrics.count("migrations")
            tel.tracer.event("migrate", clock, cat="window", rid=r.rid,
                             from_replica=rep.slot)
            r.migrations += 1
            if r.migrations > self.migration_budget:
                self._resolve_failed(
                    r, clock,
                    f"migration budget ({self.migration_budget}) spent: "
                    f"request lost its host {r.migrations} times "
                    f"(last: {reason}); {len(r.out)} generated tokens kept")
                st["failed_migrations"] += 1
            else:
                # re-route through normal dispatch; the shared backoff
                # schedule paces repeat offenders (0 base: immediate)
                self._hold[r.rid] = clock + backoff_delay(
                    r.migrations, self.migrate_backoff_steps)
                self._pendq.append(r)
                st["migrated_requests"] += 1
        self._pendq.sort(key=lambda r: (r.arrival, r.rid))

    def _plan_mean(self) -> float:
        """The occupancy assumption baked into the current plan."""
        for d in self.plan.decisions:
            if "expected_mean_len" in getattr(d, "numbers", {}):
                return float(d.numbers["expected_mean_len"])
        return self.plan.cache_len / 2

    # ----------------------------------------------------------------- run
    def run(self, requests: List[StreamRequest], rng=None,
            chaos: Optional[chaos_mod.ReplicaChaosConfig] = None
            ) -> List[StreamRequest]:
        root = rng if rng is not None else jax.random.PRNGKey(0)
        if chaos is None:
            chaos = chaos_mod.ReplicaChaosConfig()
        elif isinstance(chaos, chaos_mod.ChaosConfig):
            # request-level chaos through the multi-replica path: every
            # replica gets the same seeded schedule
            chaos = chaos_mod.ReplicaChaosConfig(
                request_chaos={s: chaos for s in range(self.n_replicas)})
        reqs = list(requests)
        rids = [r.rid for r in reqs]
        if len(set(rids)) != len(rids):
            raise ValueError(f"request rids must be unique, got {rids}")
        # feasibility against the plan envelope, up front: a late infeasible
        # request must raise before any replica does any work (the same
        # caller-bug contract as the single-scheduler run), and re-planning
        # pins cache_len so the check stays valid across hot-swaps
        for r in reqs:
            total = len(r.prompt) + r.max_new
            if r.max_new > 0 and total > self.plan.cache_len:
                raise ValueError(
                    f"request {r.rid}: prompt ({len(r.prompt)}) + max_new "
                    f"({r.max_new}) exceeds cache_len "
                    f"({self.plan.cache_len})")
        T = self.sync_every
        st = self._st = self.phase_stats = {
            "replicas": self.n_replicas, "replicas_spawned": 0,
            "failovers": 0, "failover_reasons": {},
            "migrated_requests": 0, "failed_migrations": 0,
            "scale_ups": 0, "scale_downs": 0, "replans": 0,
            "spec_replans": 0, "rounds": 0, "clock_steps": 0.0,
        }
        self._all = []
        self._next_slot = 0
        # fleet telemetry: one reset per run() — the replica schedulers
        # share this bundle (never resetting it themselves) and tag their
        # events with their slot; control-plane events live on slot -1
        self.telemetry.reset()
        tel = self.telemetry
        self._failed: List[StreamRequest] = []
        self._pendq = sorted(reqs, key=lambda r: (r.arrival, r.rid))
        self._hold: Dict[int, float] = {}       # rid -> earliest dispatch
        self._finished_lengths: List[int] = []
        self._done_seen: Dict[int, int] = {}    # slot -> done entries seen
        chaos_done = {"kill": set(), "stall": set(), "corrupt": set()}
        up_streak = down_streak = 0
        G = 0.0
        for _ in range(self.n_replicas):
            self._spawn(root, chaos, at_clock=G)

        rounds = 0
        while True:
            live = self._live()
            # ---- 1. replica chaos due at this clock -----------------------
            by_slot = {rep.slot: rep for rep in live}
            for slot, step in sorted(chaos.kill_at_step.items()):
                if step <= G + 1e-9 and slot not in chaos_done["kill"] \
                        and slot in by_slot:
                    chaos_done["kill"].add(slot)
                    rep = by_slot[slot]
                    rep.kill()
                    rep.state = DEAD
                    rep.fail_reason = \
                        f"replica died (chaos kill at step {step:g})"
            for slot, step in sorted(chaos.stall_at_step.items()):
                if step <= G + 1e-9 and slot not in chaos_done["stall"] \
                        and slot in by_slot:
                    chaos_done["stall"].add(slot)
                    by_slot[slot].stalled_by_chaos = True
            for slot, step in sorted(chaos.corrupt_pool_at_step.items()):
                if step <= G + 1e-9 and slot not in chaos_done["corrupt"] \
                        and slot in by_slot:
                    rep = by_slot[slot]
                    pager = rep.scheduler.pager
                    if pager is not None:
                        chaos_done["corrupt"].add(slot)
                        # phantom refcount: the exact metadata drift
                        # audit_pool exists to catch
                        pager._refs[0] += 1

            # ---- 2. supervise + failover ---------------------------------
            candidates = [rep for rep in self._all
                          if rep.state != RETIRED and not rep.failed_over]
            for rep, reason in self.supervisor.audit(candidates, G, T):
                self._failover(rep, reason, G)
            live = self._live()
            unresolved = any(r.outcome is None for r in reqs)
            if not live and unresolved:
                # total fleet loss with work outstanding: spawn a cold
                # replacement (fresh slot — chaos schedules never re-fire)
                self._spawn(root, chaos, at_clock=G)
                live = self._live()

            # ---- 3. autoscale (hysteresis on measured queue depth) -------
            if self.autoscale is not None and live:
                asc = self.autoscale
                arrived = sum(1 for r in self._pendq
                              if r.arrival <= G + 1e-9)
                depth = arrived + sum(rep.queue_depth() for rep in live)
                per = depth / len(live)
                if per > asc.high_depth:
                    up_streak += 1
                    down_streak = 0
                elif per < asc.low_depth:
                    down_streak += 1
                    up_streak = 0
                else:
                    up_streak = down_streak = 0
                if up_streak >= asc.patience_windows \
                        and len(live) < asc.max_replicas:
                    rep = self._spawn(root, chaos, at_clock=G)
                    st["scale_ups"] += 1
                    tel.metrics.count("scale_ups")
                    tel.tracer.event("scale_up", G, cat="window",
                                     replica=rep.slot)
                    up_streak = 0
                    live = self._live()
                elif down_streak >= asc.patience_windows \
                        and len(live) > asc.min_replicas:
                    drained = [rep for rep in live if rep.last_status
                               and rep.last_status["drained"]
                               and rep.queue_depth() == 0]
                    if drained:
                        rep = max(drained, key=lambda rep: rep.slot)
                        rep.done_accum.extend(rep.stop())
                        if rep.scheduler._live is not None:
                            rep.scheduler._live["done"] = []  # in accum now
                        rep.state = RETIRED
                        self.router.forget_replica(rep.slot)
                        st["scale_downs"] += 1
                        tel.metrics.count("scale_downs")
                        tel.tracer.event("scale_down", G, cat="window",
                                         replica=rep.slot)
                        down_streak = 0
                        live = self._live()

            # ---- 4. feedback re-planning at drain boundaries -------------
            if self.replan is not None \
                    and len(self._finished_lengths) >= self.replan.min_samples:
                measured = statistics.fmean(self._finished_lengths)
                assumed = self._plan_mean()
                if abs(measured - assumed) / max(assumed, 1.0) \
                        > self.replan.drift_threshold:
                    new_plan = plan_lib.replan_from_lengths(
                        self.cfg, self.plan, self._finished_lengths)
                    if new_plan != self.plan:
                        self.plan = new_plan    # spawns use it immediately
                        st["replans"] += 1
                        tel.metrics.count("replans")
                        tel.tracer.event("replan", G, cat="window",
                                         measured_mean=round(measured, 3))
                    for rep in live:
                        if rep.last_status and rep.last_status["drained"] \
                                and rep.queue_depth() == 0 \
                                and rep.scheduler.plan != new_plan:
                            rep.swap_plan(
                                new_plan,
                                self._rng_for(root, rep.slot,
                                              rep.generation + 1),
                                chaos=chaos.request_chaos.get(rep.slot),
                                at_clock=G)
                            self._done_seen[rep.slot] = 0

            # ---- 4b. acceptance-adaptive speculative k (ISSUE 10) --------
            # the plan's spec Decision assumed a geometric acceptance rate;
            # the verifier measures the real one (spec_drafted/accepted
            # counters). At drain boundaries, invert the measured rate back
            # to per-token acceptance and re-run the same gain model — a
            # draft that misses steps k down (or off), one that hits grows
            # it. Same hot-swap discipline as the length replan above.
            if self.replan is not None and self.plan.spec_k >= 2:
                drafted = int(tel.metrics.counters.get(
                    "spec_drafted_tokens", 0))
                accepted = int(tel.metrics.counters.get(
                    "spec_accepted_tokens", 0))
                spec_plan = plan_lib.replan_spec_k(
                    self.cfg, self.plan, drafted_tokens=drafted,
                    accepted_tokens=accepted)
                if spec_plan != self.plan:
                    self.plan = spec_plan   # spawns use it immediately
                    st["spec_replans"] += 1
                    tel.metrics.count("replans")
                    tel.tracer.event(
                        "spec_replan", G, cat="spec",
                        spec_k=spec_plan.spec_k,
                        measured_rate=round(accepted / max(drafted, 1), 3))
                    for rep in live:
                        if rep.last_status and rep.last_status["drained"] \
                                and rep.queue_depth() == 0 \
                                and rep.scheduler.plan != spec_plan:
                            rep.swap_plan(
                                spec_plan,
                                self._rng_for(root, rep.slot,
                                              rep.generation + 1),
                                chaos=chaos.request_chaos.get(rep.slot),
                                at_clock=G)
                            self._done_seen[rep.slot] = 0

            # ---- 5. dispatch due arrivals (fair order, router placed) ----
            due = [r for r in self._pendq if r.arrival <= G + 1e-9
                   and self._hold.get(r.rid, -1.0) <= G + 1e-9]
            if due and live:
                for r in Router.fair_order(due):
                    rep = self.router.place(r, live)
                    r.replica = rep.slot
                    rep.scheduler.inject([r])
                    self._pendq.remove(r)
                    self._hold.pop(r.rid, None)
                tel.tracer.event("dispatch", G, cat="window",
                                 placed=len(due))

            # ---- 6. tick the fleet at G (lockstep) -----------------------
            for rep in sorted(live, key=lambda rep: rep.slot):
                if rep.stalled_by_chaos:
                    continue            # hung process: no response
                rep.tick(G)

            # ---- 7. finished-length feedback -----------------------------
            for rep in self._live():
                slive = rep.scheduler._live
                if slive is None:
                    continue
                seen = self._done_seen.get(rep.slot, 0)
                for r in slive["done"][seen:]:
                    if r.replica is None:
                        r.replica = rep.slot
                    if r.outcome is not None and r.outcome.status == "ok":
                        self._finished_lengths.append(
                            len(r.prompt) + len(r.out))
                self._done_seen[rep.slot] = len(slive["done"])

            tel.tracer.span("window", G, G + T, cat="window",
                            live=len(self._live()),
                            pending=len(self._pendq))
            st["rounds"] = rounds = rounds + 1
            G += T
            st["clock_steps"] = G
            if all(r.outcome is not None for r in reqs):
                break
            if rounds > self.max_rounds:
                raise RuntimeError(
                    f"replica set made no terminal progress within "
                    f"max_rounds ({self.max_rounds}) windows — "
                    "supervision/failover wedged")

        # ---- finalize: stop live replicas (all drained), merge done ------
        st["replicas_final"] = len(self._live())
        done: List[StreamRequest] = list(self._failed)
        for rep in self._all:
            if rep.state == LIVE:
                rep.done_accum.extend(rep.stop())
                rep.state = RETIRED
                if rep.scheduler._live is not None:
                    rep.scheduler._live["done"] = []   # folded into accum
            done.extend(rep.collect_done())
        # a request only ever resolves on one replica (or here): the merge
        # is the exactly-once proof surface the chaos tests sweep
        by_rid: Dict[int, StreamRequest] = {}
        for r in done:
            if r.rid in by_rid:
                raise RuntimeError(
                    f"rid {r.rid} resolved on two replicas — exactly-once "
                    "outcome invariant broken")
            by_rid[r.rid] = r
        st["outcomes"] = {k: 0 for k in guard_mod.OUTCOMES}
        for r in done:
            if r.outcome is not None:
                st["outcomes"][r.outcome.status] += 1
        st["router"] = dict(self.router.stats)
        # aggregate the per-replica scheduler counters the benchmarks read
        agg_keys = ("decode_chunks", "decode_steps", "prefill_batches",
                    "prefill_prompts", "prefill_real_tokens", "preemptions",
                    "shared_tokens_admitted", "cow_copies",
                    "stalled_boundaries", "step_retries",
                    "clamped_admissions", "idle_steps")
        st["fleet"] = {k: 0 for k in agg_keys}
        for rep in self._all:
            ps = rep.scheduler.phase_stats
            for k in agg_keys:
                st["fleet"][k] += ps.get(k, 0)
        # fleet-wide observability: per-tenant goodput/percentiles from the
        # shared registry, and ONE drift report over the whole run's
        # measured windows (the replica schedulers skip per-run drift on a
        # shared bundle — partial-fleet reports would double-count)
        tel.metrics.gauge("clock", G)
        st["tenants"] = tel.metrics.tenant_summary()
        st["drift"] = tel.detect_drift(self.plan).summary()
        return sorted(done, key=lambda r: r.rid)
