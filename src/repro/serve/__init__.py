from repro.serve import engine, facade, kvcache, paging, scheduler, sparse
from repro.serve.facade import LLM

__all__ = ["LLM", "engine", "facade", "kvcache", "paging", "scheduler",
           "sparse"]
