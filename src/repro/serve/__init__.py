from repro.serve import (chaos, engine, facade, guard, kvcache, paging,
                         replica, router, scheduler, sparse)
from repro.serve.facade import LLM

__all__ = ["LLM", "chaos", "engine", "facade", "guard", "kvcache", "paging",
           "replica", "router", "scheduler", "sparse"]
