from repro.serve import (chaos, engine, facade, guard, kvcache, paging,
                         replica, router, scheduler, sparse, telemetry)
from repro.serve.facade import LLM
from repro.serve.telemetry import Telemetry

__all__ = ["LLM", "Telemetry", "chaos", "engine", "facade", "guard",
           "kvcache", "paging", "replica", "router", "scheduler", "sparse",
           "telemetry"]
