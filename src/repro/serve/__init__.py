from repro.serve import engine, kvcache

__all__ = ["engine", "kvcache"]
