from repro.serve import engine, kvcache, sparse

__all__ = ["engine", "kvcache", "sparse"]
