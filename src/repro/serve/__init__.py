from repro.serve import engine, kvcache, paging, scheduler, sparse

__all__ = ["engine", "kvcache", "paging", "scheduler", "sparse"]
