from repro.serve import (chaos, engine, facade, guard, kvcache, paging,
                         scheduler, sparse)
from repro.serve.facade import LLM

__all__ = ["LLM", "chaos", "engine", "facade", "guard", "kvcache", "paging",
           "scheduler", "sparse"]
