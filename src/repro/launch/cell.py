"""Build one (arch × input-shape × mesh) cell: step function, abstract inputs
(ShapeDtypeStructs — no allocation), and in/out shardings from the HM-planner.

Shared by launch/dryrun.py (AOT lower+compile), benchmarks (roofline terms)
and the perf loop (plan overrides = the hillclimb knobs).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro.configs import ShapeConfig, get_config
from repro.core import planner
from repro.models import decoding, transformer as tfm
from repro.models.layers import COMPUTE_DTYPE
from repro.serve import engine
from repro.sharding import autoshard, specs as sh
from repro.train import loop as train_loop, optimizer as opt_lib


@dataclasses.dataclass
class CellBuild:
    """Everything needed to ``jax.jit(fn, ...).lower(*abstract_args)``."""
    name: str
    kind: str                     # train | prefill | decode
    fn: Callable
    abstract_args: Tuple
    in_shardings: Tuple
    out_shardings: Any
    donate_argnums: Tuple[int, ...]
    plan: planner.ModelPlan
    cfg: Any
    shape: ShapeConfig
    hints: Any = None

    def lower(self, mesh: Mesh):
        from repro.models import layers
        jitted = jax.jit(self.fn,
                         in_shardings=self.in_shardings,
                         out_shardings=self.out_shardings,
                         donate_argnums=self.donate_argnums)
        token = layers.set_hints(self.hints)   # intra-layer NoC-mode pins
        try:
            with mesh:
                return jitted.lower(*self.abstract_args)
        finally:
            layers.reset_hints(token)


def mesh_desc(mesh: Mesh) -> planner.MeshDesc:
    ax = sh.mesh_axis_sizes(mesh)
    return planner.MeshDesc(pod=ax.get("pod", 1), data=ax.get("data", 1),
                            model=ax.get("model", 1))


# ------------------------------------------------------------- input specs
def input_specs(cfg, shape: ShapeConfig) -> Dict[str, jax.ShapeDtypeStruct]:
    """ShapeDtypeStruct stand-ins for every model input of this cell.

    train/prefill: the token batch (+ stub frontend embeddings per spec);
    decode: the single-token batch (cache specs come from abstract_cache).
    """
    B = shape.global_batch
    if shape.kind == "decode":
        tok_shape = ((B, cfg.num_codebooks, 1) if cfg.num_codebooks > 1
                     else (B, 1))
        out = {"tokens": jax.ShapeDtypeStruct(tok_shape, jnp.int32)}
    else:
        S_text = shape.seq_len - (cfg.num_patches if cfg.frontend == "vision"
                                  else 0)
        tok_shape = ((B, cfg.num_codebooks, S_text) if cfg.num_codebooks > 1
                     else (B, S_text))
        out = {"tokens": jax.ShapeDtypeStruct(tok_shape, jnp.int32)}
        if shape.kind == "train":
            out["labels"] = jax.ShapeDtypeStruct(tok_shape, jnp.int32)
        if cfg.frontend == "vision":
            out["patch_embeds"] = jax.ShapeDtypeStruct(
                (B, cfg.num_patches, cfg.d_model), COMPUTE_DTYPE)
    if cfg.cross_attn_cond:
        out["cond"] = jax.ShapeDtypeStruct(
            (B, cfg.cross_attn_cond, cfg.d_model), COMPUTE_DTYPE)
    return out


# ------------------------------------------------------------- cell builders
def build_cell(arch: str, shape: ShapeConfig, mesh: Mesh, *,
               remat_policy: str = "dots", microbatches: int = 1,
               plan: Optional[planner.ModelPlan] = None) -> CellBuild:
    cfg = get_config(arch)
    md = mesh_desc(mesh)
    plan = plan or planner.plan_model(cfg, shape, md)
    mesh_axes = sh.mesh_axis_sizes(mesh)

    if shape.kind == "train":
        return _build_train(cfg, shape, mesh, plan, mesh_axes,
                            remat_policy, microbatches)
    if shape.kind == "prefill":
        return _build_prefill(cfg, shape, mesh, plan, mesh_axes)
    return _build_decode(cfg, shape, mesh, plan, mesh_axes)


def _named(mesh, tree):
    return sh.tree_named(mesh, tree)


def serve_partition_specs(serve_plan) -> Dict[str, Dict]:
    """Placement for the *serving* path, read off a frozen ServePlan.

    ISSUE 10 subsumes this module's per-cell planner consultation for
    serving: ``core.plan.plan_serve``'s mesh resolution stage freezes one
    ``hmmesh.Mode`` per data type (weights / KV pages / activations /
    experts) into the plan itself, and ``serve.shard.partition_specs``
    reads them back in the same (mode, PartitionSpec) vocabulary the
    autoshard hints use here. Launch tooling that reports the serving
    placement (dryrun cost sheets) asks the plan — it never re-runs
    ``planner.plan_model`` and risks disagreeing with what serving does."""
    from repro.serve import shard
    return shard.partition_specs(serve_plan)


def _build_train(cfg, shape, mesh, plan, mesh_axes, remat_policy,
                 microbatches) -> CellBuild:
    opt_cfg = opt_lib.OptimizerConfig()
    hints = autoshard.make_hints(plan, mesh, shape.global_batch)
    step = train_loop.make_train_step(cfg, opt_cfg,
                                      remat_policy=remat_policy,
                                      microbatches=microbatches,
                                      hints=hints)
    a_params, a_opt = train_loop.abstract_train_state(cfg)
    a_batch = input_specs(cfg, shape)

    p_spec = autoshard.param_specs(a_params, plan, mesh_axes)
    opt_spec = opt_lib.AdamWState(step=P(), mu=p_spec,
                                  nu=jax.tree.map(lambda s: s, p_spec))
    b_spec = autoshard.batch_spec(a_batch, plan, mesh_axes)
    metrics_spec = jax.eval_shape(step, a_params, a_opt, a_batch)[2]
    m_spec = jax.tree.map(lambda _: P(), metrics_spec)

    return CellBuild(
        name=f"{cfg.name}:{shape.name}", kind="train", fn=step,
        abstract_args=(a_params, a_opt, a_batch),
        in_shardings=(_named(mesh, p_spec), _named(mesh, opt_spec),
                      _named(mesh, b_spec)),
        out_shardings=(_named(mesh, p_spec), _named(mesh, opt_spec),
                       _named(mesh, m_spec)),
        donate_argnums=(0, 1), plan=plan, cfg=cfg, shape=shape,
        hints=hints)


def _build_prefill(cfg, shape, mesh, plan, mesh_axes) -> CellBuild:
    cache_len = shape.seq_len
    hints = autoshard.make_hints(plan, mesh, shape.global_batch)

    def prefill_step(params, batch):
        return decoding.prefill(params, batch["tokens"], cfg, cache_len,
                                patch_embeds=batch.get("patch_embeds"),
                                cond=batch.get("cond"), hints=hints)

    a_params = tfm.abstract_params(cfg)
    a_batch = input_specs(cfg, shape)
    p_spec = autoshard.param_specs(a_params, plan, mesh_axes)
    b_spec = autoshard.batch_spec(a_batch, plan, mesh_axes)

    a_logits, a_cache = jax.eval_shape(prefill_step, a_params, a_batch)
    c_spec = autoshard.cache_spec(a_cache, plan, mesh_axes)
    dp = sh.dp_axes(mesh_axes)
    l_spec = P(*([sh.maybe(dp, a_logits.shape[0], mesh_axes)] +
                 [None] * (len(a_logits.shape) - 1)))

    return CellBuild(
        name=f"{cfg.name}:{shape.name}", kind="prefill", fn=prefill_step,
        abstract_args=(a_params, a_batch),
        in_shardings=(_named(mesh, p_spec), _named(mesh, b_spec)),
        out_shardings=(_named(mesh, l_spec), _named(mesh, c_spec)),
        donate_argnums=(), plan=plan, cfg=cfg, shape=shape,
        hints=hints)


def _build_decode(cfg, shape, mesh, plan, mesh_axes) -> CellBuild:
    B, cache_len = shape.global_batch, shape.seq_len
    hints = autoshard.make_hints(plan, mesh, B)

    def serve_step(params, cache, batch, pos):
        return decoding.serve_step(params, cache, batch["tokens"], pos, cfg,
                                   cond=batch.get("cond"), hints=hints)

    a_params = tfm.abstract_params(cfg)
    a_cache = decoding.abstract_cache(cfg, B, cache_len)
    a_batch = input_specs(cfg, shape)
    a_pos = jax.ShapeDtypeStruct((), jnp.int32)

    p_spec = autoshard.param_specs(a_params, plan, mesh_axes)
    c_spec = autoshard.cache_spec(a_cache, plan, mesh_axes)
    b_spec = autoshard.batch_spec(a_batch, plan, mesh_axes)

    a_logits, _ = jax.eval_shape(serve_step, a_params, a_cache, a_batch, a_pos)
    dp = sh.dp_axes(mesh_axes)
    l_spec = P(*([sh.maybe(dp, a_logits.shape[0], mesh_axes)] +
                 [None] * (len(a_logits.shape) - 1)))

    return CellBuild(
        name=f"{cfg.name}:{shape.name}", kind="decode", fn=serve_step,
        abstract_args=(a_params, a_cache, a_batch, a_pos),
        in_shardings=(_named(mesh, p_spec), _named(mesh, c_spec),
                      _named(mesh, b_spec), _named(mesh, P())),
        out_shardings=(_named(mesh, l_spec), _named(mesh, c_spec)),
        donate_argnums=(1,), plan=plan, cfg=cfg, shape=shape,
        hints=hints)
