"""Launch layer: production mesh, AOT multi-pod dry-run, train/serve drivers."""
