"""Serving driver: --arch <id> [--reduced] batched continuous decoding.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen2.5-3b --reduced \
        --requests 16 --max-new 24
"""
from __future__ import annotations

import argparse
import time

import jax

from repro.configs import ARCH_NAMES, get_config
from repro.core import plan as plan_lib
from repro.models import transformer as tfm
from repro.serve.engine import DecodeEngine, Request


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_NAMES, required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--cache-len", type=int, default=128)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_config(args.arch + ("-reduced" if args.reduced else ""))
    rng = jax.random.PRNGKey(args.seed)
    params = tfm.init_params(rng, cfg)

    import numpy as np
    nprng = np.random.default_rng(args.seed)
    reqs = [Request(rid=i,
                    prompt=list(nprng.integers(
                        2, cfg.vocab_size, size=args.prompt_len)),
                    max_new=args.max_new)
            for i in range(args.requests)]

    engine = DecodeEngine(cfg, params,
                          plan_lib.plan_for_engine(cfg, slots=args.slots,
                                                   cache_len=args.cache_len),
                          temperature=args.temperature)
    t0 = time.time()
    done = engine.run(reqs, rng=jax.random.PRNGKey(args.seed + 1))
    dt = time.time() - t0
    total_new = sum(len(r.out) for r in done)
    print(f"served {len(done)} requests, {total_new} tokens "
          f"in {dt:.1f}s ({total_new / dt:.1f} tok/s)")
    for r in done[:4]:
        print(f"  req {r.rid}: {len(r.out)} new tokens, "
              f"first 8 = {r.out[:8]}")
    return done


if __name__ == "__main__":
    main()
