"""Production mesh construction (DESIGN.md §5).

``pod`` is the paper's inter-cluster 2D-mesh level; (`data`,`model`) are the
intra-pod axes (the all-to-all-within-cluster level). Defined as FUNCTIONS so
importing this module never touches jax device state — only launch/dryrun.py
(which sets XLA_FLAGS first) ever builds the 256/512-device meshes.
"""
from __future__ import annotations

import jax
from jax.sharding import Mesh

# AxisType landed after jax 0.4.x; older versions only have Auto meshes, which
# is exactly what we request — so its absence changes nothing.
try:
    from jax.sharding import AxisType
except ImportError:          # pragma: no cover - jax < 0.5
    AxisType = None


def _make(shape, axes) -> Mesh:
    n = 1
    for s in shape:
        n *= s
    devs = jax.devices()
    assert len(devs) >= n, (f"need {n} devices, have {len(devs)} — the dry-run "
                            "must set XLA_FLAGS=--xla_force_host_platform_"
                            "device_count=512 before importing jax")
    kw = {} if AxisType is None else {
        "axis_types": (AxisType.Auto,) * len(axes)}
    return jax.make_mesh(shape, axes, devices=devs[:n], **kw)


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _make(shape, axes)


def make_local_mesh(model: int = 1) -> Mesh:
    """Mesh over whatever devices exist (CPU: 1) — examples and smoke tests."""
    n = len(jax.devices())
    assert n % model == 0
    return _make((n // model, model), ("data", "model"))


def make_scaled_mesh(chips: int, *, model: int = 16, pods: int = 1) -> Mesh:
    """Arbitrary-scale mesh for the strong-scaling study (Fig. 14 analogue)."""
    per_pod = chips // pods
    assert per_pod % model == 0
    data = per_pod // model
    if pods > 1:
        return _make((pods, data, model), ("pod", "data", "model"))
    return _make((data, model), ("data", "model"))
