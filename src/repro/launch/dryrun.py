import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod AOT dry-run (deliverable e).

For every (architecture × input shape × mesh) cell:
    with mesh:
        lowered  = jax.jit(step, in_shardings=…, out_shardings=…).lower(**specs)
        compiled = lowered.compile()
        memory_analysis()  — proves it fits per-chip HBM
        cost_analysis()    — FLOPs/bytes for §Roofline
plus the collective-bytes HLO parse (core.eyexam) for the third roofline term.

Usage:
    python -m repro.launch.dryrun --arch gemma2-2b --shape train_4k --mesh multi
    python -m repro.launch.dryrun --all --mesh both --out results/dryrun
Each cell writes one JSON under --out (skipped if it already exists, so the
batch is resumable). The 512 placeholder host devices exist ONLY here.
"""
import argparse
import json
import time
import traceback
from typing import Dict, Optional

import jax

from repro.configs import (ARCH_NAMES, SHAPES, cell_is_runnable, get_config,
                           train_flops_per_token)
from repro.core import eyexam
from repro.launch.cell import build_cell, mesh_desc
from repro.launch.mesh import make_production_mesh


def _memory_dict(mem) -> Dict[str, float]:
    out = {}
    for k in ("argument_size_in_bytes", "output_size_in_bytes",
              "temp_size_in_bytes", "alias_size_in_bytes",
              "generated_code_size_in_bytes"):
        v = getattr(mem, k, None)
        if v is not None:
            out[k] = float(v)
    return out


def model_flops(cfg, shape) -> float:
    """MODEL_FLOPS for the §Roofline 'useful compute' ratio (whole step)."""
    n_active = cfg.param_count(active_only=True)
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n_active * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n_active * tokens
    return 2.0 * n_active * shape.global_batch        # one token per slot


def run_cell(arch: str, shape_name: str, multi_pod: bool, *,
             remat_policy: str = "dots", microbatches: int = 1,
             plan=None) -> Dict:
    shape = SHAPES[shape_name]
    mesh_name = "2x16x16" if multi_pod else "16x16"
    rec: Dict = {"arch": arch, "shape": shape_name, "mesh": mesh_name,
                 "kind": shape.kind}
    if not cell_is_runnable(arch, shape_name):
        rec.update(status="skipped",
                   reason="pure full-attention arch at 500k ctx "
                          "(DESIGN.md §4 long_500k applicability)")
        return rec
    t0 = time.monotonic()
    mesh = make_production_mesh(multi_pod=multi_pod)
    cfg = get_config(arch)
    try:
        cell = build_cell(arch, shape, mesh, remat_policy=remat_policy,
                          microbatches=microbatches, plan=plan)
        lowered = cell.lower(mesh)
        compiled = lowered.compile()
        chips = mesh.devices.size
        hlo = compiled.as_text()
        roof = eyexam.roofline_from_compiled(compiled, chips, hlo_text=hlo)
        mem = _memory_dict(compiled.memory_analysis())
        mf = model_flops(cfg, shape)
        rec.update(
            status="ok",
            compile_s=round(time.monotonic() - t0, 1),
            chips=chips,
            plan_rule=cell.plan.param_rule,
            plan_flags={
                "experts": cell.plan.shard_experts,
                "heads": cell.plan.shard_heads,
                "kv_heads": cell.plan.shard_kv_heads,
                "ffn": cell.plan.shard_ffn,
                "vocab": cell.plan.shard_vocab,
                "cache_seq": cell.plan.cache_seq_sharded,
            },
            memory=mem,
            hbm_per_chip_gb=round(
                (mem.get("argument_size_in_bytes", 0) +
                 mem.get("output_size_in_bytes", 0) +
                 mem.get("temp_size_in_bytes", 0) -
                 mem.get("alias_size_in_bytes", 0)) / 1e9, 3),
            flops_per_chip=roof.flops,
            hbm_bytes_per_chip=roof.hbm_bytes,
            coll_bytes_per_chip=roof.coll_bytes,
            coll_by_op={k: v for k, v in roof.per_op_coll.items()
                        if k != "counts"},
            coll_counts=roof.per_op_coll.get("counts"),
            t_compute_s=roof.t_compute,
            t_memory_s=roof.t_memory,
            t_collective_s=roof.t_collective,
            bound=roof.bound,
            model_flops_total=mf,
            model_flops_per_chip=mf / chips,
            useful_flops_ratio=(mf / chips) / max(roof.flops, 1.0),
            roofline_fraction=roof.fraction_of_roofline(mf / chips),
        )
    except Exception as e:  # noqa: BLE001 — record the failure, don't die
        rec.update(status="error", error=f"{type(e).__name__}: {e}",
                   traceback=traceback.format_exc()[-2000:],
                   compile_s=round(time.monotonic() - t0, 1))
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_NAMES)
    ap.add_argument("--shape", choices=list(SHAPES))
    ap.add_argument("--mesh", choices=["single", "multi", "both"],
                    default="both")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default="results/dryrun")
    ap.add_argument("--remat", default="full")
    ap.add_argument("--microbatches", type=int, default=4)
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args()

    os.makedirs(args.out, exist_ok=True)
    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]
    cells = ([(a, s) for a in ARCH_NAMES for s in SHAPES]
             if args.all else [(args.arch, args.shape)])
    for arch, shape in cells:
        for mp in meshes:
            tag = f"{arch}__{shape}__{'2x16x16' if mp else '16x16'}"
            path = os.path.join(args.out, tag + ".json")
            if os.path.exists(path) and not args.force:
                print(f"SKIP {tag} (exists)")
                continue
            rec = run_cell(arch, shape, mp, remat_policy=args.remat,
                           microbatches=args.microbatches)
            with open(path, "w") as f:
                json.dump(rec, f, indent=1)
            status = rec["status"]
            extra = (f" bound={rec.get('bound')} "
                     f"t=({rec.get('t_compute_s', 0):.2e},"
                     f"{rec.get('t_memory_s', 0):.2e},"
                     f"{rec.get('t_collective_s', 0):.2e})"
                     if status == "ok" else rec.get("error", rec.get("reason")))
            print(f"{status.upper():7s} {tag} {extra}", flush=True)


if __name__ == "__main__":
    main()
