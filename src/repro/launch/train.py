"""Training driver: --arch <id> [--reduced] with fault-tolerant supervision.

On a real cluster this runs under the production mesh with the HM-planned
shardings; on this CPU container it drives reduced configs end-to-end
(checkpoints, restarts, straggler detection and metrics all live).

    PYTHONPATH=src python -m repro.launch.train --arch gemma2-2b --reduced \
        --steps 50 --batch 8 --seq 64
"""
from __future__ import annotations

import argparse
import json
import os
import time

import jax
import numpy as np

from repro.configs import ARCH_NAMES, ShapeConfig, get_config
from repro.core import planner
from repro.data import pipeline as data_lib
from repro.launch import mesh as mesh_lib
from repro.launch.cell import mesh_desc
from repro.runtime.fault_tolerance import FaultToleranceConfig, Supervisor
from repro.sharding import autoshard, specs as sh
from repro.train import loop as train_loop, optimizer as opt_lib


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_NAMES, required=True)
    ap.add_argument("--reduced", action="store_true",
                    help="tiny same-family config (CPU)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--remat", default="none")
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args()

    cfg = get_config(args.arch + ("-reduced" if args.reduced else ""))
    mesh = mesh_lib.make_local_mesh()
    mesh_axes = sh.mesh_axis_sizes(mesh)
    shape = ShapeConfig("cli", "train", args.seq, args.batch)
    plan = planner.plan_model(cfg, shape, mesh_desc(mesh))
    hints = (autoshard.make_hints(plan, mesh, args.batch)
             if mesh.devices.size > 1 else None)

    opt_cfg = opt_lib.OptimizerConfig(peak_lr=args.lr,
                                      warmup_steps=min(20, args.steps // 5),
                                      total_steps=args.steps)
    step_fn = train_loop.make_train_step(cfg, opt_cfg,
                                         remat_policy=args.remat,
                                         microbatches=args.microbatches,
                                         hints=hints)
    jitted = jax.jit(step_fn, donate_argnums=(0, 1))

    dcfg = data_lib.DataConfig(
        seq_len=args.seq, global_batch=args.batch,
        vocab_size=cfg.vocab_size, seed=args.seed,
        num_codebooks=cfg.num_codebooks,
        num_patches=cfg.num_patches if cfg.frontend == "vision" else 0,
        d_model=cfg.d_model, cond_len=cfg.cross_attn_cond)

    def data_fn(step: int):
        return {k: jax.numpy.asarray(v)
                for k, v in data_lib.synth_batch(dcfg, step).items()}

    def wrapped_step(state, batch):
        params, opt_state = state
        params, opt_state, metrics = jitted(params, opt_state, batch)
        return (params, opt_state), metrics

    def init_state():
        return train_loop.init_train_state(jax.random.PRNGKey(args.seed), cfg)

    ckpt_dir = args.ckpt_dir or os.path.join(
        "results", "ckpt", cfg.name.replace("/", "_"))
    sup = Supervisor(
        FaultToleranceConfig(checkpoint_dir=ckpt_dir,
                             checkpoint_every=args.ckpt_every),
        step_fn=wrapped_step, data_fn=data_fn, init_state_fn=init_state)

    t0 = time.time()
    result = sup.run(args.steps)
    dt = time.time() - t0
    for m in result["metrics"]:
        if m["step"] % args.log_every == 0 or m["step"] == args.steps - 1:
            print(f"step {m['step']:5d} loss={m.get('loss', 0):.4f} "
                  f"acc={m.get('accuracy', 0):.4f} "
                  f"gnorm={m.get('grad_norm', 0):.2f}")
    toks = args.steps * args.batch * args.seq
    print(f"done: {args.steps} steps, {dt:.1f}s, {toks / dt:.0f} tok/s, "
          f"restarts={result['restarts']}")
    return result


if __name__ == "__main__":
    main()
