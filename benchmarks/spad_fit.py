"""Table III reproduction: sparse-AlexNet weights packed into the per-PE SPad,
plus the TPU analogue (BCSC tile fit in VMEM via core.dataflow).

Paper: nominal weights per PE exceed the 192-entry SPad in most layers, but
the compressed (non-zero) count fits — mapping by nnz instead of nominal also
reduces workload imbalance (§IV-A).
"""
from __future__ import annotations

from typing import Dict

from benchmarks.workloads import alexnet
from repro.core import dataflow

SPAD_CAPACITY = 192      # weights per PE (96×24b data SPad @ 12b/weight)

# paper Table III: (M0, C0, S) per layer
TABLE_III = {
    "CONV1": (12, 1, 11), "CONV2": (32, 2, 5), "CONV3": (32, 5, 3),
    "CONV4": (24, 4, 3), "CONV5": (32, 4, 3), "FC6": (32, 2, 6),
    "FC7": (32, 15, 1), "FC8": (32, 15, 1),
}
PAPER_COMPRESSED = {"CONV1": 64, "CONV2": 86, "CONV3": 126, "CONV4": 100,
                    "CONV5": 174, "FC6": 92, "FC7": 84, "FC8": 170}


def run() -> Dict:
    layers = {l.name: l for l in alexnet(sparse=True)}
    out: Dict = {}
    for name, (m0, c0, s) in TABLE_III.items():
        nominal = m0 * c0 * s
        sp = layers[name].sparsity_w
        compressed = int(round(nominal * (1 - sp)))
        out[name] = {
            "M0": m0, "C0": c0, "S": s,
            "nominal": nominal,
            "compressed_model": compressed,
            "compressed_paper": PAPER_COMPRESSED[name],
            "nominal_fits": nominal <= SPAD_CAPACITY,
            "compressed_fits": compressed <= SPAD_CAPACITY,
        }
    # TPU analogue: a d_model x d_ff matmul tile must fit VMEM
    t = dataflow.rs_matmul_tiling(4096, 4096, 14336)
    out["_vmem_analogue"] = dataflow.spad_fit_report(
        4096 * 14336, sparsity=0.6, tiling=t)
    return out


def main() -> Dict:
    res = run()
    print("=== Table III: sparse-AlexNet weights per PE vs SPad (192) ===")
    print(f"{'layer':7s} {'nominal':>8s} {'comp(model)':>12s} "
          f"{'comp(paper)':>12s} {'fits?':>6s}")
    for name, r in res.items():
        if name.startswith("_"):
            continue
        print(f"{name:7s} {r['nominal']:8d} {r['compressed_model']:12d} "
              f"{r['compressed_paper']:12d} "
              f"{'yes' if r['compressed_fits'] else 'NO':>6s}")
    v = res["_vmem_analogue"]
    print(f"VMEM analogue (4096x14336 @ 60% sparse): tile "
          f"{v['resident_tile_bytes'] / 1024:.0f} KiB resident, "
          f"fits={v['fits_vmem']}")
    return res


if __name__ == "__main__":
    main()
