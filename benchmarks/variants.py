"""Fig. 19/21 reproduction: Eyeriss v1 vs v1.5 vs v2 speedups.

    v1   — broadcast NoC, dense PEs           (192 PEs, 1 MAC/PE)
    v1.5 — hierarchical-mesh NoC, dense PEs   (192 PEs, 1 MAC/PE)
    v2   — HM-NoC + sparse PEs + SIMD         (192 PEs, 2 MACs/PE, zero-skip)

Paper headline ratios (batch 1): sparse AlexNet on v2 = 42.5× over v1;
sparse MobileNet on v2 = 12.6× over v1; HM-NoC alone gives ~5.6× on MobileNet.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, List

from benchmarks.workloads import NETWORKS, alexnet, mobilenet
from repro.core import eyexam
from repro.core.reuse import LayerShape

N_PES = 192


def _acc(noc: str, simd: bool) -> eyexam.AcceleratorModel:
    return eyexam.AcceleratorModel(
        n_pes=N_PES, array_h=12, array_w=16, noc=noc, cluster_size=12,
        macs_per_pe=2 if simd else 1)


def _cycles(layers: List[LayerShape], acc, sparse_skip: bool) -> float:
    total = 0.0
    for l in layers:
        bound = eyexam.seven_steps(l, acc)[-1]["bound"]
        macs = l.effective_macs if sparse_skip else l.macs
        # DW layers can't use SIMD (1 in/out channel — paper §V-A2)
        if sparse_skip and acc.macs_per_pe > 1 and l.G > 1 and l.M == 1:
            bound = bound / acc.macs_per_pe
        total += macs / max(bound, 1e-9)
    return total


def run(batch: int = 1) -> Dict:
    out: Dict = {}
    for net_name, fn in (("alexnet", alexnet), ("mobilenet", mobilenet)):
        dense = fn(batch, sparse=False)
        sparse = fn(batch, sparse=True)
        c_v1 = _cycles(dense, _acc("broadcast", False), False)
        c_v15 = _cycles(dense, _acc("hmnoc", False), False)
        c_v2 = _cycles(dense, _acc("hmnoc", True), True)
        c_v2s = _cycles(sparse, _acc("hmnoc", True), True)
        out[net_name] = {
            "v1": 1.0,
            "v1.5": c_v1 / c_v15,
            "v2": c_v1 / c_v2,
            "v2_sparse": c_v1 / c_v2s,
            "cycles": {"v1": c_v1, "v1.5": c_v15, "v2": c_v2,
                       "v2_sparse": c_v2s},
        }
    return out


PAPER = {"alexnet": {"v2_sparse": 42.5}, "mobilenet": {"v2_sparse": 12.6}}


def main() -> Dict:
    res = run()
    print("=== Fig.19/21: speedup over Eyeriss v1 (batch 1) ===")
    print(f"{'net':10s} {'v1':>6s} {'v1.5':>7s} {'v2':>7s} "
          f"{'v2+sparse':>10s} {'paper v2+sparse':>16s}")
    for net, r in res.items():
        print(f"{net:10s} {r['v1']:6.1f} {r['v1.5']:7.1f} {r['v2']:7.1f} "
              f"{r['v2_sparse']:10.1f} {PAPER[net]['v2_sparse']:16.1f}")
    return res


if __name__ == "__main__":
    main()
