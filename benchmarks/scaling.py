"""Fig. 14 reproduction: strong scaling of Eyeriss v2 (HM-NoC) vs v1
(broadcast NoC) at 256 / 1024 / 16384 PEs, batch 1, via the Eyexam model.

Paper claims: v2 scales linearly 256→1024 and reaches >85% of linear at
16384 PEs on AlexNet/GoogLeNet/MobileNet; v1 barely improves (FC layers in
AlexNet and DW layers in MobileNet are NoC-bandwidth-bound).
"""
from __future__ import annotations

import json
import math
from typing import Dict

from benchmarks.workloads import NETWORKS
from repro.core import eyexam

SCALES = (256, 1024, 16384)


def _acc(n_pes: int, noc: str) -> eyexam.AcceleratorModel:
    side = int(math.sqrt(n_pes))
    return eyexam.AcceleratorModel(
        n_pes=n_pes, array_h=side, array_w=side, noc=noc,
        cluster_size=16)           # v2 scales with 4×4-PE clusters (§III-D)


def run(batch: int = 1) -> Dict:
    out: Dict = {"scales": list(SCALES), "networks": {}}
    for net, fn in NETWORKS.items():
        layers = fn(batch)
        rows = {}
        for noc in ("hmnoc", "broadcast"):
            perf = [eyexam.network_performance(layers, _acc(n, noc))
                    for n in SCALES]
            rows[noc] = [p / perf[0] for p in perf]   # normalized to 256 PEs
        linear = [n / SCALES[0] for n in SCALES]
        rows["v2_frac_of_linear_at_16384"] = rows["hmnoc"][-1] / linear[-1]
        rows["v1_frac_of_linear_at_16384"] = rows["broadcast"][-1] / linear[-1]
        out["networks"][net] = rows
    return out


def main() -> Dict:
    res = run()
    print("=== Fig.14: strong scaling, normalized performance "
          "(256 -> 1024 -> 16384 PEs) ===")
    for net, rows in res["networks"].items():
        v2 = " ".join(f"{x:7.1f}" for x in rows["hmnoc"])
        v1 = " ".join(f"{x:7.1f}" for x in rows["broadcast"])
        print(f"{net:10s} v2(HM-NoC) {v2}   "
              f"[{rows['v2_frac_of_linear_at_16384'] * 100:5.1f}% of linear]")
        print(f"{'':10s} v1(bcast)  {v1}   "
              f"[{rows['v1_frac_of_linear_at_16384'] * 100:5.1f}% of linear]")
    return res


if __name__ == "__main__":
    main()
