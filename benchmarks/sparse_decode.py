"""Sparse/compressed decode analysis — what actually bounds the decode cells,
and which compression lever (paper §IV) moves each regime.

Measured finding (see run()): at decode_32k's batch of 128 slots the memory
term is **KV-cache streaming** (the whole 32k-token cache is read every
step; weights amortize over the 128 slots — weight-stream share < 1%).
Weight sparsity (BCSC, the paper's Sparse PE) therefore pays at *small
batch*, while at large batch the paper-faithful compression move is applying
the same keep-it-compressed idea to the **cache** (int8 KV ≈ ×2 bytes).
This mirrors the paper's own Table VI shift: compact models (less reuse)
move the bottleneck from compute to delivery, and the right compression
target follows the bottleneck.

ISSUE 1 additions:
* ``kernel_proxy`` — dense rs_matmul vs bcsc_gemv at decode shapes, grid-step
  counts (the interpret-mode proxy; on TPU the same harness wall-clocks).
* ``decode_benchmark`` — DecodeEngine tokens/sec, dense vs BCSC-packed params
  at batch {1, 4, 8}; written to BENCH_sparse_decode.json as the repo's first
  benchmark-trajectory point.

    PYTHONPATH=src python benchmarks/sparse_decode.py [--smoke] [--no-engine]
"""
from __future__ import annotations

import argparse
import glob
import json
import os
import time
from typing import Dict

import numpy as np

from repro.configs import get_config
from repro.core import eyexam
from repro.models import decoding

SPARSITIES = (0.5, 0.75, 0.9)
BCSC_OVERHEAD = 1.02     # index-vector bytes per payload byte
BENCH_JSON = "BENCH_sparse_decode.json"


def run(dryrun_dir: str = "results/dryrun_opt") -> Dict:
    out: Dict = {}
    for f in sorted(glob.glob(os.path.join(dryrun_dir,
                                           "*decode_32k__16x16*"))):
        r = json.load(open(f))
        if r["status"] != "ok":
            continue
        cfg = get_config(r["arch"])
        chips = r["chips"]
        # ANALYTIC decode stream model (the measured term stays conservative
        # on the CPU proxy — scan-carry cache rewrites that TPU aliasing
        # elides; see EXPERIMENTS.md D1). Per chip, per decode step:
        #   weights (active, bf16) + full KV/state-cache read.
        w_bytes = cfg.param_count(active_only=True) * 2 / chips
        cache = decoding.abstract_cache(cfg, 128, 32768)
        import jax
        c_bytes = sum(l.size * l.dtype.itemsize
                      for l in jax.tree.leaves(cache)) / chips
        t_w = w_bytes / eyexam.HBM_BW
        t_c = c_bytes / eyexam.HBM_BW
        t128 = t_w + t_c                      # batch-128 step
        rows: Dict = {
            "t_analytic_128_ms": t128 * 1e3,
            "cache_share": t_c / t128,
            "int8_cache_speedup": t128 / (t_w + t_c / 2),
        }
        # batch-1 regime (one slot): weights dominate; BCSC pays directly
        t1 = t_w + t_c / 128
        for sp in SPARSITIES:
            t1_sp = t_w * (1 - sp) * BCSC_OVERHEAD + t_c / 128
            rows[f"b1_bcsc_speedup_{sp:.2f}"] = t1 / t1_sp
        out[r["arch"]] = rows
    return out


# ------------------------------------------------------- ISSUE 1: fast path
def kernel_proxy(sparsities=SPARSITIES + (0.7,), K: int = 256, N: int = 512,
                 block: int = 16) -> Dict:
    """Batch-1 MLP projection: dense rs_matmul grid steps vs bcsc_gemv nnzb.

    Grid steps are the interpret-mode cost proxy (each step is one MXU-tile
    visit); both sides are normalized to the same (bk, bn) tiles so the ratio
    is exactly the structural-skip factor the paper's Sparse PE claims (§IV).
    """
    import jax.numpy as jnp
    from repro.core import sparsity as sp
    from repro.kernels import ops

    rng = np.random.default_rng(0)
    w = rng.standard_normal((K, N)).astype(np.float32)
    dense_blocks = (K // block) * (N // block)
    out: Dict = {"shape": [K, N], "block": block,
                 "dense_grid_steps": dense_blocks}
    for s in sorted(sparsities):
        ws = np.asarray(sp.block_magnitude_prune(jnp.asarray(w), s,
                                                 block, block))
        m = sp.bcsc_encode(ws, block, block)
        blocks, _, _, _ = ops.prepare_bcsc(m)
        steps = int(blocks.shape[0])
        out[f"sparsity_{s:.2f}"] = {
            "gemv_grid_steps": steps,
            "speedup_vs_dense": dense_blocks / max(steps, 1),
        }
    return out


def decode_benchmark(batches=(1, 4, 8), max_new: int = 8,
                     arch: str = "qwen2.5-3b-reduced",
                     sparsity: float = 0.75, sync_every: int = 4) -> Dict:
    """DecodeEngine tokens/sec, dense vs BCSC-packed MLP weights.

    On this CPU container kernels run interpret=True, so the sparse wall-clock
    is NOT the headline (Python-interpreted kernels); the grid-step proxy
    (kernel_proxy) carries the perf claim. On TPU the same harness times the
    compiled kernels. host_syncs per generated token is reported as the
    device-residency check (must be << 1).
    """
    import jax
    import jax.numpy as jnp
    from repro.core import sparsity as sp
    from repro.models import transformer as tfm
    from repro.serve import sparse as sps
    from repro.serve.engine import DecodeEngine, Request

    cfg = get_config(arch)
    params = tfm.init_params(jax.random.PRNGKey(0), cfg)
    for slot in params.get("blocks", {}):
        mlp = params["blocks"][slot].get("mlp")
        if mlp:
            for nm in list(mlp):
                w = mlp[nm]
                mlp[nm] = jnp.stack([
                    sp.block_magnitude_prune(w[l], sparsity, 16, 16)
                    for l in range(w.shape[0])])
    packed, stats = sps.sparsify_mlp_params(params, cfg, sparsity=0.0)

    out: Dict = {"arch": arch, "sparsity": sparsity, "max_new": max_new,
                 "block_density": stats.get("block_density"),
                 "interpret_mode": jax.default_backend() != "tpu",
                 "batches": {}}
    for b in batches:
        row: Dict = {}
        for name, p in (("dense", params), ("sparse", packed)):
            reqs = [Request(rid=i, prompt=[5, 6, 7, 8], max_new=max_new)
                    for i in range(b)]
            eng = DecodeEngine(cfg, p, slots=b, cache_len=32,
                               eos_id=-1, sync_every=sync_every)
            eng.run([Request(rid=99, prompt=[5, 6, 7, 8], max_new=max_new)
                     for _ in range(b)])          # warmup / compile
            eng.host_syncs = 0       # count the timed run only
            t0 = time.perf_counter()
            done = eng.run(reqs)
            dt = time.perf_counter() - t0
            toks = sum(len(r.out) for r in done)
            row[name] = {"tokens_per_s": toks / max(dt, 1e-9),
                         "host_syncs_per_token": eng.host_syncs / max(toks, 1)}
        out["batches"][str(b)] = row
    return out


def main(smoke: bool = False, engine: bool = True) -> Dict:
    res: Dict = {"analytic": _analytic_main(), "kernel_proxy": kernel_proxy()}
    if engine:
        res["decode"] = decode_benchmark(
            batches=(1,) if smoke else (1, 4, 8),
            max_new=4 if smoke else 8)

    kp = res["kernel_proxy"]
    print("=== Batch-1 BCSC GEMV vs dense RS grid steps "
          f"({kp['shape'][0]}x{kp['shape'][1]}, {kp['block']}-blocks) ===")
    print(f"dense grid steps: {kp['dense_grid_steps']}")
    for k in sorted(k for k in kp if k.startswith("sparsity_")):
        r = kp[k]
        print(f"  {k[9:]:>5s} block-sparse: {r['gemv_grid_steps']:5d} steps "
              f"-> {r['speedup_vs_dense']:.2f}x fewer")
    if engine:
        d = res["decode"]
        mode = "interpret (proxy only)" if d["interpret_mode"] else "compiled"
        print(f"=== DecodeEngine tokens/sec [{mode}] "
              f"{d['arch']} @ {d['sparsity']:.0%} sparsity ===")
        for b, row in d["batches"].items():
            print(f"  batch {b}: dense {row['dense']['tokens_per_s']:8.2f} t/s"
                  f"  sparse {row['sparse']['tokens_per_s']:8.2f} t/s"
                  f"  (syncs/token {row['sparse']['host_syncs_per_token']:.3f})")

    with open(BENCH_JSON, "w") as f:
        json.dump(res, f, indent=2, default=float)
    print(f"wrote {BENCH_JSON}")
    return res


def _analytic_main() -> Dict:
    """The pre-ISSUE-1 analytic table (needs dry-run records on disk)."""
    res = run()
    if not res:
        print("no decode records — run the dry-run batch first "
              "(analytic table skipped)")
        return {}
    print("=== Decode compression analysis (paper §IV applied per regime) ===")
    print(f"{'arch':28s} {'cache%':>7s} {'int8-KV x':>10s}   "
          f"batch-1 BCSC x @ " +
          "/".join(f"{s:.0%}" for s in SPARSITIES))
    for arch, r in res.items():
        b1 = "/".join(f"{r[f'b1_bcsc_speedup_{s:.2f}']:.2f}"
                      for s in SPARSITIES)
        print(f"{arch:28s} {r['cache_share'] * 100:6.1f}% "
              f"{r['int8_cache_speedup']:10.2f}   {b1}")
    print("(analytic decode stream model; cache% = KV/state-cache share "
          "at batch 128;\n int8-KV x = step speedup from int8 cache; "
          "batch-1 BCSC x = weight-stream speedup\n from block-sparse "
          "weights at one slot — the paper's Sparse-PE regime)")
    return res


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="batch 1 only, 4 tokens (CI)")
    ap.add_argument("--no-engine", action="store_true",
                    help="skip the DecodeEngine wall-clock section")
    args = ap.parse_args()
    main(smoke=args.smoke, engine=not args.no_engine)
