"""Sparse/compressed decode analysis — what actually bounds the decode cells,
and which compression lever (paper §IV) moves each regime.

Measured finding (see decode_regimes()): at decode_32k's batch of 128 slots
the memory term is **KV-cache streaming** (the whole 32k-token cache is read
every step; weights amortize over the 128 slots — weight-stream share < 1%).
Weight sparsity (BCSC, the paper's Sparse PE) therefore pays at *small
batch*, while at large batch the paper-faithful compression move is applying
the same keep-it-compressed idea to the **cache** (int8 KV ≈ ×2 bytes).

ISSUE 1 additions:
* ``kernel_proxy`` — dense rs_matmul vs bcsc_gemv at decode shapes, grid-step
  counts (the interpret-mode proxy; on TPU the same harness wall-clocks).
* ``decode_benchmark`` — DecodeEngine tokens/sec, dense vs BCSC-packed params
  at batch {1, 4, 8}; written to BENCH_sparse_decode.json.

ISSUE 2 additions (the end-to-end gap PR 1 left):
* ``mlp_proxy`` — fused bcsc_mlp megakernel vs the two-call path: grid steps,
  payload block visits, and an HBM-bytes-moved model including the hidden-
  activation round-trip the megakernel eliminates. Wall-clock-free, so the
  CI perf guard (scripts/perf_guard.py) can enforce it in interpret mode.
* ``decode_benchmark`` now reports the sparse/dense end-to-end ratio as a
  first-class metric (vs the recorded PR 1 baseline 0.87 at batch 1), a
  per-phase prefill/decode breakdown from the engine's batched-prefill
  stats, and best-of-N timing (single-shot numbers on a shared CPU were
  ±30% noise).
* ``mlp_bound_analysis`` — the Eyexam-style analytic model (DESIGN.md §9)
  of *why* two-call lost, written to BENCH_sparse_decode.json["analytic"].

    PYTHONPATH=src python benchmarks/sparse_decode.py [--smoke] [--no-engine]
"""
from __future__ import annotations

import argparse
import glob
import json
import os
import time
from typing import Dict, List

import numpy as np

from repro.configs import get_config
from repro.core import eyexam
from repro.models import decoding

SPARSITIES = (0.5, 0.75, 0.9)
BCSC_OVERHEAD = 1.02     # index-vector bytes per payload byte
BENCH_JSON = "BENCH_sparse_decode.json"
PR1_E2E_RATIO_B1 = 0.87  # PR 1's recorded batch-1 sparse/dense tokens/sec
KERNEL_LAUNCH_S = 2e-6   # per-kernel dispatch overhead (TPU-class estimate)
ID_BYTES = 8             # row_id + col_id int32 per payload block


def decode_regimes(dryrun_dir: str = "results/dryrun_opt") -> Dict:
    out: Dict = {}
    for f in sorted(glob.glob(os.path.join(dryrun_dir,
                                           "*decode_32k__16x16*"))):
        r = json.load(open(f))
        if r["status"] != "ok":
            continue
        cfg = get_config(r["arch"])
        chips = r["chips"]
        # ANALYTIC decode stream model (the measured term stays conservative
        # on the CPU proxy — scan-carry cache rewrites that TPU aliasing
        # elides; see EXPERIMENTS.md D1). Per chip, per decode step:
        #   weights (active, bf16) + full KV/state-cache read.
        w_bytes = cfg.param_count(active_only=True) * 2 / chips
        cache = decoding.abstract_cache(cfg, 128, 32768)
        import jax
        c_bytes = sum(l.size * l.dtype.itemsize
                      for l in jax.tree.leaves(cache)) / chips
        t_w = w_bytes / eyexam.HBM_BW
        t_c = c_bytes / eyexam.HBM_BW
        t128 = t_w + t_c                      # batch-128 step
        rows: Dict = {
            "t_analytic_128_ms": t128 * 1e3,
            "cache_share": t_c / t128,
            "int8_cache_speedup": t128 / (t_w + t_c / 2),
        }
        # batch-1 regime (one slot): weights dominate; BCSC pays directly
        t1 = t_w + t_c / 128
        for sp in SPARSITIES:
            t1_sp = t_w * (1 - sp) * BCSC_OVERHEAD + t_c / 128
            rows[f"b1_bcsc_speedup_{sp:.2f}"] = t1 / t1_sp
        out[r["arch"]] = rows
    return out


# ------------------------------------------------------- ISSUE 1: fast path
def kernel_proxy(sparsities=SPARSITIES + (0.7,), K: int = 256, N: int = 512,
                 block: int = 16) -> Dict:
    """Batch-1 MLP projection: dense rs_matmul grid steps vs bcsc_gemv nnzb.

    Grid steps are the interpret-mode cost proxy (each step is one MXU-tile
    visit); both sides are normalized to the same (bk, bn) tiles so the ratio
    is exactly the structural-skip factor the paper's Sparse PE claims (§IV).
    """
    import jax.numpy as jnp
    from repro.core import sparsity as sp
    from repro.kernels import ops

    rng = np.random.default_rng(0)
    w = rng.standard_normal((K, N)).astype(np.float32)
    dense_blocks = (K // block) * (N // block)
    out: Dict = {"shape": [K, N], "block": block,
                 "dense_grid_steps": dense_blocks}
    for s in sorted(sparsities):
        ws = np.asarray(sp.block_magnitude_prune(jnp.asarray(w), s,
                                                 block, block))
        m = sp.bcsc_encode(ws, block, block)
        blocks, _, _, _ = ops.prepare_bcsc(m)
        steps = int(blocks.shape[0])
        out[f"sparsity_{s:.2f}"] = {
            "gemv_grid_steps": steps,
            "speedup_vs_dense": dense_blocks / max(steps, 1),
        }
    return out


# ------------------------------------------------- shared: pruned + packed
def _pruned_packed(arch: str, sparsity: float, block: int = 16):
    import jax
    import jax.numpy as jnp
    from repro.core import sparsity as sp
    from repro.models import transformer as tfm
    from repro.serve import sparse as sps

    cfg = get_config(arch)
    params = tfm.init_params(jax.random.PRNGKey(0), cfg)
    for slot in params.get("blocks", {}):
        mlp = params["blocks"][slot].get("mlp")
        if mlp:
            for nm in list(mlp):
                w = mlp[nm]
                mlp[nm] = jnp.stack([
                    sp.block_magnitude_prune(w[l], sparsity, block, block)
                    for l in range(w.shape[0])])
    packed, stats = sps.sparsify_mlp_params(params, cfg, sparsity=0.0)
    return cfg, params, packed, stats


# --------------------------------- ISSUE 2: fused megakernel vs two-call
def mlp_proxy(arch: str = "qwen2.5-3b-reduced", sparsity: float = 0.75,
              block: int = 16, bm: int = 8, stats: Dict = None) -> Dict:
    """Wall-clock-free cost model: fused bcsc_mlp vs the PR 1 two-call path.

    Counts, per decode token (M = bm activation rows) over every packed MLP
    layer of the model:

    * grid steps — sequential grid steps the kernel actually executes (the
      pipeline/prologue overhead proxy). Two-call visits one payload block
      per step and walks the full padded stack capacity. The megakernel's
      unrolled variant runs ONE step per m-tile; its gridded variant runs
      every capacity chunk step (a skipped chunk still spins its step — only
      its DMA and MACs are elided, which block visits/bytes capture).
    * work chunks — chunk-level units doing real DMA+MACs: capacity chunks
      for the unrolled variant (pads are masked, not skipped), ceil(real/C)
      for the gridded variant (whole pad chunks skipped).
    * block visits — payload blocks DMA'd from HBM. The megakernel's skip is
      chunk-granular, so its waste is < C blocks per segment vs the two-call
      path's full pad-to-densest-layer capacity.
    * hbm bytes — weight payload + index vectors + activations in/out
      **including the hidden-activation round-trip** (g/u written fp32, read
      for the gate product, h written bf16, re-read by the down projection)
      that exists only in the two-call path: the megakernel holds the hidden
      in VMEM scratch from first MAC to final drain.
    """
    from repro.kernels import bcsc_mlp as bmlp

    if stats is None:
        cfg, _, _, stats = _pruned_packed(arch, sparsity, block)
    else:
        cfg = get_config(arch)
    bb = block * block
    w_byte = 2                                   # bf16 payload (pack dtype)
    d = cfg.d_model
    ff = cfg.dense_d_ff if (cfg.moe and cfg.dense_d_ff) else cfg.d_ff
    gated = cfg.mlp_gated

    two = {"grid_steps": 0, "block_visits": 0, "hbm_bytes": 0,
           "kernel_launches": 0}
    fused = {"grid_steps": 0, "work_chunks": 0, "block_visits": 0,
             "hbm_bytes": 0, "kernel_launches": 0}
    weights = stats["weights"]
    names = list(weights)
    n_layers = len(weights[names[0]]["real"])
    for li in range(n_layers):
        seg = []                        # (real, padded, C) per projection
        for nm in names:
            w = weights[nm]
            P = w["padded"][li]
            seg.append((w["real"][li], P, bmlp._pick_chunk(P)))
        n_chunks = sum(p // c for _, p, c in seg)
        unrolled = n_chunks <= bmlp.UNROLL_CHUNKS_MAX

        # ---- two-call: one kernel per projection, 1 block per grid step
        two["kernel_launches"] += len(seg)
        for real, P, _ in seg:
            two["grid_steps"] += P
            two["block_visits"] += P
            two["hbm_bytes"] += P * (bb * w_byte + ID_BYTES)
        # activations: x read per up kernel, h read by the down kernel,
        # plus the hidden round-trip between the kernels
        ups = 2 if gated else 1
        two["hbm_bytes"] += ups * bm * d * 2          # x in (bf16) per up
        two["hbm_bytes"] += ups * bm * ff * 4         # g/u out (fp32)
        if gated:
            two["hbm_bytes"] += 2 * bm * ff * 4       # g,u re-read for gate
        two["hbm_bytes"] += bm * ff * 2               # h written bf16
        two["hbm_bytes"] += bm * ff * 2               # h read by down kernel
        two["hbm_bytes"] += bm * d * 4                # down out (fp32)

        # ---- fused megakernel: one launch, chunked walk, VMEM hidden
        fused["kernel_launches"] += 1
        fused["grid_steps"] += 1 if unrolled else n_chunks
        for real, P, C in seg:
            if unrolled:
                chunks = P // C          # whole (small) payload resident
            else:
                chunks = max(-(-real // C), 1)        # ceil: ragged skip
            fused["work_chunks"] += chunks
            fused["block_visits"] += chunks * C
            fused["hbm_bytes"] += chunks * C * (bb * w_byte + ID_BYTES)
        fused["hbm_bytes"] += bm * d * 2              # x in, VMEM-resident
        fused["hbm_bytes"] += bm * d * 4              # final out (fp32)

    return {
        "arch": arch, "sparsity": sparsity, "bm": bm,
        "block_density": stats.get("block_density"),
        "packing_efficiency": stats.get("packing_efficiency"),
        "per_weight_packing": {
            nm: {"real": w["real"], "padded": w["padded"],
                 "packing_efficiency": w["packing_efficiency"]}
            for nm, w in weights.items()},
        "two_call": two,
        "fused": fused,
        "ratios": {
            "grid_steps": two["grid_steps"] / max(fused["grid_steps"], 1),
            "block_visits": (two["block_visits"] /
                             max(fused["block_visits"], 1)),
            "hbm_bytes": two["hbm_bytes"] / max(fused["hbm_bytes"], 1),
        },
    }


def mlp_bound_analysis(arch: str = "qwen2.5-3b", sparsity: float = 0.75,
                       packing_efficiency: float = 0.93) -> Dict:
    """Eyexam-style bound shift (paper Appendix A; DESIGN.md §9).

    Why PR 1's two-call sparse path lost end-to-end at batch 1 even though
    its kernels won the grid-step proxy: the decode-step MLP time is

        t = t_weight_stream + t_hidden_roundtrip + n_launch · t_launch

    Sparsity only shrinks the first term. The two-call path *adds* the second
    term (the (bm × d_ff) hidden crosses HBM four times: fp32 out ×2, gate
    re-read, bf16 write + re-read) and triples the third — at full scale the
    hidden round-trip is small next to weights, but the launch term is pure
    overhead, and on the CPU interpret proxy (where per-launch cost is ~100×
    a TPU launch) it dominated, which is exactly the 0.87 ratio recorded in
    PR 1. The megakernel removes both added terms, so the bound returns to
    the weight stream — the only term sparsity can shrink.
    """
    cfg = get_config(arch)
    d, ff = cfg.d_model, cfg.d_ff
    bm, L = 8, cfg.num_layers
    ups = 2 if cfg.mlp_gated else 1
    w_dense = (ups * d * ff + ff * d) * 2            # bf16
    w_real = w_dense * (1 - sparsity) * BCSC_OVERHEAD
    w_padded = w_real / max(packing_efficiency, 1e-6)
    hidden_rt = bm * ff * (ups * 4 + (2 * 4 if ups == 2 else 0) + 2 + 2)
    xio = bm * d * (2 + 4)

    def t(bytes_, launches):
        return bytes_ / eyexam.HBM_BW + launches * KERNEL_LAUNCH_S

    t_dense = t(w_dense + hidden_rt + xio, ups + 1)
    t_two = t(w_padded + hidden_rt + xio, ups + 1)
    t_fused = t(w_real + xio, 1)
    return {
        "arch": arch, "sparsity": sparsity, "layers": L,
        "per_layer_bytes": {
            "weights_dense": w_dense,
            "weights_sparse_real": w_real,
            "weights_sparse_padded": w_padded,
            "hidden_roundtrip": hidden_rt,
            "act_in_out": xio,
        },
        "per_layer_time_s": {
            "dense": t_dense,
            "two_call_sparse": t_two,
            "fused_sparse": t_fused,
        },
        "speedup": {
            "two_call_vs_dense": t_dense / t_two,
            "fused_vs_dense": t_dense / t_fused,
            "fused_vs_two_call": t_two / t_fused,
        },
        "bound": "weight-stream (the term sparsity shrinks) once the hidden "
                 "round-trip and extra launches are fused away",
        "kernel_launch_s": KERNEL_LAUNCH_S,
    }


# --------------------------------------------------------- engine benchmark
def decode_benchmark(batches=(1, 4, 8), max_new: int = 8,
                     arch: str = "qwen2.5-3b-reduced",
                     sparsity: float = 0.75, sync_every: int = 4,
                     repeats: int = 5, prepacked=None) -> Dict:
    """DecodeEngine tokens/sec, dense vs BCSC-packed MLP weights.

    On this CPU container kernels run interpret=True, so the sparse wall-clock
    is NOT the headline (Python-interpreted kernels); the grid-step/bytes
    proxies (mlp_proxy) carry the perf claim. On TPU the same harness times
    the compiled kernels. host_syncs per generated token is reported as the
    device-residency check (must be << 1). Timing is best-of-``repeats``
    (interleaved warm engines — the min is the standard noise-robust
    estimator on a shared CPU; single-shot runs here vary ±30%); ``phases``
    reports the best run's batched-prefill/decode wall-clock split and pad
    overhead.
    """
    import jax
    from repro.serve.engine import DecodeEngine, Request

    # ``prepacked``: reuse a (cfg, params, packed, stats) tuple from
    # _pruned_packed instead of re-pruning+encoding the whole model
    cfg, params, packed, stats = prepacked or _pruned_packed(arch, sparsity)

    out: Dict = {"arch": arch, "sparsity": sparsity, "max_new": max_new,
                 "block_density": stats.get("block_density"),
                 "packing_efficiency": stats.get("packing_efficiency"),
                 "interpret_mode": jax.default_backend() != "tpu",
                 "repeats": repeats, "batches": {}}
    for b in batches:
        row: Dict = {}
        engines = {}
        for name, p in (("dense", params), ("sparse", packed)):
            eng = DecodeEngine(cfg, p, slots=b, cache_len=32,
                               eos_id=-1, sync_every=sync_every)
            eng.run([Request(rid=99, prompt=[5, 6, 7, 8], max_new=max_new)
                     for _ in range(b)])          # warmup / compile
            engines[name] = eng
        times: Dict[str, List] = {n: [] for n in engines}
        for _ in range(repeats):
            for name, eng in engines.items():     # interleaved A/B
                reqs = [Request(rid=i, prompt=[5, 6, 7, 8], max_new=max_new)
                        for i in range(b)]
                eng.host_syncs = 0
                t0 = time.perf_counter()
                done = eng.run(reqs)
                times[name].append((time.perf_counter() - t0,
                                    dict(eng.phase_stats), eng.host_syncs))
        for name, eng in engines.items():
            toks = b * max_new
            dt, st, syncs = min(times[name], key=lambda r: r[0])
            row[name] = {
                "tokens_per_s": toks / max(dt, 1e-9),
                "host_syncs_per_token": syncs / max(toks, 1),
                "phases": {
                    "prefill_s": st["prefill_s"],
                    "decode_s": st["decode_s"],
                    "prefill_batches": st["prefill_batches"],
                    "prefill_prompts": st["prefill_prompts"],
                    "prefill_real_tokens": st["prefill_real_tokens"],
                    "prefill_padded_tokens": st["prefill_padded_tokens"],
                },
            }
        row["e2e_ratio"] = (row["sparse"]["tokens_per_s"] /
                            max(row["dense"]["tokens_per_s"], 1e-9))
        out["batches"][str(b)] = row
    if "1" in out["batches"]:
        out["e2e_ratio_b1"] = out["batches"]["1"]["e2e_ratio"]
        out["pr1_baseline_e2e_ratio_b1"] = PR1_E2E_RATIO_B1
        out["improves_pr1_baseline"] = (
            out["e2e_ratio_b1"] > PR1_E2E_RATIO_B1)
    return out


def main(smoke: bool = False, engine: bool = True, repeats: int = None) -> Dict:
    sparsity = 0.75
    prepacked = _pruned_packed("qwen2.5-3b-reduced", sparsity)
    stats = prepacked[3]
    res: Dict = {
        "analytic": {
            "mlp_megakernel": mlp_bound_analysis(
                packing_efficiency=stats.get("packing_efficiency", 0.93)),
            "decode_regimes": decode_regimes(),
        },
        "kernel_proxy": kernel_proxy(),
        "mlp_proxy": mlp_proxy(sparsity=sparsity, stats=stats),
    }
    if engine:
        res["decode"] = decode_benchmark(
            batches=(1,) if smoke else (1, 4, 8),
            max_new=8,
            sparsity=sparsity,
            repeats=repeats or (5 if smoke else 7),
            prepacked=prepacked)

    kp = res["kernel_proxy"]
    print("=== Batch-1 BCSC GEMV vs dense RS grid steps "
          f"({kp['shape'][0]}x{kp['shape'][1]}, {kp['block']}-blocks) ===")
    print(f"dense grid steps: {kp['dense_grid_steps']}")
    for k in sorted(k for k in kp if k.startswith("sparsity_")):
        r = kp[k]
        print(f"  {k[9:]:>5s} block-sparse: {r['gemv_grid_steps']:5d} steps "
              f"-> {r['speedup_vs_dense']:.2f}x fewer")

    mp = res["mlp_proxy"]
    print(f"=== Fused bcsc_mlp vs two-call @ {mp['sparsity']:.0%} sparsity "
          f"({mp['arch']}) ===")
    for side in ("two_call", "fused"):
        r = mp[side]
        wc = f"  {r['work_chunks']:4d} work chunks" if "work_chunks" in r \
            else ""
        print(f"  {side:9s}: {r['grid_steps']:5d} grid steps  "
              f"{r['block_visits']:5d} block visits  "
              f"{r['hbm_bytes']:8d} HBM bytes  "
              f"{r['kernel_launches']:3d} launches{wc}")
    rr = mp["ratios"]
    print(f"  fused wins: {rr['grid_steps']:.2f}x steps, "
          f"{rr['hbm_bytes']:.2f}x bytes "
          f"(packing efficiency {mp['packing_efficiency']:.2f})")

    if engine:
        d = res["decode"]
        mode = "interpret (proxy only)" if d["interpret_mode"] else "compiled"
        print(f"=== DecodeEngine tokens/sec [{mode}] "
              f"{d['arch']} @ {d['sparsity']:.0%} sparsity ===")
        for b, row in d["batches"].items():
            ph = row["sparse"]["phases"]
            print(f"  batch {b}: dense {row['dense']['tokens_per_s']:8.2f} t/s"
                  f"  sparse {row['sparse']['tokens_per_s']:8.2f} t/s"
                  f"  ratio {row['e2e_ratio']:.3f}"
                  f"  (prefill {ph['prefill_s']*1e3:.1f}ms/"
                  f"{ph['prefill_batches']}b, decode {ph['decode_s']*1e3:.1f}ms,"
                  f" syncs/tok {row['sparse']['host_syncs_per_token']:.3f})")
        if "e2e_ratio_b1" in d:
            verdict = "improves" if d["improves_pr1_baseline"] else "REGRESSES"
            print(f"  batch-1 e2e sparse/dense ratio {d['e2e_ratio_b1']:.3f} "
                  f"{verdict} PR 1 baseline {PR1_E2E_RATIO_B1}")

    with open(BENCH_JSON, "w") as f:
        json.dump(res, f, indent=2, default=float)
    print(f"wrote {BENCH_JSON}")
    return res


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="batch 1 only (CI)")
    ap.add_argument("--no-engine", action="store_true",
                    help="skip the DecodeEngine wall-clock section")
    ap.add_argument("--repeats", type=int, default=None,
                    help="timing repeats per engine config (best-of)")
    args = ap.parse_args()
    main(smoke=args.smoke, engine=not args.no_engine, repeats=args.repeats)
